//! Multi-layer TNN digit recognition: trains 2/3/4-layer TNNs with online
//! STDP on the procedural digit corpus and reports the error-rate ordering
//! the paper's Table III cites, plus the scaled hardware PPA of the real
//! Table III designs.
//!
//! Run: `cargo run --release --example mnist_tnn`

use tnn7::harness;
use tnn7::mnist::{trainable_network, DigitCorpus};
use tnn7::tnn::encode::encode_image_onoff;
use tnn7::tnn::params::TnnParams;
use tnn7::tnn::VoteClassifier;
use tnn7::util::Rng64;

fn main() -> tnn7::Result<()> {
    let train = DigitCorpus::generate(60, 1);
    let test = DigitCorpus::generate(25, 2);
    println!("corpus: {} train / {} test synthetic digits (16x16)", train.len(), test.len());

    let mut errors = Vec::new();
    for layers in [2usize, 3, 4] {
        let mut rng = Rng64::seed_from_u64(layers as u64 * 101);
        let mut net = trainable_network(layers, TnnParams::default());
        net.randomize(&mut rng);
        for _epoch in 0..2 {
            for img in &train.images {
                net.step(&encode_image_onoff(img, 8), &mut rng);
            }
        }
        let mut vote = VoteClassifier::new(net.output_len(), 10);
        for (img, &l) in train.images.iter().zip(&train.labels) {
            vote.observe(&net.infer(&encode_image_onoff(img, 8)), l);
        }
        let mut correct = 0;
        for (img, &l) in test.images.iter().zip(&test.labels) {
            if vote.classify(&net.infer(&encode_image_onoff(img, 8))) == Some(l) {
                correct += 1;
            }
        }
        let err = 100.0 * (1.0 - correct as f64 / test.len() as f64);
        println!(
            "{layers}-layer TNN ({} synapses): error {err:.1}% ({correct}/{})",
            net.synapse_count(),
            test.len()
        );
        errors.push(err);
    }
    println!(
        "error ordering deeper-is-better: {}",
        if errors[0] >= errors[1] && errors[1] >= errors[2] { "holds" } else { "violated on this corpus" }
    );

    println!("\nTable III hardware PPA (paper designs, synapse-count scaled):");
    harness::print_table3(&harness::table3());
    Ok(())
}
