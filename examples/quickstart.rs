//! Quickstart: load the AOT-compiled XLA column, run a few gamma cycles of
//! online STDP learning, and cross-check against the Rust golden model.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use tnn7::runtime::XlaRuntime;
use tnn7::tnn::column::Column;
use tnn7::tnn::params::TnnParams;
use tnn7::tnn::spike::SpikeTime;
use tnn7::util::Rng64;

fn main() -> tnn7::Result<()> {
    let rt = XlaRuntime::load("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.column(16, 4, "step")?;
    let meta = &exe.meta;
    println!("loaded {} (p={}, q={}, θ={})", meta.name, meta.p, meta.q, meta.theta);

    let params = TnnParams::default();
    let mut rng = Rng64::seed_from_u64(1);
    let mut golden = Column::with_random_weights(meta.p, meta.q, meta.theta, params, &mut rng);
    let mut w: Vec<f32> = golden.weights().iter().map(|&x| x as f32).collect();

    for gamma in 0..5 {
        let xs: Vec<SpikeTime> = (0..meta.p)
            .map(|i| SpikeTime::at(((i + gamma) % 8) as u32))
            .collect();
        let n = meta.p * meta.q;
        let u_case: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let u_stab: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
        let (y, w_new) = exe.step(&xs, &w, &u_case, &u_stab)?;
        let uc: Vec<f64> = u_case.iter().map(|&v| v as f64).collect();
        let us: Vec<f64> = u_stab.iter().map(|&v| v as f64).collect();
        let gold = golden.step_with_uniforms(&xs, &uc, &us);
        assert_eq!(y, gold.output, "XLA and golden model agree");
        w = w_new;
        println!("gamma {gamma}: winner {:?}, output volley {:?}", gold.winner, y);
    }
    println!("quickstart OK — XLA kernel bit-exact with the golden model");
    Ok(())
}
