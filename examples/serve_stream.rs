//! Serving demo: the coordinator streaming gamma instances through the XLA
//! column with backpressure, reporting throughput and step latency — the
//! "edge-native sensory processing unit" in software.
//!
//! Run: `make artifacts && cargo run --release --example serve_stream`

use tnn7::coordinator::{encode_ucr, run_stream, Engine};
use tnn7::runtime::XlaRuntime;
use tnn7::ucr;
use tnn7::util::Rng64;

fn main() -> tnn7::Result<()> {
    let rt = XlaRuntime::load("artifacts")?;
    println!("platform {} | artifacts: {:?}", rt.platform(), rt.artifact_names());
    let dataset = ucr::ucr_suite().into_iter().find(|c| c.name == "TwoLeadECG").unwrap();
    let data = ucr::generate(dataset, 150, 9);
    let items = encode_ucr(&data, 8);
    let mut rng = Rng64::seed_from_u64(4);
    let exe = rt.column(dataset.p, dataset.q, "step")?;
    let mut engine = Engine::xla(exe, &mut rng);
    for depth in [1usize, 8, 64] {
        let out = run_stream(&mut engine, items.clone(), depth, 7)?;
        println!("channel depth {depth:>3}: {}", out.metrics.summary(out.wall));
    }
    Ok(())
}
