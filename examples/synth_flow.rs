//! Synthesis-flow walkthrough: build the 82×2 TwoLeadECG column, run both
//! flows, and print the netlist statistics, PPA and layout congestion —
//! Figs. 12/13 for a single design point.
//!
//! Run: `cargo run --release --example synth_flow`

use tnn7::cells;
use tnn7::gates::column_design::{build_column, BrvSource};
use tnn7::harness;
use tnn7::layout::place_and_estimate;
use tnn7::ppa::report::analyze;
use tnn7::synth::flow::{synthesize, Flow};

fn main() {
    let (p, q) = (82, 2);
    let theta = (p as u32 * 7) / 4;
    let d = build_column(p, q, theta, BrvSource::Lfsr);
    println!(
        "built column_{p}x{q}: {} generic gates, {} macro instances",
        d.netlist.len(),
        d.netlist.macros.len()
    );
    for flow in [Flow::Baseline, Flow::Tnn7] {
        let out = synthesize(&d.netlist, flow);
        let lib = flow.library();
        let rep = analyze(&out.mapped, &lib, harness::GAMMA_CYCLES);
        let lay = place_and_estimate(&out.mapped, &lib);
        println!("\n=== {} flow ===", flow.name());
        println!(
            "  synthesis: {:?} total (expand {:?}, optimize {:?} in {} iters, map {:?})",
            out.stats.wall, out.stats.expand_wall, out.stats.opt_wall,
            out.stats.opt.iterations, out.stats.map_wall
        );
        println!(
            "  gates in {} → cells out {} + {} hard macros",
            out.stats.gates_in, out.stats.cells_out, out.stats.macros_out
        );
        println!("  {}", rep.row());
        println!(
            "  layout: die {:.1}x{:.1} µm, WL {:.0} µm, congestion avg {:.2} peak {:.2}",
            lay.die_w_um, lay.die_h_um, lay.total_wl_um, lay.avg_congestion, lay.peak_congestion
        );
    }
}
