//! End-to-end driver (DESIGN.md §6 E2E): unsupervised time-series
//! clustering through the full stack — synthetic UCR workload → streaming
//! coordinator → XLA column executable (PJRT) with online STDP → clustering
//! metrics — followed by the hardware story for the same column: synthesis
//! under both flows + PPA.
//!
//! Run: `make artifacts && cargo run --release --example ucr_clustering`

use tnn7::cells;
use tnn7::coordinator::{encode_ucr, run_stream, ucr_engine, volley_density, Engine};
use tnn7::gates::column_design::{build_column, BrvSource};
use tnn7::ppa::report::analyze;
use tnn7::runtime::XlaRuntime;
use tnn7::synth::flow::{synthesize, Flow};
use tnn7::tnn::params::TnnParams;
use tnn7::ucr;
use tnn7::util::Rng64;

fn main() -> tnn7::Result<()> {
    let dataset = ucr::ucr_suite()
        .into_iter()
        .find(|c| c.name == "TwoLeadECG")
        .unwrap();
    let data = ucr::generate(dataset, 100, 5);
    let items = encode_ucr(&data, 8);
    println!(
        "TwoLeadECG: {} instances, spike density {:.2}",
        items.len(),
        volley_density(&items)
    );

    // --- functional pipeline: golden engine (always available) -------------
    let mut rng = Rng64::seed_from_u64(2);
    let mut engine = ucr_engine(dataset.p, dataset.q, &items, TnnParams::default(), &mut rng);
    let mut last = None;
    for epoch in 0..5 {
        let out = run_stream(&mut engine, items.clone(), 32, 5 + epoch)?;
        if epoch == 0 || epoch == 4 {
            println!("epoch {epoch}: {}", out.metrics.summary(out.wall));
        }
        last = Some(out);
    }
    let _ = last;
    let mut pred = Vec::new();
    let mut truth = Vec::new();
    for item in &items {
        if let (Some(w), Some(l)) = (engine.infer_winner(&item.volley)?, item.label) {
            pred.push(w);
            truth.push(l);
        }
    }
    println!(
        "golden engine: rand index {:.3}, purity {:.3}",
        ucr::rand_index(&pred, &truth),
        ucr::purity(&pred, &truth, dataset.q, dataset.q)
    );

    // --- production path: XLA executable through PJRT ----------------------
    match XlaRuntime::load("artifacts") {
        Ok(rt) => {
            let exe = rt.column(dataset.p, dataset.q, "step")?;
            let mut rng = Rng64::seed_from_u64(3);
            let mut xla_engine = Engine::xla(exe, &mut rng);
            let out = run_stream(&mut xla_engine, items.clone(), 32, 11)?;
            println!(
                "xla engine ({}): {}",
                rt.platform(),
                out.metrics.summary(out.wall)
            );
        }
        Err(e) => println!("(XLA path skipped: {e})"),
    }

    // --- hardware story: synthesize the same column both ways --------------
    let theta = (dataset.p as u32 * 7) / 4;
    let d = build_column(dataset.p, dataset.q, theta, BrvSource::Lfsr);
    let base = synthesize(&d.netlist, Flow::Baseline);
    let t7 = synthesize(&d.netlist, Flow::Tnn7);
    let rb = analyze(&base.mapped, &cells::asap7(), 16);
    let r7 = analyze(&t7.mapped, &cells::tnn7(), 16);
    println!("hardware (82x2 column):");
    println!("  {}", rb.row());
    println!("  {}", r7.row());
    let (p, dl, a, e) = r7.improvement_vs(&rb);
    println!("  TNN7 improvements: power {p:.0}%, delay {dl:.0}%, area {a:.0}%, EDP {e:.0}%");
    Ok(())
}
