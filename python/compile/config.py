"""Shared configuration for the TNN column kernels.

These constants mirror rust/src/tnn/params.rs (the golden model) — the two
sides are kept bit-compatible so the Rust coordinator can cross-check XLA
results against its own cycle-level reference.
"""

from dataclasses import dataclass, field


# f32 sentinel for "no spike" (temporal infinity). Matches
# rust/src/tnn/spike.rs::SpikeTime::INF_F32.
INF = 1.0e9


@dataclass(frozen=True)
class ColumnConfig:
    """Static configuration of one p×q TNN column kernel.

    Every field is baked into the lowered HLO (one artifact per
    configuration); only the spike volley, weights and uniform draws are
    runtime inputs.
    """

    p: int                      # synapses per neuron
    q: int                      # neurons per column
    theta: int                  # firing threshold
    weight_bits: int = 3        # 3-bit weights => w_max = 7
    gamma_cycles: int = 16      # unit cycles per gamma cycle
    mu_capture: float = 1.0
    mu_minus: float = 0.5
    mu_search: float = 1.0 / 16.0
    mu_backoff: float = 0.5
    stabilize: bool = True
    batch: int = 1              # gamma instances processed per call

    @property
    def w_max(self) -> int:
        return (1 << self.weight_bits) - 1

    @property
    def t_max(self) -> int:
        return 1 << self.weight_bits

    @property
    def name(self) -> str:
        base = f"column_p{self.p}_q{self.q}_th{self.theta}"
        if self.batch > 1:
            base += f"_b{self.batch}"
        return base

    def validate(self) -> None:
        assert self.p >= 1 and self.q >= 1, "p,q must be >= 1"
        assert 1 <= self.weight_bits <= 6
        assert self.gamma_cycles >= 2 * self.t_max, (
            "gamma_cycles must cover the latest ramp"
        )
        assert self.theta >= 1
        for mu in (self.mu_capture, self.mu_minus, self.mu_search, self.mu_backoff):
            assert 0.0 <= mu <= 1.0


def default_theta(p: int, weight_bits: int = 3) -> int:
    """θ ∝ p·w_max sizing rule (mirrors TnnParams::default_theta)."""
    w_max = (1 << weight_bits) - 1
    return max(1, (p * w_max) // 4)
