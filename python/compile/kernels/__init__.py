"""Layer-1 kernels: Pallas implementations (`column`) and the pure-jnp
oracle (`ref`) they are verified against."""

from . import column, ref  # noqa: F401
