"""Pallas kernels for the TNN column gamma-cycle step (Layer 1).

Hardware adaptation (see DESIGN.md §3): the ASIC's temporally-unrolled
datapath — p×q RNL synapse ramps feeding per-neuron adder trees over
`gamma_cycles` unit clocks — is folded into dense relational arithmetic on
spike-time integers. The (G, p) ramp relation is materialised in VMEM and
reduced over the synapse axis per neuron tile, which is the TPU-native
expression of the adder tree (VPU masked reductions; MXU-eligible when the
clamp is rewritten as masked matmul for large p).

Two kernels are exposed:

  * `body_kernel`  — grid over neuron tiles; computes pre-inhibition fire
    times. BlockSpec keeps the full input volley (p ≤ ~1.6k ⇒ ≤ 6.4 KB)
    resident while streaming weight tiles HBM→VMEM.
  * `stdp_kernel`  — grid over neuron tiles; elementwise (p, TQ) weight
    update gated by broadcast STDP case masks.

WTA is a q-length min/argmin — far too small to benefit from a kernel, so it
stays in the surrounding jnp (fused by XLA into the same HLO module).

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness is the target here (real-TPU efficiency
is estimated analytically in EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import INF, ColumnConfig


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _neuron_tile(q: int) -> int:
    """Neuron-axis tile: multiples of 8 up to 128 (VPU lane friendly)."""
    if q >= 128:
        return 128
    for t in (64, 32, 16, 8):
        if q % t == 0 and q >= t:
            return t
    return q


# --------------------------------------------------------------------------
# body: pre-inhibition fire times
# --------------------------------------------------------------------------

def _body_kernel(x_ref, w_ref, y_ref, *, cfg: ColumnConfig):
    """One neuron tile: fire time of each neuron in the tile.

    x_ref: (p,)    w_ref: (p, TQ)    y_ref: (TQ,)
    """
    x = x_ref[...]                      # (p,)
    w = w_ref[...]                      # (p, TQ)
    g = cfg.gamma_cycles
    ts = jnp.arange(g, dtype=jnp.float32)                   # (G,)
    ramp = jnp.maximum(ts[:, None] + 1.0 - x[None, :], 0.0)  # (G, p)
    # Potential of each neuron at each cycle: clamp at per-synapse weight and
    # reduce over the synapse axis. (G, p, TQ) intermediate lives in VMEM.
    pot = jnp.minimum(ramp[:, :, None], w[None, :, :]).sum(axis=1)  # (G, TQ)
    fired = pot >= float(cfg.theta)
    any_fired = fired.any(axis=0)
    first_t = jnp.argmax(fired, axis=0).astype(jnp.float32)
    y_ref[...] = jnp.where(any_fired, first_t, INF)


def body_fire_times(x, w, cfg: ColumnConfig):
    """Pallas-tiled pre-inhibition fire times: (q,)."""
    q = cfg.q
    tq = _neuron_tile(q)
    grid = (_ceil_div(q, tq),)
    return pl.pallas_call(
        functools.partial(_body_kernel, cfg=cfg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cfg.p,), lambda j: (0,)),        # x: whole volley
            pl.BlockSpec((cfg.p, tq), lambda j: (0, j)),   # w: neuron tile
        ],
        out_specs=pl.BlockSpec((tq,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=True,
    )(x, w)


# --------------------------------------------------------------------------
# STDP: weight update
# --------------------------------------------------------------------------

def _stdp_kernel(x_ref, yout_ref, w_ref, ucase_ref, ustab_ref, wnew_ref,
                 *, cfg: ColumnConfig):
    """One neuron tile of the STDP update.

    x_ref: (p,)  yout_ref: (TQ,)  w/u/wnew: (p, TQ)
    """
    x = x_ref[...]
    y_out = yout_ref[...]
    w = w_ref[...]
    u_case = ucase_ref[...]
    u_stab = ustab_ref[...]

    ein = (x < INF * 0.5)[:, None]
    eout = (y_out < INF * 0.5)[None, :]
    xb = x[:, None]
    yb = y_out[None, :]

    capture = ein & eout & (xb <= yb)
    minus = ein & eout & (xb > yb)
    search = ein & ~eout
    backoff = ~ein & eout

    mu = (
        capture * cfg.mu_capture
        + minus * cfg.mu_minus
        + search * cfg.mu_search
        + backoff * cfg.mu_backoff
    ).astype(jnp.float32)

    inc = capture | search
    dec = minus | backoff

    w_max = float(cfg.w_max)
    if cfg.stabilize:
        stab_gate = jnp.where(
            inc,
            (w + 1.0) / (w_max + 1.0),
            (w_max - w + 1.0) / (w_max + 1.0),
        )
    else:
        stab_gate = jnp.ones_like(w)

    fire = (u_case < mu) & (u_stab < stab_gate) & (inc | dec)
    delta = jnp.where(inc, 1.0, -1.0)
    wnew_ref[...] = jnp.clip(w + jnp.where(fire, delta, 0.0), 0.0, w_max)


def stdp_update(x, y_out, w, u_case, u_stab, cfg: ColumnConfig):
    """Pallas-tiled STDP weight update: (p, q)."""
    p, q = cfg.p, cfg.q
    tq = _neuron_tile(q)
    grid = (_ceil_div(q, tq),)
    pq_spec = pl.BlockSpec((p, tq), lambda j: (0, j))
    return pl.pallas_call(
        functools.partial(_stdp_kernel, cfg=cfg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p,), lambda j: (0,)),
            pl.BlockSpec((tq,), lambda j: (j,)),
            pq_spec,
            pq_spec,
            pq_spec,
        ],
        out_specs=pq_spec,
        out_shape=jax.ShapeDtypeStruct((p, q), jnp.float32),
        interpret=True,
    )(x, y_out, w, u_case, u_stab)


# --------------------------------------------------------------------------
# composition
# --------------------------------------------------------------------------

def wta(y_body):
    """1-WTA (earliest wins, lowest index breaks ties) — q-length, stays jnp."""
    q = y_body.shape[0]
    winner = jnp.argmin(y_body)
    has_spike = y_body[winner] < INF * 0.5
    mask = (jnp.arange(q) == winner) & has_spike
    return jnp.where(mask, y_body, INF)


def column_step(x, w, u_case, u_stab, cfg: ColumnConfig):
    """One gamma cycle (inference + WTA + STDP) built from the Pallas
    kernels. Returns (y_out, w_new)."""
    y_body = body_fire_times(x, w, cfg)
    y_out = wta(y_body)
    w_new = stdp_update(x, y_out, w, u_case, u_stab, cfg)
    return y_out, w_new


def column_infer(x, w, cfg: ColumnConfig):
    """Inference only."""
    return wta(body_fire_times(x, w, cfg))
