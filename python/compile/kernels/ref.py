"""Pure-jnp oracle for the TNN column gamma-cycle step.

This is the executable specification the Pallas kernels are tested against
(pytest + hypothesis sweep shapes and inputs). It mirrors, operation for
operation, the Rust golden model in rust/src/tnn/ — same RNL response, same
WTA tie-break, same STDP case table and bimodal stabilization.

Wire format (all f32):
  x        (p,)   input spike times; INF = no spike
  w        (p,q)  integer-valued weights in [0, w_max]
  u_case   (p,q)  uniforms in [0,1) gating the per-case Bernoulli draw
  u_stab   (p,q)  uniforms in [0,1) gating the stabilization draw
returns
  y_out    (q,)   post-WTA output spike times (at most one finite entry)
  w_new    (p,q)  updated weights
"""

import jax.numpy as jnp

from ..config import INF, ColumnConfig


def body_potentials(x, w, cfg: ColumnConfig):
    """Integrated body potential per (unit cycle, neuron): (G, q).

    RNL semantics: synapse i contributes clamp(t+1-x_i, 0, w_ij) at the end
    of unit cycle t (the integral of a width-w pulse starting at x_i).
    """
    ts = jnp.arange(cfg.gamma_cycles, dtype=jnp.float32)  # (G,)
    # (G, p): per-cycle elapsed ramp of each input line, before clamping.
    ramp = ts[:, None] + 1.0 - x[None, :]
    ramp = jnp.maximum(ramp, 0.0)
    # (G, p, q): clamp each line's ramp at its per-neuron weight, then sum i.
    contrib = jnp.minimum(ramp[:, :, None], w[None, :, :])
    return contrib.sum(axis=1)  # (G, q)


def body_fire_times(x, w, cfg: ColumnConfig):
    """Pre-inhibition fire time per neuron: first t with potential ≥ θ."""
    pot = body_potentials(x, w, cfg)  # (G, q)
    fired = pot >= float(cfg.theta)
    any_fired = fired.any(axis=0)
    first_t = jnp.argmax(fired, axis=0).astype(jnp.float32)
    return jnp.where(any_fired, first_t, INF)


def wta(y_body):
    """1-WTA lateral inhibition: earliest spike wins, ties to lowest index."""
    q = y_body.shape[0]
    winner = jnp.argmin(y_body)  # argmin returns the first minimal index
    has_spike = y_body[winner] < INF * 0.5
    mask = (jnp.arange(q) == winner) & has_spike
    return jnp.where(mask, y_body, INF)


def stdp(x, y_out, w, u_case, u_stab, cfg: ColumnConfig):
    """Four-case probabilistic STDP with bimodal stabilization."""
    ein = (x < INF * 0.5)[:, None]        # (p,1)
    eout = (y_out < INF * 0.5)[None, :]   # (1,q)
    xb = x[:, None]
    yb = y_out[None, :]

    capture = ein & eout & (xb <= yb)
    minus = ein & eout & (xb > yb)
    search = ein & ~eout
    backoff = ~ein & eout

    mu = (
        capture * cfg.mu_capture
        + minus * cfg.mu_minus
        + search * cfg.mu_search
        + backoff * cfg.mu_backoff
    ).astype(jnp.float32)

    inc = capture | search
    dec = minus | backoff

    w_max = float(cfg.w_max)
    if cfg.stabilize:
        stab_gate = jnp.where(
            inc,
            (w + 1.0) / (w_max + 1.0),
            (w_max - w + 1.0) / (w_max + 1.0),
        )
    else:
        stab_gate = jnp.ones_like(w)

    fire = (u_case < mu) & (u_stab < stab_gate) & (inc | dec)
    delta = jnp.where(inc, 1.0, -1.0)
    w_new = jnp.clip(w + jnp.where(fire, delta, 0.0), 0.0, w_max)
    return w_new


def column_step(x, w, u_case, u_stab, cfg: ColumnConfig):
    """One full gamma cycle: inference + WTA + STDP. Returns (y_out, w_new)."""
    y_body = body_fire_times(x, w, cfg)
    y_out = wta(y_body)
    w_new = stdp(x, y_out, w, u_case, u_stab, cfg)
    return y_out, w_new


def column_infer(x, w, cfg: ColumnConfig):
    """Inference only (no learning). Returns y_out."""
    return wta(body_fire_times(x, w, cfg))
