"""Layer-2 JAX model: gamma-cycle column step functions built on the Pallas
kernels, plus batched variants, ready for AOT lowering.

The Rust coordinator (Layer 3) drives these as compiled XLA executables; the
functions here define the exact HLO modules that end up in artifacts/.

Exported entry points (all shapes static per ColumnConfig):

  column_step   (x, w, u_case, u_stab) -> (y_out, w_new)     learning step
  column_infer  (x, w)                 -> (y_out,)           inference only
  column_step_batched / column_infer_batched: scan over a batch of gamma
      instances, threading the weights through (online learning across the
      batch, exactly like B sequential gamma cycles — the coordinator's
      batching optimisation).
"""

import jax
import jax.numpy as jnp

from .config import ColumnConfig
from .kernels import column as K


def column_step(cfg: ColumnConfig):
    """Returns the single-instance learning-step function."""

    def step(x, w, u_case, u_stab):
        y_out, w_new = K.column_step(x, w, u_case, u_stab, cfg)
        return (y_out, w_new)

    return step


def column_infer(cfg: ColumnConfig):
    """Returns the single-instance inference function."""

    def infer(x, w):
        return (K.column_infer(x, w, cfg),)

    return infer


def column_step_batched(cfg: ColumnConfig):
    """Returns a function processing `cfg.batch` gamma instances serially
    (scan), threading weight updates through — bit-identical to calling the
    single-instance step B times, but one host↔device round-trip.

    x: (B, p), u_case/u_stab: (B, p, q), w: (p, q)
    returns y_out: (B, q), w_new: (p, q)
    """

    def step(xs, w, u_cases, u_stabs):
        def body(w, inputs):
            x, u_case, u_stab = inputs
            y_out, w_new = K.column_step(x, w, u_case, u_stab, cfg)
            return w_new, y_out

        w_new, ys = jax.lax.scan(body, w, (xs, u_cases, u_stabs))
        return (ys, w_new)

    return step


def column_infer_batched(cfg: ColumnConfig):
    """Batched inference (vmap — instances are independent).

    x: (B, p), w: (p, q) -> y_out: (B, q)
    """

    def infer(xs, w):
        return (jax.vmap(lambda x: K.column_infer(x, w, cfg))(xs),)

    return infer


def example_args(cfg: ColumnConfig, kind: str):
    """ShapeDtypeStructs for lowering each entry-point kind."""
    f32 = jnp.float32
    p, q, b = cfg.p, cfg.q, cfg.batch
    if kind == "step":
        return (
            jax.ShapeDtypeStruct((p,), f32),
            jax.ShapeDtypeStruct((p, q), f32),
            jax.ShapeDtypeStruct((p, q), f32),
            jax.ShapeDtypeStruct((p, q), f32),
        )
    if kind == "infer":
        return (
            jax.ShapeDtypeStruct((p,), f32),
            jax.ShapeDtypeStruct((p, q), f32),
        )
    if kind == "step_batched":
        return (
            jax.ShapeDtypeStruct((b, p), f32),
            jax.ShapeDtypeStruct((p, q), f32),
            jax.ShapeDtypeStruct((b, p, q), f32),
            jax.ShapeDtypeStruct((b, p, q), f32),
        )
    if kind == "infer_batched":
        return (
            jax.ShapeDtypeStruct((b, p), f32),
            jax.ShapeDtypeStruct((p, q), f32),
        )
    raise ValueError(f"unknown kind {kind!r}")


def entry_point(cfg: ColumnConfig, kind: str):
    """The function object for a given entry-point kind."""
    return {
        "step": column_step,
        "infer": column_infer,
        "step_batched": column_step_batched,
        "infer_batched": column_infer_batched,
    }[kind](cfg)
