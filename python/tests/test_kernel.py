"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps column geometries and input distributions; every case
asserts exact equality (the computation is integer-valued in f32, so
allclose with zero tolerance is the right bar).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.config import INF, ColumnConfig, default_theta
from compile.kernels import column as K
from compile.kernels import ref


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def make_inputs(rng: np.random.Generator, cfg: ColumnConfig,
                spike_prob: float = 0.8):
    x = np.where(
        rng.random(cfg.p) < spike_prob,
        rng.integers(0, cfg.t_max, cfg.p).astype(np.float32),
        np.float32(INF),
    ).astype(np.float32)
    w = rng.integers(0, cfg.w_max + 1, (cfg.p, cfg.q)).astype(np.float32)
    u_case = rng.random((cfg.p, cfg.q)).astype(np.float32)
    u_stab = rng.random((cfg.p, cfg.q)).astype(np.float32)
    return x, w, u_case, u_stab


def assert_step_matches(cfg: ColumnConfig, x, w, u_case, u_stab):
    y_k, w_k = K.column_step(jnp.asarray(x), jnp.asarray(w),
                             jnp.asarray(u_case), jnp.asarray(u_stab), cfg)
    y_r, w_r = ref.column_step(jnp.asarray(x), jnp.asarray(w),
                               jnp.asarray(u_case), jnp.asarray(u_stab), cfg)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    return np.asarray(y_k), np.asarray(w_k)


# ---------------------------------------------------------------------------
# directed cases
# ---------------------------------------------------------------------------

def test_single_synapse_fire_time_matches_hand_computation():
    # w=3, spike at x=2, theta=3: potential 1,2,3 at t=2,3,4 -> fires t=4.
    cfg = ColumnConfig(p=1, q=1, theta=3)
    y = np.asarray(K.column_infer(jnp.asarray([2.0]), jnp.asarray([[3.0]]), cfg))
    assert y[0] == 4.0


def test_unreachable_theta_never_fires():
    cfg = ColumnConfig(p=2, q=1, theta=100)
    y = np.asarray(K.column_infer(
        jnp.asarray([0.0, 0.0]), jnp.asarray([[7.0], [7.0]]), cfg))
    assert y[0] >= INF * 0.5


def test_wta_tie_breaks_to_lowest_index():
    # Two identical neurons -> both fire at the same t; index 0 must win.
    cfg = ColumnConfig(p=2, q=2, theta=2)
    w = jnp.asarray([[7.0, 7.0], [7.0, 7.0]])
    y = np.asarray(K.column_infer(jnp.asarray([0.0, 0.0]), w, cfg))
    assert y[0] < INF * 0.5
    assert y[1] >= INF * 0.5


def test_capture_and_backoff_update_weights():
    # p=2: line 0 spikes at 0, line 1 silent. q=1 neuron fires.
    cfg = ColumnConfig(p=2, q=1, theta=1)
    x = jnp.asarray([0.0, INF])
    w = jnp.asarray([[3.0], [3.0]])
    zeros = jnp.zeros((2, 1))
    y, w_new = K.column_step(x, w, zeros, zeros, cfg)
    assert np.asarray(y)[0] < INF * 0.5
    # line 0: capture (u=0 passes) -> 4; line 1: backoff -> 2.
    np.testing.assert_array_equal(np.asarray(w_new), [[4.0], [2.0]])


def test_no_input_no_update():
    cfg = ColumnConfig(p=3, q=2, theta=1)
    x = jnp.full((3,), INF)
    w = jnp.full((3, 2), 4.0)
    zeros = jnp.zeros((3, 2))
    y, w_new = K.column_step(x, w, zeros, zeros, cfg)
    assert (np.asarray(y) >= INF * 0.5).all()
    np.testing.assert_array_equal(np.asarray(w_new), np.asarray(w))


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=40),
    q=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    spike_prob=st.floats(min_value=0.0, max_value=1.0),
)
def test_kernel_matches_ref_on_random_columns(p, q, seed, spike_prob):
    rng = np.random.default_rng(seed)
    cfg = ColumnConfig(p=p, q=q, theta=default_theta(p))
    x, w, u_case, u_stab = make_inputs(rng, cfg, spike_prob)
    y, w_new = assert_step_matches(cfg, x, w, u_case, u_stab)
    # Invariants: at most one output spike; weights stay in range.
    assert (y < INF * 0.5).sum() <= 1
    assert w_new.min() >= 0 and w_new.max() <= cfg.w_max


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    theta=st.integers(min_value=1, max_value=60),
)
def test_kernel_matches_ref_across_thetas(seed, theta):
    rng = np.random.default_rng(seed)
    cfg = ColumnConfig(p=12, q=5, theta=theta)
    x, w, u_case, u_stab = make_inputs(rng, cfg)
    assert_step_matches(cfg, x, w, u_case, u_stab)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_matches_ref_without_stabilization(seed):
    rng = np.random.default_rng(seed)
    cfg = ColumnConfig(p=10, q=3, theta=8, stabilize=False)
    x, w, u_case, u_stab = make_inputs(rng, cfg)
    assert_step_matches(cfg, x, w, u_case, u_stab)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    weight_bits=st.integers(min_value=2, max_value=4),
)
def test_kernel_matches_ref_across_weight_precisions(seed, weight_bits):
    rng = np.random.default_rng(seed)
    cfg = ColumnConfig(p=9, q=4, theta=6, weight_bits=weight_bits,
                       gamma_cycles=2 ** (weight_bits + 1))
    x, w, u_case, u_stab = make_inputs(rng, cfg)
    assert_step_matches(cfg, x, w, u_case, u_stab)


@pytest.mark.parametrize("q", [1, 2, 3, 8, 16, 17])
def test_neuron_tiling_boundaries(q):
    """Tile-boundary geometries (q not a multiple of the tile)."""
    rng = np.random.default_rng(q)
    cfg = ColumnConfig(p=20, q=q, theta=default_theta(20))
    x, w, u_case, u_stab = make_inputs(rng, cfg)
    assert_step_matches(cfg, x, w, u_case, u_stab)
