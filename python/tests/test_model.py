"""Layer-2 model tests: batched semantics, entry-point shapes, AOT lowering."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.config import INF, ColumnConfig, default_theta
from compile.kernels import column as K


def rng_inputs(cfg, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    shape = (cfg.p,) if batch is None else (batch, cfg.p)
    x = np.where(
        rng.random(shape) < 0.8,
        rng.integers(0, cfg.t_max, shape).astype(np.float32),
        np.float32(INF),
    ).astype(np.float32)
    w = rng.integers(0, cfg.w_max + 1, (cfg.p, cfg.q)).astype(np.float32)
    ushape = (cfg.p, cfg.q) if batch is None else (batch, cfg.p, cfg.q)
    u1 = rng.random(ushape).astype(np.float32)
    u2 = rng.random(ushape).astype(np.float32)
    return x, w, u1, u2


def test_batched_step_equals_sequential_steps():
    cfg = ColumnConfig(p=10, q=3, theta=default_theta(10), batch=5)
    xs, w, u1s, u2s = rng_inputs(cfg, seed=1, batch=5)
    ys_b, w_b = model.column_step_batched(cfg)(
        jnp.asarray(xs), jnp.asarray(w), jnp.asarray(u1s), jnp.asarray(u2s))
    # sequential reference
    w_seq = jnp.asarray(w)
    ys_seq = []
    for i in range(5):
        y, w_seq = K.column_step(jnp.asarray(xs[i]), w_seq,
                                 jnp.asarray(u1s[i]), jnp.asarray(u2s[i]), cfg)
        ys_seq.append(np.asarray(y))
    np.testing.assert_array_equal(np.asarray(ys_b), np.stack(ys_seq))
    np.testing.assert_array_equal(np.asarray(w_b), np.asarray(w_seq))


def test_batched_infer_is_independent_per_instance():
    cfg = ColumnConfig(p=8, q=2, theta=4, batch=3)
    xs, w, _, _ = rng_inputs(cfg, seed=2, batch=3)
    (ys,) = model.column_infer_batched(cfg)(jnp.asarray(xs), jnp.asarray(w))
    for i in range(3):
        (y_single,) = model.column_infer(cfg)(jnp.asarray(xs[i]), jnp.asarray(w))
        np.testing.assert_array_equal(np.asarray(ys)[i], np.asarray(y_single))


@pytest.mark.parametrize("kind", ["step", "infer", "step_batched", "infer_batched"])
def test_entry_points_trace_with_example_args(kind):
    cfg = ColumnConfig(p=6, q=2, theta=3, batch=4)
    fn = model.entry_point(cfg, kind)
    args = model.example_args(cfg, kind)
    lowered = jax.jit(fn).lower(*args)
    assert lowered is not None


def test_aot_lowering_emits_parseable_hlo_text():
    cfg = ColumnConfig(p=4, q=2, theta=2)
    text = aot.lower_entry(cfg, "step")
    assert text.startswith("HloModule")
    assert "f32[4,2]" in text  # weight parameter shape present
    # return_tuple=True => tuple-shaped ROOT
    assert "(f32[2]" in text


def test_registry_configs_are_valid():
    for cfg, kinds in aot.registry():
        cfg.validate()
        for kind in kinds:
            assert kind in ("step", "infer", "step_batched", "infer_batched")
            # batched kinds require batch > 1 configs
            if "batched" in kind:
                assert cfg.batch > 1


def test_artifact_names_are_unique():
    names = [
        aot.artifact_name(cfg, kind)
        for cfg, kinds in aot.registry()
        for kind in kinds
    ]
    assert len(names) == len(set(names))
