//! Compiled-netlist-program throughput: the interpreted 64-lane engine vs
//! the compiled lane-block engine (`gates::compile`) across lane-block
//! widths `W` and settle worker counts, on the flagship 82×2 TwoLeadECG
//! column and a 16×8 (128-synapse) MNIST-layer-shaped geometry.
//!
//! Every iteration simulates the same number of *lane-cycles* on every
//! configuration, so medians compare like for like; the headline metric is
//! net·lane-cycles per second. Bit-exactness of the compiled engine at
//! `W = 1` against the interpreter is asserted before any timing. Records
//! the full matrix in `BENCH_compiled.json`.
//!
//! Run with `cargo bench --bench compiled_sim` (set `TNN7_BENCH_FAST=1`
//! for a CI-speed configuration).

use tnn7::gates::column_design::{build_column, BrvSource};
use tnn7::gates::{collect_toggles, SimBackend};
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::json::Json;

fn main() {
    let fast = std::env::var("TNN7_BENCH_FAST").is_ok();
    // Lane-cycles per logical iteration: a multiple of 64·W for every
    // tested W, so all configurations do identical work per iteration.
    let lane_cycles: u64 = if fast { 512 } else { 4096 };
    // (words, threads) matrix for the compiled engine.
    let configs: &[(usize, usize)] = if fast {
        &[(1, 1), (4, 1), (4, 2)]
    } else {
        &[(1, 1), (2, 1), (4, 1), (4, 2), (4, 4)]
    };
    // The acceptance geometries: the 82×2 UCR flagship and a ≥16×8 shape.
    let geoms: &[(&str, usize, usize)] = &[("TwoLeadECG-82x2", 82, 2), ("mnist-layer-16x8", 16, 8)];

    let b = Bencher::from_env();
    let mut design_rows: Vec<Json> = Vec::new();
    for &(name, p, q) in geoms {
        let d = build_column(p, q, (p as u32 * 7) / 4, BrvSource::Lfsr);
        let nl = &d.netlist;
        println!(
            "{name}: {} nets, {} macro instances, {lane_cycles} lane-cycles/iter",
            nl.len(),
            nl.macros.len()
        );

        // Equivalence guard before any timing: compiled W=1 reproduces the
        // interpreter's toggle report bit for bit.
        let a = collect_toggles(nl, 256, 3, SimBackend::BitParallel64).unwrap();
        let c = collect_toggles(
            nl,
            256,
            3,
            SimBackend::Compiled { words: 1, threads: 1 },
        )
        .unwrap();
        assert_eq!(a.cycles, c.cycles, "{name}: cycle accounting");
        assert_eq!(a.toggles, c.toggles, "{name}: compiled W=1 != interpreter");

        let rate = |median_ns: f64| nl.len() as f64 * lane_cycles as f64 / (median_ns * 1e-9);
        let s_word = b.bench(&format!("interpreted bit-parallel-64 ({name})"), || {
            let r = collect_toggles(nl, lane_cycles, 7, SimBackend::BitParallel64).unwrap();
            black_box(r.cycles)
        });
        println!("{}", s_word.report());
        let word_rate = rate(s_word.median_ns());

        let mut compiled_rows: Vec<Json> = Vec::new();
        for &(words, threads) in configs {
            let s = b.bench(
                &format!("compiled W={words} threads={threads} ({name})"),
                || {
                    let r = collect_toggles(
                        nl,
                        lane_cycles,
                        7,
                        SimBackend::Compiled { words, threads },
                    )
                    .unwrap();
                    black_box(r.cycles)
                },
            );
            println!("{}", s.report());
            let speedup = s_word.median_ns() / s.median_ns();
            println!(
                "  => W={words} t={threads}: {:.2e} net·lane-cycles/s, {speedup:.2}x vs interpreted",
                rate(s.median_ns())
            );
            compiled_rows.push(
                Json::obj()
                    .set("words", words)
                    .set("threads", threads)
                    .set("median_ns", s.median_ns())
                    .set("net_lane_cycles_per_sec", rate(s.median_ns()))
                    .set("speedup_vs_interpreted", speedup),
            );
        }
        design_rows.push(
            Json::obj()
                .set("design", name)
                .set("p", p)
                .set("q", q)
                .set("nets", nl.len())
                .set("lane_cycles_per_iter", lane_cycles as f64)
                .set(
                    "interpreted",
                    Json::obj()
                        .set("median_ns", s_word.median_ns())
                        .set("net_lane_cycles_per_sec", word_rate),
                )
                .set("compiled", Json::Arr(compiled_rows)),
        );
    }

    let json = Json::obj().set("designs", Json::Arr(design_rows));
    std::fs::write("BENCH_compiled.json", json.to_pretty()).expect("write BENCH_compiled.json");
    println!("wrote BENCH_compiled.json");
}
