//! Fault-campaign throughput: how fast the lane-parallel campaign runner
//! burns through seeded stuck-at + SEU faults on the UCR column netlist,
//! per simulator backend. The word/compiled engines pack up to
//! `sim_words x 64 - 1` faults per netlist pass (lane 0 stays fault-free
//! as the reference), so faults/s is the figure of merit — the scalar
//! engine pays one full pass per fault.
//!
//! Run with `cargo bench --bench fault_campaign` (set `TNN7_BENCH_FAST=1`
//! for a CI-speed configuration). Writes `BENCH_faults.json` — the
//! campaign report of `tnn7 faults` plus per-backend timing medians.

use tnn7::gates::fault::{campaign, sample_faults};
use tnn7::gates::artifact_cache::design_handle;
use tnn7::gates::SimBackend;
use tnn7::harness::{fault_campaign, faults_json, FaultSpec};
use tnn7::tnn::spike::random_volley;
use tnn7::tnn::SpikeTime;
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::Rng64;

fn main() {
    let fast = std::env::var("TNN7_BENCH_FAST").is_ok();
    let mut spec = if fast { FaultSpec::quick() } else { FaultSpec::default() };
    // The bench times each backend separately below; keep the in-report
    // cross-check on the cheap word engine.
    spec.backend = SimBackend::BitParallel64;

    // --- timed section: one campaign per backend on a fixed fault set ---
    let (p, q, theta) = (16, 3, 21);
    let d = design_handle(p, q, theta).expect("design builds");
    let gamma = 8u32;
    let items = if fast { 2 } else { 6 };
    let n_faults = if fast { 16 } else { 96 };
    let mut rng = Rng64::seed_from_u64(0xFA017);
    let ws: Vec<u8> = (0..p * q).map(|_| rng.gen_u8_inclusive(0, 7)).collect();
    let volleys_data: Vec<Vec<SpikeTime>> = (0..items)
        .map(|_| random_volley(p, 0.3, gamma, &mut rng))
        .collect();
    let volleys: Vec<&[SpikeTime]> = volleys_data.iter().map(|v| v.as_slice()).collect();
    let faults = sample_faults(&d.netlist, n_faults / 2, n_faults / 2, items as u64 * gamma as u64, 11);
    println!(
        "fault campaign bench: {p}x{q} column, {} faults x {items} items, gamma {gamma}",
        faults.len()
    );

    let b = Bencher::from_env();
    let backends = [
        ("scalar", SimBackend::Scalar),
        ("bit-parallel-64", SimBackend::BitParallel64),
        ("compiled-2w", SimBackend::Compiled { words: 2, threads: 1 }),
    ];
    let mut stats = Vec::new();
    for (name, backend) in backends {
        let s = b.bench(&format!("campaign {} ({} faults)", name, faults.len()), || {
            let r = campaign(&d, &ws, gamma, &volleys, &faults, backend).unwrap();
            assert_eq!(r.counts().total(), faults.len());
            black_box(r.outcomes.len())
        });
        println!("{}", s.report());
        let faults_per_s = faults.len() as f64 / (s.median_ns() / 1e9).max(1e-12);
        println!("  => {faults_per_s:.0} faults/s on {name}");
        stats.push((name, s, faults_per_s));
    }

    // --- report section: the full seeded campaign `tnn7 faults` prints ---
    let report = fault_campaign(&spec).expect("fault campaign");
    assert!(report.gate.backends_agree, "backend fault verdicts diverged");

    let json = faults_json(&report)
        .set("fast", fast)
        .set(
            "bench",
            stats.iter().fold(tnn7::util::json::Json::obj(), |j, (name, s, fps)| {
                j.set(
                    *name,
                    tnn7::util::json::Json::obj()
                        .set("median_ns", s.median_ns())
                        .set("faults_per_s", *fps),
                )
            }),
        );
    std::fs::write("BENCH_faults.json", json.to_pretty()).expect("write BENCH_faults.json");
    println!("  wrote BENCH_faults.json");
}
