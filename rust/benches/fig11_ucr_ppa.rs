//! Bench for paper Fig. 11: PPA scaling across the 36 UCR single-column
//! designs, ASAP7 vs TNN7. Full sweep once (prints the figure's series),
//! then times the quick subsample as the benchmark body.
use tnn7::harness;
use tnn7::util::bench::Bencher;

fn main() {
    let full = std::env::var("TNN7_BENCH_FAST").is_err();
    let rows = harness::fig11(!full);
    harness::print_fig11(&rows);
    std::fs::create_dir_all("target/reports").ok();
    std::fs::write(
        "target/reports/fig11.json",
        harness::fig11_json(&rows).to_pretty(),
    )
    .ok();
    let b = Bencher { samples: 3, ..Bencher::from_env() };
    let stats = b.bench("fig11: smallest column, both flows", || {
        harness::fig11(true).into_iter().next()
    });
    println!("{}", stats.report());
}
