//! Bench for paper Fig. 12: synthesis (netlist generation) runtime across
//! the UCR suite, ASAP7 baseline vs TNN7 hard-macro flow. The wall-clock
//! ratio is the paper's headline 3.17x.
use tnn7::harness;

fn main() {
    let full = std::env::var("TNN7_BENCH_FAST").is_err();
    let rows = harness::fig12(!full);
    harness::print_fig12(&rows);
    std::fs::create_dir_all("target/reports").ok();
    std::fs::write(
        "target/reports/fig12.json",
        harness::fig12_json(&rows).to_pretty(),
    )
    .ok();
}
