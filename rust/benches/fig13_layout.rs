//! Bench for paper Fig. 13: placement + routing-congestion comparison for
//! the 82×2 TwoLeadECG column (ASAP7 vs TNN7 layouts).
use tnn7::harness;
use tnn7::util::bench::Bencher;

fn main() {
    let (base, t7) = harness::fig13();
    harness::print_fig13(&base, &t7);
    let b = Bencher { samples: 3, ..Bencher::from_env() };
    let stats = b.bench("fig13: place+estimate 82x2 (both flows)", harness::fig13);
    println!("{}", stats.report());
}
