//! Gate-engine inference throughput: the scalar `ColumnSim` path vs the
//! 64-lane word-parallel netlist sweep (`GateColumn::infer_batch`) on a UCR
//! column. The word path packs one gamma item per simulator lane, so a
//! full-dataset gate-level inference sweep costs roughly one scalar pass —
//! this is what makes `report conformance` and `run ucr --engine gate`
//! scoring practical. Winner equivalence between the two paths is asserted
//! before timing. Records the baseline/after pair in `BENCH_gate.json`.
//!
//! Run with `cargo bench --bench gate_engine` (set `TNN7_BENCH_FAST=1` for
//! a CI-speed configuration on a smaller geometry).

use tnn7::coordinator::encode_ucr;
use tnn7::gates::gate_engine::GateColumn;
use tnn7::tnn::column::Column;
use tnn7::tnn::params::TnnParams;
use tnn7::tnn::spike::SpikeTime;
use tnn7::ucr::{self, UcrConfig};
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::json::Json;
use tnn7::util::Rng64;

fn main() {
    let fast = std::env::var("TNN7_BENCH_FAST").is_ok();
    let (cfg, n_volleys) = if fast {
        (
            UcrConfig {
                name: "conformance-16x3",
                p: 16,
                q: 3,
            },
            32usize,
        )
    } else {
        (
            ucr::ucr_suite()
                .into_iter()
                .find(|c| c.name == "TwoLeadECG")
                .unwrap(),
            64usize,
        )
    };
    let data = ucr::generate(cfg, n_volleys.div_ceil(cfg.q).max(1), 7);
    let items = encode_ucr(&data, 8);
    let volleys: Vec<&[SpikeTime]> = items
        .iter()
        .take(n_volleys)
        .map(|i| i.volley.as_slice())
        .collect();

    let theta = (cfg.p as u32 * 7) / 4;
    let col = Column::with_random_weights(
        cfg.p,
        cfg.q,
        theta,
        TnnParams::default(),
        &mut Rng64::seed_from_u64(9),
    );
    let mut gate = GateColumn::from_column(&col).expect("column design levelizes");
    println!(
        "{} {}x{} gate column, {} volleys per sweep",
        cfg.name,
        cfg.p,
        cfg.q,
        volleys.len()
    );

    // Equivalence guard before timing: the word sweep must reproduce the
    // scalar path winner for winner.
    let word_winners = gate.infer_batch(&volleys).unwrap();
    let scalar_winners: Vec<Option<usize>> =
        volleys.iter().map(|v| gate.infer_winner(v)).collect();
    assert_eq!(
        word_winners, scalar_winners,
        "word-parallel sweep disagrees with scalar gate path"
    );

    let b = Bencher::from_env();
    let s_scalar = b.bench("scalar gate inference (per-volley ColumnSim)", || {
        let mut fired = 0usize;
        for v in &volleys {
            fired += usize::from(black_box(gate.infer_winner(v)).is_some());
        }
        fired
    });
    println!("{}", s_scalar.report());
    let s_word = b.bench("word-parallel gate inference (64-lane sweep)", || {
        black_box(gate.infer_batch(&volleys).unwrap()).len()
    });
    println!("{}", s_word.report());

    let per_volley_scalar = s_scalar.median_ns() / volleys.len() as f64;
    let per_volley_word = s_word.median_ns() / volleys.len() as f64;
    let speedup = s_scalar.median_ns() / s_word.median_ns();
    println!(
        "  => scalar {per_volley_scalar:.0} ns/volley | word-parallel {per_volley_word:.0} \
         ns/volley | speedup {speedup:.1}x"
    );
    assert!(speedup > 0.0);

    let json = Json::obj()
        .set("design", cfg.name)
        .set("p", cfg.p)
        .set("q", cfg.q)
        .set("volleys", volleys.len())
        .set(
            "baseline_scalar",
            Json::obj()
                .set("median_ns_per_sweep", s_scalar.median_ns())
                .set("ns_per_volley", per_volley_scalar),
        )
        .set(
            "after_word_parallel",
            Json::obj()
                .set("median_ns_per_sweep", s_word.median_ns())
                .set("ns_per_volley", per_volley_word),
        )
        .set("speedup", speedup);
    std::fs::write("BENCH_gate.json", json.to_pretty()).expect("write BENCH_gate.json");
    println!("wrote BENCH_gate.json");
}
