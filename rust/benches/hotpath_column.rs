//! L3 hot-path benchmark: gamma-cycle throughput of each engine — golden
//! model, gate-level toggle collection (scalar vs 64-lane bit-parallel,
//! selected via `SimBackend`), XLA single-step, and the batched XLA
//! pipeline — on the 82×2 column.
use tnn7::coordinator::{encode_ucr, Engine};
use tnn7::gates::column_design::{build_column, BrvSource};
use tnn7::gates::{collect_toggles, SimBackend};
use tnn7::runtime::XlaRuntime;
use tnn7::tnn::params::TnnParams;
use tnn7::ucr;
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::Rng64;

fn main() {
    let dataset = ucr::ucr_suite().into_iter().find(|c| c.name == "TwoLeadECG").unwrap();
    let data = ucr::generate(dataset, 40, 3);
    let items = encode_ucr(&data, 8);
    let b = Bencher::from_env();
    let mut rng = Rng64::seed_from_u64(5);

    // golden engine
    let mut engine = tnn7::coordinator::ucr_engine(dataset.p, dataset.q, &items, TnnParams::default(), &mut rng);
    let mut k = 0usize;
    let s = b.bench("golden column step (82x2)", || {
        k = (k + 1) % items.len();
        engine.step(&items[k].volley, &mut rng).unwrap()
    });
    println!("{}", s.report());
    println!("  => {:.0} gamma cycles/s", 1e9 / s.median_ns());

    // gate-level toggle collection (feeds the activity-based power model):
    // the same netlist under both simulation backends, 128 cycles per
    // iteration (two 64-lane passes for the bit-parallel engine).
    let theta = (dataset.p as u32 * 7) / 4;
    let design = build_column(dataset.p, dataset.q, theta, BrvSource::Lfsr);
    let nl = &design.netlist;
    let mut per_cycle = [0.0f64; 2];
    for (i, backend) in [SimBackend::Scalar, SimBackend::BitParallel64].iter().enumerate() {
        let s = b.bench(
            &format!("gate sim toggle collect (82x2, 128 cyc, {})", backend.name()),
            || black_box(collect_toggles(nl, 128, 7, *backend).unwrap().toggles.len()),
        );
        println!("{}", s.report());
        per_cycle[i] = s.median_ns() / 128.0;
        println!("  => {:.0} gate-sim cycles/s", 1e9 / per_cycle[i]);
    }
    println!(
        "  => bit-parallel toggle-collection speedup: {:.1}x",
        per_cycle[0] / per_cycle[1]
    );

    // XLA engines
    let Ok(rt) = XlaRuntime::load("artifacts") else {
        println!("(artifacts missing; XLA benches skipped)");
        return;
    };
    let exe = rt.column(dataset.p, dataset.q, "step").unwrap();
    let mut xla = Engine::xla(exe, &mut rng);
    let s = b.bench("xla column step (82x2)", || {
        k = (k + 1) % items.len();
        xla.step(&items[k].volley, &mut rng).unwrap()
    });
    println!("{}", s.report());
    println!("  => {:.0} gamma cycles/s", 1e9 / s.median_ns());

    // batched path: 16 gamma instances per PJRT call
    if let Ok(bexe) = rt.by_name("column_p82_q2_th143_b16_step_batched") {
        let (p, q, bsz) = (bexe.meta.p, bexe.meta.q, bexe.meta.batch);
        let mut w: Vec<f32> = (0..p * q).map(|_| rng.gen_range(0, 8) as f32).collect();
        let xs: Vec<tnn7::tnn::spike::SpikeTime> = (0..bsz)
            .flat_map(|i| items[i % items.len()].volley.clone())
            .collect();
        let s = b.bench("xla batched step (82x2, B=16)", || {
            let u1: Vec<f32> = (0..bsz * p * q).map(|_| rng.gen_f32()).collect();
            let u2: Vec<f32> = (0..bsz * p * q).map(|_| rng.gen_f32()).collect();
            let (y, w_new) = bexe.step_batched(&xs, &w, &u1, &u2).unwrap();
            w = w_new;
            black_box(y)
        });
        println!("{}", s.report());
        println!(
            "  => {:.0} gamma cycles/s (amortized over B=16)",
            16.0 * 1e9 / s.median_ns()
        );
    }
}
