//! Netlist-optimizer payoff: the inference `PassPipeline` (`gates::opt`)
//! measured end to end on the flagship 82×2 TwoLeadECG column and a 16×8
//! (128-synapse) MNIST-layer-shaped geometry — instruction counts before
//! and after specialization, compile time with and without the pipeline,
//! and interpreted vs compiled vs compiled+optimized throughput under the
//! same inference-shaped stimulus (BRV inputs tied low, exactly what the
//! optimizer was told to assume).
//!
//! Every configuration simulates the same number of lane-cycles per
//! iteration, and the headline metric is **net·lane-cycles/sec computed
//! with the unoptimized design's net count** for every row — the
//! optimized program does strictly less work for the same semantic
//! volume, so its rate reads as an end-to-end speedup, not as a smaller
//! denominator. Toggle equivalence on all retained nets is asserted
//! before any timing. Records the matrix in `BENCH_opt.json`.
//!
//! Run with `cargo bench --bench netlist_opt` (set `TNN7_BENCH_FAST=1`
//! for a CI-speed configuration).

use std::collections::HashSet;

use tnn7::gates::column_design::{build_column, BrvSource, ColumnDesign};
use tnn7::gates::{CompiledProgram, CompiledSim, NetId, Netlist, PassPipeline, WordSimulator};
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::json::Json;
use tnn7::util::Rng64;

/// The tied-low BRV input set of an `Inputs`-sourced column.
fn tied_brvs(d: &ColumnDesign) -> HashSet<NetId> {
    d.brv_case
        .iter()
        .flatten()
        .chain(d.brv_stab.iter().flatten())
        .copied()
        .collect()
}

/// Interpreted throughput run: sparse Bernoulli(1/8) pulses on every
/// non-tied input, tied inputs held low, `lane_cycles / 64` passes.
fn run_word(nl: &Netlist, tied: &HashSet<NetId>, lane_cycles: u64, seed: u64) -> u64 {
    let mut sim = WordSimulator::new(nl).unwrap();
    let mut rng = Rng64::seed_from_u64(seed);
    for _ in 0..lane_cycles / 64 {
        for (_, id) in &nl.inputs {
            let id = *id;
            if tied.contains(&id) {
                sim.set_input_net(id, 0);
            } else {
                sim.set_input_net(id, rng.next_u64() & rng.next_u64() & rng.next_u64());
            }
        }
        sim.cycle();
    }
    sim.lane_cycles()
}

/// Compiled throughput run under the same stimulus plan.
fn run_compiled(
    nl: &Netlist,
    tied: &HashSet<NetId>,
    lane_cycles: u64,
    words: usize,
    threads: usize,
    seed: u64,
) -> u64 {
    let mut sim = CompiledSim::new(nl, words, threads).unwrap();
    let mut rng = Rng64::seed_from_u64(seed);
    for _ in 0..lane_cycles / (64 * words as u64) {
        for (_, id) in &nl.inputs {
            let id = *id;
            for w in 0..words {
                if tied.contains(&id) {
                    sim.set_input_net(id, w, 0);
                } else {
                    sim.set_input_net(id, w, rng.next_u64() & rng.next_u64() & rng.next_u64());
                }
            }
        }
        sim.cycle();
    }
    sim.lane_cycles()
}

fn main() {
    let fast = std::env::var("TNN7_BENCH_FAST").is_ok();
    // Lane-cycles per logical iteration: a multiple of 64·W for every
    // tested W, so all configurations do identical semantic work.
    let lane_cycles: u64 = if fast { 512 } else { 4096 };
    let (words, threads): (usize, usize) = if fast { (2, 1) } else { (4, 2) };
    let geoms: &[(&str, usize, usize)] = &[("TwoLeadECG-82x2", 82, 2), ("mnist-layer-16x8", 16, 8)];

    let b = Bencher::from_env();
    let mut design_rows: Vec<Json> = Vec::new();
    for &(name, p, q) in geoms {
        // BRVs as primary inputs: that is the netlist the inference
        // assumptions specialize (the LFSR variant has nothing to tie).
        let d = build_column(p, q, (p as u32 * 7) / 4, BrvSource::Inputs);
        let nl = &d.netlist;
        let tied = tied_brvs(&d);

        // Compile both programs, timing each lowering once (the engine
        // interns them per process, so this is a one-off cost in practice).
        let t0 = std::time::Instant::now();
        let full = CompiledProgram::compile(nl).unwrap();
        let compile_ms_full = t0.elapsed().as_secs_f64() * 1e3;
        let pipeline = PassPipeline::inference(d.inference_assumptions(), d.keep_set());
        let t0 = std::time::Instant::now();
        let (optp, _remap) = CompiledProgram::compile_opt(nl, &pipeline).unwrap();
        let compile_ms_opt = t0.elapsed().as_secs_f64() * 1e3;
        let (od, remap) = d.optimize_inference().unwrap();
        let cut = 1.0 - optp.instr_count() as f64 / full.instr_count() as f64;
        println!(
            "{name}: {} nets -> {}, {} instrs -> {} ({:.1}% cut), compile {compile_ms_full:.1} ms -> {compile_ms_opt:.1} ms",
            nl.len(),
            od.netlist.len(),
            full.instr_count(),
            optp.instr_count(),
            cut * 100.0
        );
        if p * q >= 128 {
            assert!(
                cut >= 0.25,
                "{name}: acceptance floor is a 25% instruction cut, got {:.1}%",
                cut * 100.0
            );
        }

        // Equivalence guard before any timing: identical stimulus draws,
        // toggle counters bit-exact on every retained net.
        {
            let mut c_o = CompiledSim::new(nl, 1, 1).unwrap();
            let mut c_p = CompiledSim::new(&od.netlist, 1, 1).unwrap();
            let mut rng = Rng64::seed_from_u64(3);
            for _ in 0..16 {
                for (_, id) in &nl.inputs {
                    let id = *id;
                    if tied.contains(&id) {
                        c_o.set_input_net(id, 0, 0);
                        continue;
                    }
                    let w = rng.next_u64() & rng.next_u64() & rng.next_u64();
                    c_o.set_input_net(id, 0, w);
                    c_p.set_input_net(remap.net(id).unwrap(), 0, w);
                }
                c_o.cycle();
                c_p.cycle();
            }
            assert_eq!(
                &remap.translate_per_net(c_o.toggles())[..],
                c_p.toggles(),
                "{name}: optimized toggles diverge on retained nets"
            );
        }

        // One shared denominator: the unoptimized design's net count.
        let rate = |median_ns: f64| nl.len() as f64 * lane_cycles as f64 / (median_ns * 1e-9);
        let s_word = b.bench(&format!("interpreted bit-parallel-64 ({name})"), || {
            black_box(run_word(nl, &tied, lane_cycles, 7))
        });
        println!("{}", s_word.report());
        let s_full = b.bench(
            &format!("compiled W={words} threads={threads} ({name})"),
            || black_box(run_compiled(nl, &tied, lane_cycles, words, threads, 7)),
        );
        println!("{}", s_full.report());
        let none = HashSet::new();
        let s_opt = b.bench(
            &format!("compiled+opt W={words} threads={threads} ({name})"),
            || black_box(run_compiled(&od.netlist, &none, lane_cycles, words, threads, 7)),
        );
        println!("{}", s_opt.report());
        println!(
            "  => interpreted {:.2e}, compiled {:.2e}, compiled+opt {:.2e} net·lane-cycles/s ({:.2}x over compiled)",
            rate(s_word.median_ns()),
            rate(s_full.median_ns()),
            rate(s_opt.median_ns()),
            s_full.median_ns() / s_opt.median_ns()
        );

        design_rows.push(
            Json::obj()
                .set("design", name)
                .set("p", p)
                .set("q", q)
                .set("nets", nl.len())
                .set("nets_optimized", od.netlist.len())
                .set("instr_full", full.instr_count())
                .set("instr_opt", optp.instr_count())
                .set("instr_cut_pct", cut * 100.0)
                .set("compile_ms_full", compile_ms_full)
                .set("compile_ms_opt", compile_ms_opt)
                .set("lane_cycles_per_iter", lane_cycles as f64)
                .set("words", words)
                .set("threads", threads)
                .set(
                    "interpreted",
                    Json::obj()
                        .set("median_ns", s_word.median_ns())
                        .set("net_lane_cycles_per_sec", rate(s_word.median_ns())),
                )
                .set(
                    "compiled",
                    Json::obj()
                        .set("median_ns", s_full.median_ns())
                        .set("net_lane_cycles_per_sec", rate(s_full.median_ns())),
                )
                .set(
                    "compiled_opt",
                    Json::obj()
                        .set("median_ns", s_opt.median_ns())
                        .set("net_lane_cycles_per_sec", rate(s_opt.median_ns()))
                        .set("speedup_vs_compiled", s_full.median_ns() / s_opt.median_ns()),
                ),
        );
    }

    let json = Json::obj().set("designs", Json::Arr(design_rows));
    std::fs::write("BENCH_opt.json", json.to_pretty()).expect("write BENCH_opt.json");
    println!("wrote BENCH_opt.json");
}
