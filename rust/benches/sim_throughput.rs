//! Gate-sim toggle-collection throughput: scalar vs 64-lane bit-parallel
//! engine on the 82×2 TwoLeadECG column (the acceptance benchmark for the
//! bit-parallel simulator). Prints per-cycle costs and the speedup, verifies
//! that both backends measure the same switching activity, and records the
//! baseline/after pair in `BENCH_sim.json`.
//!
//! Run with `cargo bench --bench sim_throughput` (set `TNN7_BENCH_FAST=1`
//! for a CI-speed configuration).

use tnn7::gates::column_design::{build_column, BrvSource};
use tnn7::gates::{collect_toggles, SimBackend};
use tnn7::ucr;
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::json::Json;

/// One logical benchmark iteration simulates this many cycles (a multiple
/// of 64 so both backends do identical work).
const CYCLES_PER_ITER: u64 = 512;

fn main() {
    let cfg = ucr::ucr_suite()
        .into_iter()
        .find(|c| c.name == "TwoLeadECG")
        .unwrap();
    let theta = (cfg.p as u32 * 7) / 4;
    let d = build_column(cfg.p, cfg.q, theta, BrvSource::Lfsr);
    let nl = &d.netlist;
    println!(
        "82x2 TwoLeadECG column: {} nets, {} macro instances",
        nl.len(),
        nl.macros.len()
    );

    let b = Bencher::from_env();
    let s_scalar = b.bench("scalar toggle collection (512 cycles, 82x2)", || {
        let r = collect_toggles(nl, CYCLES_PER_ITER, 7, SimBackend::Scalar).unwrap();
        black_box(r.toggles.len())
    });
    println!("{}", s_scalar.report());
    let s_word = b.bench("bit-parallel-64 toggle collection (512 cycles, 82x2)", || {
        let r = collect_toggles(nl, CYCLES_PER_ITER, 7, SimBackend::BitParallel64).unwrap();
        black_box(r.toggles.len())
    });
    println!("{}", s_word.report());

    let scalar_ns_cycle = s_scalar.median_ns() / CYCLES_PER_ITER as f64;
    let word_ns_cycle = s_word.median_ns() / CYCLES_PER_ITER as f64;
    let speedup = s_scalar.median_ns() / s_word.median_ns();
    println!(
        "  => scalar {scalar_ns_cycle:.1} ns/cycle | bit-parallel {word_ns_cycle:.2} ns/cycle | \
         speedup {speedup:.1}x (acceptance target >= 10x)"
    );

    // Cross-check: both backends must measure the same switching activity.
    let a_s = collect_toggles(nl, 8192, 11, SimBackend::Scalar)
        .unwrap()
        .activity();
    let a_w = collect_toggles(nl, 8192, 11, SimBackend::BitParallel64)
        .unwrap()
        .activity();
    println!(
        "  activity cross-check: scalar α {a_s:.4} vs bit-parallel α {a_w:.4} (Δ {:.4})",
        (a_s - a_w).abs()
    );
    assert!(
        (a_s - a_w).abs() < 0.05,
        "backends disagree on measured activity"
    );

    let json = Json::obj()
        .set("design", nl.name.as_str())
        .set("nets", nl.len())
        .set("macros", nl.macros.len())
        .set("cycles_per_iter", CYCLES_PER_ITER as f64)
        .set(
            "baseline_scalar",
            Json::obj()
                .set("median_ns_per_iter", s_scalar.median_ns())
                .set("ns_per_cycle", scalar_ns_cycle)
                .set("activity", a_s),
        )
        .set(
            "after_bit_parallel_64",
            Json::obj()
                .set("median_ns_per_iter", s_word.median_ns())
                .set("ns_per_cycle", word_ns_cycle)
                .set("activity", a_w),
        )
        .set("speedup", speedup);
    std::fs::write("BENCH_sim.json", json.to_pretty()).expect("write BENCH_sim.json");
    println!("  wrote BENCH_sim.json");
}
