//! Sweep executor throughput: cold grid vs fully warm cache on the
//! built-in quick campaign, plus a single-point compute cost. The warm
//! number is the sweep's "resume instantly" claim made measurable: a warm
//! pass only hashes keys and parses kv entries, so it should be orders of
//! magnitude faster than the cold pass it replaces.
//!
//! Run with `cargo bench --bench sweep_throughput` (set `TNN7_BENCH_FAST=1`
//! for a CI-speed configuration). Writes `BENCH_sweep.json` — the same
//! artifact name `tnn7 sweep` emits, with the bench's cold/warm medians in
//! place of a full campaign report.

use tnn7::sweep::{compute_point, run_sweep, SweepSpec};
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::json::Json;

fn main() {
    let mut spec = SweepSpec::quick();
    let base = std::env::temp_dir().join(format!("tnn7_sweep_bench_{}", std::process::id()));
    spec.cache_dir = base.join("cache");
    spec.out_dir = base.join("out");
    std::fs::remove_dir_all(&base).ok();

    let points = spec.points();
    println!(
        "sweep bench: quick campaign, {} points ({} geometries x {} flows), {} workers",
        points.len(),
        spec.geometries.len(),
        spec.flows.len(),
        if spec.threads == 0 { "machine".to_string() } else { spec.threads.to_string() }
    );

    let b = Bencher::from_env();

    // One grid point from scratch (synthesis + PPA + training + scoring).
    // points[1] is the quick grid's (6x2, tnn7, golden) point.
    let s_point = b.bench("compute_point (6x2, tnn7, golden)", || {
        black_box(compute_point(&points[1]).unwrap().purity)
    });
    println!("{}", s_point.report());

    // Cold grid: cache cleared before every iteration.
    let s_cold = b.bench("run_sweep cold (6 points)", || {
        std::fs::remove_dir_all(&spec.cache_dir).ok();
        let o = run_sweep(&spec, true).unwrap();
        assert_eq!(o.computed, o.rows.len());
        black_box(o.rows.len())
    });
    println!("{}", s_cold.report());

    // Warm grid: every point served from the cache filled above.
    let s_warm = b.bench("run_sweep warm (6 points, all cached)", || {
        let o = run_sweep(&spec, true).unwrap();
        assert_eq!(o.cached, o.rows.len());
        black_box(o.rows.len())
    });
    println!("{}", s_warm.report());

    let resume_speedup = s_cold.median_ns() / s_warm.median_ns().max(1.0);
    println!(
        "  => cold {} vs warm {} per grid: warm-cache resume is {resume_speedup:.0}x faster",
        tnn7::util::bench::fmt_dur(s_cold.median),
        tnn7::util::bench::fmt_dur(s_warm.median),
    );

    let json = Json::obj()
        .set("campaign", "quick")
        .set("points", points.len())
        .set("point_median_ns", s_point.median_ns())
        .set("cold_median_ns", s_cold.median_ns())
        .set("warm_median_ns", s_warm.median_ns())
        .set("resume_speedup", resume_speedup);
    std::fs::write("BENCH_sweep.json", json.to_pretty()).expect("write BENCH_sweep.json");
    println!("  wrote BENCH_sweep.json");
    std::fs::remove_dir_all(&base).ok();
}
