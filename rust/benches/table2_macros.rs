//! Bench for paper Table II: per-macro PPA (TNN7 hard cell vs synthesized
//! ASAP7 baseline), plus the per-macro synthesis+analysis cost.
use tnn7::harness;
use tnn7::util::bench::Bencher;

fn main() {
    let rows = harness::table2();
    harness::print_table2(&rows);
    let b = Bencher::from_env();
    let stats = b.bench("table2: synthesize+analyze all 9 macros", || harness::table2());
    println!("{}", stats.report());
}
