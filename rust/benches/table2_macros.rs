//! Bench for paper Table II: per-macro PPA (TNN7 hard cell vs synthesized
//! ASAP7 baseline), plus the per-macro synthesis+analysis cost and the
//! scalar vs word-level behavioral-model evaluation cost (the inner loop of
//! the two simulation backends), with a lane-for-lane cross-check.
use tnn7::gates::macros9::{
    self, MacroState, WordMacroState, ALL_MACROS, WORD_LANES,
};
use tnn7::harness;
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::Rng64;

fn main() {
    let rows = harness::table2();
    harness::print_table2(&rows);
    let b = Bencher::from_env();
    let stats = b.bench("table2: synthesize+analyze all 9 macros", || harness::table2());
    println!("{}", stats.report());

    // Behavioral-model cost: evaluate+step all nine macros once per lane.
    // The scalar engine pays this per cycle; the word engine amortizes it
    // over 64 lanes.
    let mut rng = Rng64::seed_from_u64(42);
    let max_pins = ALL_MACROS.iter().map(|k| k.input_pins().len()).max().unwrap();
    let words: Vec<u64> = (0..max_pins).map(|_| rng.next_u64()).collect();
    let bools: Vec<bool> = words.iter().map(|w| w & 1 == 1).collect();

    let mut sstates: Vec<MacroState> = ALL_MACROS.iter().map(|_| MacroState::default()).collect();
    let mut sout = Vec::new();
    let s_scalar = b.bench("scalar eval+step, 9 macros x 64 lanes", || {
        for _lane in 0..WORD_LANES {
            for (k, &kind) in ALL_MACROS.iter().enumerate() {
                let n = kind.input_pins().len();
                macros9::eval(kind, &bools[..n], &sstates[k], &mut sout);
                macros9::step(kind, &bools[..n], &mut sstates[k]);
            }
        }
        black_box(sstates[1].bits())
    });
    println!("{}", s_scalar.report());

    let mut wstates: Vec<WordMacroState> =
        ALL_MACROS.iter().map(|_| WordMacroState::default()).collect();
    let mut wout = Vec::new();
    let s_word = b.bench("word eval_word+step_word, 9 macros (64 lanes/call)", || {
        for (k, &kind) in ALL_MACROS.iter().enumerate() {
            let n = kind.input_pins().len();
            macros9::eval_word(kind, &words[..n], &wstates[k], &mut wout);
            macros9::step_word(kind, &words[..n], &mut wstates[k]);
        }
        black_box(wstates[1].plane(0))
    });
    println!("{}", s_word.report());
    println!(
        "  => word-level macro-model speedup: {:.1}x over 64 scalar lanes",
        s_scalar.median_ns() / s_word.median_ns()
    );

    // Cross-check: lane-for-lane equivalence of the word models against the
    // scalar models over a fresh randomized run (the longer exhaustive
    // version lives in the macros9 unit tests).
    for &kind in &ALL_MACROS {
        let n = kind.input_pins().len();
        let mut wst = WordMacroState::default();
        let mut lanes: Vec<MacroState> =
            (0..WORD_LANES).map(|_| MacroState::default()).collect();
        let mut wo = Vec::new();
        let mut so = Vec::new();
        for cycle in 0..64u32 {
            let ins: Vec<u64> = (0..n).map(|_| rng.next_u64() & rng.next_u64()).collect();
            macros9::eval_word(kind, &ins, &wst, &mut wo);
            for lane in 0..WORD_LANES {
                let lin: Vec<bool> = ins.iter().map(|w| w >> lane & 1 == 1).collect();
                macros9::eval(kind, &lin, &lanes[lane], &mut so);
                for (pin, &w) in wo.iter().enumerate() {
                    assert_eq!(
                        w >> lane & 1 == 1,
                        so[pin],
                        "{kind:?} pin {pin} lane {lane} cycle {cycle}"
                    );
                }
                macros9::step(kind, &lin, &mut lanes[lane]);
            }
            macros9::step_word(kind, &ins, &mut wst);
        }
        for lane in 0..WORD_LANES {
            assert_eq!(
                wst.extract_lane(lane).bits(),
                lanes[lane].bits(),
                "{kind:?} final state, lane {lane}"
            );
        }
    }
    println!("  macro word/scalar lane-for-lane cross-check OK (9 macros x 64 cycles x 64 lanes)");
}
