//! Bench for paper Table III: the three MNIST multi-layer prototypes under
//! both flows (synapse-count scaling, as the paper does).
use tnn7::harness;
use tnn7::util::bench::Bencher;

fn main() {
    let rows = harness::table3();
    harness::print_table3(&rows);
    for r in &rows {
        let pct = |n: f64, b: f64| (1.0 - n / b) * 100.0;
        println!(
            "{:<16} TNN7 improvements: power {:.0}%, comp-time {:.0}%, area {:.0}%  (paper: 14%/16%/28%)",
            r.name,
            pct(r.tnn7.power_mw, r.base.power_mw),
            pct(r.tnn7.comp_time_ns, r.base.comp_time_ns),
            pct(r.tnn7.area_mm2, r.base.area_mm2),
        );
    }
    let b = Bencher { samples: 3, ..Bencher::from_env() };
    let stats = b.bench("table3: scale 2-layer design (both flows)", || {
        let d = &tnn7::mnist::mnist_layer_geometries()[0];
        (
            tnn7::ppa::scale::scale_network(&d.layers, tnn7::synth::flow::Flow::Baseline, 16),
            tnn7::ppa::scale::scale_network(&d.layers, tnn7::synth::flow::Flow::Tnn7, 16),
        )
    });
    println!("{}", stats.report());
}
