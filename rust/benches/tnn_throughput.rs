//! Behavioral-engine training throughput: scalar per-sample golden model
//! vs the batched SoA kernel with deterministic multi-threaded column
//! sharding (`tnn::batch`), on the two workloads that dominate experiment
//! wall-clock — a full training epoch of the 4-layer MNIST network and UCR
//! TwoLeadECG online training. Verifies the cross-engine equivalence
//! guarantees (inference bit-exact, training thread-count invariant) and
//! records the baseline/after medians in `BENCH_tnn.json`.
//!
//! Run with `cargo bench --bench tnn_throughput` (set `TNN7_BENCH_FAST=1`
//! for a CI-speed configuration). Acceptance target: batched
//! multi-threaded >= 3x scalar on both workloads.

use tnn7::harness::{mnist_train_workload, ucr_train_workload};
use tnn7::tnn::batch::{default_threads, BatchedColumn};
use tnn7::util::bench::{black_box, Bencher};
use tnn7::util::json::Json;
use tnn7::util::Rng64;

fn main() {
    let fast = std::env::var("TNN7_BENCH_FAST").is_ok();
    let threads = default_threads();
    let b = Bencher::from_env();
    let json = Json::obj()
        .set("threads", threads)
        .set("mnist_4layer_epoch", bench_mnist(&b, fast, threads))
        .set("ucr_twoleadecg_epoch", bench_ucr(&b, fast));
    std::fs::write("BENCH_tnn.json", json.to_pretty()).expect("write BENCH_tnn.json");
    println!("  wrote BENCH_tnn.json");
}

// ---------------------------------------------------------------------
// 4-layer MNIST network epoch
// ---------------------------------------------------------------------

fn bench_mnist(b: &Bencher, fast: bool, threads: usize) -> Json {
    let samples = if fast { 30 } else { 120 };
    // Same workload construction as `harness::train_engines` / `report train`.
    let (base, batch) = mnist_train_workload(samples, 40);
    println!(
        "4-layer MNIST network: {} synapses, epoch of {} samples, {} worker threads",
        base.synapse_count(),
        batch.len(),
        threads
    );

    // Equivalence guard (cheap, every bench run): batched inference is
    // bit-exact with per-sample inference, and a training epoch is
    // bit-exact across 1/2/4-thread shardings.
    {
        let got = base.infer_batch(&batch, threads);
        for (s, v) in batch.iter().enumerate().take(8) {
            assert_eq!(got.volley(s), &base.infer(v)[..], "infer mismatch at {s}");
        }
        let stream = Rng64::seed_from_u64(77);
        let mut reference: Option<Vec<Vec<u8>>> = None;
        for t in [1usize, 2, 4] {
            let mut net = base.clone();
            net.step_epoch(&batch, &stream, t);
            let ws: Vec<Vec<u8>> = net
                .layers()
                .iter()
                .flat_map(|l| l.columns())
                .map(|c| c.weights().to_vec())
                .collect();
            match &reference {
                None => reference = Some(ws),
                Some(r) => assert_eq!(&ws, r, "{t}-thread epoch diverged"),
            }
        }
        println!("  equivalence: infer bit-exact; epoch invariant across 1/2/4 threads");
    }

    let mut scalar_net = base.clone();
    let mut rng = Rng64::seed_from_u64(42);
    let s_scalar = b.bench("scalar 4-layer mnist epoch", || {
        for v in batch.iter() {
            black_box(scalar_net.step(v, &mut rng));
        }
    });
    println!("{}", s_scalar.report());

    let epoch_stream = Rng64::seed_from_u64(43);
    let mut epoch = 0u64;
    let mut b1_net = base.clone();
    let s_b1 = b.bench("batched 4-layer mnist epoch (1 thread)", || {
        epoch += 1;
        black_box(b1_net.step_epoch(&batch, &epoch_stream.split_stream(epoch), 1))
    });
    println!("{}", s_b1.report());

    let mut bm_net = base.clone();
    let s_bm = b.bench(
        &format!("batched 4-layer mnist epoch ({threads} threads)"),
        || {
            epoch += 1;
            black_box(bm_net.step_epoch(&batch, &epoch_stream.split_stream(epoch), threads))
        },
    );
    println!("{}", s_bm.report());

    report_speedups(&s_scalar, &s_b1, &s_bm, batch.len())
}

// ---------------------------------------------------------------------
// UCR TwoLeadECG online-training epoch (single 82×2 column)
// ---------------------------------------------------------------------

fn bench_ucr(b: &Bencher, fast: bool) -> Json {
    let per_cluster = if fast { 40 } else { 120 };
    // Same workload construction as `harness::train_engines` / `report train`.
    let (base, items) = ucr_train_workload(per_cluster, 7);
    println!(
        "UCR TwoLeadECG column: {}x{} (θ={}), epoch of {} samples",
        base.p(),
        base.q(),
        base.theta(),
        items.len()
    );

    let mut scalar = base.clone();
    let mut rng_s = Rng64::seed_from_u64(44);
    let s_scalar = b.bench("scalar TwoLeadECG training epoch", || {
        for item in &items {
            black_box(scalar.step(&item.volley, &mut rng_s).winner);
        }
    });
    println!("{}", s_scalar.report());

    let mut batched = BatchedColumn::new(base.clone());
    let mut rng_b = Rng64::seed_from_u64(44);
    let s_batched = b.bench("batched TwoLeadECG training epoch", || {
        for item in &items {
            black_box(batched.step(&item.volley, &mut rng_b));
        }
    });
    println!("{}", s_batched.report());

    // Single column: the multi-thread figure equals the single-thread one.
    report_speedups(&s_scalar, &s_batched, &s_batched, items.len())
}

fn report_speedups(
    scalar: &tnn7::util::bench::BenchStats,
    b1: &tnn7::util::bench::BenchStats,
    bm: &tnn7::util::bench::BenchStats,
    samples: usize,
) -> Json {
    let speedup_1t = scalar.median_ns() / b1.median_ns();
    let speedup_mt = scalar.median_ns() / bm.median_ns();
    let per_sample_us = |s: &tnn7::util::bench::BenchStats| s.median_ns() / 1e3 / samples as f64;
    println!(
        "  => scalar {:.1} µs/sample | batched 1t {:.1} µs/sample ({speedup_1t:.1}x) | \
         batched mt {:.1} µs/sample ({speedup_mt:.1}x; acceptance target >= 3x)",
        per_sample_us(scalar),
        per_sample_us(b1),
        per_sample_us(bm),
    );
    Json::obj()
        .set("samples_per_epoch", samples)
        .set(
            "baseline_scalar",
            Json::obj()
                .set("median_ns_per_epoch", scalar.median_ns())
                .set("us_per_sample", per_sample_us(scalar)),
        )
        .set(
            "after_batched_1t",
            Json::obj()
                .set("median_ns_per_epoch", b1.median_ns())
                .set("us_per_sample", per_sample_us(b1)),
        )
        .set(
            "after_batched_mt",
            Json::obj()
                .set("median_ns_per_epoch", bm.median_ns())
                .set("us_per_sample", per_sample_us(bm)),
        )
        .set("speedup_1t", speedup_1t)
        .set("speedup_mt", speedup_mt)
}
