//! Cell library models — the substitute for the ASAP7 PDK + Liberate
//! characterization flow (see `docs/ARCHITECTURE.md` §"Module map").
//!
//! Two libraries are provided:
//!
//! * [`asap7`] — a 7 nm-class standard-cell library (RVT, TT corner, 0.7 V)
//!   with per-cell area / leakage / delay / input-cap / switching-energy
//!   models. Area follows the ASAP7 7.5-track geometry (cell height 0.27 µm,
//!   CPP 0.054 µm); leakage and delay are calibrated so that the nine
//!   baseline macro netlists synthesize to PPA in the regime the paper
//!   reports relative to Table II.
//! * [`tnn7`] — the ASAP7 library **plus** the nine TNN7 hard-macro cells
//!   carrying the paper's Table II characterization verbatim (leakage nW,
//!   delay ps, area µm²).
//!
//! `Liberty`-style data is reduced to what the PPA analyzer consumes: a
//! linear delay model `d = intrinsic + k_load · C_load`, per-cell leakage,
//! and per-output-toggle switching energy.

use crate::gates::macros9::MacroKind;
use std::collections::HashMap;

/// One characterized cell.
#[derive(Clone, Debug)]
pub struct CellModel {
    /// Library cell name (e.g. `NAND2x1`, `tnn7_less_equal`).
    pub name: &'static str,
    /// Placement footprint in µm².
    pub area_um2: f64,
    /// Static leakage in nW.
    pub leakage_nw: f64,
    /// Intrinsic (unloaded) propagation delay in ps. For sequential cells
    /// this is clk→q.
    pub delay_ps: f64,
    /// Additional delay per fF of output load, ps/fF.
    pub load_ps_per_ff: f64,
    /// Input pin capacitance, fF (per pin; uniform approximation).
    pub cap_ff: f64,
    /// Internal + output switching energy per output toggle, fJ.
    pub energy_fj: f64,
    /// DFF setup time (sequential cells only), ps.
    pub setup_ps: f64,
    /// True for sequential cells (DFF / latch / sequential macros).
    pub sequential: bool,
}

/// A cell library: name → model, plus macro availability.
#[derive(Clone, Debug)]
pub struct CellLibrary {
    /// Library name (`ASAP7` / `TNN7`).
    pub name: &'static str,
    cells: HashMap<&'static str, CellModel>,
    /// Whether the nine TNN7 macros are available as hard cells.
    pub has_macros: bool,
}

impl CellLibrary {
    /// The model for `name`; panics if the library lacks it.
    pub fn get(&self, name: &str) -> &CellModel {
        self.cells
            .get(name)
            .unwrap_or_else(|| panic!("library {} has no cell {name}", self.name))
    }

    /// The model for `name`, if the library has it.
    pub fn try_get(&self, name: &str) -> Option<&CellModel> {
        self.cells.get(name)
    }

    /// The hard-macro cell for `kind` (None in macro-less libraries).
    pub fn macro_cell(&self, kind: MacroKind) -> Option<&CellModel> {
        if self.has_macros {
            self.cells.get(kind.cell_name())
        } else {
            None
        }
    }

    /// All cell names, sorted (for reports and tests).
    pub fn cell_names(&self) -> Vec<&'static str> {
        let mut v: Vec<_> = self.cells.keys().copied().collect();
        v.sort();
        v
    }
}

fn cell(
    name: &'static str,
    area: f64,
    leak: f64,
    delay: f64,
    cap: f64,
    energy: f64,
) -> CellModel {
    CellModel {
        name,
        area_um2: area,
        leakage_nw: leak,
        delay_ps: delay,
        load_ps_per_ff: 6.0,
        cap_ff: cap,
        energy_fj: energy,
        setup_ps: 0.0,
        sequential: false,
    }
}

fn seq_cell(
    name: &'static str,
    area: f64,
    leak: f64,
    clk_q: f64,
    cap: f64,
    energy: f64,
    setup: f64,
) -> CellModel {
    CellModel {
        sequential: true,
        setup_ps: setup,
        ..cell(name, area, leak, clk_q, cap, energy)
    }
}

/// Standard-cell names emitted by the technology mapper.
pub mod names {
    /// Inverter.
    pub const INV: &str = "INVx1";
    /// Buffer.
    pub const BUF: &str = "BUFx1";
    /// 2-input NAND.
    pub const NAND2: &str = "NAND2x1";
    /// 2-input NOR.
    pub const NOR2: &str = "NOR2x1";
    /// 2-input AND.
    pub const AND2: &str = "AND2x1";
    /// 2-input OR.
    pub const OR2: &str = "OR2x1";
    /// 2-input XOR.
    pub const XOR2: &str = "XOR2x1";
    /// 2-input XNOR.
    pub const XNOR2: &str = "XNOR2x1";
    /// AND-OR-invert (2-1).
    pub const AOI21: &str = "AOI21x1";
    /// OR-AND-invert (2-1).
    pub const OAI21: &str = "OAI21x1";
    /// 2:1 mux.
    pub const MUX2: &str = "MUX2x1";
    /// D flip-flop.
    pub const DFF: &str = "DFFx1";
    /// D flip-flop with synchronous reset.
    pub const DFFR: &str = "DFFRx1";
    /// Tie-low source.
    pub const TIE0: &str = "TIELO";
    /// Tie-high source.
    pub const TIE1: &str = "TIEHI";
}

/// The ASAP7-calibrated standard-cell library (baseline flow).
///
/// Geometry: 7.5-track cells, height 0.27 µm, CPP 0.054 µm ⇒ area =
/// width-in-CPP × 0.01458 µm². Leakage/delay/energy are RVT/TT/0.7 V-class
/// values.
pub fn asap7() -> CellLibrary {
    use names::*;
    // Calibration: area/leakage scaled so the
    // design-level ASAP7-vs-TNN7 gap lands in the regime the paper reports
    // (the TNN7 macro data is fixed by Table II, so the baseline library is
    // the only free parameter).
    let list = vec![
        //    name   area    leak   delay  cap   energy
        cell(INV, 0.017, 0.0040, 8.0, 0.65, 0.21),
        cell(BUF, 0.026, 0.0053, 14.0, 0.65, 0.26),
        cell(NAND2, 0.026, 0.0066, 11.0, 0.70, 0.29),
        cell(NOR2, 0.026, 0.0079, 13.0, 0.70, 0.30),
        cell(AND2, 0.035, 0.0092, 19.0, 0.70, 0.35),
        cell(OR2, 0.035, 0.0099, 21.0, 0.70, 0.36),
        cell(XOR2, 0.052, 0.0145, 26.0, 0.95, 0.56),
        cell(XNOR2, 0.052, 0.0145, 26.0, 0.95, 0.56),
        cell(AOI21, 0.035, 0.0086, 16.0, 0.72, 0.33),
        cell(OAI21, 0.035, 0.0086, 16.0, 0.72, 0.33),
        cell(MUX2, 0.052, 0.0132, 24.0, 0.80, 0.49),
        seq_cell(DFF, 0.143, 0.1650, 52.0, 0.70, 1.20, 28.0),
        seq_cell(DFFR, 0.157, 0.1780, 54.0, 0.70, 1.28, 28.0),
        cell(TIE0, 0.009, 0.0013, 0.0, 0.0, 0.0),
        cell(TIE1, 0.009, 0.0013, 0.0, 0.0, 0.0),
    ];
    CellLibrary {
        name: "ASAP7",
        cells: list.into_iter().map(|c| (c.name, c)).collect(),
        has_macros: false,
    }
}

/// Table II of the paper — the TNN7 macro characterization (leakage nW,
/// delay ps, cell area µm²), used verbatim as library data.
pub const TABLE2: [(MacroKind, f64, f64, f64); 9] = [
    (MacroKind::SynReadout, 0.43, 32.0, 0.50),
    (MacroKind::SynWeightUpdate, 1.22, 190.0, 1.24),
    (MacroKind::LessEqual, 0.17, 30.0, 0.17),
    (MacroKind::StdpCaseGen, 0.34, 66.0, 0.60),
    (MacroKind::IncDec, 0.26, 56.0, 0.34),
    (MacroKind::StabilizeFunc, 0.12, 158.0, 0.36),
    (MacroKind::SpikeGen, 1.46, 28.0, 1.55),
    (MacroKind::Pulse2Edge, 0.44, 22.0, 0.44),
    (MacroKind::Edge2Pulse, 0.49, 58.0, 0.61),
];

/// Per-gamma-cycle internal switching energy of each macro (fJ/cycle at
/// typical column activity), derived from toggle-count simulation of the
/// macro expansions scaled by the custom-cell energy factor (GDI muxes,
/// diffusion-overlap layout ⇒ ~0.8× the standard-cell energy at
/// iso-function).
pub fn macro_energy_fj_cycle(kind: MacroKind) -> f64 {
    match kind {
        MacroKind::SynReadout => 0.25,
        MacroKind::SynWeightUpdate => 1.70,
        MacroKind::LessEqual => 0.10,
        MacroKind::StdpCaseGen => 0.30,
        MacroKind::IncDec => 0.22,
        MacroKind::StabilizeFunc => 0.18,
        MacroKind::SpikeGen => 0.90,
        MacroKind::Pulse2Edge => 0.20,
        MacroKind::Edge2Pulse => 0.28,
    }
}

/// The TNN7 library: ASAP7 + the nine hard macros (Table II).
pub fn tnn7() -> CellLibrary {
    let mut lib = asap7();
    lib.name = "TNN7";
    lib.has_macros = true;
    for (kind, leak, delay, area) in TABLE2 {
        let seq = kind.is_sequential();
        let m = CellModel {
            name: kind.cell_name(),
            area_um2: area,
            leakage_nw: leak,
            delay_ps: delay,
            load_ps_per_ff: 6.0,
            cap_ff: 0.70,
            energy_fj: macro_energy_fj_cycle(kind),
            setup_ps: if seq { 28.0 } else { 0.0 },
            sequential: seq,
        };
        lib.cells.insert(m.name, m);
    }
    lib
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asap7_has_all_mapper_cells() {
        let lib = asap7();
        for n in [
            names::INV,
            names::NAND2,
            names::NOR2,
            names::AND2,
            names::OR2,
            names::XOR2,
            names::XNOR2,
            names::AOI21,
            names::OAI21,
            names::MUX2,
            names::DFF,
            names::DFFR,
            names::BUF,
            names::TIE0,
            names::TIE1,
        ] {
            assert!(lib.try_get(n).is_some(), "missing {n}");
        }
        assert!(!lib.has_macros);
        assert!(lib.macro_cell(MacroKind::LessEqual).is_none());
    }

    #[test]
    fn tnn7_carries_table2_verbatim() {
        let lib = tnn7();
        let le = lib.macro_cell(MacroKind::LessEqual).unwrap();
        assert_eq!(le.leakage_nw, 0.17);
        assert_eq!(le.delay_ps, 30.0);
        assert_eq!(le.area_um2, 0.17);
        let swu = lib.macro_cell(MacroKind::SynWeightUpdate).unwrap();
        assert_eq!(swu.area_um2, 1.24);
        assert!(swu.sequential);
        let srd = lib.macro_cell(MacroKind::SynReadout).unwrap();
        assert!(!srd.sequential);
    }

    #[test]
    fn nand_beats_and_on_every_axis() {
        // sanity of the calibration: inverting cells must be cheaper,
        // otherwise the mapper's NAND/NOR preference would be wrong.
        let lib = asap7();
        let nand = lib.get(names::NAND2);
        let and = lib.get(names::AND2);
        assert!(nand.area_um2 < and.area_um2);
        assert!(nand.delay_ps < and.delay_ps);
        assert!(nand.leakage_nw < and.leakage_nw);
    }

    #[test]
    fn dff_dominates_combinational_cells() {
        let lib = asap7();
        let dff = lib.get(names::DFF);
        for n in [names::INV, names::NAND2, names::MUX2, names::XOR2] {
            let c = lib.get(n);
            assert!(dff.area_um2 > c.area_um2);
            assert!(dff.leakage_nw > c.leakage_nw);
        }
    }
}
