//! CLI surface of the `tnn7` binary: the subcommand table, usage/help
//! rendering, and the small argument helpers the parser shares with it.
//!
//! The binary's usage text is **generated** from [`COMMANDS`] — the same
//! table `main.rs` dispatches on — so the advertised flag set cannot
//! drift from the parser again (each subcommand's synopsis/flags live in
//! exactly one place, and `tests/cli_help.rs` smoke-checks every entry).

/// One subcommand: its name, a one-line synopsis (shown in the global
/// usage), and per-flag help lines (shown by `tnn7 help <cmd>` and
/// `tnn7 <cmd> --help`).
#[derive(Clone, Copy, Debug)]
pub struct CommandSpec {
    /// Subcommand name as typed on the command line.
    pub name: &'static str,
    /// One-line synopsis: the subcommand with its full flag set.
    pub synopsis: &'static str,
    /// Flag-by-flag help, one line per entry.
    pub details: &'static [&'static str],
}

/// Every subcommand the binary dispatches, in display order. This table
/// is the single source of truth for the usage text.
pub const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "report",
        synopsis: "report table2|fig11|table3|fig12|fig13|sim|train|conformance|faults|headline [--quick]",
        details: &[
            "regenerate one paper artifact (printed as a paper-style table)",
            "--quick     CI-speed subsample (fig11/fig12/train/conformance/faults)",
        ],
    },
    CommandSpec {
        name: "faults",
        synopsis: "faults [--quick] [key=value ...]",
        details: &[
            "seeded fault-injection campaign: gate-level stuck-at + SEU faults on the UCR",
            "column (classified masked/latent/propagated per macro type, cross-checked",
            "bit-for-bit on every simulator backend) plus weight-memory flip ladders on",
            "the UCR column and the 4-layer MNIST network",
            "--quick          CI-speed campaign (few faults, tiny workloads)",
            "key=value        spec overrides: seed=, stuck=, seu=, items=, per_cluster=,",
            "                 mnist_samples=, flips=1,2,4, backend=scalar|bit-parallel-64|",
            "                 compiled, sim_words=, threads=",
        ],
    },
    CommandSpec {
        name: "run",
        synopsis: "run ucr|mnist [--dataset NAME] [--layers N] [--engine xla|golden|batched|gate] [--sim-backend B] [key=value ...]",
        details: &[
            "run a workload end to end with online STDP learning",
            "--dataset NAME   (ucr) dataset from the 36-design suite, default TwoLeadECG",
            "--layers N       (mnist) network depth, default 3",
            "--engine KIND    ucr: xla|golden|batched|gate; mnist: golden|batched",
            "--sim-backend B  gate-engine batched-inference simulator:",
            "                 scalar|bit-parallel-64|compiled (winners identical; compiled",
            "                 runs sim_words x 64 lanes per pass, sharded over threads=)",
            "key=value        config overrides: seed=, gamma_instances=, channel_depth=,",
            "                 batch=, threads=, artifacts_dir=, out_dir=, engine=,",
            "                 sim_backend=, sim_words=",
        ],
    },
    CommandSpec {
        name: "sweep",
        synopsis: "sweep [SPEC.kv] [--quick] [--no-cache] [key=value ...]",
        details: &[
            "design-space exploration: grid over (p x q, theta, flow, engine, seed) with a",
            "resumable content-addressed point cache; writes sweep.tsv + BENCH_sweep.json",
            "SPEC.kv          spec file (keys below); omitted = built-in 12-point default grid",
            "--quick          built-in 6-point CI grid with tiny workload budgets",
            "--no-cache       ignore and do not update the point cache",
            "key=value        spec overrides: name=, geometries=8x2,12x2, datasets=TwoLeadECG,",
            "                 theta=default|sparse|fixed:<n>, flows=asap7,tnn7,",
            "                 engines=golden,batched,gate, seeds=, per_cluster=, epochs=,",
            "                 threads=, cache_dir=, out_dir=, sim_backend=, sim_words=,",
            "                 opt=none|inference (compiled-backend netlist optimization)",
            "                 (sim_backend/sim_words/opt are execution knobs like threads=:",
            "                 results and cache keys are identical under every backend/level)",
        ],
    },
    CommandSpec {
        name: "synth",
        synopsis: "synth [--p P] [--q Q] [--flow asap7|tnn7]",
        details: &[
            "synthesize one p x q column and print its PPA row",
            "--p P            synapses per neuron, default 82",
            "--q Q            neurons, default 2",
            "--flow FLOW      asap7 (expand macros) or tnn7 (preserve macros), default tnn7",
        ],
    },
    CommandSpec {
        name: "emit-verilog",
        synopsis: "emit-verilog [--flat] [--p P] [--q Q] [OUT.v]",
        details: &[
            "emit a p x q column netlist as tnn7-v1 structural Verilog (the frozen",
            "naming contract in docs/ARCHITECTURE.md): byte-deterministic, macro",
            "instances preserved as TNN7 cell instantiations, parseable back into",
            "the exact netlist by `parse-verilog`",
            "--flat           behavioral fallback: expand each macro into its generic-gate",
            "                 implementation (no TNN7 cells; for flows without the library)",
            "--p P            synapses per neuron, default 82",
            "--q Q            neurons, default 2",
            "OUT.v            output path; omitted or `-` writes to stdout",
        ],
    },
    CommandSpec {
        name: "parse-verilog",
        synopsis: "parse-verilog FILE.v",
        details: &[
            "parse tnn7-v1 structural Verilog (the `emit-verilog` subset) back into a",
            "netlist, verify it, and print its census (nets, gates, macros, ports);",
            "errors carry the 1-based line and column of the offending token",
            "FILE.v           input path; `-` reads stdin",
        ],
    },
    CommandSpec {
        name: "serve",
        synopsis: "serve [--stdin | --listen ADDR] [--quick] [key=value ...]",
        details: &[
            "always-on dynamic-batching inference service: a mixed-engine,",
            "mixed-geometry registry (entries named <engine>:<p>x<q>), same-entry",
            "arrivals coalesced into words x 64-lane compiled passes — winners",
            "bit-exact with sequential inference at any worker count",
            "(default)        bench mode: seeded client sweeps steady|bursty|shuffled",
            "                 arrivals, diffs batched winners against a sequential",
            "                 reference, writes BENCH_serve.json + serve_transcript.tsv",
            "--stdin          pipe mode: requests `<id> <entry> <t1,...,tp>` on stdin",
            "                 (`-` = no spike), replies `<id> <winner|->` sorted by id",
            "--listen ADDR    socket mode: serve the same line protocol on a local",
            "                 TCP address (e.g. 127.0.0.1:7411); `!drain` control line",
            "                 stops accepting, flushes in-flight replies, and exits;",
            "                 malformed lines reply `!parse` without killing the stream",
            "--quick          CI-speed bench (1-word lane blocks, small budgets)",
            "key=value        spec overrides: seed=, workers=, words=, threads=,",
            "                 engines=gate,golden, geometries=12x2,8x3, per_cluster=,",
            "                 requests=, patterns=steady,bursty,shuffled, capacity=,",
            "                 queue_depth= (admission bound; full queue sheds with",
            "                 `!overload`), deadline_ms= (expired requests reply",
            "                 `!deadline`), max_connections=, read_timeout_ms=,",
            "                 chaos=off|default|heavy (deterministic fault-injection",
            "                 harness: writes BENCH_chaos.json + chaos_transcript.tsv),",
            "                 out_dir=",
        ],
    },
    CommandSpec {
        name: "selftest",
        synopsis: "selftest",
        details: &["golden vs gate-level (vs XLA, if built) cross-check on a small column"],
    },
    CommandSpec {
        name: "help",
        synopsis: "help [COMMAND]",
        details: &["print the global usage, or one subcommand's flag-by-flag help"],
    },
];

/// Look up a subcommand's table entry.
pub fn command(name: &str) -> Option<&'static CommandSpec> {
    COMMANDS.iter().find(|c| c.name == name)
}

/// The global usage text, generated from [`COMMANDS`].
pub fn usage() -> String {
    let mut s = String::from("usage: tnn7 <command> ...\n");
    for c in COMMANDS {
        s.push_str("  tnn7 ");
        s.push_str(c.synopsis);
        s.push('\n');
    }
    s.push_str("run `tnn7 help <command>` for flag-by-flag help");
    s
}

/// One subcommand's full help text, generated from its table entry.
pub fn help_for(name: &str) -> Option<String> {
    let c = command(name)?;
    let mut s = format!("usage: tnn7 {}\n", c.synopsis);
    for d in c.details {
        s.push_str("  ");
        s.push_str(d);
        s.push('\n');
    }
    Some(s.trim_end().to_string())
}

/// Is the boolean flag present?
pub fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Value of `--name VALUE`, if present.
pub fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// The `key=value` override arguments (everything containing `=` that is
/// not a `--flag`).
pub fn overrides(args: &[String]) -> Vec<String> {
    args.iter()
        .filter(|a| a.contains('=') && !a.starts_with("--"))
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_lists_every_command() {
        let u = usage();
        for c in COMMANDS {
            assert!(u.contains(c.name), "usage must mention {}", c.name);
        }
        assert!(u.contains("--engine xla|golden|batched|gate"));
        assert!(u.contains("--quick"));
        assert!(u.contains("emit-verilog [--flat]"));
        assert!(u.contains("parse-verilog FILE.v"));
    }

    #[test]
    fn every_command_has_nonempty_help() {
        for c in COMMANDS {
            let h = help_for(c.name).expect("help for every command");
            assert!(h.starts_with(&format!("usage: tnn7 {}", c.synopsis)));
            assert!(!c.details.is_empty(), "{} needs details", c.name);
        }
        assert!(help_for("nope").is_none());
        assert_eq!(command("sweep").unwrap().name, "sweep");
    }

    #[test]
    fn advertised_run_config_keys_are_accepted_by_the_parser() {
        // The `run` help advertises these `key=value` overrides; each must
        // be a real RunConfig key (this is the anti-drift tripwire).
        let mut cfg = crate::config::RunConfig::default();
        for kv in [
            "seed=1",
            "gamma_instances=2",
            "channel_depth=3",
            "batch=4",
            "threads=5",
            "artifacts_dir=a",
            "out_dir=o",
            "engine=golden",
            "sim_backend=compiled",
            "sim_words=4",
        ] {
            cfg.apply_overrides(&[kv.to_string()])
                .unwrap_or_else(|e| panic!("advertised key {kv:?} rejected: {e}"));
        }
    }

    #[test]
    fn advertised_sweep_keys_are_accepted_by_the_parser() {
        let mut spec = crate::sweep::SweepSpec::default();
        for kv in [
            "name=x",
            "geometries=8x2,12x2",
            "datasets=TwoLeadECG",
            "theta=fixed:9",
            "flows=asap7,tnn7",
            "engines=golden,batched",
            "seeds=1,2",
            "per_cluster=3",
            "epochs=2",
            "threads=2",
            "cache_dir=c",
            "out_dir=o",
            "sim_backend=compiled",
            "sim_words=4",
            "opt=inference",
        ] {
            spec.apply_overrides(&[kv.to_string()])
                .unwrap_or_else(|e| panic!("advertised sweep key {kv:?} rejected: {e}"));
        }
    }

    #[test]
    fn advertised_faults_keys_are_accepted_by_the_parser() {
        let mut spec = crate::harness::FaultSpec::quick();
        for kv in [
            "seed=1",
            "stuck=2",
            "seu=3",
            "items=4",
            "per_cluster=5",
            "mnist_samples=10",
            "flips=1,2,4",
            "backend=compiled",
            "sim_words=4",
            "threads=2",
        ] {
            spec.apply_overrides(&[kv.to_string()])
                .unwrap_or_else(|e| panic!("advertised faults key {kv:?} rejected: {e}"));
        }
    }

    #[test]
    fn advertised_serve_keys_are_accepted_by_the_parser() {
        let mut spec = crate::serve::ServeSpec::quick();
        for kv in [
            "seed=1",
            "workers=2",
            "words=2",
            "threads=1",
            "engines=gate,golden",
            "geometries=12x2,8x3",
            "per_cluster=4",
            "requests=8",
            "patterns=steady,bursty,shuffled",
            "capacity=8",
            "queue_depth=16",
            "deadline_ms=250",
            "max_connections=4",
            "read_timeout_ms=900",
            "chaos=default",
            "out_dir=o",
        ] {
            spec.apply_overrides(&[kv.to_string()])
                .unwrap_or_else(|e| panic!("advertised serve key {kv:?} rejected: {e}"));
        }
    }

    #[test]
    fn arg_helpers() {
        let args: Vec<String> = ["ucr", "--engine", "gate", "seed=9", "--quick"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(flag(&args, "--quick"));
        assert!(!flag(&args, "--no-cache"));
        assert_eq!(opt(&args, "--engine"), Some("gate"));
        assert_eq!(opt(&args, "--missing"), None);
        assert_eq!(overrides(&args), vec!["seed=9".to_string()]);
    }
}
