//! Experiment configuration: a small typed layer over the kv format
//! (`configs/*.kv`), with CLI-style overrides — the launcher's config
//! system.

use crate::gates::SimBackend;
use crate::util::kv::KvDoc;
use std::path::PathBuf;

/// Which engine executes column steps on the request path (the behavioral
/// analogue of `gates::SimBackend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT-compiled XLA executable via PJRT (the production path).
    Xla,
    /// Rust golden model (always available; used for fallback and checking).
    Golden,
    /// Batched structure-of-arrays engine (`tnn::batch`): reusable kernel
    /// scratch, precomputed STDP thresholds, deterministic parallel
    /// training.
    Batched,
    /// Gate-level macro netlist engine (`gates::gate_engine`): the nine
    /// TNN7 macros assembled into the full column netlist, stepped cycle by
    /// cycle — every workload run doubles as an RTL-vs-behavioral
    /// conformance check (winners and weights bit-exact with the golden
    /// model on a shared seed).
    Gate,
}

impl EngineKind {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "xla" => Ok(EngineKind::Xla),
            "golden" => Ok(EngineKind::Golden),
            "batched" => Ok(EngineKind::Batched),
            "gate" => Ok(EngineKind::Gate),
            other => anyhow::bail!("unknown engine {other:?} (xla|golden|batched|gate)"),
        }
    }

    /// Canonical spelling (inverse of [`EngineKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Golden => "golden",
            EngineKind::Batched => "batched",
            EngineKind::Gate => "gate",
        }
    }
}

/// Top-level run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Directory with AOT artifacts (manifest.kv + *.hlo.txt).
    pub artifacts_dir: PathBuf,
    /// Engine selection.
    pub engine: EngineKind,
    /// PRNG seed for workloads and STDP draws.
    pub seed: u64,
    /// Gamma instances (samples) to stream in online-learning runs.
    pub gamma_instances: usize,
    /// Bounded-channel depth between source and engine (backpressure).
    pub channel_depth: usize,
    /// Batch size for the batched XLA path (1 = unbatched).
    pub batch: usize,
    /// Worker threads for the batched behavioral engine's column sharding
    /// and the sweep executor (0 = machine parallelism).
    pub threads: usize,
    /// Output directory for reports.
    pub out_dir: PathBuf,
    /// Default on-disk result-cache location for design-space sweeps —
    /// consumed by `SweepSpec::default()` (`crate::sweep`), overridable
    /// per sweep via the spec file or `cache_dir=` override.
    pub cache_dir: PathBuf,
    /// Gate-level simulator backend for the gate engine's batched
    /// inference sweeps (`sim_backend` key / `--sim-backend` flag:
    /// `scalar` | `bit-parallel-64` | `compiled`). Winners are bit-exact
    /// across backends — a throughput knob, never a semantics knob.
    pub sim_backend: SimBackend,
    /// Lane-block width `W` for the compiled backend (`sim_words` key):
    /// `W` × 64 lanes per compiled pass, `1..=64`.
    pub sim_words: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: "artifacts".into(),
            engine: EngineKind::Golden,
            seed: 7,
            gamma_instances: 400,
            channel_depth: 64,
            batch: 1,
            threads: 0,
            out_dir: "target/reports".into(),
            cache_dir: "target/sweep-cache".into(),
            sim_backend: SimBackend::BitParallel64,
            sim_words: crate::gates::DEFAULT_SIM_WORDS,
        }
    }
}

impl RunConfig {
    /// Load from a kv file; missing keys keep defaults.
    pub fn from_kv(doc: &KvDoc) -> crate::Result<Self> {
        let mut c = RunConfig::default();
        if let Some(v) = doc.get("artifacts_dir") {
            c.artifacts_dir = v.into();
        }
        if let Some(v) = doc.get("engine") {
            c.engine = EngineKind::parse(v)?;
        }
        if let Some(v) = doc.get_u64("seed")? {
            c.seed = v;
        }
        if let Some(v) = doc.get_usize("gamma_instances")? {
            c.gamma_instances = v;
        }
        if let Some(v) = doc.get_usize("channel_depth")? {
            c.channel_depth = v;
        }
        if let Some(v) = doc.get_usize("batch")? {
            c.batch = v;
        }
        if let Some(v) = doc.get_usize("threads")? {
            c.threads = v;
        }
        if let Some(v) = doc.get("out_dir") {
            c.out_dir = v.into();
        }
        if let Some(v) = doc.get("cache_dir") {
            c.cache_dir = v.into();
        }
        if let Some(v) = doc.get("sim_backend") {
            c.sim_backend = SimBackend::parse(v)?;
        }
        if let Some(v) = doc.get_usize("sim_words")? {
            c.sim_words = v;
        }
        c.validate()?;
        Ok(c)
    }

    /// The fully-resolved simulator backend: a `compiled` selection picks
    /// up the `sim_words` lane-block width and the `threads` worker count
    /// (the same key the batched engine and sweep executor use; 0 =
    /// machine parallelism).
    pub fn resolved_sim_backend(&self) -> SimBackend {
        match self.sim_backend {
            SimBackend::Compiled { .. } => SimBackend::Compiled {
                words: self.sim_words,
                threads: self.threads,
            },
            b => b,
        }
    }

    /// Apply `key=value` CLI overrides.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> crate::Result<()> {
        let mut doc = KvDoc::default();
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override must be key=value: {o}"))?;
            doc.set(k.trim(), v.trim());
        }
        let merged = Self::from_kv(&doc)?;
        // from_kv starts from defaults; re-apply only the overridden keys.
        for key in doc.keys() {
            match key {
                "artifacts_dir" => self.artifacts_dir = merged.artifacts_dir.clone(),
                "engine" => self.engine = merged.engine,
                "seed" => self.seed = merged.seed,
                "gamma_instances" => self.gamma_instances = merged.gamma_instances,
                "channel_depth" => self.channel_depth = merged.channel_depth,
                "batch" => self.batch = merged.batch,
                "threads" => self.threads = merged.threads,
                "out_dir" => self.out_dir = merged.out_dir.clone(),
                "cache_dir" => self.cache_dir = merged.cache_dir.clone(),
                "sim_backend" => self.sim_backend = merged.sim_backend,
                "sim_words" => self.sim_words = merged.sim_words,
                other => anyhow::bail!("unknown config key {other:?}"),
            }
        }
        self.validate()
    }

    /// Sanity-check the configuration.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.channel_depth >= 1, "channel_depth must be >= 1");
        anyhow::ensure!(self.batch >= 1, "batch must be >= 1");
        anyhow::ensure!(self.gamma_instances >= 1, "gamma_instances must be >= 1");
        anyhow::ensure!(
            (1..=64).contains(&self.sim_words),
            "sim_words must be in 1..=64"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn kv_roundtrip() {
        let doc = KvDoc::parse("engine = xla\nseed = 42\nbatch = 16\n").unwrap();
        let c = RunConfig::from_kv(&doc).unwrap();
        assert_eq!(c.engine, EngineKind::Xla);
        assert_eq!(c.seed, 42);
        assert_eq!(c.batch, 16);
        assert_eq!(c.channel_depth, 64, "default preserved");
    }

    #[test]
    fn gate_engine_parses() {
        assert_eq!(EngineKind::parse("gate").unwrap(), EngineKind::Gate);
        assert_eq!(EngineKind::Gate.name(), "gate");
        let doc = KvDoc::parse("engine = gate\n").unwrap();
        assert_eq!(RunConfig::from_kv(&doc).unwrap().engine, EngineKind::Gate);
        let mut c = RunConfig::default();
        c.apply_overrides(&["engine=gate".into()]).unwrap();
        assert_eq!(c.engine, EngineKind::Gate);
    }

    #[test]
    fn batched_engine_and_threads_parse() {
        assert_eq!(EngineKind::parse("batched").unwrap(), EngineKind::Batched);
        assert_eq!(EngineKind::Batched.name(), "batched");
        let doc = KvDoc::parse("engine = batched\nthreads = 4\n").unwrap();
        let c = RunConfig::from_kv(&doc).unwrap();
        assert_eq!(c.engine, EngineKind::Batched);
        assert_eq!(c.threads, 4);
        let mut c = RunConfig::default();
        assert_eq!(c.threads, 0, "default: machine parallelism");
        c.apply_overrides(&["threads=2".into()]).unwrap();
        assert_eq!(c.threads, 2);
    }

    #[test]
    fn cache_dir_parses_and_overrides() {
        let doc = KvDoc::parse("cache_dir = /tmp/points\n").unwrap();
        let c = RunConfig::from_kv(&doc).unwrap();
        assert_eq!(c.cache_dir, PathBuf::from("/tmp/points"));
        let mut c = RunConfig::default();
        assert_eq!(c.cache_dir, PathBuf::from("target/sweep-cache"));
        c.apply_overrides(&["cache_dir=elsewhere".into()]).unwrap();
        assert_eq!(c.cache_dir, PathBuf::from("elsewhere"));
    }

    #[test]
    fn sim_backend_and_words_parse_and_resolve() {
        let doc = KvDoc::parse("sim_backend = compiled\nsim_words = 4\nthreads = 2\n").unwrap();
        let c = RunConfig::from_kv(&doc).unwrap();
        assert_eq!(c.sim_words, 4);
        assert_eq!(
            c.resolved_sim_backend(),
            SimBackend::Compiled { words: 4, threads: 2 }
        );
        let c = RunConfig::default();
        assert_eq!(c.resolved_sim_backend(), SimBackend::BitParallel64);
        let mut c = RunConfig::default();
        c.apply_overrides(&["sim_backend=scalar".into(), "sim_words=8".into()])
            .unwrap();
        assert_eq!(c.sim_backend, SimBackend::Scalar);
        assert_eq!(c.sim_words, 8);
        assert!(c.apply_overrides(&["sim_words=0".into()]).is_err());
        assert!(c.apply_overrides(&["sim_words=65".into()]).is_err());
        assert!(c.apply_overrides(&["sim_backend=vcs".into()]).is_err());
    }

    #[test]
    fn overrides_apply_and_reject_unknown() {
        let mut c = RunConfig::default();
        c.apply_overrides(&["seed=9".into(), "engine=xla".into()])
            .unwrap();
        assert_eq!(c.seed, 9);
        assert_eq!(c.engine, EngineKind::Xla);
        assert!(c.apply_overrides(&["bogus=1".into()]).is_err());
        assert!(c.apply_overrides(&["batch=0".into()]).is_err());
    }
}
