//! Streaming orchestrator — the Layer-3 deployment shell of the TNN
//! "sensory processing unit".
//!
//! One gamma cycle = one input instance. A producer thread encodes raw
//! samples into spike volleys and feeds a **bounded** channel (providing
//! backpressure, like the gamma-period pacing of real-time operation); the
//! consumer drives the selected column engine — the AOT-compiled **XLA**
//! executable (production path; optionally the batched variant) or the Rust
//! **golden model** — applying STDP online and recording WTA winners and
//! latency metrics.

mod service;

pub use service::ServiceEngine;

use crate::config::EngineKind;
use crate::gates::gate_engine::GateColumn;
use crate::metrics::StreamMetrics;
use crate::runtime::ColumnExecutable;
use crate::tnn::batch::BatchedColumn;
use crate::tnn::column::Column;
use crate::tnn::params::TnnParams;
use crate::tnn::spike::SpikeTime;
use crate::util::Rng64;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One unit of streamed work: an encoded gamma instance.
#[derive(Clone, Debug)]
pub struct GammaItem {
    /// The encoded input spike volley (one SpikeTime per input line).
    pub volley: Vec<SpikeTime>,
    /// Ground-truth label if known (for purity scoring downstream).
    pub label: Option<usize>,
}

/// The column engine the coordinator drives (selection mirrors
/// `gates::SimBackend` on the hardware half: a reference engine and a
/// throughput engine with identical semantics, plus the XLA path).
pub enum Engine<'a> {
    /// The scalar golden model (the bit-accurate reference).
    Golden(Column),
    /// The batched SoA kernel engine (`tnn::batch`).
    Batched(BatchedColumn),
    /// The gate-level TNN7 macro-netlist engine (`gates::gate_engine`).
    Gate(GateColumn),
    /// An AOT-compiled XLA column executable (weights live host-side and
    /// cross the PJRT boundary every step).
    Xla {
        /// The bound executable.
        exe: ColumnExecutable<'a>,
        /// Current synaptic weights, row-major p×q.
        weights: Vec<f32>,
    },
}

impl Engine<'_> {
    /// Which engine kind this is.
    pub fn kind(&self) -> EngineKind {
        match self {
            Engine::Golden(_) => EngineKind::Golden,
            Engine::Batched(_) => EngineKind::Batched,
            Engine::Gate(_) => EngineKind::Gate,
            Engine::Xla { .. } => EngineKind::Xla,
        }
    }

    /// The engine's column geometry `(p, q)`.
    pub fn geometry(&self) -> (usize, usize) {
        match self {
            Engine::Golden(c) => (c.p(), c.q()),
            Engine::Batched(b) => (b.column().p(), b.column().q()),
            Engine::Gate(g) => (g.p(), g.q()),
            Engine::Xla { exe, .. } => (exe.meta.p, exe.meta.q),
        }
    }

    /// Snapshot of the engine's synaptic weights (row-major p×q), for
    /// cross-engine conformance diffing. `None` for the XLA engine, whose
    /// f32 weights live on the device side of the PJRT boundary.
    pub fn weights(&self) -> Option<Vec<u8>> {
        match self {
            Engine::Golden(c) => Some(c.weights().to_vec()),
            Engine::Batched(b) => Some(b.column().weights().to_vec()),
            Engine::Gate(g) => Some(g.weights()),
            Engine::Xla { .. } => None,
        }
    }

    /// One learning step. Returns the post-WTA winner (if any).
    pub fn step(&mut self, xs: &[SpikeTime], rng: &mut Rng64) -> crate::Result<Option<usize>> {
        match self {
            Engine::Golden(col) => Ok(col.step(xs, rng).winner),
            Engine::Batched(b) => Ok(b.step(xs, rng)),
            Engine::Gate(g) => Ok(g.step(xs, rng)),
            Engine::Xla { exe, weights } => {
                let n = exe.meta.p * exe.meta.q;
                let u_case: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
                let u_stab: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
                let (y, w_new) = exe.step(xs, weights, &u_case, &u_stab)?;
                *weights = w_new;
                Ok(y.iter().position(|t| t.is_spike()))
            }
        }
    }

    /// Inference-only winner (no weight change; `&mut` only for the batched
    /// engine's reusable kernel scratch).
    pub fn infer_winner(&mut self, xs: &[SpikeTime]) -> crate::Result<Option<usize>> {
        match self {
            Engine::Golden(col) => Ok(col.infer(xs).winner),
            Engine::Batched(b) => Ok(b.infer_winner(xs)),
            Engine::Gate(g) => Ok(g.infer_winner(xs)),
            Engine::Xla { exe, weights } => {
                // The step artifact doubles for inference by discarding the
                // weight update (u >= 1 blocks every STDP case).
                let n = exe.meta.p * exe.meta.q;
                let ones = vec![1.0f32; n];
                let (y, _) = exe.step(xs, weights, &ones, &ones)?;
                Ok(y.iter().position(|t| t.is_spike()))
            }
        }
    }

    /// Select the gate-level simulator backend behind the gate engine's
    /// batched inference sweeps ([`GateColumn::set_sim_backend`]); a no-op
    /// for every other engine. Winners are bit-exact across backends, so
    /// this only changes throughput — never results (which is what keeps
    /// sweep cache keys backend-stable, see `crate::sweep`).
    pub fn set_sim_backend(&mut self, backend: crate::gates::SimBackend) {
        if let Engine::Gate(g) = self {
            g.set_sim_backend(backend);
        }
    }

    /// Select the netlist optimization level behind the gate engine's
    /// compiled batched sweeps ([`GateColumn::set_opt_level`]); a no-op
    /// for every other engine. Like [`Engine::set_sim_backend`], an
    /// execution knob: winners are bit-exact across levels, so sweep
    /// cache keys stay opt-stable.
    pub fn set_opt_level(&mut self, opt: crate::gates::OptLevel) {
        if let Engine::Gate(g) = self {
            g.set_opt_level(opt);
        }
    }

    /// Inference-only winners over a whole item set. The gate engine routes
    /// through its batched netlist sweep ([`GateColumn::infer_batch`] — 64
    /// interpreter lanes or `words × 64` compiled lanes per pass, bit-exact
    /// with the per-item path); every other engine loops
    /// [`Engine::infer_winner`].
    pub fn infer_winners(&mut self, items: &[GammaItem]) -> crate::Result<Vec<Option<usize>>> {
        if let Engine::Gate(g) = self {
            let volleys: Vec<&[SpikeTime]> = items.iter().map(|i| i.volley.as_slice()).collect();
            return g.infer_batch(&volleys);
        }
        items.iter().map(|i| self.infer_winner(&i.volley)).collect()
    }

    /// Freeze this engine's inference state (geometry, θ, params, weights)
    /// into a `Send + Sync` [`ServiceEngine`] for the serving layer. The
    /// engine itself is untouched — the handle is a snapshot, so training
    /// after the freeze does not flow into it (re-freeze to publish new
    /// weights). `words`/`threads` size the gate kind's pooled compiled
    /// executors and are ignored for the behavioral kinds; the XLA kind is
    /// rejected (device-side state).
    pub fn service(&self, words: usize, threads: usize) -> crate::Result<ServiceEngine> {
        let (p, q) = self.geometry();
        let (theta, params) = match self {
            Engine::Golden(c) => (c.theta(), c.params().clone()),
            Engine::Batched(b) => (b.column().theta(), b.column().params().clone()),
            Engine::Gate(g) => (g.theta(), g.params().clone()),
            Engine::Xla { .. } => {
                anyhow::bail!("XLA engines cannot be served (device-side state)")
            }
        };
        let ws = self.weights().expect("behavioral engines expose weights");
        ServiceEngine::new(self.kind(), p, q, theta, params, &ws, words, threads)
    }

    /// Build a Golden engine for a geometry.
    pub fn golden(p: usize, q: usize, params: TnnParams, rng: &mut Rng64) -> Engine<'static> {
        let theta = params.default_theta(p);
        Engine::Golden(Column::with_random_weights(p, q, theta, params, rng))
    }

    /// Build an XLA engine from a bound executable (random initial weights).
    pub fn xla<'a>(exe: ColumnExecutable<'a>, rng: &mut Rng64) -> Engine<'a> {
        let n = exe.meta.p * exe.meta.q;
        let w_max = (1u32 << exe.meta.weight_bits) - 1;
        let weights = (0..n)
            .map(|_| rng.gen_range(0, w_max as usize + 1) as f32)
            .collect();
        Engine::Xla { exe, weights }
    }
}

/// Results of one streaming run.
#[derive(Debug)]
pub struct StreamOutcome {
    /// Gamma instances processed.
    pub processed: u64,
    /// End-to-end wall time.
    pub wall: Duration,
    /// Processed instances per second.
    pub throughput_hz: f64,
    /// Winner neuron per instance (post-WTA), in arrival order.
    pub winners: Vec<Option<usize>>,
    /// Labels echoed from the items (same order).
    pub labels: Vec<Option<usize>>,
    /// Counters and latency histogram of the run.
    pub metrics: StreamMetrics,
}

/// Stream `items` through `engine` with online STDP learning.
///
/// The producer runs on its own thread and the bounded channel of depth
/// `channel_depth` enforces backpressure; the consumer (caller thread)
/// steps the engine per gamma instance.
pub fn run_stream(
    engine: &mut Engine<'_>,
    items: Vec<GammaItem>,
    channel_depth: usize,
    seed: u64,
) -> crate::Result<StreamOutcome> {
    let metrics = StreamMetrics::default();
    let (tx, rx) = mpsc::sync_channel::<GammaItem>(channel_depth.max(1));
    let n_items = items.len();
    let t0 = Instant::now();
    let mut winners = Vec::with_capacity(n_items);
    let mut labels = Vec::with_capacity(n_items);
    let mut rng = Rng64::seed_from_u64(seed);

    std::thread::scope(|scope| -> crate::Result<()> {
        let metrics_ref = &metrics;
        scope.spawn(move || {
            for item in items {
                metrics_ref.enqueued.inc();
                if tx.try_send(item.clone()).is_err() {
                    metrics_ref.backpressure_stalls.inc();
                    if tx.send(item).is_err() {
                        break; // consumer gone
                    }
                }
            }
        });
        while let Ok(item) = rx.recv() {
            let ts = Instant::now();
            let w = engine.step(&item.volley, &mut rng)?;
            metrics.step_latency.observe(ts.elapsed());
            metrics.processed.inc();
            winners.push(w);
            labels.push(item.label);
        }
        Ok(())
    })?;

    let wall = t0.elapsed();
    Ok(StreamOutcome {
        processed: metrics.processed.get(),
        throughput_hz: metrics.processed.get() as f64 / wall.as_secs_f64().max(1e-9),
        wall,
        winners,
        labels,
        metrics,
    })
}

/// Encode a UCR dataset into gamma items (sparse intensity-to-latency — see
/// `tnn::encode::encode_series_sparse`). Returns the items plus the volley
/// spike density (used for θ sizing).
pub fn encode_ucr(data: &crate::ucr::UcrData, t_max: u32) -> Vec<GammaItem> {
    use crate::tnn::encode::{encode_series_sparse, SERIES_SPARSE_THRESHOLD};
    data.series
        .iter()
        .zip(&data.labels)
        .map(|(s, &l)| GammaItem {
            volley: encode_series_sparse(s, t_max, SERIES_SPARSE_THRESHOLD),
            label: Some(l),
        })
        .collect()
}

/// Spike density of a set of gamma items (spikes per line per instance).
/// Sums per-item volley lengths, so mixed-length item sets (multi-geometry
/// streams) get the true density — not one extrapolated from `items[0]`.
pub fn volley_density(items: &[GammaItem]) -> f64 {
    let mut spikes = 0usize;
    let mut lines = 0usize;
    for i in items {
        spikes += i.volley.iter().filter(|t| t.is_spike()).count();
        lines += i.volley.len();
    }
    if lines == 0 {
        return 0.0;
    }
    spikes as f64 / lines as f64
}

/// Score inference winners against the items' ground-truth labels:
/// `(fired, rand_index, purity)` over the items that fired and carry a
/// label (`q` clusters on both sides). One scoring convention shared by
/// the CLI (`run ucr`) and the conformance harness.
pub fn score_winners(
    winners: &[Option<usize>],
    items: &[GammaItem],
    q: usize,
) -> (usize, f64, f64) {
    let (mut pred, mut truth) = (Vec::new(), Vec::new());
    for (w, item) in winners.iter().zip(items) {
        if let (Some(w), Some(l)) = (*w, item.label) {
            pred.push(w);
            truth.push(l);
        }
    }
    if pred.is_empty() {
        return (0, 0.0, 0.0);
    }
    let ri = crate::ucr::rand_index(&pred, &truth);
    let pu = crate::ucr::purity(&pred, &truth, q, q);
    (pred.len(), ri, pu)
}

/// Build a golden UCR engine with density-scaled θ.
pub fn ucr_engine(
    p: usize,
    q: usize,
    items: &[GammaItem],
    params: TnnParams,
    rng: &mut Rng64,
) -> Engine<'static> {
    ucr_engine_with(EngineKind::Golden, p, q, items, params, rng).expect("golden is infallible")
}

/// Build a UCR engine of the requested kind with density-scaled θ (the XLA
/// engine carries AOT artifacts and must be constructed via
/// [`Engine::xla`] instead).
pub fn ucr_engine_with(
    kind: EngineKind,
    p: usize,
    q: usize,
    items: &[GammaItem],
    params: TnnParams,
    rng: &mut Rng64,
) -> crate::Result<Engine<'static>> {
    let theta = crate::tnn::encode::sparse_theta(p, params.w_max(), volley_density(items));
    engine_with_theta(kind, p, q, theta, params, rng)
}

/// Build a behavioral engine with an explicit θ — the one shared
/// construction path behind [`ucr_engine_with`] and the design-space sweep
/// executor ([`crate::sweep`]): every engine kind starts from the same
/// randomly-initialised column (identical weight draws for a given rng
/// state), so cross-engine runs on a shared seed are comparable volley for
/// volley — which is what makes the swept engines the *conformance-checked*
/// engines rather than lookalikes.
pub fn engine_with_theta(
    kind: EngineKind,
    p: usize,
    q: usize,
    theta: u32,
    params: TnnParams,
    rng: &mut Rng64,
) -> crate::Result<Engine<'static>> {
    let col = Column::with_random_weights(p, q, theta, params, rng);
    match kind {
        EngineKind::Golden => Ok(Engine::Golden(col)),
        EngineKind::Batched => Ok(Engine::Batched(col.batched())),
        EngineKind::Gate => Ok(Engine::Gate(GateColumn::from_column(&col)?)),
        EngineKind::Xla => anyhow::bail!("XLA engines require a runtime; use Engine::xla"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ucr::{self, UcrConfig};

    #[test]
    fn golden_stream_processes_everything() {
        let cfg = UcrConfig {
            name: "TwoLeadECG",
            p: 82,
            q: 2,
        };
        let data = ucr::generate(cfg, 10, 3);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(1);
        let mut engine = Engine::golden(82, 2, TnnParams::default(), &mut rng);
        let out = run_stream(&mut engine, items, 8, 11).unwrap();
        assert_eq!(out.processed, 20);
        assert_eq!(out.winners.len(), 20);
        assert!(out.throughput_hz > 0.0);
    }

    #[test]
    fn online_learning_improves_clustering() {
        // After streaming enough gamma instances, WTA winners should track
        // the true clusters far better than chance.
        let cfg = UcrConfig {
            name: "TwoLeadECG",
            p: 82,
            q: 2,
        };
        let data = ucr::generate(cfg, 60, 5);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(2);
        let mut engine = ucr_engine(82, 2, &items, TnnParams::default(), &mut rng);
        for epoch in 0..5 {
            let _ = run_stream(&mut engine, items.clone(), 16, 5 + epoch).unwrap();
        }
        // score on a fresh inference pass
        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for item in &items {
            if let Some(w) = engine.infer_winner(&item.volley).unwrap() {
                pred.push(w);
                truth.push(item.label.unwrap());
            }
        }
        assert!(
            pred.len() > items.len() / 2,
            "column should fire on most instances ({}/{})",
            pred.len(),
            items.len()
        );
        let ri = ucr::rand_index(&pred, &truth);
        assert!(ri > 0.6, "rand index after learning: {ri}");
    }

    #[test]
    fn batched_engine_streams_and_learns() {
        // The batched SoA engine drives the same streaming pipeline as the
        // golden model and reaches the same clustering quality.
        let cfg = UcrConfig {
            name: "TwoLeadECG",
            p: 82,
            q: 2,
        };
        let data = ucr::generate(cfg, 60, 5);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(2);
        let mut engine = ucr_engine_with(
            crate::config::EngineKind::Batched,
            82,
            2,
            &items,
            TnnParams::default(),
            &mut rng,
        )
        .unwrap();
        assert_eq!(engine.kind(), crate::config::EngineKind::Batched);
        assert_eq!(engine.geometry(), (82, 2));
        for epoch in 0..5 {
            let out = run_stream(&mut engine, items.clone(), 16, 5 + epoch).unwrap();
            assert_eq!(out.processed as usize, items.len());
        }
        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for item in &items {
            if let Some(w) = engine.infer_winner(&item.volley).unwrap() {
                pred.push(w);
                truth.push(item.label.unwrap());
            }
        }
        assert!(
            pred.len() > items.len() / 2,
            "batched column should fire on most instances ({}/{})",
            pred.len(),
            items.len()
        );
        let ri = ucr::rand_index(&pred, &truth);
        assert!(ri > 0.6, "rand index after batched learning: {ri}");
    }

    #[test]
    fn batched_and_golden_inference_agree() {
        // Inference is draw-free: on identical weights the two engines must
        // produce identical winners on every volley.
        let cfg = UcrConfig {
            name: "ECG200",
            p: 96,
            q: 2,
        };
        let data = ucr::generate(cfg, 20, 4);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(9);
        let col = crate::tnn::Column::with_random_weights(
            96,
            2,
            40,
            TnnParams::default(),
            &mut rng,
        );
        let mut golden = Engine::Golden(col.clone());
        let mut batched = Engine::Batched(col.batched());
        for item in &items {
            assert_eq!(
                golden.infer_winner(&item.volley).unwrap(),
                batched.infer_winner(&item.volley).unwrap()
            );
        }
    }

    #[test]
    fn volley_density_sums_per_item_lengths() {
        let items = vec![
            GammaItem {
                volley: vec![SpikeTime::at(0), SpikeTime::NONE],
                label: None,
            },
            GammaItem {
                volley: vec![SpikeTime::at(1); 6],
                label: None,
            },
        ];
        // 7 spikes over 2 + 6 = 8 lines. The old `items[0]`-based
        // denominator (2 items × 2 lines = 4) reported 1.75 here — an
        // impossible density that inflated θ for mixed-length item sets.
        let d = volley_density(&items);
        assert!((d - 7.0 / 8.0).abs() < 1e-12, "density {d}");
        assert_eq!(volley_density(&[]), 0.0);
    }

    #[test]
    fn gate_engine_streams_bit_exactly_with_golden() {
        // The tentpole contract: on a shared seed, the gate-level macro
        // netlist engine produces the same winners as the golden model on
        // every training gamma, and ends every epoch with identical
        // weights. Reduced geometry keeps the netlist small for CI.
        let cfg = UcrConfig {
            name: "TwoLeadECG",
            p: 12,
            q: 2,
        };
        let data = ucr::generate(cfg, 8, 5);
        let items = encode_ucr(&data, 8);
        let mut rng_a = Rng64::seed_from_u64(21);
        let mut rng_b = Rng64::seed_from_u64(21);
        let params = TnnParams::default();
        let mut golden = ucr_engine_with(
            crate::config::EngineKind::Golden,
            12,
            2,
            &items,
            params.clone(),
            &mut rng_a,
        )
        .unwrap();
        let mut gate = ucr_engine_with(
            crate::config::EngineKind::Gate,
            12,
            2,
            &items,
            params,
            &mut rng_b,
        )
        .unwrap();
        assert_eq!(gate.kind(), crate::config::EngineKind::Gate);
        assert_eq!(gate.geometry(), (12, 2));
        assert_eq!(gate.weights(), golden.weights(), "identical initial weights");

        for epoch in 0..2 {
            let og = run_stream(&mut golden, items.clone(), 8, 300 + epoch).unwrap();
            let oh = run_stream(&mut gate, items.clone(), 8, 300 + epoch).unwrap();
            assert_eq!(og.winners, oh.winners, "epoch {epoch}: training winners");
            assert_eq!(gate.weights(), golden.weights(), "epoch {epoch}: weights");
        }

        // Draw-free inference agrees too — per item, through the gate
        // engine's 64-lane word-parallel batch path, and through the
        // compiled lane-block backend (set_sim_backend is a no-op on
        // golden, so calling it on both engines is symmetric).
        let wg = golden.infer_winners(&items).unwrap();
        let wh = gate.infer_winners(&items).unwrap();
        assert_eq!(wg, wh, "batched inference winners");
        golden.set_sim_backend(crate::gates::SimBackend::Compiled { words: 2, threads: 1 });
        gate.set_sim_backend(crate::gates::SimBackend::Compiled { words: 2, threads: 1 });
        let wc = gate.infer_winners(&items).unwrap();
        assert_eq!(wg, wc, "compiled batched inference winners");
        for item in &items {
            assert_eq!(
                golden.infer_winner(&item.volley).unwrap(),
                gate.infer_winner(&item.volley).unwrap()
            );
        }
    }

    #[test]
    fn backpressure_counts_stalls() {
        let cfg = UcrConfig {
            name: "ECG200",
            p: 96,
            q: 2,
        };
        let data = ucr::generate(cfg, 20, 9);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(3);
        let mut engine = Engine::golden(96, 2, TnnParams::default(), &mut rng);
        let out = run_stream(&mut engine, items, 1, 13).unwrap();
        // With depth 1 the producer outruns the consumer at least once.
        assert!(out.metrics.backpressure_stalls.get() > 0);
        assert_eq!(out.processed, 40);
    }
}
