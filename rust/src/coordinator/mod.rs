//! Streaming orchestrator — the Layer-3 deployment shell of the TNN
//! "sensory processing unit".
//!
//! One gamma cycle = one input instance. A producer thread encodes raw
//! samples into spike volleys and feeds a **bounded** channel (providing
//! backpressure, like the gamma-period pacing of real-time operation); the
//! consumer drives the selected column engine — the AOT-compiled **XLA**
//! executable (production path; optionally the batched variant) or the Rust
//! **golden model** — applying STDP online and recording WTA winners and
//! latency metrics.

use crate::config::EngineKind;
use crate::metrics::StreamMetrics;
use crate::runtime::ColumnExecutable;
use crate::tnn::batch::BatchedColumn;
use crate::tnn::column::Column;
use crate::tnn::params::TnnParams;
use crate::tnn::spike::SpikeTime;
use crate::util::Rng64;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One unit of streamed work: an encoded gamma instance.
#[derive(Clone, Debug)]
pub struct GammaItem {
    pub volley: Vec<SpikeTime>,
    /// Ground-truth label if known (for purity scoring downstream).
    pub label: Option<usize>,
}

/// The column engine the coordinator drives (selection mirrors
/// `gates::SimBackend` on the hardware half: a reference engine and a
/// throughput engine with identical semantics, plus the XLA path).
pub enum Engine<'a> {
    Golden(Column),
    Batched(BatchedColumn),
    Xla {
        exe: ColumnExecutable<'a>,
        weights: Vec<f32>,
    },
}

impl Engine<'_> {
    pub fn kind(&self) -> EngineKind {
        match self {
            Engine::Golden(_) => EngineKind::Golden,
            Engine::Batched(_) => EngineKind::Batched,
            Engine::Xla { .. } => EngineKind::Xla,
        }
    }

    pub fn geometry(&self) -> (usize, usize) {
        match self {
            Engine::Golden(c) => (c.p(), c.q()),
            Engine::Batched(b) => (b.column().p(), b.column().q()),
            Engine::Xla { exe, .. } => (exe.meta.p, exe.meta.q),
        }
    }

    /// One learning step. Returns the post-WTA winner (if any).
    pub fn step(&mut self, xs: &[SpikeTime], rng: &mut Rng64) -> crate::Result<Option<usize>> {
        match self {
            Engine::Golden(col) => Ok(col.step(xs, rng).winner),
            Engine::Batched(b) => Ok(b.step(xs, rng)),
            Engine::Xla { exe, weights } => {
                let n = exe.meta.p * exe.meta.q;
                let u_case: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
                let u_stab: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
                let (y, w_new) = exe.step(xs, weights, &u_case, &u_stab)?;
                *weights = w_new;
                Ok(y.iter().position(|t| t.is_spike()))
            }
        }
    }

    /// Inference-only winner (no weight change; `&mut` only for the batched
    /// engine's reusable kernel scratch).
    pub fn infer_winner(&mut self, xs: &[SpikeTime]) -> crate::Result<Option<usize>> {
        match self {
            Engine::Golden(col) => Ok(col.infer(xs).winner),
            Engine::Batched(b) => Ok(b.infer_winner(xs)),
            Engine::Xla { exe, weights } => {
                // The step artifact doubles for inference by discarding the
                // weight update (u >= 1 blocks every STDP case).
                let n = exe.meta.p * exe.meta.q;
                let ones = vec![1.0f32; n];
                let (y, _) = exe.step(xs, weights, &ones, &ones)?;
                Ok(y.iter().position(|t| t.is_spike()))
            }
        }
    }

    /// Build a Golden engine for a geometry.
    pub fn golden(p: usize, q: usize, params: TnnParams, rng: &mut Rng64) -> Engine<'static> {
        let theta = params.default_theta(p);
        Engine::Golden(Column::with_random_weights(p, q, theta, params, rng))
    }

    /// Build an XLA engine from a bound executable (random initial weights).
    pub fn xla<'a>(exe: ColumnExecutable<'a>, rng: &mut Rng64) -> Engine<'a> {
        let n = exe.meta.p * exe.meta.q;
        let w_max = (1u32 << exe.meta.weight_bits) - 1;
        let weights = (0..n)
            .map(|_| rng.gen_range(0, w_max as usize + 1) as f32)
            .collect();
        Engine::Xla { exe, weights }
    }
}

/// Results of one streaming run.
#[derive(Debug)]
pub struct StreamOutcome {
    pub processed: u64,
    pub wall: Duration,
    pub throughput_hz: f64,
    /// Winner neuron per instance (post-WTA), in arrival order.
    pub winners: Vec<Option<usize>>,
    /// Labels echoed from the items (same order).
    pub labels: Vec<Option<usize>>,
    pub metrics: StreamMetrics,
}

/// Stream `items` through `engine` with online STDP learning.
///
/// The producer runs on its own thread and the bounded channel of depth
/// `channel_depth` enforces backpressure; the consumer (caller thread)
/// steps the engine per gamma instance.
pub fn run_stream(
    engine: &mut Engine<'_>,
    items: Vec<GammaItem>,
    channel_depth: usize,
    seed: u64,
) -> crate::Result<StreamOutcome> {
    let metrics = StreamMetrics::default();
    let (tx, rx) = mpsc::sync_channel::<GammaItem>(channel_depth.max(1));
    let n_items = items.len();
    let t0 = Instant::now();
    let mut winners = Vec::with_capacity(n_items);
    let mut labels = Vec::with_capacity(n_items);
    let mut rng = Rng64::seed_from_u64(seed);

    std::thread::scope(|scope| -> crate::Result<()> {
        let metrics_ref = &metrics;
        scope.spawn(move || {
            for item in items {
                metrics_ref.enqueued.inc();
                if tx.try_send(item.clone()).is_err() {
                    metrics_ref.backpressure_stalls.inc();
                    if tx.send(item).is_err() {
                        break; // consumer gone
                    }
                }
            }
        });
        while let Ok(item) = rx.recv() {
            let ts = Instant::now();
            let w = engine.step(&item.volley, &mut rng)?;
            metrics.step_latency.observe(ts.elapsed());
            metrics.processed.inc();
            winners.push(w);
            labels.push(item.label);
        }
        Ok(())
    })?;

    let wall = t0.elapsed();
    Ok(StreamOutcome {
        processed: metrics.processed.get(),
        throughput_hz: metrics.processed.get() as f64 / wall.as_secs_f64().max(1e-9),
        wall,
        winners,
        labels,
        metrics,
    })
}

/// Encode a UCR dataset into gamma items (sparse intensity-to-latency — see
/// `tnn::encode::encode_series_sparse`). Returns the items plus the volley
/// spike density (used for θ sizing).
pub fn encode_ucr(data: &crate::ucr::UcrData, t_max: u32) -> Vec<GammaItem> {
    use crate::tnn::encode::{encode_series_sparse, SERIES_SPARSE_THRESHOLD};
    data.series
        .iter()
        .zip(&data.labels)
        .map(|(s, &l)| GammaItem {
            volley: encode_series_sparse(s, t_max, SERIES_SPARSE_THRESHOLD),
            label: Some(l),
        })
        .collect()
}

/// Spike density of a set of gamma items (spikes per line per instance).
pub fn volley_density(items: &[GammaItem]) -> f64 {
    if items.is_empty() {
        return 0.0;
    }
    let spikes: usize = items
        .iter()
        .map(|i| i.volley.iter().filter(|t| t.is_spike()).count())
        .sum();
    spikes as f64 / (items.len() * items[0].volley.len()) as f64
}

/// Build a golden UCR engine with density-scaled θ.
pub fn ucr_engine(
    p: usize,
    q: usize,
    items: &[GammaItem],
    params: TnnParams,
    rng: &mut Rng64,
) -> Engine<'static> {
    ucr_engine_with(EngineKind::Golden, p, q, items, params, rng).expect("golden is infallible")
}

/// Build a UCR engine of the requested kind with density-scaled θ (the XLA
/// engine carries AOT artifacts and must be constructed via
/// [`Engine::xla`] instead).
pub fn ucr_engine_with(
    kind: EngineKind,
    p: usize,
    q: usize,
    items: &[GammaItem],
    params: TnnParams,
    rng: &mut Rng64,
) -> crate::Result<Engine<'static>> {
    let theta = crate::tnn::encode::sparse_theta(p, params.w_max(), volley_density(items));
    let col = Column::with_random_weights(p, q, theta, params, rng);
    match kind {
        EngineKind::Golden => Ok(Engine::Golden(col)),
        EngineKind::Batched => Ok(Engine::Batched(col.batched())),
        EngineKind::Xla => anyhow::bail!("XLA engines require a runtime; use Engine::xla"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ucr::{self, UcrConfig};

    #[test]
    fn golden_stream_processes_everything() {
        let cfg = UcrConfig {
            name: "TwoLeadECG",
            p: 82,
            q: 2,
        };
        let data = ucr::generate(cfg, 10, 3);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(1);
        let mut engine = Engine::golden(82, 2, TnnParams::default(), &mut rng);
        let out = run_stream(&mut engine, items, 8, 11).unwrap();
        assert_eq!(out.processed, 20);
        assert_eq!(out.winners.len(), 20);
        assert!(out.throughput_hz > 0.0);
    }

    #[test]
    fn online_learning_improves_clustering() {
        // After streaming enough gamma instances, WTA winners should track
        // the true clusters far better than chance.
        let cfg = UcrConfig {
            name: "TwoLeadECG",
            p: 82,
            q: 2,
        };
        let data = ucr::generate(cfg, 60, 5);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(2);
        let mut engine = ucr_engine(82, 2, &items, TnnParams::default(), &mut rng);
        for epoch in 0..5 {
            let _ = run_stream(&mut engine, items.clone(), 16, 5 + epoch).unwrap();
        }
        // score on a fresh inference pass
        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for item in &items {
            if let Some(w) = engine.infer_winner(&item.volley).unwrap() {
                pred.push(w);
                truth.push(item.label.unwrap());
            }
        }
        assert!(
            pred.len() > items.len() / 2,
            "column should fire on most instances ({}/{})",
            pred.len(),
            items.len()
        );
        let ri = ucr::rand_index(&pred, &truth);
        assert!(ri > 0.6, "rand index after learning: {ri}");
    }

    #[test]
    fn batched_engine_streams_and_learns() {
        // The batched SoA engine drives the same streaming pipeline as the
        // golden model and reaches the same clustering quality.
        let cfg = UcrConfig {
            name: "TwoLeadECG",
            p: 82,
            q: 2,
        };
        let data = ucr::generate(cfg, 60, 5);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(2);
        let mut engine = ucr_engine_with(
            crate::config::EngineKind::Batched,
            82,
            2,
            &items,
            TnnParams::default(),
            &mut rng,
        )
        .unwrap();
        assert_eq!(engine.kind(), crate::config::EngineKind::Batched);
        assert_eq!(engine.geometry(), (82, 2));
        for epoch in 0..5 {
            let out = run_stream(&mut engine, items.clone(), 16, 5 + epoch).unwrap();
            assert_eq!(out.processed as usize, items.len());
        }
        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for item in &items {
            if let Some(w) = engine.infer_winner(&item.volley).unwrap() {
                pred.push(w);
                truth.push(item.label.unwrap());
            }
        }
        assert!(
            pred.len() > items.len() / 2,
            "batched column should fire on most instances ({}/{})",
            pred.len(),
            items.len()
        );
        let ri = ucr::rand_index(&pred, &truth);
        assert!(ri > 0.6, "rand index after batched learning: {ri}");
    }

    #[test]
    fn batched_and_golden_inference_agree() {
        // Inference is draw-free: on identical weights the two engines must
        // produce identical winners on every volley.
        let cfg = UcrConfig {
            name: "ECG200",
            p: 96,
            q: 2,
        };
        let data = ucr::generate(cfg, 20, 4);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(9);
        let col = crate::tnn::Column::with_random_weights(
            96,
            2,
            40,
            TnnParams::default(),
            &mut rng,
        );
        let mut golden = Engine::Golden(col.clone());
        let mut batched = Engine::Batched(col.batched());
        for item in &items {
            assert_eq!(
                golden.infer_winner(&item.volley).unwrap(),
                batched.infer_winner(&item.volley).unwrap()
            );
        }
    }

    #[test]
    fn backpressure_counts_stalls() {
        let cfg = UcrConfig {
            name: "ECG200",
            p: 96,
            q: 2,
        };
        let data = ucr::generate(cfg, 20, 9);
        let items = encode_ucr(&data, 8);
        let mut rng = Rng64::seed_from_u64(3);
        let mut engine = Engine::golden(96, 2, TnnParams::default(), &mut rng);
        let out = run_stream(&mut engine, items, 1, 13).unwrap();
        // With depth 1 the producer outruns the consumer at least once.
        assert!(out.metrics.backpressure_stalls.get() > 0);
        assert_eq!(out.processed, 40);
    }
}
