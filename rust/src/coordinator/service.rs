//! `Send + Sync` inference service handles — the serving-side face of
//! [`Engine`](super::Engine).
//!
//! The batch [`Engine`] is deliberately `&mut self` stateful: training
//! mutates weights, and even its inference paths reuse kernel scratch
//! (the batched engine) or persistent simulators (the gate engine). A
//! long-lived server cannot hand one `&mut` engine to N workers, so
//! [`ServiceEngine`] freezes an engine's inference-relevant state —
//! geometry, θ, hyper-parameters, the weight snapshot — into an immutable
//! handle whose methods take `&self` and keep all mutable state
//! *per-request*:
//!
//! * **Golden / batched kinds** run the draw-free scalar
//!   [`Column::infer`] path, which is already `&self` (the batched
//!   engine's inference winners are bit-exact with the golden model's, so
//!   one frozen column serves both kinds).
//! * **Gate kind** holds [`Arc`] handles to the shared design and the
//!   [`OptLevel::Inference`]-specialized compiled program from the
//!   artifact cache, plus a checkout **pool of compiled executors**
//!   ([`CompiledSim`] is plain owned data, hence `Send`): a request
//!   checks one out (or builds a fresh one under pool pressure), runs the
//!   shared lane-block sweep, and returns it. Executor state is
//!   per-request scratch; the program is shared and never mutated.
//!
//! Inference is draw-free on every engine (all-ones uniforms block every
//! STDP case), so a `ServiceEngine` holds no RNG at all — which is the
//! determinism rule that makes dynamic batching semantics-free: winners
//! depend only on (weights, volley), never on which pass a volley landed
//! in or which worker ran it.

use crate::config::EngineKind;
use crate::gates::artifact_cache::{design_handle, program_handle, ColumnProgram};
use crate::gates::column_design::ColumnDesign;
use crate::gates::compile::CompiledSim;
use crate::gates::fault::GateFault;
use crate::gates::gate_engine::compiled_inference_sweep;
use crate::gates::opt::OptLevel;
use crate::tnn::column::Column;
use crate::tnn::params::TnnParams;
use crate::tnn::spike::SpikeTime;
use std::sync::{Arc, Mutex};

/// Gate-kind serving state: shared immutable artifacts plus the executor
/// checkout pool.
struct GateService {
    /// The shared design artifact (held so cache eviction cannot outlive
    /// an active server, and so tests can assert sharing via
    /// [`Arc::ptr_eq`]).
    design: Arc<ColumnDesign>,
    /// The inference-specialized compiled program all executors clone from.
    program: Arc<ColumnProgram>,
    /// Lane-block width of pooled executors.
    words: usize,
    /// Settle worker threads per executor (resolved, never 0).
    threads: usize,
    /// Returned executors awaiting the next request (LIFO: the warmest
    /// executor is reused first).
    pool: Mutex<Vec<CompiledSim>>,
}

/// An immutable, thread-safe inference handle over a frozen engine
/// snapshot. See the module docs for the design; construct via
/// [`Engine::service`](super::Engine::service) or [`ServiceEngine::new`].
pub struct ServiceEngine {
    kind: EngineKind,
    /// The frozen scalar column: weight snapshot + θ + params. Serves
    /// golden/batched requests directly and is the geometry/weight source
    /// of truth for the gate path.
    column: Column,
    /// Present iff `kind == Gate`.
    gate: Option<GateService>,
}

impl ServiceEngine {
    /// Freeze an inference service handle for `kind` at an explicit
    /// geometry and weight snapshot (row-major p×q). For the gate kind,
    /// `words`/`threads` size the pooled compiled executors (`threads = 0`
    /// resolves to machine parallelism); both are ignored otherwise. The
    /// XLA kind is rejected: its weights live across the PJRT boundary and
    /// its executable is not shareable scratch.
    #[allow(clippy::too_many_arguments)] // mirrors engine_with_theta + pool knobs
    pub fn new(
        kind: EngineKind,
        p: usize,
        q: usize,
        theta: u32,
        params: TnnParams,
        ws: &[u8],
        words: usize,
        threads: usize,
    ) -> crate::Result<ServiceEngine> {
        anyhow::ensure!(
            ws.len() == p * q,
            "weight snapshot length {} != p*q = {}",
            ws.len(),
            p * q
        );
        let mut column = Column::new(p, q, theta, params);
        column.set_weights(ws);
        let gate = match kind {
            EngineKind::Gate => {
                let design = design_handle(p, q, theta)?;
                let program = program_handle(p, q, theta, OptLevel::Inference)?;
                let threads = if threads == 0 {
                    std::thread::available_parallelism().map_or(1, |n| n.get())
                } else {
                    threads
                };
                Some(GateService {
                    design,
                    program,
                    words: words.max(1),
                    threads,
                    pool: Mutex::new(Vec::new()),
                })
            }
            EngineKind::Golden | EngineKind::Batched => None,
            EngineKind::Xla => anyhow::bail!("XLA engines cannot be served (device-side state)"),
        };
        Ok(ServiceEngine { kind, column, gate })
    }

    /// Which engine kind this handle serves.
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// The frozen geometry `(p, q)`.
    pub fn geometry(&self) -> (usize, usize) {
        (self.column.p(), self.column.q())
    }

    /// The frozen firing threshold.
    pub fn theta(&self) -> u32 {
        self.column.theta()
    }

    /// The frozen weight snapshot (row-major p×q).
    pub fn weights(&self) -> &[u8] {
        self.column.weights()
    }

    /// The shared design artifact behind the gate path (`None` for the
    /// behavioral kinds) — the [`Arc::ptr_eq`] witness that server, engine
    /// and fault harness resolve one cache entry.
    pub fn design_handle(&self) -> Option<&Arc<ColumnDesign>> {
        self.gate.as_ref().map(|g| &g.design)
    }

    /// Serve one query: the draw-free inference winner for `xs`.
    /// Equivalent to `infer_batch(&[xs])` (batching is semantics-free).
    pub fn infer_winner(&self, xs: &[SpikeTime]) -> crate::Result<Option<usize>> {
        Ok(self.infer_batch(&[xs])?[0])
    }

    /// Serve a coalesced batch: draw-free inference winners for `volleys`,
    /// in order. Gate kind packs the batch into `words × 64`-lane compiled
    /// passes on a pooled executor; behavioral kinds loop the scalar
    /// column. Winners are bit-exact with sequential
    /// [`Engine::infer_winner`](super::Engine::infer_winner) calls on the
    /// same queries regardless of how arrivals were coalesced.
    pub fn infer_batch(&self, volleys: &[&[SpikeTime]]) -> crate::Result<Vec<Option<usize>>> {
        self.infer_batch_inner(volleys, None)
    }

    /// Serve a coalesced batch with a gate-level fault held across the
    /// pass — the chaos harness's injection hook. Only stuck-at faults
    /// are supported (SEUs are cycle-addressed, which has no stable
    /// meaning inside a dynamically-coalesced pass); the force is applied
    /// to every lane word before the sweep and cleared before the
    /// executor returns to the pool, so one faulted request can never
    /// contaminate later passes. Behavioral kinds have no nets to fault:
    /// the request runs clean (deterministically `Ok`), so a chaos
    /// schedule stays worker-count-invariant on mixed registries.
    pub fn infer_batch_faulted(
        &self,
        volleys: &[&[SpikeTime]],
        fault: &GateFault,
    ) -> crate::Result<Vec<Option<usize>>> {
        let GateFault::StuckAt { .. } = fault else {
            anyhow::bail!("only stuck-at faults can ride the serving path, got {fault:?}")
        };
        self.infer_batch_inner(volleys, Some(fault))
    }

    fn infer_batch_inner(
        &self,
        volleys: &[&[SpikeTime]],
        fault: Option<&GateFault>,
    ) -> crate::Result<Vec<Option<usize>>> {
        match &self.gate {
            Some(g) => {
                // Per-request scratch: check an executor out of the pool
                // (or build one under pool pressure — the program Arc makes
                // that a clone of the instruction stream, not a recompile).
                let checked_out = g.pool.lock().unwrap_or_else(|p| p.into_inner()).pop();
                let mut csim = checked_out.unwrap_or_else(|| {
                    CompiledSim::from_program(g.program.prog.clone(), g.words, g.threads)
                });
                if let Some(&GateFault::StuckAt { net, value }) = fault {
                    anyhow::ensure!(
                        (net as usize) < g.program.prog.net_count(),
                        "fault net {net} out of range for program with {} nets",
                        g.program.prog.net_count()
                    );
                    let (sa0, sa1) = if value { (0, u64::MAX) } else { (u64::MAX, 0) };
                    for w in 0..g.words {
                        csim.force_net_word(net, w, sa0, sa1);
                    }
                }
                let winners = compiled_inference_sweep(
                    &g.program,
                    &mut csim,
                    self.column.params().gamma_cycles,
                    self.column.q(),
                    self.column.weights(),
                    volleys,
                );
                // Stuck-at forces survive reset_state by design; strip
                // them before the executor goes back to the shared pool.
                csim.clear_faults();
                g.pool.lock().unwrap_or_else(|p| p.into_inner()).push(csim);
                Ok(winners)
            }
            None => Ok(volleys
                .iter()
                .map(|v| self.column.infer(v).winner)
                .collect()),
        }
    }

    /// Nets in the gate path's compiled program (`None` for behavioral
    /// kinds) — the sample space for chaos-injected stuck-at faults.
    pub fn gate_net_count(&self) -> Option<usize> {
        self.gate.as_ref().map(|g| g.program.prog.net_count())
    }

    /// Executors currently idle in the gate pool (0 for behavioral kinds);
    /// its high-water mark is the server's effective concurrency.
    pub fn pooled_executors(&self) -> usize {
        self.gate
            .as_ref()
            .map_or(0, |g| g.pool.lock().unwrap_or_else(|p| p.into_inner()).len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{encode_ucr, ucr_engine_with};
    use crate::ucr::{self, UcrConfig};
    use crate::util::Rng64;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn service_engine_is_send_and_sync() {
        // The whole point of the type: shareable across server workers.
        assert_send_sync::<ServiceEngine>();
        assert_send_sync::<Arc<ServiceEngine>>();
    }

    #[test]
    fn service_matches_stateful_engine_for_every_behavioral_kind() {
        let cfg = UcrConfig { name: "TwoLeadECG", p: 12, q: 2 };
        let data = ucr::generate(cfg, 10, 7);
        let items = encode_ucr(&data, 8);
        for kind in [EngineKind::Golden, EngineKind::Batched, EngineKind::Gate] {
            let mut rng = Rng64::seed_from_u64(33);
            let mut engine =
                ucr_engine_with(kind, 12, 2, &items, TnnParams::default(), &mut rng).unwrap();
            let svc = engine.service(2, 1).unwrap();
            assert_eq!(svc.kind(), kind);
            assert_eq!(svc.geometry(), (12, 2));
            // Batched against sequential: bit-exact per volley.
            let volleys: Vec<&[SpikeTime]> =
                items.iter().map(|i| i.volley.as_slice()).collect();
            let batch = svc.infer_batch(&volleys).unwrap();
            for (k, item) in items.iter().enumerate() {
                let want = engine.infer_winner(&item.volley).unwrap();
                assert_eq!(batch[k], want, "{kind:?} volley {k}");
                assert_eq!(svc.infer_winner(&item.volley).unwrap(), want);
            }
        }
    }

    #[test]
    fn gate_service_shares_cached_artifacts_and_pools_executors() {
        let svc = ServiceEngine::new(
            EngineKind::Gate,
            6,
            2,
            7,
            TnnParams::default(),
            &[1u8; 12],
            1,
            1,
        )
        .unwrap();
        let d = design_handle(6, 2, 7).unwrap();
        assert!(Arc::ptr_eq(svc.design_handle().unwrap(), &d));
        assert_eq!(svc.pooled_executors(), 0, "pool starts empty");
        let volley = vec![SpikeTime::at(0); 6];
        svc.infer_winner(&volley).unwrap();
        assert_eq!(svc.pooled_executors(), 1, "executor returned to pool");
        svc.infer_winner(&volley).unwrap();
        assert_eq!(svc.pooled_executors(), 1, "pooled executor was reused");
    }

    #[test]
    fn faulted_inference_is_deterministic_and_never_pollutes_the_pool() {
        let svc = ServiceEngine::new(
            EngineKind::Gate,
            6,
            2,
            7,
            TnnParams::default(),
            &[2u8; 12],
            1,
            1,
        )
        .unwrap();
        let volley = vec![SpikeTime::at(3); 6];
        let clean = svc.infer_winner(&volley).unwrap();
        // Stuck-at-1 on neuron 0's spike output: it "fires" at cycle 0,
        // so the earliest-spike WTA winner is forced to 0.
        let prog = program_handle(6, 2, 7, OptLevel::Inference).unwrap();
        let fault = GateFault::StuckAt {
            net: prog.out_spike[0],
            value: true,
        };
        let forced = svc.infer_batch_faulted(&[&volley], &fault).unwrap();
        assert_eq!(forced, vec![Some(0)], "stuck-at-1 spike wins at cycle 0");
        // The pooled executor must come back clean: the same volley on
        // the normal path reproduces the unfaulted winner.
        assert_eq!(svc.infer_winner(&volley).unwrap(), clean, "pool polluted");
        assert_eq!(svc.gate_net_count(), Some(prog.prog.net_count()));
        // SEU faults are cycle-addressed and rejected on this path.
        let seu = GateFault::SeuNet { net: 0, cycle: 1 };
        let err = svc.infer_batch_faulted(&[&volley], &seu).unwrap_err();
        assert!(err.to_string().contains("stuck-at"), "{err}");
        // Behavioral kinds have no nets: the fault is a clean no-op.
        let golden = ServiceEngine::new(
            EngineKind::Golden,
            6,
            2,
            7,
            TnnParams::default(),
            &[2u8; 12],
            1,
            1,
        )
        .unwrap();
        assert_eq!(golden.gate_net_count(), None);
        let w = golden.infer_batch_faulted(&[&volley], &fault).unwrap();
        assert_eq!(w[0], golden.infer_winner(&volley).unwrap());
    }

    #[test]
    fn xla_kind_is_rejected() {
        let err = ServiceEngine::new(
            EngineKind::Xla,
            4,
            2,
            5,
            TnnParams::default(),
            &[0u8; 8],
            1,
            1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("cannot be served"), "{err}");
    }
}
