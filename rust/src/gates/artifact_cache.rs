//! Concurrent, evicting cache for gate-level build artifacts.
//!
//! Column designs and compiled programs are expensive to construct
//! (netlist assembly, levelization, the optimizer pipeline) and immutable
//! once built, so every engine, test, sweep point and fault campaign that
//! asks for the same (p, q, θ) — or (p, q, θ, [`OptLevel`]) — should share
//! one artifact. The first implementation interned them with `Box::leak`
//! into the process lifetime, which leaked one design + program per key
//! *forever*: fine for a one-shot CLI, unbounded memory growth for the
//! long-lived `tnn7 serve` loop sweeping the full UCR geometry mix. This
//! module replaces those interners with a proper cache:
//!
//! * **Sharded `RwLock` map** — readers of different keys never contend
//!   on one global mutex; the hot path (hit) takes one shard read lock.
//! * **`Arc`-handed entries** — callers hold [`Arc`] handles, so an
//!   evicted entry stays alive for exactly as long as someone still uses
//!   it. Until eviction, every handle for a key is pointer-identical
//!   (builds are deduplicated through a per-key [`OnceLock`]).
//! * **LRU eviction with a capacity knob** — inserting past capacity
//!   evicts the least-recently-used entry ([`ShardedLruCache::set_capacity`]
//!   resizes live; the serve spec's `capacity=` key feeds it).
//! * **Memoized build failures, with a bounded retry budget** — a builder
//!   that panics (or errors) is caught once and the failure stored under
//!   the key; later callers get a clean `Err` instead of re-running the
//!   panicking build (the old clear-poison-and-retry discipline turned
//!   one bad geometry into a panic storm under a server). But a failure
//!   is not pinned forever either: after
//!   [`FAILURE_RETRY_BUDGET`] lookups the failed cell is evicted so the
//!   next caller re-runs the build — an always-on server must eventually
//!   recover from transient failures (OOM during compile, a capacity
//!   blip) without a restart. [`ShardedLruCache::retry_failures`] drops
//!   every memoized failure immediately for callers that know the
//!   condition has cleared.
//!
//! The concrete caches live behind [`design_handle`] / [`program_handle`];
//! the gate engine, the sweep executor (through [`GateColumn`]) and the
//! fault harness all resolve artifacts through them, which is what makes
//! "campaign and engine share one design" a provable [`Arc::ptr_eq`]
//! check rather than a convention.
//!
//! [`GateColumn`]: super::gate_engine::GateColumn

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use super::column_design::{build_column, BrvSource, ColumnDesign};
use super::compile::CompiledProgram;
use super::netlist::NetId;
use super::opt::{NetRemap, OptLevel, PassPipeline};

/// How many times a memoized build failure is served before the failed
/// cell is evicted and the next lookup retries the build. High enough
/// that a panicking geometry under request flood stays a trickle of
/// retries, not a storm; low enough that a long-lived server recovers
/// from transient build failures without a restart.
pub const FAILURE_RETRY_BUDGET: u64 = 16;

/// One cache slot: the build cell every caller of the key shares, an LRU
/// stamp bumped on every hit (atomically, so hits stay on the shard
/// *read* lock), and a count of how many times a memoized failure in the
/// cell has been served (drives the retry budget).
struct Slot<V> {
    cell: Arc<OnceLock<Result<Arc<V>, String>>>,
    last_used: Arc<AtomicU64>,
    failure_hits: Arc<AtomicU64>,
}

/// A concurrent build-once cache: sharded `RwLock` map from key to
/// [`Arc`]-handed value, LRU eviction past a runtime-adjustable capacity,
/// and per-key memoization of build failures (panics included).
///
/// Eviction removes the map entry only; outstanding [`Arc`] handles keep
/// their artifact alive, and the next `get_or_build` of that key rebuilds
/// a fresh entry. Victim selection is approximate LRU: the scan walks the
/// shards one read lock at a time, so a concurrent touch can revive an
/// entry between selection and removal — in that case the eviction loop
/// simply picks again. Capacity is enforced globally, not per shard.
pub struct ShardedLruCache<K, V> {
    shards: Vec<RwLock<HashMap<K, Slot<V>>>>,
    capacity: AtomicUsize,
    len: AtomicUsize,
    clock: AtomicU64,
    evictions: AtomicU64,
}

impl<K: Eq + Hash + Clone, V> ShardedLruCache<K, V> {
    /// An empty cache with `shards` lock shards (≥ 1) and room for
    /// `capacity` entries (≥ 1) before LRU eviction kicks in.
    pub fn new(shards: usize, capacity: usize) -> ShardedLruCache<K, V> {
        ShardedLruCache {
            shards: (0..shards.max(1)).map(|_| RwLock::new(HashMap::new())).collect(),
            capacity: AtomicUsize::new(capacity.max(1)),
            len: AtomicUsize::new(0),
            clock: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: &K) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Fetch the entry for `key`, running `build` (outside every lock) if
    /// it is not cached. Concurrent callers of the same key share one
    /// build — the [`OnceLock`] serializes them and hands each the same
    /// `Arc`, so handles are pointer-identical until the entry is evicted.
    /// A build that returns `Err` or panics is memoized: later callers get
    /// the stored error without re-running the build — until the failure
    /// has been served [`FAILURE_RETRY_BUDGET`] times, at which point the
    /// cell is evicted and the next lookup retries the build.
    pub fn get_or_build(
        &self,
        key: K,
        build: impl FnOnce() -> Result<V, String>,
    ) -> Result<Arc<V>, String> {
        let stamp = self.clock.fetch_add(1, Ordering::Relaxed);
        let shard = &self.shards[self.shard_of(&key)];
        // Fast path: shard read lock, bump the LRU stamp atomically.
        let found = {
            let map = shard.read().unwrap_or_else(|p| p.into_inner());
            map.get(&key).map(|s| {
                s.last_used.store(stamp, Ordering::Relaxed);
                (s.cell.clone(), s.failure_hits.clone())
            })
        };
        let (cell, failure_hits) = match found {
            Some(f) => f,
            None => {
                let mut map = shard.write().unwrap_or_else(|p| p.into_inner());
                // Re-check under the write lock: a racing miss may have
                // inserted the slot while we upgraded.
                if let Some(s) = map.get(&key) {
                    s.last_used.store(stamp, Ordering::Relaxed);
                    (s.cell.clone(), s.failure_hits.clone())
                } else {
                    let slot = Slot {
                        cell: Arc::new(OnceLock::new()),
                        last_used: Arc::new(AtomicU64::new(stamp)),
                        failure_hits: Arc::new(AtomicU64::new(0)),
                    };
                    let found = (slot.cell.clone(), slot.failure_hits.clone());
                    map.insert(key.clone(), slot);
                    drop(map);
                    self.len.fetch_add(1, Ordering::Relaxed);
                    self.evict_over_capacity(Some(&key));
                    found
                }
            }
        };
        // The build runs outside all shard locks, so building one key
        // never blocks hits (or builds) of other keys. A panic is caught
        // and stored as the key's memoized result — the fix for the old
        // interner's clear-poison-rebuild-repanic storm.
        let res = cell.get_or_init(|| {
            catch_unwind(AssertUnwindSafe(build))
                .unwrap_or_else(|payload| {
                    Err(format!("artifact build panicked: {}", panic_message(&*payload)))
                })
                .map(Arc::new)
        });
        match res {
            Ok(v) => Ok(v.clone()),
            Err(e) => {
                // Budget the memoization: once this failure has been
                // served FAILURE_RETRY_BUDGET times (the building caller
                // counts as the first), drop the cell so the next lookup
                // retries the build.
                if failure_hits.fetch_add(1, Ordering::Relaxed) + 1 >= FAILURE_RETRY_BUDGET {
                    self.remove_if_same_failed_cell(&key, &cell);
                }
                Err(e.clone())
            }
        }
    }

    /// Evict `key` iff its slot still holds exactly `cell` and that cell
    /// memoizes a failure — never a concurrently rebuilt (or succeeding)
    /// entry.
    fn remove_if_same_failed_cell(&self, key: &K, cell: &Arc<OnceLock<Result<Arc<V>, String>>>) {
        let shard = &self.shards[self.shard_of(key)];
        let mut map = shard.write().unwrap_or_else(|p| p.into_inner());
        let stale = map
            .get(key)
            .is_some_and(|s| Arc::ptr_eq(&s.cell, cell) && matches!(s.cell.get(), Some(Err(_))));
        if stale {
            map.remove(key);
            self.len.fetch_sub(1, Ordering::Relaxed);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drop every memoized build failure now (rather than waiting out
    /// each cell's [`FAILURE_RETRY_BUDGET`]), so the next lookup of each
    /// failed key re-runs its build. Returns how many failures were
    /// dropped. Cells still mid-build are left alone.
    pub fn retry_failures(&self) -> usize {
        let mut dropped = 0;
        for shard in &self.shards {
            let mut map = shard.write().unwrap_or_else(|p| p.into_inner());
            let failed: Vec<K> = map
                .iter()
                .filter(|(_, s)| matches!(s.cell.get(), Some(Err(_))))
                .map(|(k, _)| k.clone())
                .collect();
            for k in failed {
                map.remove(&k);
                self.len.fetch_sub(1, Ordering::Relaxed);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                dropped += 1;
            }
        }
        dropped
    }

    /// Evict least-recently-used entries until `len <= capacity`, never
    /// evicting `keep` (the key being inserted).
    fn evict_over_capacity(&self, keep: Option<&K>) {
        loop {
            let cap = self.capacity.load(Ordering::Relaxed).max(1);
            if self.len.load(Ordering::Relaxed) <= cap {
                return;
            }
            // Scan for the globally-oldest stamp, one shard read lock at
            // a time (approximate: see the type-level doc).
            let mut victim: Option<(usize, K, u64)> = None;
            for (i, shard) in self.shards.iter().enumerate() {
                let map = shard.read().unwrap_or_else(|p| p.into_inner());
                for (k, s) in map.iter() {
                    if keep == Some(k) {
                        continue;
                    }
                    let lu = s.last_used.load(Ordering::Relaxed);
                    let older = match &victim {
                        None => true,
                        Some((_, _, best)) => lu < *best,
                    };
                    if older {
                        victim = Some((i, k.clone(), lu));
                    }
                }
            }
            let Some((i, k, lu)) = victim else { return };
            let mut map = self.shards[i].write().unwrap_or_else(|p| p.into_inner());
            match map.get(&k) {
                // Untouched since selection: evict it.
                Some(s) if s.last_used.load(Ordering::Relaxed) == lu => {
                    map.remove(&k);
                    self.len.fetch_sub(1, Ordering::Relaxed);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // Revived (or a racing evictor removed it): pick again.
                _ => {}
            }
        }
    }

    /// Number of cached entries (outstanding handles to evicted entries
    /// are not counted — they live on the callers' side).
    pub fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    /// True when the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The current eviction threshold.
    pub fn capacity(&self) -> usize {
        self.capacity.load(Ordering::Relaxed)
    }

    /// Resize the eviction threshold (min 1) and immediately evict down
    /// to it — the serve spec's `capacity=` knob lands here.
    pub fn set_capacity(&self, capacity: usize) {
        self.capacity.store(capacity.max(1), Ordering::Relaxed);
        self.evict_over_capacity(None);
    }

    /// Total entries evicted over the cache's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

/// Render a `catch_unwind` payload as the memoized error string.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

// ---------------------------------------------------------------------------
// The concrete gate-artifact caches.
// ---------------------------------------------------------------------------

/// Design-cache key: (p, q, θ).
pub type DesignKey = (usize, usize, u32);

/// Program-cache key: (p, q, θ, optimization level).
pub type ProgramKey = (usize, usize, u32, OptLevel);

/// Default capacity of the global design cache — comfortably above the
/// 36-dataset UCR suite plus the conformance geometries, so batch runs
/// still behave like the old interner (no eviction mid-run) while the
/// serve loop stays memory-stable under arbitrary geometry churn.
pub const DESIGN_CACHE_CAPACITY: usize = 64;

/// Default capacity of the global program cache (two [`OptLevel`]s per
/// geometry, so twice the design headroom).
pub const PROGRAM_CACHE_CAPACITY: usize = 128;

const CACHE_SHARDS: usize = 8;

/// The process-wide design cache behind [`design_handle`].
pub fn design_cache() -> &'static ShardedLruCache<DesignKey, ColumnDesign> {
    static CACHE: OnceLock<ShardedLruCache<DesignKey, ColumnDesign>> = OnceLock::new();
    CACHE.get_or_init(|| ShardedLruCache::new(CACHE_SHARDS, DESIGN_CACHE_CAPACITY))
}

/// The process-wide compiled-program cache behind [`program_handle`].
pub fn program_cache() -> &'static ShardedLruCache<ProgramKey, ColumnProgram> {
    static CACHE: OnceLock<ShardedLruCache<ProgramKey, ColumnProgram>> = OnceLock::new();
    CACHE.get_or_init(|| ShardedLruCache::new(CACHE_SHARDS, PROGRAM_CACHE_CAPACITY))
}

/// Build (or fetch) the shared `BrvSource::Inputs` column netlist for a
/// geometry. Every engine, test, sweep point and fault campaign resolving
/// the same (p, q, θ) gets the same [`Arc`] (pointer-identical until
/// eviction) — the in-memory analogue of an AOT-compiled hardware
/// artifact, minus the old interner's unbounded leak.
pub fn design_handle(p: usize, q: usize, theta: u32) -> crate::Result<Arc<ColumnDesign>> {
    design_cache()
        .get_or_build((p, q, theta), || Ok(build_column(p, q, theta, BrvSource::Inputs)))
        .map_err(anyhow::Error::msg)
}

/// Build (or fetch) the shared compiled program for a geometry at an
/// optimization level. The levelize/optimize/lower pipeline runs once per
/// live (p, q, θ, opt) key; a [`GateColumn`](super::gate_engine::GateColumn)
/// that changes lane-block width or worker count clones the instruction
/// stream into a fresh executor instead of recompiling.
pub fn program_handle(
    p: usize,
    q: usize,
    theta: u32,
    opt: OptLevel,
) -> crate::Result<Arc<ColumnProgram>> {
    let d = design_handle(p, q, theta)?;
    program_cache()
        .get_or_build((p, q, theta, opt), || Ok(build_program(&d, opt)))
        .map_err(anyhow::Error::msg)
}

/// Set both global cache capacities (the serve spec's `capacity=` knob).
pub fn set_cache_capacities(designs: usize, programs: usize) {
    design_cache().set_capacity(designs);
    program_cache().set_capacity(programs);
}

/// Drop every memoized build failure from both global caches (see
/// [`ShardedLruCache::retry_failures`]). Returns how many were dropped.
pub fn retry_cached_failures() -> usize {
    design_cache().retry_failures() + program_cache().retry_failures()
}

/// Snapshot of the global caches, reported into `BENCH_serve.json`.
#[derive(Clone, Copy, Debug)]
pub struct CacheStats {
    /// Live entries in the design cache.
    pub designs: usize,
    /// Live entries in the program cache.
    pub programs: usize,
    /// Design-cache eviction threshold.
    pub design_capacity: usize,
    /// Program-cache eviction threshold.
    pub program_capacity: usize,
    /// Lifetime evictions across both caches.
    pub evictions: u64,
}

/// Read the global caches' current occupancy and eviction counters.
pub fn cache_stats() -> CacheStats {
    CacheStats {
        designs: design_cache().len(),
        programs: program_cache().len(),
        design_capacity: design_cache().capacity(),
        program_capacity: program_cache().capacity(),
        evictions: design_cache().evictions() + program_cache().evictions(),
    }
}

// ---------------------------------------------------------------------------
// The compiled-program artifact itself.
// ---------------------------------------------------------------------------

/// A compiled column program plus the design's engine-facing handles
/// (pulse/reset/output nets, weight-readout instances) expressed in the
/// program's own net-id space — identical to the design's ids under
/// [`OptLevel::None`], optimizer-renumbered under [`OptLevel::Inference`].
pub struct ColumnProgram {
    /// The levelized instruction program the executor clones from.
    pub prog: CompiledProgram,
    /// IN(i) pulse input nets, one per synapse line.
    pub in_pulse: Vec<NetId>,
    /// The GRST (WTA reset) input net.
    pub grst: NetId,
    /// win(j) spike output nets, one per neuron.
    pub out_spike: Vec<NetId>,
    /// `SynWeightUpdate` instance index per (i, j) synapse, row-major.
    pub syn_inst: Vec<u32>,
    /// BRV input nets that still exist in this program and must be forced
    /// low before an inference sweep. The full BRV set under
    /// [`OptLevel::None`]; empty under [`OptLevel::Inference`] once the
    /// optimizer has folded them away (kept as a list, not an assumption,
    /// so a partially-folding pipeline would still silence the survivors).
    pub silence: Vec<NetId>,
    /// Design-id → program-id translation (identity under
    /// [`OptLevel::None`]) for toggle reports and fault sites.
    pub remap: NetRemap,
}

fn build_program(d: &ColumnDesign, opt: OptLevel) -> ColumnProgram {
    let all_brv = || {
        d.brv_case
            .iter()
            .flatten()
            .chain(d.brv_stab.iter().flatten())
            .copied()
    };
    match opt {
        OptLevel::None => ColumnProgram {
            prog: CompiledProgram::compile(&d.netlist).expect("cached design compiles"),
            in_pulse: d.in_pulse.clone(),
            grst: d.grst,
            out_spike: d.out_spike.clone(),
            syn_inst: d.syn_inst.clone(),
            silence: all_brv().collect(),
            remap: NetRemap::identity(d.netlist.len(), d.netlist.macros.len()),
        },
        OptLevel::Inference => {
            let pipeline = PassPipeline::inference(d.inference_assumptions(), d.keep_set());
            let (prog, remap) = CompiledProgram::compile_opt(&d.netlist, &pipeline)
                .expect("cached design optimizes and compiles");
            let keep = |n: NetId| remap.net(n).expect("keep-set net survives optimization");
            ColumnProgram {
                in_pulse: d.in_pulse.iter().map(|&n| keep(n)).collect(),
                grst: keep(d.grst),
                out_spike: d.out_spike.iter().map(|&n| keep(n)).collect(),
                syn_inst: d
                    .syn_inst
                    .iter()
                    .map(|&i| remap.macro_inst(i).expect("weight instance survives"))
                    .collect(),
                silence: all_brv().filter_map(|n| remap.net(n)).collect(),
                prog,
                remap,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn handles_are_pointer_identical_and_rebuilt_after_eviction() {
        let cache: ShardedLruCache<u32, u32> = ShardedLruCache::new(4, 8);
        let a = cache.get_or_build(1, || Ok(10)).unwrap();
        let b = cache.get_or_build(1, || Ok(99)).unwrap(); // build must not rerun
        assert!(Arc::ptr_eq(&a, &b), "same key shares one Arc until eviction");
        assert_eq!(*b, 10, "second build closure never ran");
        // Evict everything by shrinking capacity around a flood of keys.
        for k in 2..12 {
            cache.get_or_build(k, || Ok(k)).unwrap();
        }
        cache.set_capacity(1);
        assert!(cache.len() <= 1);
        let c = cache.get_or_build(1, || Ok(20)).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "evicted key rebuilds a fresh entry");
        assert_eq!(*c, 20);
        // The old handle still works: eviction never invalidates it.
        assert_eq!(*a, 10);
    }

    #[test]
    fn eviction_fires_past_capacity_and_is_memory_stable() {
        // The regression test for the Box::leak interner: past capacity,
        // entries must actually leave the map (len stays bounded and the
        // eviction counter advances) instead of accumulating forever.
        let cache: ShardedLruCache<u64, Vec<u8>> = ShardedLruCache::new(4, 3);
        for k in 0..50u64 {
            cache.get_or_build(k, || Ok(vec![0u8; 64])).unwrap();
            assert!(cache.len() <= 3, "len {} exceeded capacity at key {k}", cache.len());
        }
        assert_eq!(cache.capacity(), 3);
        assert!(cache.evictions() >= 47, "evictions {} too low", cache.evictions());
    }

    #[test]
    fn lru_order_decides_the_victim() {
        let cache: ShardedLruCache<u8, u8> = ShardedLruCache::new(1, 2);
        cache.get_or_build(1, || Ok(1)).unwrap();
        cache.get_or_build(2, || Ok(2)).unwrap();
        // Touch 1 so 2 becomes the LRU entry, then insert 3.
        cache.get_or_build(1, || Ok(0)).unwrap();
        cache.get_or_build(3, || Ok(3)).unwrap();
        let ran = AtomicUsize::new(0);
        cache
            .get_or_build(1, || {
                ran.fetch_add(1, Ordering::Relaxed);
                Ok(0)
            })
            .unwrap();
        assert_eq!(ran.load(Ordering::Relaxed), 0, "recently-used key survived");
        cache
            .get_or_build(2, || {
                ran.fetch_add(1, Ordering::Relaxed);
                Ok(2)
            })
            .unwrap();
        assert_eq!(ran.load(Ordering::Relaxed), 1, "LRU key was the victim");
    }

    #[test]
    fn build_failures_are_memoized_not_repanicked() {
        // The panic-storm fix: the first caller eats the panic (as a clean
        // Err), every later caller gets the same Err, and the builder is
        // never run again for that key.
        let cache: ShardedLruCache<u8, u8> = ShardedLruCache::new(2, 4);
        let runs = AtomicUsize::new(0);
        for attempt in 0..3 {
            let err = cache
                .get_or_build(7, || {
                    runs.fetch_add(1, Ordering::Relaxed);
                    panic!("bad geometry");
                })
                .unwrap_err();
            assert!(err.contains("bad geometry"), "attempt {attempt}: {err}");
        }
        assert_eq!(runs.load(Ordering::Relaxed), 1, "panicking build ran once");
        // Plain Err results are memoized the same way.
        let err_runs = AtomicUsize::new(0);
        for _ in 0..3 {
            let err = cache
                .get_or_build(8, || {
                    err_runs.fetch_add(1, Ordering::Relaxed);
                    Err("no such design".to_string())
                })
                .unwrap_err();
            assert_eq!(err, "no such design");
        }
        assert_eq!(err_runs.load(Ordering::Relaxed), 1);
        // Failed entries occupy slots and are evictable like any other.
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn failure_memoization_has_a_bounded_retry_budget() {
        // The always-on-server regression: a failure must not be pinned
        // forever. After FAILURE_RETRY_BUDGET lookups the failed cell is
        // evicted and the build re-runs — so a condition that has cleared
        // (here: the builder succeeds on its third run) eventually serves
        // real artifacts again without a process restart.
        let cache: ShardedLruCache<u8, u8> = ShardedLruCache::new(2, 4);
        let runs = AtomicUsize::new(0);
        let budget = FAILURE_RETRY_BUDGET as usize;
        let mut outcomes = Vec::new();
        for _ in 0..(2 * budget + 1) {
            let r = cache.get_or_build(7, || {
                let n = runs.fetch_add(1, Ordering::Relaxed);
                if n < 2 {
                    Err("transient".to_string())
                } else {
                    Ok(42)
                }
            });
            outcomes.push(r.is_ok());
        }
        // Lookups 1..=budget serve failure #1, lookup budget+1 retries
        // (failure #2), lookups through 2*budget serve it, and lookup
        // 2*budget+1 retries again — successfully this time.
        assert_eq!(runs.load(Ordering::Relaxed), 3, "build ran once per budget window");
        assert!(outcomes[..2 * budget].iter().all(|ok| !ok));
        assert!(outcomes[2 * budget], "recovered after the budget elapsed");
        assert_eq!(cache.get_or_build(7, || Err("never".into())), Ok(Arc::new(42)));
    }

    #[test]
    fn retry_failures_drops_memoized_failures_immediately() {
        let cache: ShardedLruCache<u8, u8> = ShardedLruCache::new(2, 8);
        cache.get_or_build(1, || Ok(1)).unwrap();
        cache.get_or_build(2, || Err("boom".into())).unwrap_err();
        cache.get_or_build(3, || Err("boom".into())).unwrap_err();
        assert_eq!(cache.retry_failures(), 2, "both failures dropped");
        assert_eq!(cache.len(), 1, "the success stays cached");
        // Next lookup of a dropped key re-runs the build.
        assert_eq!(cache.get_or_build(2, || Ok(2)), Ok(Arc::new(2)));
        assert_eq!(cache.retry_failures(), 0, "nothing failed anymore");
    }

    #[test]
    fn design_and_program_handles_share_artifacts() {
        let a = design_handle(4, 2, 5).unwrap();
        let b = design_handle(4, 2, 5).unwrap();
        let c = design_handle(4, 2, 6).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same geometry shares one design");
        assert!(!Arc::ptr_eq(&a, &c), "distinct θ gets its own design");
        assert_eq!((a.p, a.q, a.theta), (4, 2, 5));
        let p1 = program_handle(4, 2, 5, OptLevel::None).unwrap();
        let p2 = program_handle(4, 2, 5, OptLevel::None).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "programs shared per (geometry, opt)");
        let lean = program_handle(4, 2, 5, OptLevel::Inference).unwrap();
        assert!(lean.prog.instr_count() < p1.prog.instr_count());
    }
}
