//! Structural generator for complete p×q TNN column designs.
//!
//! Assembles the microarchitecture of Fig. 1 of the paper out of the nine
//! macros plus standard arithmetic (adder trees, accumulators, comparators):
//!
//! ```text
//!  IN[i] ─ pulse2edge → EIN_i ─ edge2pulse → SPIKE_i        (encode, ×p;
//!          spike_gen window monitored)                       Fig. 8–10)
//!  synapse (i,j), ×p×q:
//!     syn_weight_update(SPIKE_i, WT_INC, WT_DEC) → W, C, RD  (Fig. 3)
//!     syn_readout(C, RD) → RESP_ij                           (Fig. 2)
//!     less_equal(EIN_i, EOUT_j) → GREATER                    (Fig. 4)
//!     stdp_case_gen(GREATER, EIN_i, EOUT_j) → cases          (Fig. 5)
//!     stabilize_func(sel = W / ~W by direction, B0..7)       (Fig. 7)
//!     incdec(cases, BRVs, BSTAB) → INC, DEC                  (Fig. 6)
//!     WT_INC/WT_DEC = INC/DEC strobed at gamma end
//!  neuron j, ×q:
//!     popcount(RESP_*j) → accumulator ─ ≥ θ → FIRE_j
//!  WTA:
//!     less_equal(FIRE_j, OR_{k≠j} FIRE_k) + priority chain → EOUT_j
//! ```
//!
//! The generator serves three purposes: functional cross-check against the
//! golden model (BRV streams as primary inputs), synthesis workload for the
//! Fig. 11/12 experiments (BRVs from an on-column LFSR, self-contained),
//! and PPA analysis target.

use super::macros9::MacroKind;
use super::netlist::{NetBuilder, NetId, Netlist};
use super::opt::{KeepSet, NetRemap, OptAssumptions, PassPipeline};
use super::sim::Simulator;
use crate::tnn::params::TnnParams;
use crate::tnn::spike::SpikeTime;

/// Where the Bernoulli random variables come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BrvSource {
    /// Primary inputs — controllable, used for golden-model cross-checks.
    Inputs,
    /// On-column LFSR bank — self-contained, used for synthesis/PPA (the
    /// real column of [6] carries its pseudo-random source on silicon).
    Lfsr,
}

/// Handles into the generated netlist for stimulus and observation.
#[derive(Clone, Debug)]
pub struct ColumnDesign {
    /// The generated column netlist (macros + glue).
    pub netlist: Netlist,
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons in the column.
    pub q: usize,
    /// Neuron firing threshold baked into the comparator tree.
    pub theta: u32,
    /// Per input line: the IN pulse net.
    pub in_pulse: Vec<NetId>,
    /// Gamma reset / gamma-end strobe (single net, doubles as both).
    pub grst: NetId,
    /// Post-WTA output edges, one per neuron.
    pub out_spike: Vec<NetId>,
    /// Pre-WTA fire edges, one per neuron (monitor).
    pub fire: Vec<NetId>,
    /// Per synapse (row-major i*q+j): index of its SynWeightUpdate macro
    /// instance (for weight preload/observation in behavioral simulation).
    pub syn_inst: Vec<u32>,
    /// Per synapse: BRV input nets `[BCAP, BMIN, BSRCH, BBKF]`
    /// (empty when `BrvSource::Lfsr`).
    pub brv_case: Vec<[NetId; 4]>,
    /// Per synapse: the 8 stabilization stream nets `B0..B7`
    /// (empty when `BrvSource::Lfsr`).
    pub brv_stab: Vec<[NetId; 8]>,
}

impl ColumnDesign {
    /// The explicit keep-set for the netlist optimizer: every net the
    /// engines stimulate or observe by id (`in_pulse`, `grst`,
    /// `out_spike`, `fire`). Monitored nets that are primary outputs
    /// (the `win[i]` spike-encode windows) are implicit liveness roots
    /// already; listing the engine-addressed nets here makes the
    /// "optimization cannot delete it" contract independent of how the
    /// port list evolves.
    pub fn keep_set(&self) -> KeepSet {
        let mut keep = KeepSet::from_nets(self.in_pulse.iter().copied());
        keep.insert(self.grst);
        for &n in &self.out_spike {
            keep.insert(n);
        }
        for &n in &self.fire {
            keep.insert(n);
        }
        keep
    }

    /// The batched-inference protocol's optimizer assumptions: every BRV
    /// input (`brv_case` + `brv_stab`) is tied low, exactly as the gate
    /// engine and the fault campaigns silence them. Empty for
    /// `BrvSource::Lfsr` columns (no BRV inputs to tie).
    pub fn inference_assumptions(&self) -> OptAssumptions {
        OptAssumptions::tied_low(
            self.brv_case
                .iter()
                .flatten()
                .chain(self.brv_stab.iter().flatten())
                .copied(),
        )
    }

    /// Run the inference [`PassPipeline`] over the column and return the
    /// optimized design (all stimulus/observation handles translated via
    /// the remap) plus the remap itself. The BRV handle vectors come back
    /// empty: constant propagation rewires their readers and dead-code
    /// elimination removes the tied inputs, so there is nothing left to
    /// silence.
    pub fn optimize_inference(&self) -> Result<(ColumnDesign, NetRemap), String> {
        let pipeline = PassPipeline::inference(self.inference_assumptions(), self.keep_set());
        let (netlist, remap) = pipeline.run(&self.netlist)?;
        let net = |n: NetId| remap.net(n).expect("keep-set net survived optimization");
        let d = ColumnDesign {
            netlist,
            p: self.p,
            q: self.q,
            theta: self.theta,
            in_pulse: self.in_pulse.iter().map(|&n| net(n)).collect(),
            grst: net(self.grst),
            out_spike: self.out_spike.iter().map(|&n| net(n)).collect(),
            fire: self.fire.iter().map(|&n| net(n)).collect(),
            syn_inst: self
                .syn_inst
                .iter()
                .map(|&i| {
                    remap
                        .macro_inst(i)
                        .expect("weight-readout instance survived optimization")
                })
                .collect(),
            brv_case: Vec::new(),
            brv_stab: Vec::new(),
        };
        debug_assert!(
            self.brv_case
                .iter()
                .flatten()
                .chain(self.brv_stab.iter().flatten())
                .all(|&n| remap.net(n).is_none()),
            "tied-low BRV inputs should fold away entirely"
        );
        Ok((d, remap))
    }
}

/// Build a p×q column netlist.
pub fn build_column(p: usize, q: usize, theta: u32, brv: BrvSource) -> ColumnDesign {
    assert!(p >= 1 && q >= 1);
    let mut b = NetBuilder::new(&format!("column_{p}x{q}"));

    // --- global controls ---------------------------------------------------
    let grst = b.input("GRST");

    // --- input encode block (×p) --------------------------------------------
    let mut in_pulse = Vec::with_capacity(p);
    let mut ein = Vec::with_capacity(p);
    let mut spike = Vec::with_capacity(p);
    for i in 0..p {
        let x = b.input(&format!("IN[{i}]"));
        in_pulse.push(x);
        let e = b.macro_inst(MacroKind::Pulse2Edge, vec![x, grst])[0];
        ein.push(e);
        let sp = b.macro_inst(MacroKind::Edge2Pulse, vec![e, grst])[0];
        spike.push(sp);
        // Spike-encoding window (Fig. 8) — part of the real column's encode
        // block; monitored as a primary output, which roots it in the
        // optimizer's liveness sweep (see `ColumnDesign::keep_set` for the
        // non-port nets under the same contract).
        let win = b.macro_inst(MacroKind::SpikeGen, vec![x, grst])[0];
        b.output(&format!("win[{i}]"), win);
    }

    // --- LFSR BRV bank (synthesis configuration) ----------------------------
    // 16-bit Fibonacci LFSR (x^16 + x^15 + x^13 + x^4 + 1), shared by the
    // column; stream probabilities are built from tap combinations.
    let lfsr_bits: Vec<NetId> = if brv == BrvSource::Lfsr {
        let cells = b.dff_cell_vec(16);
        let t0 = b.xor(cells[15], cells[14]);
        let t1 = b.xor(t0, cells[12]);
        let fb = b.xor(t1, cells[3]);
        let mut next = vec![fb];
        next.extend_from_slice(&cells[..15]);
        b.patch_dff_vec(&cells, &next, None, 0xACE1); // nonzero seed
        cells
    } else {
        Vec::new()
    };
    let mut lfsr_rot = 0usize;

    // --- synapse datapath (×p×q) ---------------------------------------------
    // STDP control (WT_INC/WT_DEC) is produced by logic built after the WTA;
    // forward wires bridge the passes.
    let mut resp = vec![Vec::with_capacity(p); q]; // resp[j][i]
    let mut syn_inst = Vec::with_capacity(p * q);
    let mut wt_inc_wires = Vec::with_capacity(p * q);
    let mut wt_dec_wires = Vec::with_capacity(p * q);
    let mut w_bits: Vec<[NetId; 3]> = Vec::with_capacity(p * q);
    for i in 0..p {
        for _j in 0..q {
            let wi = b.wire();
            let wd = b.wire();
            wt_inc_wires.push(wi);
            wt_dec_wires.push(wd);
            let outs = b.macro_inst(MacroKind::SynWeightUpdate, vec![spike[i], wi, wd, grst]);
            syn_inst.push((b.netlist().macros.len() - 1) as u32);
            w_bits.push([outs[0], outs[1], outs[2]]);
            let r = b.macro_inst(
                MacroKind::SynReadout,
                vec![outs[3], outs[4], outs[5], outs[6]],
            )[0];
            resp[_j].push(r);
        }
    }

    // --- neuron bodies (×q) ---------------------------------------------------
    let mut fire = Vec::with_capacity(q);
    for j in 0..q {
        let cnt = b.popcount(&resp[j]);
        let max_pot = (p as u64) * 7;
        let wa = (64 - max_pot.leading_zeros()) as usize;
        let zero = b.constant(false);
        let mut cnt_w = cnt.clone();
        cnt_w.resize(wa, zero);
        let acc = b.dff_cell_vec(wa);
        let sum = b.add_vec(&acc, &cnt_w); // wa+1 bits; carry unreachable
        b.patch_dff_vec(&acc, &sum[..wa], Some(grst), 0);
        let f = b.ge_const(&sum[..wa], theta as u64);
        fire.push(f);
        b.output(&format!("fire[{j}]"), f);
    }

    // --- 1-WTA lateral inhibition ----------------------------------------------
    let fal = b.constant(false);
    let mut prefix = vec![fal; q]; // OR of fire[0..j)
    for j in 1..q {
        prefix[j] = b.or(prefix[j - 1], fire[j - 1]);
    }
    let mut suffix = vec![fal; q]; // OR of fire(j..q)
    for j in (0..q.saturating_sub(1)).rev() {
        suffix[j] = b.or(suffix[j + 1], fire[j + 1]);
    }
    let mut le_out = Vec::with_capacity(q);
    for j in 0..q {
        let inh = b.or(prefix[j], suffix[j]);
        let le = b.macro_inst(MacroKind::LessEqual, vec![fire[j], inh, grst])[0];
        le_out.push(le);
    }
    // Priority chain: all surviving less_equal edges rise on the same (min)
    // cycle, so a static lowest-index-wins chain implements the tie-break.
    let mut eout = Vec::with_capacity(q);
    let mut le_pre = fal;
    for j in 0..q {
        let nle = b.not(le_pre);
        let e = b.and(le_out[j], nle);
        eout.push(e);
        b.output(&format!("out[{j}]"), e);
        le_pre = b.or(le_pre, le_out[j]);
    }

    // --- STDP control (×p×q, pass 2) ---------------------------------------------
    let mut brv_case_nets = Vec::new();
    let mut brv_stab_nets = Vec::new();
    for i in 0..p {
        for j in 0..q {
            let k = i * q + j;
            // GREATER_ij = !(x_i ≤ y_j) via a less_equal on the edges.
            let le = b.macro_inst(MacroKind::LessEqual, vec![ein[i], eout[j], grst])[0];
            let greater = b.not(le);
            let cases = b.macro_inst(MacroKind::StdpCaseGen, vec![greater, ein[i], eout[j]]);
            let (c0, c1, c2, c3) = (cases[0], cases[1], cases[2], cases[3]);
            // Direction-dependent stabilize select: INC uses W, DEC uses ~W
            // (prob (w+1)/8 up, (w_max−w+1)/8 down).
            let inc_case = b.or(c0, c2);
            let [w0, w1, w2] = w_bits[k];
            let nw0 = b.not(w0);
            let nw1 = b.not(w1);
            let nw2 = b.not(w2);
            let s0 = b.mux(inc_case, nw0, w0);
            let s1 = b.mux(inc_case, nw1, w1);
            let s2 = b.mux(inc_case, nw2, w2);
            let (case_nets, stab_nets): ([NetId; 4], [NetId; 8]) = match brv {
                BrvSource::Inputs => {
                    let c = [
                        b.input(&format!("BCAP[{k}]")),
                        b.input(&format!("BMIN[{k}]")),
                        b.input(&format!("BSRCH[{k}]")),
                        b.input(&format!("BBKF[{k}]")),
                    ];
                    let mut s = [0 as NetId; 8];
                    for (m, slot) in s.iter_mut().enumerate() {
                        *slot = b.input(&format!("BST{m}[{k}]"));
                    }
                    (c, s)
                }
                BrvSource::Lfsr => {
                    // µ_capture≈1 (const1), µ_minus≈1/2 (tap),
                    // µ_search≈1/16 (AND of 4 taps), µ_backoff≈1/2 (tap).
                    let one = b.constant(true);
                    let t: Vec<NetId> = (0..6)
                        .map(|m| lfsr_bits[(lfsr_rot + m * 5) % 16])
                        .collect();
                    lfsr_rot = (lfsr_rot + 7) % 16;
                    let srch1 = b.and(t[0], t[1]);
                    let srch2 = b.and(t[2], t[3]);
                    let srch = b.and(srch1, srch2);
                    let c = [one, t[4], srch, t[5]];
                    // B_m with prob (m+1)/8 from 3 fresh taps.
                    let u: Vec<NetId> = (0..3)
                        .map(|m| lfsr_bits[(lfsr_rot + m * 5) % 16])
                        .collect();
                    lfsr_rot = (lfsr_rot + 7) % 16;
                    let (ta, tb, tc) = (u[0], u[1], u[2]);
                    let and_ab = b.and(ta, tb);
                    let and_abc = b.and(and_ab, tc); // 1/8
                    let or_bc = b.or(tb, tc);
                    let a_and_orbc = b.and(ta, or_bc); // 3/8
                    let and_bc = b.and(tb, tc);
                    let a_or_andbc = b.or(ta, and_bc); // 5/8
                    let ab_or = b.or(ta, tb); // 6/8
                    let abc_or = b.or(ab_or, tc); // 7/8
                    let s = [and_abc, and_ab, a_and_orbc, ta, a_or_andbc, ab_or, abc_or, one];
                    (c, s)
                }
            };
            if brv == BrvSource::Inputs {
                brv_case_nets.push(case_nets);
                brv_stab_nets.push(stab_nets);
            }
            let bstab = b.macro_inst(
                MacroKind::StabilizeFunc,
                vec![
                    s0,
                    s1,
                    s2,
                    stab_nets[0],
                    stab_nets[1],
                    stab_nets[2],
                    stab_nets[3],
                    stab_nets[4],
                    stab_nets[5],
                    stab_nets[6],
                    stab_nets[7],
                ],
            )[0];
            let id = b.macro_inst(
                MacroKind::IncDec,
                vec![
                    c0,
                    c1,
                    c2,
                    c3,
                    case_nets[0],
                    case_nets[1],
                    case_nets[2],
                    case_nets[3],
                    bstab,
                ],
            );
            // Weight updates strobed at gamma end (GRST doubles as GEND; a
            // synchronous reset captures after the update is applied).
            let wt_inc = b.and(id[0], grst);
            let wt_dec = b.and(id[1], grst);
            b.connect(wt_inc_wires[k], wt_inc);
            b.connect(wt_dec_wires[k], wt_dec);
        }
    }

    let netlist = b.finish();
    ColumnDesign {
        netlist,
        p,
        q,
        theta,
        in_pulse,
        grst,
        out_spike: eout,
        fire,
        syn_inst,
        brv_case: brv_case_nets,
        brv_stab: brv_stab_nets,
    }
}

/// Gate-level column simulation harness (requires `BrvSource::Inputs`).
pub struct ColumnSim<'a> {
    design: &'a ColumnDesign,
    /// The underlying netlist simulator (exposed for probing nets).
    pub sim: Simulator<'a>,
    params: TnnParams,
}

impl<'a> ColumnSim<'a> {
    /// Build a simulator over `design` (requires input-driven BRVs).
    pub fn new(design: &'a ColumnDesign, params: TnnParams) -> Result<Self, String> {
        assert!(
            !design.brv_case.is_empty(),
            "ColumnSim requires BrvSource::Inputs"
        );
        let sim = Simulator::new(&design.netlist)?;
        Ok(ColumnSim {
            design,
            sim,
            params,
        })
    }

    /// Preload synaptic weights (row-major p×q).
    pub fn set_weights(&mut self, ws: &[u8]) {
        assert_eq!(ws.len(), self.design.p * self.design.q);
        for (k, &w) in ws.iter().enumerate() {
            let inst = self.design.syn_inst[k] as usize;
            let mut st = self.sim.macro_state(inst).clone();
            st.set_weight(w);
            self.sim.set_macro_state(inst, st);
        }
    }

    /// Read back the stored weights.
    pub fn weights(&self) -> Vec<u8> {
        self.design
            .syn_inst
            .iter()
            .map(|&inst| self.sim.macro_state(inst as usize).weight())
            .collect()
    }

    /// Run one gamma cycle with the same uniform draws the golden model
    /// consumes; returns the post-WTA spike times.
    pub fn run_gamma(
        &mut self,
        xs: &[SpikeTime],
        u_case: &[f64],
        u_stab: &[f64],
    ) -> Vec<SpikeTime> {
        let d = self.design;
        assert_eq!(xs.len(), d.p);
        let n = d.p * d.q;
        assert_eq!(u_case.len(), n);
        assert_eq!(u_stab.len(), n);
        let g = self.params.gamma_cycles;
        let w_max = self.params.w_max() as f64;
        let mut out = vec![SpikeTime::NONE; d.q];

        // BRV inputs are constant across the gamma cycle (sampled by the
        // gamma-end strobe). All four case streams derive from the one
        // uniform draw — equivalent to the golden model's single
        // `u_case < µ(active case)` test because the cases are one-hot.
        for k in 0..n {
            let c = d.brv_case[k];
            self.sim.set_input_net(c[0], u_case[k] < self.params.mu_capture);
            self.sim.set_input_net(c[1], u_case[k] < self.params.mu_minus);
            self.sim.set_input_net(c[2], u_case[k] < self.params.mu_search);
            self.sim.set_input_net(c[3], u_case[k] < self.params.mu_backoff);
            for m in 0..8 {
                let prob = if self.params.stabilize {
                    (m as f64 + 1.0) / (w_max + 1.0)
                } else {
                    1.0
                };
                self.sim.set_input_net(d.brv_stab[k][m], u_stab[k] < prob);
            }
        }

        for t in 0..g {
            for (i, &x) in xs.iter().enumerate() {
                self.sim
                    .set_input_net(d.in_pulse[i], x.is_spike() && x.0 == t);
            }
            self.sim.set_input_net(d.grst, t == g - 1);
            self.sim.settle();
            for (j, &net) in d.out_spike.iter().enumerate() {
                if self.sim.get(net) && !out[j].is_spike() {
                    out[j] = SpikeTime::at(t);
                }
            }
            self.sim.clock();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tnn::column::Column;
    use crate::util::Rng64;

    #[test]
    fn column_netlist_builds_and_levelizes() {
        let d = build_column(4, 2, 4, BrvSource::Inputs);
        assert_eq!(d.in_pulse.len(), 4);
        assert_eq!(d.out_spike.len(), 2);
        assert_eq!(d.syn_inst.len(), 8);
        d.netlist.levelize().expect("acyclic");
        // p*(p2e + e2p + spike_gen) + p*q*(swu + readout + le + casegen +
        // stab + incdec) + q*le(wta)
        assert_eq!(d.netlist.macros.len(), 3 * 4 + 6 * 8 + 2);
    }

    #[test]
    fn lfsr_variant_is_self_contained() {
        let d = build_column(3, 2, 3, BrvSource::Lfsr);
        assert_eq!(d.netlist.inputs.len(), 1 + 3, "only GRST + IN[i]");
        d.netlist.levelize().expect("acyclic");
    }

    #[test]
    fn gate_column_matches_golden_inference() {
        let mut rng = Rng64::seed_from_u64(77);
        for trial in 0..10 {
            let (p, q) = (rng.gen_range(2, 7), rng.gen_range(1, 4));
            let theta = rng.gen_range(1, p * 3) as u32;
            let params = TnnParams::default();
            let design = build_column(p, q, theta, BrvSource::Inputs);
            let mut gsim = ColumnSim::new(&design, params.clone()).unwrap();
            let mut golden = Column::with_random_weights(p, q, theta, params, &mut rng);
            gsim.set_weights(golden.weights());
            let xs: Vec<SpikeTime> = (0..p)
                .map(|_| {
                    if rng.gen_bool(0.25) {
                        SpikeTime::NONE
                    } else {
                        SpikeTime::at(rng.gen_range(0, 8) as u32)
                    }
                })
                .collect();
            // u = 1.0 blocks every update → pure inference.
            let ones = vec![1.0; p * q];
            let got = gsim.run_gamma(&xs, &ones, &ones);
            let want = golden.step_with_uniforms(&xs, &ones, &ones);
            assert_eq!(got, want.output, "trial {trial} p={p} q={q} theta={theta}");
        }
    }

    #[test]
    fn gate_column_matches_golden_learning_over_many_gammas() {
        let mut rng = Rng64::seed_from_u64(123);
        let (p, q, theta) = (5, 2, 6);
        let params = TnnParams::default();
        let design = build_column(p, q, theta, BrvSource::Inputs);
        let mut gsim = ColumnSim::new(&design, params.clone()).unwrap();
        let mut golden = Column::with_random_weights(p, q, theta, params, &mut rng);
        gsim.set_weights(golden.weights());
        let n = p * q;
        for gamma in 0..40 {
            let xs: Vec<SpikeTime> = (0..p)
                .map(|_| {
                    if rng.gen_bool(0.3) {
                        SpikeTime::NONE
                    } else {
                        SpikeTime::at(rng.gen_range(0, 8) as u32)
                    }
                })
                .collect();
            let mut u_case = vec![0.0; n];
            let mut u_stab = vec![0.0; n];
            rng.fill_f64(&mut u_case);
            rng.fill_f64(&mut u_stab);
            let got = gsim.run_gamma(&xs, &u_case, &u_stab);
            let want = golden.step_with_uniforms(&xs, &u_case, &u_stab);
            assert_eq!(got, want.output, "gamma {gamma}: spike mismatch");
            assert_eq!(
                gsim.weights(),
                golden.weights(),
                "gamma {gamma}: weight mismatch"
            );
        }
    }
}
