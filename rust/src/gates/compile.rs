//! Compiled netlist programs: the gate-level hot loop as a flat
//! instruction stream over multi-word lane blocks.
//!
//! [`WordSimulator`](super::wordsim::WordSimulator) interprets the
//! level-packed schedule by re-matching on [`Gate`] variants and
//! re-resolving macro pins every settle. This module *compiles* that
//! schedule once — [`CompiledProgram::compile`] lowers
//! [`Netlist::levelize_buckets`] output into a flat stream of fixed-width
//! instructions (a `u8` opcode plus pre-resolved operand net slots), with
//! the macro pins of each instance grouped per level so the instance's
//! behavioral model evaluates **once per level** and its pins commit as
//! plain stores (no per-pin input comparison at all) — and
//! [`CompiledSim`] executes it:
//!
//! * **Lane blocks.** Every net carries `W` `u64` words
//!   (`W` = [`CompiledSim::words`], the `sim_words` config key), so one
//!   settle pass advances `W × 64` independent stimulus lanes. Word `w`
//!   of the compiled engine is bit-for-bit an independent 64-lane
//!   `WordSimulator` run under the same stimulus, and lane 0 of word 0
//!   is the scalar engine — both enforced by `tests/compiled_sim.rs`.
//! * **Sharded levels.** Each level's instruction slice is split into
//!   contiguous, work-indexed chunks across `threads` `std::thread`
//!   workers (chunk `k` of a level is always the same instructions, no
//!   matter which worker runs it). Every instruction writes only its own
//!   destination net's value words and toggle counter, and reads only
//!   nets settled in earlier levels, so the partitioning cannot change
//!   any value or toggle count: results are **bit-exact at any worker
//!   count** — the determinism contract of `docs/ARCHITECTURE.md`.
//!
//! The interpreted engines stay as the reference: the differential suite
//! (`tests/compiled_sim.rs`) holds the compiled engine to exact value and
//! toggle equality against both of them over the shared
//! [`super::CONFORMANCE_GEOMETRIES`] matrix.

use super::macros9::{self, MacroKind, MacroState, WordMacroState};
use super::netlist::{Gate, NetId, Netlist};
use std::collections::BTreeMap;
use std::sync::Barrier;

/// Lanes per word (one bit per lane).
const LANES: usize = macros9::WORD_LANES;

/// Sentinel for "no reset net" in a [`DffSlot`].
const NO_RST: u32 = u32::MAX;

/// Sentinel in a macro group's gather list for an input position outside
/// the group's dep union: read as constant 0 instead of touching the net.
const NO_NET: u32 = u32::MAX;

/// Compiled opcodes. `Macro` evaluates one macro instance for one level
/// and commits all of that level's pins of the instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Op {
    Buf,
    Not,
    And,
    Or,
    Xor,
    Mux,
    Macro,
}

/// One fixed-width instruction: opcode + pre-resolved operand slots.
/// Gate ops read nets `a`/`b`/`c` (Mux: `a` = select, `b` = else-net,
/// `c` = then-net) and write net `dst`; `Macro` reads group `a` of
/// [`CompiledProgram::groups`] (its `dst`/`b`/`c` are unused).
#[derive(Clone, Copy, Debug)]
struct Instr {
    op: Op,
    dst: u32,
    a: u32,
    b: u32,
    c: u32,
}

/// One (macro instance, level) evaluation group: which instance to
/// evaluate and which of its pins commit in this level.
#[derive(Clone, Copy, Debug)]
struct MacroGroup {
    /// Macro instance index (into [`CompiledProgram::minsts`]).
    inst: u32,
    /// Range into [`CompiledProgram::group_pins`].
    pin_start: u32,
    pin_end: u32,
    /// Range into [`CompiledProgram::group_gather`]: the instance's full
    /// input arity, with positions outside this group's dep union set to
    /// [`NO_NET`]. Restricting settle-time reads to declared deps is what
    /// keeps the sharded execution race-free — a non-dep input may be
    /// driven by a net in this very level (levelization only orders pins
    /// after their *deps*), and by the `pin_deps` contract the committed
    /// pins' outputs cannot depend on it, so it is read as constant 0.
    in_start: u32,
    in_end: u32,
}

/// Per-instance macro metadata shared by settle groups, `clock` state
/// stepping and Moore-pin refresh.
#[derive(Clone, Copy, Debug)]
struct MInst {
    kind: MacroKind,
    /// Range into [`CompiledProgram::minputs`].
    in_start: u32,
    in_end: u32,
    /// Range into [`CompiledProgram::moore_pins`].
    moore_start: u32,
    moore_end: u32,
}

/// One D flip-flop: output net, data net, reset net (`NO_RST` = never
/// resets) and reset/init value.
#[derive(Clone, Copy, Debug)]
struct DffSlot {
    net: u32,
    d: u32,
    rst: u32,
    init: bool,
}

/// A netlist lowered to a flat, self-contained instruction stream.
///
/// The program copies everything the executor needs out of the source
/// [`Netlist`] (schedule, operand slots, macro pin tables, DFF table,
/// constants, port names), so it owns no borrows and outlives the
/// netlist it was compiled from.
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    /// Design name (inherited from the netlist; labels reports).
    pub name: String,
    n_nets: usize,
    instrs: Vec<Instr>,
    /// `level_ends[k]` = exclusive end index of level `k` in `instrs`.
    level_ends: Vec<u32>,
    groups: Vec<MacroGroup>,
    /// `(pin, dst net)` pairs, grouped by [`MacroGroup`] ranges.
    group_pins: Vec<(u8, u32)>,
    /// Per-group settle gather lists (dep-union inputs; [`NO_NET`] = 0).
    group_gather: Vec<NetId>,
    minsts: Vec<MInst>,
    minputs: Vec<NetId>,
    /// `(pin, dst net)` pairs of Moore (state-only) macro outputs.
    moore_pins: Vec<(u8, u32)>,
    dffs: Vec<DffSlot>,
    /// Nets driven by `Const(true)`.
    const_ones: Vec<NetId>,
    /// Primary-input flags (for the `set_input_net` debug assert).
    is_input: Vec<bool>,
    /// Primary inputs: (name, net) — resolved by `bind_inputs`.
    inputs: Vec<(String, NetId)>,
    /// Primary outputs: (name, net) — resolved by `bind_outputs`.
    outputs: Vec<(String, NetId)>,
    /// Widest macro input list (gather-buffer size).
    max_macro_inputs: usize,
}

impl CompiledProgram {
    /// Run an optimizer [`PassPipeline`](super::opt::PassPipeline) over
    /// `nl`, then lower the optimized netlist. Returns the program
    /// together with the composed [`NetRemap`](super::opt::NetRemap) so
    /// callers can translate net ids (stimulus, observation, fault
    /// sites, per-net toggle/α vectors) into the optimized space.
    ///
    /// The program is *only* equivalent to the unoptimized one under
    /// stimulus that honors the pipeline's `OptAssumptions` (tied-low
    /// inputs actually held low), and only on nets the remap retains.
    pub fn compile_opt(
        nl: &Netlist,
        pipeline: &super::opt::PassPipeline,
    ) -> Result<(CompiledProgram, super::opt::NetRemap), String> {
        let (optimized, remap) = pipeline.run(nl)?;
        Ok((Self::compile(&optimized)?, remap))
    }

    /// Lower a netlist's level-packed schedule into a compiled program.
    /// Runs [`Netlist::verify`] first, so dangling nets, inconsistent
    /// macro pin tables and combinational cycles all fail loudly here
    /// instead of corrupting the instruction stream.
    pub fn compile(nl: &Netlist) -> Result<CompiledProgram, String> {
        nl.verify()?;
        let levels = nl.levelize_buckets()?;

        // Per-instance metadata (inputs, Moore pins) for settle + clock.
        let mut minputs = Vec::new();
        let mut moore_pins = Vec::new();
        let mut minsts = Vec::with_capacity(nl.macros.len());
        let mut max_macro_inputs = 0usize;
        for m in &nl.macros {
            let in_start = minputs.len() as u32;
            minputs.extend_from_slice(&m.inputs);
            max_macro_inputs = max_macro_inputs.max(m.inputs.len());
            let moore_start = moore_pins.len() as u32;
            for (pin, &net) in m.outputs.iter().enumerate() {
                if m.kind.pin_deps(pin as u8).is_empty() {
                    moore_pins.push((pin as u8, net));
                }
            }
            minsts.push(MInst {
                kind: m.kind,
                in_start,
                in_end: minputs.len() as u32,
                moore_start,
                moore_end: moore_pins.len() as u32,
            });
        }

        // Instruction stream: per level, gate ops in net order, then one
        // Macro group per instance (ascending instance id) covering every
        // Mealy pin of that instance scheduled in this level.
        let mut instrs = Vec::new();
        let mut level_ends = Vec::with_capacity(levels.len());
        let mut groups: Vec<MacroGroup> = Vec::new();
        let mut group_pins: Vec<(u8, u32)> = Vec::new();
        let mut group_gather: Vec<NetId> = Vec::new();
        for level in &levels {
            let mut by_inst: BTreeMap<u32, Vec<(u8, u32)>> = BTreeMap::new();
            for &id in level {
                match nl.gates[id as usize] {
                    Gate::Buf(a) => instrs.push(Instr { op: Op::Buf, dst: id, a, b: 0, c: 0 }),
                    Gate::Not(a) => instrs.push(Instr { op: Op::Not, dst: id, a, b: 0, c: 0 }),
                    Gate::And(a, b) => instrs.push(Instr { op: Op::And, dst: id, a, b, c: 0 }),
                    Gate::Or(a, b) => instrs.push(Instr { op: Op::Or, dst: id, a, b, c: 0 }),
                    Gate::Xor(a, b) => instrs.push(Instr { op: Op::Xor, dst: id, a, b, c: 0 }),
                    Gate::Mux(s, a, b) => {
                        instrs.push(Instr { op: Op::Mux, dst: id, a: s, b: a, c: b })
                    }
                    Gate::MacroOut { inst, pin } => {
                        by_inst.entry(inst).or_default().push((pin, id));
                    }
                    ref g => {
                        // Sources and state elements are never scheduled
                        // by levelize_buckets.
                        unreachable!("non-combinational gate {g:?} in schedule")
                    }
                }
            }
            for (inst, pins) in by_inst {
                let m = &nl.macros[inst as usize];
                // Settle gather = union of the group's pins' declared deps
                // (all strictly earlier levels); every other input position
                // reads as constant 0 — output-preserving by the pin_deps
                // contract, and the reason sharded settles cannot race on a
                // same-level non-dep driver.
                let mut in_union = vec![false; m.inputs.len()];
                for &(pin, _) in &pins {
                    for &d in m.kind.pin_deps(pin) {
                        in_union[d] = true;
                    }
                }
                let in_start = group_gather.len() as u32;
                for (k, &src) in m.inputs.iter().enumerate() {
                    group_gather.push(if in_union[k] { src } else { NO_NET });
                }
                let pin_start = group_pins.len() as u32;
                group_pins.extend(pins);
                groups.push(MacroGroup {
                    inst,
                    pin_start,
                    pin_end: group_pins.len() as u32,
                    in_start,
                    in_end: group_gather.len() as u32,
                });
                instrs.push(Instr {
                    op: Op::Macro,
                    dst: 0,
                    a: (groups.len() - 1) as u32,
                    b: 0,
                    c: 0,
                });
            }
            level_ends.push(instrs.len() as u32);
        }

        // Sequential + source side tables.
        let mut dffs = Vec::new();
        let mut const_ones = Vec::new();
        let mut is_input = vec![false; nl.gates.len()];
        for (i, g) in nl.gates.iter().enumerate() {
            match *g {
                Gate::Dff { d, rst, init } => dffs.push(DffSlot {
                    net: i as u32,
                    d,
                    rst: rst.unwrap_or(NO_RST),
                    init,
                }),
                Gate::Const(true) => const_ones.push(i as NetId),
                Gate::Input => is_input[i] = true,
                _ => {}
            }
        }

        Ok(CompiledProgram {
            name: nl.name.clone(),
            n_nets: nl.gates.len(),
            instrs,
            level_ends,
            groups,
            group_pins,
            group_gather,
            minsts,
            minputs,
            moore_pins,
            dffs,
            const_ones,
            is_input,
            inputs: nl.inputs.clone(),
            outputs: nl.outputs.clone(),
            max_macro_inputs,
        })
    }

    /// Net count of the compiled design.
    pub fn net_count(&self) -> usize {
        self.n_nets
    }

    /// Combinational levels in the schedule.
    pub fn level_count(&self) -> usize {
        self.level_ends.len()
    }

    /// Total instructions (gate ops + macro groups).
    pub fn instr_count(&self) -> usize {
        self.instrs.len()
    }

    /// (instance, level) macro evaluation groups — the number of macro
    /// model evaluations one settle performs per word.
    pub fn macro_group_count(&self) -> usize {
        self.groups.len()
    }
}

/// Contiguous work-indexed chunk `[lo, hi)` of `len` items for worker
/// `wid` of `workers` — the frozen partitioning rule of the sharded
/// settle (chunk boundaries depend only on `(len, wid, workers)`).
fn chunk(len: usize, wid: usize, workers: usize) -> (usize, usize) {
    (len * wid / workers, len * (wid + 1) / workers)
}

/// Raw-pointer view of the mutable execution state, shared by the settle
/// workers of one `thread::scope`.
///
/// # Safety invariants (upheld by `settle`)
///
/// * `values` points at `n_nets × words` words, `toggles` at `n_nets`
///   counters, `states` at `n_macros × words` macro states; all outlive
///   the scope.
/// * Within one level, every instruction writes only its own destination
///   nets' value words and toggle counters, and destinations are unique
///   across the level. Every operand read is a net settled in an earlier
///   level: gate operands by `levelize_buckets` construction, and macro
///   gathers because they are restricted to the group's pin-dep union
///   (each dep is strictly below its pin's level; non-dep positions read
///   as constant 0 via `NO_NET`, never as a net). So concurrent workers
///   never touch the same slot.
/// * `states` is only read during settle (mutated exclusively by `clock`,
///   which runs on the driver thread with `&mut self`).
/// * `force_sa0` / `force_sa1` are either both null (fault-free run) or
///   both point at `n_nets × words` stuck-at lane masks that are only read
///   during settle (mutated exclusively through `&mut self` between
///   settles) — so sharing them between workers is read-read.
/// * Levels are separated by a barrier all workers pass through.
#[derive(Clone, Copy)]
struct ExecShared<'p> {
    prog: &'p CompiledProgram,
    values: *mut u64,
    toggles: *mut u64,
    states: *const WordMacroState,
    force_sa0: *const u64,
    force_sa1: *const u64,
    words: usize,
}

// SAFETY: see the invariant list on `ExecShared` — all aliasing between
// workers is read-read, and all writes are to worker-exclusive slots.
unsafe impl Send for ExecShared<'_> {}
unsafe impl Sync for ExecShared<'_> {}

/// Read word `w` of net `net`.
///
/// # Safety
/// `net < n_nets`, `w < words`, and no concurrent writer of this slot
/// (see [`ExecShared`]).
#[inline]
unsafe fn val(sh: &ExecShared, net: u32, w: usize) -> u64 {
    *sh.values.add(net as usize * sh.words + w)
}

/// Commit word `w` of net `net`, returning the number of toggled lanes.
/// Under fault injection (non-null force masks) the freshly evaluated
/// word is clamped to its stuck-at lanes before the toggle compare, so a
/// forced net never "recovers" mid-settle.
///
/// # Safety
/// As [`val`], plus: this worker is the only writer of `net` this level.
#[inline]
unsafe fn commit(sh: &ExecShared, net: u32, w: usize, mut v: u64) -> u32 {
    let idx = net as usize * sh.words + w;
    if !sh.force_sa0.is_null() {
        v = (v & !*sh.force_sa0.add(idx)) | *sh.force_sa1.add(idx);
    }
    let p = sh.values.add(idx);
    let diff = *p ^ v;
    if diff != 0 {
        *p = v;
    }
    diff.count_ones()
}

/// Execute one instruction across all `words` lane blocks.
///
/// # Safety
/// [`ExecShared`] invariants hold and `ins` belongs to the level
/// currently being executed.
unsafe fn exec_instr(sh: &ExecShared, ins: &Instr, min: &mut [u64], mout: &mut Vec<u64>) {
    let words = sh.words;
    let mut t = 0u32;
    match ins.op {
        Op::Buf => {
            for w in 0..words {
                t += commit(sh, ins.dst, w, val(sh, ins.a, w));
            }
        }
        Op::Not => {
            for w in 0..words {
                t += commit(sh, ins.dst, w, !val(sh, ins.a, w));
            }
        }
        Op::And => {
            for w in 0..words {
                t += commit(sh, ins.dst, w, val(sh, ins.a, w) & val(sh, ins.b, w));
            }
        }
        Op::Or => {
            for w in 0..words {
                t += commit(sh, ins.dst, w, val(sh, ins.a, w) | val(sh, ins.b, w));
            }
        }
        Op::Xor => {
            for w in 0..words {
                t += commit(sh, ins.dst, w, val(sh, ins.a, w) ^ val(sh, ins.b, w));
            }
        }
        Op::Mux => {
            for w in 0..words {
                let s = val(sh, ins.a, w);
                let v = (val(sh, ins.c, w) & s) | (val(sh, ins.b, w) & !s);
                t += commit(sh, ins.dst, w, v);
            }
        }
        Op::Macro => {
            let g = &sh.prog.groups[ins.a as usize];
            let mi = &sh.prog.minsts[g.inst as usize];
            // Dep-union gather only: non-dep positions (NO_NET) read as 0
            // instead of touching a possibly same-level net — committed
            // pins are input-independent of them by the pin_deps contract.
            let srcs = &sh.prog.group_gather[g.in_start as usize..g.in_end as usize];
            let pins = &sh.prog.group_pins[g.pin_start as usize..g.pin_end as usize];
            for w in 0..words {
                for (k, &src) in srcs.iter().enumerate() {
                    min[k] = if src == NO_NET { 0 } else { val(sh, src, w) };
                }
                let st = &*sh.states.add(g.inst as usize * words + w);
                macros9::eval_word(mi.kind, &min[..srcs.len()], st, mout);
                for &(pin, dst) in pins {
                    let d = commit(sh, dst, w, mout[pin as usize]);
                    if d != 0 {
                        *sh.toggles.add(dst as usize) += d as u64;
                    }
                }
            }
            return;
        }
    }
    if t != 0 {
        *sh.toggles.add(ins.dst as usize) += t as u64;
    }
}

/// One settle worker: execute this worker's chunk of every level, with a
/// barrier between levels.
fn settle_worker(sh: &ExecShared, wid: usize, workers: usize, barrier: &Barrier) {
    let mut min = vec![0u64; sh.prog.max_macro_inputs];
    let mut mout: Vec<u64> = Vec::new();
    let mut start = 0usize;
    for &end in &sh.prog.level_ends {
        let end = end as usize;
        let (lo, hi) = chunk(end - start, wid, workers);
        for ins in &sh.prog.instrs[start + lo..start + hi] {
            // SAFETY: the ExecShared invariants hold — unique dst per
            // level, reads only from earlier levels, chunk slices are
            // disjoint across workers.
            unsafe { exec_instr(sh, ins, &mut min, &mut mout) };
        }
        barrier.wait();
        start = end;
    }
}

/// Executor for a [`CompiledProgram`]: `words × 64` lanes per pass,
/// per-level sharding across `threads` workers, per-net toggle counters.
///
/// The cycle protocol is the interpreters': set primary input words,
/// [`CompiledSim::settle`], observe outputs, [`CompiledSim::clock`].
pub struct CompiledSim {
    prog: CompiledProgram,
    words: usize,
    threads: usize,
    /// Word `w` of net `n` lives at `values[n * words + w]`.
    values: Vec<u64>,
    toggles: Vec<u64>,
    /// Word `w` of instance `i` lives at `macro_states[i * words + w]`.
    macro_states: Vec<WordMacroState>,
    passes: u64,
    /// Stuck-at lane masks, indexed like `values` (`net * words + w`);
    /// empty when fault-free — the executor then passes null pointers and
    /// `commit` pays one branch. `forced_nets` lists nets with any forced
    /// lane so the settle-entry clamp (covering Input/Dff/Const/Moore nets
    /// that are not in the instruction stream) doesn't scan every net.
    force_sa0: Vec<u64>,
    force_sa1: Vec<u64>,
    forced_nets: Vec<NetId>,
    // clock-phase scratch (driver thread only)
    dff_next: Vec<u64>,
    macro_in: Vec<u64>,
    macro_out: Vec<u64>,
}

impl CompiledSim {
    /// Compile `nl` and build an executor with a `words`-word lane block
    /// per net, sharding settles across `threads` workers (0 = machine
    /// parallelism, 1 = inline). Errors on combinational cycles or a
    /// `words` outside `1..=64`.
    pub fn new(nl: &Netlist, words: usize, threads: usize) -> Result<CompiledSim, String> {
        if !(1..=64).contains(&words) {
            return Err(format!("lane-block width {words} outside 1..=64"));
        }
        Ok(Self::from_program(CompiledProgram::compile(nl)?, words, threads))
    }

    /// Build an executor over an already-compiled program. Panics on a
    /// `words` outside `1..=64` (the fallible path is
    /// [`CompiledSim::new`]).
    pub fn from_program(prog: CompiledProgram, words: usize, threads: usize) -> CompiledSim {
        assert!(
            (1..=64).contains(&words),
            "lane-block width {words} outside 1..=64"
        );
        let threads = if threads == 0 {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        } else {
            threads
        };
        let n = prog.net_count();
        let mut values = vec![0u64; n * words];
        for &c in &prog.const_ones {
            values[c as usize * words..(c as usize + 1) * words].fill(!0);
        }
        for d in &prog.dffs {
            if d.init {
                let i = d.net as usize;
                values[i * words..(i + 1) * words].fill(!0);
            }
        }
        let macro_states = vec![WordMacroState::default(); prog.minsts.len() * words];
        CompiledSim {
            toggles: vec![0; n],
            values,
            macro_states,
            words,
            threads,
            passes: 0,
            force_sa0: Vec::new(),
            force_sa1: Vec::new(),
            forced_nets: Vec::new(),
            dff_next: Vec::new(),
            macro_in: Vec::new(),
            macro_out: Vec::new(),
            prog,
        }
    }

    /// The compiled program this executor runs.
    pub fn program(&self) -> &CompiledProgram {
        &self.prog
    }

    /// Lane-block width `W` (u64 words per net; `W × 64` lanes per pass).
    pub fn words(&self) -> usize {
        self.words
    }

    /// Worker threads a settle shards its levels across.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Set word `w` of a primary input net.
    pub fn set_input_net(&mut self, id: NetId, w: usize, word: u64) {
        debug_assert!(self.prog.is_input[id as usize], "net {id} is not an input");
        debug_assert!(w < self.words);
        self.values[id as usize * self.words + w] = word;
    }

    /// Current word `w` of any net.
    pub fn get_word(&self, id: NetId, w: usize) -> u64 {
        self.values[id as usize * self.words + w]
    }

    /// Current value of net `id` in one of the `words × 64` lanes.
    pub fn get_lane(&self, id: NetId, lane: usize) -> bool {
        debug_assert!(lane < self.words * LANES);
        self.get_word(id, lane / LANES) >> (lane % LANES) & 1 == 1
    }

    /// Resolve primary-input names to net ids in one pass (steady-state
    /// stimulus then uses [`CompiledSim::set_input_net`] — the compiled
    /// engine has no per-call name lookups at all). Errors on unknown
    /// names.
    pub fn bind_inputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        super::netlist::bind_ports(&self.prog.inputs, names, "input")
    }

    /// Resolve primary-output names to net ids in one pass. Errors on
    /// unknown names.
    pub fn bind_outputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        super::netlist::bind_ports(&self.prog.outputs, names, "output")
    }

    /// Combinational settle (one pass over all levels), sharded across
    /// the configured worker threads. Counts toggles per lane against the
    /// previous settled words.
    pub fn settle(&mut self) {
        // Re-clamp forced nets first (driver thread, before workers spawn):
        // Input/Dff/Const/Moore-pin nets are not in the instruction stream,
        // so a clock-phase write or caller stimulus would otherwise undo
        // the force.
        for &id in &self.forced_nets {
            for w in 0..self.words {
                let idx = id as usize * self.words + w;
                self.values[idx] =
                    (self.values[idx] & !self.force_sa0[idx]) | self.force_sa1[idx];
            }
        }
        let workers = self.threads.max(1);
        let shared = ExecShared {
            prog: &self.prog,
            values: self.values.as_mut_ptr(),
            toggles: self.toggles.as_mut_ptr(),
            states: self.macro_states.as_ptr(),
            force_sa0: if self.force_sa0.is_empty() {
                std::ptr::null()
            } else {
                self.force_sa0.as_ptr()
            },
            force_sa1: if self.force_sa1.is_empty() {
                std::ptr::null()
            } else {
                self.force_sa1.as_ptr()
            },
            words: self.words,
        };
        if workers == 1 {
            let barrier = Barrier::new(1);
            settle_worker(&shared, 0, 1, &barrier);
        } else {
            let barrier = Barrier::new(workers);
            std::thread::scope(|s| {
                for wid in 1..workers {
                    let sh = &shared;
                    let b = &barrier;
                    s.spawn(move || settle_worker(sh, wid, workers, b));
                }
                settle_worker(&shared, 0, workers, &barrier);
            });
        }
    }

    /// Clock edge: capture DFFs word-wide, advance macro state, refresh
    /// Moore macro pins — the interpreters' exact phase ordering. Runs on
    /// the driver thread (settle is where the work is).
    pub fn clock(&mut self) {
        self.passes += 1;
        let words = self.words;
        // Capture all DFF next-words first (reads only).
        self.dff_next.clear();
        self.dff_next.resize(self.prog.dffs.len() * words, 0);
        for (k, d) in self.prog.dffs.iter().enumerate() {
            let init_word = if d.init { !0u64 } else { 0 };
            for w in 0..words {
                let r = if d.rst == NO_RST {
                    0
                } else {
                    self.values[d.rst as usize * words + w]
                };
                let dv = self.values[d.d as usize * words + w];
                self.dff_next[k * words + w] = (dv & !r) | (init_word & r);
            }
        }
        // Advance macro behavioral state (reads pre-capture values).
        for (i, mi) in self.prog.minsts.iter().enumerate() {
            let srcs = &self.prog.minputs[mi.in_start as usize..mi.in_end as usize];
            for w in 0..words {
                self.macro_in.clear();
                for &src in srcs {
                    self.macro_in.push(self.values[src as usize * words + w]);
                }
                macros9::step_word(mi.kind, &self.macro_in, &mut self.macro_states[i * words + w]);
            }
        }
        // Commit DFFs, counting toggles.
        for (k, d) in self.prog.dffs.iter().enumerate() {
            let i = d.net as usize;
            for w in 0..words {
                let v = self.dff_next[k * words + w];
                let diff = self.values[i * words + w] ^ v;
                if diff != 0 {
                    self.toggles[i] += diff.count_ones() as u64;
                    self.values[i * words + w] = v;
                }
            }
        }
        // Refresh Moore macro pins from the new state. (Moore outputs are
        // input-independent by the `pin_deps` contract, so gathering
        // post-capture inputs matches the interpreters.)
        for (i, mi) in self.prog.minsts.iter().enumerate() {
            if mi.moore_start == mi.moore_end {
                continue;
            }
            let srcs = &self.prog.minputs[mi.in_start as usize..mi.in_end as usize];
            let pins = &self.prog.moore_pins[mi.moore_start as usize..mi.moore_end as usize];
            for w in 0..words {
                self.macro_in.clear();
                for &src in srcs {
                    self.macro_in.push(self.values[src as usize * words + w]);
                }
                macros9::eval_word(
                    mi.kind,
                    &self.macro_in,
                    &self.macro_states[i * words + w],
                    &mut self.macro_out,
                );
                for &(pin, net) in pins {
                    let v = self.macro_out[pin as usize];
                    let n = net as usize;
                    let diff = self.values[n * words + w] ^ v;
                    if diff != 0 {
                        self.toggles[n] += diff.count_ones() as u64;
                        self.values[n * words + w] = v;
                    }
                }
            }
        }
    }

    /// One full pass: settle, then clock. Inputs must be set beforehand.
    pub fn cycle(&mut self) {
        self.settle();
        self.clock();
    }

    /// Word passes executed so far (each is one cycle in all lanes).
    pub fn passes(&self) -> u64 {
        self.passes
    }

    /// Total simulated lane-cycles (`passes × words × 64`) — the
    /// denominator for activity, comparable with the interpreters.
    pub fn lane_cycles(&self) -> u64 {
        self.passes * (self.words * LANES) as u64
    }

    /// Per-net toggle counts, accumulated across all lanes and passes.
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Average toggle rate (toggles per net per lane-cycle) — the α
    /// activity factor of the dynamic power model.
    pub fn activity(&self) -> f64 {
        super::mean_activity(&self.toggles, self.lane_cycles())
    }

    /// Read word `w` of a macro instance's behavioral state.
    pub fn macro_state(&self, inst: usize, w: usize) -> &WordMacroState {
        &self.macro_states[inst * self.words + w]
    }

    /// Broadcast a scalar macro state into every lane of every word of an
    /// instance (e.g. to preload synaptic weights before a sweep).
    pub fn set_macro_state_broadcast(&mut self, inst: usize, st: &MacroState) {
        let wide = WordMacroState::broadcast(st);
        for w in 0..self.words {
            self.macro_states[inst * self.words + w] = wide.clone();
        }
    }

    /// Force the `sa0` lanes of word `w` of net `id` stuck at 0 and the
    /// `sa1` lanes stuck at 1, until [`CompiledSim::clear_faults`]. Forces
    /// accumulate across calls, are applied immediately, re-applied at
    /// every settle entry, and clamp freshly evaluated words inside the
    /// settle, so they hold across [`CompiledSim::clock`] and
    /// [`CompiledSim::reset_state`]. A lane in both masks resolves to
    /// stuck-at-1.
    pub fn force_net_word(&mut self, id: NetId, w: usize, sa0: u64, sa1: u64) {
        debug_assert!(w < self.words);
        if self.force_sa0.is_empty() {
            self.force_sa0 = vec![0; self.prog.n_nets * self.words];
            self.force_sa1 = vec![0; self.prog.n_nets * self.words];
        }
        let base = id as usize * self.words;
        if (0..self.words).all(|k| self.force_sa0[base + k] | self.force_sa1[base + k] == 0) {
            self.forced_nets.push(id);
        }
        let idx = base + w;
        self.force_sa0[idx] |= sa0;
        self.force_sa1[idx] |= sa1;
        self.values[idx] = (self.values[idx] & !self.force_sa0[idx]) | self.force_sa1[idx];
    }

    /// One-shot single-event upset: invert the `mask` lanes of word `w` of
    /// net `id`. Call between clock and the next settle; the flip persists
    /// on state nets (DFF outputs) and is swallowed by the next settle on
    /// combinational nets.
    pub fn flip_net_word(&mut self, id: NetId, w: usize, mask: u64) {
        debug_assert!(w < self.words);
        self.values[id as usize * self.words + w] ^= mask;
    }

    /// One-shot single-event upset in macro behavioral state: invert state
    /// bit `bit` of instance `inst` in the `mask` lanes of word `w` (see
    /// [`MacroKind::state_bits`]).
    ///
    /// [`MacroKind::state_bits`]: super::macros9::MacroKind::state_bits
    pub fn flip_macro_bit_word(&mut self, inst: usize, w: usize, bit: usize, mask: u64) {
        debug_assert!(w < self.words);
        let st = &mut self.macro_states[inst * self.words + w];
        let plane = st.plane(bit);
        st.set_plane(bit, plane ^ mask);
    }

    /// Remove all stuck-at forces (flips are one-shot and need no undo).
    pub fn clear_faults(&mut self) {
        self.force_sa0.clear();
        self.force_sa1.clear();
        self.forced_nets.clear();
    }

    /// Reset all state (DFFs to init, macro states cleared, toggles and
    /// pass counters kept) — the interpreters' `reset_state` semantics.
    pub fn reset_state(&mut self) {
        let words = self.words;
        for d in &self.prog.dffs {
            let i = d.net as usize;
            let v = if d.init { !0u64 } else { 0 };
            self.values[i * words..(i + 1) * words].fill(v);
        }
        for st in &mut self.macro_states {
            *st = WordMacroState::default();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::column_design::{build_column, BrvSource};
    use super::super::macros9::MacroKind;
    use super::super::netlist::NetBuilder;
    use super::super::wordsim::WordSimulator;
    use super::*;
    use crate::util::Rng64;

    #[test]
    fn chunks_cover_and_partition() {
        for len in [0usize, 1, 5, 16, 17, 1000] {
            for workers in [1usize, 2, 3, 4, 7] {
                let mut covered = 0;
                let mut prev_end = 0;
                for wid in 0..workers {
                    let (lo, hi) = chunk(len, wid, workers);
                    assert_eq!(lo, prev_end, "chunks are contiguous");
                    assert!(hi >= lo);
                    covered += hi - lo;
                    prev_end = hi;
                }
                assert_eq!(covered, len, "chunks cover exactly once");
                assert_eq!(prev_end, len);
            }
        }
    }

    #[test]
    fn comb_logic_settles_per_word_and_lane() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.xor(a, c);
        b.output("x", x);
        let nl = b.finish();
        let mut sim = CompiledSim::new(&nl, 2, 1).unwrap();
        sim.set_input_net(a, 0, 0b0110);
        sim.set_input_net(c, 0, 0b1100);
        sim.set_input_net(a, 1, !0);
        sim.set_input_net(c, 1, 0);
        sim.settle();
        assert_eq!(sim.get_word(x, 0) & 0b1111, 0b1010);
        assert_eq!(sim.get_word(x, 1), !0);
        assert!(!sim.get_lane(x, 0));
        assert!(sim.get_lane(x, 1));
        assert!(sim.get_lane(x, 64), "lane 64 = bit 0 of word 1");
        assert_eq!(sim.program().level_count(), 1);
        assert_eq!(sim.program().instr_count(), 1);
    }

    #[test]
    fn dff_captures_word_wide_and_counts_lane_toggles() {
        let mut b = NetBuilder::new("t");
        let d = b.input("d");
        let r = b.input("r");
        let q = b.dff(d, Some(r), false);
        b.output("q", q);
        let nl = b.finish();
        let mut sim = CompiledSim::new(&nl, 2, 1).unwrap();
        sim.set_input_net(d, 0, 0xFF);
        sim.set_input_net(r, 0, 0x0F);
        sim.set_input_net(d, 1, 0b11);
        sim.set_input_net(r, 1, 0);
        sim.cycle();
        assert_eq!(sim.get_word(q, 0), 0xF0);
        assert_eq!(sim.get_word(q, 1), 0b11);
        assert_eq!(sim.toggles()[q as usize], 4 + 2);
        assert_eq!(sim.passes(), 1);
        assert_eq!(sim.lane_cycles(), 128);
    }

    #[test]
    fn macro_groups_evaluate_once_per_level() {
        // stdp_case_gen has four Mealy pins in one level: the program must
        // hold exactly one macro group (not four pin evaluations).
        let mut b = NetBuilder::new("t");
        let g = b.input("g");
        let ein = b.input("ein");
        let eout = b.input("eout");
        let outs = b.macro_inst(MacroKind::StdpCaseGen, vec![g, ein, eout]);
        for (k, &o) in outs.iter().enumerate() {
            b.output(&format!("c{k}"), o);
        }
        let nl = b.finish();
        let prog = CompiledProgram::compile(&nl).unwrap();
        assert_eq!(prog.macro_group_count(), 1);
        assert_eq!(prog.instr_count(), 1);
        let mut sim = CompiledSim::from_program(prog, 1, 1);
        sim.set_input_net(g, 0, 0);
        sim.set_input_net(ein, 0, !0);
        sim.set_input_net(eout, 0, !0);
        sim.settle();
        assert_eq!(sim.get_word(outs[0], 0), !0, "case0 = ein & eout & !greater");
        assert_eq!(sim.get_word(outs[1], 0), 0);
    }

    #[test]
    fn words_match_independent_wordsim_runs_on_a_column() {
        // Word w of the compiled engine must be bit-for-bit an independent
        // WordSimulator run under the same stimulus, including toggles.
        let d = build_column(5, 2, 6, BrvSource::Lfsr);
        let nl = &d.netlist;
        let words = 2usize;
        let mut csim = CompiledSim::new(nl, words, 1).unwrap();
        let mut wsims: Vec<WordSimulator> =
            (0..words).map(|_| WordSimulator::new(nl).unwrap()).collect();
        let inputs: Vec<NetId> = nl.inputs.iter().map(|(_, id)| *id).collect();
        let mut rng = Rng64::seed_from_u64(0xC0DE);
        for _ in 0..24 {
            for &id in &inputs {
                for (w, ws) in wsims.iter_mut().enumerate() {
                    let word = rng.next_u64() & rng.next_u64() & rng.next_u64();
                    csim.set_input_net(id, w, word);
                    ws.set_input_net(id, word);
                }
            }
            csim.cycle();
            for ws in &mut wsims {
                ws.cycle();
            }
            for net in 0..nl.len() as NetId {
                for (w, ws) in wsims.iter().enumerate() {
                    assert_eq!(csim.get_word(net, w), ws.get(net), "net {net} word {w}");
                }
            }
        }
        let mut want = vec![0u64; nl.len()];
        for ws in &wsims {
            for (t, &x) in want.iter_mut().zip(ws.toggles()) {
                *t += x;
            }
        }
        assert_eq!(csim.toggles(), want.as_slice(), "toggles = sum of word runs");
        assert!(csim.activity() > 0.0);
    }

    #[test]
    fn sharded_settle_is_bit_exact_at_any_worker_count() {
        let d = build_column(6, 3, 8, BrvSource::Lfsr);
        let nl = &d.netlist;
        let run = |threads: usize| -> (Vec<u64>, Vec<u64>) {
            let mut sim = CompiledSim::new(nl, 2, threads).unwrap();
            let inputs: Vec<NetId> = nl.inputs.iter().map(|(_, id)| *id).collect();
            let mut rng = Rng64::seed_from_u64(99);
            for _ in 0..16 {
                for &id in &inputs {
                    for w in 0..2 {
                        sim.set_input_net(id, w, rng.next_u64() & rng.next_u64());
                    }
                }
                sim.cycle();
            }
            (sim.toggles().to_vec(), sim.values.clone())
        };
        let (t1, v1) = run(1);
        for threads in [2, 4] {
            let (t, v) = run(threads);
            assert_eq!(t, t1, "{threads}-worker toggles differ");
            assert_eq!(v, v1, "{threads}-worker values differ");
        }
    }

    #[test]
    fn bind_ports_resolve_in_bulk_and_reject_unknowns() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.and(a, c);
        b.output("x", x);
        let sim = CompiledSim::new(&b.finish(), 1, 1).unwrap();
        assert_eq!(sim.bind_inputs(&["b", "a"]).unwrap(), vec![c, a]);
        assert_eq!(sim.bind_outputs(&["x"]).unwrap(), vec![x]);
        assert!(sim.bind_inputs(&["nope"]).is_err());
        assert!(sim.bind_outputs(&["a"]).is_err());
    }

    #[test]
    fn reset_state_restores_dff_init_and_macro_state() {
        let d = build_column(4, 2, 5, BrvSource::Lfsr);
        let nl = &d.netlist;
        let mut sim = CompiledSim::new(nl, 2, 1).unwrap();
        let inputs: Vec<NetId> = nl.inputs.iter().map(|(_, id)| *id).collect();
        let mut rng = Rng64::seed_from_u64(5);
        for _ in 0..8 {
            for &id in &inputs {
                for w in 0..2 {
                    sim.set_input_net(id, w, rng.next_u64());
                }
            }
            sim.cycle();
        }
        let toggles_before = sim.toggles().to_vec();
        sim.reset_state();
        assert_eq!(sim.toggles(), toggles_before.as_slice(), "toggles kept");
        for d in &sim.prog.dffs {
            let want = if d.init { !0u64 } else { 0 };
            for w in 0..2 {
                assert_eq!(sim.get_word(d.net, w), want);
            }
        }
        for st in &sim.macro_states {
            for k in 0..super::super::macros9::MAX_STATE_BITS {
                assert_eq!(st.plane(k), 0);
            }
        }
    }
}
