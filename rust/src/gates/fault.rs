//! Seeded deterministic fault-injection campaigns over the gate backends.
//!
//! TNN7 positions the nine macros as silicon for always-on edge sensing,
//! where stuck-at defects and soft errors in synaptic-weight DFFs are the
//! dominant reliability concern. This module makes the "unary temporal
//! codes degrade gracefully" claim measurable: it injects faults into the
//! column netlist and classifies each one against a fault-free reference.
//!
//! Three fault models ([`GateFault`]):
//!
//! * **stuck-at-0/1** on any net — a permanent defect, clamped at every
//!   settle (the engines re-apply the clamp on entry so even nets outside
//!   the combinational schedule — DFF outputs, primary inputs — hold);
//! * **SEU on a net** — a one-shot bit flip applied immediately before the
//!   settle of a chosen global unit cycle (state nets latch it,
//!   combinational nets shed it at that same settle);
//! * **SEU in macro state** — a one-shot flip of one internal state bit of
//!   a macro instance (e.g. a `syn_weight_update` weight DFF).
//!
//! The campaign runner exploits the lane machinery: the bit-parallel
//! interpreter simulates 63 distinct faults per pass and the compiled
//! engine `words × 64 − 1`, with **lane 0 always the fault-free
//! reference** — every lane receives the identical broadcast stimulus, so
//! masked/propagated/latent classification falls out of a lane-vs-lane-0
//! XOR. Gates and macros evaluate lane-wise, so a lane's trajectory never
//! depends on which pass it shares with other faults: the scalar backend,
//! the interpreter, and the compiled engine at any `words`/`threads`
//! produce bit-identical [`FaultOutcome`]s (pinned by `tests/faults.rs`).
//!
//! Fault-site sampling follows the crate's frozen determinism discipline:
//! fault `f` draws from `Rng64::seed_from_u64(seed).split_stream(f)`, so a
//! campaign is reproducible from its printed seed alone, independent of
//! backend, worker count and lane-block width.

use super::column_design::ColumnDesign;
use super::compile::CompiledSim;
use super::macros9::MacroState;
use super::netlist::{Gate, NetId, Netlist};
use super::opt::NetRemap;
use super::sim::Simulator;
use super::wordsim::{WordSimulator, LANES};
use super::SimBackend;
use crate::tnn::spike::{earliest_spike, SpikeTime};
use crate::util::Rng64;
use std::collections::BTreeMap;

/// A single hardware fault to inject into a campaign run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateFault {
    /// Permanent stuck-at defect: `net` reads `value` on every cycle.
    StuckAt {
        /// The defective net.
        net: NetId,
        /// The value the net is stuck at.
        value: bool,
    },
    /// Single-event upset on a net: inverted immediately before the settle
    /// of global unit cycle `cycle` (`item * gamma + t`).
    SeuNet {
        /// The upset net.
        net: NetId,
        /// Global unit cycle of the strike.
        cycle: u64,
    },
    /// Single-event upset in one bit of a macro instance's internal state,
    /// applied immediately before the settle of `cycle`.
    SeuMacroBit {
        /// Macro instance index into `Netlist::macros`.
        inst: usize,
        /// State bit index (`< MacroKind::state_bits()`).
        bit: u8,
        /// Global unit cycle of the strike.
        cycle: u64,
    },
}

impl GateFault {
    /// Translate this fault's site through a netlist-optimizer
    /// [`NetRemap`]: the same fault expressed in the optimized netlist's
    /// ids, or `None` when the site (net or macro instance) was optimized
    /// away — a fault on removed logic is masked by construction, since
    /// removed logic is unreachable from every retained net.
    pub fn remap(&self, remap: &NetRemap) -> Option<GateFault> {
        match *self {
            GateFault::StuckAt { net, value } => remap
                .net(net)
                .map(|net| GateFault::StuckAt { net, value }),
            GateFault::SeuNet { net, cycle } => {
                remap.net(net).map(|net| GateFault::SeuNet { net, cycle })
            }
            GateFault::SeuMacroBit { inst, bit, cycle } => {
                remap.macro_inst(inst as u32).map(|inst| GateFault::SeuMacroBit {
                    inst: inst as usize,
                    bit,
                    cycle,
                })
            }
        }
    }
}

/// How a fault manifested relative to the fault-free reference lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultClass {
    /// No observable difference: outputs and end-of-item state both match.
    Masked,
    /// Internal state diverged (DFF or macro state at some item boundary)
    /// but the post-WTA output stream never did.
    Latent,
    /// The post-WTA output stream differed on at least one cycle.
    Propagated,
}

impl FaultClass {
    /// Display name (`masked` / `latent` / `propagated`).
    pub fn name(&self) -> &'static str {
        match self {
            FaultClass::Masked => "masked",
            FaultClass::Latent => "latent",
            FaultClass::Propagated => "propagated",
        }
    }
}

/// Per-fault campaign verdict.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultOutcome {
    /// The injected fault.
    pub fault: GateFault,
    /// Site label: the macro cell name driving the faulted net (or
    /// `"dff"` / `"input"` / `"const"` / `"logic"` for glue).
    pub site: String,
    /// Masked / latent / propagated classification.
    pub class: FaultClass,
    /// Number of gamma items whose post-WTA winner differed from the
    /// fault-free reference.
    pub winner_mismatches: usize,
}

/// Masked/latent/propagated tallies.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Faults with no observable effect.
    pub masked: usize,
    /// Faults that corrupted state without reaching an output.
    pub latent: usize,
    /// Faults visible in the output stream.
    pub propagated: usize,
}

impl FaultCounts {
    /// Total classified faults.
    pub fn total(&self) -> usize {
        self.masked + self.latent + self.propagated
    }

    fn add(&mut self, class: FaultClass) {
        match class {
            FaultClass::Masked => self.masked += 1,
            FaultClass::Latent => self.latent += 1,
            FaultClass::Propagated => self.propagated += 1,
        }
    }
}

/// Result of a fault campaign: one outcome per injected fault (in input
/// order) plus the fault-free reference winners (bit-identical to baseline
/// batched inference on every backend).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CampaignResult {
    /// Per-fault verdicts, in the order the faults were supplied.
    pub outcomes: Vec<FaultOutcome>,
    /// Fault-free post-WTA winner per gamma item (the lane-0 reference).
    pub ref_winners: Vec<Option<usize>>,
}

impl CampaignResult {
    /// Overall masked/latent/propagated tallies.
    pub fn counts(&self) -> FaultCounts {
        let mut c = FaultCounts::default();
        for o in &self.outcomes {
            c.add(o.class);
        }
        c
    }

    /// Tallies grouped by fault-site label (macro cell name or glue kind).
    pub fn counts_by_site(&self) -> BTreeMap<String, FaultCounts> {
        let mut m: BTreeMap<String, FaultCounts> = BTreeMap::new();
        for o in &self.outcomes {
            m.entry(o.site.clone()).or_default().add(o.class);
        }
        m
    }
}

/// The state the latent-fault comparison inspects at every item boundary:
/// all DFF output nets (gate-index order) plus every sequential macro
/// instance's internal state bits (instance order).
struct StateSites {
    nets: Vec<NetId>,
    macros: Vec<(usize, usize)>, // (instance, state_bits)
}

fn state_sites(nl: &Netlist) -> StateSites {
    let nets = nl
        .gates
        .iter()
        .enumerate()
        .filter(|(_, g)| matches!(g, Gate::Dff { .. }))
        .map(|(i, _)| i as NetId)
        .collect();
    let macros = nl
        .macros
        .iter()
        .enumerate()
        .filter(|(_, m)| m.kind.state_bits() > 0)
        .map(|(i, m)| (i, m.kind.state_bits()))
        .collect();
    StateSites { nets, macros }
}

/// Label a fault's site: the cell name of the macro driving the net, or a
/// glue-kind label for plain gates (`dff` / `input` / `const` / `logic`).
pub fn site_label(nl: &Netlist, fault: &GateFault) -> String {
    match *fault {
        GateFault::StuckAt { net, .. } | GateFault::SeuNet { net, .. } => {
            match &nl.gates[net as usize] {
                Gate::MacroOut { inst, .. } => {
                    nl.macros[*inst as usize].kind.cell_name().to_string()
                }
                Gate::Dff { .. } => "dff".to_string(),
                Gate::Input => "input".to_string(),
                Gate::Const(_) => "const".to_string(),
                _ => "logic".to_string(),
            }
        }
        GateFault::SeuMacroBit { inst, .. } => nl.macros[inst].kind.cell_name().to_string(),
    }
}

/// Sample a reproducible fault list: `stuck` stuck-at faults on uniformly
/// chosen nets followed by `seu` single-event upsets on uniformly chosen
/// state sites (DFF nets and macro state bits) at uniformly chosen global
/// cycles in `0..total_cycles`.
///
/// Determinism discipline: fault `f` draws **only** from
/// `Rng64::seed_from_u64(seed).split_stream(f)` — the sampled campaign is
/// a pure function of `(netlist, stuck, seu, total_cycles, seed)`,
/// independent of backend, thread count and lane-block width.
pub fn sample_faults(
    nl: &Netlist,
    stuck: usize,
    seu: usize,
    total_cycles: u64,
    seed: u64,
) -> Vec<GateFault> {
    let root = Rng64::seed_from_u64(seed);
    let n_nets = nl.gates.len();
    let sites = state_sites(nl);
    let mut seu_sites: Vec<GateFault> = Vec::new();
    for &net in &sites.nets {
        seu_sites.push(GateFault::SeuNet { net, cycle: 0 });
    }
    for &(inst, bits) in &sites.macros {
        for bit in 0..bits {
            seu_sites.push(GateFault::SeuMacroBit {
                inst,
                bit: bit as u8,
                cycle: 0,
            });
        }
    }
    assert!(
        seu == 0 || !seu_sites.is_empty(),
        "netlist has no state to upset"
    );
    assert!(seu == 0 || total_cycles > 0, "SEU campaign needs cycles");
    let mut faults = Vec::with_capacity(stuck + seu);
    for f in 0..stuck {
        let mut rng = root.split_stream(f as u64);
        let net = rng.gen_range(0, n_nets) as NetId;
        let value = rng.gen_bool(0.5);
        faults.push(GateFault::StuckAt { net, value });
    }
    for f in stuck..stuck + seu {
        let mut rng = root.split_stream(f as u64);
        let site = rng.gen_range(0, seu_sites.len());
        let cycle = rng.gen_range_u64(0, total_cycles - 1);
        faults.push(match seu_sites[site] {
            GateFault::SeuNet { net, .. } => GateFault::SeuNet { net, cycle },
            GateFault::SeuMacroBit { inst, bit, .. } => {
                GateFault::SeuMacroBit { inst, bit, cycle }
            }
            GateFault::StuckAt { .. } => unreachable!("site list holds SEUs only"),
        });
    }
    faults
}

fn validate_faults(
    nl: &Netlist,
    faults: &[GateFault],
    total_cycles: u64,
) -> Result<(), String> {
    let n = nl.gates.len();
    for (i, f) in faults.iter().enumerate() {
        match *f {
            GateFault::StuckAt { net, .. } => {
                if net as usize >= n {
                    return Err(format!("fault {i}: net {net} out of range ({n} nets)"));
                }
            }
            GateFault::SeuNet { net, cycle } => {
                if net as usize >= n {
                    return Err(format!("fault {i}: net {net} out of range ({n} nets)"));
                }
                if cycle >= total_cycles {
                    return Err(format!(
                        "fault {i}: SEU cycle {cycle} beyond campaign ({total_cycles} cycles)"
                    ));
                }
            }
            GateFault::SeuMacroBit { inst, bit, cycle } => {
                if inst >= nl.macros.len() {
                    return Err(format!(
                        "fault {i}: macro instance {inst} out of range ({} instances)",
                        nl.macros.len()
                    ));
                }
                let bits = nl.macros[inst].kind.state_bits();
                if bit as usize >= bits {
                    return Err(format!(
                        "fault {i}: state bit {bit} out of range ({} has {bits} bits)",
                        nl.macros[inst].kind.cell_name()
                    ));
                }
                if cycle >= total_cycles {
                    return Err(format!(
                        "fault {i}: SEU cycle {cycle} beyond campaign ({total_cycles} cycles)"
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Run a fault campaign over a column design: every fault is simulated
/// against the identical broadcast stimulus (`volleys`, one gamma item
/// each, `gamma` unit cycles per item) and classified against the
/// fault-free reference. `ws` preloads the synaptic weights (row-major
/// p×q) once at campaign start, so weight-state corruption persists across
/// items (that is the latency being measured).
///
/// The netlist must pass [`Netlist::verify`] — campaigns refuse to inject
/// into structurally broken designs. Outcomes are bit-identical across
/// every backend, thread count and lane-block width.
pub fn campaign(
    d: &ColumnDesign,
    ws: &[u8],
    gamma: u32,
    volleys: &[&[SpikeTime]],
    faults: &[GateFault],
    backend: SimBackend,
) -> Result<CampaignResult, String> {
    d.netlist.verify()?;
    if ws.len() != d.p * d.q {
        return Err(format!(
            "weights length {} != p*q = {}",
            ws.len(),
            d.p * d.q
        ));
    }
    if gamma == 0 {
        return Err("gamma must be >= 1".to_string());
    }
    for (k, v) in volleys.iter().enumerate() {
        if v.len() != d.p {
            return Err(format!("volley {k} length {} != p = {}", v.len(), d.p));
        }
    }
    validate_faults(&d.netlist, faults, volleys.len() as u64 * gamma as u64)?;
    let sites = state_sites(&d.netlist);
    match backend {
        SimBackend::Scalar => scalar_campaign(d, ws, gamma, volleys, faults, &sites),
        SimBackend::BitParallel64 => word_campaign(d, ws, gamma, volleys, faults, &sites),
        SimBackend::Compiled { words, threads } => {
            compiled_campaign(d, ws, gamma, volleys, faults, &sites, words.max(1), threads)
        }
    }
}

/// All-ones for the low `n` lanes (`n` in `1..=64`).
fn lane_mask(n: usize) -> u64 {
    if n >= 64 {
        !0
    } else {
        (1u64 << n) - 1
    }
}

/// Broadcast the LSB of `v` (the reference lane) to all 64 lanes.
fn splat_lsb(v: u64) -> u64 {
    0u64.wrapping_sub(v & 1)
}

fn winner_of(times: &[SpikeTime]) -> Option<usize> {
    let (idx, t) = earliest_spike(times);
    t.is_spike().then_some(idx)
}

fn classify(out_diff: bool, state_diff: bool) -> FaultClass {
    if out_diff {
        FaultClass::Propagated
    } else if state_diff {
        FaultClass::Latent
    } else {
        FaultClass::Masked
    }
}

/// One scalar run's full observable trace (the scalar backend's analogue
/// of the word engines' lane-0 reference).
struct ScalarTrace {
    /// `out[item * gamma * q + t * q + j]`: post-settle out_spike values.
    out: Vec<bool>,
    /// `state[item * nets + si]`: DFF nets at each item boundary.
    state: Vec<bool>,
    /// `macro_bits[item * seq_macros + mi]`: macro state at each boundary.
    macro_bits: Vec<u32>,
    /// Post-WTA winner per item.
    winners: Vec<Option<usize>>,
}

fn scalar_pass(
    sim: &mut Simulator<'_>,
    d: &ColumnDesign,
    ws: &[u8],
    gamma: u32,
    volleys: &[&[SpikeTime]],
    sites: &StateSites,
    fault: Option<&GateFault>,
) -> ScalarTrace {
    let q = d.q;
    sim.clear_faults();
    sim.reset_state();
    for (k, &inst) in d.syn_inst.iter().enumerate() {
        let mut st = MacroState::default();
        st.set_weight(ws[k]);
        sim.set_macro_state(inst as usize, st);
    }
    for case in &d.brv_case {
        for &net in case {
            sim.set_input_net(net, false);
        }
    }
    for stab in &d.brv_stab {
        for &net in stab {
            sim.set_input_net(net, false);
        }
    }
    if let Some(&GateFault::StuckAt { net, value }) = fault {
        sim.force_net(net, value);
    }
    let g = gamma;
    let mut trace = ScalarTrace {
        out: Vec::with_capacity(volleys.len() * g as usize * q),
        state: Vec::with_capacity(volleys.len() * sites.nets.len()),
        macro_bits: Vec::with_capacity(volleys.len() * sites.macros.len()),
        winners: Vec::with_capacity(volleys.len()),
    };
    let mut times = vec![SpikeTime::NONE; q];
    for (item, volley) in volleys.iter().enumerate() {
        times.fill(SpikeTime::NONE);
        for t in 0..g {
            let c = item as u64 * g as u64 + t as u64;
            for (i, &net) in d.in_pulse.iter().enumerate() {
                let x = volley[i];
                sim.set_input_net(net, x.is_spike() && x.0 == t);
            }
            sim.set_input_net(d.grst, t == g - 1);
            match fault {
                Some(&GateFault::SeuNet { net, cycle }) if cycle == c => sim.flip_net(net),
                Some(&GateFault::SeuMacroBit { inst, bit, cycle }) if cycle == c => {
                    sim.flip_macro_bit(inst, bit)
                }
                _ => {}
            }
            sim.settle();
            for (j, &net) in d.out_spike.iter().enumerate() {
                let v = sim.get(net);
                trace.out.push(v);
                if v && !times[j].is_spike() {
                    times[j] = SpikeTime::at(t);
                }
            }
            sim.clock();
        }
        for &net in &sites.nets {
            trace.state.push(sim.get(net));
        }
        for &(inst, _) in &sites.macros {
            trace.macro_bits.push(sim.macro_state(inst).bits());
        }
        trace.winners.push(winner_of(&times));
    }
    trace
}

fn scalar_campaign(
    d: &ColumnDesign,
    ws: &[u8],
    gamma: u32,
    volleys: &[&[SpikeTime]],
    faults: &[GateFault],
    sites: &StateSites,
) -> Result<CampaignResult, String> {
    let mut sim = Simulator::new(&d.netlist)?;
    let reference = scalar_pass(&mut sim, d, ws, gamma, volleys, sites, None);
    let mut outcomes = Vec::with_capacity(faults.len());
    for f in faults {
        let run = scalar_pass(&mut sim, d, ws, gamma, volleys, sites, Some(f));
        let out_diff = run.out != reference.out;
        let state_diff =
            run.state != reference.state || run.macro_bits != reference.macro_bits;
        let winner_mismatches = run
            .winners
            .iter()
            .zip(&reference.winners)
            .filter(|(a, b)| a != b)
            .count();
        outcomes.push(FaultOutcome {
            fault: *f,
            site: site_label(&d.netlist, f),
            class: classify(out_diff, state_diff),
            winner_mismatches,
        });
    }
    Ok(CampaignResult {
        outcomes,
        ref_winners: reference.winners,
    })
}

/// The 64-lane interpreter campaign: lane 0 fault-free, lanes 1..=63 carry
/// one fault each, all lanes fed the identical broadcast stimulus.
///
/// NOTE: this and [`compiled_campaign`] implement the SAME campaign
/// protocol (weight broadcast, BRV silencing, per-cycle SEU strikes,
/// lane-vs-lane-0 diffing) on two engines — any protocol change must land
/// in both, plus [`scalar_pass`]; `tests/faults.rs` pins the equality.
fn word_campaign(
    d: &ColumnDesign,
    ws: &[u8],
    gamma: u32,
    volleys: &[&[SpikeTime]],
    faults: &[GateFault],
    sites: &StateSites,
) -> Result<CampaignResult, String> {
    let q = d.q;
    let g = gamma;
    let mut wsim = WordSimulator::new(&d.netlist)?;
    let mut outcomes = Vec::with_capacity(faults.len());
    let mut ref_winners: Vec<Option<usize>> = Vec::new();
    let chunks: Vec<&[GateFault]> = if faults.is_empty() {
        vec![faults]
    } else {
        faults.chunks(LANES - 1).collect()
    };
    for (ci, chunk) in chunks.iter().enumerate() {
        wsim.clear_faults();
        wsim.reset_state();
        for (k, &inst) in d.syn_inst.iter().enumerate() {
            let mut st = MacroState::default();
            st.set_weight(ws[k]);
            wsim.set_macro_state_broadcast(inst as usize, &st);
        }
        for case in &d.brv_case {
            for &net in case {
                wsim.set_input_net(net, 0);
            }
        }
        for stab in &d.brv_stab {
            for &net in stab {
                wsim.set_input_net(net, 0);
            }
        }
        for (k, f) in chunk.iter().enumerate() {
            if let GateFault::StuckAt { net, value } = *f {
                let mask = 1u64 << (k + 1);
                if value {
                    wsim.force_net_lanes(net, 0, mask);
                } else {
                    wsim.force_net_lanes(net, mask, 0);
                }
            }
        }
        let used = lane_mask(chunk.len() + 1);
        let mut out_diff = 0u64;
        let mut state_diff = 0u64;
        let mut mism = vec![0usize; chunk.len()];
        let mut times = vec![SpikeTime::NONE; LANES * q];
        let mut seen = vec![0u64; q];
        for (item, volley) in volleys.iter().enumerate() {
            times.fill(SpikeTime::NONE);
            seen.fill(0);
            for t in 0..g {
                let c = item as u64 * g as u64 + t as u64;
                for (i, &net) in d.in_pulse.iter().enumerate() {
                    let x = volley[i];
                    wsim.set_input_net(net, if x.is_spike() && x.0 == t { !0u64 } else { 0 });
                }
                wsim.set_input_net(d.grst, if t == g - 1 { !0u64 } else { 0 });
                for (k, f) in chunk.iter().enumerate() {
                    let mask = 1u64 << (k + 1);
                    match *f {
                        GateFault::SeuNet { net, cycle } if cycle == c => {
                            wsim.flip_net_lanes(net, mask)
                        }
                        GateFault::SeuMacroBit { inst, bit, cycle } if cycle == c => {
                            wsim.flip_macro_bit_lanes(inst, bit as usize, mask)
                        }
                        _ => {}
                    }
                }
                wsim.settle();
                for (j, &net) in d.out_spike.iter().enumerate() {
                    let v = wsim.get(net);
                    out_diff |= (v ^ splat_lsb(v)) & used;
                    let fresh = v & !seen[j];
                    if fresh != 0 {
                        seen[j] |= fresh;
                        let mut bits = fresh;
                        while bits != 0 {
                            let l = bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            times[l * q + j] = SpikeTime::at(t);
                        }
                    }
                }
                wsim.clock();
            }
            for &net in &sites.nets {
                let v = wsim.get(net);
                state_diff |= (v ^ splat_lsb(v)) & used;
            }
            for &(inst, bits) in &sites.macros {
                for b in 0..bits {
                    let pl = wsim.macro_state(inst).plane(b);
                    state_diff |= (pl ^ splat_lsb(pl)) & used;
                }
            }
            let w0 = winner_of(&times[..q]);
            if ci == 0 {
                ref_winners.push(w0);
            }
            for (k, m) in mism.iter_mut().enumerate() {
                let l = k + 1;
                if winner_of(&times[l * q..(l + 1) * q]) != w0 {
                    *m += 1;
                }
            }
        }
        for (k, f) in chunk.iter().enumerate() {
            let lane = k + 1;
            outcomes.push(FaultOutcome {
                fault: *f,
                site: site_label(&d.netlist, f),
                class: classify(
                    (out_diff >> lane) & 1 != 0,
                    (state_diff >> lane) & 1 != 0,
                ),
                winner_mismatches: mism[k],
            });
        }
    }
    Ok(CampaignResult {
        outcomes,
        ref_winners,
    })
}

/// The compiled lane-block campaign: `words × 64 − 1` faults per pass,
/// reference in lane 0 of word 0 (see the drift note on
/// [`word_campaign`]).
#[allow(clippy::too_many_arguments)]
fn compiled_campaign(
    d: &ColumnDesign,
    ws: &[u8],
    gamma: u32,
    volleys: &[&[SpikeTime]],
    faults: &[GateFault],
    sites: &StateSites,
    words: usize,
    threads: usize,
) -> Result<CampaignResult, String> {
    let q = d.q;
    let g = gamma;
    let mut csim = CompiledSim::new(&d.netlist, words, threads)?;
    let lanes_total = words * LANES;
    let per_pass = lanes_total - 1;
    let mut outcomes = Vec::with_capacity(faults.len());
    let mut ref_winners: Vec<Option<usize>> = Vec::new();
    let chunks: Vec<&[GateFault]> = if faults.is_empty() {
        vec![faults]
    } else {
        faults.chunks(per_pass).collect()
    };
    for (ci, chunk) in chunks.iter().enumerate() {
        csim.clear_faults();
        csim.reset_state();
        for (k, &inst) in d.syn_inst.iter().enumerate() {
            let mut st = MacroState::default();
            st.set_weight(ws[k]);
            csim.set_macro_state_broadcast(inst as usize, &st);
        }
        for case in &d.brv_case {
            for &net in case {
                for w in 0..words {
                    csim.set_input_net(net, w, 0);
                }
            }
        }
        for stab in &d.brv_stab {
            for &net in stab {
                for w in 0..words {
                    csim.set_input_net(net, w, 0);
                }
            }
        }
        for (k, f) in chunk.iter().enumerate() {
            if let GateFault::StuckAt { net, value } = *f {
                let gl = k + 1;
                let mask = 1u64 << (gl % LANES);
                if value {
                    csim.force_net_word(net, gl / LANES, 0, mask);
                } else {
                    csim.force_net_word(net, gl / LANES, mask, 0);
                }
            }
        }
        // Per-word used-lane masks: lanes 0..=chunk.len() globally.
        let total_used = chunk.len() + 1;
        let used: Vec<u64> = (0..words)
            .map(|w| {
                let lanes = total_used.saturating_sub(w * LANES).min(LANES);
                if lanes == 0 {
                    0
                } else {
                    lane_mask(lanes)
                }
            })
            .collect();
        let mut out_diff = vec![0u64; words];
        let mut state_diff = vec![0u64; words];
        let mut mism = vec![0usize; chunk.len()];
        let mut times = vec![SpikeTime::NONE; lanes_total * q];
        let mut seen = vec![0u64; q * words];
        for (item, volley) in volleys.iter().enumerate() {
            times.fill(SpikeTime::NONE);
            seen.fill(0);
            for t in 0..g {
                let c = item as u64 * g as u64 + t as u64;
                for (i, &net) in d.in_pulse.iter().enumerate() {
                    let x = volley[i];
                    let word = if x.is_spike() && x.0 == t { !0u64 } else { 0 };
                    for w in 0..words {
                        csim.set_input_net(net, w, word);
                    }
                }
                for w in 0..words {
                    csim.set_input_net(d.grst, w, if t == g - 1 { !0u64 } else { 0 });
                }
                for (k, f) in chunk.iter().enumerate() {
                    let gl = k + 1;
                    let mask = 1u64 << (gl % LANES);
                    match *f {
                        GateFault::SeuNet { net, cycle } if cycle == c => {
                            csim.flip_net_word(net, gl / LANES, mask)
                        }
                        GateFault::SeuMacroBit { inst, bit, cycle } if cycle == c => {
                            csim.flip_macro_bit_word(inst, gl / LANES, bit as usize, mask)
                        }
                        _ => {}
                    }
                }
                csim.settle();
                for (j, &net) in d.out_spike.iter().enumerate() {
                    let r = splat_lsb(csim.get_word(net, 0));
                    for w in 0..words {
                        let v = csim.get_word(net, w);
                        out_diff[w] |= (v ^ r) & used[w];
                        let fresh = v & !seen[j * words + w];
                        if fresh != 0 {
                            seen[j * words + w] |= fresh;
                            let mut bits = fresh;
                            while bits != 0 {
                                let l = bits.trailing_zeros() as usize;
                                bits &= bits - 1;
                                times[(w * LANES + l) * q + j] = SpikeTime::at(t);
                            }
                        }
                    }
                }
                csim.clock();
            }
            for &net in &sites.nets {
                let r = splat_lsb(csim.get_word(net, 0));
                for w in 0..words {
                    state_diff[w] |= (csim.get_word(net, w) ^ r) & used[w];
                }
            }
            for &(inst, bits) in &sites.macros {
                for b in 0..bits {
                    let r = splat_lsb(csim.macro_state(inst, 0).plane(b));
                    for w in 0..words {
                        state_diff[w] |= (csim.macro_state(inst, w).plane(b) ^ r) & used[w];
                    }
                }
            }
            let w0 = winner_of(&times[..q]);
            if ci == 0 {
                ref_winners.push(w0);
            }
            for (k, m) in mism.iter_mut().enumerate() {
                let gl = k + 1;
                if winner_of(&times[gl * q..(gl + 1) * q]) != w0 {
                    *m += 1;
                }
            }
        }
        for (k, f) in chunk.iter().enumerate() {
            let gl = k + 1;
            outcomes.push(FaultOutcome {
                fault: *f,
                site: site_label(&d.netlist, f),
                class: classify(
                    (out_diff[gl / LANES] >> (gl % LANES)) & 1 != 0,
                    (state_diff[gl / LANES] >> (gl % LANES)) & 1 != 0,
                ),
                winner_mismatches: mism[k],
            });
        }
    }
    Ok(CampaignResult {
        outcomes,
        ref_winners,
    })
}

#[cfg(test)]
mod tests {
    use super::super::column_design::{build_column, BrvSource};
    use super::super::gate_engine::GateColumn;
    use super::*;
    use crate::tnn::params::TnnParams;
    use crate::tnn::spike::random_volley;

    fn setup(
        p: usize,
        q: usize,
        theta: u32,
        items: usize,
        seed: u64,
    ) -> (ColumnDesign, Vec<u8>, Vec<Vec<SpikeTime>>, u32) {
        let mut rng = Rng64::seed_from_u64(seed);
        let d = build_column(p, q, theta, BrvSource::Inputs);
        let ws: Vec<u8> = (0..p * q).map(|_| rng.gen_range(0, 8) as u8).collect();
        let gamma = TnnParams::default().gamma_cycles;
        let volleys: Vec<Vec<SpikeTime>> = (0..items)
            .map(|_| random_volley(p, 0.3, gamma, &mut rng))
            .collect();
        (d, ws, volleys, gamma)
    }

    fn backends() -> Vec<SimBackend> {
        vec![
            SimBackend::Scalar,
            SimBackend::BitParallel64,
            SimBackend::Compiled { words: 1, threads: 1 },
            SimBackend::Compiled { words: 2, threads: 2 },
        ]
    }

    #[test]
    fn zero_fault_campaign_matches_baseline_inference_everywhere() {
        let (d, ws, volleys, gamma) = setup(5, 2, 5, 9, 11);
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let mut gate =
            GateColumn::with_weights(d.p, d.q, d.theta, TnnParams::default(), &ws).unwrap();
        let baseline: Vec<Option<usize>> =
            volleys.iter().map(|v| gate.infer_winner(v)).collect();
        for backend in backends() {
            let r = campaign(&d, &ws, gamma, &refs, &[], backend).unwrap();
            assert!(r.outcomes.is_empty());
            assert_eq!(r.ref_winners, baseline, "backend {}", backend.name());
        }
    }

    #[test]
    fn stuck_output_propagates_identically_on_every_backend() {
        let (d, ws, volleys, gamma) = setup(4, 2, 4, 6, 3);
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let faults = [GateFault::StuckAt {
            net: d.out_spike[0],
            value: true,
        }];
        let mut results = Vec::new();
        for backend in backends() {
            let r = campaign(&d, &ws, gamma, &refs, &faults, backend).unwrap();
            assert_eq!(r.outcomes.len(), 1);
            assert_eq!(
                r.outcomes[0].class,
                FaultClass::Propagated,
                "backend {}",
                backend.name()
            );
            results.push(r);
        }
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
    }

    #[test]
    fn sampled_campaign_outcomes_are_backend_invariant() {
        let (d, ws, volleys, gamma) = setup(5, 2, 5, 7, 99);
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let total = volleys.len() as u64 * gamma as u64;
        let faults = sample_faults(&d.netlist, 6, 6, total, 0xFA17);
        assert_eq!(faults.len(), 12);
        let mut results = Vec::new();
        for backend in backends() {
            results.push(campaign(&d, &ws, gamma, &refs, &faults, backend).unwrap());
        }
        for (i, r) in results.iter().enumerate().skip(1) {
            assert_eq!(r, &results[0], "backend #{i} diverged");
        }
        // The sampled set should exercise macro sites (labels feed the
        // per-macro-type report).
        assert!(results[0].outcomes.iter().any(|o| o.site != "logic"));
        assert_eq!(results[0].counts().total(), 12);
    }

    #[test]
    fn sample_faults_is_reproducible_from_its_seed() {
        let d = build_column(4, 2, 4, BrvSource::Inputs);
        let a = sample_faults(&d.netlist, 8, 8, 128, 42);
        let b = sample_faults(&d.netlist, 8, 8, 128, 42);
        assert_eq!(a, b);
        let c = sample_faults(&d.netlist, 8, 8, 128, 43);
        assert_ne!(a, c, "distinct seeds sample distinct campaigns");
    }

    #[test]
    fn campaign_rejects_malformed_faults() {
        let (d, ws, volleys, gamma) = setup(3, 1, 3, 2, 1);
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let bad_net = GateFault::StuckAt {
            net: d.netlist.gates.len() as NetId,
            value: true,
        };
        let err = campaign(&d, &ws, gamma, &refs, &[bad_net], SimBackend::Scalar)
            .unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        let late = GateFault::SeuNet {
            net: 0,
            cycle: volleys.len() as u64 * gamma as u64,
        };
        let err = campaign(&d, &ws, gamma, &refs, &[late], SimBackend::Scalar).unwrap_err();
        assert!(err.contains("beyond campaign"), "{err}");
    }
}
