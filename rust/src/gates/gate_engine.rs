//! The gate-level macro netlist as a first-class column engine.
//!
//! [`ColumnSim`](super::column_design::ColumnSim) began life as a test-only
//! cross-check harness; this module promotes the same netlist — the nine
//! TNN7 macros assembled into the full p×q column of Fig. 1 — to a
//! selectable engine (`config::EngineKind::Gate`) behind the
//! `coordinator::Engine` interface, so **every workload doubles as an
//! RTL-vs-behavioral equivalence check**:
//!
//! * **Training** ([`GateColumn::step`]) draws its uniforms with exactly the
//!   golden model's protocol (one `fill_f64` for the case draws, one for the
//!   stabilization draws, row-major p×q) and feeds them to the netlist as
//!   Bernoulli-thresholded BRV inputs. On a shared seed the gate engine's
//!   WTA winners *and* its synaptic weights are bit-exact with
//!   `Column::step`, gamma cycle for gamma cycle.
//! * **Inference** ([`GateColumn::infer_winner`]) is draw-free: all-ones
//!   uniforms block every STDP case, exactly like the golden/batched
//!   engines' inference paths.
//! * **Batched inference** ([`GateColumn::infer_batch`]) packs up to 64
//!   gamma items into the lanes of a [`WordSimulator`] over the same
//!   netlist: gates evaluate as bitwise word ops, so a full-dataset
//!   gate-level inference sweep costs roughly one scalar pass. Lane `l` is
//!   bit-for-bit the scalar engine on item `l`, so the winners are
//!   bit-exact with the scalar path (and hence with the golden model).
//!
//! Gate netlists are immutable once built and levelized, so designs and
//! compiled programs are shared through the concurrent artifact cache
//! ([`super::artifact_cache`]): each (p, q, θ) geometry is built once and
//! handed out as an [`Arc`] to every engine, test, sweep point and fault
//! campaign that asks for it — the in-memory analogue of an AOT-compiled
//! hardware artifact, with LRU eviction instead of the old
//! process-lifetime leak. Compiled programs get the same treatment
//! ([`program_handle`](super::artifact_cache::program_handle)): each
//! (p, q, θ, [`OptLevel`]) is levelized, optionally optimizer-reduced and
//! lowered to a [`CompiledProgram`](super::compile::CompiledProgram) once
//! per live cache entry, so switching lane-block width or worker count on
//! a `GateColumn` re-allocates executor state but never recompiles.

use super::artifact_cache::{design_handle, program_handle, ColumnProgram};
use super::column_design::{ColumnDesign, ColumnSim};
use super::compile::CompiledSim;
use super::macros9::MacroState;
use super::opt::OptLevel;
use super::wordsim::{WordSimulator, LANES};
use super::SimBackend;
use crate::tnn::column::Column;
use crate::tnn::params::TnnParams;
use crate::tnn::spike::{earliest_spike, SpikeTime};
use crate::util::Rng64;
use std::sync::Arc;

/// The gate-level column engine: the macro netlist plus a persistent scalar
/// simulator (synaptic weights live in the `syn_weight_update` macro
/// states) and a lazily-built word simulator for batched inference sweeps.
pub struct GateColumn {
    // NOTE field order: `sim` and `wsim` borrow the design owned by
    // `design_owner` (see `with_weights` for the safety argument), so they
    // are declared first and drop first.
    sim: ColumnSim<'static>,
    /// 64-lane engine over the same netlist, built on first batched sweep.
    wsim: Option<WordSimulator<'static>>,
    /// Compiled lane-block engine, built on first batched sweep under a
    /// `SimBackend::Compiled` selection.
    csim: Option<CompiledSim>,
    /// The cached program behind `csim` (same opt level), held so repeated
    /// sweeps skip the cache lookup and the entry survives eviction for as
    /// long as this engine uses it.
    cprog: Option<Arc<ColumnProgram>>,
    /// Which simulator runs the batched inference sweeps (winners are
    /// bit-exact across backends; this is purely a throughput knob).
    backend: SimBackend,
    /// Netlist optimization level for the compiled backend (the
    /// interpreters always run the full netlist).
    opt: OptLevel,
    params: TnnParams,
    /// All-ones uniforms: `u >= 1` fails every `u < µ` test, so no BRV
    /// fires and a gamma cycle is pure inference.
    ones: Vec<f64>,
    // training draw buffers (reused; the golden model allocates per step,
    // but consumes the identical stream)
    u_case: Vec<f64>,
    u_stab: Vec<f64>,
    /// Borrow of `design_owner`'s pointee (never handed out as `'static`).
    design: &'static ColumnDesign,
    /// The cache handle that keeps `design` alive for this engine's whole
    /// lifetime, eviction or not.
    design_owner: Arc<ColumnDesign>,
}

impl GateColumn {
    /// Build from an existing golden column, copying geometry, parameters
    /// and the current weight matrix — the constructor `ucr_engine_with`
    /// uses so all engines start from identical state on a shared seed.
    pub fn from_column(col: &Column) -> crate::Result<GateColumn> {
        Self::with_weights(
            col.p(),
            col.q(),
            col.theta(),
            col.params().clone(),
            col.weights(),
        )
    }

    /// Build for a geometry with explicit initial weights (row-major p×q).
    pub fn with_weights(
        p: usize,
        q: usize,
        theta: u32,
        params: TnnParams,
        ws: &[u8],
    ) -> crate::Result<GateColumn> {
        let design_owner = design_handle(p, q, theta)?;
        // SAFETY: `design` points into the heap allocation owned by
        // `design_owner`, which this struct holds for its entire lifetime;
        // `Arc`'s pointee address is stable under moves of the handle (and
        // of the `GateColumn`), and a `ColumnDesign` is never mutated after
        // construction. The reference is confined to this struct's private
        // fields and the simulators borrowing from them — no accessor
        // re-exports it — so it cannot outlive `design_owner`. This is the
        // owning-handle pattern that lets cache entries be evictable
        // (`Arc`) while the borrowing simulators keep their plain-`&`
        // APIs.
        let design: &'static ColumnDesign = unsafe { &*Arc::as_ptr(&design_owner) };
        let mut sim = ColumnSim::new(design, params.clone()).map_err(anyhow::Error::msg)?;
        sim.set_weights(ws);
        let n = p * q;
        Ok(GateColumn {
            sim,
            wsim: None,
            csim: None,
            cprog: None,
            backend: SimBackend::BitParallel64,
            opt: OptLevel::None,
            params,
            ones: vec![1.0; n],
            u_case: vec![0.0; n],
            u_stab: vec![0.0; n],
            design,
            design_owner,
        })
    }

    /// Synapse lines per neuron.
    pub fn p(&self) -> usize {
        self.design.p
    }
    /// Neurons in the column.
    pub fn q(&self) -> usize {
        self.design.q
    }
    /// Neuron firing threshold.
    pub fn theta(&self) -> u32 {
        self.design.theta
    }
    /// The engine's hyper-parameters.
    pub fn params(&self) -> &TnnParams {
        &self.params
    }

    /// The shared cache handle this engine's design came from — the same
    /// `Arc` every other consumer of this (p, q, θ) holds (until
    /// eviction), which is what makes "fault campaign and engine run one
    /// design artifact" a checkable [`Arc::ptr_eq`] fact.
    pub fn design_handle(&self) -> &Arc<ColumnDesign> {
        &self.design_owner
    }

    /// Read the synaptic weights back out of the macro states.
    pub fn weights(&self) -> Vec<u8> {
        self.sim.weights()
    }

    /// Preload synaptic weights (row-major p×q).
    pub fn set_weights(&mut self, ws: &[u8]) {
        self.sim.set_weights(ws);
    }

    /// One learning gamma cycle through the netlist, drawing uniforms with
    /// the golden model's protocol (`u_case` fill, then `u_stab` fill) so
    /// gate and golden consume a shared stream identically. Returns the
    /// post-WTA winner.
    pub fn step(&mut self, xs: &[SpikeTime], rng: &mut Rng64) -> Option<usize> {
        rng.fill_f64(&mut self.u_case);
        rng.fill_f64(&mut self.u_stab);
        let out = self.sim.run_gamma(xs, &self.u_case, &self.u_stab);
        out.iter().position(|t| t.is_spike())
    }

    /// Draw-free inference: the post-WTA output volley (weights untouched).
    pub fn infer(&mut self, xs: &[SpikeTime]) -> Vec<SpikeTime> {
        self.sim.run_gamma(xs, &self.ones, &self.ones)
    }

    /// Draw-free inference winner.
    pub fn infer_winner(&mut self, xs: &[SpikeTime]) -> Option<usize> {
        self.infer(xs).iter().position(|t| t.is_spike())
    }

    /// Select the gate-level simulator behind [`GateColumn::infer_batch`]:
    /// `Compiled { words, threads }` packs `words × 64` volleys per pass
    /// into a [`CompiledSim`]; `BitParallel64` (the default) uses the
    /// 64-lane [`WordSimulator`] interpreter; `Scalar` loops the per-volley
    /// scalar path (the honest scalar baseline). Winners are bit-exact
    /// across backends — this is a throughput knob, never a semantics
    /// knob.
    pub fn set_sim_backend(&mut self, backend: SimBackend) {
        if backend != self.backend {
            self.backend = backend;
            self.csim = None; // rebuilt lazily with the new lane-block width
        }
    }

    /// The simulator backend batched inference sweeps run on.
    pub fn sim_backend(&self) -> SimBackend {
        self.backend
    }

    /// Select the netlist optimization level for the compiled backend:
    /// [`OptLevel::Inference`] runs batched sweeps on the
    /// inference-specialized program (BRV constant propagation + dead-logic
    /// elimination + locality scheduling, via
    /// [`program_handle`](super::artifact_cache::program_handle)) instead
    /// of the full learning netlist. Winners are bit-exact across levels —
    /// like [`GateColumn::set_sim_backend`], a throughput knob, never a
    /// semantics knob. Only the `Compiled` backend consults it; the
    /// interpreter backends always run the full netlist.
    pub fn set_opt_level(&mut self, opt: OptLevel) {
        if opt != self.opt {
            self.opt = opt;
            self.csim = None; // rebuilt lazily from the other cached program
            self.cprog = None;
        }
    }

    /// The netlist optimization level the compiled backend runs at.
    pub fn opt_level(&self) -> OptLevel {
        self.opt
    }

    /// Batched gate-level inference sweep: packs many volleys per pass
    /// into the lanes of the selected simulator backend over the same
    /// netlist (64 per pass on the interpreter, `words × 64` on the
    /// compiled engine). Weights are broadcast into every lane and all BRV
    /// inputs are held low (the word-level analogue of the scalar path's
    /// all-ones uniforms), so each lane runs the exact scalar inference
    /// gamma cycle and winners are bit-exact with
    /// [`GateColumn::infer_winner`] on every backend. Errs only when the
    /// compiled backend's program build failed (a memoized cache error —
    /// the interpreter backends never fail).
    pub fn infer_batch(&mut self, volleys: &[&[SpikeTime]]) -> crate::Result<Vec<Option<usize>>> {
        // Hard assert, matching the scalar path (`ColumnSim::run_gamma`): a
        // malformed volley must fail loudly on both paths, in release too.
        for (k, v) in volleys.iter().enumerate() {
            assert_eq!(v.len(), self.design.p, "volley {k} length != p");
        }
        match self.backend {
            SimBackend::Compiled { words, threads } => {
                self.infer_batch_compiled(volleys, words, threads)
            }
            SimBackend::BitParallel64 => Ok(self.infer_batch_word(volleys)),
            SimBackend::Scalar => {
                // The flag means what it says: the true scalar engine, one
                // volley at a time (useful as a baseline / cross-check).
                let mut winners = Vec::with_capacity(volleys.len());
                for v in volleys {
                    winners.push(self.infer_winner(v));
                }
                Ok(winners)
            }
        }
    }

    /// The 64-lane interpreter sweep behind [`GateColumn::infer_batch`].
    ///
    /// NOTE: this and [`compiled_inference_sweep`] implement the SAME
    /// inference protocol (weight broadcast, BRV silencing, GRST on the
    /// last gamma cycle, first-spike extraction) on two different engines —
    /// any protocol change must land in both, and the cross-backend
    /// equality tests (unit, conformance, bench guard) exist to fail
    /// loudly if they drift.
    fn infer_batch_word(&mut self, volleys: &[&[SpikeTime]]) -> Vec<Option<usize>> {
        let d = self.design;
        let g = self.params.gamma_cycles;
        let q = d.q;
        let ws = self.sim.weights();
        let wsim = self
            .wsim
            .get_or_insert_with(|| WordSimulator::new(&d.netlist).expect("cached design levelizes"));

        let mut winners = Vec::with_capacity(volleys.len());
        for chunk in volleys.chunks(LANES) {
            wsim.reset_state();
            // Broadcast the current weights into every lane and silence the
            // BRV streams (no case ever fires → pure inference).
            for (k, &inst) in d.syn_inst.iter().enumerate() {
                let mut st = MacroState::default();
                st.set_weight(ws[k]);
                wsim.set_macro_state_broadcast(inst as usize, &st);
            }
            for case in &d.brv_case {
                for &net in case {
                    wsim.set_input_net(net, 0);
                }
            }
            for stab in &d.brv_stab {
                for &net in stab {
                    wsim.set_input_net(net, 0);
                }
            }

            // Run one gamma cycle in all lanes, recording each lane's first
            // cycle with the output net high (level semantics, identical to
            // `ColumnSim::run_gamma`).
            let mut times = vec![SpikeTime::NONE; chunk.len() * q];
            let mut seen = vec![0u64; q];
            for t in 0..g {
                for (i, &net) in d.in_pulse.iter().enumerate() {
                    let mut word = 0u64;
                    for (l, volley) in chunk.iter().enumerate() {
                        let x = volley[i];
                        if x.is_spike() && x.0 == t {
                            word |= 1u64 << l;
                        }
                    }
                    wsim.set_input_net(net, word);
                }
                wsim.set_input_net(d.grst, if t == g - 1 { !0u64 } else { 0 });
                wsim.settle();
                for (j, &net) in d.out_spike.iter().enumerate() {
                    let fresh = wsim.get(net) & !seen[j];
                    if fresh != 0 {
                        seen[j] |= fresh;
                        let mut bits = fresh;
                        while bits != 0 {
                            let l = bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            if l < chunk.len() {
                                times[l * q + j] = SpikeTime::at(t);
                            }
                        }
                    }
                }
                wsim.clock();
            }
            for lane_times in times.chunks_exact(q) {
                let (idx, t) = earliest_spike(lane_times);
                winners.push(t.is_spike().then_some(idx));
            }
        }
        winners
    }

    /// The compiled lane-block sweep behind [`GateColumn::infer_batch`]:
    /// one compiled pass per `words × 64`-volley chunk, levels sharded
    /// across `threads` workers, addressed through the cached
    /// [`ColumnProgram`] for the selected [`OptLevel`] — under
    /// [`OptLevel::Inference`] the program's nets are optimizer-renumbered
    /// and the BRV silencing loop collapses to the (normally empty)
    /// survivor list. The sweep body itself is the shared
    /// [`compiled_inference_sweep`], which the serve-path
    /// `coordinator::ServiceEngine` also drives.
    fn infer_batch_compiled(
        &mut self,
        volleys: &[&[SpikeTime]],
        words: usize,
        threads: usize,
    ) -> crate::Result<Vec<Option<usize>>> {
        let d = self.design;
        if self.cprog.is_none() {
            self.cprog = Some(program_handle(d.p, d.q, d.theta, self.opt)?);
        }
        let cp = self.cprog.as_ref().expect("set above").clone();
        // Resolve 0 = machine parallelism BEFORE the rebuild check —
        // `CompiledSim::threads()` reports the resolved count, and
        // comparing it against a raw 0 would rebuild every call.
        let threads = if threads == 0 {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        } else {
            threads
        };
        // `set_opt_level` clears `csim` and `cprog`, so an existing
        // executor always belongs to the current program — only
        // width/workers can drift.
        let rebuild = match &self.csim {
            Some(c) => c.words() != words || c.threads() != threads,
            None => true,
        };
        if rebuild {
            self.csim = Some(CompiledSim::from_program(cp.prog.clone(), words, threads));
        }
        let csim = self.csim.as_mut().expect("built above");
        let ws = self.sim.weights();
        Ok(compiled_inference_sweep(
            &cp,
            csim,
            self.params.gamma_cycles,
            d.q,
            &ws,
            volleys,
        ))
    }
}

/// One batched inference sweep on a compiled executor: chunks `volleys`
/// into `csim.words() × 64`-lane passes, broadcasts `ws` into every lane,
/// silences the program's surviving BRV inputs, pulses GRST on the last
/// gamma cycle and extracts each lane's earliest output spike — the exact
/// protocol of [`GateColumn::infer_batch`]'s interpreter path, word by
/// word (see the drift note there).
///
/// Shared by the gate engine and the serving layer
/// (`coordinator::ServiceEngine`), which runs it on pooled executors so
/// concurrent requests get per-request scratch over one cached program.
pub(crate) fn compiled_inference_sweep(
    cp: &ColumnProgram,
    csim: &mut CompiledSim,
    gamma: u32,
    q: usize,
    ws: &[u8],
    volleys: &[&[SpikeTime]],
) -> Vec<Option<usize>> {
    let p = cp.in_pulse.len();
    for (k, v) in volleys.iter().enumerate() {
        assert_eq!(v.len(), p, "volley {k} length != p");
    }
    let words = csim.words();
    let lanes = words * LANES;

    let mut winners = Vec::with_capacity(volleys.len());
    for chunk in volleys.chunks(lanes) {
        csim.reset_state();
        // Broadcast the current weights into every lane of every word
        // and silence any surviving BRV streams (no case ever fires →
        // pure inference), exactly like the interpreter path.
        for (k, &inst) in cp.syn_inst.iter().enumerate() {
            let mut st = MacroState::default();
            st.set_weight(ws[k]);
            csim.set_macro_state_broadcast(inst as usize, &st);
        }
        for &net in &cp.silence {
            for w in 0..words {
                csim.set_input_net(net, w, 0);
            }
        }

        // One gamma cycle in all lanes; record each lane's first cycle
        // with the output net high (level semantics, identical to
        // `ColumnSim::run_gamma`). `seen[j * words + w]` masks lanes of
        // word `w` that already fired on output j.
        let mut times = vec![SpikeTime::NONE; chunk.len() * q];
        let mut seen = vec![0u64; q * words];
        for t in 0..gamma {
            for (i, &net) in cp.in_pulse.iter().enumerate() {
                for w in 0..words {
                    let mut word = 0u64;
                    for (l, volley) in chunk.iter().skip(w * LANES).take(LANES).enumerate() {
                        let x = volley[i];
                        if x.is_spike() && x.0 == t {
                            word |= 1u64 << l;
                        }
                    }
                    csim.set_input_net(net, w, word);
                }
            }
            for w in 0..words {
                csim.set_input_net(cp.grst, w, if t == gamma - 1 { !0u64 } else { 0 });
            }
            csim.settle();
            for (j, &net) in cp.out_spike.iter().enumerate() {
                for w in 0..words {
                    let fresh = csim.get_word(net, w) & !seen[j * words + w];
                    if fresh != 0 {
                        seen[j * words + w] |= fresh;
                        let mut bits = fresh;
                        while bits != 0 {
                            let l = bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            let idx = w * LANES + l;
                            if idx < chunk.len() {
                                times[idx * q + j] = SpikeTime::at(t);
                            }
                        }
                    }
                }
            }
            csim.clock();
        }
        for lane_times in times.chunks_exact(q) {
            let (idx, t) = earliest_spike(lane_times);
            winners.push(t.is_spike().then_some(idx));
        }
    }
    winners
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_volley(p: usize, rng: &mut Rng64) -> Vec<SpikeTime> {
        crate::tnn::spike::random_volley(p, 0.3, 8, rng)
    }

    #[test]
    fn designs_are_shared_per_geometry_until_eviction() {
        let a = design_handle(4, 2, 5).unwrap();
        let b = design_handle(4, 2, 5).unwrap();
        let c = design_handle(4, 2, 6).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same geometry shares one design");
        assert!(!Arc::ptr_eq(&a, &c), "distinct θ gets its own design");
        assert_eq!(a.p, 4);
        assert_eq!(a.q, 2);
        assert!(!a.brv_case.is_empty(), "engine designs carry BRV inputs");
        // The engine holds the same shared artifact.
        let gate = GateColumn::with_weights(4, 2, 5, TnnParams::default(), &[0; 8]).unwrap();
        assert!(Arc::ptr_eq(&a, gate.design_handle()));
    }

    #[test]
    fn gate_step_matches_golden_on_a_shared_stream() {
        // The engine contract: identical winners AND identical weights,
        // gamma for gamma, when both engines consume the same seed.
        let mut setup = Rng64::seed_from_u64(404);
        let (p, q, theta) = (6, 3, 7);
        let params = TnnParams::default();
        let mut golden = Column::with_random_weights(p, q, theta, params, &mut setup);
        let mut gate = GateColumn::from_column(&golden).unwrap();
        assert_eq!(gate.weights(), golden.weights());
        assert_eq!((gate.p(), gate.q(), gate.theta()), (p, q, theta));

        let mut rng_gold = Rng64::seed_from_u64(77);
        let mut rng_gate = rng_gold.clone();
        let mut data = Rng64::seed_from_u64(5);
        for gamma in 0..30 {
            let xs = random_volley(p, &mut data);
            let want = golden.step(&xs, &mut rng_gold).winner;
            let got = gate.step(&xs, &mut rng_gate);
            assert_eq!(got, want, "gamma {gamma}: winner mismatch");
            assert_eq!(gate.weights(), golden.weights(), "gamma {gamma}: weights");
        }
        // Stream alignment: both engines consumed the same number of draws.
        assert_eq!(rng_gold.next_u64(), rng_gate.next_u64());
    }

    #[test]
    fn infer_is_draw_free_and_leaves_weights_untouched() {
        let mut rng = Rng64::seed_from_u64(8);
        let golden = Column::with_random_weights(5, 2, 6, TnnParams::default(), &mut rng);
        let mut gate = GateColumn::from_column(&golden).unwrap();
        let before = gate.weights();
        for _ in 0..10 {
            let xs = random_volley(5, &mut rng);
            assert_eq!(gate.infer_winner(&xs), golden.infer(&xs).winner);
        }
        assert_eq!(gate.weights(), before);
    }

    #[test]
    fn word_batch_inference_matches_scalar_and_golden_across_chunks() {
        // 70 volleys forces a second 64-lane chunk.
        let mut rng = Rng64::seed_from_u64(1234);
        let golden = Column::with_random_weights(6, 2, 8, TnnParams::default(), &mut rng);
        let mut gate = GateColumn::from_column(&golden).unwrap();
        let volleys: Vec<Vec<SpikeTime>> =
            (0..70).map(|_| random_volley(6, &mut rng)).collect();
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let batch = gate.infer_batch(&refs).unwrap();
        assert_eq!(batch.len(), 70);
        let mut fired = 0;
        for (k, v) in volleys.iter().enumerate() {
            assert_eq!(batch[k], gate.infer_winner(v), "volley {k} vs scalar gate");
            assert_eq!(batch[k], golden.infer(v).winner, "volley {k} vs golden");
            fired += usize::from(batch[k].is_some());
        }
        assert!(fired > 0, "stimulus should make some neuron fire");
    }

    #[test]
    fn compiled_batch_inference_is_bit_exact_with_word_and_scalar_paths() {
        // 150 volleys force multiple chunks at every tested lane-block
        // width (words=1 -> 3 chunks, words=2 -> 2 chunks).
        let mut rng = Rng64::seed_from_u64(4321);
        let golden = Column::with_random_weights(6, 3, 8, TnnParams::default(), &mut rng);
        let mut gate = GateColumn::from_column(&golden).unwrap();
        let volleys: Vec<Vec<SpikeTime>> =
            (0..150).map(|_| random_volley(6, &mut rng)).collect();
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        assert_eq!(gate.sim_backend(), crate::gates::SimBackend::BitParallel64);
        let word = gate.infer_batch(&refs).unwrap();
        for (words, threads) in [(1usize, 1usize), (2, 2)] {
            gate.set_sim_backend(crate::gates::SimBackend::Compiled { words, threads });
            assert_eq!(
                gate.sim_backend(),
                crate::gates::SimBackend::Compiled { words, threads }
            );
            let compiled = gate.infer_batch(&refs).unwrap();
            assert_eq!(compiled, word, "words={words} threads={threads}");
        }
        // The scalar backend loops the true per-volley scalar engine.
        gate.set_sim_backend(crate::gates::SimBackend::Scalar);
        assert_eq!(gate.infer_batch(&refs).unwrap(), word, "scalar backend batch");
        // …and both agree with the scalar per-volley path and golden.
        for (k, v) in volleys.iter().enumerate() {
            assert_eq!(word[k], gate.infer_winner(v), "volley {k} vs scalar gate");
            assert_eq!(word[k], golden.infer(v).winner, "volley {k} vs golden");
        }
    }

    #[test]
    fn optimized_compiled_batch_is_bit_exact_and_leaner() {
        let mut rng = Rng64::seed_from_u64(9090);
        let golden = Column::with_random_weights(6, 3, 8, TnnParams::default(), &mut rng);
        let mut gate = GateColumn::from_column(&golden).unwrap();
        let volleys: Vec<Vec<SpikeTime>> =
            (0..100).map(|_| random_volley(6, &mut rng)).collect();
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let word = gate.infer_batch(&refs).unwrap();

        gate.set_sim_backend(crate::gates::SimBackend::Compiled { words: 2, threads: 1 });
        assert_eq!(gate.opt_level(), OptLevel::None);
        let plain = gate.infer_batch(&refs).unwrap();
        gate.set_opt_level(OptLevel::Inference);
        let lean = gate.infer_batch(&refs).unwrap();
        assert_eq!(lean, plain, "opt=inference winners drifted");
        assert_eq!(lean, word, "opt=inference vs interpreter");
        // Flipping back rebuilds from the cached unoptimized program.
        gate.set_opt_level(OptLevel::None);
        assert_eq!(gate.infer_batch(&refs).unwrap(), word, "opt=none after round-trip");

        let full = program_handle(6, 3, 8, OptLevel::None).unwrap();
        let opt = program_handle(6, 3, 8, OptLevel::Inference).unwrap();
        assert!(
            Arc::ptr_eq(&opt, &program_handle(6, 3, 8, OptLevel::Inference).unwrap()),
            "programs are shared per (geometry, opt) key"
        );
        assert!(
            opt.prog.instr_count() < full.prog.instr_count(),
            "inference specialization must shrink the instruction stream"
        );
        assert!(
            opt.silence.is_empty(),
            "every BRV input should fold away under the inference pipeline"
        );
        assert!(opt.remap.new_net_count() < opt.remap.old_net_count());
    }

    #[test]
    fn compiled_batch_after_training_uses_current_weights() {
        // Train a little, then check the compiled sweep reflects the
        // updated weights (weights are re-broadcast every sweep).
        let mut rng = Rng64::seed_from_u64(77);
        let golden = Column::with_random_weights(4, 2, 4, TnnParams::default(), &mut rng);
        let mut gate = GateColumn::from_column(&golden).unwrap();
        gate.set_sim_backend(crate::gates::SimBackend::Compiled { words: 1, threads: 1 });
        let mut stream = Rng64::seed_from_u64(31);
        let volleys: Vec<Vec<SpikeTime>> =
            (0..10).map(|_| random_volley(4, &mut rng)).collect();
        for v in &volleys {
            gate.step(v, &mut stream);
        }
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let batch = gate.infer_batch(&refs).unwrap();
        for (k, v) in volleys.iter().enumerate() {
            assert_eq!(batch[k], gate.infer_winner(v), "volley {k}");
        }
    }

    #[test]
    fn word_batch_after_training_uses_current_weights() {
        // Train the gate engine a little, then check the batched sweep
        // reflects the updated weights (and still matches the scalar path).
        let mut rng = Rng64::seed_from_u64(2024);
        let golden = Column::with_random_weights(4, 2, 4, TnnParams::default(), &mut rng);
        let mut gate = GateColumn::from_column(&golden).unwrap();
        let mut stream = Rng64::seed_from_u64(99);
        let volleys: Vec<Vec<SpikeTime>> =
            (0..12).map(|_| random_volley(4, &mut rng)).collect();
        for v in &volleys {
            gate.step(v, &mut stream);
        }
        let refs: Vec<&[SpikeTime]> = volleys.iter().map(|v| v.as_slice()).collect();
        let batch = gate.infer_batch(&refs).unwrap();
        for (k, v) in volleys.iter().enumerate() {
            assert_eq!(batch[k], gate.infer_winner(v), "volley {k}");
        }
    }
}
