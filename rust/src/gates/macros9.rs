//! The nine TNN7 custom macros (paper Table I / Figs. 2–10).
//!
//! Each macro exists in three coordinated forms:
//!
//! 1. a **pin interface** (`input_pins` / `output_pins`) shared by all forms;
//! 2. a **cycle-accurate behavioral model** ([`MacroState`]) used when the
//!    macro is instantiated as a hard cell in a netlist simulation — this is
//!    the function Liberate/Spectre characterised in the paper, and it is
//!    cross-checked against the golden TNN model in `rust/src/tnn/`;
//! 3. a **generic-gate expansion** ([`expand`]) — the behavioral-RTL
//!    equivalent that the ASAP7 *baseline* flow synthesizes from standard
//!    cells (what Genus saw in [6] before TNN7 existed).
//!
//! The TNN7 synthesis flow preserves instances as hard cells (form 2 +
//! Table II PPA data from [`crate::cells::tnn7lib`]); the baseline flow
//! calls [`expand`] and hands the result to the optimizer/mapper. This is
//! exactly the comparison the paper's Section IV makes.

use super::netlist::{NetBuilder, NetId};

/// Identity of one of the nine macros.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MacroKind {
    /// Fig. 2 — RNL readout: asserts response while the live weight counter
    /// is non-zero during a readout process.
    SynReadout,
    /// Fig. 3 — weight register + live down-counter + STDP inc/dec port.
    SynWeightUpdate,
    /// Fig. 4 — temporal `less_equal`: DATA propagates iff it arrives no
    /// later than INHIBIT.
    LessEqual,
    /// Fig. 5 — one-hot STDP case generation from GREATER/EIN/EOUT.
    StdpCaseGen,
    /// Fig. 6 — INC/DEC control from cases × Bernoulli draws.
    IncDec,
    /// Fig. 7 — 8:1 BRV select by 3-bit weight (bimodal stabilization).
    StabilizeFunc,
    /// Fig. 8 — input pulse → t_max-cycle spike pulse.
    SpikeGen,
    /// Fig. 9 — pulse → edge (high until gamma end).
    Pulse2Edge,
    /// Fig. 10 — edge → single-aclk pulse.
    Edge2Pulse,
}

/// All nine macros, in the paper's Fig. 2–10 order.
pub const ALL_MACROS: [MacroKind; 9] = [
    MacroKind::SynReadout,
    MacroKind::SynWeightUpdate,
    MacroKind::LessEqual,
    MacroKind::StdpCaseGen,
    MacroKind::IncDec,
    MacroKind::StabilizeFunc,
    MacroKind::SpikeGen,
    MacroKind::Pulse2Edge,
    MacroKind::Edge2Pulse,
];

impl MacroKind {
    /// Library cell name (matches the paper's Table II rows).
    pub fn cell_name(&self) -> &'static str {
        match self {
            MacroKind::SynReadout => "syn_readout",
            MacroKind::SynWeightUpdate => "syn_weight_update",
            MacroKind::LessEqual => "less_equal",
            MacroKind::StdpCaseGen => "stdp_case_gen",
            MacroKind::IncDec => "incdec",
            MacroKind::StabilizeFunc => "stabilize_func",
            MacroKind::SpikeGen => "spike_gen",
            MacroKind::Pulse2Edge => "pulse2edge",
            MacroKind::Edge2Pulse => "edge2pulse",
        }
    }

    /// Inverse of `cell_name` (None for non-macro cell names).
    pub fn from_cell_name(name: &str) -> Option<MacroKind> {
        ALL_MACROS.iter().copied().find(|m| m.cell_name() == name)
    }

    /// Input pin names (order = net order in `MacroInst::inputs`).
    pub fn input_pins(&self) -> &'static [&'static str] {
        match self {
            // live counter value + reading flag
            MacroKind::SynReadout => &["C0", "C1", "C2", "RD"],
            // spike pulse, STDP inc/dec strobes, gamma reset
            MacroKind::SynWeightUpdate => &["SPIKE", "WT_INC", "WT_DEC", "GRST"],
            MacroKind::LessEqual => &["DATA", "INHIBIT", "GRST"],
            MacroKind::StdpCaseGen => &["GREATER", "EIN", "EOUT"],
            // one-hot cases + per-case BRVs + stabilization BRV
            MacroKind::IncDec => &["C0", "C1", "C2", "C3", "BCAP", "BMIN", "BSRCH", "BBKF", "BSTAB"],
            // 3-bit select + 8 BRV streams
            MacroKind::StabilizeFunc => &["S0", "S1", "S2", "B0", "B1", "B2", "B3", "B4", "B5", "B6", "B7"],
            MacroKind::SpikeGen => &["PULSE", "GRST"],
            MacroKind::Pulse2Edge => &["PULSE", "GRST"],
            // GRST clears the edge-tracking state at the gamma boundary
            // (the gclk-synchronised reset implicit in the paper's Fig. 10).
            MacroKind::Edge2Pulse => &["EDGE", "GRST"],
        }
    }

    /// Output pin names.
    pub fn output_pins(&self) -> &'static [&'static str] {
        match self {
            MacroKind::SynReadout => &["RESP"],
            // stored weight, live counter, reading flag
            MacroKind::SynWeightUpdate => &["W0", "W1", "W2", "C0", "C1", "C2", "RD"],
            MacroKind::LessEqual => &["OUT"],
            MacroKind::StdpCaseGen => &["CASE0", "CASE1", "CASE2", "CASE3"],
            MacroKind::IncDec => &["INC", "DEC"],
            MacroKind::StabilizeFunc => &["OUT"],
            MacroKind::SpikeGen => &["SPIKE"],
            MacroKind::Pulse2Edge => &["EDGE"],
            MacroKind::Edge2Pulse => &["PULSE"],
        }
    }

    /// Same-cycle (Mealy) input dependencies of output pin `pin`, as indices
    /// into `input_pins()`. Moore pins — functions of internal state only —
    /// return an empty slice; this is what makes the STDP feedback loop
    /// (weight → stabilize_func → incdec → syn_weight_update → weight)
    /// acyclic at the combinational level: `syn_weight_update`'s outputs are
    /// registered.
    ///
    /// This table is **normative**: `eval`/`eval_word` must compute pin
    /// `pin` as a function of exactly these inputs plus state. Levelization
    /// orders pins by it, and the compiled engine
    /// ([`crate::gates::compile`]) feeds constant 0 for every *non*-dep
    /// input during its sharded settle (a non-dep net may still be
    /// settling in the same level) — an under-declared dependency here
    /// would mis-simulate in every engine.
    pub fn pin_deps(&self, pin: u8) -> &'static [usize] {
        match self {
            MacroKind::SynReadout => &[0, 1, 2, 3],
            // W pins (0–2) are registered; C/RD pins (3–6) are Mealy on
            // SPIKE only — crucially NOT on WT_INC/WT_DEC, which is what
            // keeps the STDP feedback loop combinationally acyclic.
            MacroKind::SynWeightUpdate => {
                if pin <= 2 {
                    &[]
                } else {
                    &[0]
                }
            }
            MacroKind::LessEqual => &[0],      // OUT gates DATA through state
            MacroKind::StdpCaseGen => &[0, 1, 2],
            MacroKind::IncDec => &[0, 1, 2, 3, 4, 5, 6, 7, 8],
            MacroKind::StabilizeFunc => &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10],
            MacroKind::SpikeGen => {
                let _ = pin;
                &[] // Moore: SPIKE is the registered `active` bit
            }
            MacroKind::Pulse2Edge => &[0],
            MacroKind::Edge2Pulse => &[0],
        }
    }

    /// Number of state bits in the behavioral model (0 = combinational).
    pub fn state_bits(&self) -> usize {
        match self {
            MacroKind::SynReadout => 0,
            MacroKind::SynWeightUpdate => 7, // weight[3] + counter[3] + reading
            MacroKind::LessEqual => 2,       // inh_seen + passed
            MacroKind::StdpCaseGen => 0,
            MacroKind::IncDec => 0,
            MacroKind::StabilizeFunc => 0,
            MacroKind::SpikeGen => 5, // counter[3] + active + started
            MacroKind::Pulse2Edge => 1,
            MacroKind::Edge2Pulse => 1,
        }
    }

    /// Does this macro hold state across unit cycles?
    pub fn is_sequential(&self) -> bool {
        self.state_bits() > 0
    }
}

/// Behavioral state of one macro instance during simulation.
#[derive(Clone, Debug, Default)]
pub struct MacroState {
    bits: u32,
}

impl MacroState {
    /// Raw state bits (layout documented per macro in `state_bits`).
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Construct from raw bits.
    pub fn from_bits(bits: u32) -> Self {
        MacroState { bits }
    }

    /// For `SynWeightUpdate`: the stored weight field.
    pub fn weight(&self) -> u8 {
        self.field(0, 3) as u8
    }

    /// For `SynWeightUpdate`: overwrite the stored weight field.
    pub fn set_weight(&mut self, w: u8) {
        assert!(w <= 7);
        self.set_field(0, 3, w as u32);
    }

    fn get(&self, k: usize) -> bool {
        (self.bits >> k) & 1 == 1
    }
    fn set(&mut self, k: usize, v: bool) {
        if v {
            self.bits |= 1 << k;
        } else {
            self.bits &= !(1 << k);
        }
    }
    fn field(&self, lo: usize, width: usize) -> u32 {
        (self.bits >> lo) & ((1 << width) - 1)
    }
    fn set_field(&mut self, lo: usize, width: usize, v: u32) {
        let mask = ((1u32 << width) - 1) << lo;
        self.bits = (self.bits & !mask) | ((v << lo) & mask);
    }
}

/// Combinational evaluation of a macro's outputs from its current inputs
/// and state. (Mealy: outputs may depend on same-cycle inputs, exactly like
/// the transistor-level cells.)
pub fn eval(kind: MacroKind, inputs: &[bool], st: &MacroState, out: &mut Vec<bool>) {
    out.clear();
    match kind {
        MacroKind::SynReadout => {
            let (c0, c1, c2, rd) = (inputs[0], inputs[1], inputs[2], inputs[3]);
            out.push(rd && (c0 || c1 || c2));
        }
        MacroKind::SynWeightUpdate => {
            // W pins are Moore (registered weight); C/RD pins are Mealy on
            // SPIKE so the readout starts the same cycle the spike arrives
            // (matching the golden RnlSynapse and the paper's datapath,
            // where the spike gates the counter load combinationally).
            let spike = inputs[0];
            let w = st.field(0, 3);
            let c = st.field(3, 3);
            let rd = st.get(6);
            let start = spike && !rd;
            let eff_c = if start { w } else { c };
            let eff_rd = rd || start;
            out.push(w & 1 == 1);
            out.push(w >> 1 & 1 == 1);
            out.push(w >> 2 & 1 == 1);
            out.push(eff_c & 1 == 1);
            out.push(eff_c >> 1 & 1 == 1);
            out.push(eff_c >> 2 & 1 == 1);
            out.push(eff_rd);
        }
        MacroKind::LessEqual => {
            let data = inputs[0];
            let inh_seen = st.get(0);
            let passed = st.get(1);
            out.push(data && (!inh_seen || passed));
        }
        MacroKind::StdpCaseGen => {
            let (greater, ein, eout) = (inputs[0], inputs[1], inputs[2]);
            out.push(ein && eout && !greater);
            out.push(ein && eout && greater);
            out.push(ein && !eout);
            out.push(!ein && eout);
        }
        MacroKind::IncDec => {
            let (c0, c1, c2, c3) = (inputs[0], inputs[1], inputs[2], inputs[3]);
            let (bcap, bmin, bsrch, bbkf, bstab) =
                (inputs[4], inputs[5], inputs[6], inputs[7], inputs[8]);
            out.push(((c0 && bcap) || (c2 && bsrch)) && bstab);
            out.push(((c1 && bmin) || (c3 && bbkf)) && bstab);
        }
        MacroKind::StabilizeFunc => {
            let sel = inputs[0] as usize | (inputs[1] as usize) << 1 | (inputs[2] as usize) << 2;
            out.push(inputs[3 + sel]);
        }
        MacroKind::SpikeGen => {
            out.push(st.get(3)); // active
        }
        MacroKind::Pulse2Edge => {
            out.push(inputs[0] || st.get(0));
        }
        MacroKind::Edge2Pulse => {
            out.push(inputs[0] && !st.get(0));
        }
    }
}

/// Clock-edge state update (no-op for combinational macros).
pub fn step(kind: MacroKind, inputs: &[bool], st: &mut MacroState) {
    match kind {
        MacroKind::SynWeightUpdate => {
            let (spike, inc, dec, grst) = (inputs[0], inputs[1], inputs[2], inputs[3]);
            let w_old = st.field(0, 3);
            let mut w = w_old;
            let mut c = st.field(3, 3);
            let mut rd = st.get(6);
            // STDP port: saturating unit inc/dec (INC has priority).
            if inc && w < 7 {
                w += 1;
            } else if dec && w > 0 {
                w -= 1;
            }
            if grst {
                rd = false;
                c = 0;
            } else if spike && !rd {
                // Readout starts: the Mealy eval already emitted the count
                // `w_old` this cycle, so the register captures w_old − 1.
                rd = true;
                c = w_old.saturating_sub(1);
            } else if rd && c > 0 {
                c -= 1;
            }
            st.set_field(0, 3, w);
            st.set_field(3, 3, c);
            st.set(6, rd);
        }
        MacroKind::LessEqual => {
            let (data, inhibit, grst) = (inputs[0], inputs[1], inputs[2]);
            if grst {
                st.set(0, false);
                st.set(1, false);
            } else {
                let inh_seen = st.get(0);
                let passed = st.get(1);
                // Pass latches while DATA is high and no strictly-earlier
                // INHIBIT was seen.
                st.set(1, passed || (data && !inh_seen));
                st.set(0, inh_seen || inhibit);
            }
        }
        MacroKind::SpikeGen => {
            let (pulse, grst) = (inputs[0], inputs[1]);
            let mut cnt = st.field(0, 3);
            let mut active = st.get(3);
            let mut started = st.get(4);
            if grst {
                cnt = 0;
                active = false;
                started = false;
            } else if !active && pulse && !started {
                active = true;
                started = true;
                cnt = 7;
            } else if active {
                if cnt == 0 {
                    active = false;
                } else {
                    cnt -= 1;
                }
            }
            st.set_field(0, 3, cnt);
            st.set(3, active);
            st.set(4, started);
        }
        MacroKind::Pulse2Edge => {
            let (pulse, grst) = (inputs[0], inputs[1]);
            st.set(0, if grst { false } else { st.get(0) || pulse });
        }
        MacroKind::Edge2Pulse => {
            st.set(0, inputs[0] && !inputs[1]);
        }
        _ => {} // combinational macros hold no state
    }
}

// ---------------------------------------------------------------------
// 64-lane word-level behavioral models (bit-parallel simulation)
//
// Every quantity is bit-sliced across 64 independent simulation lanes: bit
// `l` of a `u64` word is the boolean value in lane `l`. Multi-bit state
// fields (the 3-bit weight / counter of `syn_weight_update`, the spike_gen
// counter) are stored as bit-planes, and arithmetic on them is done with
// ripple carry/borrow logic over the planes — one `u64` op per plane
// instead of one bool op per lane. `eval_word`/`step_word` are exact
// word-wide transcriptions of `eval`/`step` above (proved lane-by-lane by
// the equivalence tests below).
// ---------------------------------------------------------------------

/// Number of independent stimulus lanes in a machine word.
pub const WORD_LANES: usize = 64;

/// Maximum `state_bits()` across the nine macros (`SynWeightUpdate`'s 7).
pub const MAX_STATE_BITS: usize = 7;

/// Behavioral state of one macro instance across [`WORD_LANES`] lanes:
/// plane `k` holds state bit `k` of every lane (bit `l` of `planes[k]` is
/// state bit `k` of lane `l`, matching the [`MacroState`] bit layout).
#[derive(Clone, Debug, Default)]
pub struct WordMacroState {
    planes: [u64; MAX_STATE_BITS],
}

impl WordMacroState {
    /// State-bit plane `k` across all lanes.
    pub fn plane(&self, k: usize) -> u64 {
        self.planes[k]
    }

    /// Overwrite state-bit plane `k` across all lanes.
    pub fn set_plane(&mut self, k: usize, v: u64) {
        self.planes[k] = v;
    }

    /// Replicate a scalar state into every lane.
    pub fn broadcast(st: &MacroState) -> WordMacroState {
        let mut w = WordMacroState::default();
        for k in 0..MAX_STATE_BITS {
            if st.bits() >> k & 1 == 1 {
                w.planes[k] = !0;
            }
        }
        w
    }

    /// Extract one lane as a scalar state.
    pub fn extract_lane(&self, lane: usize) -> MacroState {
        debug_assert!(lane < WORD_LANES);
        let mut bits = 0u32;
        for k in 0..MAX_STATE_BITS {
            bits |= ((self.planes[k] >> lane & 1) as u32) << k;
        }
        MacroState::from_bits(bits)
    }

    fn field3(&self, lo: usize) -> [u64; 3] {
        [self.planes[lo], self.planes[lo + 1], self.planes[lo + 2]]
    }

    fn set_field3(&mut self, lo: usize, v: [u64; 3]) {
        self.planes[lo] = v[0];
        self.planes[lo + 1] = v[1];
        self.planes[lo + 2] = v[2];
    }
}

/// Bit-sliced wrapping increment of a 3-bit field (per lane).
#[inline]
fn inc3(b: [u64; 3]) -> [u64; 3] {
    let carry0 = b[0];
    let carry1 = b[1] & carry0;
    [!b[0], b[1] ^ carry0, b[2] ^ carry1]
}

/// Bit-sliced wrapping decrement of a 3-bit field (per lane).
#[inline]
fn dec3(b: [u64; 3]) -> [u64; 3] {
    let borrow0 = !b[0];
    let borrow1 = !b[1] & borrow0;
    [!b[0], b[1] ^ borrow0, b[2] ^ borrow1]
}

/// Per-lane 3-way select: lane takes `b` where `m` is set, else `a`.
#[inline]
fn sel3(m: u64, a: [u64; 3], b: [u64; 3]) -> [u64; 3] {
    [
        (a[0] & !m) | (b[0] & m),
        (a[1] & !m) | (b[1] & m),
        (a[2] & !m) | (b[2] & m),
    ]
}

/// Word-wide combinational evaluation: 64 lanes of [`eval`] in one call.
/// `inputs[k]` carries input pin `k` for all lanes; `out[k]` returns output
/// pin `k` for all lanes.
pub fn eval_word(kind: MacroKind, inputs: &[u64], st: &WordMacroState, out: &mut Vec<u64>) {
    out.clear();
    match kind {
        MacroKind::SynReadout => {
            let (c0, c1, c2, rd) = (inputs[0], inputs[1], inputs[2], inputs[3]);
            out.push(rd & (c0 | c1 | c2));
        }
        MacroKind::SynWeightUpdate => {
            let spike = inputs[0];
            let w = st.field3(0);
            let c = st.field3(3);
            let rd = st.plane(6);
            let start = spike & !rd;
            let eff_c = sel3(start, c, w);
            out.push(w[0]);
            out.push(w[1]);
            out.push(w[2]);
            out.push(eff_c[0]);
            out.push(eff_c[1]);
            out.push(eff_c[2]);
            out.push(rd | start);
        }
        MacroKind::LessEqual => {
            let data = inputs[0];
            let inh_seen = st.plane(0);
            let passed = st.plane(1);
            out.push(data & (!inh_seen | passed));
        }
        MacroKind::StdpCaseGen => {
            let (greater, ein, eout) = (inputs[0], inputs[1], inputs[2]);
            out.push(ein & eout & !greater);
            out.push(ein & eout & greater);
            out.push(ein & !eout);
            out.push(!ein & eout);
        }
        MacroKind::IncDec => {
            let (c0, c1, c2, c3) = (inputs[0], inputs[1], inputs[2], inputs[3]);
            let (bcap, bmin, bsrch, bbkf, bstab) =
                (inputs[4], inputs[5], inputs[6], inputs[7], inputs[8]);
            out.push(((c0 & bcap) | (c2 & bsrch)) & bstab);
            out.push(((c1 & bmin) | (c3 & bbkf)) & bstab);
        }
        MacroKind::StabilizeFunc => {
            // 8:1 mux per lane as a tree of word-wide 2:1 selects.
            let sel = |s: u64, a: u64, b: u64| (a & !s) | (b & s);
            let (s0, s1, s2) = (inputs[0], inputs[1], inputs[2]);
            let m0 = sel(s0, inputs[3], inputs[4]);
            let m1 = sel(s0, inputs[5], inputs[6]);
            let m2 = sel(s0, inputs[7], inputs[8]);
            let m3 = sel(s0, inputs[9], inputs[10]);
            let n0 = sel(s1, m0, m1);
            let n1 = sel(s1, m2, m3);
            out.push(sel(s2, n0, n1));
        }
        MacroKind::SpikeGen => {
            out.push(st.plane(3)); // active
        }
        MacroKind::Pulse2Edge => {
            out.push(inputs[0] | st.plane(0));
        }
        MacroKind::Edge2Pulse => {
            out.push(inputs[0] & !st.plane(0));
        }
    }
}

/// Word-wide clock-edge state update: 64 lanes of [`step`] in one call.
pub fn step_word(kind: MacroKind, inputs: &[u64], st: &mut WordMacroState) {
    match kind {
        MacroKind::SynWeightUpdate => {
            let (spike, inc, dec, grst) = (inputs[0], inputs[1], inputs[2], inputs[3]);
            let w_old = st.field3(0);
            let c_old = st.field3(3);
            let rd_old = st.plane(6);
            // STDP port: saturating unit inc/dec, INC-branch priority (a
            // lane decrements when the inc branch was not taken, i.e. also
            // when INC was asserted at saturation — matching `step`).
            let at_max = w_old[0] & w_old[1] & w_old[2];
            let w_nz = w_old[0] | w_old[1] | w_old[2];
            let m_inc = inc & !at_max;
            let m_dec = dec & !m_inc & w_nz;
            let w_new = sel3(m_dec, sel3(m_inc, w_old, inc3(w_old)), dec3(w_old));
            // Readout counter / reading flag. Load value is the *pre-update*
            // weight minus one, floored at zero.
            let start = spike & !rd_old & !grst;
            let c_nz = c_old[0] | c_old[1] | c_old[2];
            let m_cdec = !grst & !start & rd_old & c_nz;
            let load = dec3(w_old).map(|plane| plane & w_nz);
            let c_stepped = sel3(m_cdec, c_old, dec3(c_old));
            let c_next = sel3(start, c_stepped, load).map(|plane| plane & !grst);
            let rd_new = (rd_old | start) & !grst;
            st.set_field3(0, w_new);
            st.set_field3(3, c_next);
            st.set_plane(6, rd_new);
        }
        MacroKind::LessEqual => {
            let (data, inhibit, grst) = (inputs[0], inputs[1], inputs[2]);
            let inh_seen = st.plane(0);
            let passed = st.plane(1);
            st.set_plane(1, !grst & (passed | (data & !inh_seen)));
            st.set_plane(0, !grst & (inh_seen | inhibit));
        }
        MacroKind::SpikeGen => {
            let (pulse, grst) = (inputs[0], inputs[1]);
            let cnt = st.field3(0);
            let active = st.plane(3);
            let started = st.plane(4);
            let fire = !grst & !active & pulse & !started;
            let cnt_nz = cnt[0] | cnt[1] | cnt[2];
            let in_active = !grst & !fire & active;
            let stop = in_active & !cnt_nz;
            let m_cdec = in_active & cnt_nz;
            // fire loads 7 (all planes set); otherwise decrement-or-hold.
            let held = sel3(m_cdec, cnt, dec3(cnt));
            let cnt_next = sel3(fire, held, [!0, !0, !0]).map(|plane| plane & !grst);
            st.set_field3(0, cnt_next);
            st.set_plane(3, !grst & (fire | (active & !stop)));
            st.set_plane(4, !grst & (started | fire));
        }
        MacroKind::Pulse2Edge => {
            let (pulse, grst) = (inputs[0], inputs[1]);
            st.set_plane(0, !grst & (st.plane(0) | pulse));
        }
        MacroKind::Edge2Pulse => {
            st.set_plane(0, inputs[0] & !inputs[1]);
        }
        _ => {} // combinational macros hold no state
    }
}

// ---------------------------------------------------------------------
// Generic-gate expansions (the ASAP7 baseline RTL)
//
// Note on SpikeGen timing: SPIKE is a Moore output that rises one cycle
// after PULSE arrives. The column generator applies this one-cycle encode
// latency uniformly to every input line, so relative spike times (the only
// thing TNN semantics depend on) are unaffected.
// ---------------------------------------------------------------------

/// Expand a macro into generic gates on `b`, returning its output nets.
/// Functionally identical to the behavioral model (verified by tests).
pub fn expand(kind: MacroKind, b: &mut NetBuilder, inputs: &[NetId]) -> Vec<NetId> {
    match kind {
        MacroKind::SynReadout => {
            let nz1 = b.or(inputs[0], inputs[1]);
            let nz = b.or(nz1, inputs[2]);
            vec![b.and(nz, inputs[3])]
        }
        MacroKind::SynWeightUpdate => expand_syn_weight_update(b, inputs),
        MacroKind::LessEqual => {
            // passed'   = !grst & (passed | data & !inh_seen)
            // inh_seen' = !grst & (inh_seen | inhibit)
            // OUT       = data & (!inh_seen | passed)
            expand_less_equal(b, inputs[0], inputs[1], inputs[2])
        }
        MacroKind::StdpCaseGen => {
            let (greater, ein, eout) = (inputs[0], inputs[1], inputs[2]);
            let both = b.and(ein, eout);
            let ngreater = b.not(greater);
            let c0 = b.and(both, ngreater);
            let c1 = b.and(both, greater);
            let neout = b.not(eout);
            let c2 = b.and(ein, neout);
            let nein = b.not(ein);
            let c3 = b.and(nein, eout);
            vec![c0, c1, c2, c3]
        }
        MacroKind::IncDec => {
            let (c0, c1, c2, c3) = (inputs[0], inputs[1], inputs[2], inputs[3]);
            let (bcap, bmin, bsrch, bbkf, bstab) =
                (inputs[4], inputs[5], inputs[6], inputs[7], inputs[8]);
            let i0 = b.and(c0, bcap);
            let i2 = b.and(c2, bsrch);
            let ior = b.or(i0, i2);
            let inc = b.and(ior, bstab);
            let d1 = b.and(c1, bmin);
            let d3 = b.and(c3, bbkf);
            let dor = b.or(d1, d3);
            let dec = b.and(dor, bstab);
            vec![inc, dec]
        }
        MacroKind::StabilizeFunc => {
            let (s0, s1, s2) = (inputs[0], inputs[1], inputs[2]);
            let bs = &inputs[3..11];
            // 8:1 mux as a tree of 2:1 muxes (the GDI structure of Fig. 7).
            let m0 = b.mux(s0, bs[0], bs[1]);
            let m1 = b.mux(s0, bs[2], bs[3]);
            let m2 = b.mux(s0, bs[4], bs[5]);
            let m3 = b.mux(s0, bs[6], bs[7]);
            let n0 = b.mux(s1, m0, m1);
            let n1 = b.mux(s1, m2, m3);
            vec![b.mux(s2, n0, n1)]
        }
        MacroKind::SpikeGen => expand_spike_gen(b, inputs),
        MacroKind::Pulse2Edge => {
            let (pulse, grst) = (inputs[0], inputs[1]);
            // seen' = !grst & (seen | pulse); EDGE = pulse | seen
            let seen = build_sticky(b, pulse, grst);
            vec![b.or(pulse, seen)]
        }
        MacroKind::Edge2Pulse => {
            let (edge, grst) = (inputs[0], inputs[1]);
            let prev = b.dff(edge, Some(grst), false);
            let nprev = b.not(prev);
            vec![b.and(edge, nprev)]
        }
    }
}

/// Registered sticky bit `q' = !rst & (q | set)` (see
/// [`NetBuilder::sticky_dff`]).
fn build_sticky(b: &mut NetBuilder, set: NetId, rst: NetId) -> NetId {
    b.sticky_dff(set, rst)
}

fn expand_less_equal(b: &mut NetBuilder, data: NetId, inhibit: NetId, grst: NetId) -> Vec<NetId> {
    let inh_seen = b.sticky_dff(inhibit, grst);
    let ninh = b.not(inh_seen);
    let pass_now = b.and(data, ninh);
    let passed = b.sticky_dff(pass_now, grst);
    let gate = b.or(ninh, passed);
    vec![b.and(data, gate)]
}

fn expand_syn_weight_update(b: &mut NetBuilder, inputs: &[NetId]) -> Vec<NetId> {
    let (spike, winc, wdec, grst) = (inputs[0], inputs[1], inputs[2], inputs[3]);
    // Weight register with saturating inc/dec; INC has priority over DEC.
    let w = b.dff_cell_vec(3); // forward-declared state (patched below)
    let w_nets: Vec<NetId> = w.clone();
    let at_max = b.and_tree(&w_nets);
    let nz = b.or_tree(&w_nets);
    let can_inc = b.not(at_max);
    let do_inc = b.and(winc, can_inc);
    let ndo_inc = b.not(do_inc);
    let dec_en = b.and(wdec, nz);
    let do_dec = b.and(dec_en, ndo_inc);
    let w_inc = b.inc_vec(&w_nets);
    let w_dec = b.dec_vec(&w_nets);
    let w_after_inc = b.mux_vec(do_inc, &w_nets, &w_inc);
    let w_next = b.mux_vec(do_dec, &w_after_inc, &w_dec);
    b.patch_dff_vec(&w, &w_next, None, 0);

    // Reading flag + live counter. The readout is Mealy on SPIKE: on the
    // start cycle the effective count is the stored weight, and the
    // register captures w−1 (floored at 0) for the following cycles.
    let rd = b.dff_cell_vec(1);
    let c = b.dff_cell_vec(3);
    let c_nets = c.clone();
    let c_nz = b.or_tree(&c_nets);
    let nrd = b.not(rd[0]);
    let start = b.and(spike, nrd);
    let rd_next = b.or(rd[0], start); // cleared by grst via reset pin
    b.patch_dff_vec(&rd, &[rd_next], Some(grst), 0);
    // load value: (w == 0) ? 0 : w - 1  — gate the wrapped decrement by nz.
    let w_dec_load = b.dec_vec(&w_nets);
    let c_load: Vec<NetId> = w_dec_load.iter().map(|&bit| b.and(bit, nz)).collect();
    let c_dec = b.dec_vec(&c_nets);
    let keep_dec = b.and(rd[0], c_nz);
    let c_after = b.mux_vec(keep_dec, &c_nets, &c_dec);
    let c_next = b.mux_vec(start, &c_after, &c_load);
    b.patch_dff_vec(&c, &c_next, Some(grst), 0);

    // Mealy outputs: eff_c = start ? w : c ; eff_rd = rd | start.
    let eff_c = b.mux_vec(start, &c_nets, &w_nets);
    let eff_rd = b.or(rd[0], start);
    vec![w[0], w[1], w[2], eff_c[0], eff_c[1], eff_c[2], eff_rd]
}

fn expand_spike_gen(b: &mut NetBuilder, inputs: &[NetId]) -> Vec<NetId> {
    let (pulse, grst) = (inputs[0], inputs[1]);
    let cnt = b.dff_cell_vec(3);
    let active = b.dff_cell_vec(1);
    let started = b.dff_cell_vec(1);
    let nactive = b.not(active[0]);
    let nstarted = b.not(started[0]);
    let fire = {
        let t = b.and(pulse, nactive);
        b.and(t, nstarted)
    };
    let started_next = b.or(started[0], fire);
    b.patch_dff_vec(&started, &[started_next], Some(grst), 0);
    let cnt_nets = cnt.clone();
    let cnt_nz = b.or_tree(&cnt_nets);
    let cnt_dec = b.dec_vec(&cnt_nets);
    let seven: Vec<NetId> = (0..3).map(|_| b.constant(true)).collect();
    let keep_dec = b.and(active[0], cnt_nz);
    let cnt_after = b.mux_vec(keep_dec, &cnt_nets, &cnt_dec);
    let cnt_next = b.mux_vec(fire, &cnt_after, &seven);
    b.patch_dff_vec(&cnt, &cnt_next, Some(grst), 0);
    let ncnt_nz = b.not(cnt_nz);
    let stop = b.and(active[0], ncnt_nz);
    let nstop = b.not(stop);
    let act_hold = b.and(active[0], nstop);
    let active_next = b.or(act_hold, fire);
    b.patch_dff_vec(&active, &[active_next], Some(grst), 0);
    vec![active[0]]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_tables_are_consistent() {
        for m in ALL_MACROS {
            assert!(!m.input_pins().is_empty());
            assert!(!m.output_pins().is_empty());
            assert_eq!(MacroKind::from_cell_name(m.cell_name()), Some(m));
        }
    }

    #[test]
    fn stdp_case_gen_truth_table() {
        let st = MacroState::default();
        let mut out = Vec::new();
        // (greater, ein, eout) -> expected one-hot
        let cases = [
            ((false, true, true), [true, false, false, false]),
            ((true, true, true), [false, true, false, false]),
            ((false, true, false), [false, false, true, false]),
            ((true, true, false), [false, false, true, false]),
            ((false, false, true), [false, false, false, true]),
            ((false, false, false), [false, false, false, false]),
        ];
        for ((g, ein, eout), want) in cases {
            eval(MacroKind::StdpCaseGen, &[g, ein, eout], &st, &mut out);
            assert_eq!(out.as_slice(), &want, "g={g} ein={ein} eout={eout}");
        }
    }

    #[test]
    fn incdec_gating() {
        let st = MacroState::default();
        let mut out = Vec::new();
        // capture case with BCAP=1, BSTAB=1 -> INC
        eval(
            MacroKind::IncDec,
            &[true, false, false, false, true, true, true, true, true],
            &st,
            &mut out,
        );
        assert_eq!(out, vec![true, false]);
        // BSTAB=0 blocks everything
        eval(
            MacroKind::IncDec,
            &[true, false, false, false, true, true, true, true, false],
            &st,
            &mut out,
        );
        assert_eq!(out, vec![false, false]);
        // backoff case -> DEC
        eval(
            MacroKind::IncDec,
            &[false, false, false, true, true, true, true, true, true],
            &st,
            &mut out,
        );
        assert_eq!(out, vec![false, true]);
    }

    #[test]
    fn stabilize_func_selects() {
        let st = MacroState::default();
        let mut out = Vec::new();
        for sel in 0..8usize {
            let mut inputs = vec![sel & 1 == 1, sel >> 1 & 1 == 1, sel >> 2 & 1 == 1];
            let mut bs = vec![false; 8];
            bs[sel] = true;
            inputs.extend(bs);
            eval(MacroKind::StabilizeFunc, &inputs, &st, &mut out);
            assert_eq!(out, vec![true], "sel={sel}");
        }
    }

    #[test]
    fn syn_weight_update_behavioral_matches_rnl_synapse() {
        use crate::tnn::synapse::RnlSynapse;
        // Drive both with the same spike schedule; compare readout.
        for w0 in 0..=7u8 {
            for x in 0..8u32 {
                let mut st = MacroState::default();
                st.set_field(0, 3, w0 as u32);
                let mut golden = RnlSynapse::new(w0, 7);
                let mut out = Vec::new();
                for t in 0..20u32 {
                    let spike = t == x;
                    // macro eval: readout = RD && counter != 0 (SynReadout
                    // consumes C/RD outputs). Counter visible via eval.
                    eval(MacroKind::SynWeightUpdate, &[spike, false, false, false], &st, &mut out);
                    let c = out[3] as u32 | (out[4] as u32) << 1 | (out[5] as u32) << 2;
                    let rd = out[6];
                    let resp_macro = rd && c != 0;
                    let resp_golden = golden.tick(spike);
                    step(MacroKind::SynWeightUpdate, &[spike, false, false, false], &mut st);
                    assert_eq!(
                        resp_macro, resp_golden,
                        "w={w0} x={x} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn syn_weight_update_stdp_port_saturates() {
        let mut st = MacroState::default();
        st.set_field(0, 3, 7);
        step(MacroKind::SynWeightUpdate, &[false, true, false, false], &mut st);
        assert_eq!(st.field(0, 3), 7, "inc saturates at 7");
        st.set_field(0, 3, 0);
        step(MacroKind::SynWeightUpdate, &[false, false, true, false], &mut st);
        assert_eq!(st.field(0, 3), 0, "dec saturates at 0");
        step(MacroKind::SynWeightUpdate, &[false, true, false, false], &mut st);
        assert_eq!(st.field(0, 3), 1);
    }

    #[test]
    fn less_equal_behavioral_temporal_semantics() {
        // data at t=2, inhibit at t=4 -> passes.
        assert!(le_passes(2, Some(4)));
        // data at t=4, inhibit at t=2 -> blocked.
        assert!(!le_passes(4, Some(2)));
        // tie passes.
        assert!(le_passes(3, Some(3)));
        // no inhibit -> passes.
        assert!(le_passes(5, None));
    }

    fn le_passes(data_t: u32, inh_t: Option<u32>) -> bool {
        let mut st = MacroState::default();
        let mut out = Vec::new();
        let mut passed = false;
        for t in 0..10u32 {
            let data = t >= data_t; // edge signal
            let inh = inh_t.map_or(false, |it| t >= it);
            eval(MacroKind::LessEqual, &[data, inh, false], &st, &mut out);
            passed |= out[0];
            step(MacroKind::LessEqual, &[data, inh, false], &mut st);
        }
        passed
    }

    #[test]
    fn pulse2edge_and_edge2pulse_roundtrip() {
        let mut p2e = MacroState::default();
        let mut e2p = MacroState::default();
        let mut out = Vec::new();
        let mut edge_hist = Vec::new();
        let mut pulse_hist = Vec::new();
        for t in 0..8u32 {
            let pulse = t == 3; // 1-cycle pulse at t=3
            eval(MacroKind::Pulse2Edge, &[pulse, false], &p2e, &mut out);
            let edge = out[0];
            edge_hist.push(edge);
            eval(MacroKind::Edge2Pulse, &[edge, false], &e2p, &mut out);
            pulse_hist.push(out[0]);
            step(MacroKind::Pulse2Edge, &[pulse, false], &mut p2e);
            step(MacroKind::Edge2Pulse, &[edge, false], &mut e2p);
        }
        // edge rises at t=3 and stays; regenerated pulse is exactly t=3.
        assert_eq!(edge_hist, vec![false, false, false, true, true, true, true, true]);
        assert_eq!(pulse_hist, vec![false, false, false, true, false, false, false, false]);
    }

    #[test]
    fn word_models_match_scalar_models_lane_for_lane() {
        // For every macro: drive 64 independent random stimulus streams
        // through the word-level model and, lane by lane, through the scalar
        // model; outputs and post-step states must agree exactly, including
        // across periodic gamma resets.
        use crate::util::Rng64;
        for kind in ALL_MACROS {
            let n_in = kind.input_pins().len();
            let mut rng = Rng64::seed_from_u64(0xA11CE ^ kind as u64);
            let mut wst = WordMacroState::default();
            let mut sst: Vec<MacroState> = (0..WORD_LANES).map(|_| MacroState::default()).collect();
            let mut wout = Vec::new();
            let mut sout = Vec::new();
            let grst_pin = kind.input_pins().iter().position(|&p| p == "GRST");
            for cycle in 0..400u32 {
                let inputs: Vec<u64> = (0..n_in)
                    .map(|i| {
                        if Some(i) == grst_pin && cycle % 16 == 15 {
                            // gamma boundary: reset half the lanes, leave
                            // the rest running (exercises both phases).
                            rng.next_u64() | 0xFFFF_FFFF
                        } else {
                            rng.next_u64() & rng.next_u64() // p = 1/4
                        }
                    })
                    .collect();
                eval_word(kind, &inputs, &wst, &mut wout);
                for lane in 0..WORD_LANES {
                    let lane_in: Vec<bool> =
                        inputs.iter().map(|w| w >> lane & 1 == 1).collect();
                    eval(kind, &lane_in, &sst[lane], &mut sout);
                    for (pin, &w) in wout.iter().enumerate() {
                        assert_eq!(
                            w >> lane & 1 == 1,
                            sout[pin],
                            "{kind:?} pin {pin} lane {lane} cycle {cycle}"
                        );
                    }
                    step(kind, &lane_in, &mut sst[lane]);
                }
                step_word(kind, &inputs, &mut wst);
                for lane in 0..WORD_LANES {
                    assert_eq!(
                        wst.extract_lane(lane).bits(),
                        sst[lane].bits(),
                        "{kind:?} state lane {lane} cycle {cycle}"
                    );
                }
            }
        }
    }

    #[test]
    fn word_state_broadcast_and_extract_roundtrip() {
        let mut st = MacroState::default();
        st.set_weight(5);
        let w = WordMacroState::broadcast(&st);
        for lane in [0, 1, 31, 63] {
            assert_eq!(w.extract_lane(lane).bits(), st.bits());
            assert_eq!(w.extract_lane(lane).weight(), 5);
        }
        let mut w2 = WordMacroState::default();
        w2.set_plane(0, 1 << 7); // weight bit 0 set only in lane 7
        assert_eq!(w2.extract_lane(7).weight(), 1);
        assert_eq!(w2.extract_lane(6).weight(), 0);
        assert_eq!(w2.plane(0), 1 << 7);
    }

    #[test]
    fn spike_gen_emits_8_cycle_pulse_once() {
        let mut st = MacroState::default();
        let mut out = Vec::new();
        let mut hist = Vec::new();
        for t in 0..16u32 {
            let pulse = (3..=5).contains(&t); // wide input pulse
            eval(MacroKind::SpikeGen, &[pulse, false], &st, &mut out);
            hist.push(out[0]);
            step(MacroKind::SpikeGen, &[pulse, false], &mut st);
        }
        let high: Vec<usize> = hist
            .iter()
            .enumerate()
            .filter(|(_, &h)| h)
            .map(|(i, _)| i)
            .collect();
        // Moore output: rises the cycle after the pulse arrives, 8 wide.
        assert_eq!(high, (4..12).collect::<Vec<_>>());
    }
}
