//! Gate-level hardware substrate.
//!
//! This module replaces the schematic/netlist layer of the paper's
//! Cadence-based flow (see DESIGN.md §5): a generic gate-level netlist IR
//! with a structural builder ([`netlist`]), a levelized synchronous
//! simulator used for functional verification and switching-activity
//! extraction ([`sim`]), the nine TNN7 macros — each with a cycle-accurate
//! behavioral model *and* a generic-gate expansion ([`macros9`]) — and the
//! structural generator that assembles full p×q TNN columns out of them
//! ([`column_design`]).

pub mod column_design;
pub mod macros9;
pub mod netlist;
pub mod sim;

pub use macros9::MacroKind;
pub use netlist::{Gate, NetBuilder, NetId, Netlist};
pub use sim::Simulator;
