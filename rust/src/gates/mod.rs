//! Gate-level hardware substrate.
//!
//! This module replaces the schematic/netlist layer of the paper's
//! Cadence-based flow (see `docs/ARCHITECTURE.md`): a generic gate-level netlist IR
//! with a structural builder ([`netlist`]), **three** levelized synchronous
//! simulation engines used for functional verification and
//! switching-activity extraction — the scalar reference engine ([`sim`]),
//! the 64-lane bit-parallel interpreter ([`wordsim`]), and the compiled
//! netlist program ([`compile`]: multi-word lane blocks + threaded level
//! execution), selectable via [`SimBackend`] — the nine TNN7 macros, each
//! with a cycle-accurate behavioral model (scalar *and* word-level) plus a
//! generic-gate expansion ([`macros9`]), the structural generator that
//! assembles full p×q TNN columns out of them ([`column_design`]), and the
//! gate-level *column engine* that runs real workloads on the macro
//! netlist behind the `coordinator::Engine` interface ([`gate_engine`]),
//! plus seeded deterministic fault-injection campaigns (stuck-at, SEU)
//! that run on all three engines with bit-identical verdicts ([`fault`]),
//! and the netlist optimizer pass pipeline — constant propagation,
//! dead-logic elimination, locality renumbering — that specializes the
//! compiled program for inference workloads ([`opt`]), and the concurrent
//! evicting artifact cache that shares built designs and compiled programs
//! across engines, sweeps, fault campaigns and the serving layer
//! ([`artifact_cache`]), and the structural-Verilog interchange layer —
//! deterministic synthesizable emission plus a parser that rebuilds the
//! exact netlist, round-trip-proven bit-identical on every backend
//! ([`verilog`]).

pub mod artifact_cache;
pub mod column_design;
pub mod compile;
pub mod fault;
pub mod gate_engine;
pub mod macros9;
pub mod netlist;
pub mod opt;
pub mod sim;
pub mod verilog;
pub mod wordsim;

pub use artifact_cache::{
    cache_stats, design_handle, program_handle, CacheStats, ColumnProgram, ShardedLruCache,
};
pub use compile::{CompiledProgram, CompiledSim};
pub use fault::{CampaignResult, FaultClass, FaultCounts, FaultOutcome, GateFault};
pub use gate_engine::GateColumn;
pub use macros9::MacroKind;
pub use netlist::{Gate, NetBuilder, NetId, Netlist};
pub use opt::{KeepSet, NetRemap, OptAssumptions, OptLevel, Pass, PassPipeline};
pub use sim::Simulator;
pub use verilog::{ParsedModule, VerilogError};
pub use wordsim::{WordSimulator, LANES};

/// Seeded (p, q, seed) geometry matrix shared by the word-simulator lane-0
/// equivalence tests and the three-engine conformance harness
/// (`harness::conformance`): one flagship column (the 82×2 TwoLeadECG
/// design of Fig. 13) plus small geometries that cover tall, wide and
/// single-neuron corner shapes.
pub const CONFORMANCE_GEOMETRIES: [(usize, usize, u64); 4] = [
    (82, 2, 0xBEEF),
    (16, 3, 0xA11CE),
    (7, 4, 0x5EED),
    (33, 1, 0xD00D),
];

use crate::util::Rng64;

/// Default lane-block width `W` for the compiled backend (`sim_words`
/// config key): `W × 64` lanes per pass.
pub const DEFAULT_SIM_WORDS: usize = 2;

/// Which gate-level simulation engine collects toggle statistics.
///
/// All engines implement identical synchronous semantics: lane 0 of the
/// bit-parallel interpreter is bit-for-bit the scalar engine, and every
/// word of the compiled engine is bit-for-bit an independent bit-parallel
/// run (enforced by `tests/compiled_sim.rs`). The bit-parallel interpreter
/// simulates 64 independent stimulus lanes per pass; the compiled engine
/// lowers the schedule to a flat instruction stream over `words × 64`-lane
/// blocks and shards each level across worker threads (toggle counts
/// bit-exact at any thread count).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimBackend {
    /// One boolean per net per cycle — the reference engine.
    Scalar,
    /// 64 stimulus lanes packed into one `u64` per net (interpreter).
    BitParallel64,
    /// Compiled netlist program ([`compile::CompiledSim`]).
    Compiled {
        /// Lane-block width `W`: `u64` words per net, `W × 64` lanes/pass.
        words: usize,
        /// Settle worker threads (0 = machine parallelism, 1 = inline).
        threads: usize,
    },
}

impl SimBackend {
    /// Display name (`scalar` / `bit-parallel-64` / `compiled`).
    pub fn name(&self) -> &'static str {
        match self {
            SimBackend::Scalar => "scalar",
            SimBackend::BitParallel64 => "bit-parallel-64",
            SimBackend::Compiled { .. } => "compiled",
        }
    }

    /// Parse a CLI/config spelling: `scalar`, `bit-parallel-64` (alias
    /// `word`), or `compiled` (lane-block width [`DEFAULT_SIM_WORDS`],
    /// inline execution — callers override via the `sim_words` / `threads`
    /// config keys, see `RunConfig::resolved_sim_backend`).
    pub fn parse(s: &str) -> crate::Result<SimBackend> {
        match s {
            "scalar" => Ok(SimBackend::Scalar),
            "bit-parallel-64" | "word" => Ok(SimBackend::BitParallel64),
            "compiled" => Ok(SimBackend::Compiled {
                words: DEFAULT_SIM_WORDS,
                threads: 1,
            }),
            other => anyhow::bail!(
                "unknown sim backend {other:?} (scalar|bit-parallel-64|compiled)"
            ),
        }
    }
}

/// The single α definition shared by both engines and [`ToggleReport`]:
/// total toggles per net per simulated cycle.
pub(crate) fn mean_activity(toggles: &[u64], cycles: u64) -> f64 {
    if cycles == 0 || toggles.is_empty() {
        return 0.0;
    }
    let total: u64 = toggles.iter().sum();
    total as f64 / (cycles as f64 * toggles.len() as f64)
}

/// Per-net toggle statistics from a randomized toggle-collection run.
#[derive(Clone, Debug)]
pub struct ToggleReport {
    /// Backend that produced the statistics.
    pub backend: SimBackend,
    /// Per-net toggle counts (summed over every simulated cycle; for the
    /// bit-parallel backend, over every lane of every pass).
    pub toggles: Vec<u64>,
    /// Simulated cycles (lane-cycles for the bit-parallel backend).
    pub cycles: u64,
}

impl ToggleReport {
    /// Average toggle rate over all nets (toggles per net per cycle) — the
    /// α activity factor of the dynamic power model.
    pub fn activity(&self) -> f64 {
        mean_activity(&self.toggles, self.cycles)
    }

    /// Per-net toggle rate (toggles per cycle).
    pub fn alpha(&self) -> Vec<f64> {
        let c = self.cycles.max(1) as f64;
        self.toggles.iter().map(|&t| t as f64 / c).collect()
    }
}

/// Collect per-net toggle statistics by driving `nl` with a reproducible
/// TNN-shaped pseudo-random workload: primary inputs are sparse Bernoulli
/// pulse streams (p = 1/8), except inputs named `"GRST"`, which receive a
/// sparser Bernoulli(1/16) gamma-boundary strobe. Both backends use the
/// same stimulus distribution, so their toggle statistics are directly
/// comparable (and are cross-checked in tests and benches).
///
/// `cycles` is the number of simulated cycles; the word-wide backends run
/// `ceil(cycles / lanes_per_pass)` passes (64 lane-cycles per word each),
/// so they may simulate up to `lanes_per_pass − 1` extra lane-cycles —
/// `ToggleReport::cycles` always records what was actually simulated.
///
/// The compiled backend with `words = 1` consumes the rng in exactly the
/// bit-parallel interpreter's order, so its toggle report is bit-identical
/// to `BitParallel64`'s (the differential tests pin this).
pub fn collect_toggles(
    nl: &Netlist,
    cycles: u64,
    seed: u64,
    backend: SimBackend,
) -> Result<ToggleReport, String> {
    let mut rng = Rng64::seed_from_u64(seed);
    let inputs = stimulus_inputs(nl);
    match backend {
        SimBackend::Scalar => {
            let mut sim = Simulator::new(nl)?;
            for _ in 0..cycles {
                for &(id, is_grst) in &inputs {
                    let p = if is_grst { 0.0625 } else { 0.125 };
                    sim.set_input_net(id, rng.gen_bool(p));
                }
                sim.cycle();
            }
            Ok(ToggleReport {
                backend,
                toggles: sim.toggles().to_vec(),
                cycles: sim.cycles(),
            })
        }
        SimBackend::BitParallel64 => {
            let mut sim = WordSimulator::new(nl)?;
            let passes = cycles.div_ceil(LANES as u64);
            for _ in 0..passes {
                for &(id, is_grst) in &inputs {
                    // Bernoulli(1/8) / Bernoulli(1/16) per lane via AND of
                    // independent uniform words.
                    let mut w = rng.next_u64() & rng.next_u64() & rng.next_u64();
                    if is_grst {
                        w &= rng.next_u64();
                    }
                    sim.set_input_net(id, w);
                }
                sim.cycle();
            }
            Ok(ToggleReport {
                backend,
                toggles: sim.toggles().to_vec(),
                cycles: sim.lane_cycles(),
            })
        }
        SimBackend::Compiled { words, threads } => {
            let mut sim = CompiledSim::new(nl, words, threads)?;
            let lanes = (words * LANES) as u64;
            let passes = cycles.div_ceil(lanes);
            for _ in 0..passes {
                for &(id, is_grst) in &inputs {
                    // Same per-word draw rule (and, for words = 1, the
                    // same draw order) as the bit-parallel interpreter.
                    for w in 0..words {
                        let mut word = rng.next_u64() & rng.next_u64() & rng.next_u64();
                        if is_grst {
                            word &= rng.next_u64();
                        }
                        sim.set_input_net(id, w, word);
                    }
                }
                sim.cycle();
            }
            Ok(ToggleReport {
                backend,
                toggles: sim.toggles().to_vec(),
                cycles: sim.lane_cycles(),
            })
        }
    }
}

/// The one stimulus plan shared by every [`collect_toggles`] backend:
/// each primary input paired with its "is the GRST gamma strobe" flag
/// (which selects the sparser Bernoulli rate). Resolved once per run —
/// no backend touches a name map in its pass loop.
fn stimulus_inputs(nl: &Netlist) -> Vec<(NetId, bool)> {
    nl.inputs
        .iter()
        .map(|(name, id)| (*id, name == "GRST"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::column_design::{build_column, BrvSource};
    use super::*;

    #[test]
    fn backends_report_comparable_activity_on_a_column() {
        // Note on sample sizes: nets derived from the on-column LFSR are
        // identical across lanes (the LFSR sees no per-lane stimulus), so
        // the word backend's effective sample count for them is the number
        // of word passes (cycles/64), not lane-cycles — hence the long run.
        let d = build_column(6, 2, 6, BrvSource::Lfsr);
        let s = collect_toggles(&d.netlist, 16384, 3, SimBackend::Scalar).unwrap();
        let w = collect_toggles(&d.netlist, 16384, 3, SimBackend::BitParallel64).unwrap();
        assert_eq!(s.cycles, 16384);
        assert_eq!(w.cycles, 16384);
        let (a_s, a_w) = (s.activity(), w.activity());
        assert!(a_s > 0.0 && a_w > 0.0);
        assert!((a_s - a_w).abs() < 0.05, "scalar α {a_s:.4} vs word α {a_w:.4}");
        // Per-net rates agree within sampling noise on busy nets.
        let (al_s, al_w) = (s.alpha(), w.alpha());
        for i in 0..al_s.len() {
            assert!(
                (al_s[i] - al_w[i]).abs() < 0.25,
                "net {i}: scalar {} vs word {}",
                al_s[i],
                al_w[i]
            );
        }
    }

    #[test]
    fn toggle_report_activity_math() {
        let r = ToggleReport {
            backend: SimBackend::Scalar,
            toggles: vec![10, 0, 30],
            cycles: 10,
        };
        assert!((r.activity() - 40.0 / 30.0).abs() < 1e-12);
        assert_eq!(r.alpha(), vec![1.0, 0.0, 3.0]);
        assert_eq!(r.backend.name(), "scalar");
        assert_eq!(SimBackend::BitParallel64.name(), "bit-parallel-64");
        assert_eq!(
            SimBackend::Compiled { words: 4, threads: 2 }.name(),
            "compiled"
        );
    }

    #[test]
    fn sim_backend_parses_all_spellings() {
        assert_eq!(SimBackend::parse("scalar").unwrap(), SimBackend::Scalar);
        assert_eq!(
            SimBackend::parse("bit-parallel-64").unwrap(),
            SimBackend::BitParallel64
        );
        assert_eq!(SimBackend::parse("word").unwrap(), SimBackend::BitParallel64);
        assert_eq!(
            SimBackend::parse("compiled").unwrap(),
            SimBackend::Compiled { words: DEFAULT_SIM_WORDS, threads: 1 }
        );
        assert!(SimBackend::parse("vcs").is_err());
    }

    #[test]
    fn compiled_w1_toggle_report_is_bit_identical_to_interpreter() {
        // words = 1 consumes the rng in the interpreter's exact order, so
        // the two reports must agree toggle for toggle — the keystone of
        // the compiled engine's bit-exactness contract.
        let d = build_column(5, 2, 6, BrvSource::Lfsr);
        let w = collect_toggles(&d.netlist, 2048, 11, SimBackend::BitParallel64).unwrap();
        let c = collect_toggles(
            &d.netlist,
            2048,
            11,
            SimBackend::Compiled { words: 1, threads: 2 },
        )
        .unwrap();
        assert_eq!(c.cycles, w.cycles);
        assert_eq!(c.toggles, w.toggles);
    }

    #[test]
    fn compiled_multiword_backend_measures_comparable_activity() {
        let d = build_column(6, 2, 6, BrvSource::Lfsr);
        let w = collect_toggles(&d.netlist, 16384, 3, SimBackend::BitParallel64).unwrap();
        let c = collect_toggles(
            &d.netlist,
            16384,
            3,
            SimBackend::Compiled { words: 4, threads: 2 },
        )
        .unwrap();
        assert_eq!(c.cycles, 16384, "64 passes x 4 words x 64 lanes");
        let (a_w, a_c) = (w.activity(), c.activity());
        assert!(a_c > 0.0);
        assert!((a_w - a_c).abs() < 0.05, "word α {a_w:.4} vs compiled α {a_c:.4}");
    }
}
