//! Generic gate-level netlist IR and structural builder.
//!
//! A netlist is a DAG of single-output gates (the output net of gate `i` is
//! `NetId(i)`), plus a side table of multi-output **macro instances** whose
//! output pins appear as [`Gate::MacroOut`] nodes. Sequential elements
//! ([`Gate::Dff`]) and macro instances form the state boundary; everything
//! else is combinational.
//!
//! The builder doubles as the "RTL elaboration" front-end of the synthesis
//! flow: designs — including the full TNN column — are
//! described structurally through it (vectors, adders, comparators, trees),
//! producing the generic netlist that [`crate::synth`] optimizes and maps
//! onto a cell library (see `docs/ARCHITECTURE.md` §"Module map").

use super::macros9::MacroKind;
use std::collections::HashMap;

/// Index of a gate == id of its output net.
pub type NetId = u32;

/// Sentinel for a forward-declared (not yet patched) DFF data input.
pub const PENDING_D: NetId = u32::MAX;

/// A single-output generic gate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Gate {
    /// Primary input (name registered in `Netlist::inputs`).
    Input,
    /// Constant 0/1.
    Const(bool),
    /// Identity buffer — also the forward-wire placeholder (`wire()` /
    /// `connect()`): created with `PENDING_D` and patched later.
    Buf(NetId),
    /// Inverter.
    Not(NetId),
    /// 2-input AND.
    And(NetId, NetId),
    /// 2-input OR.
    Or(NetId, NetId),
    /// 2-input XOR.
    Xor(NetId, NetId),
    /// `sel ? b : a`.
    Mux(NetId, NetId, NetId),
    /// D flip-flop with synchronous reset-to-`init` when `rst` is high.
    /// `rst == None` means never reset. Clock is implicit (single domain).
    Dff {
        d: NetId,
        rst: Option<NetId>,
        init: bool,
    },
    /// Output pin `pin` of macro instance `inst`.
    MacroOut { inst: u32, pin: u8 },
}

impl Gate {
    /// Is this a state element (value produced at clock edges)?
    pub fn is_state(&self) -> bool {
        matches!(self, Gate::Dff { .. } | Gate::MacroOut { .. })
    }

    /// Combinational fan-in nets (empty for inputs/consts/state outputs).
    pub fn comb_fanin(&self, out: &mut Vec<NetId>) {
        out.clear();
        match *self {
            Gate::Buf(a) | Gate::Not(a) => out.push(a),
            Gate::And(a, b) | Gate::Or(a, b) | Gate::Xor(a, b) => {
                out.push(a);
                out.push(b);
            }
            Gate::Mux(s, a, b) => {
                out.push(s);
                out.push(a);
                out.push(b);
            }
            _ => {}
        }
    }
}

/// A hard-macro instance (one of the nine TNN7 macros).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MacroInst {
    /// Which of the nine TNN7 macros is instantiated.
    pub kind: MacroKind,
    /// Input nets, in the pin order defined by `kind.input_pins()`.
    pub inputs: Vec<NetId>,
    /// Output pin net ids (`Gate::MacroOut` nodes), in `kind.output_pins()`
    /// order.
    pub outputs: Vec<NetId>,
}

/// A gate-level netlist.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Netlist {
    /// Design name (labels reports and simulators).
    pub name: String,
    /// All gates; index == output [`NetId`].
    pub gates: Vec<Gate>,
    /// Hard-macro instances (referenced by [`Gate::MacroOut`] nodes).
    pub macros: Vec<MacroInst>,
    /// Primary inputs: (name, net).
    pub inputs: Vec<(String, NetId)>,
    /// Primary outputs: (name, net).
    pub outputs: Vec<(String, NetId)>,
}

impl Netlist {
    /// The gate driving net `id`.
    pub fn gate(&self, id: NetId) -> &Gate {
        &self.gates[id as usize]
    }

    /// Total net (gate) count.
    pub fn len(&self) -> usize {
        self.gates.len()
    }

    /// Is the netlist empty?
    pub fn is_empty(&self) -> bool {
        self.gates.is_empty()
    }

    /// Count of gates by coarse class: (comb, dff, macro_pins, inputs+consts).
    pub fn census(&self) -> Census {
        let mut c = Census::default();
        for g in &self.gates {
            match g {
                Gate::Input | Gate::Const(_) => c.sources += 1,
                Gate::Dff { .. } => c.dffs += 1,
                Gate::MacroOut { .. } => c.macro_pins += 1,
                _ => c.comb += 1,
            }
        }
        c.macros = self.macros.len();
        c
    }

    /// Combinational fan-in of net `id`, including **Mealy** macro-output
    /// dependencies: a `MacroOut` pin depends (same-cycle) on the subset of
    /// the macro's input nets declared by `MacroKind::pin_deps` — Moore pins
    /// (pure state) declare none, which is what breaks the apparent cycles
    /// in the STDP feedback path (weight → stabilize → incdec → weight).
    pub fn comb_fanin_full(&self, id: NetId, out: &mut Vec<NetId>) {
        let g = &self.gates[id as usize];
        if let Gate::MacroOut { inst, pin } = *g {
            out.clear();
            let m = &self.macros[inst as usize];
            for &dep in m.kind.pin_deps(pin) {
                out.push(m.inputs[dep]);
            }
        } else {
            g.comb_fanin(out);
        }
    }

    /// Level-packed topological schedule of combinational evaluation:
    /// `levels[k]` holds every comb net (including Mealy macro pins) whose
    /// longest chain of comb fan-ins has length `k`. Source and
    /// state-element nets are not scheduled (they change only at inputs /
    /// clock edges). Nets within a level are sorted by id, which both makes
    /// the schedule deterministic and keeps the simulators' inner loops
    /// walking memory mostly forward; levels are also the natural split
    /// points for a future thread-per-level evaluation. Errors on a
    /// combinational cycle.
    pub fn levelize_buckets(&self) -> Result<Vec<Vec<NetId>>, String> {
        let n = self.gates.len();
        // A node participates in comb evaluation iff it has comb fan-ins.
        let mut is_comb = vec![false; n];
        let mut fin = Vec::new();
        for i in 0..n {
            self.comb_fanin_full(i as NetId, &mut fin);
            is_comb[i] = !fin.is_empty();
        }
        let mut indegree = vec![0u32; n];
        let mut fanout: Vec<Vec<NetId>> = vec![Vec::new(); n];
        let mut comb_count = 0usize;
        for i in 0..n {
            if !is_comb[i] {
                continue;
            }
            comb_count += 1;
            self.comb_fanin_full(i as NetId, &mut fin);
            for &src in &fin {
                if is_comb[src as usize] {
                    indegree[i] += 1;
                    fanout[src as usize].push(i as NetId);
                }
            }
        }
        let mut frontier: Vec<NetId> = (0..n as NetId)
            .filter(|&i| is_comb[i as usize] && indegree[i as usize] == 0)
            .collect();
        let mut levels: Vec<Vec<NetId>> = Vec::new();
        let mut scheduled = 0usize;
        while !frontier.is_empty() {
            scheduled += frontier.len();
            let mut next = Vec::new();
            for &id in &frontier {
                for &succ in &fanout[id as usize] {
                    indegree[succ as usize] -= 1;
                    if indegree[succ as usize] == 0 {
                        next.push(succ);
                    }
                }
            }
            next.sort_unstable();
            levels.push(std::mem::replace(&mut frontier, next));
        }
        if scheduled != comb_count {
            return Err(format!(
                "combinational cycle: {} of {} comb gates unordered",
                comb_count - scheduled,
                comb_count
            ));
        }
        Ok(levels)
    }

    /// Flat topological order of combinational evaluation (the level-packed
    /// schedule of [`Self::levelize_buckets`] flattened level by level).
    pub fn levelize(&self) -> Result<Vec<NetId>, String> {
        Ok(self.levelize_buckets()?.into_iter().flatten().collect())
    }

    /// Resolve a batch of primary-input names to net ids in one pass —
    /// the bulk binder hot paths use so steady-state stimulus never
    /// touches a name map (see the per-call, panicking `set_input` on the
    /// simulators). Errors on unknown names.
    pub fn bind_inputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        bind_ports(&self.inputs, names, "input")
    }

    /// Resolve a batch of primary-output names to net ids in one pass.
    /// Errors on unknown names.
    pub fn bind_outputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        bind_ports(&self.outputs, names, "output")
    }

    /// Fanout count per net (used by timing/power models).
    pub fn fanout_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.gates.len()];
        let mut fin = Vec::new();
        for g in &self.gates {
            g.comb_fanin(&mut fin);
            for &src in &fin {
                counts[src as usize] += 1;
            }
            if let Gate::Dff { d, rst, .. } = *g {
                counts[d as usize] += 1;
                if let Some(r) = rst {
                    counts[r as usize] += 1;
                }
            }
        }
        for m in &self.macros {
            for &src in &m.inputs {
                counts[src as usize] += 1;
            }
        }
        for (_, net) in &self.outputs {
            counts[*net as usize] += 1;
        }
        counts
    }

    /// Structural pre-flight check, run before every compile and by the
    /// fault-injection campaigns (which refuse to inject into unverified
    /// netlists): every net reference must be in range and patched (no
    /// dangling `PENDING_D`), every macro pin table must be consistent with
    /// its `MacroOut` nodes (each pin driven by exactly the node that claims
    /// it — the multiple-driver check in this single-output-per-net IR),
    /// ports must resolve, and the combinational core must be acyclic.
    /// Errors name the offending gate / instance.
    pub fn verify(&self) -> Result<(), String> {
        let n = self.gates.len();
        let bad = |src: NetId| src == PENDING_D || src as usize >= n;
        let describe = |src: NetId| {
            if src == PENDING_D {
                "is dangling (never patched)".to_string()
            } else {
                format!("is out of range (netlist has {n} nets)")
            }
        };
        let mut fin = Vec::new();
        for (i, g) in self.gates.iter().enumerate() {
            g.comb_fanin(&mut fin);
            if let Gate::Dff { d, rst, .. } = *g {
                fin.push(d);
                if let Some(r) = rst {
                    fin.push(r);
                }
            }
            for &src in &fin {
                if bad(src) {
                    return Err(format!(
                        "{}: gate {i} ({g:?}): fan-in net {src} {}",
                        self.name,
                        describe(src)
                    ));
                }
            }
            if let Gate::MacroOut { inst, pin } = *g {
                let m = self.macros.get(inst as usize).ok_or_else(|| {
                    format!(
                        "{}: gate {i}: MacroOut references missing macro instance {inst}",
                        self.name
                    )
                })?;
                if m.outputs.get(pin as usize).copied() != Some(i as NetId) {
                    return Err(format!(
                        "{}: gate {i}: {:?} instance {inst} pin {pin} is not the net its \
                         pin table claims ({:?}) — multiple or missing driver",
                        self.name,
                        m.kind,
                        m.outputs.get(pin as usize)
                    ));
                }
            }
        }
        for (inst, m) in self.macros.iter().enumerate() {
            if m.inputs.len() != m.kind.input_pins().len() {
                return Err(format!(
                    "{}: macro {inst} ({:?}): {} input nets for {} pins",
                    self.name,
                    m.kind,
                    m.inputs.len(),
                    m.kind.input_pins().len()
                ));
            }
            if m.outputs.len() != m.kind.output_pins().len() {
                return Err(format!(
                    "{}: macro {inst} ({:?}): {} output nets for {} pins",
                    self.name,
                    m.kind,
                    m.outputs.len(),
                    m.kind.output_pins().len()
                ));
            }
            for (k, &src) in m.inputs.iter().enumerate() {
                if bad(src) {
                    return Err(format!(
                        "{}: macro {inst} ({:?}) input pin {k}: net {src} {}",
                        self.name,
                        m.kind,
                        describe(src)
                    ));
                }
            }
            for (k, &net) in m.outputs.iter().enumerate() {
                let owns = (net as usize) < n
                    && matches!(self.gates[net as usize], Gate::MacroOut { inst: gi, pin }
                        if gi as usize == inst && pin as usize == k);
                if !owns {
                    return Err(format!(
                        "{}: macro {inst} ({:?}) output pin {k}: net {net} is not its own \
                         MacroOut node — multiple drivers or stolen pin",
                        self.name, m.kind
                    ));
                }
            }
        }
        for (name, id) in &self.inputs {
            if (*id as usize) >= n || !matches!(self.gates[*id as usize], Gate::Input) {
                return Err(format!(
                    "{}: input port {name:?} bound to net {id}, which is not an Input gate",
                    self.name
                ));
            }
        }
        for (name, id) in &self.outputs {
            if bad(*id) {
                return Err(format!(
                    "{}: output port {name:?}: net {id} {}",
                    self.name,
                    describe(*id)
                ));
            }
        }
        self.levelize_buckets()
            .map(|_| ())
            .map_err(|e| format!("{}: {e}", self.name))
    }
}

/// Shared implementation of the bulk port binders: build the name index
/// once, then resolve every requested name against it (`kind` labels the
/// error message: "input" / "output"). Callers that already own a name
/// index — the simulators — use [`resolve_ports`] directly instead.
pub(crate) fn bind_ports(
    ports: &[(String, NetId)],
    names: &[&str],
    kind: &str,
) -> Result<Vec<NetId>, String> {
    let index: HashMap<&str, NetId> = ports
        .iter()
        .map(|(name, id)| (name.as_str(), *id))
        .collect();
    resolve_ports(&index, names, kind)
}

/// Resolve a batch of port names against an existing name index (the
/// allocation-free half of [`bind_ports`]).
pub(crate) fn resolve_ports(
    index: &HashMap<&str, NetId>,
    names: &[&str],
    kind: &str,
) -> Result<Vec<NetId>, String> {
    names
        .iter()
        .map(|&n| {
            index
                .get(n)
                .copied()
                .ok_or_else(|| format!("unknown {kind} {n:?}"))
        })
        .collect()
}

/// Gate counts by coarse class (the [`Netlist::census`] result).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Census {
    /// Combinational gates.
    pub comb: usize,
    /// D flip-flops.
    pub dffs: usize,
    /// Hard-macro instances.
    pub macros: usize,
    /// Macro output pins (one net each).
    pub macro_pins: usize,
    /// Primary inputs and constants.
    pub sources: usize,
}

impl Census {
    /// Total "design objects" the synthesis optimizer must visit.
    pub fn work_items(&self) -> usize {
        self.comb + self.dffs + self.macros
    }
}

/// Structural netlist builder — the elaboration front-end.
///
/// Optional *structural hashing* (`share: true`) folds identical gates on
/// construction; the synthesis flow builds with sharing OFF so the optimizer
/// has realistic work to do (mirroring behavioral RTL fed to Genus).
pub struct NetBuilder {
    nl: Netlist,
    share: bool,
    cache: HashMap<Gate, NetId>,
    zero: Option<NetId>,
    one: Option<NetId>,
}

impl NetBuilder {
    /// Start building a netlist named `name` (sharing off).
    pub fn new(name: &str) -> Self {
        NetBuilder {
            nl: Netlist {
                name: name.to_string(),
                ..Netlist::default()
            },
            share: false,
            cache: HashMap::new(),
            zero: None,
            one: None,
        }
    }

    /// Enable structural hashing at build time.
    pub fn with_sharing(mut self) -> Self {
        self.share = true;
        self
    }

    fn push(&mut self, g: Gate) -> NetId {
        if self.share && !g.is_state() && !matches!(g, Gate::Input) {
            if let Some(&id) = self.cache.get(&g) {
                return id;
            }
        }
        let id = self.nl.gates.len() as NetId;
        self.nl.gates.push(g);
        if self.share {
            self.cache.insert(g, id);
        }
        id
    }

    // ---- primitives -----------------------------------------------------

    /// Declare a primary input.
    pub fn input(&mut self, name: &str) -> NetId {
        let id = self.push(Gate::Input);
        self.nl.inputs.push((name.to_string(), id));
        id
    }

    /// Declare a `width`-bit primary input vector (`name[k]` per bit).
    pub fn input_vec(&mut self, name: &str, width: usize) -> Vec<NetId> {
        (0..width)
            .map(|k| self.input(&format!("{name}[{k}]")))
            .collect()
    }

    /// Constant 0/1 net (deduplicated per builder).
    pub fn constant(&mut self, v: bool) -> NetId {
        let slot = if v { &mut self.one } else { &mut self.zero };
        if let Some(id) = *slot {
            return id;
        }
        let id = self.nl.gates.len() as NetId;
        self.nl.gates.push(Gate::Const(v));
        *slot = Some(id);
        id
    }

    /// Inverter.
    pub fn not(&mut self, a: NetId) -> NetId {
        self.push(Gate::Not(a))
    }
    /// 2-input AND.
    pub fn and(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(Gate::And(a, b))
    }
    /// 2-input OR.
    pub fn or(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(Gate::Or(a, b))
    }
    /// 2-input XOR.
    pub fn xor(&mut self, a: NetId, b: NetId) -> NetId {
        self.push(Gate::Xor(a, b))
    }
    /// 2:1 mux (`sel ? b : a`).
    pub fn mux(&mut self, sel: NetId, a: NetId, b: NetId) -> NetId {
        self.push(Gate::Mux(sel, a, b))
    }
    /// 2-input NAND (AND + NOT pair; the optimizer re-fuses them).
    pub fn nand(&mut self, a: NetId, b: NetId) -> NetId {
        let x = self.and(a, b);
        self.not(x)
    }
    /// 2-input NOR (OR + NOT pair).
    pub fn nor(&mut self, a: NetId, b: NetId) -> NetId {
        let x = self.or(a, b);
        self.not(x)
    }

    /// D flip-flop with optional synchronous reset to `init`.
    pub fn dff(&mut self, d: NetId, rst: Option<NetId>, init: bool) -> NetId {
        self.push(Gate::Dff { d, rst, init })
    }

    /// Allocate `width` DFF state cells whose `d` inputs will be patched
    /// later with [`Self::patch_dff_vec`] — the idiom for feedback
    /// (registers whose next-state logic reads their own output).
    pub fn dff_cell_vec(&mut self, width: usize) -> Vec<NetId> {
        (0..width)
            .map(|_| {
                self.push(Gate::Dff {
                    d: PENDING_D,
                    rst: None,
                    init: false,
                })
            })
            .collect()
    }

    /// Patch forward-declared DFF cells with their next-state nets, reset
    /// and init value (bit `k` of `init`).
    pub fn patch_dff_vec(&mut self, cells: &[NetId], d: &[NetId], rst: Option<NetId>, init: u64) {
        assert_eq!(cells.len(), d.len());
        for (k, (&cell, &dn)) in cells.iter().zip(d).enumerate() {
            match &mut self.nl.gates[cell as usize] {
                Gate::Dff { d: slot, rst: r, init: iv } => {
                    assert_eq!(*slot, PENDING_D, "DFF {cell} already patched");
                    *slot = dn;
                    *r = rst;
                    *iv = (init >> k) & 1 == 1;
                }
                g => panic!("patch_dff_vec on non-DFF gate {g:?}"),
            }
        }
    }

    /// Registered sticky bit: `q' = !rst & (q | set)`; returns `q`.
    pub fn sticky_dff(&mut self, set: NetId, rst: NetId) -> NetId {
        let q = self.dff_cell_vec(1)[0];
        let d = self.or(q, set);
        self.patch_dff_vec(&[q], &[d], Some(rst), 0);
        q
    }

    /// Forward-declared wire: usable as a fan-in immediately, driven later
    /// with [`Self::connect`]. (The netlist idiom for feedback through
    /// logic built in a later pass, e.g. STDP control → synapse datapath.)
    pub fn wire(&mut self) -> NetId {
        self.push(Gate::Buf(PENDING_D))
    }

    /// Drive a forward wire created by [`Self::wire`].
    pub fn connect(&mut self, wire: NetId, src: NetId) {
        match &mut self.nl.gates[wire as usize] {
            Gate::Buf(slot) => {
                assert_eq!(*slot, PENDING_D, "wire {wire} already connected");
                *slot = src;
            }
            g => panic!("connect() on non-wire gate {g:?}"),
        }
    }

    /// Instantiate a hard macro; returns its output nets.
    pub fn macro_inst(&mut self, kind: MacroKind, inputs: Vec<NetId>) -> Vec<NetId> {
        assert_eq!(
            inputs.len(),
            kind.input_pins().len(),
            "{kind:?}: wrong input count"
        );
        let inst = self.nl.macros.len() as u32;
        let outputs: Vec<NetId> = (0..kind.output_pins().len() as u8)
            .map(|pin| self.push(Gate::MacroOut { inst, pin }))
            .collect();
        self.nl.macros.push(MacroInst {
            kind,
            inputs,
            outputs: outputs.clone(),
        });
        outputs
    }

    // ---- word-level helpers (the "RTL" layer) ---------------------------

    /// Reduction OR over a slice (balanced tree).
    pub fn or_tree(&mut self, xs: &[NetId]) -> NetId {
        self.reduce_tree(xs, |b, x, y| b.or(x, y))
    }

    /// Reduction AND over a slice (balanced tree).
    pub fn and_tree(&mut self, xs: &[NetId]) -> NetId {
        self.reduce_tree(xs, |b, x, y| b.and(x, y))
    }

    fn reduce_tree(
        &mut self,
        xs: &[NetId],
        f: impl Fn(&mut Self, NetId, NetId) -> NetId + Copy,
    ) -> NetId {
        assert!(!xs.is_empty());
        let mut layer = xs.to_vec();
        while layer.len() > 1 {
            let mut next = Vec::with_capacity(layer.len().div_ceil(2));
            for pair in layer.chunks(2) {
                next.push(if pair.len() == 2 {
                    f(self, pair[0], pair[1])
                } else {
                    pair[0]
                });
            }
            layer = next;
        }
        layer[0]
    }

    /// Full adder: returns (sum, carry).
    pub fn full_adder(&mut self, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
        let ab = self.xor(a, b);
        let sum = self.xor(ab, c);
        let and1 = self.and(a, b);
        let and2 = self.and(ab, c);
        let carry = self.or(and1, and2);
        (sum, carry)
    }

    /// Half adder: returns (sum, carry).
    pub fn half_adder(&mut self, a: NetId, b: NetId) -> (NetId, NetId) {
        (self.xor(a, b), self.and(a, b))
    }

    /// Ripple-carry add of two equal-width LSB-first vectors; output is one
    /// bit wider.
    pub fn add_vec(&mut self, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
        assert_eq!(a.len(), b.len());
        let mut out = Vec::with_capacity(a.len() + 1);
        let mut carry = self.constant(false);
        for (&x, &y) in a.iter().zip(b) {
            let (s, c) = self.full_adder(x, y, carry);
            out.push(s);
            carry = c;
        }
        out.push(carry);
        out
    }

    /// Increment an LSB-first vector by 1 (wrapping); returns same width.
    pub fn inc_vec(&mut self, a: &[NetId]) -> Vec<NetId> {
        let mut out = Vec::with_capacity(a.len());
        let mut carry = self.constant(true);
        for &x in a {
            let (s, c) = self.half_adder(x, carry);
            out.push(s);
            carry = c;
        }
        out
    }

    /// Decrement an LSB-first vector by 1 (wrapping); returns same width.
    /// (a - 1 = a + 111…1 with no carry-in.)
    pub fn dec_vec(&mut self, a: &[NetId]) -> Vec<NetId> {
        let one = self.constant(true);
        let mut out = Vec::with_capacity(a.len());
        let mut carry = self.constant(false);
        for &x in a {
            let (s1, c1) = self.half_adder(x, one);
            let (s, c2) = self.half_adder(s1, carry);
            out.push(s);
            let c = self.or(c1, c2);
            carry = c;
        }
        out
    }

    /// `a != 0` (reduction OR).
    pub fn nonzero(&mut self, a: &[NetId]) -> NetId {
        self.or_tree(a)
    }

    /// `a == const k` over an LSB-first vector.
    pub fn eq_const(&mut self, a: &[NetId], k: u64) -> NetId {
        let lits: Vec<NetId> = a
            .iter()
            .enumerate()
            .map(|(i, &bit)| {
                if (k >> i) & 1 == 1 {
                    bit
                } else {
                    self.not(bit)
                }
            })
            .collect();
        self.and_tree(&lits)
    }

    /// `a >= const k` over an LSB-first unsigned vector (magnitude compare
    /// against a constant, MSB-first chain).
    pub fn ge_const(&mut self, a: &[NetId], k: u64) -> NetId {
        // ge = 1 initially (empty suffix comparison: a==k so far ⇒ ge).
        // Scan MSB→LSB: at each bit, if k-bit is 1 and a-bit is 0 → lose
        // unless already strictly greater; track (gt, eq) pair.
        let mut gt = self.constant(false);
        let mut eq = self.constant(true);
        for (i, &bit) in a.iter().enumerate().rev() {
            let kb = (k >> i) & 1 == 1;
            if kb {
                // a_i=1 keeps eq; a_i=0 with eq → lose (eq=0, gt unchanged)
                let new_eq = self.and(eq, bit);
                eq = new_eq;
            } else {
                // a_i=1 with eq → strictly greater
                let win = self.and(eq, bit);
                let new_gt = self.or(gt, win);
                gt = new_gt;
            }
        }
        self.or(gt, eq)
    }

    /// Population count of `xs`: LSB-first sum vector, built as a
    /// carry-save (Wallace) compressor tree — 3:2 full-adder compression
    /// per weight column until every column holds ≤ 2 bits, then one final
    /// ripple add. Logic depth is O(log n), matching the adder trees the
    /// paper's neuron bodies use.
    pub fn popcount(&mut self, xs: &[NetId]) -> Vec<NetId> {
        assert!(!xs.is_empty());
        if xs.len() == 1 {
            return vec![xs[0]];
        }
        let mut cols: Vec<Vec<NetId>> = vec![xs.to_vec()];
        loop {
            let max_h = cols.iter().map(|c| c.len()).max().unwrap();
            if max_h <= 2 {
                break;
            }
            let mut next: Vec<Vec<NetId>> = vec![Vec::new(); cols.len() + 1];
            for w in 0..cols.len() {
                let col = cols[w].clone();
                let mut i = 0;
                while col.len() - i >= 3 {
                    let (s, c) = self.full_adder(col[i], col[i + 1], col[i + 2]);
                    next[w].push(s);
                    next[w + 1].push(c);
                    i += 3;
                }
                if col.len() - i == 2 {
                    let (s, c) = self.half_adder(col[i], col[i + 1]);
                    next[w].push(s);
                    next[w + 1].push(c);
                } else if col.len() - i == 1 {
                    next[w].push(col[i]);
                }
            }
            while next.last().map_or(false, |c| c.is_empty()) {
                next.pop();
            }
            cols = next;
        }
        // Each column now holds ≤ 2 bits: one ripple add of the two rows.
        let zero = self.constant(false);
        let a: Vec<NetId> = cols
            .iter()
            .map(|c| c.first().copied().unwrap_or(zero))
            .collect();
        let all_single = cols.iter().all(|c| c.len() <= 1);
        if all_single {
            return a;
        }
        let b: Vec<NetId> = cols
            .iter()
            .map(|c| c.get(1).copied().unwrap_or(zero))
            .collect();
        self.add_vec(&a, &b)
    }

    /// Vector 2:1 mux.
    pub fn mux_vec(&mut self, sel: NetId, a: &[NetId], b: &[NetId]) -> Vec<NetId> {
        assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b)
            .map(|(&x, &y)| self.mux(sel, x, y))
            .collect()
    }

    /// Register a vector of DFFs.
    pub fn dff_vec(&mut self, d: &[NetId], rst: Option<NetId>, init: u64) -> Vec<NetId> {
        d.iter()
            .enumerate()
            .map(|(i, &bit)| self.dff(bit, rst, (init >> i) & 1 == 1))
            .collect()
    }

    // ---- finalization ----------------------------------------------------

    /// Declare a primary output.
    pub fn output(&mut self, name: &str, net: NetId) {
        self.nl.outputs.push((name.to_string(), net));
    }

    /// Declare a primary output vector (`name[k]` per bit).
    pub fn output_vec(&mut self, name: &str, nets: &[NetId]) {
        for (k, &n) in nets.iter().enumerate() {
            self.output(&format!("{name}[{k}]"), n);
        }
    }

    /// Finish building and return the netlist.
    pub fn finish(self) -> Netlist {
        for (i, g) in self.nl.gates.iter().enumerate() {
            match g {
                Gate::Dff { d, .. } => {
                    assert_ne!(*d, PENDING_D, "DFF {i} was never patched")
                }
                Gate::Buf(src) => {
                    assert_ne!(*src, PENDING_D, "wire {i} was never connected")
                }
                _ => {}
            }
        }
        self.nl
    }

    /// Peek at the netlist under construction.
    pub fn netlist(&self) -> &Netlist {
        &self.nl
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_assembles_and_levelizes() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.and(a, c);
        let y = b.not(x);
        let q = b.dff(y, None, false);
        b.output("q", q);
        let nl = b.finish();
        assert_eq!(nl.census().comb, 2);
        assert_eq!(nl.census().dffs, 1);
        let order = nl.levelize().unwrap();
        assert_eq!(order.len(), 2);
        // and must come before not
        let pos_and = order.iter().position(|&i| i == x).unwrap();
        let pos_not = order.iter().position(|&i| i == y).unwrap();
        assert!(pos_and < pos_not);
    }

    #[test]
    fn levelize_buckets_pack_by_depth() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.and(a, c); // depth 0
        let w = b.or(a, c); // depth 0
        let y = b.not(x); // depth 1
        let z = b.xor(y, a); // depth 2
        let q = b.dff(z, None, false);
        b.output("q", q);
        b.output("w", w);
        let nl = b.finish();
        let levels = nl.levelize_buckets().unwrap();
        assert_eq!(levels, vec![vec![x, w], vec![y], vec![z]]);
        let flat = nl.levelize().unwrap();
        assert_eq!(flat, vec![x, w, y, z]);
    }

    #[test]
    fn sharing_folds_duplicates() {
        let mut b = NetBuilder::new("t").with_sharing();
        let a = b.input("a");
        let c = b.input("b");
        let x1 = b.and(a, c);
        let x2 = b.and(a, c);
        assert_eq!(x1, x2);
        let mut b2 = NetBuilder::new("t");
        let a = b2.input("a");
        let c = b2.input("b");
        let x1 = b2.and(a, c);
        let x2 = b2.and(a, c);
        assert_ne!(x1, x2, "sharing off by default");
    }

    #[test]
    fn constants_are_unique() {
        let mut b = NetBuilder::new("t");
        assert_eq!(b.constant(true), b.constant(true));
        assert_ne!(b.constant(true), b.constant(false));
    }

    #[test]
    fn bulk_port_binders_resolve_and_reject() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.or(a, c);
        b.output("x", x);
        b.output("a_thru", a);
        let nl = b.finish();
        assert_eq!(nl.bind_inputs(&["b", "a", "b"]).unwrap(), vec![c, a, c]);
        assert_eq!(nl.bind_outputs(&["a_thru", "x"]).unwrap(), vec![a, x]);
        let err = nl.bind_inputs(&["missing"]).unwrap_err();
        assert!(err.contains("unknown input"), "{err}");
        assert!(nl.bind_outputs(&["missing"]).is_err());
    }

    #[test]
    fn verify_accepts_builder_output() {
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let x = b.and(p, g);
        let q = b.dff(x, Some(g), false);
        let outs = b.macro_inst(MacroKind::Pulse2Edge, vec![p, g]);
        b.output("q", q);
        b.output("e", outs[0]);
        b.finish().verify().unwrap();
    }

    #[test]
    fn verify_flags_dangling_and_out_of_range_nets() {
        let mut nl = Netlist {
            name: "bad".into(),
            gates: vec![Gate::Input, Gate::Buf(PENDING_D)],
            ..Netlist::default()
        };
        let err = nl.verify().unwrap_err();
        assert!(err.contains("dangling") && err.contains("gate 1"), "{err}");
        nl.gates[1] = Gate::And(0, 99);
        let err = nl.verify().unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn verify_flags_macro_pin_theft_naming_the_instance() {
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let outs = b.macro_inst(MacroKind::Pulse2Edge, vec![p, g]);
        b.output("e", outs[0]);
        let mut nl = b.finish();
        nl.verify().unwrap();
        // Point the instance's pin table at an input net: the MacroOut node
        // and the pin table now disagree about who drives the pin.
        nl.macros[0].outputs[0] = p;
        let err = nl.verify().unwrap_err();
        assert!(
            err.contains("Pulse2Edge") && err.contains("pin 0"),
            "{err}"
        );
    }

    #[test]
    fn verify_flags_combinational_cycles() {
        let mut b = NetBuilder::new("c");
        let a = b.input("a");
        let w = b.wire();
        let x = b.and(a, w);
        b.connect(w, x);
        b.output("x", x);
        let err = b.finish().verify().unwrap_err();
        assert!(err.contains("combinational cycle"), "{err}");
    }

    #[test]
    fn fanout_counts_include_outputs_and_dffs() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let n = b.not(a);
        let q = b.dff(n, Some(a), false);
        b.output("q", q);
        b.output("n", n);
        let nl = b.finish();
        let fo = nl.fanout_counts();
        assert_eq!(fo[a as usize], 2); // not + rst
        assert_eq!(fo[n as usize], 2); // dff.d + output
        assert_eq!(fo[q as usize], 1); // output
    }
}
