//! Netlist optimizer pass pipeline: constant propagation seeded from
//! `Gate::Const` nets and caller-declared tied-low inputs, dead-net /
//! dead-instance elimination behind an explicit keep-set, and a
//! locality-aware renumbering of the levelized schedule — with every pass
//! returning a [`NetRemap`] so per-net artifacts (toggle reports, measured
//! α vectors, fault sites) translate onto the optimized netlist.
//!
//! The pipeline is held to the repo's differential standard: on every
//! *retained* net, values and toggle counts are **bit-exact** with the
//! unoptimized netlist under any stimulus that honors the assumptions
//! (tied-low inputs actually held low from before the first settle), on
//! every simulator backend at every worker count (`tests/netlist_opt.rs`).
//! Three arguments carry the proof obligations:
//!
//! * **Readers are rewired, never re-timed.** A net proven constant `c`
//!   keeps its driver; only its *readers* move to a canonical `Const`
//!   net. Levelization settles a net before any reader evaluates, so at
//!   every settle a reader observes `c` either way. Folded combinational
//!   gates are rewritten to `Buf(const)`, which commits the same word at
//!   the same settle as the original gate (one 0→1 transition at the
//!   first settle for a constant-true net, none for a constant-false
//!   one). DFFs and macro output pins are never retyped — their
//!   init/reset and pin-table semantics stay byte-identical — only their
//!   readers move.
//! * **State is folded only when provably frozen.** A DFF folds only if
//!   its data input is the constant it initializes to, or its reset is
//!   constant-true (pinning it at `init`). A macro pin folds only if
//!   exhaustive enumeration of its unknown `pin_deps` inputs × all
//!   `2^state_bits` behavioral state values yields a single output — an
//!   over-approximation of the reachable state set, so it can miss folds
//!   but never invent one. Moore pins (empty `pin_deps`) refresh only at
//!   clock edges and read 0 until the first one, so they fold to 0 only.
//! * **Dead logic cannot observe or be observed.** Reverse reachability
//!   from the primary outputs plus the keep-set; a live macro instance
//!   pins all of its inputs and output pins live (its state step reads
//!   every input at each clock), so removing a dead instance can never
//!   change a retained net.
//!
//! Pass order for the inference pipeline is `ConstProp → DeadCode →
//! Locality`: propagation rewires readers of constant cones onto
//! canonical `Const` nets, elimination then removes the unread cones and
//! compacts ids, and the locality pass renumbers the survivors so the
//! compiled instruction stream's operand slots cluster by producer.

use super::macros9::{self, MacroState};
use super::netlist::{Gate, MacroInst, NetId, Netlist};

/// Optimization level selector — an *execution knob* like
/// [`SimBackend`](super::SimBackend): it changes how fast a workload
/// simulates, never what any retained net computes, so sweep cache keys
/// exclude it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum OptLevel {
    /// Lower the netlist exactly as built (the seed behavior).
    #[default]
    None,
    /// Inference specialization: assume the BRV pseudo-random inputs are
    /// tied low (as the gate engine's batched-inference protocol holds
    /// them), fold the training-update cone away, and renumber for
    /// operand locality.
    Inference,
}

impl OptLevel {
    /// Display name (`none` / `inference`).
    pub fn name(self) -> &'static str {
        match self {
            OptLevel::None => "none",
            OptLevel::Inference => "inference",
        }
    }

    /// Parse a CLI/config spelling (`none` | `inference`).
    pub fn parse(s: &str) -> crate::Result<OptLevel> {
        match s {
            "none" => Ok(OptLevel::None),
            "inference" => Ok(OptLevel::Inference),
            other => anyhow::bail!("unknown opt level {other:?} (none|inference)"),
        }
    }
}

/// Environment facts the optimizer is allowed to assume. The assumptions
/// are a *contract*: equivalence on retained nets holds only under
/// stimulus that honors them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OptAssumptions {
    /// Primary-input nets the execution environment holds at constant 0
    /// from before the first settle — the gate engine populates this with
    /// its silenced `brv_case` / `brv_stab` inputs.
    pub tied_low_inputs: Vec<NetId>,
}

impl OptAssumptions {
    /// No assumptions: only `Gate::Const` nets seed constant propagation.
    pub fn none() -> OptAssumptions {
        OptAssumptions::default()
    }

    /// Assume every net in `nets` is a primary input held at constant 0.
    pub fn tied_low(nets: impl IntoIterator<Item = NetId>) -> OptAssumptions {
        OptAssumptions {
            tied_low_inputs: nets.into_iter().collect(),
        }
    }
}

/// Nets that dead-logic elimination must retain even when nothing in the
/// netlist reads them — the explicit form of "monitored so optimization
/// cannot delete it". Primary outputs are always implicit roots; the
/// keep-set adds engine-observed nets that are not ports.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KeepSet {
    nets: Vec<NetId>,
}

impl KeepSet {
    /// Empty keep-set: only primary outputs root the liveness sweep.
    pub fn new() -> KeepSet {
        KeepSet::default()
    }

    /// Build a keep-set from any collection of net ids.
    pub fn from_nets(nets: impl IntoIterator<Item = NetId>) -> KeepSet {
        let mut v: Vec<NetId> = nets.into_iter().collect();
        v.sort_unstable();
        v.dedup();
        KeepSet { nets: v }
    }

    /// Add one net to the keep-set.
    pub fn insert(&mut self, net: NetId) {
        if let Err(i) = self.nets.binary_search(&net) {
            self.nets.insert(i, net);
        }
    }

    /// The kept nets, sorted ascending.
    pub fn nets(&self) -> &[NetId] {
        &self.nets
    }

    /// Number of kept nets.
    pub fn len(&self) -> usize {
        self.nets.len()
    }

    /// True when no extra nets are kept.
    pub fn is_empty(&self) -> bool {
        self.nets.is_empty()
    }
}

/// Old-id → new-id translation artifact returned by every pass (and by
/// the whole pipeline, composed). Invariants:
///
/// * `net(old)` is `Some(new)` iff the net survived; surviving nets keep
///   their relative semantics (same gate kind, operands mapped), and two
///   distinct survivors never collapse onto one new id.
/// * `macro_inst(old)` likewise for macro instances.
/// * Per-net artifacts indexed by old ids (toggle counts, α vectors,
///   fault sites) translate with [`NetRemap::translate_per_net`] /
///   [`GateFault::remap`](super::fault::GateFault::remap); entries for
///   removed nets are dropped.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetRemap {
    net_map: Vec<Option<NetId>>,
    macro_map: Vec<Option<u32>>,
    new_nets: usize,
    new_macros: usize,
}

impl NetRemap {
    /// The identity remap over `nets` nets and `macros` instances.
    pub fn identity(nets: usize, macros: usize) -> NetRemap {
        NetRemap {
            net_map: (0..nets).map(|i| Some(i as NetId)).collect(),
            macro_map: (0..macros).map(|i| Some(i as u32)).collect(),
            new_nets: nets,
            new_macros: macros,
        }
    }

    /// Build a remap from explicit maps — the constructor for renumbering
    /// transforms implemented outside this module (e.g. the synthesis
    /// flow's DCE compaction, [`crate::synth::opt::optimize_tracked`]).
    /// Every `Some` image must be `< new_nets` / `< new_macros`, and two
    /// survivors must never share an image (checked in debug builds).
    pub fn from_maps(
        net_map: Vec<Option<NetId>>,
        new_nets: usize,
        macro_map: Vec<Option<u32>>,
        new_macros: usize,
    ) -> NetRemap {
        debug_assert!(net_map.iter().flatten().all(|&n| (n as usize) < new_nets));
        debug_assert!(macro_map.iter().flatten().all(|&m| (m as usize) < new_macros));
        debug_assert_eq!(
            {
                let mut v: Vec<NetId> = net_map.iter().flatten().copied().collect();
                v.sort_unstable();
                v.dedup();
                v.len()
            },
            net_map.iter().flatten().count(),
            "two survivors collapsed onto one new net id"
        );
        NetRemap {
            net_map,
            macro_map,
            new_nets,
            new_macros,
        }
    }

    /// New id of `old`, or `None` if the net was removed.
    pub fn net(&self, old: NetId) -> Option<NetId> {
        self.net_map.get(old as usize).copied().flatten()
    }

    /// New index of macro instance `old`, or `None` if removed.
    pub fn macro_inst(&self, old: u32) -> Option<u32> {
        self.macro_map.get(old as usize).copied().flatten()
    }

    /// Net count of the pre-pass netlist.
    pub fn old_net_count(&self) -> usize {
        self.net_map.len()
    }

    /// Net count of the post-pass netlist.
    pub fn new_net_count(&self) -> usize {
        self.new_nets
    }

    /// Macro-instance count of the pre-pass netlist.
    pub fn old_macro_count(&self) -> usize {
        self.macro_map.len()
    }

    /// Macro-instance count of the post-pass netlist.
    pub fn new_macro_count(&self) -> usize {
        self.new_macros
    }

    /// The removed set: old net ids with no image, ascending.
    pub fn removed_nets(&self) -> Vec<NetId> {
        self.net_map
            .iter()
            .enumerate()
            .filter(|(_, m)| m.is_none())
            .map(|(i, _)| i as NetId)
            .collect()
    }

    /// True when the remap maps every net and instance to itself (the
    /// pass was a structural no-op as far as ids are concerned).
    pub fn is_identity(&self) -> bool {
        self.new_nets == self.net_map.len()
            && self.new_macros == self.macro_map.len()
            && self
                .net_map
                .iter()
                .enumerate()
                .all(|(i, m)| *m == Some(i as NetId))
            && self
                .macro_map
                .iter()
                .enumerate()
                .all(|(i, m)| *m == Some(i as u32))
    }

    /// Compose: apply `self` (old → mid), then `next` (mid → new).
    pub fn then(&self, next: &NetRemap) -> NetRemap {
        NetRemap {
            net_map: self
                .net_map
                .iter()
                .map(|m| m.and_then(|mid| next.net(mid)))
                .collect(),
            macro_map: self
                .macro_map
                .iter()
                .map(|m| m.and_then(|mid| next.macro_inst(mid)))
                .collect(),
            new_nets: next.new_nets,
            new_macros: next.new_macros,
        }
    }

    /// Translate a per-net vector indexed by old ids onto the new net
    /// space: surviving entries move to their new index, removed entries
    /// are dropped, and new-only nets (canonical constants appended by
    /// constant propagation) read `T::default()`.
    ///
    /// Panics if `old.len()` differs from [`NetRemap::old_net_count`].
    pub fn translate_per_net<T: Copy + Default>(&self, old: &[T]) -> Vec<T> {
        assert_eq!(
            old.len(),
            self.net_map.len(),
            "per-net vector length {} != pre-pass net count {}",
            old.len(),
            self.net_map.len()
        );
        let mut out = vec![T::default(); self.new_nets];
        for (i, m) in self.net_map.iter().enumerate() {
            if let Some(n) = *m {
                out[n as usize] = old[i];
            }
        }
        out
    }
}

/// One optimizer pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    /// Constant propagation + reader rewiring ([`const_propagate`]).
    ConstProp,
    /// Dead-net / dead-instance elimination ([`eliminate_dead`]).
    DeadCode,
    /// Locality-aware schedule renumbering ([`schedule_locality`]).
    Locality,
}

/// An ordered list of passes plus the assumptions and keep-set they run
/// under (both expressed in the *input* netlist's ids; the pipeline
/// translates them through intermediate remaps automatically).
#[derive(Clone, Debug, Default)]
pub struct PassPipeline {
    /// Tied-low input assumptions, in input-netlist ids.
    pub assume: OptAssumptions,
    /// Extra liveness roots, in input-netlist ids.
    pub keep: KeepSet,
    passes: Vec<Pass>,
}

impl PassPipeline {
    /// The empty pipeline: `run` verifies and returns the netlist
    /// unchanged under an identity remap.
    pub fn none() -> PassPipeline {
        PassPipeline::default()
    }

    /// The inference pipeline: `ConstProp → DeadCode → Locality`.
    pub fn inference(assume: OptAssumptions, keep: KeepSet) -> PassPipeline {
        PassPipeline {
            assume,
            keep,
            passes: vec![Pass::ConstProp, Pass::DeadCode, Pass::Locality],
        }
    }

    /// A custom pass order under the given assumptions and keep-set.
    pub fn custom(passes: Vec<Pass>, assume: OptAssumptions, keep: KeepSet) -> PassPipeline {
        PassPipeline { assume, keep, passes }
    }

    /// The pass order this pipeline runs.
    pub fn passes(&self) -> &[Pass] {
        &self.passes
    }

    /// Run the pipeline: verify `nl`, apply each pass in order, and
    /// return the optimized netlist with the composed remap (input ids →
    /// output ids). Assumptions and keep nets are translated through the
    /// accumulated remap before each pass, so tied inputs removed by an
    /// earlier pass simply drop out.
    pub fn run(&self, nl: &Netlist) -> Result<(Netlist, NetRemap), String> {
        nl.verify()?;
        let mut cur = nl.clone();
        let mut acc = NetRemap::identity(nl.len(), nl.macros.len());
        for pass in &self.passes {
            let (next, r) = match pass {
                Pass::ConstProp => {
                    let assume = OptAssumptions::tied_low(
                        self.assume
                            .tied_low_inputs
                            .iter()
                            .filter_map(|&n| acc.net(n)),
                    );
                    const_propagate(&cur, &assume)
                }
                Pass::DeadCode => {
                    let keep =
                        KeepSet::from_nets(self.keep.nets().iter().filter_map(|&n| acc.net(n)));
                    eliminate_dead(&cur, &keep)
                }
                Pass::Locality => schedule_locality(&cur)?,
            };
            acc = acc.then(&r);
            cur = next;
        }
        Ok((cur, acc))
    }
}

/// Exhaustive-enumeration budget for macro-pin folding: unknown dep
/// inputs + behavioral state bits, capped so one pin costs at most 2^12
/// behavioral evaluations per propagation sweep.
const FOLD_ENUM_CAP: usize = 12;

/// Lattice value of one macro output pin: `Some(c)` iff the pin reads `c`
/// for every assignment of its unknown `pin_deps` inputs × every state
/// value (known inputs pinned to their constants, non-dep inputs
/// irrelevant by the `pin_deps` contract). Moore pins fold to 0 only —
/// they hold 0 until the first clock refresh.
fn macro_pin_value(
    m: &MacroInst,
    pin: u8,
    value: &[Option<bool>],
    ins: &mut Vec<bool>,
    out: &mut Vec<bool>,
) -> Option<bool> {
    let deps = m.kind.pin_deps(pin);
    let sbits = m.kind.state_bits();
    let unknown: Vec<usize> = deps
        .iter()
        .copied()
        .filter(|&d| value[m.inputs[d] as usize].is_none())
        .collect();
    if unknown.len() + sbits > FOLD_ENUM_CAP {
        return None;
    }
    ins.clear();
    ins.resize(m.inputs.len(), false);
    for &d in deps {
        if let Some(v) = value[m.inputs[d] as usize] {
            ins[d] = v;
        }
    }
    let mut result: Option<bool> = None;
    for ivec in 0u32..(1u32 << unknown.len()) {
        for (k, &d) in unknown.iter().enumerate() {
            ins[d] = (ivec >> k) & 1 == 1;
        }
        for st_bits in 0u32..(1u32 << sbits) {
            let st = MacroState::from_bits(st_bits);
            macros9::eval(m.kind, ins, &st, out);
            let v = out[pin as usize];
            match result {
                None => result = Some(v),
                Some(r) if r != v => return None,
                _ => {}
            }
        }
    }
    if deps.is_empty() && result == Some(true) {
        return None;
    }
    result
}

/// Lattice value of one combinational gate (`None` = unknown). Includes
/// the short-circuit rules (`And` with a known-0 operand, `Or` with a
/// known-1, `Mux` with agreeing branches).
fn comb_value(g: &Gate, value: &[Option<bool>]) -> Option<bool> {
    let v = |a: NetId| value[a as usize];
    match *g {
        Gate::Buf(a) => v(a),
        Gate::Not(a) => v(a).map(|x| !x),
        Gate::And(a, b) => match (v(a), v(b)) {
            (Some(false), _) | (_, Some(false)) => Some(false),
            (Some(x), Some(y)) => Some(x && y),
            _ => None,
        },
        Gate::Or(a, b) => match (v(a), v(b)) {
            (Some(true), _) | (_, Some(true)) => Some(true),
            (Some(x), Some(y)) => Some(x || y),
            _ => None,
        },
        Gate::Xor(a, b) => match (v(a), v(b)) {
            (Some(x), Some(y)) => Some(x != y),
            _ => None,
        },
        Gate::Mux(s, a, b) => match v(s) {
            Some(true) => v(b),
            Some(false) => v(a),
            None => match (v(a), v(b)) {
                (Some(x), Some(y)) if x == y => Some(x),
                _ => None,
            },
        },
        _ => None,
    }
}

/// Constant propagation + reader rewiring.
///
/// Seeds the lattice from `Gate::Const` nets and the tied-low inputs,
/// iterates to a fixpoint through combinational gates, DFFs (fold only
/// when reset/init semantics provably preserve the constant) and macro
/// pins (exhaustive `pin_deps` × state enumeration), then rewires every
/// *reader* of a constant net onto a canonical `Const` net and rewrites
/// folded combinational gates to `Buf(const)`. A `Mux` whose select is
/// constant becomes a `Buf` of the selected branch, releasing the
/// unselected cone for dead-code elimination. Drivers are never retyped:
/// inputs, DFFs and macro pins keep their gates (and their exact values
/// and toggle counts); they simply lose their fanout.
///
/// The remap is the identity over the input nets; at most two canonical
/// constant nets are appended.
pub fn const_propagate(nl: &Netlist, assume: &OptAssumptions) -> (Netlist, NetRemap) {
    let n = nl.gates.len();
    let mut value: Vec<Option<bool>> = vec![None; n];
    for (i, g) in nl.gates.iter().enumerate() {
        if let Gate::Const(v) = *g {
            value[i] = Some(v);
        }
    }
    for &id in &assume.tied_low_inputs {
        assert!(
            matches!(nl.gates[id as usize], Gate::Input),
            "tied-low assumption on net {id}, which is not a primary input"
        );
        value[id as usize] = Some(false);
    }
    let mut ins = Vec::new();
    let mut outs = Vec::new();
    loop {
        let mut changed = false;
        for (i, g) in nl.gates.iter().enumerate() {
            if value[i].is_some() {
                continue;
            }
            let v = match *g {
                Gate::Input | Gate::Const(_) => None,
                Gate::Dff { d, rst, init } => {
                    let pinned = rst.is_some_and(|r| value[r as usize] == Some(true));
                    if pinned || value[d as usize] == Some(init) {
                        Some(init)
                    } else {
                        None
                    }
                }
                Gate::MacroOut { inst, pin } => {
                    macro_pin_value(&nl.macros[inst as usize], pin, &value, &mut ins, &mut outs)
                }
                ref g => comb_value(g, &value),
            };
            if v.is_some() {
                value[i] = v;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    // Which constant polarities will actually be read after rewiring?
    let is_comb = |g: &Gate| {
        matches!(
            g,
            Gate::Buf(_) | Gate::Not(_) | Gate::And(..) | Gate::Or(..) | Gate::Xor(..) | Gate::Mux(..)
        )
    };
    let mut need = [false, false];
    let mark = |need: &mut [bool; 2], a: NetId| {
        if let Some(v) = value[a as usize] {
            need[v as usize] = true;
        }
    };
    for (i, g) in nl.gates.iter().enumerate() {
        if is_comb(g) {
            if let Some(v) = value[i] {
                need[v as usize] = true; // folded gate becomes Buf(const)
                continue;
            }
        }
        match *g {
            Gate::Buf(a) | Gate::Not(a) => mark(&mut need, a),
            Gate::And(a, b) | Gate::Or(a, b) | Gate::Xor(a, b) => {
                mark(&mut need, a);
                mark(&mut need, b);
            }
            Gate::Mux(s, a, b) => {
                // A known select reduces to Buf(branch); the surviving
                // branch is unknown (else the mux itself would have
                // folded), so no constant is read.
                if value[s as usize].is_none() {
                    mark(&mut need, s);
                    mark(&mut need, a);
                    mark(&mut need, b);
                }
            }
            Gate::Dff { d, rst, .. } => {
                mark(&mut need, d);
                if let Some(r) = rst {
                    mark(&mut need, r);
                }
            }
            _ => {}
        }
    }
    for m in &nl.macros {
        for &a in &m.inputs {
            mark(&mut need, a);
        }
    }

    let mut out_nl = nl.clone();
    // Canonical constant per polarity: the lowest existing `Const` net,
    // else a fresh one appended past the original id range.
    let mut canon: [Option<NetId>; 2] = [None, None];
    for (i, g) in nl.gates.iter().enumerate() {
        if let Gate::Const(v) = *g {
            let slot = &mut canon[v as usize];
            if slot.is_none() {
                *slot = Some(i as NetId);
            }
        }
    }
    for v in 0..2usize {
        if need[v] && canon[v].is_none() {
            canon[v] = Some(out_nl.gates.len() as NetId);
            out_nl.gates.push(Gate::Const(v == 1));
        }
    }

    let canon_net = |v: bool| canon[v as usize].expect("canonical const allocated");
    let sub = |a: NetId| match value[a as usize] {
        Some(v) => canon_net(v),
        None => a,
    };
    for (i, g) in nl.gates.iter().enumerate() {
        let folded = if is_comb(g) { value[i] } else { None };
        out_nl.gates[i] = match *g {
            Gate::Input | Gate::Const(_) | Gate::MacroOut { .. } => continue,
            Gate::Dff { d, rst, init } => Gate::Dff {
                d: sub(d),
                rst: rst.map(sub),
                init,
            },
            _ if folded.is_some() => Gate::Buf(canon_net(folded.unwrap())),
            Gate::Buf(a) => Gate::Buf(sub(a)),
            Gate::Not(a) => Gate::Not(sub(a)),
            Gate::And(a, b) => Gate::And(sub(a), sub(b)),
            Gate::Or(a, b) => Gate::Or(sub(a), sub(b)),
            Gate::Xor(a, b) => Gate::Xor(sub(a), sub(b)),
            Gate::Mux(s, a, b) => match value[s as usize] {
                Some(sv) => Gate::Buf(sub(if sv { b } else { a })),
                None => Gate::Mux(sub(s), sub(a), sub(b)),
            },
        };
    }
    for m in &mut out_nl.macros {
        for a in &mut m.inputs {
            *a = sub(*a);
        }
    }

    let new_nets = out_nl.gates.len();
    let remap = NetRemap {
        net_map: (0..n).map(|i| Some(i as NetId)).collect(),
        macro_map: (0..nl.macros.len()).map(|i| Some(i as u32)).collect(),
        new_nets,
        new_macros: nl.macros.len(),
    };
    (out_nl, remap)
}

/// Dead-net / dead-instance elimination.
///
/// Liveness is reverse reachability from every primary output plus the
/// keep-set. A DFF roots its data and reset nets; a live macro instance
/// roots **all** of its inputs (the behavioral state step reads every
/// input at each clock) and retains all of its output-pin nets (the
/// pin-table consistency `Netlist::verify` demands). Everything else —
/// including primary inputs nothing reads any more — is removed, and the
/// survivors are compacted in their original relative order.
pub fn eliminate_dead(nl: &Netlist, keep: &KeepSet) -> (Netlist, NetRemap) {
    let n = nl.gates.len();
    let mut live = vec![false; n];
    let mut live_inst = vec![false; nl.macros.len()];
    let mut stack: Vec<NetId> = Vec::new();
    for (_, id) in &nl.outputs {
        stack.push(*id);
    }
    for &id in keep.nets() {
        assert!(
            (id as usize) < n,
            "keep-set net {id} out of range ({n} nets)"
        );
        stack.push(id);
    }
    let mut fanin = Vec::new();
    while let Some(id) = stack.pop() {
        let i = id as usize;
        if live[i] {
            continue;
        }
        live[i] = true;
        match nl.gates[i] {
            Gate::Dff { d, rst, .. } => {
                stack.push(d);
                if let Some(r) = rst {
                    stack.push(r);
                }
            }
            Gate::MacroOut { inst, .. } => {
                let mi = inst as usize;
                if !live_inst[mi] {
                    live_inst[mi] = true;
                    stack.extend_from_slice(&nl.macros[mi].inputs);
                    stack.extend_from_slice(&nl.macros[mi].outputs);
                }
            }
            ref g => {
                g.comb_fanin(&mut fanin);
                stack.extend_from_slice(&fanin);
            }
        }
    }

    let mut net_map: Vec<Option<NetId>> = vec![None; n];
    let mut next = 0u32;
    for (i, &alive) in live.iter().enumerate() {
        if alive {
            net_map[i] = Some(next);
            next += 1;
        }
    }
    let mut macro_map: Vec<Option<u32>> = vec![None; nl.macros.len()];
    let mut mnext = 0u32;
    for (i, &alive) in live_inst.iter().enumerate() {
        if alive {
            macro_map[i] = Some(mnext);
            mnext += 1;
        }
    }
    let map = |a: NetId| net_map[a as usize].expect("live net reads a dead net");

    let mut gates = Vec::with_capacity(next as usize);
    for (i, g) in nl.gates.iter().enumerate() {
        if !live[i] {
            continue;
        }
        gates.push(match *g {
            Gate::Input => Gate::Input,
            Gate::Const(v) => Gate::Const(v),
            Gate::Buf(a) => Gate::Buf(map(a)),
            Gate::Not(a) => Gate::Not(map(a)),
            Gate::And(a, b) => Gate::And(map(a), map(b)),
            Gate::Or(a, b) => Gate::Or(map(a), map(b)),
            Gate::Xor(a, b) => Gate::Xor(map(a), map(b)),
            Gate::Mux(s, a, b) => Gate::Mux(map(s), map(a), map(b)),
            Gate::Dff { d, rst, init } => Gate::Dff {
                d: map(d),
                rst: rst.map(map),
                init,
            },
            Gate::MacroOut { inst, pin } => Gate::MacroOut {
                inst: macro_map[inst as usize].expect("live pin of a dead instance"),
                pin,
            },
        });
    }
    let macros = nl
        .macros
        .iter()
        .zip(&live_inst)
        .filter(|(_, &alive)| alive)
        .map(|(m, _)| MacroInst {
            kind: m.kind,
            inputs: m.inputs.iter().map(|&a| map(a)).collect(),
            outputs: m.outputs.iter().map(|&a| map(a)).collect(),
        })
        .collect();
    let inputs = nl
        .inputs
        .iter()
        .filter(|(_, id)| live[*id as usize])
        .map(|(name, id)| (name.clone(), map(*id)))
        .collect();
    let outputs = nl
        .outputs
        .iter()
        .map(|(name, id)| (name.clone(), map(*id)))
        .collect();

    let out_nl = Netlist {
        name: nl.name.clone(),
        gates,
        macros,
        inputs,
        outputs,
    };
    let remap = NetRemap {
        net_map,
        macro_map,
        new_nets: next as usize,
        new_macros: mnext as usize,
    };
    (out_nl, remap)
}

/// Locality-aware schedule renumbering (fanout-aware instruction
/// scheduling for the compiled engine).
///
/// `Netlist::levelize_buckets` orders each level by ascending net id and
/// `CompiledProgram::compile` emits instructions in that order, so the
/// within-level schedule *is* the numbering. This pass renumbers nets so
/// that (a) every level's destination slots are contiguous — commits walk
/// the value array forward — and (b) within a level, instructions are
/// clustered by the smallest new id among their operands (producer
/// locality), high-fanout producers first so the operands most readers
/// share sit at the front of each cluster. A pure renumbering: values,
/// toggles and levels are preserved net-for-net under the remap.
pub fn schedule_locality(nl: &Netlist) -> Result<(Netlist, NetRemap), String> {
    let n = nl.gates.len();
    let levels = nl.levelize_buckets()?;
    let mut scheduled = vec![false; n];
    for level in &levels {
        for &id in level {
            scheduled[id as usize] = true;
        }
    }
    let mut new_of: Vec<NetId> = vec![NetId::MAX; n];
    let mut next = 0u32;
    // Sources (inputs, constants, DFFs, Moore pins) first, in old order.
    for (i, &s) in scheduled.iter().enumerate() {
        if !s {
            new_of[i] = next;
            next += 1;
        }
    }
    let fanout = nl.fanout_counts();
    let mut fanin = Vec::new();
    for level in &levels {
        let mut keyed: Vec<(NetId, u32, NetId)> = Vec::with_capacity(level.len());
        for &id in level {
            nl.comb_fanin_full(id, &mut fanin);
            let locality = fanin
                .iter()
                .map(|&d| new_of[d as usize])
                .min()
                .unwrap_or(0);
            keyed.push((locality, u32::MAX - fanout[id as usize], id));
        }
        keyed.sort_unstable();
        for &(_, _, id) in &keyed {
            new_of[id as usize] = next;
            next += 1;
        }
    }
    debug_assert_eq!(next as usize, n, "every net renumbered exactly once");
    if new_of.iter().enumerate().all(|(i, &m)| m == i as NetId) {
        return Ok((nl.clone(), NetRemap::identity(n, nl.macros.len())));
    }

    let map = |a: NetId| new_of[a as usize];
    let mut gates = vec![Gate::Input; n];
    for (i, g) in nl.gates.iter().enumerate() {
        gates[new_of[i] as usize] = match *g {
            Gate::Input => Gate::Input,
            Gate::Const(v) => Gate::Const(v),
            Gate::Buf(a) => Gate::Buf(map(a)),
            Gate::Not(a) => Gate::Not(map(a)),
            Gate::And(a, b) => Gate::And(map(a), map(b)),
            Gate::Or(a, b) => Gate::Or(map(a), map(b)),
            Gate::Xor(a, b) => Gate::Xor(map(a), map(b)),
            Gate::Mux(s, a, b) => Gate::Mux(map(s), map(a), map(b)),
            Gate::Dff { d, rst, init } => Gate::Dff {
                d: map(d),
                rst: rst.map(map),
                init,
            },
            Gate::MacroOut { inst, pin } => Gate::MacroOut { inst, pin },
        };
    }
    let macros = nl
        .macros
        .iter()
        .map(|m| MacroInst {
            kind: m.kind,
            inputs: m.inputs.iter().map(|&a| map(a)).collect(),
            outputs: m.outputs.iter().map(|&a| map(a)).collect(),
        })
        .collect();
    let inputs = nl
        .inputs
        .iter()
        .map(|(name, id)| (name.clone(), map(*id)))
        .collect();
    let outputs = nl
        .outputs
        .iter()
        .map(|(name, id)| (name.clone(), map(*id)))
        .collect();

    let out_nl = Netlist {
        name: nl.name.clone(),
        gates,
        macros,
        inputs,
        outputs,
    };
    let remap = NetRemap {
        net_map: new_of.iter().map(|&m| Some(m)).collect(),
        macro_map: (0..nl.macros.len()).map(|i| Some(i as u32)).collect(),
        new_nets: n,
        new_macros: nl.macros.len(),
    };
    Ok((out_nl, remap))
}

#[cfg(test)]
mod tests {
    use super::super::macros9::MacroKind;
    use super::super::netlist::NetBuilder;
    use super::*;

    #[test]
    fn opt_level_parses_and_names() {
        assert_eq!(OptLevel::parse("none").unwrap(), OptLevel::None);
        assert_eq!(OptLevel::parse("inference").unwrap(), OptLevel::Inference);
        assert!(OptLevel::parse("full").is_err());
        assert_eq!(OptLevel::None.name(), "none");
        assert_eq!(OptLevel::Inference.name(), "inference");
        assert_eq!(OptLevel::default(), OptLevel::None);
    }

    #[test]
    fn tied_low_input_folds_its_cone_and_rewires_readers() {
        let mut b = NetBuilder::new("t");
        let x = b.input("X");
        let y = b.input("Y");
        let z = b.and(x, y); // constant 0 under the assumption
        let w = b.or(z, y); // reader of z: rewired to the const net
        b.output("W", w);
        let nl = b.finish();
        let (opt, remap) = const_propagate(&nl, &OptAssumptions::tied_low([x]));
        assert!(remap.removed_nets().is_empty(), "const prop never removes");
        let zero = match opt.gates[z as usize] {
            Gate::Buf(c) => c,
            ref g => panic!("folded AND should be Buf(const), got {g:?}"),
        };
        assert_eq!(opt.gates[zero as usize], Gate::Const(false));
        assert_eq!(opt.gates[w as usize], Gate::Or(zero, y));
        opt.verify().unwrap();
    }

    #[test]
    fn mux_with_constant_select_releases_the_unselected_branch() {
        let mut b = NetBuilder::new("t");
        let a = b.input("A");
        let x = b.input("X");
        let sel = b.constant(true);
        let deep = b.not(x); // only read through the unselected... selected branch
        let m = b.mux(sel, a, deep); // sel=1 → picks `deep`
        b.output("M", m);
        let nl = b.finish();
        let (opt, _) = const_propagate(&nl, &OptAssumptions::none());
        assert_eq!(opt.gates[m as usize], Gate::Buf(deep));
        opt.verify().unwrap();
        // And the dual: constant-false select picks the first branch.
        let mut b = NetBuilder::new("t2");
        let a = b.input("A");
        let x = b.input("X");
        let sel = b.constant(false);
        let deep = b.not(x);
        let m = b.mux(sel, a, deep);
        b.output("M", m);
        let nl = b.finish();
        let (opt, _) = const_propagate(&nl, &OptAssumptions::none());
        assert_eq!(opt.gates[m as usize], Gate::Buf(a));
        // `deep` is now unread; dead-code elimination removes it and X.
        let (dce, remap) = eliminate_dead(&opt, &KeepSet::new());
        assert_eq!(remap.net(deep), None);
        assert_eq!(remap.net(x), None);
        assert!(remap.net(m).is_some());
        dce.verify().unwrap();
    }

    #[test]
    fn dff_folds_only_when_init_matches_the_constant_data() {
        let mut b = NetBuilder::new("t");
        let zero = b.constant(false);
        let q0 = b.dff(zero, None, false); // d = 0, init = 0: frozen at 0
        let q1 = b.dff(zero, None, true); // d = 0, init = 1: toggles once
        let y = b.input("Y");
        let r0 = b.and(q0, y);
        let r1 = b.and(q1, y);
        b.output("R0", r0);
        b.output("R1", r1);
        let nl = b.finish();
        let (opt, _) = const_propagate(&nl, &OptAssumptions::none());
        // q0's reader is rewired onto the constant; q1's is not.
        assert_eq!(opt.gates[r0 as usize], Gate::And(zero, y));
        assert_eq!(opt.gates[r1 as usize], Gate::And(q1, y));
        // The folded DFF itself is never retyped.
        assert!(matches!(opt.gates[q0 as usize], Gate::Dff { .. }));
        opt.verify().unwrap();
    }

    #[test]
    fn stabilize_func_folds_to_zero_when_brv_inputs_are_tied() {
        let mut b = NetBuilder::new("t");
        let sels: Vec<_> = (0..3).map(|i| b.input(&format!("S{i}"))).collect();
        let brvs: Vec<_> = (0..8).map(|i| b.input(&format!("B{i}"))).collect();
        let mut ins = sels.clone();
        ins.extend_from_slice(&brvs);
        let outs = b.macro_inst(MacroKind::StabilizeFunc, ins);
        let y = b.input("Y");
        let r = b.and(outs[0], y);
        b.output("R", r);
        let nl = b.finish();
        let (opt, _) = const_propagate(&nl, &OptAssumptions::tied_low(brvs.clone()));
        // OUT is an 8:1 mux over all-zero data: constant 0 for any select
        // and the reader moves to a const net, leaving the pin unread.
        let zero = match opt.gates[r as usize] {
            Gate::And(c, yy) => {
                assert_eq!(yy, y);
                c
            }
            ref g => panic!("expected And, got {g:?}"),
        };
        assert_eq!(opt.gates[zero as usize], Gate::Const(false));
        // The pin net itself keeps its MacroOut gate (pin-table safety).
        assert!(matches!(opt.gates[outs[0] as usize], Gate::MacroOut { .. }));
        // DCE then drops the whole instance and the tied inputs.
        let (dce, remap) = eliminate_dead(&opt, &KeepSet::new());
        assert_eq!(remap.new_macro_count(), 0);
        for &bn in &brvs {
            assert_eq!(remap.net(bn), None);
        }
        dce.verify().unwrap();
    }

    #[test]
    fn keep_set_roots_liveness_like_an_output() {
        let mut b = NetBuilder::new("t");
        let x = b.input("X");
        let y = b.input("Y");
        let dead = b.and(x, y);
        let kept = b.or(x, y);
        b.output("X2", x);
        let nl = b.finish();
        let (dce, remap) = eliminate_dead(&nl, &KeepSet::from_nets([kept]));
        assert_eq!(remap.net(dead), None, "unread and unkept: removed");
        let new_kept = remap.net(kept).expect("kept net survives");
        assert!(matches!(dce.gates[new_kept as usize], Gate::Or(..)));
        assert!(remap.net(y).is_some(), "read by the kept net");
        dce.verify().unwrap();
    }

    #[test]
    fn locality_pass_is_a_pure_renumbering() {
        let mut b = NetBuilder::new("t");
        let xs = b.input_vec("X", 8);
        let count = b.popcount(&xs);
        let ge = b.ge_const(&count, 3);
        b.output("GE", ge);
        let nl = b.finish();
        let (re, remap) = schedule_locality(&nl).unwrap();
        re.verify().unwrap();
        assert_eq!(re.len(), nl.len());
        assert_eq!(re.census(), nl.census());
        assert!(remap.removed_nets().is_empty());
        // Bijection: every old gate appears at its new id with operands
        // mapped — checked here for kinds via the census and spot-checked
        // for the output port.
        let (_, old_out) = nl.outputs[0].clone();
        let (_, new_out) = re.outputs[0].clone();
        assert_eq!(remap.net(old_out), Some(new_out));
        // Levels keep their populations (renumbering never re-times).
        let old_levels: Vec<usize> =
            nl.levelize_buckets().unwrap().iter().map(|l| l.len()).collect();
        let new_levels: Vec<usize> =
            re.levelize_buckets().unwrap().iter().map(|l| l.len()).collect();
        assert_eq!(old_levels, new_levels);
        // New ids inside each level are contiguous ascending.
        let buckets = re.levelize_buckets().unwrap();
        for level in &buckets {
            for pair in level.windows(2) {
                assert_eq!(pair[1], pair[0] + 1, "level ids contiguous");
            }
        }
    }

    #[test]
    fn zero_assumption_pipeline_is_a_structural_noop_on_live_const_free_logic() {
        // No Const gates, no dead nets, no assumptions: ConstProp and
        // DeadCode must return the netlist byte-for-byte with identity
        // remaps.
        let mut b = NetBuilder::new("t");
        let xs = b.input_vec("X", 4);
        let n0 = b.and(xs[0], xs[1]);
        let n1 = b.xor(xs[2], xs[3]);
        let n2 = b.mux(n0, n1, xs[0]);
        let q = b.dff(n2, Some(xs[1]), false);
        let outs = b.macro_inst(MacroKind::Pulse2Edge, vec![q]);
        b.output("OUT", outs[0]);
        let nl = b.finish();
        let (cp, r1) = const_propagate(&nl, &OptAssumptions::none());
        assert!(r1.is_identity());
        assert_eq!(cp, nl);
        let (dce, r2) = eliminate_dead(&nl, &KeepSet::new());
        assert!(r2.is_identity());
        assert_eq!(dce, nl);
    }

    #[test]
    fn remap_compose_and_translate() {
        let a = NetRemap {
            net_map: vec![Some(1), None, Some(0)],
            macro_map: vec![Some(0)],
            new_nets: 2,
            new_macros: 1,
        };
        let b = NetRemap {
            net_map: vec![Some(0), Some(1)],
            macro_map: vec![None],
            new_nets: 2,
            new_macros: 0,
        };
        let c = a.then(&b);
        assert_eq!(c.net(0), Some(1));
        assert_eq!(c.net(1), None);
        assert_eq!(c.net(2), Some(0));
        assert_eq!(c.macro_inst(0), None);
        assert_eq!(c.removed_nets(), vec![1]);
        assert!(!c.is_identity());
        assert!(NetRemap::identity(4, 2).is_identity());
        let v = a.translate_per_net(&[10u64, 20, 30]);
        assert_eq!(v, vec![30, 10]);
    }
}
