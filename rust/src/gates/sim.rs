//! Levelized synchronous netlist simulator.
//!
//! Replaces the Spectre/Liberate functional-verification step of the
//! paper's flow: gate netlists (including hard-macro instances with
//! behavioral models) are simulated cycle by cycle against the golden TNN
//! model, and per-net toggle counts are accumulated for the
//! activity-based dynamic-power model in [`crate::ppa::power`].
//!
//! Semantics: single implicit clock; per cycle
//!   1. caller sets primary inputs,
//!   2. combinational settle in topological order (Mealy macro pins are
//!      re-evaluated from their behavioral models),
//!   3. outputs observable,
//!   4. `clock()` — DFFs capture, macro behavioral state advances.

use super::macros9::{self, MacroState};
use super::netlist::{Gate, NetId, Netlist};
use std::collections::HashMap;

/// Simulator instance bound to a netlist.
pub struct Simulator<'a> {
    nl: &'a Netlist,
    order: Vec<NetId>,
    values: Vec<bool>,
    macro_states: Vec<MacroState>,
    input_index: HashMap<&'a str, NetId>,
    output_index: HashMap<&'a str, NetId>,
    toggles: Vec<u64>,
    cycles: u64,
    // Stuck-at fault forces (empty when fault-free — the common case pays
    // one branch per settle). `force_mask[id]` marks a forced net,
    // `force_val[id]` its stuck value; `forced_nets` lists forced ids so the
    // settle-entry clamp (which covers Input/Dff/Const/Moore nets that are
    // not in the combinational schedule) doesn't scan every net.
    force_mask: Vec<bool>,
    force_val: Vec<bool>,
    forced_nets: Vec<NetId>,
    // scratch buffers
    dff_next: Vec<(usize, bool)>,
    macro_in: Vec<bool>,
    macro_out: Vec<bool>,
}

impl<'a> Simulator<'a> {
    /// Build a levelized simulator over `nl` (errors on true
    /// combinational cycles).
    pub fn new(nl: &'a Netlist) -> Result<Self, String> {
        let order = nl.levelize()?;
        let mut values = vec![false; nl.gates.len()];
        for (i, g) in nl.gates.iter().enumerate() {
            match g {
                Gate::Const(v) => values[i] = *v,
                Gate::Dff { init, .. } => values[i] = *init,
                _ => {}
            }
        }
        let macro_states = nl.macros.iter().map(|_| MacroState::default()).collect();
        let input_index = nl
            .inputs
            .iter()
            .map(|(name, id)| (name.as_str(), *id))
            .collect();
        let output_index = nl
            .outputs
            .iter()
            .map(|(name, id)| (name.as_str(), *id))
            .collect();
        Ok(Simulator {
            nl,
            order,
            toggles: vec![0; nl.gates.len()],
            values,
            macro_states,
            input_index,
            output_index,
            cycles: 0,
            force_mask: Vec::new(),
            force_val: Vec::new(),
            forced_nets: Vec::new(),
            dff_next: Vec::new(),
            macro_in: Vec::new(),
            macro_out: Vec::new(),
        })
    }

    /// Set a primary input by name. Panics on unknown names (tests want
    /// loud failures). Per-call `HashMap` lookup — steady-state stimulus
    /// should resolve ids once via [`Simulator::bind_inputs`] and use
    /// [`Simulator::set_input_net`].
    pub fn set_input(&mut self, name: &str, v: bool) {
        let id = *self
            .input_index
            .get(name)
            .unwrap_or_else(|| panic!("unknown input {name}"));
        self.values[id as usize] = v;
    }

    /// Set a primary input by net id (fast path for generated stimulus).
    pub fn set_input_net(&mut self, id: NetId, v: bool) {
        debug_assert!(matches!(self.nl.gates[id as usize], Gate::Input));
        self.values[id as usize] = v;
    }

    /// Current value of any net.
    pub fn get(&self, id: NetId) -> bool {
        self.values[id as usize]
    }

    /// Net id of a primary output by name (indexed — O(1)). Panics on
    /// unknown names (tests want loud failures).
    pub fn get_output_net(&self, name: &str) -> NetId {
        *self
            .output_index
            .get(name)
            .unwrap_or_else(|| panic!("unknown output {name}"))
    }

    /// Value of a primary output by name.
    pub fn get_output(&self, name: &str) -> bool {
        self.values[self.get_output_net(name) as usize]
    }

    /// Resolve primary-input names to net ids in one pass against the
    /// simulator's prebuilt name index. Errors on unknown names.
    pub fn bind_inputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        super::netlist::resolve_ports(&self.input_index, names, "input")
    }

    /// Resolve primary-output names to net ids in one pass against the
    /// simulator's prebuilt name index. Errors on unknown names.
    pub fn bind_outputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        super::netlist::resolve_ports(&self.output_index, names, "output")
    }

    /// Combinational settle (phase 2). Counts toggles against the previous
    /// settled values.
    // Index loop: the body calls `eval_net(&mut self)`, so an iterator
    // borrow of `order` cannot be held across it.
    #[allow(clippy::needless_range_loop)]
    pub fn settle(&mut self) {
        // Re-clamp forced nets first: Input/Dff/Const/Moore-pin nets are not
        // in the combinational schedule, so a clock-phase write (DFF commit,
        // Moore refresh) or caller stimulus would otherwise undo the force.
        for &id in &self.forced_nets {
            self.values[id as usize] = self.force_val[id as usize];
        }
        let clamp = !self.forced_nets.is_empty();
        for k in 0..self.order.len() {
            let id = self.order[k];
            let mut new = self.eval_net(id);
            if clamp && self.force_mask[id as usize] {
                new = self.force_val[id as usize];
            }
            let old = self.values[id as usize];
            if new != old {
                self.toggles[id as usize] += 1;
                self.values[id as usize] = new;
            }
        }
    }

    fn eval_net(&mut self, id: NetId) -> bool {
        match self.nl.gates[id as usize] {
            Gate::Buf(a) => self.values[a as usize],
            Gate::Not(a) => !self.values[a as usize],
            Gate::And(a, b) => self.values[a as usize] && self.values[b as usize],
            Gate::Or(a, b) => self.values[a as usize] || self.values[b as usize],
            Gate::Xor(a, b) => self.values[a as usize] ^ self.values[b as usize],
            Gate::Mux(s, a, b) => {
                if self.values[s as usize] {
                    self.values[b as usize]
                } else {
                    self.values[a as usize]
                }
            }
            Gate::MacroOut { inst, pin } => {
                let m = &self.nl.macros[inst as usize];
                self.macro_in.clear();
                for &src in &m.inputs {
                    self.macro_in.push(self.values[src as usize]);
                }
                macros9::eval(
                    m.kind,
                    &self.macro_in,
                    &self.macro_states[inst as usize],
                    &mut self.macro_out,
                );
                self.macro_out[pin as usize]
            }
            Gate::Input | Gate::Const(_) | Gate::Dff { .. } => self.values[id as usize],
        }
    }

    /// Clock edge (phase 4): capture DFFs, advance macro state, then
    /// re-evaluate Moore macro outputs for the next cycle.
    pub fn clock(&mut self) {
        self.cycles += 1;
        // Capture all DFF next-values first (no ordering hazards).
        self.dff_next.clear();
        for (i, g) in self.nl.gates.iter().enumerate() {
            if let Gate::Dff { d, rst, init } = *g {
                let v = if rst.map_or(false, |r| self.values[r as usize]) {
                    init
                } else {
                    self.values[d as usize]
                };
                self.dff_next.push((i, v));
            }
        }
        // Advance macro behavioral state.
        for (inst, m) in self.nl.macros.iter().enumerate() {
            self.macro_in.clear();
            for &src in &m.inputs {
                self.macro_in.push(self.values[src as usize]);
            }
            macros9::step(m.kind, &self.macro_in, &mut self.macro_states[inst]);
        }
        for &(i, v) in &self.dff_next {
            if self.values[i] != v {
                self.toggles[i] += 1;
                self.values[i] = v;
            }
        }
        // Refresh Moore macro pins (state-only outputs) so they reflect the
        // new state before the next settle (Mealy pins are recomputed in
        // settle anyway, but Moore pins have no comb fan-in and would
        // otherwise go stale).
        for (inst, m) in self.nl.macros.iter().enumerate() {
            self.macro_in.clear();
            for &src in &m.inputs {
                self.macro_in.push(self.values[src as usize]);
            }
            macros9::eval(
                m.kind,
                &self.macro_in,
                &self.macro_states[inst],
                &mut self.macro_out,
            );
            for (pin, &net) in m.outputs.iter().enumerate() {
                if m.kind.pin_deps(pin as u8).is_empty() {
                    let v = self.macro_out[pin];
                    if self.values[net as usize] != v {
                        self.toggles[net as usize] += 1;
                        self.values[net as usize] = v;
                    }
                }
            }
        }
    }

    /// One full cycle: settle, then clock. Inputs must be set beforehand.
    pub fn cycle(&mut self) {
        self.settle();
        self.clock();
    }

    /// Simulated cycles so far.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Per-net toggle counts (for activity extraction).
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Average toggle rate (toggles per net per cycle) — the α activity
    /// factor used by the dynamic power model.
    pub fn activity(&self) -> f64 {
        super::mean_activity(&self.toggles, self.cycles)
    }

    /// Read a macro instance's behavioral state.
    pub fn macro_state(&self, inst: usize) -> &MacroState {
        &self.macro_states[inst]
    }

    /// Overwrite a macro instance's behavioral state (used e.g. to preload
    /// synaptic weights before a gate-level cross-check run).
    pub fn set_macro_state(&mut self, inst: usize, st: MacroState) {
        self.macro_states[inst] = st;
    }

    /// Force net `id` to a stuck-at `value` until [`Simulator::clear_faults`].
    /// The force is applied immediately, re-applied at every settle entry,
    /// and clamps the net's freshly evaluated value during settle, so the
    /// fault holds across [`Simulator::clock`] and
    /// [`Simulator::reset_state`].
    pub fn force_net(&mut self, id: NetId, value: bool) {
        if self.force_mask.is_empty() {
            self.force_mask = vec![false; self.nl.gates.len()];
            self.force_val = vec![false; self.nl.gates.len()];
        }
        if !self.force_mask[id as usize] {
            self.forced_nets.push(id);
        }
        self.force_mask[id as usize] = true;
        self.force_val[id as usize] = value;
        self.values[id as usize] = value;
    }

    /// One-shot single-event upset: invert the current value of net `id`.
    /// Call between [`Simulator::clock`] and the next settle; the flip
    /// persists on state nets (DFF outputs) and is swallowed by the next
    /// settle on combinational nets.
    pub fn flip_net(&mut self, id: NetId) {
        self.values[id as usize] = !self.values[id as usize];
    }

    /// One-shot single-event upset in a macro instance's behavioral state:
    /// invert state bit `bit` (see [`MacroKind::state_bits`]).
    ///
    /// [`MacroKind::state_bits`]: super::macros9::MacroKind::state_bits
    pub fn flip_macro_bit(&mut self, inst: usize, bit: u8) {
        let st = &self.macro_states[inst];
        self.macro_states[inst] = MacroState::from_bits(st.bits() ^ (1 << bit));
    }

    /// Remove all stuck-at forces (flips are one-shot and need no undo).
    pub fn clear_faults(&mut self) {
        self.force_mask.clear();
        self.force_val.clear();
        self.forced_nets.clear();
    }

    /// Reset all state (DFFs to init, macro states cleared, toggles kept).
    pub fn reset_state(&mut self) {
        for (i, g) in self.nl.gates.iter().enumerate() {
            if let Gate::Dff { init, .. } = g {
                self.values[i] = *init;
            }
        }
        for st in &mut self.macro_states {
            *st = MacroState::default();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::macros9::MacroKind;
    use super::super::netlist::NetBuilder;
    use super::*;

    #[test]
    fn comb_logic_settles() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.xor(a, c);
        b.output("x", x);
        let nl = b.finish();
        let mut sim = Simulator::new(&nl).unwrap();
        for (va, vb, want) in [(false, false, false), (true, false, true), (true, true, false)] {
            sim.set_input("a", va);
            sim.set_input("b", vb);
            sim.settle();
            assert_eq!(sim.get_output("x"), want);
        }
    }

    #[test]
    fn output_index_resolves_names_to_nets() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let n = b.not(a);
        b.output("n", n);
        b.output("a_thru", a);
        let nl = b.finish();
        let mut sim = Simulator::new(&nl).unwrap();
        assert_eq!(sim.get_output_net("n"), n);
        assert_eq!(sim.get_output_net("a_thru"), a);
        sim.set_input("a", true);
        sim.settle();
        assert!(!sim.get_output("n"));
        assert!(sim.get_output("a_thru"));
    }

    #[test]
    fn dff_delays_one_cycle_and_resets() {
        let mut b = NetBuilder::new("t");
        let d = b.input("d");
        let r = b.input("r");
        let q = b.dff(d, Some(r), false);
        b.output("q", q);
        let nl = b.finish();
        let mut sim = Simulator::new(&nl).unwrap();
        sim.set_input("d", true);
        sim.set_input("r", false);
        sim.settle();
        assert!(!sim.get_output("q"), "q lags d");
        sim.clock();
        sim.settle();
        assert!(sim.get_output("q"));
        sim.set_input("r", true);
        sim.cycle();
        sim.settle();
        assert!(!sim.get_output("q"), "sync reset clears");
    }

    #[test]
    fn sticky_dff_latches_until_reset() {
        let mut b = NetBuilder::new("t");
        let s = b.input("s");
        let r = b.input("r");
        let q = b.sticky_dff(s, r);
        b.output("q", q);
        let nl = b.finish();
        let mut sim = Simulator::new(&nl).unwrap();
        sim.set_input("s", false);
        sim.set_input("r", false);
        sim.cycle();
        sim.settle();
        assert!(!sim.get_output("q"), "starts clear");
        sim.set_input("s", true);
        sim.cycle();
        sim.set_input("s", false);
        sim.settle();
        assert!(sim.get_output("q"), "stays set after set pulse");
        sim.set_input("r", true);
        sim.cycle();
        sim.set_input("r", false);
        sim.settle();
        assert!(!sim.get_output("q"), "reset clears");
    }

    #[test]
    fn macro_instance_evaluates_behaviorally() {
        // pulse2edge as a hard macro inside a netlist.
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let outs = b.macro_inst(MacroKind::Pulse2Edge, vec![p, g]);
        b.output("edge", outs[0]);
        let nl = b.finish();
        let mut sim = Simulator::new(&nl).unwrap();
        sim.set_input("g", false);
        let mut hist = Vec::new();
        for t in 0..6 {
            sim.set_input("p", t == 2);
            sim.settle();
            hist.push(sim.get_output("edge"));
            sim.clock();
        }
        assert_eq!(hist, vec![false, false, true, true, true, true]);
    }

    #[test]
    fn stuck_at_force_holds_across_clock_and_clears() {
        let mut b = NetBuilder::new("t");
        let d = b.input("d");
        let q = b.dff(d, None, false);
        let n = b.not(q);
        b.output("q", q);
        b.output("n", n);
        let nl = b.finish();
        let mut sim = Simulator::new(&nl).unwrap();
        sim.force_net(q, true);
        sim.set_input("d", false);
        sim.settle();
        assert!(sim.get_output("q"), "forced high despite d=0");
        assert!(!sim.get_output("n"), "fault propagates through fan-out");
        sim.clock();
        sim.settle();
        assert!(sim.get_output("q"), "force survives the clock edge");
        sim.clear_faults();
        sim.clock();
        sim.settle();
        assert!(!sim.get_output("q"), "cleared fault releases the net");
    }

    #[test]
    fn seu_flip_persists_on_state_nets_only() {
        let mut b = NetBuilder::new("t");
        let d = b.input("d");
        let q = b.dff(d, None, false);
        let x = b.not(d);
        b.output("q", q);
        b.output("x", x);
        let nl = b.finish();
        let mut sim = Simulator::new(&nl).unwrap();
        sim.set_input("d", false);
        sim.cycle();
        sim.flip_net(q); // upset the DFF state bit
        sim.flip_net(x); // upset a combinational net
        sim.settle();
        assert!(sim.get_output("q"), "DFF upset persists through settle");
        assert!(sim.get_output("x"), "comb upset is recomputed away");
    }

    #[test]
    fn macro_expansion_matches_behavior_for_all_macros() {
        // For every macro: drive identical random stimulus into (a) a
        // netlist instantiating the hard macro and (b) its generic-gate
        // expansion; outputs must agree cycle by cycle.
        use crate::gates::macros9::{expand, ALL_MACROS};
        use crate::util::Rng64;
        for kind in ALL_MACROS {
            let n_in = kind.input_pins().len();
            // hard-macro netlist
            let mut bm = NetBuilder::new("m");
            let ins_m: Vec<_> = (0..n_in).map(|i| bm.input(&format!("i{i}"))).collect();
            let outs_m = bm.macro_inst(kind, ins_m.clone());
            for (k, &o) in outs_m.iter().enumerate() {
                bm.output(&format!("o{k}"), o);
            }
            let nl_m = bm.finish();
            // expansion netlist
            let mut be = NetBuilder::new("e");
            let ins_e: Vec<_> = (0..n_in).map(|i| be.input(&format!("i{i}"))).collect();
            let outs_e = expand(kind, &mut be, &ins_e);
            for (k, &o) in outs_e.iter().enumerate() {
                be.output(&format!("o{k}"), o);
            }
            let nl_e = be.finish();

            let mut sim_m = Simulator::new(&nl_m).unwrap();
            let mut sim_e = Simulator::new(&nl_e).unwrap();
            let mut rng = Rng64::seed_from_u64(0xC0FFEE ^ kind as u64);
            let grst_pin = kind
                .input_pins()
                .iter()
                .position(|&p| p == "GRST");
            for cycle in 0..400u32 {
                // Periodic gamma structure: reset every 16 cycles.
                let gamma_end = cycle % 16 == 15;
                for i in 0..n_in {
                    let v = if Some(i) == grst_pin {
                        gamma_end
                    } else {
                        rng.gen_bool(0.3)
                    };
                    sim_m.set_input(&format!("i{i}"), v);
                    sim_e.set_input(&format!("i{i}"), v);
                }
                sim_m.settle();
                sim_e.settle();
                for k in 0..kind.output_pins().len() {
                    assert_eq!(
                        sim_m.get_output(&format!("o{k}")),
                        sim_e.get_output(&format!("o{k}")),
                        "{kind:?} pin {k} cycle {cycle}"
                    );
                }
                sim_m.clock();
                sim_e.clock();
            }
        }
    }
}
