//! Structural Verilog interchange: deterministic emission of any
//! [`Netlist`] as synthesizable structural Verilog, and a parser for the
//! emitted subset that rebuilds the exact netlist — the repo's first
//! externally-consumable artifact (the EDA-tool handoff the paper's flow
//! claims hinge on).
//!
//! # The `tnn7-v1` naming contract (normative)
//!
//! Emission is a pure function of the netlist — byte-reproducible — and
//! the text obeys a frozen naming contract so the parser can rebuild the
//! *exact* structure (same net ids, same instance indices, same port
//! order):
//!
//! * net `k` is named `n<k>`; macro instance `k` is named `m<k>`; the
//!   single implicit clock is the port `clk`;
//! * declared port names are preserved: a name is emitted verbatim iff it
//!   is a *simple identifier* (`[A-Za-z_][A-Za-z0-9_]*`) that is not a
//!   reserved word, not `clk`, and not of the reserved net/instance shape
//!   `n<digits>` / `m<digits>`; every other name is emitted as a Verilog
//!   escaped identifier (`\name` + mandatory trailing space);
//! * statement order is frozen: module header (clk, then inputs in
//!   declaration order, then outputs), net declarations in id order
//!   (`wire n<k>;` for combinational nets, `reg n<k> = 1'b<init>;` for
//!   DFFs), input-port binds in declaration order, gate statements in id
//!   order, macro instances in index order, output-port binds;
//! * gates map to `assign` forms (`Mux(s, a, b)` emits `s ? b : a`),
//!   [`Gate::Dff`] to a guarded `always @(posedge clk)` block
//!   (synchronous reset to the declared initializer), and each TNN7 macro
//!   to a module instantiation of its library cell
//!   ([`MacroKind::cell_name`]) with named pin connections — sequential
//!   cells take `.CLK(clk)` as their first connection.
//!
//! [`emit_flat`] is the behavioral fallback for flows without the TNN7
//! library: every macro instance is replaced by its generic-gate
//! expansion ([`super::macros9::expand`]) before emission, so the text
//! contains no cell instances (net ids are *not* preserved — flat
//! equivalence is behavioral, checked on the ports).
//!
//! Round-trip conformance — parse(emit(nl)) simulates bit-identically
//! (values *and* toggle counts) to `nl` on every simulator backend — is
//! the fourth differential leg of `harness::conformance`, pinned by
//! [`roundtrip_mismatches`], `tests/verilog.rs`, randomized property
//! tests, and the no-toolchain Python port
//! (`scripts/fuzz_verilog_roundtrip.py`).
//!
//! ```
//! use tnn7::gates::{NetBuilder, verilog};
//! let mut b = NetBuilder::new("toy");
//! let a = b.input("a");
//! let q = b.dff(a, None, false);
//! b.output("q", q);
//! let nl = b.finish();
//! let text = verilog::emit(&nl).unwrap();
//! let back = verilog::parse(&text).unwrap();
//! assert_eq!(back.netlist, nl);
//! assert_eq!(verilog::emit(&back.netlist).unwrap(), text); // fixpoint
//! ```

use super::macros9::{self, MacroKind};
use super::netlist::{Gate, MacroInst, NetBuilder, NetId, Netlist};
use std::collections::{HashMap, HashSet};
use std::fmt::Write as _;

/// Words that can never be emitted verbatim as a port name (they would
/// collide with the emitted subset's own vocabulary); such names are
/// escaped instead. Part of the normative `tnn7-v1` contract.
const RESERVED: &[&str] = &[
    "module", "endmodule", "input", "output", "inout", "wire", "reg", "assign", "always",
    "posedge", "negedge", "if", "else", "begin", "end", "clk",
];

/// Is `s` a simple identifier: `[A-Za-z_][A-Za-z0-9_]*`?
fn simple_ident(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Does `s` have the reserved net/instance shape `n<digits>` / `m<digits>`?
fn net_like(s: &str) -> bool {
    let mut chars = s.chars();
    matches!(chars.next(), Some('n') | Some('m'))
        && s.len() > 1
        && chars.all(|c| c.is_ascii_digit())
}

/// Render a port name under the naming contract: verbatim when simple and
/// unreserved, escaped-identifier form otherwise. Errors on names the
/// escaped form cannot carry (empty, whitespace, backslash).
fn render_port(name: &str) -> Result<String, String> {
    if name.is_empty() || name.contains(|c: char| c.is_whitespace() || c == '\\') {
        return Err(format!(
            "port name {name:?} cannot be emitted (empty or contains whitespace/backslash)"
        ));
    }
    if simple_ident(name) && !RESERVED.contains(&name) && !net_like(name) {
        Ok(name.to_string())
    } else {
        Ok(format!("\\{name} "))
    }
}

/// Emit `nl` as `tnn7-v1` structural Verilog (see the module docs for the
/// normative contract). The netlist is [`Netlist::verify`]-ed first; the
/// remaining error cases are naming problems (an `Input` gate with no
/// port, duplicate port names, a non-identifier module name).
pub fn emit(nl: &Netlist) -> Result<String, String> {
    nl.verify()?;
    if !simple_ident(&nl.name) || net_like(&nl.name) || RESERVED.contains(&nl.name.as_str()) {
        return Err(format!(
            "module name {:?} is not a plain unreserved identifier",
            nl.name
        ));
    }
    let n = nl.gates.len();
    // Port sanity: unique names, every Input gate reachable from exactly
    // one input port (the bind statement is its only driver).
    let mut seen: HashSet<&str> = HashSet::new();
    for (name, _) in nl.inputs.iter().chain(nl.outputs.iter()) {
        if !seen.insert(name.as_str()) {
            return Err(format!("duplicate port name {name:?}"));
        }
    }
    let mut input_port: Vec<Option<&str>> = vec![None; n];
    for (name, id) in &nl.inputs {
        let slot = &mut input_port[*id as usize];
        if slot.is_some() {
            return Err(format!("two input ports bound to net n{id}"));
        }
        *slot = Some(name.as_str());
    }
    for (i, g) in nl.gates.iter().enumerate() {
        if matches!(g, Gate::Input) && input_port[i].is_none() {
            return Err(format!("input net n{i} has no port name"));
        }
    }

    let mut s = String::new();
    let _ = writeln!(
        s,
        "// tnn7-v1 {}: {} nets, {} macros",
        nl.name,
        n,
        nl.macros.len()
    );
    let _ = writeln!(s, "module {} (", nl.name);
    let mut ports: Vec<String> = vec!["  input wire clk".to_string()];
    for (name, _) in &nl.inputs {
        ports.push(format!("  input wire {}", render_port(name)?));
    }
    for (name, _) in &nl.outputs {
        ports.push(format!("  output wire {}", render_port(name)?));
    }
    let _ = writeln!(s, "{}\n);", ports.join(",\n"));

    // Net declarations, id order.
    for (i, g) in nl.gates.iter().enumerate() {
        match g {
            Gate::Dff { init, .. } => {
                let _ = writeln!(s, "  reg n{i} = 1'b{};", *init as u8);
            }
            _ => {
                let _ = writeln!(s, "  wire n{i};");
            }
        }
    }
    // Input-port binds, declaration order.
    for (name, id) in &nl.inputs {
        let _ = writeln!(s, "  assign n{id} = {};", render_port(name)?);
    }
    // Gate statements, id order.
    for (i, g) in nl.gates.iter().enumerate() {
        match *g {
            Gate::Input | Gate::MacroOut { .. } => {}
            Gate::Const(v) => {
                let _ = writeln!(s, "  assign n{i} = 1'b{};", v as u8);
            }
            Gate::Buf(a) => {
                let _ = writeln!(s, "  assign n{i} = n{a};");
            }
            Gate::Not(a) => {
                let _ = writeln!(s, "  assign n{i} = ~n{a};");
            }
            Gate::And(a, b) => {
                let _ = writeln!(s, "  assign n{i} = n{a} & n{b};");
            }
            Gate::Or(a, b) => {
                let _ = writeln!(s, "  assign n{i} = n{a} | n{b};");
            }
            Gate::Xor(a, b) => {
                let _ = writeln!(s, "  assign n{i} = n{a} ^ n{b};");
            }
            Gate::Mux(sel, a, b) => {
                let _ = writeln!(s, "  assign n{i} = n{sel} ? n{b} : n{a};");
            }
            Gate::Dff { d, rst, init } => match rst {
                Some(r) => {
                    let _ = writeln!(
                        s,
                        "  always @(posedge clk) if (n{r}) n{i} <= 1'b{}; else n{i} <= n{d};",
                        init as u8
                    );
                }
                None => {
                    let _ = writeln!(s, "  always @(posedge clk) n{i} <= n{d};");
                }
            },
        }
    }
    // Macro instances, index order: named pin connections in pin-table
    // order, `.CLK(clk)` first for sequential cells.
    for (k, m) in nl.macros.iter().enumerate() {
        let mut pins: Vec<String> = Vec::new();
        if m.kind.is_sequential() {
            pins.push(".CLK(clk)".to_string());
        }
        for (pin, &net) in m.kind.input_pins().iter().zip(&m.inputs) {
            pins.push(format!(".{pin}(n{net})"));
        }
        for (pin, &net) in m.kind.output_pins().iter().zip(&m.outputs) {
            pins.push(format!(".{pin}(n{net})"));
        }
        let _ = writeln!(s, "  {} m{k} ({});", m.kind.cell_name(), pins.join(", "));
    }
    // Output-port binds, declaration order.
    for (name, id) in &nl.outputs {
        let _ = writeln!(s, "  assign {} = n{id};", render_port(name)?);
    }
    s.push_str("endmodule\n");
    Ok(s)
}

/// Replace every macro instance with its generic-gate expansion
/// ([`super::macros9::expand`]) — the behavioral-RTL form the ASAP7
/// baseline flow synthesizes. Net ids are renumbered (the expansion
/// allocates fresh nets); port names and order are preserved, so flat
/// equivalence with the original is behavioral on the ports.
pub fn flatten(nl: &Netlist) -> Result<Netlist, String> {
    nl.verify()?;
    let n = nl.gates.len();
    let mut input_port: Vec<Option<&str>> = vec![None; n];
    for (name, id) in &nl.inputs {
        if input_port[*id as usize].is_some() {
            return Err(format!("two input ports bound to net n{id}"));
        }
        input_port[*id as usize] = Some(name.as_str());
    }
    let mut b = NetBuilder::new(&nl.name);
    // Pass 1: one placeholder per net, preserving relative order — inputs
    // and constants directly, DFFs as pending cells, everything else as a
    // forward wire (netlists may reference forward through wires/DFFs).
    let mut map: Vec<NetId> = Vec::with_capacity(n);
    for (i, g) in nl.gates.iter().enumerate() {
        let new = match g {
            Gate::Input => b.input(
                input_port[i].ok_or_else(|| format!("input net n{i} has no port name"))?,
            ),
            Gate::Const(v) => b.constant(*v),
            Gate::Dff { .. } => b.dff_cell_vec(1)[0],
            _ => b.wire(),
        };
        map.push(new);
    }
    // Pass 2: build the real logic behind each placeholder.
    for (i, g) in nl.gates.iter().enumerate() {
        let w = map[i];
        match *g {
            Gate::Input | Gate::Const(_) | Gate::MacroOut { .. } => {}
            Gate::Buf(a) => b.connect(w, map[a as usize]),
            Gate::Not(a) => {
                let x = b.not(map[a as usize]);
                b.connect(w, x);
            }
            Gate::And(a, c) => {
                let x = b.and(map[a as usize], map[c as usize]);
                b.connect(w, x);
            }
            Gate::Or(a, c) => {
                let x = b.or(map[a as usize], map[c as usize]);
                b.connect(w, x);
            }
            Gate::Xor(a, c) => {
                let x = b.xor(map[a as usize], map[c as usize]);
                b.connect(w, x);
            }
            Gate::Mux(sel, a, c) => {
                let x = b.mux(map[sel as usize], map[a as usize], map[c as usize]);
                b.connect(w, x);
            }
            Gate::Dff { d, rst, init } => {
                b.patch_dff_vec(
                    &[w],
                    &[map[d as usize]],
                    rst.map(|r| map[r as usize]),
                    init as u64,
                );
            }
        }
    }
    for m in &nl.macros {
        let ins: Vec<NetId> = m.inputs.iter().map(|&a| map[a as usize]).collect();
        let outs = macros9::expand(m.kind, &mut b, &ins);
        debug_assert_eq!(outs.len(), m.outputs.len());
        for (&old, &new) in m.outputs.iter().zip(&outs) {
            b.connect(map[old as usize], new);
        }
    }
    for (name, id) in &nl.outputs {
        b.output(name, map[*id as usize]);
    }
    let flat = b.finish();
    flat.verify()?;
    Ok(flat)
}

/// [`emit`] the macro-free [`flatten`]-ed form of `nl` — the `--flat`
/// behavioral fallback of `tnn7 emit-verilog`.
pub fn emit_flat(nl: &Netlist) -> Result<String, String> {
    emit(&flatten(nl)?)
}

// ---------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------

/// Structured parse error: 1-based line and column plus a message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VerilogError {
    /// 1-based source line of the offending token.
    pub line: usize,
    /// 1-based source column of the offending token.
    pub col: usize,
    /// What went wrong.
    pub msg: String,
}

impl std::fmt::Display for VerilogError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}, col {}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for VerilogError {}

/// A parsed `tnn7-v1` module: the rebuilt netlist plus the flat port/name
/// map (every declared port, both directions, name → net id). The
/// netlist's own `inputs` / `outputs` tables carry the declaration order.
#[derive(Clone, Debug)]
pub struct ParsedModule {
    /// The rebuilt netlist (structurally identical to the emitted one).
    pub netlist: Netlist,
    /// Port name → bound net id, inputs and outputs together.
    pub ports: HashMap<String, NetId>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum Tok {
    /// Identifier; `escaped` distinguishes `\n5 ` (a port named "n5")
    /// from the net reference `n5`.
    Ident { name: String, escaped: bool },
    /// `1'b0` / `1'b1`.
    Lit(bool),
    /// Single-character punctuation: `( ) ; , . = ~ & | ^ ? : @`.
    Punct(char),
    /// `<=` (non-blocking assignment).
    LtEq,
}

#[derive(Clone, Debug)]
struct Token {
    tok: Tok,
    line: usize,
    col: usize,
}

fn err(line: usize, col: usize, msg: impl Into<String>) -> VerilogError {
    VerilogError {
        line,
        col,
        msg: msg.into(),
    }
}

fn lex(src: &str) -> Result<Vec<Token>, VerilogError> {
    let mut toks = Vec::new();
    let bytes = src.as_bytes();
    let (mut i, mut line, mut col) = (0usize, 1usize, 1usize);
    while i < bytes.len() {
        let c = bytes[i] as char;
        let (tl, tc) = (line, col);
        match c {
            '\n' => {
                i += 1;
                line += 1;
                col = 1;
            }
            c if c.is_ascii_whitespace() => {
                i += 1;
                col += 1;
            }
            '/' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'/' {
                    while i < bytes.len() && bytes[i] != b'\n' {
                        i += 1;
                    }
                    // newline handled by the loop; col reset there
                    col += 2; // position tracking not needed inside comments
                } else {
                    return Err(err(tl, tc, "unexpected character '/'"));
                }
            }
            '\\' => {
                let start = i + 1;
                let mut j = start;
                while j < bytes.len() && !(bytes[j] as char).is_ascii_whitespace() {
                    j += 1;
                }
                if j == start {
                    return Err(err(tl, tc, "empty escaped identifier"));
                }
                let name = src[start..j].to_string();
                toks.push(Token {
                    tok: Tok::Ident { name, escaped: true },
                    line: tl,
                    col: tc,
                });
                col += j - i;
                i = j;
            }
            '1' => {
                // The only literal shape in the subset is 1'b0 / 1'b1.
                if i + 3 < bytes.len()
                    && bytes[i + 1] == b'\''
                    && bytes[i + 2] == b'b'
                    && (bytes[i + 3] == b'0' || bytes[i + 3] == b'1')
                {
                    toks.push(Token {
                        tok: Tok::Lit(bytes[i + 3] == b'1'),
                        line: tl,
                        col: tc,
                    });
                    i += 4;
                    col += 4;
                } else {
                    return Err(err(tl, tc, "malformed literal (expected 1'b0 or 1'b1)"));
                }
            }
            '<' => {
                if i + 1 < bytes.len() && bytes[i + 1] == b'=' {
                    toks.push(Token {
                        tok: Tok::LtEq,
                        line: tl,
                        col: tc,
                    });
                    i += 2;
                    col += 2;
                } else {
                    return Err(err(tl, tc, "unexpected character '<'"));
                }
            }
            '(' | ')' | ';' | ',' | '.' | '=' | '~' | '&' | '|' | '^' | '?' | ':' | '@' => {
                toks.push(Token {
                    tok: Tok::Punct(c),
                    line: tl,
                    col: tc,
                });
                i += 1;
                col += 1;
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                let mut j = i;
                while j < bytes.len() {
                    let cj = bytes[j] as char;
                    if cj.is_ascii_alphanumeric() || cj == '_' {
                        j += 1;
                    } else {
                        break;
                    }
                }
                toks.push(Token {
                    tok: Tok::Ident {
                        name: src[start..j].to_string(),
                        escaped: false,
                    },
                    line: tl,
                    col: tc,
                });
                col += j - i;
                i = j;
            }
            other => return Err(err(tl, tc, format!("unexpected character {other:?}"))),
        }
    }
    Ok(toks)
}

/// One declared net during parsing.
struct NetSlot {
    is_reg: bool,
    init: bool,
    line: usize,
    col: usize,
    driver: Option<Gate>,
}

/// One declared port during parsing.
struct PortSlot {
    name: String,
    net: Option<NetId>,
    line: usize,
    col: usize,
}

struct Cursor {
    toks: Vec<Token>,
    pos: usize,
    eof_line: usize,
}

impl Cursor {
    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<Token, VerilogError> {
        let t = self
            .toks
            .get(self.pos)
            .cloned()
            .ok_or_else(|| err(self.eof_line, 1, "unexpected end of input"))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect_punct(&mut self, c: char) -> Result<(), VerilogError> {
        let t = self.next()?;
        if t.tok == Tok::Punct(c) {
            Ok(())
        } else {
            Err(err(t.line, t.col, format!("expected {c:?}")))
        }
    }

    fn expect_lteq(&mut self) -> Result<(), VerilogError> {
        let t = self.next()?;
        if t.tok == Tok::LtEq {
            Ok(())
        } else {
            Err(err(t.line, t.col, "expected \"<=\""))
        }
    }

    fn expect_keyword(&mut self, kw: &str) -> Result<(), VerilogError> {
        let t = self.next()?;
        match &t.tok {
            Tok::Ident { name, escaped: false } if name == kw => Ok(()),
            _ => Err(err(t.line, t.col, format!("expected {kw:?}"))),
        }
    }

    fn expect_lit(&mut self) -> Result<(bool, usize, usize), VerilogError> {
        let t = self.next()?;
        match t.tok {
            Tok::Lit(v) => Ok((v, t.line, t.col)),
            _ => Err(err(t.line, t.col, "expected 1'b0 or 1'b1")),
        }
    }

    /// Any identifier (simple or escaped); returns (name, escaped, line, col).
    fn expect_ident(&mut self) -> Result<(String, bool, usize, usize), VerilogError> {
        let t = self.next()?;
        match t.tok {
            Tok::Ident { name, escaped } => Ok((name, escaped, t.line, t.col)),
            _ => Err(err(t.line, t.col, "expected an identifier")),
        }
    }
}

/// Decode a (non-escaped) `n<k>` / `m<k>` identifier into its index.
fn decode_indexed(name: &str, prefix: char) -> Option<usize> {
    let mut chars = name.chars();
    if chars.next() != Some(prefix) {
        return None;
    }
    let digits = &name[1..];
    if digits.is_empty() || !digits.chars().all(|c| c.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Parse `tnn7-v1` structural Verilog (the [`emit`]-ed subset) back into a
/// [`Netlist`] plus the port/name map. Errors carry the 1-based line and
/// column of the offending token; structural violations — dangling
/// (never-driven) nets, duplicate drivers, unbound or unknown ports,
/// malformed macro instances — are rejected with a specific message.
pub fn parse(src: &str) -> Result<ParsedModule, VerilogError> {
    let eof_line = src.lines().count() + 1;
    let mut cur = Cursor {
        toks: lex(src)?,
        pos: 0,
        eof_line,
    };

    // --- module header -------------------------------------------------
    cur.expect_keyword("module")?;
    let (name, escaped, nl_, nc_) = cur.expect_ident()?;
    if escaped || !simple_ident(&name) {
        return Err(err(nl_, nc_, "module name must be a simple identifier"));
    }
    cur.expect_punct('(')?;
    // First port is always the implicit clock.
    cur.expect_keyword("input")?;
    cur.expect_keyword("wire")?;
    let (clk, clk_esc, cl, cc) = cur.expect_ident()?;
    if clk_esc || clk != "clk" {
        return Err(err(cl, cc, "first port must be `input wire clk`"));
    }
    let mut in_ports: Vec<PortSlot> = Vec::new();
    let mut out_ports: Vec<PortSlot> = Vec::new();
    loop {
        let t = cur.next()?;
        match t.tok {
            Tok::Punct(')') => break,
            Tok::Punct(',') => {
                let dir = cur.expect_ident()?;
                let is_input = match dir.0.as_str() {
                    "input" if !dir.1 => true,
                    "output" if !dir.1 => false,
                    _ => return Err(err(dir.2, dir.3, "expected \"input\" or \"output\"")),
                };
                cur.expect_keyword("wire")?;
                let (pname, _esc, pl, pc) = cur.expect_ident()?;
                if in_ports
                    .iter()
                    .chain(out_ports.iter())
                    .any(|p| p.name == pname)
                {
                    return Err(err(pl, pc, format!("duplicate port name {pname:?}")));
                }
                let slot = PortSlot {
                    name: pname,
                    net: None,
                    line: pl,
                    col: pc,
                };
                if is_input {
                    in_ports.push(slot);
                } else {
                    out_ports.push(slot);
                }
            }
            _ => return Err(err(t.line, t.col, "expected ',' or ')' in port list")),
        }
    }
    cur.expect_punct(';')?;

    // --- body ----------------------------------------------------------
    let mut nets: Vec<NetSlot> = Vec::new();
    let mut macros: Vec<MacroInst> = Vec::new();

    // Resolve an already-declared net reference.
    fn net_ref(nets: &[NetSlot], cur: &mut Cursor) -> Result<NetId, VerilogError> {
        let (nm, esc, l, c) = cur.expect_ident()?;
        let k = (!esc)
            .then(|| decode_indexed(&nm, 'n'))
            .flatten()
            .ok_or_else(|| err(l, c, format!("expected a net identifier, found {nm:?}")))?;
        if k >= nets.len() {
            return Err(err(l, c, format!("undeclared net n{k}")));
        }
        Ok(k as NetId)
    }
    // Install a driver, rejecting duplicates and wire/reg statement-kind
    // mismatches.
    fn drive(
        nets: &mut [NetSlot],
        k: NetId,
        g: Gate,
        l: usize,
        c: usize,
    ) -> Result<(), VerilogError> {
        let slot = &mut nets[k as usize];
        if slot.driver.is_some() {
            return Err(err(l, c, format!("duplicate driver for net n{k}")));
        }
        if slot.is_reg != matches!(g, Gate::Dff { .. }) {
            let (decl, stmt) = if slot.is_reg {
                ("reg", "a continuous driver")
            } else {
                ("wire", "an always block")
            };
            return Err(err(l, c, format!("net n{k} is declared {decl} but driven by {stmt}")));
        }
        slot.driver = Some(g);
        Ok(())
    }

    loop {
        let t = cur.next()?;
        let (sl, sc) = (t.line, t.col);
        let kw = match t.tok {
            Tok::Ident { ref name, escaped: false } => name.clone(),
            _ => return Err(err(sl, sc, "expected a statement keyword or cell name")),
        };
        match kw.as_str() {
            "endmodule" => break,
            "wire" | "reg" => {
                let (nm, esc, l, c) = cur.expect_ident()?;
                let k = (!esc)
                    .then(|| decode_indexed(&nm, 'n'))
                    .flatten()
                    .ok_or_else(|| err(l, c, format!("expected a net name, found {nm:?}")))?;
                if k != nets.len() {
                    return Err(err(
                        l,
                        c,
                        format!("net declarations must be contiguous (expected n{})", nets.len()),
                    ));
                }
                let (is_reg, init) = if kw == "reg" {
                    cur.expect_punct('=')?;
                    let (v, _, _) = cur.expect_lit()?;
                    (true, v)
                } else {
                    (false, false)
                };
                cur.expect_punct(';')?;
                nets.push(NetSlot {
                    is_reg,
                    init,
                    line: l,
                    col: c,
                    driver: None,
                });
            }
            "assign" => {
                let (lhs, lhs_esc, ll, lc) = cur.expect_ident()?;
                let lhs_net = (!lhs_esc).then(|| decode_indexed(&lhs, 'n')).flatten();
                cur.expect_punct('=')?;
                match lhs_net {
                    Some(k) if k < nets.len() => {
                        let k = k as NetId;
                        // RHS: literal, port bind, or a gate expression.
                        let rt = cur.next()?;
                        let gate = match rt.tok {
                            Tok::Lit(v) => {
                                cur.expect_punct(';')?;
                                Gate::Const(v)
                            }
                            Tok::Punct('~') => {
                                let a = net_ref(&nets, &mut cur)?;
                                cur.expect_punct(';')?;
                                Gate::Not(a)
                            }
                            Tok::Ident { ref name, escaped } => {
                                let a = (!escaped).then(|| decode_indexed(name, 'n')).flatten();
                                match a {
                                    Some(a) if a < nets.len() => {
                                        let a = a as NetId;
                                        let op = cur.next()?;
                                        match op.tok {
                                            Tok::Punct(';') => Gate::Buf(a),
                                            Tok::Punct('&') => {
                                                let b2 = net_ref(&nets, &mut cur)?;
                                                cur.expect_punct(';')?;
                                                Gate::And(a, b2)
                                            }
                                            Tok::Punct('|') => {
                                                let b2 = net_ref(&nets, &mut cur)?;
                                                cur.expect_punct(';')?;
                                                Gate::Or(a, b2)
                                            }
                                            Tok::Punct('^') => {
                                                let b2 = net_ref(&nets, &mut cur)?;
                                                cur.expect_punct(';')?;
                                                Gate::Xor(a, b2)
                                            }
                                            Tok::Punct('?') => {
                                                // sel ? b : a  ⇒  Mux(sel, a, b)
                                                let bb = net_ref(&nets, &mut cur)?;
                                                cur.expect_punct(':')?;
                                                let aa = net_ref(&nets, &mut cur)?;
                                                cur.expect_punct(';')?;
                                                Gate::Mux(a, aa, bb)
                                            }
                                            _ => {
                                                return Err(err(
                                                    op.line,
                                                    op.col,
                                                    "expected ';' or a binary operator",
                                                ))
                                            }
                                        }
                                    }
                                    Some(a) => {
                                        return Err(err(
                                            rt.line,
                                            rt.col,
                                            format!("undeclared net n{a}"),
                                        ))
                                    }
                                    None => {
                                        // Input-port bind: assign n<k> = <port>;
                                        let port = in_ports
                                            .iter_mut()
                                            .find(|p| p.name == *name)
                                            .ok_or_else(|| {
                                                err(
                                                    rt.line,
                                                    rt.col,
                                                    format!("unknown input port {name:?}"),
                                                )
                                            })?;
                                        if port.net.is_some() {
                                            return Err(err(
                                                rt.line,
                                                rt.col,
                                                format!("input port {name:?} bound twice"),
                                            ));
                                        }
                                        port.net = Some(k);
                                        cur.expect_punct(';')?;
                                        Gate::Input
                                    }
                                }
                            }
                            _ => return Err(err(rt.line, rt.col, "expected an expression")),
                        };
                        drive(&mut nets, k, gate, ll, lc)?;
                    }
                    Some(k) => return Err(err(ll, lc, format!("undeclared net n{k}"))),
                    None => {
                        // Output-port bind: assign <port> = n<k>;
                        let src_net = net_ref(&nets, &mut cur)?;
                        cur.expect_punct(';')?;
                        let port = out_ports
                            .iter_mut()
                            .find(|p| p.name == lhs)
                            .ok_or_else(|| {
                                err(ll, lc, format!("unknown output port {lhs:?}"))
                            })?;
                        if port.net.is_some() {
                            return Err(err(ll, lc, format!("output port {lhs:?} bound twice")));
                        }
                        port.net = Some(src_net);
                    }
                }
            }
            "always" => {
                cur.expect_punct('@')?;
                cur.expect_punct('(')?;
                cur.expect_keyword("posedge")?;
                cur.expect_keyword("clk")?;
                cur.expect_punct(')')?;
                let t2 = cur.next()?;
                match t2.tok {
                    Tok::Ident { ref name, escaped: false } if name == "if" => {
                        cur.expect_punct('(')?;
                        let rst = net_ref(&nets, &mut cur)?;
                        cur.expect_punct(')')?;
                        let (qn, _, ql, qc) = cur.expect_ident()?;
                        let q = decode_indexed(&qn, 'n')
                            .filter(|&k| k < nets.len())
                            .ok_or_else(|| err(ql, qc, format!("undeclared net {qn:?}")))?
                            as NetId;
                        cur.expect_lteq()?;
                        let (v, vl, vc) = cur.expect_lit()?;
                        if v != nets[q as usize].init {
                            return Err(err(
                                vl,
                                vc,
                                format!("reset value 1'b{} disagrees with n{q}'s initializer", v as u8),
                            ));
                        }
                        cur.expect_punct(';')?;
                        cur.expect_keyword("else")?;
                        let (qn2, _, q2l, q2c) = cur.expect_ident()?;
                        if qn2 != qn {
                            return Err(err(
                                q2l,
                                q2c,
                                "reset and data branches drive different nets",
                            ));
                        }
                        cur.expect_lteq()?;
                        let d = net_ref(&nets, &mut cur)?;
                        cur.expect_punct(';')?;
                        let init = nets[q as usize].init;
                        drive(&mut nets, q, Gate::Dff { d, rst: Some(rst), init }, ql, qc)?;
                    }
                    Tok::Ident { ref name, escaped: false } => {
                        let q = decode_indexed(name, 'n')
                            .filter(|&k| k < nets.len())
                            .ok_or_else(|| {
                                err(t2.line, t2.col, format!("undeclared net {name:?}"))
                            })? as NetId;
                        cur.expect_lteq()?;
                        let d = net_ref(&nets, &mut cur)?;
                        cur.expect_punct(';')?;
                        let init = nets[q as usize].init;
                        drive(&mut nets, q, Gate::Dff { d, rst: None, init }, t2.line, t2.col)?;
                    }
                    _ => return Err(err(t2.line, t2.col, "expected \"if\" or a net name")),
                }
            }
            cell => {
                // Macro instance: <cell> m<k> (.PIN(net), ...);
                let kind = MacroKind::from_cell_name(cell)
                    .ok_or_else(|| err(sl, sc, format!("unknown macro cell {cell:?}")))?;
                let (inm, iesc, il, ic) = cur.expect_ident()?;
                let k = (!iesc).then(|| decode_indexed(&inm, 'm')).flatten();
                if k != Some(macros.len()) {
                    return Err(err(
                        il,
                        ic,
                        format!("expected instance m{} (instances are emitted in index order)", macros.len()),
                    ));
                }
                let inst = macros.len() as u32;
                cur.expect_punct('(')?;
                let mut expected: Vec<(&str, bool)> = Vec::new(); // (pin, is_output)
                if kind.is_sequential() {
                    expected.push(("CLK", false));
                }
                expected.extend(kind.input_pins().iter().map(|&p| (p, false)));
                expected.extend(kind.output_pins().iter().map(|&p| (p, true)));
                let mut inputs: Vec<NetId> = Vec::new();
                let mut outputs: Vec<NetId> = Vec::new();
                let last = expected.len() - 1;
                for (idx, (pin, is_out)) in expected.iter().enumerate() {
                    cur.expect_punct('.')?;
                    let (pn, pesc, pl, pc) = cur.expect_ident()?;
                    if pesc || pn != *pin {
                        return Err(err(
                            pl,
                            pc,
                            format!("expected pin .{pin} of {}, found .{pn}", kind.cell_name()),
                        ));
                    }
                    cur.expect_punct('(')?;
                    if *pin == "CLK" {
                        cur.expect_keyword("clk")?;
                    } else {
                        let (nn, nesc, nl2, nc2) = cur.expect_ident()?;
                        let net = (!nesc)
                            .then(|| decode_indexed(&nn, 'n'))
                            .flatten()
                            .filter(|&n| n < nets.len())
                            .ok_or_else(|| {
                                err(nl2, nc2, format!("undeclared net {nn:?} on pin .{pin}"))
                            })? as NetId;
                        if *is_out {
                            drive(
                                &mut nets,
                                net,
                                Gate::MacroOut { inst, pin: outputs.len() as u8 },
                                nl2,
                                nc2,
                            )?;
                            outputs.push(net);
                        } else {
                            inputs.push(net);
                        }
                    }
                    cur.expect_punct(')')?;
                    if idx < last {
                        cur.expect_punct(',')?;
                    }
                }
                cur.expect_punct(')')?;
                cur.expect_punct(';')?;
                macros.push(MacroInst { kind, inputs, outputs });
            }
        }
    }
    if let Some(t) = cur.peek() {
        return Err(err(t.line, t.col, "trailing tokens after endmodule"));
    }

    // --- structural completion checks ----------------------------------
    for (k, slot) in nets.iter().enumerate() {
        if slot.driver.is_none() {
            return Err(err(slot.line, slot.col, format!("net n{k} is never driven")));
        }
    }
    for p in &in_ports {
        if p.net.is_none() {
            return Err(err(
                p.line,
                p.col,
                format!("input port {:?} is never bound to a net", p.name),
            ));
        }
    }
    for p in &out_ports {
        if p.net.is_none() {
            return Err(err(
                p.line,
                p.col,
                format!("output port {:?} is never bound to a net", p.name),
            ));
        }
    }

    let netlist = Netlist {
        name,
        gates: nets.iter().map(|s| s.driver.unwrap()).collect(),
        macros,
        inputs: in_ports
            .iter()
            .map(|p| (p.name.clone(), p.net.unwrap()))
            .collect(),
        outputs: out_ports
            .iter()
            .map(|p| (p.name.clone(), p.net.unwrap()))
            .collect(),
    };
    netlist
        .verify()
        .map_err(|e| err(eof_line - 1, 1, format!("netlist verification failed: {e}")))?;
    let ports = netlist
        .inputs
        .iter()
        .chain(netlist.outputs.iter())
        .map(|(n2, id)| (n2.clone(), *id))
        .collect();
    Ok(ParsedModule { netlist, ports })
}

// ---------------------------------------------------------------------
// Round-trip differential check (the fourth conformance leg's engine)
// ---------------------------------------------------------------------

/// The simulator-backend matrix every round trip is checked on: the
/// scalar reference, the 64-lane interpreter, and the compiled engine at
/// 1, 2 and 4 worker threads.
fn roundtrip_backends() -> [super::SimBackend; 5] {
    use super::SimBackend::*;
    [
        Scalar,
        BitParallel64,
        Compiled { words: 2, threads: 1 },
        Compiled { words: 2, threads: 2 },
        Compiled { words: 2, threads: 4 },
    ]
}

/// Differential round-trip check: emit `nl`, parse the text back, and
/// count every disagreement between the original and the round-tripped
/// netlist — byte-determinism of emission, structural equality,
/// emit∘parse∘emit fixpoint, per-backend toggle-report equality
/// (scalar / bit-parallel-64 / compiled at 1, 2 and 4 workers), and
/// per-net value equality under lockstep stimulus on the scalar and
/// compiled engines. Returns 0 iff the round trip is bit-exact; parse
/// failures are hard errors.
pub fn roundtrip_mismatches(nl: &Netlist, cycles: u64, seed: u64) -> Result<usize, String> {
    use super::{collect_toggles, CompiledSim, Simulator};
    use crate::util::Rng64;

    let mut m = 0usize;
    let text = emit(nl)?;
    if emit(nl)? != text {
        m += 1; // emission must be byte-deterministic
    }
    let parsed = parse(&text).map_err(|e| format!("parse-back failed: {e}"))?.netlist;
    if parsed != *nl {
        m += 1;
    }
    if emit(&parsed)? != text {
        m += 1; // emit∘parse∘emit fixpoint
    }
    for backend in roundtrip_backends() {
        let a = collect_toggles(nl, cycles, seed, backend)?;
        let b = collect_toggles(&parsed, cycles, seed, backend)?;
        if a.cycles != b.cycles || a.toggles != b.toggles {
            m += 1;
        }
    }
    if parsed.len() != nl.len() || parsed.inputs.len() != nl.inputs.len() {
        return Ok(m + 2); // value checks subsumed by the structural diff
    }
    let n = nl.len() as NetId;
    // Scalar lockstep: every net, every settled cycle.
    {
        let mut a = Simulator::new(nl)?;
        let mut b = Simulator::new(&parsed)?;
        let mut rng = Rng64::seed_from_u64(seed ^ 0x56C0_57A7);
        let mut bad = false;
        for _ in 0..cycles.min(64) {
            for ((_, ia), (_, ib)) in nl.inputs.iter().zip(&parsed.inputs) {
                let v = rng.gen_bool(0.125);
                a.set_input_net(*ia, v);
                b.set_input_net(*ib, v);
            }
            a.settle();
            b.settle();
            for net in 0..n {
                if a.get(net) != b.get(net) {
                    bad = true;
                }
            }
            a.clock();
            b.clock();
        }
        if bad {
            m += 1;
        }
    }
    // Compiled lockstep (2 words × 4 workers): every net, every word.
    {
        let mut a = CompiledSim::new(nl, 2, 4)?;
        let mut b = CompiledSim::new(&parsed, 2, 4)?;
        let mut rng = Rng64::seed_from_u64(seed ^ 0xC0_4417);
        let mut bad = false;
        for _ in 0..8 {
            for ((_, ia), (_, ib)) in nl.inputs.iter().zip(&parsed.inputs) {
                for w in 0..2 {
                    let word = rng.next_u64() & rng.next_u64() & rng.next_u64();
                    a.set_input_net(*ia, w, word);
                    b.set_input_net(*ib, w, word);
                }
            }
            a.cycle();
            b.cycle();
            for net in 0..n {
                for w in 0..2 {
                    if a.get_word(net, w) != b.get_word(net, w) {
                        bad = true;
                    }
                }
            }
        }
        if bad {
            m += 1;
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Netlist {
        let mut b = NetBuilder::new("toy");
        let a = b.input("a");
        let g = b.input("IN[0]"); // needs escaping
        let x = b.and(a, g);
        let nx = b.not(x);
        let q = b.dff(nx, Some(g), true);
        let outs = b.macro_inst(MacroKind::Pulse2Edge, vec![x, g]);
        let cse = b.macro_inst(MacroKind::StdpCaseGen, vec![a, g, q]);
        let mx = b.mux(a, q, outs[0]);
        b.output("q", q);
        b.output("wire", mx); // reserved word → escaped
        b.output("case0", cse[0]);
        b.finish()
    }

    #[test]
    fn emit_parse_roundtrip_is_exact_and_a_fixpoint() {
        let nl = toy();
        let text = emit(&nl).unwrap();
        assert_eq!(emit(&nl).unwrap(), text, "byte-deterministic");
        let back = parse(&text).unwrap();
        assert_eq!(back.netlist, nl);
        assert_eq!(emit(&back.netlist).unwrap(), text, "fixpoint");
        for (name, id) in nl.inputs.iter().chain(&nl.outputs) {
            assert_eq!(back.ports.get(name), Some(id), "port map covers {name}");
        }
    }

    #[test]
    fn escaping_rules_follow_the_contract() {
        assert_eq!(render_port("GRST").unwrap(), "GRST");
        assert_eq!(render_port("IN[0]").unwrap(), "\\IN[0] ");
        assert_eq!(render_port("clk").unwrap(), "\\clk ");
        assert_eq!(render_port("wire").unwrap(), "\\wire ");
        assert_eq!(render_port("n5").unwrap(), "\\n5 ");
        assert_eq!(render_port("m12").unwrap(), "\\m12 ");
        assert_eq!(render_port("n5x").unwrap(), "n5x");
        assert!(render_port("has space").is_err());
        assert!(render_port("").is_err());
    }

    #[test]
    fn ports_named_like_reserved_words_roundtrip() {
        let mut b = NetBuilder::new("t");
        let a = b.input("clk"); // escaped, distinct from the clock port
        let x = b.not(a);
        b.output("n0", x); // net-shaped name → escaped
        let nl = b.finish();
        let text = emit(&nl).unwrap();
        let back = parse(&text).unwrap().netlist;
        assert_eq!(back, nl);
    }

    #[test]
    fn mux_polarity_survives_the_text(){
        // Mux(s, a, b) = s ? b : a — polarity must survive the text form.
        let mut b = NetBuilder::new("t");
        let s = b.input("s");
        let a = b.input("a");
        let c = b.input("c");
        let x = b.mux(s, a, c);
        b.output("x", x);
        let nl = b.finish();
        let text = emit(&nl).unwrap();
        assert!(text.contains("assign n3 = n0 ? n2 : n1;"), "{text}");
        assert_eq!(parse(&text).unwrap().netlist, nl);
    }

    #[test]
    fn emit_rejects_bad_names() {
        let mut b = NetBuilder::new("bad name");
        let a = b.input("a");
        b.output("x", a);
        assert!(emit(&b.finish()).unwrap_err().contains("module name"));

        let mut b = NetBuilder::new("t");
        let a = b.input("dup");
        b.output("dup", a);
        assert!(emit(&b.finish()).unwrap_err().contains("duplicate port"));

        // Input gate with no port entry.
        let nl = Netlist {
            name: "t".into(),
            gates: vec![Gate::Input],
            ..Netlist::default()
        };
        assert!(emit(&nl).unwrap_err().contains("no port name"));
    }

    #[test]
    fn parse_reports_positions_for_structural_violations() {
        // Dangling net: declared, never driven (position = the decl's name).
        let src = "module t (\n  input wire clk,\n  input wire a\n);\n  wire n0;\n  wire n1;\n  assign n0 = a;\nendmodule\n";
        let e = parse(src).unwrap_err();
        assert_eq!((e.line, e.col), (6, 8), "{e}");
        assert!(e.msg.contains("n1 is never driven"), "{e}");

        // Duplicate driver: position = the second statement's LHS.
        let src = "module t (\n  input wire clk,\n  input wire a\n);\n  wire n0;\n  assign n0 = a;\n  assign n0 = 1'b1;\nendmodule\n";
        let e = parse(src).unwrap_err();
        assert_eq!((e.line, e.col), (7, 10), "{e}");
        assert!(e.msg.contains("duplicate driver"), "{e}");

        // Bad port: RHS names a port that was never declared.
        let src = "module t (\n  input wire clk,\n  input wire a\n);\n  wire n0;\n  assign n0 = b;\nendmodule\n";
        let e = parse(src).unwrap_err();
        assert_eq!((e.line, e.col), (6, 15), "{e}");
        assert!(e.msg.contains("unknown input port"), "{e}");
    }

    #[test]
    fn parse_rejects_malformed_instances_and_literals() {
        let src = "module t (\n  input wire clk,\n  input wire a\n);\n  wire n0;\n  wire n1;\n  assign n0 = a;\n  bogus_cell m0 (.X(n0), .Y(n1));\nendmodule\n";
        let e = parse(src).unwrap_err();
        assert!(e.msg.contains("unknown macro cell"), "{e}");
        assert_eq!(e.line, 8);

        let src = "module t (\n  input wire clk,\n  input wire a\n);\n  wire n0;\n  assign n0 = 2'b10;\nendmodule\n";
        let e = parse(src).unwrap_err();
        assert!(e.msg.contains("unexpected character"), "{e}");

        // Wrong pin name for a real cell.
        let src = "module t (\n  input wire clk,\n  input wire a\n);\n  wire n0;\n  wire n1;\n  assign n0 = a;\n  pulse2edge m0 (.CLK(clk), .PULSES(n0), .GRST(n0), .EDGE(n1));\nendmodule\n";
        let e = parse(src).unwrap_err();
        assert!(e.msg.contains("expected pin .PULSE"), "{e}");
        assert_eq!((e.line, e.col), (8, 30), "{e}");
    }

    #[test]
    fn flatten_removes_macros_and_keeps_ports() {
        let nl = toy();
        let flat = flatten(&nl).unwrap();
        assert!(flat.macros.is_empty());
        assert_eq!(
            flat.inputs.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>(),
            nl.inputs.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>()
        );
        assert_eq!(
            flat.outputs.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>(),
            nl.outputs.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>()
        );
        // Flat text parses back to the flat netlist exactly.
        let text = emit_flat(&nl).unwrap();
        assert_eq!(parse(&text).unwrap().netlist, flat);
        assert!(!text.contains("pulse2edge"), "no cell instances in flat mode");
    }

    #[test]
    fn roundtrip_mismatches_is_zero_on_a_small_column() {
        let d = super::super::column_design::build_column(
            3,
            2,
            4,
            super::super::column_design::BrvSource::Lfsr,
        );
        assert_eq!(roundtrip_mismatches(&d.netlist, 256, 0xF00D).unwrap(), 0);
    }
}
