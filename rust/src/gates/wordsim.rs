//! 64-lane bit-parallel levelized netlist simulator.
//!
//! Same synchronous semantics as the scalar [`super::sim::Simulator`], but
//! every net carries a `u64` word whose bit `l` is the net's boolean value
//! in simulation *lane* `l`: 64 independent stimulus vectors advance through
//! the netlist per settle/clock pass. Gates evaluate as single bitwise word
//! ops, DFFs capture word-wide, the nine TNN7 macros step through their
//! bit-sliced behavioral models ([`super::macros9::eval_word`] /
//! [`super::macros9::step_word`]), and toggles are accumulated with
//! `popcount` — so one pass produces 64 cycles' worth of switching-activity
//! statistics. This is the 2-state word-parallel trick of commercial gate
//! simulators, and it makes toggle collection for the activity-based power
//! model 1–2 orders of magnitude faster than the scalar engine (see
//! `benches/sim_throughput.rs`).
//!
//! The combinational schedule comes level-packed from
//! [`Netlist::levelize_buckets`]: the inner loop walks each level's nets in
//! ascending id order (cache-friendly), and level boundaries are the natural
//! split points for a future thread-per-level evaluation.
//!
//! Cycle protocol (identical to the scalar engine):
//!   1. caller sets primary input words,
//!   2. [`WordSimulator::settle`] — combinational settle in level order,
//!   3. outputs observable,
//!   4. [`WordSimulator::clock`] — DFFs capture, macro state advances,
//!      Moore macro pins refresh.
//!
//! Lane 0 of this engine is bit-for-bit equivalent to the scalar engine
//! under identical stimulus (enforced by the equivalence tests below).

use super::macros9::{self, MacroState, WordMacroState, WORD_LANES};
use super::netlist::{Gate, NetId, Netlist};
use std::collections::HashMap;

/// Number of independent simulation lanes per pass (bits of a word).
pub const LANES: usize = WORD_LANES;

/// Bit-parallel simulator instance bound to a netlist.
pub struct WordSimulator<'a> {
    nl: &'a Netlist,
    /// Level-packed schedule, flattened; `level_ends[k]` is the exclusive
    /// end index of level `k` in `sched`.
    sched: Vec<NetId>,
    level_ends: Vec<u32>,
    values: Vec<u64>,
    macro_states: Vec<WordMacroState>,
    input_index: HashMap<&'a str, NetId>,
    output_index: HashMap<&'a str, NetId>,
    toggles: Vec<u64>,
    cycles: u64,
    /// Net ids of all DFFs (precomputed so `clock` skips the full gate scan).
    dffs: Vec<NetId>,
    // Per-instance macro evaluation memo: several Mealy pins of one
    // instance read the same evaluation, so `eval_word` runs at most once
    // per instance per settle — the first pin evaluates and stamps the
    // instance with the current settle generation; later pins just read
    // `macro_outs`. No per-pin `Vec == Vec` input comparison in the hot
    // loop. Soundness relies on every Mealy pin of an instance sharing
    // one schedule level (validated in `new`), so the instance's inputs
    // cannot change between its pins within a settle.
    macro_outs: Vec<Vec<u64>>,
    eval_gen: Vec<u64>,
    settle_gen: u64,
    // Stuck-at fault lane masks (empty when fault-free — the common case
    // pays one branch per settle): lanes of `force_sa0[id]` are stuck at 0,
    // lanes of `force_sa1[id]` stuck at 1. `forced_nets` lists nets with
    // any forced lane so the settle-entry clamp (covering
    // Input/Dff/Const/Moore nets that are not in the combinational
    // schedule) doesn't scan every net.
    force_sa0: Vec<u64>,
    force_sa1: Vec<u64>,
    forced_nets: Vec<NetId>,
    // scratch buffers
    dff_next: Vec<u64>,
    macro_in: Vec<u64>,
    macro_out: Vec<u64>,
}

impl<'a> WordSimulator<'a> {
    /// Build a level-packed 64-lane simulator over `nl` (errors on true
    /// combinational cycles).
    pub fn new(nl: &'a Netlist) -> Result<Self, String> {
        let levels = nl.levelize_buckets()?;
        // The once-per-settle macro memo is sound only if every scheduled
        // (Mealy) pin of an instance sits in one level — true for all nine
        // TNN7 macros, whose Mealy pins share identical `pin_deps`. A
        // future macro violating this must fail loudly, not mis-simulate.
        let mut inst_level: Vec<Option<usize>> = vec![None; nl.macros.len()];
        for (k, level) in levels.iter().enumerate() {
            for &id in level {
                if let Gate::MacroOut { inst, .. } = nl.gates[id as usize] {
                    match inst_level[inst as usize] {
                        None => inst_level[inst as usize] = Some(k),
                        Some(l0) if l0 == k => {}
                        Some(l0) => {
                            return Err(format!(
                                "macro instance {inst} has Mealy pins in levels {l0} and {k}; \
                                 once-per-settle evaluation requires one level per instance"
                            ))
                        }
                    }
                }
            }
        }
        let mut sched = Vec::with_capacity(levels.iter().map(|l| l.len()).sum());
        let mut level_ends = Vec::with_capacity(levels.len());
        for level in levels {
            sched.extend_from_slice(&level);
            level_ends.push(sched.len() as u32);
        }
        let mut values = vec![0u64; nl.gates.len()];
        let mut dffs = Vec::new();
        for (i, g) in nl.gates.iter().enumerate() {
            match g {
                Gate::Const(true) => values[i] = !0,
                Gate::Dff { init, .. } => {
                    if *init {
                        values[i] = !0;
                    }
                    dffs.push(i as NetId);
                }
                _ => {}
            }
        }
        let macro_states = nl.macros.iter().map(|_| WordMacroState::default()).collect();
        let input_index = nl
            .inputs
            .iter()
            .map(|(name, id)| (name.as_str(), *id))
            .collect();
        let output_index = nl
            .outputs
            .iter()
            .map(|(name, id)| (name.as_str(), *id))
            .collect();
        Ok(WordSimulator {
            nl,
            sched,
            level_ends,
            toggles: vec![0; nl.gates.len()],
            values,
            macro_states,
            input_index,
            output_index,
            cycles: 0,
            dffs,
            macro_outs: nl.macros.iter().map(|_| Vec::new()).collect(),
            eval_gen: vec![0; nl.macros.len()],
            settle_gen: 0,
            force_sa0: Vec::new(),
            force_sa1: Vec::new(),
            forced_nets: Vec::new(),
            dff_next: Vec::new(),
            macro_in: Vec::new(),
            macro_out: Vec::new(),
        })
    }

    /// Number of combinational levels in the schedule.
    pub fn level_count(&self) -> usize {
        self.level_ends.len()
    }

    /// Set a primary input word by name (bit `l` = value in lane `l`).
    /// Panics on unknown names. This is a per-call `HashMap` lookup —
    /// convenient in tests; steady-state stimulus should resolve ids once
    /// via [`WordSimulator::bind_inputs`] and use
    /// [`WordSimulator::set_input_net`].
    pub fn set_input(&mut self, name: &str, word: u64) {
        let id = *self
            .input_index
            .get(name)
            .unwrap_or_else(|| panic!("unknown input {name}"));
        self.values[id as usize] = word;
    }

    /// Set a primary input word by net id (fast path for generated stimulus).
    pub fn set_input_net(&mut self, id: NetId, word: u64) {
        debug_assert!(matches!(self.nl.gates[id as usize], Gate::Input));
        self.values[id as usize] = word;
    }

    /// Current word of any net.
    pub fn get(&self, id: NetId) -> u64 {
        self.values[id as usize]
    }

    /// Current value of net `id` in one lane.
    pub fn get_lane(&self, id: NetId, lane: usize) -> bool {
        debug_assert!(lane < LANES);
        self.values[id as usize] >> lane & 1 == 1
    }

    /// Word of a primary output by name.
    pub fn get_output(&self, name: &str) -> u64 {
        let id = *self
            .output_index
            .get(name)
            .unwrap_or_else(|| panic!("unknown output {name}"));
        self.values[id as usize]
    }

    /// Combinational settle (phase 2), level by level. Counts toggles (per
    /// lane, via popcount) against the previous settled words.
    // Index loops: the body calls `eval_net(&mut self)`, so iterator
    // borrows of the schedule cannot be held across it.
    #[allow(clippy::needless_range_loop)]
    pub fn settle(&mut self) {
        // Re-clamp forced nets first: Input/Dff/Const/Moore-pin nets are
        // not in the combinational schedule, so a clock-phase write (DFF
        // commit, Moore refresh) or caller stimulus would otherwise undo
        // the force.
        for &id in &self.forced_nets {
            let i = id as usize;
            self.values[i] = (self.values[i] & !self.force_sa0[i]) | self.force_sa1[i];
        }
        let clamp = !self.forced_nets.is_empty();
        // New settle pass: every instance's memo goes stale at once (a
        // counter bump, not a per-instance invalidation sweep).
        self.settle_gen += 1;
        let mut start = 0usize;
        for k in 0..self.level_ends.len() {
            let end = self.level_ends[k] as usize;
            for s in start..end {
                let id = self.sched[s];
                let mut new = self.eval_net(id);
                if clamp {
                    let i = id as usize;
                    new = (new & !self.force_sa0[i]) | self.force_sa1[i];
                }
                let old = self.values[id as usize];
                let diff = new ^ old;
                if diff != 0 {
                    self.toggles[id as usize] += diff.count_ones() as u64;
                    self.values[id as usize] = new;
                }
            }
            start = end;
        }
    }

    fn eval_net(&mut self, id: NetId) -> u64 {
        match self.nl.gates[id as usize] {
            Gate::Buf(a) => self.values[a as usize],
            Gate::Not(a) => !self.values[a as usize],
            Gate::And(a, b) => self.values[a as usize] & self.values[b as usize],
            Gate::Or(a, b) => self.values[a as usize] | self.values[b as usize],
            Gate::Xor(a, b) => self.values[a as usize] ^ self.values[b as usize],
            Gate::Mux(s, a, b) => {
                let sw = self.values[s as usize];
                (self.values[b as usize] & sw) | (self.values[a as usize] & !sw)
            }
            Gate::MacroOut { inst, pin } => {
                let iu = inst as usize;
                if self.eval_gen[iu] != self.settle_gen {
                    let m = &self.nl.macros[iu];
                    self.macro_in.clear();
                    for &src in &m.inputs {
                        self.macro_in.push(self.values[src as usize]);
                    }
                    macros9::eval_word(
                        m.kind,
                        &self.macro_in,
                        &self.macro_states[iu],
                        &mut self.macro_out,
                    );
                    self.macro_outs[iu].clear();
                    self.macro_outs[iu].extend_from_slice(&self.macro_out);
                    self.eval_gen[iu] = self.settle_gen;
                }
                self.macro_outs[iu][pin as usize]
            }
            Gate::Input | Gate::Const(_) | Gate::Dff { .. } => self.values[id as usize],
        }
    }

    /// Clock edge (phase 4): capture DFFs word-wide, advance macro state,
    /// then refresh Moore macro pins — same ordering as the scalar engine.
    pub fn clock(&mut self) {
        self.cycles += 1;
        // (No memo invalidation needed: the next settle bumps settle_gen,
        // which makes every instance's evaluation stale at once.)
        // Capture all DFF next-words first (no ordering hazards).
        self.dff_next.clear();
        for &id in &self.dffs {
            let Gate::Dff { d, rst, init } = self.nl.gates[id as usize] else {
                unreachable!("dffs list holds only DFF nets");
            };
            let r = rst.map_or(0, |rn| self.values[rn as usize]);
            let init_word = if init { !0u64 } else { 0 };
            self.dff_next
                .push((self.values[d as usize] & !r) | (init_word & r));
        }
        // Advance macro behavioral state (reads pre-capture DFF values,
        // exactly like the scalar engine).
        for (inst, m) in self.nl.macros.iter().enumerate() {
            self.macro_in.clear();
            for &src in &m.inputs {
                self.macro_in.push(self.values[src as usize]);
            }
            macros9::step_word(m.kind, &self.macro_in, &mut self.macro_states[inst]);
        }
        for (&id, &v) in self.dffs.iter().zip(&self.dff_next) {
            let i = id as usize;
            let diff = self.values[i] ^ v;
            if diff != 0 {
                self.toggles[i] += diff.count_ones() as u64;
                self.values[i] = v;
            }
        }
        // Refresh Moore macro pins (state-only outputs) so they reflect the
        // new state before the next settle. (Moore outputs are functions of
        // state alone, so a commit here changing another instance's inputs
        // is harmless — the next settle re-evaluates every instance once.)
        for (inst, m) in self.nl.macros.iter().enumerate() {
            self.macro_in.clear();
            for &src in &m.inputs {
                self.macro_in.push(self.values[src as usize]);
            }
            macros9::eval_word(
                m.kind,
                &self.macro_in,
                &self.macro_states[inst],
                &mut self.macro_out,
            );
            for (pin, &net) in m.outputs.iter().enumerate() {
                if m.kind.pin_deps(pin as u8).is_empty() {
                    let v = self.macro_out[pin];
                    let diff = self.values[net as usize] ^ v;
                    if diff != 0 {
                        self.toggles[net as usize] += diff.count_ones() as u64;
                        self.values[net as usize] = v;
                    }
                }
            }
        }
    }

    /// One full cycle: settle, then clock. Inputs must be set beforehand.
    pub fn cycle(&mut self) {
        self.settle();
        self.clock();
    }

    /// Word passes simulated so far (each pass is one cycle in all lanes).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Total simulated lane-cycles (`cycles × 64`) — the denominator for
    /// activity, comparable with the scalar engine's cycle count.
    pub fn lane_cycles(&self) -> u64 {
        self.cycles * LANES as u64
    }

    /// Per-net toggle counts, accumulated across all lanes and cycles.
    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    /// Average toggle rate (toggles per net per lane-cycle) — the α
    /// activity factor used by the dynamic power model.
    pub fn activity(&self) -> f64 {
        super::mean_activity(&self.toggles, self.lane_cycles())
    }

    /// Read a macro instance's word-level behavioral state.
    pub fn macro_state(&self, inst: usize) -> &WordMacroState {
        &self.macro_states[inst]
    }

    /// Overwrite a macro instance's word-level state.
    pub fn set_macro_state(&mut self, inst: usize, st: WordMacroState) {
        self.macro_states[inst] = st;
    }

    /// Broadcast a scalar macro state into all lanes of an instance (e.g.
    /// to preload synaptic weights before a cross-check run).
    pub fn set_macro_state_broadcast(&mut self, inst: usize, st: &MacroState) {
        self.macro_states[inst] = WordMacroState::broadcast(st);
    }

    /// Resolve primary-input names to net ids in one pass against the
    /// simulator's prebuilt name index (then drive the hot loop through
    /// [`WordSimulator::set_input_net`] — per-call name lookups never
    /// belong in steady-state stimulus). Errors on unknown names.
    pub fn bind_inputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        super::netlist::resolve_ports(&self.input_index, names, "input")
    }

    /// Resolve primary-output names to net ids in one pass against the
    /// simulator's prebuilt name index. Errors on unknown names.
    pub fn bind_outputs(&self, names: &[&str]) -> Result<Vec<NetId>, String> {
        super::netlist::resolve_ports(&self.output_index, names, "output")
    }

    /// Force the `sa0` lanes of net `id` stuck at 0 and the `sa1` lanes
    /// stuck at 1, until [`WordSimulator::clear_faults`]. Forces accumulate
    /// across calls, are applied immediately, re-applied at every settle
    /// entry, and clamp freshly evaluated words inside the settle, so they
    /// hold across [`WordSimulator::clock`] and
    /// [`WordSimulator::reset_state`]. A lane in both masks resolves to
    /// stuck-at-1.
    pub fn force_net_lanes(&mut self, id: NetId, sa0: u64, sa1: u64) {
        if self.force_sa0.is_empty() {
            self.force_sa0 = vec![0; self.nl.gates.len()];
            self.force_sa1 = vec![0; self.nl.gates.len()];
        }
        let i = id as usize;
        if self.force_sa0[i] | self.force_sa1[i] == 0 {
            self.forced_nets.push(id);
        }
        self.force_sa0[i] |= sa0;
        self.force_sa1[i] |= sa1;
        self.values[i] = (self.values[i] & !self.force_sa0[i]) | self.force_sa1[i];
    }

    /// One-shot single-event upset: invert the `mask` lanes of net `id`.
    /// Call between [`WordSimulator::clock`] and the next settle; the flip
    /// persists on state nets (DFF outputs) and is swallowed by the next
    /// settle on combinational nets.
    pub fn flip_net_lanes(&mut self, id: NetId, mask: u64) {
        self.values[id as usize] ^= mask;
    }

    /// One-shot single-event upset in macro behavioral state: invert state
    /// bit `bit` of instance `inst` in the `mask` lanes (see
    /// [`MacroKind::state_bits`]).
    ///
    /// [`MacroKind::state_bits`]: super::macros9::MacroKind::state_bits
    pub fn flip_macro_bit_lanes(&mut self, inst: usize, bit: usize, mask: u64) {
        let st = &mut self.macro_states[inst];
        let plane = st.plane(bit);
        st.set_plane(bit, plane ^ mask);
    }

    /// Remove all stuck-at forces (flips are one-shot and need no undo).
    pub fn clear_faults(&mut self) {
        self.force_sa0.clear();
        self.force_sa1.clear();
        self.forced_nets.clear();
    }

    /// Reset all state (DFFs to init, macro states cleared, toggles kept).
    pub fn reset_state(&mut self) {
        for &id in &self.dffs {
            if let Gate::Dff { init, .. } = self.nl.gates[id as usize] {
                self.values[id as usize] = if init { !0 } else { 0 };
            }
        }
        for st in &mut self.macro_states {
            *st = WordMacroState::default();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::column_design::{build_column, BrvSource};
    use super::super::macros9::MacroKind;
    use super::super::netlist::NetBuilder;
    use super::super::sim::Simulator;
    use super::*;
    use crate::util::Rng64;

    #[test]
    fn comb_logic_settles_per_lane() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.xor(a, c);
        b.output("x", x);
        let nl = b.finish();
        let mut sim = WordSimulator::new(&nl).unwrap();
        // lane 0: 0^0, lane 1: 1^0, lane 2: 1^1, lane 3: 0^1
        sim.set_input("a", 0b0110);
        sim.set_input("b", 0b1100);
        sim.settle();
        assert_eq!(sim.get_output("x") & 0b1111, 0b1010);
        assert!(!sim.get_lane(x, 0));
        assert!(sim.get_lane(x, 1));
        assert!(!sim.get_lane(x, 2));
        assert!(sim.get_lane(x, 3));
    }

    #[test]
    fn dff_captures_word_wide_and_counts_lane_toggles() {
        let mut b = NetBuilder::new("t");
        let d = b.input("d");
        let r = b.input("r");
        let q = b.dff(d, Some(r), false);
        b.output("q", q);
        let nl = b.finish();
        let mut sim = WordSimulator::new(&nl).unwrap();
        sim.set_input("d", 0xFF);
        sim.set_input("r", 0x0F); // lanes 0..4 held in reset
        sim.settle();
        assert_eq!(sim.get_output("q"), 0, "q lags d");
        sim.clock();
        assert_eq!(sim.get_output("q"), 0xF0);
        assert_eq!(sim.toggles()[q as usize], 4, "popcount of captured diff");
    }

    #[test]
    fn macro_instance_evaluates_behaviorally_per_lane() {
        // pulse2edge: pulse arrives at a different cycle per lane.
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let outs = b.macro_inst(MacroKind::Pulse2Edge, vec![p, g]);
        b.output("edge", outs[0]);
        let nl = b.finish();
        let mut sim = WordSimulator::new(&nl).unwrap();
        sim.set_input("g", 0);
        for t in 0..4u64 {
            // lane l pulses at cycle l
            sim.set_input("p", 1 << t);
            sim.settle();
            let edge = sim.get_output("edge");
            // lanes 0..=t have seen (or are seeing) their pulse
            assert_eq!(edge & 0xF, (1u64 << (t + 1)) - 1, "cycle {t}");
            sim.clock();
        }
    }

    /// Shared body of the lane-0 equivalence matrix: lane 0 of the word
    /// engine must match the scalar engine net-for-net under identical
    /// stimulus (all other lanes carry independent random stimulus at the
    /// same time).
    fn assert_lane0_matches_scalar(p: usize, q: usize, seed: u64, cycles: u32) {
        let d = build_column(p, q, (p as u32 * 7) / 4, BrvSource::Lfsr);
        let nl = &d.netlist;
        let mut ssim = Simulator::new(nl).unwrap();
        let mut wsim = WordSimulator::new(nl).unwrap();
        let inputs: Vec<_> = nl.inputs.iter().map(|(_, id)| *id).collect();
        let mut rng = Rng64::seed_from_u64(seed);
        let n = nl.len() as NetId;
        for cycle in 0..cycles {
            for &id in &inputs {
                // sparse pulses (p = 1/8), independent per lane
                let word = rng.next_u64() & rng.next_u64() & rng.next_u64();
                wsim.set_input_net(id, word);
                ssim.set_input_net(id, word & 1 == 1);
            }
            wsim.settle();
            ssim.settle();
            for id in 0..n {
                assert_eq!(
                    wsim.get_lane(id, 0),
                    ssim.get(id),
                    "{p}x{q} seed {seed:#x}: net {id} cycle {cycle} (settled)"
                );
            }
            wsim.clock();
            ssim.clock();
        }
        assert_eq!(ssim.cycles(), cycles as u64);
        assert_eq!(wsim.lane_cycles(), cycles as u64 * LANES as u64);
        // Both engines saw activity (the LFSR alone guarantees toggles).
        assert!(ssim.activity() > 0.0);
        assert!(wsim.activity() > 0.0);
    }

    /// The acceptance-criteria equivalence matrix: every (p, q, seed)
    /// geometry shared with the conformance harness
    /// (`gates::CONFORMANCE_GEOMETRIES`) is checked lane-for-net against
    /// the scalar engine. The 82×2 TwoLeadECG flagship keeps its original
    /// >1000-cycle budget; the smaller corner shapes (wide, tall,
    /// single-neuron) run 256 cycles each.
    #[test]
    fn lane0_matches_scalar_engine_across_conformance_geometries() {
        for &(p, q, seed) in crate::gates::CONFORMANCE_GEOMETRIES.iter() {
            let cycles = if p * q >= 128 { 1024 } else { 256 };
            assert_lane0_matches_scalar(p, q, seed, cycles);
        }
    }

    /// Aggregate toggle statistics from the two engines must agree
    /// statistically: every lane is an i.i.d. draw of the same stimulus
    /// process, so per-net α̂ differs only by sampling noise.
    #[test]
    fn word_activity_statistics_match_scalar_statistics() {
        let d = build_column(8, 2, 8, BrvSource::Lfsr);
        let nl = &d.netlist;
        let mut ssim = Simulator::new(nl).unwrap();
        let mut wsim = WordSimulator::new(nl).unwrap();
        let inputs: Vec<_> = nl.inputs.iter().map(|(_, id)| *id).collect();
        let mut rng = Rng64::seed_from_u64(17);
        // 256 passes = 16384 lane-cycles; LFSR-derived nets repeat across
        // lanes, so passes (not lane-cycles) bound their sample noise.
        let word_passes = 256u64;
        for _ in 0..word_passes {
            for &id in &inputs {
                wsim.set_input_net(id, rng.next_u64() & rng.next_u64() & rng.next_u64());
            }
            wsim.cycle();
        }
        for _ in 0..word_passes * LANES as u64 {
            for &id in &inputs {
                let w = rng.next_u64() & rng.next_u64() & rng.next_u64();
                ssim.set_input_net(id, w & 1 == 1);
            }
            ssim.cycle();
        }
        let a_s = ssim.activity();
        let a_w = wsim.activity();
        assert!(a_s > 0.0 && a_w > 0.0);
        assert!(
            (a_s - a_w).abs() < 0.05,
            "scalar α {a_s:.4} vs word α {a_w:.4}"
        );
    }

    #[test]
    fn macro_memo_is_per_settle_and_resettling_is_stable() {
        // Two settles without an intervening clock must agree (the memo
        // re-evaluates each instance exactly once per settle, against the
        // same inputs and state), and bind_inputs resolves in bulk.
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let outs = b.macro_inst(MacroKind::Pulse2Edge, vec![p, g]);
        b.output("edge", outs[0]);
        let nl = b.finish();
        let mut sim = WordSimulator::new(&nl).unwrap();
        let bound = sim.bind_inputs(&["p", "g"]).unwrap();
        assert_eq!(bound, vec![p, g]);
        assert_eq!(sim.bind_outputs(&["edge"]).unwrap(), vec![outs[0]]);
        assert!(sim.bind_inputs(&["nope"]).is_err());
        sim.set_input_net(bound[0], 0b1010);
        sim.set_input_net(bound[1], 0);
        sim.settle();
        let first = sim.get_output("edge");
        assert_eq!(first, 0b1010);
        sim.settle();
        assert_eq!(sim.get_output("edge"), first, "resettle is idempotent");
        // Changing an input between settles must be observed (the memo is
        // per settle, not per cycle).
        sim.set_input_net(bound[0], 0b0101);
        sim.settle();
        assert_eq!(sim.get_output("edge"), 0b0101);
    }

    #[test]
    fn stuck_at_lanes_hold_and_leave_other_lanes_alone() {
        let mut b = NetBuilder::new("t");
        let dn = b.input("d");
        let q = b.dff(dn, None, false);
        let x = b.not(q);
        b.output("q", q);
        b.output("x", x);
        let nl = b.finish();
        let mut sim = WordSimulator::new(&nl).unwrap();
        sim.force_net_lanes(q, 0, 1 << 3); // lane 3 stuck-at-1
        sim.set_input_net(dn, 0);
        sim.settle();
        assert_eq!(sim.get(q), 1 << 3, "only lane 3 forced");
        assert_eq!(sim.get(x), !(1u64 << 3), "fan-out sees the fault");
        sim.clock(); // captures d=0 into every lane...
        sim.settle(); // ...but lane 3 is re-clamped at settle entry
        assert_eq!(sim.get(q), 1 << 3, "force survives the clock edge");
        sim.clear_faults();
        sim.clock();
        sim.settle();
        assert_eq!(sim.get(q), 0, "cleared fault releases the lane");
    }

    #[test]
    fn moore_pins_refresh_after_clock_word_wide() {
        // spike_gen's SPIKE output is Moore: it must rise on the cycle
        // after the pulse, without an intervening settle — per lane.
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let outs = b.macro_inst(MacroKind::SpikeGen, vec![p, g]);
        b.output("spike", outs[0]);
        let nl = b.finish();
        let mut sim = WordSimulator::new(&nl).unwrap();
        sim.set_input("g", 0);
        sim.set_input("p", 0b101); // lanes 0 and 2 pulse
        sim.settle();
        assert_eq!(sim.get_output("spike"), 0, "Moore output lags");
        sim.clock();
        // refreshed by clock() itself, before any settle
        assert_eq!(sim.get_output("spike"), 0b101);
    }
}
