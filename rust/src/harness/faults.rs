//! Fault-injection report: drives the gate-level campaigns
//! ([`crate::gates::fault`]) and the behavioral weight-memory campaigns
//! ([`crate::tnn::fault`]) over the two reproduction workloads (the UCR
//! TwoLeadECG column and the 4-layer MNIST network) and renders the
//! results as a paper-style table plus `BENCH_faults.json`.
//!
//! Everything here is reproducible from the printed seed alone: fault
//! sites draw only from per-fault `split_stream` lanes, so the campaign
//! is invariant under the simulator backend, `sim_words` and the worker
//! thread count — the cross-backend agreement flag in the report is the
//! live check of that claim.

use crate::gates::artifact_cache::design_handle;
use crate::gates::fault::{campaign, sample_faults, CampaignResult, FaultCounts};
use crate::gates::SimBackend;
use crate::tnn::fault::{flip_column_weights, flip_network_weights};
use crate::tnn::SpikeTime;
use crate::util::json::Json;
use crate::util::kv::KvDoc;
use crate::util::Rng64;
use std::time::{Duration, Instant};

/// Campaign configuration (the `tnn7 faults` subcommand's `key=value`
/// surface), following the same kv discipline as [`crate::config::RunConfig`].
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Root seed: drives the workloads, the gate fault sites and the
    /// weight-flip sites. Printing it makes the whole report reproducible.
    pub seed: u64,
    /// Stuck-at faults to sample for the gate campaign.
    pub stuck: usize,
    /// Single-event upsets (net / macro-state bit flips) to sample.
    pub seu: usize,
    /// Gamma items each gate campaign pass simulates.
    pub items: usize,
    /// UCR training samples per cluster (workload size knob).
    pub per_cluster: usize,
    /// MNIST training samples (workload size knob).
    pub mnist_samples: usize,
    /// Weight-flip ladder: one behavioral campaign point per entry.
    pub flips: Vec<usize>,
    /// Simulator backend the primary gate campaign runs on.
    pub backend: SimBackend,
    /// Lane-block width for the compiled cross-check pass.
    pub sim_words: usize,
    /// Worker threads (0 = machine parallelism) for the compiled
    /// cross-check and the MNIST batched engine.
    pub threads: usize,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 7,
            stuck: 48,
            seu: 48,
            items: 12,
            per_cluster: 20,
            mnist_samples: 100,
            flips: vec![1, 2, 4, 8, 16, 32],
            backend: SimBackend::BitParallel64,
            sim_words: crate::gates::DEFAULT_SIM_WORDS,
            threads: 0,
        }
    }
}

impl FaultSpec {
    /// CI-speed campaign: a handful of faults on tiny workloads.
    pub fn quick() -> Self {
        FaultSpec {
            stuck: 8,
            seu: 8,
            items: 3,
            per_cluster: 6,
            mnist_samples: 30,
            flips: vec![1, 4, 16],
            ..FaultSpec::default()
        }
    }

    /// Load from a kv doc; missing keys keep defaults.
    pub fn from_kv(doc: &KvDoc) -> crate::Result<Self> {
        let mut c = FaultSpec::default();
        if let Some(v) = doc.get_u64("seed")? {
            c.seed = v;
        }
        if let Some(v) = doc.get_usize("stuck")? {
            c.stuck = v;
        }
        if let Some(v) = doc.get_usize("seu")? {
            c.seu = v;
        }
        if let Some(v) = doc.get_usize("items")? {
            c.items = v;
        }
        if let Some(v) = doc.get_usize("per_cluster")? {
            c.per_cluster = v;
        }
        if let Some(v) = doc.get_usize("mnist_samples")? {
            c.mnist_samples = v;
        }
        if let Some(v) = doc.get("flips") {
            c.flips = v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("bad flips entry {s:?} (usize list)"))
                })
                .collect::<crate::Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.get("backend") {
            c.backend = SimBackend::parse(v)?;
        }
        if let Some(v) = doc.get_usize("sim_words")? {
            c.sim_words = v;
        }
        if let Some(v) = doc.get_usize("threads")? {
            c.threads = v;
        }
        c.validate()?;
        Ok(c)
    }

    /// Apply `key=value` CLI overrides.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> crate::Result<()> {
        let mut doc = KvDoc::default();
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override must be key=value: {o}"))?;
            doc.set(k.trim(), v.trim());
        }
        let merged = Self::from_kv(&doc)?;
        // from_kv starts from defaults; re-apply only the overridden keys.
        for key in doc.keys() {
            match key {
                "seed" => self.seed = merged.seed,
                "stuck" => self.stuck = merged.stuck,
                "seu" => self.seu = merged.seu,
                "items" => self.items = merged.items,
                "per_cluster" => self.per_cluster = merged.per_cluster,
                "mnist_samples" => self.mnist_samples = merged.mnist_samples,
                "flips" => self.flips = merged.flips.clone(),
                "backend" => self.backend = merged.backend,
                "sim_words" => self.sim_words = merged.sim_words,
                "threads" => self.threads = merged.threads,
                other => anyhow::bail!("unknown faults key {other:?}"),
            }
        }
        self.validate()
    }

    /// Sanity-check the configuration.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.stuck + self.seu >= 1, "need at least one gate fault");
        anyhow::ensure!(self.items >= 1, "items must be >= 1");
        anyhow::ensure!(self.per_cluster >= 2, "per_cluster must be >= 2");
        anyhow::ensure!(self.mnist_samples >= 10, "mnist_samples must be >= 10");
        anyhow::ensure!(!self.flips.is_empty(), "flips ladder must be non-empty");
        anyhow::ensure!(
            (1..=64).contains(&self.sim_words),
            "sim_words must be in 1..=64"
        );
        Ok(())
    }
}

/// Gate-level campaign summary on the UCR column.
#[derive(Clone, Debug)]
pub struct GateCampaignSummary {
    /// Synapses per neuron of the struck column.
    pub p: usize,
    /// Neurons of the struck column.
    pub q: usize,
    /// Firing threshold of the struck column.
    pub theta: u32,
    /// Total faults injected (stuck-at + SEU).
    pub faults: usize,
    /// Gamma items each fault was simulated over.
    pub items: usize,
    /// Masked / latent / propagated totals.
    pub counts: FaultCounts,
    /// Per-site-label (macro type / dff / input / logic) classification.
    pub by_site: Vec<(String, FaultCounts)>,
    /// Faults whose WTA winner differed from the fault-free reference on
    /// at least one item.
    pub winner_mismatch_faults: usize,
    /// Did scalar, bit-parallel-64 and compiled produce bit-identical
    /// verdicts for every fault?
    pub backends_agree: bool,
    /// Backend the primary campaign ran on.
    pub backend: String,
    /// Wall time of the primary campaign pass.
    pub wall: Duration,
}

/// One behavioral weight-flip point on the UCR column: WTA winner changes
/// versus the un-flipped column over the same items.
#[derive(Clone, Debug)]
pub struct UcrFlipRow {
    /// Weight bits flipped.
    pub flips: usize,
    /// Total weight-memory bits (fault-rate denominator).
    pub memory_bits: usize,
    /// Items whose winner changed under the flips.
    pub changed: usize,
    /// Items scored.
    pub items: usize,
}

/// One behavioral weight-flip point on the MNIST network: vote-classifier
/// accuracy under the flips versus the un-flipped baseline.
#[derive(Clone, Debug)]
pub struct MnistFlipRow {
    /// Weight bits flipped (across the whole network memory).
    pub flips: usize,
    /// Total weight-memory bits (fault-rate denominator).
    pub memory_bits: usize,
    /// Correct test classifications under the flips.
    pub correct: usize,
    /// Correct test classifications of the un-flipped network.
    pub baseline_correct: usize,
    /// Test samples scored.
    pub samples: usize,
}

/// Everything `tnn7 faults` prints and `BENCH_faults.json` records.
#[derive(Clone, Debug)]
pub struct FaultsReport {
    /// The configuration the campaign ran under.
    pub spec: FaultSpec,
    /// Gate-level stuck-at + SEU campaign on the UCR column.
    pub gate: GateCampaignSummary,
    /// UCR winner-change ladder (error rate vs fault rate).
    pub ucr_flips: Vec<UcrFlipRow>,
    /// MNIST accuracy-degradation ladder.
    pub mnist_flips: Vec<MnistFlipRow>,
}

/// Run the full fault campaign described by `spec`.
///
/// Three sub-campaigns share the spec's seed: (1) a gate-level stuck-at +
/// SEU campaign on the briefly-trained UCR TwoLeadECG column, classified
/// masked/latent/propagated and cross-checked bit-for-bit on all three
/// simulator backends; (2) a weight-flip winner-change ladder on the same
/// column; (3) a weight-flip accuracy ladder on the trained 4-layer MNIST
/// network. The flip ladders use one `split_stream` lane per flip index,
/// so each ladder point's flip set is a prefix of the next — the curves
/// are monotone in the injected faults, not resampled per point.
pub fn fault_campaign(spec: &FaultSpec) -> crate::Result<FaultsReport> {
    spec.validate()?;

    // --- workload: briefly-trained UCR TwoLeadECG column ---------------
    let (mut col, items) = super::ucr_train_workload(spec.per_cluster, spec.seed);
    let mut rng = Rng64::seed_from_u64(spec.seed.wrapping_add(3));
    for item in &items {
        col.step(&item.volley, &mut rng);
    }

    // --- gate-level stuck-at + SEU campaign ----------------------------
    // Resolve through the shared artifact cache: the campaign strikes the
    // SAME design `Arc` every gate engine of this geometry runs (pinned by
    // `Arc::ptr_eq` in `tests/faults.rs`), not a private rebuild.
    let d = design_handle(col.p(), col.q(), col.theta())?;
    let gamma = col.params().gamma_cycles;
    let volleys: Vec<&[SpikeTime]> = items
        .iter()
        .take(spec.items)
        .map(|i| i.volley.as_slice())
        .collect();
    anyhow::ensure!(!volleys.is_empty(), "workload produced no gamma items");
    let total_cycles = volleys.len() as u64 * gamma as u64;
    let faults = sample_faults(&d.netlist, spec.stuck, spec.seu, total_cycles, spec.seed);

    let t0 = Instant::now();
    let primary = campaign(&d, col.weights(), gamma, &volleys, &faults, spec.backend)
        .map_err(anyhow::Error::msg)?;
    let wall = t0.elapsed();

    // Cross-backend agreement: the same campaign must produce
    // bit-identical verdicts on every engine (ISSUE acceptance gate).
    let backends_agree = [
        SimBackend::Scalar,
        SimBackend::BitParallel64,
        SimBackend::Compiled {
            words: spec.sim_words,
            threads: spec.threads,
        },
    ]
    .iter()
    .map(|&b| campaign(&d, col.weights(), gamma, &volleys, &faults, b))
    .collect::<Result<Vec<CampaignResult>, String>>()
    .map_err(anyhow::Error::msg)?
    .iter()
    .all(|r| *r == primary);

    let gate = GateCampaignSummary {
        p: col.p(),
        q: col.q(),
        theta: col.theta(),
        faults: faults.len(),
        items: volleys.len(),
        counts: primary.counts(),
        by_site: primary.counts_by_site().into_iter().collect(),
        winner_mismatch_faults: primary
            .outcomes
            .iter()
            .filter(|o| o.winner_mismatches > 0)
            .count(),
        backends_agree,
        backend: spec.backend.name().to_string(),
        wall,
    };

    // --- UCR weight-flip winner-change ladder --------------------------
    let memory_bits = col.synapse_count() * col.params().weight_bits as usize;
    let baseline: Vec<Option<usize>> = items.iter().map(|i| col.infer(&i.volley).winner).collect();
    let ucr_flips = spec
        .flips
        .iter()
        .map(|&n| {
            let mut hit = col.clone();
            flip_column_weights(&mut hit, n, spec.seed);
            let changed = items
                .iter()
                .zip(&baseline)
                .filter(|(i, &b)| hit.infer(&i.volley).winner != b)
                .count();
            UcrFlipRow {
                flips: n,
                memory_bits,
                changed,
                items: items.len(),
            }
        })
        .collect();

    // --- MNIST accuracy-degradation ladder -----------------------------
    let mnist_flips = mnist_flip_ladder(spec)?;

    Ok(FaultsReport {
        spec: spec.clone(),
        gate,
        ucr_flips,
        mnist_flips,
    })
}

/// Train the 4-layer MNIST network once, then score the held-out digits
/// under each flip count of the ladder.
fn mnist_flip_ladder(spec: &FaultSpec) -> crate::Result<Vec<MnistFlipRow>> {
    use crate::mnist::DigitCorpus;
    use crate::tnn::VoteClassifier;

    let (mut net, train_batch) = super::mnist_train_workload(spec.mnist_samples, spec.seed);
    net.step_epoch(
        &train_batch,
        &Rng64::seed_from_u64(spec.seed ^ 0xE90C),
        spec.threads,
    );
    // Labels come from re-generating the same corpus the workload encoded.
    let train = DigitCorpus::generate(spec.mnist_samples.div_ceil(10), spec.seed);
    let test = DigitCorpus::generate(4, spec.seed.wrapping_add(1));
    let test_batch = test.encode_batch(8);

    let mut vote = VoteClassifier::new(net.output_len(), 10);
    let train_out = net.infer_batch(&train_batch, spec.threads);
    for (s, &l) in train.labels.iter().enumerate() {
        vote.observe(train_out.volley(s), l);
    }
    let score = |n: &crate::tnn::TnnNetwork| -> usize {
        let out = n.infer_batch(&test_batch, spec.threads);
        test.labels
            .iter()
            .enumerate()
            .filter(|&(s, &l)| vote.classify(out.volley(s)) == Some(l))
            .count()
    };
    let baseline_correct = score(&net);

    let memory_bits: usize = net
        .layers()
        .iter()
        .flat_map(|l| l.columns().iter())
        .map(|c| c.synapse_count() * c.params().weight_bits as usize)
        .sum();
    Ok(spec
        .flips
        .iter()
        .map(|&n| {
            let mut hit = net.clone();
            flip_network_weights(&mut hit, n, spec.seed);
            MnistFlipRow {
                flips: n,
                memory_bits,
                correct: score(&hit),
                baseline_correct,
                samples: test.len(),
            }
        })
        .collect())
}

/// Print a [`FaultsReport`] as a paper-style table.
pub fn print_faults(r: &FaultsReport) {
    let g = &r.gate;
    println!(
        "Fault-injection campaign (seed {}; reproducible from the seed alone)",
        r.spec.seed
    );
    println!(
        "gate-level: {}x{} UCR column (theta {}), {} faults ({} stuck-at + {} SEU) x {} items on {} [{:?}]",
        g.p, g.q, g.theta, g.faults, r.spec.stuck, r.spec.seu, g.items, g.backend, g.wall
    );
    println!(
        "  masked {}  latent {}  propagated {}  (WTA winner flipped on {} faults)",
        g.counts.masked, g.counts.latent, g.counts.propagated, g.winner_mismatch_faults
    );
    println!(
        "{:<20} {:>8} {:>8} {:>12}",
        "  site", "masked", "latent", "propagated"
    );
    for (site, c) in &g.by_site {
        println!(
            "  {:<18} {:>8} {:>8} {:>12}",
            site, c.masked, c.latent, c.propagated
        );
    }
    println!(
        "  backends agree: {} (scalar / bit-parallel-64 / compiled verdicts bit-identical)",
        if g.backends_agree { "yes" } else { "NO" }
    );
    println!("weight-memory flips, UCR TwoLeadECG column (winner changes vs un-flipped):");
    println!(
        "{:<8} {:>12} {:>16}",
        "  flips", "fault rate", "changed winners"
    );
    for row in &r.ucr_flips {
        println!(
            "  {:<6} {:>11.2}% {:>13}/{}",
            row.flips,
            100.0 * row.flips as f64 / row.memory_bits as f64,
            row.changed,
            row.items
        );
    }
    println!("weight-memory flips, 4-layer MNIST network (vote-classifier accuracy):");
    println!(
        "{:<8} {:>12} {:>10} {:>10}",
        "  flips", "fault rate", "correct", "baseline"
    );
    for row in &r.mnist_flips {
        println!(
            "  {:<6} {:>11.3}% {:>7}/{} {:>7}/{}",
            row.flips,
            100.0 * row.flips as f64 / row.memory_bits as f64,
            row.correct,
            row.samples,
            row.baseline_correct,
            row.samples
        );
    }
}

/// JSON payload of a [`FaultsReport`] (`BENCH_faults.json`).
pub fn faults_json(r: &FaultsReport) -> Json {
    let g = &r.gate;
    let counts_json = |c: &FaultCounts| {
        Json::obj()
            .set("masked", Json::Int(c.masked as i64))
            .set("latent", Json::Int(c.latent as i64))
            .set("propagated", Json::Int(c.propagated as i64))
    };
    Json::obj()
        .set("seed", Json::Int(r.spec.seed as i64))
        .set("design", format!("TwoLeadECG-{}x{}", g.p, g.q))
        .set("p", g.p)
        .set("q", g.q)
        .set("theta", g.theta)
        .set("stuck", r.spec.stuck)
        .set("seu", r.spec.seu)
        .set("items", g.items)
        .set("backend", g.backend.as_str())
        .set(
            "gate",
            counts_json(&g.counts)
                .set("faults", g.faults)
                .set("winner_mismatch_faults", g.winner_mismatch_faults)
                .set("backends_agree", g.backends_agree)
                .set("wall_ms", g.wall.as_secs_f64() * 1e3)
                .set(
                    "by_site",
                    Json::Arr(
                        g.by_site
                            .iter()
                            .map(|(site, c)| counts_json(c).set("site", site.as_str()))
                            .collect(),
                    ),
                ),
        )
        .set(
            "ucr_flips",
            Json::Arr(
                r.ucr_flips
                    .iter()
                    .map(|f| {
                        Json::obj()
                            .set("flips", f.flips)
                            .set("memory_bits", f.memory_bits)
                            .set("changed", f.changed)
                            .set("items", f.items)
                    })
                    .collect(),
            ),
        )
        .set(
            "mnist_flips",
            Json::Arr(
                r.mnist_flips
                    .iter()
                    .map(|f| {
                        Json::obj()
                            .set("flips", f.flips)
                            .set("memory_bits", f.memory_bits)
                            .set("correct", f.correct)
                            .set("baseline_correct", f.baseline_correct)
                            .set("samples", f.samples)
                    })
                    .collect(),
            ),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_defaults_and_quick_are_valid() {
        FaultSpec::default().validate().unwrap();
        FaultSpec::quick().validate().unwrap();
    }

    #[test]
    fn spec_overrides_roundtrip_and_reject_unknown_keys() {
        let mut s = FaultSpec::quick();
        s.apply_overrides(&[
            "seed=9".into(),
            "stuck=2".into(),
            "seu=3".into(),
            "flips=1,2".into(),
            "backend=compiled".into(),
        ])
        .unwrap();
        assert_eq!(s.seed, 9);
        assert_eq!(s.stuck, 2);
        assert_eq!(s.seu, 3);
        assert_eq!(s.flips, vec![1, 2]);
        assert!(matches!(s.backend, SimBackend::Compiled { .. }));
        assert_eq!(s.items, FaultSpec::quick().items, "non-overridden keys keep quick values");
        let err = s.apply_overrides(&["bogus=1".into()]).unwrap_err();
        assert!(err.to_string().contains("unknown faults key"));
        let err = s.apply_overrides(&["flips=".into()]).unwrap_err();
        assert!(err.to_string().contains("bad flips entry"));
    }

    #[test]
    fn tiny_campaign_runs_end_to_end_and_agrees_across_backends() {
        let spec = FaultSpec {
            stuck: 3,
            seu: 3,
            items: 2,
            per_cluster: 2,
            mnist_samples: 10,
            flips: vec![1, 8],
            ..FaultSpec::default()
        };
        let r = fault_campaign(&spec).unwrap();
        assert_eq!(r.gate.faults, 6);
        assert_eq!(r.gate.counts.total(), 6);
        assert!(r.gate.backends_agree, "backend verdicts must be bit-identical");
        assert_eq!(r.ucr_flips.len(), 2);
        assert_eq!(r.mnist_flips.len(), 2);
        assert!(r.mnist_flips[0].baseline_correct <= r.mnist_flips[0].samples);
        // The report JSON carries the headline fields the schema checks.
        let j = faults_json(&r).to_string();
        for key in ["\"gate\"", "\"backends_agree\"", "\"ucr_flips\"", "\"mnist_flips\""] {
            assert!(j.contains(key), "JSON missing {key}");
        }
    }
}
