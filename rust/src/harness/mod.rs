//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see the README "Reproduction matrix" for the command that
//! drives each one). Each function both returns structured rows (consumed
//! by the benches and the JSON reporter) and can print a paper-style table.

use crate::cells;
use crate::config::EngineKind;
use crate::gates::column_design::{build_column, BrvSource};
use crate::gates::macros9::{expand, MacroKind, ALL_MACROS};
use crate::gates::netlist::NetBuilder;
use crate::gates::{collect_toggles, SimBackend};
use crate::layout::{place_and_estimate, LayoutReport};
use crate::mnist::mnist_layer_geometries;
use crate::ppa::report::{analyze, PpaReport};
use crate::ppa::scale::{scale_network, NetworkPpa};
use crate::synth::flow::{synthesize, Flow};
use crate::ucr::{ucr_suite, UcrConfig};
use crate::util::json::Json;
use std::time::{Duration, Instant};

pub mod faults;

pub use faults::{fault_campaign, faults_json, print_faults, FaultSpec, FaultsReport};

/// Default gamma period (unit cycles) used by the PPA computation-time
/// metric, matching the golden model's `TnnParams::default`.
pub const GAMMA_CYCLES: u32 = 16;

// ---------------------------------------------------------------------
// Table II — per-macro PPA: TNN7 characterization vs synthesized baseline
// ---------------------------------------------------------------------

/// One Table II comparison: a TNN7 hard macro vs its synthesized baseline.
#[derive(Clone, Debug)]
pub struct Table2Row {
    /// Which of the nine macros this row characterizes.
    pub kind: MacroKind,
    /// Paper Table II leakage carried by the TNN7 library, nW.
    pub tnn7_leakage_nw: f64,
    /// Paper Table II delay carried by the TNN7 library, ps.
    pub tnn7_delay_ps: f64,
    /// Paper Table II area carried by the TNN7 library, µm².
    pub tnn7_area_um2: f64,
    /// Our synthesized standard-cell baseline of the same function.
    pub base: PpaReport,
}

/// Synthesize each macro's RTL expansion standalone and compare against its
/// TNN7 hard-cell characterization.
pub fn table2() -> Vec<Table2Row> {
    let lib7 = cells::tnn7();
    ALL_MACROS
        .iter()
        .map(|&kind| {
            // Build a netlist that is just this macro.
            let mut b = NetBuilder::new(kind.cell_name());
            let ins: Vec<_> = kind
                .input_pins()
                .iter()
                .map(|p| b.input(p))
                .collect();
            let outs = expand(kind, &mut b, &ins);
            for (name, &o) in kind.output_pins().iter().zip(&outs) {
                b.output(name, o);
            }
            let out = synthesize(&b.finish(), Flow::Baseline);
            let base = analyze(&out.mapped, &cells::asap7(), GAMMA_CYCLES);
            let cell = lib7.macro_cell(kind).unwrap();
            Table2Row {
                kind,
                tnn7_leakage_nw: cell.leakage_nw,
                tnn7_delay_ps: cell.delay_ps,
                tnn7_area_um2: cell.area_um2,
                base,
            }
        })
        .collect()
}

/// Print [`table2`] in the paper's Table II layout.
pub fn print_table2(rows: &[Table2Row]) {
    println!("TABLE II: 7nm PPA for proposed custom macros (TNN7 cell vs synthesized ASAP7 baseline)");
    println!(
        "{:<20} | {:>12} {:>10} {:>12} | {:>12} {:>10} {:>12}",
        "Macro", "TNN7 leak nW", "delay ps", "area µm²", "base leak nW", "delay ps", "area µm²"
    );
    for r in rows {
        println!(
            "{:<20} | {:>12.2} {:>10.0} {:>12.2} | {:>12.2} {:>10.0} {:>12.2}",
            r.kind.cell_name(),
            r.tnn7_leakage_nw,
            r.tnn7_delay_ps,
            r.tnn7_area_um2,
            r.base.leakage_nw,
            r.base.critical_path_ps,
            r.base.cell_area_um2,
        );
    }
}

// ---------------------------------------------------------------------
// Fig. 11 — PPA scaling across the 36 UCR columns, ASAP7 vs TNN7
// ---------------------------------------------------------------------

/// One Fig. 11 point: a UCR column synthesized and analyzed under both flows.
#[derive(Clone, Debug)]
pub struct Fig11Row {
    /// The dataset's column geometry.
    pub config: UcrConfig,
    /// PPA under the ASAP7 baseline flow.
    pub base: PpaReport,
    /// PPA under the TNN7 macro flow.
    pub tnn7: PpaReport,
}

/// Synthesize + analyze the UCR column suite under both flows.
/// `quick` subsamples to every 4th design (CI-speed).
pub fn fig11(quick: bool) -> Vec<Fig11Row> {
    let suite = ucr_suite();
    let lib_b = cells::asap7();
    let lib_7 = cells::tnn7();
    suite
        .iter()
        .enumerate()
        .filter(|(i, _)| !quick || i % 4 == 0 || *i == suite.len() - 1)
        .map(|(_, cfg)| {
            let theta = (cfg.p as u32 * 7) / 4;
            let d = build_column(cfg.p, cfg.q, theta, BrvSource::Lfsr);
            let base = synthesize(&d.netlist, Flow::Baseline);
            let t7 = synthesize(&d.netlist, Flow::Tnn7);
            Fig11Row {
                config: *cfg,
                base: analyze(&base.mapped, &lib_b, GAMMA_CYCLES),
                tnn7: analyze(&t7.mapped, &lib_7, GAMMA_CYCLES),
            }
        })
        .collect()
}

/// Print [`fig11`] as the paper's PPA-scaling table.
pub fn print_fig11(rows: &[Fig11Row]) {
    println!("Fig. 11: ASAP7 vs TNN7 7nm PPA scaling across synapse counts (36 UCR columns)");
    println!(
        "{:<24} {:>8} | {:>10} {:>10} | {:>9} {:>9} | {:>8} {:>8} | {:>11} {:>11}",
        "dataset", "synapses", "A7 µm²", "T7 µm²", "A7 µW", "T7 µW", "A7 ns", "T7 ns", "A7 EDP", "T7 EDP"
    );
    for r in rows {
        println!(
            "{:<24} {:>8} | {:>10.1} {:>10.1} | {:>9.3} {:>9.3} | {:>8.2} {:>8.2} | {:>11.1} {:>11.1}",
            r.config.name,
            r.config.synapses(),
            r.base.area_um2,
            r.tnn7.area_um2,
            r.base.power_nw / 1000.0,
            r.tnn7.power_nw / 1000.0,
            r.base.comp_time_ns,
            r.tnn7.comp_time_ns,
            r.base.edp_fj_ns,
            r.tnn7.edp_fj_ns,
        );
    }
    let (p, d, a, e) = average_improvements(rows);
    println!(
        "average improvements with TNN7: power {p:.0}%, delay {d:.0}%, area {a:.0}%, EDP {e:.0}% \
         (paper §IV-A: ~18% power, ~18% faster, ~25% area, >45% EDP)"
    );
}

/// Mean (power, delay, area, EDP) improvements across rows.
pub fn average_improvements(rows: &[Fig11Row]) -> (f64, f64, f64, f64) {
    let mut acc = (0.0, 0.0, 0.0, 0.0);
    for r in rows {
        let (p, d, a, e) = r.tnn7.improvement_vs(&r.base);
        acc.0 += p;
        acc.1 += d;
        acc.2 += a;
        acc.3 += e;
    }
    let n = rows.len() as f64;
    (acc.0 / n, acc.1 / n, acc.2 / n, acc.3 / n)
}

// ---------------------------------------------------------------------
// Table III — MNIST multi-layer prototypes, ASAP7 vs TNN7
// ---------------------------------------------------------------------

/// One Table III row: an MNIST prototype's network-level PPA under both flows.
#[derive(Clone, Debug)]
pub struct Table3Row {
    /// Prototype name (1/3/4-layer).
    pub name: &'static str,
    /// MNIST error rate the paper reports for this prototype, %.
    pub paper_error_pct: f64,
    /// Total synapse count (the Table III scaling variable).
    pub synapses: usize,
    /// Network PPA under the ASAP7 baseline flow.
    pub base: NetworkPpa,
    /// Network PPA under the TNN7 macro flow.
    pub tnn7: NetworkPpa,
}

/// Synthesize + scale the three MNIST prototype networks under both flows.
pub fn table3() -> Vec<Table3Row> {
    mnist_layer_geometries()
        .into_iter()
        .map(|d| Table3Row {
            name: d.name,
            paper_error_pct: d.paper_error_pct,
            synapses: d.layers.iter().map(|l| l.synapses()).sum(),
            base: scale_network(&d.layers, Flow::Baseline, GAMMA_CYCLES),
            tnn7: scale_network(&d.layers, Flow::Tnn7, GAMMA_CYCLES),
        })
        .collect()
}

/// Print [`table3`] in the paper's Table III layout.
pub fn print_table3(rows: &[Table3Row]) {
    println!("TABLE III: ASAP7 vs TNN7 7nm PPA for the three MNIST TNN prototypes");
    println!(
        "{:<16} {:>9} {:>6} | {:<6} {:>9} {:>11} {:>10}",
        "Design", "synapses", "err%", "lib", "power mW", "comp ns", "area mm²"
    );
    for r in rows {
        for (lib, n) in [("ASAP7", &r.base), ("TNN7", &r.tnn7)] {
            println!(
                "{:<16} {:>9} {:>6.1} | {:<6} {:>9.2} {:>11.2} {:>10.2}",
                r.name, r.synapses, r.paper_error_pct, lib, n.power_mw, n.comp_time_ns, n.area_mm2
            );
        }
    }
}

// ---------------------------------------------------------------------
// Fig. 12 — synthesis runtime, ASAP7 vs TNN7
// ---------------------------------------------------------------------

/// One Fig. 12 point: metered synthesis runtime of a UCR column under both
/// flows.
#[derive(Clone, Debug)]
pub struct Fig12Row {
    /// The dataset's column geometry.
    pub config: UcrConfig,
    /// Baseline (ASAP7) synthesis wall time.
    pub base_wall: Duration,
    /// TNN7 synthesis wall time.
    pub tnn7_wall: Duration,
    /// Gates entering the baseline optimizer.
    pub base_gates: usize,
    /// Gates entering the TNN7 optimizer (macros preserved, so far fewer).
    pub tnn7_gates: usize,
}

impl Fig12Row {
    /// Baseline-over-TNN7 synthesis-runtime ratio (the Fig. 12 y-axis).
    pub fn speedup(&self) -> f64 {
        self.base_wall.as_secs_f64() / self.tnn7_wall.as_secs_f64().max(1e-9)
    }
}

/// Synthesize the UCR suite under both flows, metering wall time.
/// `quick` subsamples to every 4th design (CI-speed).
pub fn fig12(quick: bool) -> Vec<Fig12Row> {
    let suite = ucr_suite();
    suite
        .iter()
        .enumerate()
        .filter(|(i, _)| !quick || i % 4 == 0 || *i == suite.len() - 1)
        .map(|(_, cfg)| {
            let theta = (cfg.p as u32 * 7) / 4;
            let d = build_column(cfg.p, cfg.q, theta, BrvSource::Lfsr);
            let base = synthesize(&d.netlist, Flow::Baseline);
            let t7 = synthesize(&d.netlist, Flow::Tnn7);
            Fig12Row {
                config: *cfg,
                base_wall: base.stats.wall,
                tnn7_wall: t7.stats.wall,
                base_gates: base.stats.gates_in,
                tnn7_gates: t7.stats.gates_in,
            }
        })
        .collect()
}

/// Print [`fig12`] as the paper's synthesis-runtime table.
pub fn print_fig12(rows: &[Fig12Row]) {
    println!("Fig. 12: ASAP7 vs TNN7 synthesis runtime (netlist generation)");
    println!(
        "{:<24} {:>8} | {:>12} {:>12} | {:>9} | {:>10} {:>10}",
        "dataset", "synapses", "ASAP7", "TNN7", "speedup", "A7 gates", "T7 gates"
    );
    for r in rows {
        println!(
            "{:<24} {:>8} | {:>12} {:>12} | {:>8.2}x | {:>10} {:>10}",
            r.config.name,
            r.config.synapses(),
            crate::util::bench::fmt_dur(r.base_wall),
            crate::util::bench::fmt_dur(r.tnn7_wall),
            r.speedup(),
            r.base_gates,
            r.tnn7_gates,
        );
    }
    let avg: f64 = rows.iter().map(|r| r.speedup()).sum::<f64>() / rows.len() as f64;
    println!("average synthesis speedup with TNN7: {avg:.2}x (paper: 3.17x)");
}

// ---------------------------------------------------------------------
// Fig. 13 — layout routing density for the 82×2 TwoLeadECG column
// ---------------------------------------------------------------------

/// Place-and-estimate the 82×2 TwoLeadECG column under both flows
/// (returns `(ASAP7, TNN7)` layout reports).
pub fn fig13() -> (LayoutReport, LayoutReport) {
    let cfg = ucr_suite()
        .into_iter()
        .find(|c| c.name == "TwoLeadECG")
        .unwrap();
    let theta = (cfg.p as u32 * 7) / 4;
    let d = build_column(cfg.p, cfg.q, theta, BrvSource::Lfsr);
    let base = synthesize(&d.netlist, Flow::Baseline);
    let t7 = synthesize(&d.netlist, Flow::Tnn7);
    (
        place_and_estimate(&base.mapped, &cells::asap7()),
        place_and_estimate(&t7.mapped, &cells::tnn7()),
    )
}

/// Print [`fig13`]'s routing-density comparison.
pub fn print_fig13(base: &LayoutReport, t7: &LayoutReport) {
    println!("Fig. 13: ASAP7 vs TNN7 placement & routing-density, 82x2 TwoLeadECG column");
    for r in [base, t7] {
        println!(
            "{:<6}: die {:.1} x {:.1} µm ({} rows, {} cells) | WL {:.1} µm | WL density {:.3} µm/µm² | congestion avg {:.2} peak {:.2}",
            r.library, r.die_w_um, r.die_h_um, r.rows, r.placed_cells,
            r.total_wl_um, r.wl_density, r.avg_congestion, r.peak_congestion
        );
    }
    println!(
        "TNN7 reductions: total wirelength {:.0}%, peak congestion {:.0}% (Fig. 13's qualitative claim)",
        (1.0 - t7.total_wl_um / base.total_wl_um) * 100.0,
        (1.0 - t7.peak_congestion / base.peak_congestion) * 100.0
    );
}

// ---------------------------------------------------------------------
// Simulation engines — scalar vs 64-lane bit-parallel vs compiled
// lane-block toggle collection on the flagship 82×2 TwoLeadECG column
// (the functional-verification hot path feeding the activity-based
// power model)
// ---------------------------------------------------------------------

/// Lane-block width the `report sim` compiled measurement uses.
pub const SIM_ENGINES_WORDS: usize = 2;

/// Scalar vs bit-parallel vs compiled toggle-collection comparison on one
/// design.
#[derive(Clone, Debug)]
pub struct SimEnginesRow {
    /// Design (netlist) name.
    pub design: String,
    /// Net count of the simulated netlist.
    pub nets: usize,
    /// Simulated cycles per backend (the word-wide engines round up to a
    /// whole number of passes).
    pub scalar_cycles: u64,
    /// Lane-cycles simulated by the bit-parallel backend.
    pub word_cycles: u64,
    /// Lane-cycles simulated by the compiled backend.
    pub compiled_cycles: u64,
    /// Scalar-backend wall time.
    pub scalar_wall: Duration,
    /// Bit-parallel-backend wall time.
    pub word_wall: Duration,
    /// Compiled-backend wall time.
    pub compiled_wall: Duration,
    /// Mean switching activity α measured by the scalar backend.
    pub scalar_activity: f64,
    /// Mean switching activity α measured by the bit-parallel backend.
    pub word_activity: f64,
    /// Mean switching activity α measured by the compiled backend.
    pub compiled_activity: f64,
    /// Lane-block width `W` of the compiled measurement
    /// ([`SIM_ENGINES_WORDS`]).
    pub compiled_words: usize,
}

impl SimEnginesRow {
    /// Wall-clock speedup of the bit-parallel engine, normalized per
    /// simulated cycle.
    pub fn speedup(&self) -> f64 {
        let s = self.scalar_wall.as_secs_f64() / self.scalar_cycles.max(1) as f64;
        let w = self.word_wall.as_secs_f64() / self.word_cycles.max(1) as f64;
        s / w.max(1e-12)
    }

    /// Wall-clock speedup of the compiled engine over the scalar engine,
    /// normalized per simulated lane-cycle.
    pub fn speedup_compiled(&self) -> f64 {
        let s = self.scalar_wall.as_secs_f64() / self.scalar_cycles.max(1) as f64;
        let c = self.compiled_wall.as_secs_f64() / self.compiled_cycles.max(1) as f64;
        s / c.max(1e-12)
    }
}

/// Collect `cycles` cycles of toggle statistics on the 82×2 TwoLeadECG
/// column with all three simulation backends, timing each. The compiled
/// run uses a [`SIM_ENGINES_WORDS`]-word lane block, single-threaded, so
/// the comparison isolates the compile-vs-interpret gap (thread scaling
/// is measured by `benches/compiled_sim.rs`).
pub fn sim_engines(cycles: u64) -> SimEnginesRow {
    let cfg = ucr_suite()
        .into_iter()
        .find(|c| c.name == "TwoLeadECG")
        .unwrap();
    let theta = (cfg.p as u32 * 7) / 4;
    let d = build_column(cfg.p, cfg.q, theta, BrvSource::Lfsr);
    let t0 = Instant::now();
    let s = collect_toggles(&d.netlist, cycles, 7, SimBackend::Scalar).unwrap();
    let scalar_wall = t0.elapsed();
    let t1 = Instant::now();
    let w = collect_toggles(&d.netlist, cycles, 7, SimBackend::BitParallel64).unwrap();
    let word_wall = t1.elapsed();
    let t2 = Instant::now();
    let c = collect_toggles(
        &d.netlist,
        cycles,
        7,
        SimBackend::Compiled {
            words: SIM_ENGINES_WORDS,
            threads: 1,
        },
    )
    .unwrap();
    let compiled_wall = t2.elapsed();
    SimEnginesRow {
        design: d.netlist.name.clone(),
        nets: d.netlist.len(),
        scalar_cycles: s.cycles,
        word_cycles: w.cycles,
        compiled_cycles: c.cycles,
        scalar_wall,
        word_wall,
        compiled_wall,
        scalar_activity: s.activity(),
        word_activity: w.activity(),
        compiled_activity: c.activity(),
        compiled_words: SIM_ENGINES_WORDS,
    }
}

/// Print [`sim_engines`]'s backend comparison.
pub fn print_sim_engines(r: &SimEnginesRow) {
    println!(
        "Simulation engines: gate-sim toggle collection, {} ({} nets)",
        r.design, r.nets
    );
    let compiled_label = format!("compiled (W={})", r.compiled_words);
    for (name, cycles, wall, act) in [
        ("scalar", r.scalar_cycles, r.scalar_wall, r.scalar_activity),
        (
            "bit-parallel-64",
            r.word_cycles,
            r.word_wall,
            r.word_activity,
        ),
        (
            compiled_label.as_str(),
            r.compiled_cycles,
            r.compiled_wall,
            r.compiled_activity,
        ),
    ] {
        let per_cycle = wall.as_secs_f64() * 1e9 / cycles.max(1) as f64;
        println!(
            "{name:<16}: {cycles:>7} cycles in {:>10} ({per_cycle:>8.1} ns/cycle) | α = {act:.4}",
            crate::util::bench::fmt_dur(wall),
        );
    }
    println!(
        "bit-parallel speedup: {:.1}x | compiled speedup: {:.1}x (α spread: Δw = {:.4}, Δc = {:.4})",
        r.speedup(),
        r.speedup_compiled(),
        (r.scalar_activity - r.word_activity).abs(),
        (r.scalar_activity - r.compiled_activity).abs()
    );
}

/// JSON form of a [`SimEnginesRow`] (the `report sim` artifact schema).
pub fn sim_engines_json(r: &SimEnginesRow) -> Json {
    Json::obj()
        .set("design", r.design.as_str())
        .set("nets", r.nets)
        .set("scalar_cycles", r.scalar_cycles as f64)
        .set("word_cycles", r.word_cycles as f64)
        .set("compiled_cycles", r.compiled_cycles as f64)
        .set("scalar_ms", r.scalar_wall.as_secs_f64() * 1e3)
        .set("word_ms", r.word_wall.as_secs_f64() * 1e3)
        .set("compiled_ms", r.compiled_wall.as_secs_f64() * 1e3)
        .set("scalar_activity", r.scalar_activity)
        .set("word_activity", r.word_activity)
        .set("compiled_activity", r.compiled_activity)
        .set("compiled_words", r.compiled_words)
        .set("speedup", r.speedup())
        .set("speedup_compiled", r.speedup_compiled())
}

// ---------------------------------------------------------------------
// Training engines — scalar per-sample golden model vs the batched SoA
// kernel with deterministic multi-threaded column sharding (tnn::batch),
// on the two workloads that dominate experiment wall-clock: the 4-layer
// MNIST network epoch and UCR TwoLeadECG online training
// ---------------------------------------------------------------------

/// Scalar vs batched training-engine comparison on one workload.
#[derive(Clone, Debug)]
pub struct TrainEnginesRow {
    /// Workload label (mnist-4layer / ucr-TwoLeadECG).
    pub workload: String,
    /// Synapse count of the trained model.
    pub synapses: usize,
    /// Training samples in the epoch.
    pub samples: usize,
    /// Worker threads used for the multi-threaded measurement.
    pub threads: usize,
    /// Scalar golden-model epoch wall time.
    pub scalar_wall: Duration,
    /// Batched-kernel single-thread epoch wall time.
    pub batched_1t_wall: Duration,
    /// Batched-kernel multi-thread epoch wall time.
    pub batched_mt_wall: Duration,
}

impl TrainEnginesRow {
    /// Single-thread kernel speedup over the scalar engine.
    pub fn speedup_1t(&self) -> f64 {
        self.scalar_wall.as_secs_f64() / self.batched_1t_wall.as_secs_f64().max(1e-9)
    }
    /// Multi-threaded pipeline speedup over the scalar engine.
    pub fn speedup_mt(&self) -> f64 {
        self.scalar_wall.as_secs_f64() / self.batched_mt_wall.as_secs_f64().max(1e-9)
    }
}

/// Build the 4-layer MNIST training workload — procedural digit corpus
/// encoded to a volley batch plus a randomly-initialised network. Shared
/// by [`train_engines`] and `benches/tnn_throughput.rs` so `report train`
/// and `BENCH_tnn.json` always measure the same workload (`seed` drives
/// the corpus; `seed+1` the weights).
pub fn mnist_train_workload(
    samples: usize,
    seed: u64,
) -> (crate::tnn::TnnNetwork, crate::tnn::VolleyBatch) {
    use crate::mnist::{trainable_network, DigitCorpus};
    let corpus = DigitCorpus::generate(samples.div_ceil(10), seed);
    let batch = corpus.encode_batch(8);
    let mut net = trainable_network(4, crate::tnn::TnnParams::default());
    net.randomize(&mut crate::util::Rng64::seed_from_u64(seed.wrapping_add(1)));
    (net, batch)
}

/// Build the UCR TwoLeadECG online-training workload — sparse-encoded
/// gamma items plus an 82×2 column with density-scaled θ. Shared by
/// [`train_engines`] and `benches/tnn_throughput.rs` (`seed` drives the
/// dataset; `seed+2` the weights).
pub fn ucr_train_workload(
    per_cluster: usize,
    seed: u64,
) -> (crate::tnn::Column, Vec<crate::coordinator::GammaItem>) {
    use crate::coordinator::{encode_ucr, volley_density};
    let cfg = ucr_suite()
        .into_iter()
        .find(|c| c.name == "TwoLeadECG")
        .unwrap();
    let data = crate::ucr::generate(cfg, per_cluster, seed);
    let items = encode_ucr(&data, 8);
    let params = crate::tnn::TnnParams::default();
    let theta = crate::tnn::encode::sparse_theta(cfg.p, params.w_max(), volley_density(&items));
    let col = crate::tnn::Column::with_random_weights(
        cfg.p,
        cfg.q,
        theta,
        params,
        &mut crate::util::Rng64::seed_from_u64(seed.wrapping_add(2)),
    );
    (col, items)
}

/// Time one training epoch per engine on the 4-layer MNIST network and the
/// UCR TwoLeadECG column. `quick` shrinks the sample counts (CI-speed).
pub fn train_engines(quick: bool) -> Vec<TrainEnginesRow> {
    use crate::tnn::batch::default_threads;
    use crate::util::Rng64;

    let threads = default_threads();
    let mut rows = Vec::new();

    // 4-layer MNIST network epoch (the paper's deepest prototype shape).
    {
        let samples = if quick { 30 } else { 200 };
        let (base, batch) = mnist_train_workload(samples, 40);
        let synapses = base.synapse_count();

        let mut scalar = base.clone();
        let mut rng = Rng64::seed_from_u64(42);
        let t0 = Instant::now();
        for v in batch.iter() {
            scalar.step(v, &mut rng);
        }
        let scalar_wall = t0.elapsed();

        let stream = Rng64::seed_from_u64(42);
        let mut b1 = base.clone();
        let t1 = Instant::now();
        b1.step_epoch(&batch, &stream, 1);
        let batched_1t_wall = t1.elapsed();

        let mut bm = base.clone();
        let t2 = Instant::now();
        bm.step_epoch(&batch, &stream, threads);
        let batched_mt_wall = t2.elapsed();

        rows.push(TrainEnginesRow {
            workload: "mnist-4layer epoch".into(),
            synapses,
            samples: batch.len(),
            threads,
            scalar_wall,
            batched_1t_wall,
            batched_mt_wall,
        });
    }

    // UCR TwoLeadECG online training (single 82×2 column: the speedup here
    // is pure kernel — the multi-thread figure equals the 1-thread one).
    {
        let per_cluster = if quick { 30 } else { 150 };
        let (base, items) = ucr_train_workload(per_cluster, 7);

        let mut scalar = base.clone();
        let mut rng_s = Rng64::seed_from_u64(44);
        let t0 = Instant::now();
        for item in &items {
            scalar.step(&item.volley, &mut rng_s);
        }
        let scalar_wall = t0.elapsed();

        let mut batched = base.clone().batched();
        let mut rng_b = Rng64::seed_from_u64(44);
        let t1 = Instant::now();
        for item in &items {
            batched.step(&item.volley, &mut rng_b);
        }
        let batched_wall = t1.elapsed();

        rows.push(TrainEnginesRow {
            workload: "ucr-TwoLeadECG epoch".into(),
            synapses: base.synapse_count(),
            samples: items.len(),
            threads: 1,
            scalar_wall,
            batched_1t_wall: batched_wall,
            batched_mt_wall: batched_wall,
        });
    }

    rows
}

/// Print [`train_engines`]'s engine comparison.
pub fn print_train_engines(rows: &[TrainEnginesRow]) {
    println!(
        "Training engines: scalar golden model vs batched SoA kernel (tnn::batch; \
         determinism: batched results are bit-exact at any thread count)"
    );
    println!(
        "{:<22} {:>9} {:>8} | {:>12} {:>12} {:>12} | {:>8} {:>8}",
        "workload", "synapses", "samples", "scalar", "batched 1t", "batched mt", "1t", "mt"
    );
    for r in rows {
        println!(
            "{:<22} {:>9} {:>8} | {:>12} {:>12} {:>12} | {:>7.2}x {:>7.2}x",
            r.workload,
            r.synapses,
            r.samples,
            crate::util::bench::fmt_dur(r.scalar_wall),
            crate::util::bench::fmt_dur(r.batched_1t_wall),
            crate::util::bench::fmt_dur(r.batched_mt_wall),
            r.speedup_1t(),
            r.speedup_mt(),
        );
    }
    println!(
        "(acceptance target: batched multi-threaded >= 3x scalar; exact medians are \
         measured by `cargo bench --bench tnn_throughput` -> BENCH_tnn.json)"
    );
}

/// JSON form of [`train_engines`] rows (the `BENCH_tnn.json` schema).
pub fn train_engines_json(rows: &[TrainEnginesRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj()
                    .set("workload", r.workload.as_str())
                    .set("synapses", r.synapses)
                    .set("samples", r.samples)
                    .set("threads", r.threads)
                    .set("scalar_ms", r.scalar_wall.as_secs_f64() * 1e3)
                    .set("batched_1t_ms", r.batched_1t_wall.as_secs_f64() * 1e3)
                    .set("batched_mt_ms", r.batched_mt_wall.as_secs_f64() * 1e3)
                    .set("speedup_1t", r.speedup_1t())
                    .set("speedup_mt", r.speedup_mt())
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------
// Conformance — three-engine differential testing: the same seeded UCR
// workload runs on the golden, batched and gate-level engines, and the
// winners / weights / clustering-quality tables are diffed per geometry.
// The gate engine (the TNN7 macro netlist) must match the golden model
// bit for bit; the batched engine must match on every draw-free inference
// and is held to a loose clustering-quality floor on training (its leaner
// draw discipline samples the same stochastic process — see `tnn::batch` —
// so trajectories differ, but a catastrophic training regression may not
// hide behind that).
// ---------------------------------------------------------------------

/// One engine's diff against the golden reference on a conformance table.
#[derive(Clone, Debug)]
pub struct ConformanceEngineRow {
    /// Which engine this row diffs against the golden reference.
    pub engine: EngineKind,
    /// Winner mismatches vs golden on the draw-free pre-training inference
    /// pass (identical initial weights — must be 0 for every engine).
    pub infer_mismatches: usize,
    /// Winner mismatches vs golden across all training gammas.
    pub train_mismatches: usize,
    /// Post-training weight cells differing from golden.
    pub weight_mismatches: usize,
    /// Post-training inference: instances that fired.
    pub fired: usize,
    /// Rand index of post-training winners vs ground truth.
    pub rand_index: f64,
    /// Cluster purity of post-training winners.
    pub purity: f64,
    /// Whether this engine is required to match golden bit for bit
    /// (gate: yes; batched: training is statistical by design).
    pub bit_exact: bool,
    /// Golden reference clustering quality on the same workload (the bound
    /// the statistical rows are held to).
    pub ref_purity: f64,
    /// Instances the golden reference fired on.
    pub ref_fired: usize,
}

/// How far below the golden engine's purity a statistical (non-bit-exact)
/// engine may land and still pass — wide enough for two valid samples of
/// the same stochastic STDP process on small conformance tables, tight
/// enough to catch a catastrophic training regression (e.g. all weights
/// railed to 0 leaves purity at chance, 1/q).
pub const CONFORMANCE_PURITY_MARGIN: f64 = 0.4;

impl ConformanceEngineRow {
    /// Does this row meet its conformance requirement? Bit-exact rows must
    /// match golden on every training winner and weight; statistical rows
    /// (batched) must still fire when golden fires and keep clustering
    /// quality within [`CONFORMANCE_PURITY_MARGIN`] of golden's.
    pub fn ok(&self) -> bool {
        if self.infer_mismatches != 0 {
            return false;
        }
        if self.bit_exact {
            self.train_mismatches == 0 && self.weight_mismatches == 0
        } else {
            (self.ref_fired == 0 || self.fired > 0)
                && self.purity + CONFORMANCE_PURITY_MARGIN >= self.ref_purity
        }
    }

    /// Human-readable pass/fail label for the conformance table.
    pub fn verdict(&self) -> &'static str {
        match (self.ok(), self.bit_exact) {
            (true, true) => "OK (bit-exact)",
            (true, false) => "OK (statistical)",
            (false, _) => "MISMATCH",
        }
    }
}

/// One conformance table: one geometry, all three engines.
#[derive(Clone, Debug)]
pub struct ConformanceReport {
    /// Dataset label (real UCR name or synthetic conformance shape).
    pub dataset: String,
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons per column.
    pub q: usize,
    /// Gamma items in the workload.
    pub items: usize,
    /// Training epochs run.
    pub epochs: usize,
    /// Workload seed.
    pub seed: u64,
    /// Rows in engine order golden (reference), batched, gate.
    pub rows: Vec<ConformanceEngineRow>,
    /// Gate engine only: winner mismatches between the 64-lane
    /// word-parallel inference sweep and the scalar gate path (must be 0).
    pub word_batch_mismatches: usize,
    /// Fourth differential leg: structural-Verilog round-trip mismatches
    /// for this geometry's column netlist — emit → parse must rebuild a
    /// netlist that simulates bit-identically (values *and* toggles) on
    /// every backend, for the original and the `opt=inference` netlist,
    /// with the `NetRemap` toggle-translation law holding across the text
    /// (must be 0; see [`verilog_roundtrip_mismatches`]).
    pub verilog_roundtrip_mismatches: usize,
}

impl ConformanceReport {
    /// Did every engine meet its conformance requirement on this table?
    pub fn all_agree(&self) -> bool {
        self.word_batch_mismatches == 0
            && self.verilog_roundtrip_mismatches == 0
            && self.rows.iter().all(|r| r.ok())
    }
}

/// Everything observed from one engine on one conformance workload.
struct ConformanceTrace {
    infer0: Vec<Option<usize>>,
    train: Vec<Option<usize>>,
    weights: Vec<u8>,
    fired: usize,
    rand_index: f64,
    purity: f64,
    word_mismatches: usize,
}

fn conformance_trace(
    kind: EngineKind,
    cfg: UcrConfig,
    items: &[crate::coordinator::GammaItem],
    epochs: u64,
    seed: u64,
) -> crate::Result<ConformanceTrace> {
    use crate::coordinator::{run_stream, ucr_engine_with};
    use crate::util::Rng64;

    let mut rng = Rng64::seed_from_u64(seed);
    let mut engine = ucr_engine_with(
        kind,
        cfg.p,
        cfg.q,
        items,
        crate::tnn::TnnParams::default(),
        &mut rng,
    )?;

    // Draw-free pre-training inference (identical weights across engines).
    let mut infer0 = Vec::with_capacity(items.len());
    for item in items {
        infer0.push(engine.infer_winner(&item.volley)?);
    }
    // For the gate engine, also sweep the word-parallel batch path and diff
    // it against the scalar path.
    let word_mismatches = if kind == EngineKind::Gate {
        let word = engine.infer_winners(items)?;
        word.iter().zip(&infer0).filter(|(a, b)| a != b).count()
    } else {
        0
    };

    // Online training, one shared stream seed per epoch.
    let mut train = Vec::new();
    for epoch in 0..epochs {
        let out = run_stream(&mut engine, items.to_vec(), 16, seed.wrapping_add(1000 + epoch))?;
        train.extend(out.winners);
    }
    let weights = engine.weights().expect("behavioral engines expose weights");

    // Post-training inference → clustering quality. `infer_winners` routes
    // the gate engine through its word-parallel sweep (bit-exact with the
    // scalar path — proven by the pre-training diff above), so scoring
    // costs one netlist pass per 64 items instead of one per item.
    let post = engine.infer_winners(items)?;
    let (fired, rand_index, purity) = crate::coordinator::score_winners(&post, items, cfg.q);
    Ok(ConformanceTrace {
        infer0,
        train,
        weights,
        fired,
        rand_index,
        purity,
        word_mismatches,
    })
}

fn diff_winners(a: &[Option<usize>], b: &[Option<usize>]) -> usize {
    a.iter().zip(b).filter(|(x, y)| x != y).count()
}

fn conformance_row(
    kind: EngineKind,
    t: &ConformanceTrace,
    golden: &ConformanceTrace,
    bit_exact: bool,
) -> ConformanceEngineRow {
    ConformanceEngineRow {
        engine: kind,
        infer_mismatches: diff_winners(&t.infer0, &golden.infer0),
        train_mismatches: diff_winners(&t.train, &golden.train),
        weight_mismatches: t
            .weights
            .iter()
            .zip(&golden.weights)
            .filter(|(a, b)| a != b)
            .count(),
        fired: t.fired,
        rand_index: t.rand_index,
        purity: t.purity,
        bit_exact,
        ref_purity: golden.purity,
        ref_fired: golden.fired,
    }
}

/// Run the three-engine conformance workload for one geometry: generate a
/// seeded UCR-style dataset, build golden / batched / gate engines from
/// identical initial weights, and diff winners, weights and clustering
/// quality against the golden reference.
pub fn conformance_for(
    cfg: UcrConfig,
    per_cluster: usize,
    epochs: u64,
    seed: u64,
) -> crate::Result<ConformanceReport> {
    let data = crate::ucr::generate(cfg, per_cluster, seed);
    let items = crate::coordinator::encode_ucr(&data, 8);
    let golden = conformance_trace(EngineKind::Golden, cfg, &items, epochs, seed)?;
    let batched = conformance_trace(EngineKind::Batched, cfg, &items, epochs, seed)?;
    let gate = conformance_trace(EngineKind::Gate, cfg, &items, epochs, seed)?;
    let rows = vec![
        conformance_row(EngineKind::Golden, &golden, &golden, true),
        conformance_row(EngineKind::Batched, &batched, &golden, false),
        conformance_row(EngineKind::Gate, &gate, &golden, true),
    ];
    Ok(ConformanceReport {
        dataset: cfg.name.to_string(),
        p: cfg.p,
        q: cfg.q,
        items: items.len(),
        epochs: epochs as usize,
        seed,
        rows,
        word_batch_mismatches: gate.word_mismatches,
        verilog_roundtrip_mismatches: verilog_roundtrip_mismatches(cfg.p, cfg.q, seed)?,
    })
}

/// Fourth differential conformance leg: the structural-Verilog round trip
/// of this geometry's column netlist must be lossless. Builds the p×q
/// column (LFSR BRVs, default θ), then counts every disagreement between
/// the netlist and its emit→parse round trip — byte-determinism,
/// structural equality, re-emission fixpoint, and bit-identical values +
/// toggle counts on the scalar, bit-parallel-64 and compiled (1/2/4
/// worker) backends ([`crate::gates::verilog::roundtrip_mismatches`]).
/// The `opt=inference` pipeline output must round-trip too, and the PR 7
/// remap law must hold *across the text*: toggles measured on the
/// original netlist, translated through the [`crate::gates::NetRemap`],
/// must equal toggles measured on the netlist parsed back from the
/// optimized module's emission (lockstep stimulus via the remapped input
/// ids — the `tests/netlist_opt.rs` discipline). Returns 0 iff every
/// check agrees.
pub fn verilog_roundtrip_mismatches(p: usize, q: usize, seed: u64) -> crate::Result<usize> {
    use crate::gates::column_design::{build_column, BrvSource};
    use crate::gates::{verilog, WordSimulator};
    use crate::util::Rng64;

    let theta = (p as u32 * 7) / 4;
    let design = build_column(p, q, theta, BrvSource::Lfsr);
    // The flagship geometry is ~10× the small shapes; keep its leg at the
    // same gate-eval budget by shrinking the toggle-collection window.
    let cycles: u64 = if p * q >= 128 { 256 } else { 1024 };
    let mut m = verilog::roundtrip_mismatches(&design.netlist, cycles, seed)
        .map_err(anyhow::Error::msg)?;

    let (opt, remap) = design.optimize_inference().map_err(anyhow::Error::msg)?;
    m += verilog::roundtrip_mismatches(&opt.netlist, cycles, seed).map_err(anyhow::Error::msg)?;

    let text = verilog::emit(&opt.netlist).map_err(anyhow::Error::msg)?;
    let back = verilog::parse(&text)
        .map_err(|e| anyhow::anyhow!("round-tripped optimized netlist: {e}"))?
        .netlist;
    let mut orig = WordSimulator::new(&design.netlist).map_err(anyhow::Error::msg)?;
    let mut rt = WordSimulator::new(&back).map_err(anyhow::Error::msg)?;
    let mut rng = Rng64::seed_from_u64(seed ^ 0x04E7_1157);
    for _ in 0..16 {
        for (_, id) in &design.netlist.inputs {
            let w = rng.next_u64() & rng.next_u64() & rng.next_u64();
            orig.set_input_net(*id, w);
            if let Some(new) = remap.net(*id) {
                rt.set_input_net(new, w);
            }
        }
        orig.cycle();
        rt.cycle();
    }
    if &remap.translate_per_net(orig.toggles())[..] != rt.toggles() {
        m += 1;
    }
    Ok(m)
}

/// Dataset name for a conformance geometry (the 82×2 entry is the real
/// TwoLeadECG column of Fig. 13; the small shapes are synthetic).
fn conformance_name(p: usize, q: usize) -> &'static str {
    match (p, q) {
        (82, 2) => "TwoLeadECG",
        (16, 3) => "conformance-16x3",
        (7, 4) => "conformance-7x4",
        _ => "conformance",
    }
}

/// The full conformance suite over the shared geometry matrix
/// (`gates::CONFORMANCE_GEOMETRIES`; single-neuron shapes are skipped —
/// clustering metrics need at least two clusters). `quick` shrinks item
/// and epoch budgets to CI-smoke size. The gate engine simulates every net
/// of the p×q netlist for 16 unit cycles per gamma item, so budgets shrink
/// with synapse count.
pub fn conformance(quick: bool) -> crate::Result<Vec<ConformanceReport>> {
    let mut reports = Vec::new();
    for &(p, q, seed) in crate::gates::CONFORMANCE_GEOMETRIES.iter() {
        if q < 2 {
            continue;
        }
        let (per_cluster, epochs) = match (quick, p * q > 64) {
            (true, true) => (3, 1),
            (true, false) => (5, 2),
            (false, true) => (10, 2),
            (false, false) => (20, 3),
        };
        let cfg = UcrConfig {
            name: conformance_name(p, q),
            p,
            q,
        };
        reports.push(conformance_for(cfg, per_cluster, epochs, seed)?);
    }
    Ok(reports)
}

/// Print the [`conformance`] tables with per-engine verdicts.
pub fn print_conformance(reports: &[ConformanceReport]) {
    println!(
        "Conformance: golden vs batched vs gate-level (TNN7 macro netlist) on seeded UCR workloads"
    );
    for r in reports {
        println!(
            "\n{} ({}x{}, {} items, {} epochs, seed {:#x}) — reference: golden",
            r.dataset, r.p, r.q, r.items, r.epochs, r.seed
        );
        println!(
            "{:<9} | {:>7} {:>7} {:>8} | {:>6} {:>7} {:>7} | verdict",
            "engine", "infer≠", "train≠", "weight≠", "fired", "RI", "purity"
        );
        for row in &r.rows {
            println!(
                "{:<9} | {:>7} {:>7} {:>8} | {:>6} {:>7.3} {:>7.3} | {}",
                row.engine.name(),
                row.infer_mismatches,
                row.train_mismatches,
                row.weight_mismatches,
                row.fired,
                row.rand_index,
                row.purity,
                if row.engine == EngineKind::Golden {
                    "reference"
                } else {
                    row.verdict()
                },
            );
        }
        println!(
            "word-parallel gate sweep vs scalar gate path: {} mismatches",
            r.word_batch_mismatches
        );
        println!(
            "verilog round-trip (emit→parse, original + opt=inference): {} mismatches",
            r.verilog_roundtrip_mismatches
        );
    }
    if reports.iter().all(|r| r.all_agree()) {
        println!("\nALL ENGINES AGREE ({} conformance tables)", reports.len());
    } else {
        println!("\nENGINE DISAGREEMENT DETECTED — see tables above");
    }
}

/// JSON form of [`conformance`] reports.
pub fn conformance_json(reports: &[ConformanceReport]) -> Json {
    Json::Arr(
        reports
            .iter()
            .map(|r| {
                Json::obj()
                    .set("dataset", r.dataset.as_str())
                    .set("p", r.p)
                    .set("q", r.q)
                    .set("items", r.items)
                    .set("epochs", r.epochs)
                    .set("word_batch_mismatches", r.word_batch_mismatches)
                    .set("verilog_roundtrip_mismatches", r.verilog_roundtrip_mismatches)
                    .set("all_agree", r.all_agree())
                    .set(
                        "engines",
                        Json::Arr(
                            r.rows
                                .iter()
                                .map(|row| {
                                    Json::obj()
                                        .set("engine", row.engine.name())
                                        .set("infer_mismatches", row.infer_mismatches)
                                        .set("train_mismatches", row.train_mismatches)
                                        .set("weight_mismatches", row.weight_mismatches)
                                        .set("fired", row.fired)
                                        .set("rand_index", row.rand_index)
                                        .set("purity", row.purity)
                                })
                                .collect(),
                        ),
                    )
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------
// JSON dump for all experiments
// ---------------------------------------------------------------------

fn ppa_json(r: &PpaReport) -> Json {
    Json::obj()
        .set("area_um2", r.area_um2)
        .set("power_nw", r.power_nw)
        .set("leakage_nw", r.leakage_nw)
        .set("comp_time_ns", r.comp_time_ns)
        .set("edp", r.edp_fj_ns)
        .set("cells", r.std_cells)
        .set("macros", r.macro_cells)
}

/// JSON form of [`fig11`] rows (written to `target/reports/fig11.json`
/// by `benches/fig11_ucr_ppa.rs`).
pub fn fig11_json(rows: &[Fig11Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj()
                    .set("name", r.config.name)
                    .set("synapses", r.config.synapses())
                    .set("asap7", ppa_json(&r.base))
                    .set("tnn7", ppa_json(&r.tnn7))
            })
            .collect(),
    )
}

/// JSON form of [`fig12`] rows (written to `target/reports/fig12.json`
/// by `benches/fig12_synth_runtime.rs`).
pub fn fig12_json(rows: &[Fig12Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj()
                    .set("name", r.config.name)
                    .set("synapses", r.config.synapses())
                    .set("asap7_ms", r.base_wall.as_secs_f64() * 1e3)
                    .set("tnn7_ms", r.tnn7_wall.as_secs_f64() * 1e3)
                    .set("speedup", r.speedup())
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_covers_all_nine_macros() {
        let rows = table2();
        assert_eq!(rows.len(), 9);
        for r in &rows {
            assert!(r.tnn7_area_um2 > 0.0);
            assert!(r.base.cell_area_um2 > 0.0);
        }
        // The flagship claims: hard macros beat their synthesized baselines
        // on area in aggregate.
        let t7: f64 = rows.iter().map(|r| r.tnn7_area_um2).sum();
        let base: f64 = rows.iter().map(|r| r.base.cell_area_um2).sum();
        assert!(t7 < base, "macro suite area {t7:.2} vs baseline {base:.2}");
    }

    #[test]
    fn fig11_quick_produces_improvements_in_paper_direction() {
        let rows: Vec<Fig11Row> = fig11(true).into_iter().take(4).collect();
        assert!(!rows.is_empty());
        let (p, d, a, e) = average_improvements(&rows);
        assert!(p > 0.0, "power improvement {p:.1}%");
        assert!(d > 0.0, "delay improvement {d:.1}%");
        assert!(a > 0.0, "area improvement {a:.1}%");
        assert!(e > 0.0, "EDP improvement {e:.1}%");
    }

    #[test]
    fn sim_engines_backends_agree_and_word_is_faster() {
        let r = sim_engines(4096);
        assert_eq!(r.scalar_cycles, 4096);
        assert_eq!(r.word_cycles, 4096, "4096 cycles = exactly 64 word passes");
        assert_eq!(
            r.compiled_cycles, 4096,
            "4096 cycles = exactly 32 two-word compiled passes"
        );
        assert!(
            (r.scalar_activity - r.word_activity).abs() < 0.05,
            "α mismatch: scalar {} word {}",
            r.scalar_activity,
            r.word_activity
        );
        assert!(
            (r.scalar_activity - r.compiled_activity).abs() < 0.05,
            "α mismatch: scalar {} compiled {}",
            r.scalar_activity,
            r.compiled_activity
        );
        let j = sim_engines_json(&r).to_string();
        assert!(j.contains("speedup") && j.contains("compiled_activity"));
        // No wall-clock assertion here: timing under `cargo test` on a
        // loaded CI machine is nondeterministic. The ≥10× speedup claims
        // are measured (median-of-N) by benches/sim_throughput.rs and
        // benches/compiled_sim.rs.
        assert!(r.speedup() > 0.0 && r.speedup_compiled() > 0.0);
    }

    #[test]
    fn train_engines_quick_covers_both_workloads() {
        let rows = train_engines(true);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].workload.contains("mnist"));
        assert!(rows[1].workload.contains("TwoLeadECG"));
        for r in &rows {
            assert!(r.samples > 0 && r.synapses > 0);
            // No wall-clock thresholds here (CI machines are noisy); the
            // >=3x acceptance claim is measured median-of-N by
            // benches/tnn_throughput.rs.
            assert!(r.speedup_1t() > 0.0 && r.speedup_mt() > 0.0);
        }
        let j = train_engines_json(&rows).to_string();
        assert!(j.contains("speedup_mt") && j.contains("batched_1t_ms"));
    }

    #[test]
    fn conformance_small_geometry_all_engines_agree() {
        // One small table end to end: gate bit-exact with golden, batched
        // exact on draw-free inference, word-parallel sweep exact.
        let cfg = UcrConfig {
            name: "conformance-7x4",
            p: 7,
            q: 4,
        };
        let r = conformance_for(cfg, 5, 2, 0x5EED).unwrap();
        assert_eq!(r.rows.len(), 3);
        assert_eq!(r.items, 20);
        let golden = &r.rows[0];
        assert_eq!(golden.engine, EngineKind::Golden);
        assert!(golden.ok() && golden.infer_mismatches == 0 && golden.weight_mismatches == 0);
        let batched = &r.rows[1];
        assert_eq!(batched.engine, EngineKind::Batched);
        assert_eq!(batched.infer_mismatches, 0, "draw-free inference is exact");
        assert!(!batched.bit_exact, "batched training is statistical");
        let gate = &r.rows[2];
        assert_eq!(gate.engine, EngineKind::Gate);
        assert_eq!(gate.infer_mismatches, 0);
        assert_eq!(gate.train_mismatches, 0, "gate training winners bit-exact");
        assert_eq!(gate.weight_mismatches, 0, "gate weights bit-exact");
        assert_eq!(r.word_batch_mismatches, 0);
        assert_eq!(
            r.verilog_roundtrip_mismatches, 0,
            "emit→parse round trip must be lossless on the 7x4 column"
        );
        assert!(r.all_agree());
        let j = conformance_json(&[r]).to_string();
        assert!(j.contains("word_batch_mismatches") && j.contains("all_agree"));
        assert!(j.contains("verilog_roundtrip_mismatches"));
    }

    #[test]
    fn fig12_quick_shows_speedup_over_one() {
        let rows: Vec<Fig12Row> = fig12(true).into_iter().take(3).collect();
        for r in &rows {
            assert!(
                r.speedup() > 1.0,
                "{}: speedup {:.2}",
                r.config.name,
                r.speedup()
            );
            assert!(r.base_gates > r.tnn7_gates);
        }
    }
}
