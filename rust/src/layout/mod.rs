//! Row placement and routing-congestion estimation — the substitute for the
//! Innovus place-and-route evidence of the paper's Fig. 13, which shows the
//! TNN7-based 82×2 column routing visibly less congested than the ASAP7
//! baseline.
//!
//! Method: cells are placed into standard-cell rows in netlist (connectivity
//! -locality) order under a target utilization; every net's half-perimeter
//! wirelength (HPWL) is accumulated into a congestion grid; the reported
//! metrics are total wirelength, average congestion (routing demand per
//! bin), and peak congestion. Lower demand per unit area for the macro
//! design reproduces the figure's qualitative claim quantitatively.

use crate::cells::CellLibrary;
use crate::synth::map::MappedNetlist;
use std::collections::HashMap;

/// Placement + routing-estimate results.
#[derive(Clone, Debug)]
pub struct LayoutReport {
    /// Design (netlist) name.
    pub design: String,
    /// Library the design was mapped to.
    pub library: &'static str,
    /// Die width, µm.
    pub die_w_um: f64,
    /// Die height, µm.
    pub die_h_um: f64,
    /// Standard-cell rows.
    pub rows: usize,
    /// Placed objects (cells + macros).
    pub placed_cells: usize,
    /// Total estimated wirelength (HPWL sum), µm.
    pub total_wl_um: f64,
    /// Wirelength per unit die area, µm/µm² — the routing-density metric.
    pub wl_density: f64,
    /// Mean routing demand per congestion bin (wl µm per bin).
    pub avg_congestion: f64,
    /// Peak routing demand per congestion bin (wl µm per bin).
    pub peak_congestion: f64,
}

/// Standard-cell row height (ASAP7 7.5-track), µm.
const ROW_HEIGHT_UM: f64 = 0.27;
/// Target placement utilization.
const UTILIZATION: f64 = 0.70;
/// Congestion grid bin size, µm.
const BIN_UM: f64 = 1.0;

/// Place a mapped netlist and estimate routing congestion.
pub fn place_and_estimate(mapped: &MappedNetlist, lib: &CellLibrary) -> LayoutReport {
    // Gather placeable objects: standard cells + hard macros.
    struct Obj {
        w_um: f64,
        nets: Vec<u32>,
    }
    let mut objs: Vec<Obj> = Vec::with_capacity(mapped.cells.len() + mapped.macros.len());
    let mut total_area = 0.0;
    for c in &mapped.cells {
        let m = lib.get(c.cell);
        total_area += m.area_um2;
        let mut nets = c.ins.clone();
        nets.push(c.out);
        objs.push(Obj {
            w_um: m.area_um2 / ROW_HEIGHT_UM,
            nets,
        });
    }
    for (kind, ins, outs) in &mapped.macros {
        let m = lib.macro_cell(*kind).expect("macro cell in library");
        total_area += m.area_um2;
        let mut nets = ins.clone();
        nets.extend_from_slice(outs);
        objs.push(Obj {
            w_um: m.area_um2 / ROW_HEIGHT_UM,
            nets,
        });
    }
    // Die: near-square at target utilization.
    let die_area = total_area / UTILIZATION;
    let die_w = die_area.sqrt().max(ROW_HEIGHT_UM * 2.0);
    let rows = (die_area / die_w / ROW_HEIGHT_UM).ceil().max(1.0) as usize;
    let die_h = rows as f64 * ROW_HEIGHT_UM;

    // Row placement in object order (builder order is connectivity-local:
    // synapse datapaths and their neuron trees are emitted contiguously,
    // which is what a min-cut placer exploits too).
    let mut pos: Vec<(f64, f64)> = Vec::with_capacity(objs.len());
    let mut row = 0usize;
    let mut x = 0.0f64;
    for o in &objs {
        if x + o.w_um > die_w && x > 0.0 {
            row += 1;
            x = 0.0;
        }
        let y = (row % rows.max(1)) as f64 * ROW_HEIGHT_UM + ROW_HEIGHT_UM / 2.0;
        pos.push((x + o.w_um / 2.0, y));
        x += o.w_um;
    }
    let placed = pos.len();

    // Net bounding boxes → HPWL and congestion grid.
    let mut net_pins: HashMap<u32, (f64, f64, f64, f64)> = HashMap::new();
    for (o, &(cx, cy)) in objs.iter().zip(&pos) {
        for &net in &o.nets {
            let e = net_pins
                .entry(net)
                .or_insert((f64::MAX, f64::MIN, f64::MAX, f64::MIN));
            e.0 = e.0.min(cx);
            e.1 = e.1.max(cx);
            e.2 = e.2.min(cy);
            e.3 = e.3.max(cy);
        }
    }
    let bins_x = (die_w / BIN_UM).ceil().max(1.0) as usize;
    let bins_y = (die_h / BIN_UM).ceil().max(1.0) as usize;
    let mut grid = vec![0.0f64; bins_x * bins_y];
    let mut total_wl = 0.0;
    for (_, (x0, x1, y0, y1)) in &net_pins {
        if *x1 < *x0 {
            continue; // single-pin net
        }
        let hpwl = (x1 - x0) + (y1 - y0);
        total_wl += hpwl;
        // Spread demand uniformly over the bbox bins.
        let bx0 = (x0 / BIN_UM) as usize;
        let bx1 = ((x1 / BIN_UM) as usize).min(bins_x - 1);
        let by0 = (y0 / BIN_UM) as usize;
        let by1 = ((y1 / BIN_UM) as usize).min(bins_y - 1);
        let nbins = ((bx1 - bx0 + 1) * (by1 - by0 + 1)) as f64;
        let share = hpwl / nbins;
        for by in by0..=by1 {
            for bx in bx0..=bx1 {
                grid[by * bins_x + bx] += share;
            }
        }
    }
    let avg = grid.iter().sum::<f64>() / grid.len() as f64;
    let peak = grid.iter().fold(0.0f64, |m, &v| m.max(v));

    LayoutReport {
        design: mapped.name.clone(),
        library: lib.name,
        die_w_um: die_w,
        die_h_um: die_h,
        rows,
        placed_cells: placed,
        total_wl_um: total_wl,
        wl_density: total_wl / (die_w * die_h),
        avg_congestion: avg,
        peak_congestion: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells;
    use crate::gates::column_design::{build_column, BrvSource};
    use crate::synth::flow::{synthesize, Flow};

    fn layouts(p: usize, q: usize) -> (LayoutReport, LayoutReport) {
        let theta = (p as u32 * 7) / 4;
        let d = build_column(p, q, theta, BrvSource::Lfsr);
        let base = synthesize(&d.netlist, Flow::Baseline);
        let t7 = synthesize(&d.netlist, Flow::Tnn7);
        (
            place_and_estimate(&base.mapped, &cells::asap7()),
            place_and_estimate(&t7.mapped, &cells::tnn7()),
        )
    }

    #[test]
    fn placement_fits_all_cells() {
        let (b, t) = layouts(8, 2);
        assert!(b.placed_cells > t.placed_cells);
        assert!(b.die_w_um > 0.0 && b.die_h_um > 0.0);
        assert!(b.total_wl_um > 0.0);
    }

    #[test]
    fn tnn7_layout_is_less_congested() {
        // Fig. 13's claim: the macro design routes with visibly lower
        // density. Our quantitative proxy: wirelength per die area and
        // average bin congestion must both be lower.
        let (b, t) = layouts(12, 2);
        assert!(
            t.wl_density < b.wl_density,
            "wl density: tnn7 {} vs base {}",
            t.wl_density,
            b.wl_density
        );
        assert!(t.avg_congestion < b.avg_congestion);
    }

    #[test]
    fn bigger_columns_have_bigger_die() {
        let (b1, _) = layouts(6, 2);
        let (b2, _) = layouts(20, 2);
        assert!(b2.die_w_um * b2.die_h_um > b1.die_w_um * b1.die_h_um);
    }
}
