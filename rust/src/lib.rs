//! # TNN7 — Temporal Neural Network macro suite & hardware co-design framework
//!
//! Reproduction of *"TNN7: A Custom Macro Suite for Implementing Highly Optimized
//! Designs of Neuromorphic TNNs"* (Nair, Vellaisamy, Bhasuthkar, Shen — CMU, 2022).
//!
//! The crate is organised in two halves that mirror the paper:
//!
//! * **Functional half** — what TNN hardware *computes*:
//!   - [`tnn`]: bit-accurate, cycle-level golden model of the column
//!     microarchitecture of Nair et al. (ISVLSI'21): ramp-no-leak synapses,
//!     adder-tree neuron bodies, 1-WTA lateral inhibition, and four-case
//!     probabilistic STDP with bimodal weight stabilization.
//!   - [`runtime`] + [`coordinator`]: the deployment shell. A tokio-based
//!     streaming orchestrator feeds gamma-cycle input instances through
//!     AOT-compiled XLA executables of the same column semantics (authored in
//!     JAX/Pallas at build time, loaded via PJRT — Python is never on the
//!     request path).
//!   - [`ucr`] and [`mnist`]: the two application workloads the paper
//!     evaluates (unsupervised time-series clustering; digit recognition).
//!   - [`serve`]: the always-on deployment shape — a dynamic-batching
//!     inference server coalescing mixed-engine, mixed-geometry query
//!     streams into compiled lane-block passes over shared artifacts
//!     from [`gates::artifact_cache`].
//!
//! * **Hardware half** — what TNN hardware *costs* (the substitute for the
//!   Cadence/ASAP7 stack, built from scratch per the reproduction rules):
//!   - [`gates`]: gate-level netlist IR, the nine TNN7 macros as gate
//!     netlists, and three levelized simulation engines — a scalar
//!     reference engine, a 64-lane bit-parallel interpreter (one `u64`
//!     word per net, toggles counted by popcount), and a compiled netlist
//!     program ([`gates::compile`]: flat instruction stream over
//!     multi-word lane blocks, levels sharded across worker threads),
//!     selectable via [`gates::SimBackend`] — used to
//!     verify the macros against the golden model and to extract switching
//!     activity for the power model (see README §"Simulation engines").
//!     The macro netlist is also a first-class *column engine*
//!     ([`gates::gate_engine`], `--engine gate`): real workloads run on the
//!     gates and are diffed against the behavioral engines by the
//!     three-engine conformance suite (`harness::conformance`, README
//!     §"Verification").
//!   - [`cells`]: a 7nm-class standard-cell library model (ASAP7-calibrated)
//!     plus the TNN7 hard-macro library carrying the paper's Table II
//!     characterization.
//!   - [`synth`]: a behavioral → gate synthesis engine (elaborate, tech-map,
//!     optimize) with hard-macro preservation and wall-clock metering — the
//!     mechanism behind the paper's Fig. 12 runtime result.
//!   - [`ppa`]: post-synthesis power/performance/area analysis (static
//!     timing, leakage + activity-based dynamic power, area with net
//!     estimates, EDP).
//!   - [`layout`]: row placement and routing-congestion estimation (Fig. 13).
//!
//! [`harness`] regenerates every table and figure of the paper's evaluation,
//! and [`sweep`] generalizes them to declarative design-space exploration
//! campaigns — a grid over (geometry, θ policy, flow, engine, seed) executed
//! in parallel behind a resumable content-addressed result cache, reported
//! as Pareto frontiers.
//!
//! Two documents complement this API reference:
//!
//! * `docs/ARCHITECTURE.md` — module map, the stimulus → engines → toggles →
//!   α → PPA dataflow, and the **normative determinism contract** every
//!   parallel pipeline in this crate follows;
//! * `README.md` §"Reproduction matrix" — one table mapping each paper
//!   artifact (Table II/III, Fig. 11/12/13) to the exact command that
//!   regenerates it and the file it writes.
#![warn(missing_docs)]

pub mod cells;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod gates;
pub mod harness;
pub mod layout;
pub mod metrics;
pub mod mnist;
pub mod ppa;
pub mod runtime;
pub mod serve;
pub mod sweep;
pub mod synth;
pub mod tnn;
pub mod ucr;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
