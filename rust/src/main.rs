//! `tnn7` — leader binary / CLI.
//!
//! The subcommand surface (synopses, flags, help text) is defined once in
//! `tnn7::cli::COMMANDS` and rendered by `tnn7::cli::usage`; this file
//! only dispatches. Run `tnn7 help <command>` for flag-by-flag help.

use tnn7::cli::{self, flag, help_for, opt, overrides, usage};
use tnn7::config::{EngineKind, RunConfig};
use tnn7::coordinator::{encode_ucr, run_stream, Engine};
use tnn7::gates::column_design::{build_column, BrvSource};
use tnn7::harness;
use tnn7::runtime::XlaRuntime;
use tnn7::sweep::{self, SweepSpec};
use tnn7::synth::flow::Flow;
use tnn7::tnn::params::TnnParams;
use tnn7::ucr;
use tnn7::util::Rng64;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> tnn7::Result<()> {
    let cmd = args.first().map(|s| s.as_str());
    // `tnn7 <cmd> --help` prints the same text as `tnn7 help <cmd>`.
    if let (Some(c), true) = (cmd, flag(args, "--help")) {
        if let Some(h) = help_for(c) {
            println!("{h}");
            return Ok(());
        }
    }
    match cmd {
        Some("report") => report(args),
        Some("faults") => faults_cmd(args),
        Some("run") => run(args),
        Some("sweep") => sweep_cmd(args),
        Some("synth") => synth_cmd(args),
        Some("emit-verilog") => emit_verilog(args),
        Some("parse-verilog") => parse_verilog(args),
        Some("serve") => serve(args),
        Some("selftest") => selftest(),
        Some("help") => {
            match args.get(1).map(|s| s.as_str()).and_then(help_for) {
                Some(h) => println!("{h}"),
                None => println!("{}", usage()),
            }
            Ok(())
        }
        _ => {
            eprintln!("{}", usage());
            Ok(())
        }
    }
}

fn report(args: &[String]) -> tnn7::Result<()> {
    let quick = flag(args, "--quick");
    match args.get(1).map(|s| s.as_str()) {
        Some("table2") => harness::print_table2(&harness::table2()),
        Some("fig11") => harness::print_fig11(&harness::fig11(quick)),
        Some("table3") => harness::print_table3(&harness::table3()),
        Some("fig12") => harness::print_fig12(&harness::fig12(quick)),
        Some("fig13") => {
            let (b, t) = harness::fig13();
            harness::print_fig13(&b, &t);
        }
        Some("sim") => {
            let row = harness::sim_engines(if quick { 4096 } else { 65536 });
            harness::print_sim_engines(&row);
        }
        Some("train") => harness::print_train_engines(&harness::train_engines(quick)),
        Some("conformance") => {
            let reports = harness::conformance(quick)?;
            harness::print_conformance(&reports);
            anyhow::ensure!(
                reports.iter().all(|r| r.all_agree()),
                "engine disagreement detected"
            );
        }
        Some("faults") => run_faults(quick, &[])?,
        Some("headline") => {
            let rows = harness::fig11(quick);
            let (p, d, a, e) = harness::average_improvements(&rows);
            println!(
                "TNN7 vs ASAP7 average improvements (UCR suite{}):",
                if quick { ", quick subsample" } else { "" }
            );
            println!("  power {p:.0}%  delay {d:.0}%  area {a:.0}%  EDP {e:.0}%");
            println!("  paper: power 14%, delay 16%, area 28%, EDP 45%");
            let largest = rows.last().unwrap();
            println!(
                "largest column ({} synapses): {:.3} mm², {:.1} µW with TNN7 (paper: 0.054 mm², 39 µW)",
                largest.config.synapses(),
                largest.tnn7.area_um2 * 1e-6,
                largest.tnn7.power_nw / 1000.0
            );
        }
        other => anyhow::bail!("unknown report {other:?}\n{}", cli::help_for("report").unwrap()),
    }
    Ok(())
}

fn faults_cmd(args: &[String]) -> tnn7::Result<()> {
    run_faults(flag(args, "--quick"), &overrides(args))
}

/// Shared body of `tnn7 faults` and `tnn7 report faults`: run the seeded
/// campaign, print the table, and fail loudly if any simulator backend
/// disagrees with the others' fault verdicts.
fn run_faults(quick: bool, overrides: &[String]) -> tnn7::Result<()> {
    let mut spec = if quick {
        harness::FaultSpec::quick()
    } else {
        harness::FaultSpec::default()
    };
    spec.apply_overrides(overrides)?;
    let report = harness::fault_campaign(&spec)?;
    harness::print_faults(&report);
    anyhow::ensure!(
        report.gate.backends_agree,
        "fault verdicts differ across simulator backends"
    );
    Ok(())
}

fn run(args: &[String]) -> tnn7::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_overrides(&overrides(args))?;
    if let Some(e) = opt(args, "--engine") {
        cfg.engine = EngineKind::parse(e)?;
    }
    if let Some(b) = opt(args, "--sim-backend") {
        cfg.sim_backend = tnn7::gates::SimBackend::parse(b)?;
    }
    match args.get(1).map(|s| s.as_str()) {
        Some("ucr") => {
            let name = opt(args, "--dataset").unwrap_or("TwoLeadECG");
            let dataset = ucr::ucr_suite()
                .into_iter()
                .find(|c| c.name == name)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}"))?;
            let per_cluster = (cfg.gamma_instances / dataset.q).max(5);
            let data = ucr::generate(dataset, per_cluster, cfg.seed);
            let items = encode_ucr(&data, 8);
            let mut rng = Rng64::seed_from_u64(cfg.seed);
            let rt;
            let mut engine = match cfg.engine {
                EngineKind::Golden | EngineKind::Batched | EngineKind::Gate => {
                    if cfg.engine == EngineKind::Gate && cfg.gamma_instances > 100 {
                        eprintln!(
                            "note: the gate engine simulates the full macro netlist per gamma \
                             instance; consider gamma_instances=40 for a reduced-size run"
                        );
                    }
                    tnn7::coordinator::ucr_engine_with(
                        cfg.engine,
                        dataset.p,
                        dataset.q,
                        &items,
                        TnnParams::default(),
                        &mut rng,
                    )?
                }
                EngineKind::Xla => {
                    rt = XlaRuntime::load(&cfg.artifacts_dir)?;
                    let exe = rt.column(dataset.p, dataset.q, "step")?;
                    Engine::xla(exe, &mut rng)
                }
            };
            // Batched gate-level inference runs on the selected simulator
            // backend (`--sim-backend compiled` + `sim_words=`); winners
            // are bit-exact across backends. No-op for other engines.
            engine.set_sim_backend(cfg.resolved_sim_backend());
            let mut out = run_stream(&mut engine, items.clone(), cfg.channel_depth, cfg.seed)?;
            for epoch in 1..5 {
                out = run_stream(&mut engine, items.clone(), cfg.channel_depth, cfg.seed + epoch)?;
            }
            println!("{}", out.metrics.summary(out.wall));
            // Score clustering on a fresh inference pass. `infer_winners`
            // routes the gate engine through its 64-lane word-parallel
            // netlist sweep (bit-exact with the per-item path), and
            // `score_winners` is the same convention the conformance
            // harness reports.
            let winners = engine.infer_winners(&items)?;
            let (fired, ri, pu) =
                tnn7::coordinator::score_winners(&winners, &items, dataset.q);
            println!(
                "{name}: {} instances, rand index {ri:.3}, purity {pu:.3} (fired on {fired}/{})",
                out.processed,
                items.len(),
            );
        }
        Some("mnist") => {
            let layers: usize = opt(args, "--layers").unwrap_or("3").parse()?;
            run_mnist(layers, &cfg)?;
        }
        other => anyhow::bail!("unknown run target {other:?}\n{}", cli::help_for("run").unwrap()),
    }
    Ok(())
}

fn run_mnist(layers: usize, cfg: &RunConfig) -> tnn7::Result<()> {
    use tnn7::mnist::{trainable_network, DigitCorpus};
    use tnn7::tnn::VoteClassifier;
    let mut rng = Rng64::seed_from_u64(cfg.seed);
    let mut net = trainable_network(layers, TnnParams::default());
    net.randomize(&mut rng);
    let train = DigitCorpus::generate(cfg.gamma_instances / 10, cfg.seed);
    let test = DigitCorpus::generate(20, cfg.seed + 1);
    println!(
        "{layers}-layer TNN: {} synapses, training on {} digits ({} engine)…",
        net.synapse_count(),
        train.len(),
        cfg.engine.name(),
    );
    // Encode once; training, calibration and scoring all read this batch.
    let train_batch = train.encode_batch(8);
    match cfg.engine {
        EngineKind::Golden => {
            for volley in train_batch.iter() {
                net.step(volley, &mut rng);
            }
        }
        EngineKind::Batched => {
            // One deterministic parallel epoch: columns sharded across
            // workers, results bit-exact at any thread count.
            net.step_epoch(
                &train_batch,
                &Rng64::seed_from_u64(cfg.seed ^ 0xE90C),
                cfg.threads,
            );
        }
        EngineKind::Xla | EngineKind::Gate => {
            anyhow::bail!("run mnist supports --engine golden|batched")
        }
    }
    // calibrate the vote readout, then test (batched inference is bit-exact
    // with the per-sample path, so use it for both engines)
    let mut vote = VoteClassifier::new(net.output_len(), 10);
    let train_out = net.infer_batch(&train_batch, cfg.threads);
    for (s, &l) in train.labels.iter().enumerate() {
        vote.observe(train_out.volley(s), l);
    }
    let test_out = net.infer_batch(&test.encode_batch(8), cfg.threads);
    let mut correct = 0;
    for (s, &l) in test.labels.iter().enumerate() {
        if vote.classify(test_out.volley(s)) == Some(l) {
            correct += 1;
        }
    }
    let err = 100.0 * (1.0 - correct as f64 / test.len() as f64);
    println!(
        "{layers}-layer error rate on synthetic digits: {err:.1}% ({correct}/{} correct)",
        test.len()
    );
    Ok(())
}

fn sweep_cmd(args: &[String]) -> tnn7::Result<()> {
    // Spec resolution order: file (first non-flag, non-override argument
    // after "sweep") < built-in default/quick grid; then key=value
    // overrides on top. The default cache location is shared with
    // RunConfig's `cache_dir` key.
    let spec_file = args[1..]
        .iter()
        .find(|a| !a.starts_with("--") && !a.contains('='));
    let mut spec = match spec_file {
        Some(path) => SweepSpec::load(path)?,
        None if flag(args, "--quick") => SweepSpec::quick(),
        None => SweepSpec::default(),
    };
    spec.apply_overrides(&overrides(args))?;
    let use_cache = !flag(args, "--no-cache");
    let outcome = sweep::run_sweep(&spec, use_cache)?;
    sweep::print_summary(&outcome);
    let (tsv, json) = sweep::write_reports(&outcome)?;
    println!("wrote {} and {}", tsv.display(), json.display());
    Ok(())
}

fn synth_cmd(args: &[String]) -> tnn7::Result<()> {
    let p: usize = opt(args, "--p").unwrap_or("82").parse()?;
    let q: usize = opt(args, "--q").unwrap_or("2").parse()?;
    let flow = Flow::parse(opt(args, "--flow").unwrap_or("tnn7"))?;
    let theta = (p as u32 * 7) / 4;
    let d = build_column(p, q, theta, BrvSource::Lfsr);
    let out = flow.run(&d.netlist);
    let lib = flow.library();
    let rep = tnn7::ppa::report::analyze(&out.mapped, &lib, harness::GAMMA_CYCLES);
    println!(
        "synthesized {}x{} column with {} in {:?} ({} gates in, {} cells + {} macros out, {} opt iterations)",
        p, q, flow.name(), out.stats.wall, out.stats.gates_in,
        out.stats.cells_out, out.stats.macros_out, out.stats.opt.iterations
    );
    println!("{}", rep.row());
    Ok(())
}

fn emit_verilog(args: &[String]) -> tnn7::Result<()> {
    use tnn7::gates::verilog;
    let p: usize = opt(args, "--p").unwrap_or("82").parse()?;
    let q: usize = opt(args, "--q").unwrap_or("2").parse()?;
    let theta = (p as u32 * 7) / 4;
    let d = build_column(p, q, theta, BrvSource::Lfsr);
    let flat = flag(args, "--flat");
    let text = if flat {
        verilog::emit_flat(&d.netlist)
    } else {
        verilog::emit(&d.netlist)
    }
    .map_err(anyhow::Error::msg)?;
    // First positional argument = output path; skip the flag and the two
    // valued options when scanning for it.
    let mut out = None;
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--flat" => {}
            "--p" | "--q" => {
                it.next();
            }
            other => {
                out = Some(other.to_string());
                break;
            }
        }
    }
    match out.as_deref() {
        None | Some("-") => print!("{text}"),
        Some(path) => {
            std::fs::write(path, &text)?;
            eprintln!(
                "wrote {path}: {}x{} column, {} nets, {} macros{}",
                p,
                q,
                d.netlist.len(),
                d.netlist.macros.len(),
                if flat { " (flattened)" } else { "" }
            );
        }
    }
    Ok(())
}

fn parse_verilog(args: &[String]) -> tnn7::Result<()> {
    use tnn7::gates::verilog;
    let file = args
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("parse-verilog needs a file argument (`-` = stdin)"))?;
    let src = if file == "-" {
        std::io::read_to_string(std::io::stdin().lock())?
    } else {
        std::fs::read_to_string(file)?
    };
    let parsed = verilog::parse(&src).map_err(|e| anyhow::anyhow!("{file}: {e}"))?;
    let nl = &parsed.netlist;
    let c = nl.census();
    println!(
        "parsed module {}: {} nets ({} comb, {} dffs, {} sources), {} macros ({} macro pins), {} inputs, {} outputs",
        nl.name,
        nl.len(),
        c.comb,
        c.dffs,
        c.sources,
        c.macros,
        c.macro_pins,
        nl.inputs.len(),
        nl.outputs.len()
    );
    Ok(())
}

fn serve(args: &[String]) -> tnn7::Result<()> {
    use tnn7::serve::{
        print_chaos_summary, print_summary, run_bench, run_chaos, serve_lines, serve_socket,
        write_chaos_report, write_report, ServeSpec, Server, SocketConfig,
    };
    let mut spec = if flag(args, "--quick") {
        ServeSpec::quick()
    } else {
        ServeSpec::default()
    };
    spec.apply_overrides(&overrides(args))?;
    if spec.capacity > 0 {
        tnn7::gates::artifact_cache::set_cache_capacities(spec.capacity, spec.capacity * 2);
    }
    if spec.chaos != "off" {
        // Chaos mode: the deterministic fault-injection harness. The
        // verdict transcript is byte-stable at any worker count; a
        // stranded rider (a request that never got a reply) fails the
        // run — that is the invariant the harness exists to enforce.
        let report = run_chaos(&spec)?;
        print_chaos_summary(&report);
        write_chaos_report(&spec, &report)?;
        println!(
            "wrote {} and {}",
            spec.out_dir.join("BENCH_chaos.json").display(),
            spec.out_dir.join("chaos_transcript.tsv").display()
        );
        anyhow::ensure!(
            report.stranded == 0,
            "{} riders never received a reply",
            report.stranded
        );
        return Ok(());
    }
    if flag(args, "--stdin") {
        // CI pipe mode: requests on stdin until EOF, replies (sorted by
        // request id, byte-stable at any worker count) on stdout.
        let server = Server::start(&spec)?;
        let n = serve_lines(
            &server,
            std::io::stdin().lock(),
            std::io::stdout().lock(),
            spec.deadline_ms,
        )?;
        eprintln!(
            "tnn7 serve: answered {n} requests in {} lane-block passes",
            server.batches()
        );
        server.shutdown();
        return Ok(());
    }
    if let Some(addr) = opt(args, "--listen") {
        let server = Server::start(&spec)?;
        // Serve until a client sends the `!drain` control line (the
        // graceful-shutdown signal; no signal-handling crate is
        // vendored, so SIGINT still hard-kills). serve_socket stops
        // accepting, flushes every open connection, and joins its
        // threads; shutdown() then drains the in-flight queue.
        let drain = std::sync::atomic::AtomicBool::new(false);
        serve_socket(&server, addr, &drain, &SocketConfig::from_spec(&spec))?;
        let c = server.counters();
        eprintln!("tnn7 serve: drained ({})", c.summary());
        server.shutdown();
        return Ok(());
    }
    // Default: bench mode with the deterministic seeded client.
    let report = run_bench(&spec)?;
    print_summary(&report);
    write_report(&report)?;
    println!(
        "wrote {} and {}",
        spec.out_dir.join("BENCH_serve.json").display(),
        spec.out_dir.join("serve_transcript.tsv").display()
    );
    anyhow::ensure!(
        report.patterns.iter().all(|p| p.winners_match_sequential),
        "batched winners diverged from the sequential reference"
    );
    Ok(())
}

fn selftest() -> tnn7::Result<()> {
    use tnn7::gates::column_design::ColumnSim;
    use tnn7::tnn::column::Column;
    use tnn7::tnn::spike::SpikeTime;
    let params = TnnParams::default();
    let (p, q, theta) = (6, 2, 7);
    let mut rng = Rng64::seed_from_u64(0xDEC0DE);
    let design = build_column(p, q, theta, BrvSource::Inputs);
    let mut gate = ColumnSim::new(&design, params.clone()).map_err(anyhow::Error::msg)?;
    let mut golden = Column::with_random_weights(p, q, theta, params, &mut rng);
    gate.set_weights(golden.weights());
    let xla = XlaRuntime::load("artifacts").ok();
    let mut mismatches = 0;
    for gamma in 0..30 {
        let xs: Vec<SpikeTime> = (0..p)
            .map(|_| {
                if rng.gen_bool(0.3) {
                    SpikeTime::NONE
                } else {
                    SpikeTime::at(rng.gen_range(0, 8) as u32)
                }
            })
            .collect();
        let mut u1 = vec![0.0; p * q];
        let mut u2 = vec![0.0; p * q];
        rng.fill_f64(&mut u1);
        rng.fill_f64(&mut u2);
        let got = gate.run_gamma(&xs, &u1, &u2);
        let want = golden.step_with_uniforms(&xs, &u1, &u2);
        if got != want.output || gate.weights() != golden.weights() {
            mismatches += 1;
            eprintln!("gamma {gamma}: gate-level vs golden mismatch");
        }
    }
    println!(
        "selftest: golden vs gate-level over 30 gammas: {} mismatches",
        mismatches
    );
    if let Some(rt) = xla {
        println!("XLA runtime OK ({} artifacts)", rt.artifact_names().len());
    } else {
        println!("XLA artifacts not built (run `make artifacts`)");
    }
    anyhow::ensure!(mismatches == 0, "selftest failed");
    println!("selftest OK");
    Ok(())
}
