//! Lightweight metrics: counters, gauges and latency histograms for the
//! streaming coordinator (offline replacement for a metrics crate).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotonic counter (thread-safe).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket latency histogram (µs buckets, powers of two).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>, // bucket k: [2^k, 2^{k+1}) µs
    count: AtomicU64,
    total_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..24).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            total_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Record one latency observation.
    pub fn observe(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let k = (64 - us.max(1).leading_zeros() as u64 - 1).min(23) as usize;
        self.buckets[k].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in µs (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.total_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Maximum observed latency in µs.
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (k, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (k + 1);
            }
        }
        self.max_us()
    }
}

/// Serving-resilience metrics bundle: one [`Counter`] per event the
/// admission/supervision layer can take on a request, exported by
/// `Server::counters` and folded into `BENCH_serve.json` /
/// `BENCH_chaos.json`.
#[derive(Debug, Default)]
pub struct ServeCounters {
    /// Requests accepted into the queue.
    pub submitted: Counter,
    /// Requests rejected at admission (queue full or forced shed).
    pub shed: Counter,
    /// Requests whose deadline had passed at dequeue (no batch slot burnt).
    pub expired_dequeue: Counter,
    /// Requests whose deadline passed while their batch was in flight.
    pub expired_reply: Counter,
    /// Batches that panicked under `catch_unwind` (riders got `!internal`).
    pub batch_panics: Counter,
    /// Workers respawned by the supervisor after a panic.
    pub worker_respawns: Counter,
    /// Requests pulled out of the queue into a batch.
    pub dequeued: Counter,
    /// Replies actually sent (every submitted request gets exactly one).
    pub replies: Counter,
}

impl ServeCounters {
    /// One-line snapshot for logs / the bench summary footer.
    pub fn summary(&self) -> String {
        format!(
            "submitted {} shed {} expired {}+{} panics {} respawns {} replies {}",
            self.submitted.get(),
            self.shed.get(),
            self.expired_dequeue.get(),
            self.expired_reply.get(),
            self.batch_panics.get(),
            self.worker_respawns.get(),
            self.replies.get(),
        )
    }
}

/// Coordinator metrics bundle.
#[derive(Debug, Default)]
pub struct StreamMetrics {
    /// Items the producer pushed into the channel.
    pub enqueued: Counter,
    /// Items the consumer finished stepping.
    pub processed: Counter,
    /// Items dropped (reserved; the bounded channel blocks instead).
    pub dropped: Counter,
    /// Producer stalls caused by a full channel (backpressure events).
    pub backpressure_stalls: Counter,
    /// Per-item engine step latency.
    pub step_latency: LatencyHistogram,
}

impl StreamMetrics {
    /// One-line run summary (throughput, stalls, latency profile).
    pub fn summary(&self, wall: Duration) -> String {
        let proc = self.processed.get();
        let thr = proc as f64 / wall.as_secs_f64().max(1e-9);
        format!(
            "processed {} ({:.0}/s) | enqueued {} stalls {} | step mean {:.1} µs p99 ≤ {} µs max {} µs",
            proc,
            thr,
            self.enqueued.get(),
            self.backpressure_stalls.get(),
            self.step_latency.mean_us(),
            self.step_latency.quantile_us(0.99),
            self.step_latency.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_tracks_quantiles() {
        let h = LatencyHistogram::default();
        for us in [1u64, 2, 4, 8, 100, 1000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert!(h.mean_us() > 100.0);
        assert!(h.quantile_us(0.5) <= 16);
        assert!(h.quantile_us(1.0) >= 1000);
        assert_eq!(h.max_us(), 1000);
    }

    #[test]
    fn summary_renders() {
        let m = StreamMetrics::default();
        m.processed.add(10);
        let s = m.summary(Duration::from_secs(1));
        assert!(s.contains("processed 10"));
    }

    #[test]
    fn serve_counters_summary_renders_every_field() {
        let c = ServeCounters::default();
        c.submitted.add(9);
        c.shed.inc();
        c.batch_panics.inc();
        c.worker_respawns.inc();
        c.replies.add(9);
        let s = c.summary();
        assert!(s.contains("submitted 9"), "{s}");
        assert!(s.contains("shed 1"), "{s}");
        assert!(s.contains("panics 1"), "{s}");
        assert!(s.contains("respawns 1"), "{s}");
        assert!(s.contains("replies 9"), "{s}");
    }
}
