//! Procedural 16×16 digit corpus (MNIST stand-in for the offline build).
//!
//! Each digit 0–9 is rendered from a stroke skeleton on a 16×16 grid, then
//! perturbed per sample: sub-pixel translation, rotation, stroke-width
//! jitter and pixel noise. The corpus is linearly separable enough to
//! expose the paper's error-rate ordering (deeper TNNs → lower error) while
//! remaining honest about what it is.

use crate::util::Rng64;

/// Image side (16×16 pixels).
pub const SIDE: usize = 16;

/// Stroke skeletons per digit on a unit square: polylines of (x, y).
fn skeleton(digit: usize) -> Vec<Vec<(f64, f64)>> {
    let seg = |pts: &[(f64, f64)]| pts.to_vec();
    match digit {
        0 => vec![seg(&[
            (0.5, 0.1),
            (0.8, 0.3),
            (0.8, 0.7),
            (0.5, 0.9),
            (0.2, 0.7),
            (0.2, 0.3),
            (0.5, 0.1),
        ])],
        1 => vec![seg(&[(0.35, 0.25), (0.55, 0.1), (0.55, 0.9)])],
        2 => vec![seg(&[
            (0.2, 0.25),
            (0.5, 0.1),
            (0.8, 0.3),
            (0.3, 0.65),
            (0.2, 0.9),
            (0.8, 0.9),
        ])],
        3 => vec![seg(&[
            (0.2, 0.15),
            (0.7, 0.15),
            (0.45, 0.45),
            (0.75, 0.7),
            (0.5, 0.9),
            (0.2, 0.8),
        ])],
        4 => vec![
            seg(&[(0.65, 0.9), (0.65, 0.1), (0.2, 0.6), (0.85, 0.6)]),
        ],
        5 => vec![seg(&[
            (0.75, 0.1),
            (0.25, 0.1),
            (0.25, 0.45),
            (0.65, 0.45),
            (0.8, 0.7),
            (0.55, 0.9),
            (0.2, 0.85),
        ])],
        6 => vec![seg(&[
            (0.7, 0.1),
            (0.35, 0.4),
            (0.25, 0.7),
            (0.5, 0.9),
            (0.75, 0.7),
            (0.5, 0.55),
            (0.3, 0.65),
        ])],
        7 => vec![seg(&[(0.2, 0.1), (0.8, 0.1), (0.45, 0.9)])],
        8 => vec![
            seg(&[
                (0.5, 0.1),
                (0.75, 0.28),
                (0.5, 0.48),
                (0.25, 0.28),
                (0.5, 0.1),
            ]),
            seg(&[
                (0.5, 0.48),
                (0.8, 0.7),
                (0.5, 0.9),
                (0.2, 0.7),
                (0.5, 0.48),
            ]),
        ],
        9 => vec![seg(&[
            (0.7, 0.35),
            (0.45, 0.45),
            (0.3, 0.25),
            (0.55, 0.1),
            (0.7, 0.35),
            (0.65, 0.9),
        ])],
        _ => panic!("digit out of range"),
    }
}

/// Render one digit sample with jitter. Returns SIDE×SIDE pixels in [0,1].
pub fn render_digit(digit: usize, rng: &mut Rng64) -> Vec<f64> {
    let strokes = skeleton(digit);
    let mut img = vec![0.0f64; SIDE * SIDE];
    let (dx, dy) = (rng.gen_f64() * 0.12 - 0.06, rng.gen_f64() * 0.12 - 0.06);
    let rot = rng.gen_f64() * 0.24 - 0.12; // radians
    let width = 0.05 + rng.gen_f64() * 0.03;
    let (sinr, cosr) = rot.sin_cos();
    let tf = |x: f64, y: f64| {
        let (cx, cy) = (x - 0.5, y - 0.5);
        (
            cx * cosr - cy * sinr + 0.5 + dx,
            cx * sinr + cy * cosr + 0.5 + dy,
        )
    };
    for stroke in &strokes {
        for w in stroke.windows(2) {
            let (x0, y0) = tf(w[0].0, w[0].1);
            let (x1, y1) = tf(w[1].0, w[1].1);
            let steps = 40;
            for s in 0..=steps {
                let t = s as f64 / steps as f64;
                let px = x0 + (x1 - x0) * t;
                let py = y0 + (y1 - y0) * t;
                splat(&mut img, px, py, width);
            }
        }
    }
    // pixel noise
    for v in img.iter_mut() {
        *v = (*v + 0.05 * rng.gen_f64()).min(1.0);
    }
    img
}

fn splat(img: &mut [f64], px: f64, py: f64, width: f64) {
    let r = (width * SIDE as f64).ceil() as i64;
    let cx = px * SIDE as f64;
    let cy = py * SIDE as f64;
    let ix = cx as i64;
    let iy = cy as i64;
    for gy in (iy - r)..=(iy + r) {
        for gx in (ix - r)..=(ix + r) {
            if gx < 0 || gy < 0 || gx >= SIDE as i64 || gy >= SIDE as i64 {
                continue;
            }
            let d2 = ((gx as f64 + 0.5 - cx).powi(2) + (gy as f64 + 0.5 - cy).powi(2)).sqrt()
                / (width * SIDE as f64);
            if d2 < 1.5 {
                let k = (gy as usize) * SIDE + gx as usize;
                let val = (1.5 - d2) / 1.5;
                if val > img[k] {
                    img[k] = val;
                }
            }
        }
    }
}

/// A labelled corpus of rendered digits.
#[derive(Clone, Debug)]
pub struct DigitCorpus {
    /// Rendered images, row-major SIDE×SIDE intensities in [0,1].
    pub images: Vec<Vec<f64>>,
    /// Digit label per image.
    pub labels: Vec<usize>,
}

impl DigitCorpus {
    /// `per_class` samples per digit, shuffled.
    pub fn generate(per_class: usize, seed: u64) -> Self {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xD161_7000);
        let mut images = Vec::with_capacity(per_class * 10);
        let mut labels = Vec::with_capacity(per_class * 10);
        for d in 0..10 {
            for _ in 0..per_class {
                images.push(render_digit(d, &mut rng));
                labels.push(d);
            }
        }
        let mut idx: Vec<usize> = (0..images.len()).collect();
        rng.shuffle(&mut idx);
        DigitCorpus {
            images: idx.iter().map(|&i| images[i].clone()).collect(),
            labels: idx.iter().map(|&i| labels[i]).collect(),
        }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.images.len()
    }

    /// Is the corpus empty?
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Encode every image into a sample-major volley batch (on/off-center
    /// channels, `2·SIDE²` lines per volley) — the input form of the
    /// batched training pipeline (`TnnNetwork::step_epoch` /
    /// `infer_batch`). Sample order matches `images`/`labels`.
    pub fn encode_batch(&self, t_max: u32) -> crate::tnn::batch::VolleyBatch {
        let mut batch = crate::tnn::batch::VolleyBatch::new(SIDE * SIDE * 2);
        for img in &self.images {
            batch.push(&crate::tnn::encode::encode_image_onoff(img, t_max));
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_digits_in_range() {
        let mut rng = Rng64::seed_from_u64(1);
        for d in 0..10 {
            let img = render_digit(d, &mut rng);
            assert_eq!(img.len(), 256);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let ink: f64 = img.iter().sum();
            assert!(ink > 5.0, "digit {d} has visible ink ({ink})");
        }
    }

    #[test]
    fn encode_batch_matches_per_image_encoding() {
        use crate::tnn::encode::encode_image_onoff;
        let corpus = DigitCorpus::generate(2, 5);
        let batch = corpus.encode_batch(8);
        assert_eq!(batch.len(), corpus.len());
        assert_eq!(batch.lines(), SIDE * SIDE * 2);
        for (s, img) in corpus.images.iter().enumerate() {
            assert_eq!(batch.volley(s), &encode_image_onoff(img, 8)[..]);
        }
    }

    #[test]
    fn corpus_is_deterministic_and_balanced() {
        let a = DigitCorpus::generate(4, 9);
        let b = DigitCorpus::generate(4, 9);
        assert_eq!(a.images, b.images);
        assert_eq!(a.len(), 40);
        for d in 0..10 {
            assert_eq!(a.labels.iter().filter(|&&l| l == d).count(), 4);
        }
    }

    #[test]
    fn same_digit_more_similar_than_different() {
        // Average intra-class L2 distance must undercut inter-class.
        let mut rng = Rng64::seed_from_u64(3);
        let a1 = render_digit(1, &mut rng);
        let a2 = render_digit(1, &mut rng);
        let b = render_digit(8, &mut rng);
        let d = |x: &[f64], y: &[f64]| -> f64 {
            x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        assert!(d(&a1, &a2) < d(&a1, &b));
    }
}
