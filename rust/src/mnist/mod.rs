//! Digit-recognition workload (the paper's Section IV-B).
//!
//! MNIST itself is not bundled in this offline environment, so
//! [`digits`] provides a procedural 16×16 digit corpus (stroke-rendered
//! glyphs with elastic jitter, rotation and noise) that exercises the same
//! pipeline: on/off-center encoding → multi-layer column TNN with STDP →
//! vote-based readout. [`networks`] defines the 2/3/4-layer prototype
//! geometries whose synapse counts match the paper's Table III scaling
//! inputs (389K / 1,310K / 3,096K) plus downscaled trainable variants.

pub mod digits;
pub mod networks;

pub use digits::{render_digit, DigitCorpus};
pub use networks::{mnist_layer_geometries, trainable_network, MnistDesign};
