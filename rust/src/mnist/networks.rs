//! MNIST TNN prototype geometries (the paper's Table III designs) and
//! trainable downscaled variants.
//!
//! The paper's 2/3/4-layer prototypes come from Smith [9] (ECVT / ECCVT)
//! with total synapse counts 389K / 1,310K / 3,096K; its Table III derives
//! PPA by synaptic-count scaling with every layer treated as a "C" column
//! layer. [`mnist_layer_geometries`] reproduces exactly those scaling
//! inputs. [`trainable_network`] builds runnable (16×16-input) TNNs of 2–4
//! layers for the end-to-end error-rate experiment.

use crate::ppa::scale::LayerGeometry;
use crate::tnn::{ColumnLayer, ReceptiveField, TnnNetwork, TnnParams};

/// One Table III row: name, layer geometries, paper's reported error rate.
#[derive(Clone, Debug)]
pub struct MnistDesign {
    /// Prototype name (1/3/4-layer).
    pub name: &'static str,
    /// Per-layer geometries used for synaptic-count scaling.
    pub layers: Vec<LayerGeometry>,
    /// MNIST error rate the paper reports, %.
    pub paper_error_pct: f64,
    /// Total synapse count the paper reports.
    pub paper_synapses: usize,
}

/// The three Table III designs. Layer geometries are chosen to land the
/// paper's exact total synapse counts with MNIST-plausible shapes
/// (28×28 on/off input → patchy column layers).
pub fn mnist_layer_geometries() -> Vec<MnistDesign> {
    vec![
        MnistDesign {
            name: "2-Layer (ECVT)",
            // 225,792 + 163,584 = 389,376 ≈ paper's 389K (0.1 % off).
            layers: vec![
                LayerGeometry { p: 98, q: 16, columns: 144 }, // 225,792
                LayerGeometry { p: 1136, q: 16, columns: 9 }, // 163,584
            ],
            paper_error_pct: 7.0,
            paper_synapses: 389_000,
        },
        MnistDesign {
            name: "3-Layer (ECCVT)",
            layers: vec![
                LayerGeometry { p: 98, q: 16, columns: 144 },  // 225,792
                LayerGeometry { p: 256, q: 24, columns: 100 }, // 614,400
                LayerGeometry { p: 1175, q: 16, columns: 25 }, // 470,000
            ],
            paper_error_pct: 3.0,
            paper_synapses: 1_310_000,
        },
        MnistDesign {
            name: "4-Layer (ECCVT)",
            layers: vec![
                LayerGeometry { p: 98, q: 16, columns: 144 },  // 225,792
                LayerGeometry { p: 256, q: 24, columns: 100 }, // 614,400
                LayerGeometry { p: 384, q: 32, columns: 64 },  // 786,432
                LayerGeometry { p: 1836, q: 32, columns: 25 }, // 1,468,800
            ],
            paper_error_pct: 1.0,
            paper_synapses: 3_096_000,
        },
    ]
}

/// Build a runnable n-layer TNN (n ∈ 2..=4) over the 16×16 on/off-encoded
/// digit corpus (512 input lines). Returns the network; classify with a
/// [`crate::tnn::VoteClassifier`] over its output volley.
pub fn trainable_network(n_layers: usize, params: TnnParams) -> TnnNetwork {
    assert!((2..=4).contains(&n_layers));
    let side = super::digits::SIDE;
    let channels = 2; // on/off
    let input_len = side * side * channels;
    let mut layers = Vec::new();
    // L1: 4×4 patches, stride 4 → 16 columns over 32-line patches.
    let l1 = ColumnLayer::new(
        input_len,
        ReceptiveField::Patches2d {
            width: side,
            height: side,
            channels,
            size: 4,
            stride: 4,
        },
        12,
        None,
        params.clone(),
    );
    let mut prev = l1.output_len();
    layers.push(l1);
    if n_layers >= 3 {
        let l = ColumnLayer::new(
            prev,
            ReceptiveField::Patches1d {
                size: prev / 4,
                stride: prev / 4,
            },
            16,
            None,
            params.clone(),
        );
        prev = l.output_len();
        layers.push(l);
    }
    if n_layers >= 4 {
        let l = ColumnLayer::new(
            prev,
            ReceptiveField::Patches1d {
                size: prev / 2,
                stride: prev / 2,
            },
            20,
            None,
            params.clone(),
        );
        prev = l.output_len();
        layers.push(l);
    }
    // Final layer: one full column with enough neurons to cover 10 classes
    // redundantly.
    let lf = ColumnLayer::new(prev, ReceptiveField::Full, 40, None, params);
    layers.push(lf);
    TnnNetwork::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_synapse_counts_match_paper_within_tolerance() {
        for d in mnist_layer_geometries() {
            let total: usize = d.layers.iter().map(|l| l.synapses()).sum();
            let err = (total as f64 - d.paper_synapses as f64).abs()
                / d.paper_synapses as f64;
            assert!(
                err < 0.01,
                "{}: {} vs paper {} ({:.2}% off)",
                d.name,
                total,
                d.paper_synapses,
                err * 100.0
            );
        }
    }

    #[test]
    fn error_rates_decrease_with_depth() {
        let designs = mnist_layer_geometries();
        assert!(designs[0].paper_error_pct > designs[1].paper_error_pct);
        assert!(designs[1].paper_error_pct > designs[2].paper_error_pct);
    }

    #[test]
    fn trainable_networks_build_for_all_depths() {
        for n in 2..=4 {
            let net = trainable_network(n, TnnParams::default());
            assert_eq!(net.layers().len(), n);
            assert_eq!(net.input_len(), 512);
            assert_eq!(net.output_len(), 40);
            assert!(net.synapse_count() > 1000);
        }
    }
}
