//! Probabilistic switching-activity propagation over a mapped netlist.
//!
//! Each net carries a static signal probability `P(high)` and a transition
//! density `α` (toggles per aclk cycle). Primary inputs get workload-shaped
//! priors (TNN inputs are sparse pulse/edge signals); gates propagate both
//! quantities under the independence approximation, the standard approach
//! when a full testbench is unavailable (and cross-checked against gate-sim
//! toggle counts in the tests).

use crate::cells::names;
use crate::gates::netlist::Netlist;
use crate::gates::{collect_toggles, SimBackend};
use crate::synth::map::MappedNetlist;

/// Per-net (P, α).
#[derive(Clone, Debug)]
pub struct Activity {
    /// Per-net static signal probability P(high).
    pub prob: Vec<f64>,
    /// Per-net transition density (toggles per aclk cycle).
    pub alpha: Vec<f64>,
}

/// Workload priors.
#[derive(Clone, Copy, Debug)]
pub struct ActivityPriors {
    /// Signal probability of primary inputs.
    pub input_prob: f64,
    /// Transition density of primary inputs (toggles/cycle).
    pub input_alpha: f64,
    /// Signal probability for hard-macro output pins.
    pub macro_prob: f64,
    /// Transition density for hard-macro output pins.
    pub macro_alpha: f64,
}

impl Default for ActivityPriors {
    fn default() -> Self {
        // TNN workload: input lines are sparse pulses (one spike per gamma
        // of 16 cycles ⇒ ~2 toggles / 16 cycles); macro outputs are RNL
        // pulses and edges of similar density.
        ActivityPriors {
            input_prob: 0.15,
            input_alpha: 0.125,
            macro_prob: 0.25,
            macro_alpha: 0.15,
        }
    }
}

/// Propagate activity through the mapped netlist. Cells must be in a
/// topologically consistent order for combinational propagation; mapped
/// netlists inherit builder order, which satisfies this for cell inputs
/// created before outputs (sequential cells break remaining cycles).
pub fn propagate(mapped: &MappedNetlist, priors: ActivityPriors) -> Activity {
    let n = mapped.net_space;
    let mut prob = vec![0.5; n];
    let mut alpha = vec![0.0; n];

    for (_, net) in &mapped.inputs {
        prob[*net as usize] = priors.input_prob;
        alpha[*net as usize] = priors.input_alpha;
    }
    for (_, _, outs) in &mapped.macros {
        for &o in outs {
            prob[o as usize] = priors.macro_prob;
            alpha[o as usize] = priors.macro_alpha;
        }
    }
    // Sequential cell outputs: filtered data activity.
    for c in &mapped.cells {
        if c.sequential {
            prob[c.out as usize] = 0.3;
            alpha[c.out as usize] = 0.1;
        }
    }

    // Two sweeps are enough in practice for these feed-forward datapaths
    // (feedback passes through sequential cells whose values are seeded
    // above); a second sweep refines DFF outputs from their D activity.
    for sweep in 0..2 {
        for c in &mapped.cells {
            let o = c.out as usize;
            if c.sequential {
                if sweep == 1 {
                    // q follows d, bandwidth-limited to one toggle/cycle.
                    let d = c.ins[0] as usize;
                    prob[o] = prob[d];
                    alpha[o] = alpha[d].min(2.0 * prob[d] * (1.0 - prob[d])).min(1.0);
                }
                continue;
            }
            let (p, a) = eval_cell(c.cell, &c.ins, &prob, &alpha);
            prob[o] = p;
            alpha[o] = a.min(2.0); // physical bound: ~2 transitions/cycle max
        }
    }
    Activity { prob, alpha }
}

/// Switching activity *measured* by gate-level simulation, as an
/// alternative to the probabilistic propagation above: per-net toggle
/// counts from [`collect_toggles`] divided by simulated cycles. Because
/// technology mapping preserves the generic `NetId` namespace, the α
/// vector indexes directly into a `MappedNetlist` produced by
/// `tech_map` on the **same** netlist (toggle collection must run on the
/// pre-optimization netlist for the ids to line up).
#[derive(Clone, Debug)]
pub struct MeasuredActivity {
    /// Per-net toggles per cycle.
    pub alpha: Vec<f64>,
    /// Simulated cycles behind the estimate (lane-cycles for the
    /// bit-parallel backend).
    pub cycles: u64,
    /// Simulation backend that produced the measurement.
    pub backend: SimBackend,
}

/// Measure per-net transition density by simulating `cycles` cycles of the
/// standard randomized TNN workload on the selected backend. The
/// bit-parallel backend produces the same statistics ~64× faster (see
/// `benches/sim_throughput.rs`); the compiled backend
/// (`SimBackend::Compiled { words, threads }`) goes further with
/// `words × 64`-lane passes and threaded level execution (see
/// `benches/compiled_sim.rs`), and at `words = 1` reproduces the
/// bit-parallel backend's α vector bit for bit. All stimulus ids are
/// resolved once up front — no backend touches a name map per cycle.
pub fn measure(
    nl: &Netlist,
    cycles: u64,
    seed: u64,
    backend: SimBackend,
) -> Result<MeasuredActivity, String> {
    let report = collect_toggles(nl, cycles, seed, backend)?;
    Ok(MeasuredActivity {
        alpha: report.alpha(),
        cycles: report.cycles,
        backend: report.backend,
    })
}

fn eval_cell(cell: &str, ins: &[u32], prob: &[f64], alpha: &[f64]) -> (f64, f64) {
    let p = |k: usize| prob[ins[k] as usize];
    let a = |k: usize| alpha[ins[k] as usize];
    match cell {
        c if c == names::INV => (1.0 - p(0), a(0)),
        c if c == names::BUF => (p(0), a(0)),
        c if c == names::AND2 => {
            let po = p(0) * p(1);
            (po, a(0) * p(1) + a(1) * p(0))
        }
        c if c == names::NAND2 => {
            let po = 1.0 - p(0) * p(1);
            (po, a(0) * p(1) + a(1) * p(0))
        }
        c if c == names::OR2 => {
            let po = 1.0 - (1.0 - p(0)) * (1.0 - p(1));
            (po, a(0) * (1.0 - p(1)) + a(1) * (1.0 - p(0)))
        }
        c if c == names::NOR2 => {
            let po = (1.0 - p(0)) * (1.0 - p(1));
            (po, a(0) * (1.0 - p(1)) + a(1) * (1.0 - p(0)))
        }
        c if c == names::XOR2 || c == names::XNOR2 => {
            let px = p(0) + p(1) - 2.0 * p(0) * p(1);
            let po = if c == names::XOR2 { px } else { 1.0 - px };
            (po, a(0) + a(1))
        }
        c if c == names::AOI21 => {
            // !(i0·i1 + i2) ⇒ P = (1 − p0·p1)(1 − p2)
            let pab = p(0) * p(1);
            let pout = (1.0 - pab) * (1.0 - p(2));
            (
                pout,
                (a(0) * p(1) + a(1) * p(0)) * (1.0 - p(2)) + a(2) * (1.0 - pab),
            )
        }
        c if c == names::OAI21 => {
            // !((i0+i1)·i2)
            let pab = 1.0 - (1.0 - p(0)) * (1.0 - p(1));
            let pout = 1.0 - pab * p(2);
            (
                pout,
                (a(0) * (1.0 - p(1)) + a(1) * (1.0 - p(0))) * p(2) + a(2) * pab,
            )
        }
        c if c == names::MUX2 => {
            // ins = [sel, a, b]; out = sel ? b : a
            let ps = p(0);
            let po = (1.0 - ps) * p(1) + ps * p(2);
            (
                po,
                a(0) * (p(1) - p(2)).abs() + a(1) * (1.0 - ps) + a(2) * ps,
            )
        }
        c if c == names::TIE0 => (0.0, 0.0),
        c if c == names::TIE1 => (1.0, 0.0),
        other => panic!("activity model: unknown cell {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells;
    use crate::gates::netlist::NetBuilder;
    use crate::synth::map::tech_map;

    #[test]
    fn probabilities_stay_in_unit_interval() {
        let mut b = NetBuilder::new("t");
        let i: Vec<_> = (0..4).map(|k| b.input(&format!("i{k}"))).collect();
        let x = b.and(i[0], i[1]);
        let y = b.or(x, i[2]);
        let z = b.xor(y, i[3]);
        let nz = b.not(z);
        let q = b.dff(nz, None, false);
        b.output("q", q);
        let mapped = tech_map(&b.finish(), &cells::asap7());
        let act = propagate(&mapped, ActivityPriors::default());
        for (&p, &a) in act.prob.iter().zip(&act.alpha) {
            assert!((0.0..=1.0).contains(&p), "p={p}");
            assert!((0.0..=2.0).contains(&a), "a={a}");
        }
    }

    #[test]
    fn and_gate_attenuates_activity_vs_xor() {
        // XOR propagates every input toggle; AND gates it by the other
        // input's probability — with sparse inputs XOR output must toggle
        // strictly more.
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.and(a, c);
        let y = b.xor(a, c);
        b.output("x", x);
        b.output("y", y);
        let mapped = tech_map(&b.finish(), &cells::asap7());
        let act = propagate(&mapped, ActivityPriors::default());
        let xa = act.alpha[mapped.outputs[0].1 as usize];
        let ya = act.alpha[mapped.outputs[1].1 as usize];
        assert!(ya > xa, "xor α={ya} vs and α={xa}");
    }

    #[test]
    fn measured_activity_tracks_propagated_ordering() {
        // Under sparse random stimulus the measured α must reproduce the
        // structural ordering the probabilistic model predicts: XOR
        // propagates strictly more toggles than AND of the same inputs.
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.and(a, c);
        let y = b.xor(a, c);
        b.output("x", x);
        b.output("y", y);
        let nl = b.finish();
        let meas = measure(&nl, 8192, 5, SimBackend::BitParallel64).unwrap();
        assert_eq!(meas.backend, SimBackend::BitParallel64);
        assert_eq!(meas.cycles, 8192);
        assert!(
            meas.alpha[y as usize] > meas.alpha[x as usize],
            "xor α {} vs and α {}",
            meas.alpha[y as usize],
            meas.alpha[x as usize]
        );
        // Both backends measure the same process.
        let meas_s = measure(&nl, 8192, 5, SimBackend::Scalar).unwrap();
        for id in [a, c, x, y] {
            assert!(
                (meas.alpha[id as usize] - meas_s.alpha[id as usize]).abs() < 0.05,
                "net {id}: word {} vs scalar {}",
                meas.alpha[id as usize],
                meas_s.alpha[id as usize]
            );
        }
    }

    #[test]
    fn compiled_measure_matches_word_backend_exactly_at_w1() {
        // words = 1 shares the interpreter's stimulus stream, so the α
        // vectors must be identical — not merely statistically close.
        use crate::gates::column_design::{build_column, BrvSource};
        let d = build_column(5, 2, 6, BrvSource::Lfsr);
        let w = measure(&d.netlist, 4096, 5, SimBackend::BitParallel64).unwrap();
        let c = measure(
            &d.netlist,
            4096,
            5,
            SimBackend::Compiled { words: 1, threads: 2 },
        )
        .unwrap();
        assert_eq!(c.backend.name(), "compiled");
        assert_eq!(c.cycles, w.cycles);
        assert_eq!(c.alpha, w.alpha);
        // Multi-word blocks sample more lanes of the same process.
        let c4 = measure(
            &d.netlist,
            4096,
            5,
            SimBackend::Compiled { words: 4, threads: 1 },
        )
        .unwrap();
        assert_eq!(c4.cycles, 4096);
        let mean = |m: &MeasuredActivity| m.alpha.iter().sum::<f64>() / m.alpha.len() as f64;
        assert!((mean(&c4) - mean(&w)).abs() < 0.05);
    }

    #[test]
    fn dff_output_is_bandwidth_limited() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.xor(a, c);
        let x2 = b.xor(x, a);
        let q = b.dff(x2, None, false);
        b.output("q", q);
        let mapped = tech_map(&b.finish(), &cells::asap7());
        let mut priors = ActivityPriors::default();
        priors.input_alpha = 1.5; // absurdly busy inputs
        let act = propagate(&mapped, priors);
        let q_net = mapped.outputs[0].1 as usize;
        assert!(act.alpha[q_net] <= 1.0, "DFF q α={}", act.alpha[q_net]);
    }
}
