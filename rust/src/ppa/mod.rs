//! Post-synthesis Power / Performance / Area analysis (the substitute for
//! Genus reports + Cadence Joules).
//!
//! * **Area** — Σ cell areas (standard cells + hard macros) plus a net-area
//!   estimate proportional to total pin count (the paper's "total cell and
//!   net area").
//! * **Power** — leakage (Σ per-cell) + activity-based dynamic power:
//!   signal/transition probabilities are propagated through the mapped
//!   netlist ([`activity`]), per-toggle switching energies come from the
//!   library, hard macros contribute characterized per-gamma-cycle internal
//!   energy, and the clock tree adds per-sequential-cell energy. Evaluated
//!   at the paper's 100 kHz `aclk`.
//! * **Timing** — static timing analysis over the mapped netlist with the
//!   linear delay model `d = intrinsic + k·C_load`; critical path =
//!   worst register-to-register / input-to-register / register-to-output
//!   path including setup. **Computation time** (the paper's performance
//!   metric, "derived from the critical path delay and the gamma period as
//!   in [6]") = critical path × unit cycles per gamma, summed over layers
//!   for multi-layer networks.
//! * **EDP** — energy × delay with energy = power × computation time.

pub mod activity;
pub mod report;
pub mod scale;
pub mod timing;

pub use report::{analyze, PpaReport};
pub use scale::{scale_network, NetworkPpa};

/// Operating frequency of the unit clock (`aclk`) — the paper evaluates at
/// 100 kHz for real-time sensory processing.
pub const ACLK_HZ: f64 = 100_000.0;

/// Net-area per pin (µm²) — routing overhead proxy calibrated so the
/// largest UCR column lands in the paper's reported absolute-area regime.
pub const NET_AREA_PER_PIN_UM2: f64 = 0.045;

/// Clock-tree energy per sequential element per aclk cycle (fJ).
pub const CLK_ENERGY_PER_SEQ_FJ: f64 = 0.5;
