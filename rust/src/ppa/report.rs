//! Top-level PPA report assembly.

use super::activity::{propagate, ActivityPriors};
use super::timing::sta;
use super::{ACLK_HZ, CLK_ENERGY_PER_SEQ_FJ, NET_AREA_PER_PIN_UM2};
use crate::cells::CellLibrary;
use crate::synth::map::MappedNetlist;

/// Post-synthesis PPA of one design (single column or flat module).
#[derive(Clone, Debug)]
pub struct PpaReport {
    /// Design (netlist) name.
    pub design: String,
    /// Library the design was analyzed under.
    pub library: &'static str,
    // --- area ---
    /// Cell placement area, µm².
    pub cell_area_um2: f64,
    /// Routing/net area estimate (per-pin model), µm².
    pub net_area_um2: f64,
    /// Total area (cell + net), µm².
    pub area_um2: f64,
    // --- power (at `aclk_hz`) ---
    /// Static leakage, nW.
    pub leakage_nw: f64,
    /// Activity-dependent dynamic power, nW.
    pub dynamic_nw: f64,
    /// Total power (leakage + dynamic), nW.
    pub power_nw: f64,
    // --- timing ---
    /// Longest register-to-register combinational path, ps.
    pub critical_path_ps: f64,
    /// Computation time: critical path × unit cycles per gamma ([6]'s
    /// performance metric; the paper's "Comp. Time").
    pub comp_time_ns: f64,
    // --- derived ---
    /// Energy per processed input (power × comp-time), in fJ.
    pub energy_fj: f64,
    /// Energy-delay product, fJ·ns.
    pub edp_fj_ns: f64,
    // --- inventory ---
    /// Mapped standard-cell count.
    pub std_cells: usize,
    /// Preserved hard-macro count.
    pub macro_cells: usize,
    /// Sequential cell count (drives clock-tree energy).
    pub seq_cells: usize,
}

/// Analyze a mapped netlist under a library at the standard operating point.
pub fn analyze(mapped: &MappedNetlist, lib: &CellLibrary, gamma_cycles: u32) -> PpaReport {
    analyze_at(mapped, lib, gamma_cycles, ACLK_HZ, ActivityPriors::default())
}

/// Analyze with a per-net transition-density vector measured by gate-level
/// simulation (see [`crate::ppa::activity::measure`] and
/// [`crate::gates::SimBackend`]) instead of the probabilistic propagation.
/// `alpha` must cover the mapped netlist's net namespace — i.e. toggle
/// collection ran on the same (pre-optimization) netlist that was mapped.
pub fn analyze_with_alpha(
    mapped: &MappedNetlist,
    lib: &CellLibrary,
    gamma_cycles: u32,
    alpha: &[f64],
) -> PpaReport {
    assert!(
        alpha.len() >= mapped.net_space,
        "alpha vector covers {} nets, mapped netlist has {}",
        alpha.len(),
        mapped.net_space
    );
    analyze_core(mapped, lib, gamma_cycles, ACLK_HZ, alpha)
}

/// [`analyze_with_alpha`] for a netlist the synthesis optimizer
/// renumbered: `alpha` is indexed by the *optimizer input* netlist's ids
/// (the space toggle collection ran on) and is carried onto the mapped
/// netlist through the optimizer's [`NetRemap`]
/// ([`crate::synth::flow::SynthOutcome::remap`]). Surviving nets keep
/// their measured activity; nets the optimizer aliased away contributed
/// their switching through their canonical survivor, so dropping their
/// entries double-counts nothing.
pub fn analyze_with_alpha_remapped(
    mapped: &MappedNetlist,
    lib: &CellLibrary,
    gamma_cycles: u32,
    alpha: &[f64],
    remap: &crate::gates::opt::NetRemap,
) -> PpaReport {
    let translated = remap.translate_per_net(alpha);
    analyze_with_alpha(mapped, lib, gamma_cycles, &translated)
}

/// Full-control variant.
pub fn analyze_at(
    mapped: &MappedNetlist,
    lib: &CellLibrary,
    gamma_cycles: u32,
    aclk_hz: f64,
    priors: ActivityPriors,
) -> PpaReport {
    let act = propagate(mapped, priors);
    analyze_core(mapped, lib, gamma_cycles, aclk_hz, &act.alpha)
}

fn analyze_core(
    mapped: &MappedNetlist,
    lib: &CellLibrary,
    gamma_cycles: u32,
    aclk_hz: f64,
    alpha: &[f64],
) -> PpaReport {
    // ---- area ----
    let mut cell_area = 0.0;
    let mut leak = 0.0;
    let mut seq_cells = 0usize;
    for c in &mapped.cells {
        let m = lib.get(c.cell);
        cell_area += m.area_um2;
        leak += m.leakage_nw;
        if m.sequential {
            seq_cells += 1;
        }
    }
    for (kind, _, _) in &mapped.macros {
        let m = lib
            .macro_cell(*kind)
            .unwrap_or_else(|| panic!("library {} lacks macro {:?}", lib.name, kind));
        cell_area += m.area_um2;
        leak += m.leakage_nw;
        if m.sequential {
            seq_cells += 1;
        }
    }
    let net_area = NET_AREA_PER_PIN_UM2 * mapped.pin_count() as f64;
    let area = cell_area + net_area;

    // ---- dynamic power ----
    let mut sw_energy_fj_cycle = 0.0; // per aclk cycle
    for c in &mapped.cells {
        let m = lib.get(c.cell);
        sw_energy_fj_cycle += m.energy_fj * alpha[c.out as usize];
    }
    for (kind, _, _) in &mapped.macros {
        // Characterized per-cycle internal energy (library `energy_fj`
        // stores fJ/cycle for macro cells).
        let m = lib.macro_cell(*kind).unwrap();
        sw_energy_fj_cycle += m.energy_fj;
    }
    sw_energy_fj_cycle += CLK_ENERGY_PER_SEQ_FJ * seq_cells as f64;
    // fJ/cycle × cycles/s = fW → nW
    let dynamic_nw = sw_energy_fj_cycle * aclk_hz * 1e-6;
    let power_nw = leak + dynamic_nw;

    // ---- timing ----
    let t = sta(mapped, lib);
    let comp_time_ns = t.critical_path_ps * gamma_cycles as f64 / 1000.0;

    // ---- derived ----
    let energy_fj = power_nw * comp_time_ns * 1e-3; // nW·ns = 1e-18 J = aJ; /1e3 → fJ
    let edp = energy_fj * comp_time_ns;

    PpaReport {
        design: mapped.name.clone(),
        library: lib.name,
        cell_area_um2: cell_area,
        net_area_um2: net_area,
        area_um2: area,
        leakage_nw: leak,
        dynamic_nw,
        power_nw,
        critical_path_ps: t.critical_path_ps,
        comp_time_ns,
        energy_fj,
        edp_fj_ns: edp,
        std_cells: mapped.cell_count(),
        macro_cells: mapped.macro_count(),
        seq_cells,
    }
}

impl PpaReport {
    /// Improvement of `self` (TNN7) relative to `base` (ASAP7), as
    /// percentages (positive = TNN7 better), in the paper's reporting
    /// order: (power, delay, area, EDP).
    pub fn improvement_vs(&self, base: &PpaReport) -> (f64, f64, f64, f64) {
        let pct = |new: f64, old: f64| (1.0 - new / old) * 100.0;
        (
            pct(self.power_nw, base.power_nw),
            pct(self.comp_time_ns, base.comp_time_ns),
            pct(self.area_um2, base.area_um2),
            pct(self.edp_fj_ns, base.edp_fj_ns),
        )
    }

    /// One-line summary (library, inventory, area/power/time/EDP).
    pub fn row(&self) -> String {
        format!(
            "{:<18} {:>8} cells {:>6} macros | {:>9.2} µm² | {:>9.3} µW | {:>8.2} ns | EDP {:>10.1}",
            self.library,
            self.std_cells,
            self.macro_cells,
            self.area_um2,
            self.power_nw / 1000.0,
            self.comp_time_ns,
            self.edp_fj_ns,
        )
    }
}

/// Indices of the Pareto-optimal points of a 2-D **minimization** trade-off
/// (e.g. power vs clustering error): a point survives iff no other point is
/// at least as good on both axes and strictly better on one. Duplicate
/// coordinates all survive. The returned indices are sorted by `(x, y)`
/// ascending, so walking them traces the frontier curve left to right —
/// the shape the design-space sweep reports ([`crate::sweep`]).
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<usize> {
    // NaN/Inf points never dominate and never survive — and they must be
    // dropped BEFORE sorting: a comparator that maps incomparable pairs to
    // `Equal` is inconsistent and can scramble the whole order.
    let mut idx: Vec<usize> = (0..points.len())
        .filter(|&i| points[i].0.is_finite() && points[i].1.is_finite())
        .collect();
    // Sort by x then y; ties keep index order (stable sort) so the result
    // is deterministic for duplicated coordinates.
    idx.sort_by(|&a, &b| {
        points[a]
            .partial_cmp(&points[b])
            .expect("finite points are totally ordered")
    });
    let mut front = Vec::new();
    let mut best_y = f64::INFINITY;
    let mut last: Option<(f64, f64)> = None;
    for &i in &idx {
        let (x, y) = points[i];
        if Some((x, y)) == last || y < best_y {
            front.push(i);
            best_y = best_y.min(y);
            last = Some((x, y));
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells;
    use crate::gates::column_design::{build_column, BrvSource};
    use crate::synth::flow::{synthesize, Flow};

    fn column_reports(p: usize, q: usize) -> (PpaReport, PpaReport) {
        let theta = (p as u32 * 7) / 4;
        let d = build_column(p, q, theta, BrvSource::Lfsr);
        let base = synthesize(&d.netlist, Flow::Baseline);
        let t7 = synthesize(&d.netlist, Flow::Tnn7);
        (
            analyze(&base.mapped, &cells::asap7(), 16),
            analyze(&t7.mapped, &cells::tnn7(), 16),
        )
    }

    #[test]
    fn tnn7_beats_baseline_on_all_axes_for_a_column() {
        let (base, t7) = column_reports(16, 4);
        let (dp, dd, da, dedp) = t7.improvement_vs(&base);
        assert!(dp > 0.0, "power improvement {dp:.1}% (base {base:?} t7 {t7:?})");
        assert!(dd > 0.0, "delay improvement {dd:.1}%");
        assert!(da > 0.0, "area improvement {da:.1}%");
        assert!(dedp > 0.0, "EDP improvement {dedp:.1}%");
    }

    #[test]
    fn measured_alpha_analysis_agrees_with_probabilistic() {
        use crate::gates::SimBackend;
        use crate::ppa::activity::measure;
        use crate::synth::map::tech_map;
        // Map the raw (un-optimized) netlist so NetIds line up with the
        // toggle-collection run.
        let d = build_column(6, 2, 6, BrvSource::Lfsr);
        let lib = cells::tnn7();
        let mapped = tech_map(&d.netlist, &lib);
        let meas = measure(&d.netlist, 4096, 9, SimBackend::BitParallel64).unwrap();
        let r_meas = analyze_with_alpha(&mapped, &lib, 16, &meas.alpha);
        let r_prob = analyze(&mapped, &lib, 16);
        assert!(r_meas.dynamic_nw > 0.0);
        let ratio = r_meas.dynamic_nw / r_prob.dynamic_nw;
        assert!(
            ratio > 0.1 && ratio < 10.0,
            "measured/probabilistic dynamic power ratio {ratio:.3}"
        );
        // Only dynamic power depends on the activity source.
        assert_eq!(r_meas.area_um2, r_prob.area_um2);
        assert_eq!(r_meas.leakage_nw, r_prob.leakage_nw);
        assert_eq!(r_meas.critical_path_ps, r_prob.critical_path_ps);
    }

    #[test]
    fn remapped_measured_alpha_feeds_the_optimized_mapping() {
        use crate::gates::SimBackend;
        use crate::ppa::activity::measure;
        // The Tnn7 flow optimizes (and renumbers) the design netlist, so
        // the measured per-net vector only lines up after translation
        // through the flow's remap — the path PR 5 couldn't take.
        let d = build_column(6, 2, 6, BrvSource::Lfsr);
        let lib = cells::tnn7();
        let out = synthesize(&d.netlist, Flow::Tnn7);
        let meas = measure(&d.netlist, 4096, 9, SimBackend::BitParallel64).unwrap();
        assert_eq!(meas.alpha.len(), out.remap.old_net_count());
        assert_eq!(out.remap.new_net_count(), out.mapped.net_space);
        let r = analyze_with_alpha_remapped(&out.mapped, &lib, 16, &meas.alpha, &out.remap);
        let r_prob = analyze(&out.mapped, &lib, 16);
        assert!(r.dynamic_nw > 0.0);
        let ratio = r.dynamic_nw / r_prob.dynamic_nw;
        assert!(
            ratio > 0.1 && ratio < 10.0,
            "measured/probabilistic dynamic power ratio {ratio:.3}"
        );
        // Only dynamic power depends on the activity source.
        assert_eq!(r.area_um2, r_prob.area_um2);
        assert_eq!(r.leakage_nw, r_prob.leakage_nw);
        assert_eq!(r.critical_path_ps, r_prob.critical_path_ps);
    }

    #[test]
    fn pareto_front_keeps_only_nondominated_points() {
        // Index:            0         1         2         3         4
        let pts = [(1.0, 9.0), (2.0, 5.0), (3.0, 6.0), (4.0, 1.0), (2.0, 5.0)];
        // 2 is dominated by 1; 4 duplicates 1 and survives with it.
        assert_eq!(pareto_front(&pts), vec![0, 1, 4, 3]);
        assert_eq!(pareto_front(&[]), Vec::<usize>::new());
        // A single point is trivially on the frontier; NaN points drop out.
        assert_eq!(pareto_front(&[(1.0, 1.0)]), vec![0]);
        assert_eq!(pareto_front(&[(f64::NAN, 1.0), (2.0, 2.0)]), vec![1]);
    }

    #[test]
    fn area_and_power_scale_with_synapses() {
        let (b1, _) = column_reports(8, 2);
        let (b2, _) = column_reports(24, 4);
        assert!(b2.area_um2 > 3.0 * b1.area_um2);
        assert!(b2.power_nw > 3.0 * b1.power_nw);
    }

    #[test]
    fn comp_time_scales_sublinearly_with_p() {
        // Computation time is dominated by the adder-tree depth: log(p).
        let (b1, _) = column_reports(8, 2);
        let (b2, _) = column_reports(64, 2);
        let ratio = b2.comp_time_ns / b1.comp_time_ns;
        assert!(
            ratio < 3.0,
            "8→64 synapses should grow comp time ≪ 8×, got {ratio:.2}×"
        );
        assert!(ratio > 1.0);
    }
}
