//! Synaptic-count scaling for multi-layer networks — the method the paper
//! itself uses for Table III ("derived using synaptic count scaling as in
//! [6]", with every layer treated as a "C" column layer).
//!
//! A reference column is synthesized and analyzed; network-level area and
//! power scale linearly with total synapse count, while computation time
//! sums the per-layer critical paths (each layer's column sized by its
//! synapses-per-neuron p).

use super::report::{analyze, PpaReport};
use crate::cells;
use crate::gates::column_design::{build_column, BrvSource};
use crate::synth::flow::{synthesize, Flow};

/// Geometry of one layer for scaling purposes.
#[derive(Clone, Copy, Debug)]
pub struct LayerGeometry {
    /// Synapses per neuron (column input size).
    pub p: usize,
    /// Neurons per column.
    pub q: usize,
    /// Number of columns in the layer.
    pub columns: usize,
}

impl LayerGeometry {
    /// Total synapse count of the layer (p·q per column × columns).
    pub fn synapses(&self) -> usize {
        self.p * self.q * self.columns
    }
}

/// Network-level scaled PPA.
#[derive(Clone, Debug)]
pub struct NetworkPpa {
    /// Flow the reference columns were synthesized under.
    pub flow: Flow,
    /// Total network synapse count (the scaling variable).
    pub synapse_count: usize,
    /// Scaled network area, mm².
    pub area_mm2: f64,
    /// Scaled network power, mW.
    pub power_mw: f64,
    /// Per-input computation time (layer critical paths summed), ns.
    pub comp_time_ns: f64,
    /// Network energy-delay product.
    pub edp: f64,
    /// The per-layer reference reports the scaling was derived from.
    pub layer_refs: Vec<PpaReport>,
}

/// Scale a multi-layer network's PPA from per-layer reference columns.
///
/// For each layer a *reference column* of its (p, q) geometry is synthesized
/// under `flow`; area and power multiply by the column count, computation
/// time adds per layer (feed-forward pipeline, as in Table III where the
/// 2/3/4-layer comp times are ~linear in depth).
pub fn scale_network(layers: &[LayerGeometry], flow: Flow, gamma_cycles: u32) -> NetworkPpa {
    // Reference columns can be large (p up to ~784); cap the synthesized
    // reference geometry and scale the remainder linearly in p·q, which is
    // exact for area/power (synapse-dominated) and conservative for timing
    // (adder depth is log p — we synthesize at the true p whenever
    // feasible).
    let lib = match flow {
        Flow::Baseline => cells::asap7(),
        Flow::Tnn7 => cells::tnn7(),
    };
    let mut area_um2 = 0.0;
    let mut power_nw = 0.0;
    let mut comp_ns = 0.0;
    let mut refs = Vec::new();
    let mut synapses = 0usize;
    for l in layers {
        synapses += l.synapses();
        // Keep the reference synthesis tractable: q capped, p exact (p sets
        // the timing-relevant adder depth; q scales linearly).
        let q_ref = l.q.min(4).max(1);
        let theta = (l.p as u32 * 7) / 4;
        let d = build_column(l.p, q_ref, theta.max(1), BrvSource::Lfsr);
        let out = synthesize(&d.netlist, flow);
        let rep = analyze(&out.mapped, &lib, gamma_cycles);
        // per-synapse costs from the reference column
        let per_syn_area = rep.area_um2 / (l.p * q_ref) as f64;
        let per_syn_power = rep.power_nw / (l.p * q_ref) as f64;
        area_um2 += per_syn_area * l.synapses() as f64;
        power_nw += per_syn_power * l.synapses() as f64;
        comp_ns += rep.comp_time_ns;
        refs.push(rep);
    }
    let power_mw = power_nw * 1e-6;
    let energy = power_mw * comp_ns; // mW·ns = µJ·1e-3… consistent-unit EDP proxy
    NetworkPpa {
        flow,
        synapse_count: synapses,
        area_mm2: area_um2 * 1e-6,
        power_mw,
        comp_time_ns: comp_ns,
        edp: energy * comp_ns,
        layer_refs: refs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_geometry_counts_synapses() {
        let l = LayerGeometry {
            p: 10,
            q: 4,
            columns: 3,
        };
        assert_eq!(l.synapses(), 120);
    }

    #[test]
    fn deeper_networks_take_longer_and_more_area() {
        let layer = LayerGeometry {
            p: 32,
            q: 4,
            columns: 8,
        };
        let two = scale_network(&[layer; 2], Flow::Tnn7, 16);
        let three = scale_network(&[layer; 3], Flow::Tnn7, 16);
        assert!(three.area_mm2 > two.area_mm2);
        assert!(three.comp_time_ns > two.comp_time_ns);
        assert_eq!(three.synapse_count, 3 * layer.synapses());
    }

    #[test]
    fn tnn7_network_beats_baseline() {
        let layers = [LayerGeometry {
            p: 24,
            q: 3,
            columns: 4,
        }];
        let b = scale_network(&layers, Flow::Baseline, 16);
        let t = scale_network(&layers, Flow::Tnn7, 16);
        assert!(t.area_mm2 < b.area_mm2);
        assert!(t.power_mw < b.power_mw);
        assert!(t.comp_time_ns < b.comp_time_ns);
    }
}
