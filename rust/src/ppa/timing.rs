//! Static timing analysis over a mapped netlist (linear delay model).

use crate::cells::{names, CellLibrary, CellModel};
use crate::synth::map::MappedNetlist;

/// Effective load-dependent delay of a driver into `load_ff`, with implicit
/// buffer-tree insertion for high-fanout nets (what a real synthesis flow
/// does during optimization): beyond ~4 equivalent pins the delay follows a
/// branching-4 buffer tree, i.e. grows logarithmically in fanout instead of
/// linearly. Buffer area/power are absorbed into the net-area model.
fn load_delay_ps(load_ff: f64, drive: &CellModel, lib: &CellLibrary) -> f64 {
    let direct = drive.load_ps_per_ff * load_ff;
    let buf = lib.get(names::BUF);
    let leaf_cap = 4.0 * buf.cap_ff;
    if load_ff <= leaf_cap {
        return direct;
    }
    let stages = (load_ff / leaf_cap).log(4.0).ceil().max(1.0);
    let buffered = stages * (buf.delay_ps + buf.load_ps_per_ff * leaf_cap)
        + drive.load_ps_per_ff * leaf_cap;
    direct.min(buffered)
}

/// Timing results.
#[derive(Clone, Debug)]
pub struct TimingReport {
    /// Worst path delay (ps), including launching clk→q and capturing setup.
    pub critical_path_ps: f64,
    /// Worst combinational depth in cells.
    pub max_depth: usize,
}

/// Compute arrival times and the critical path.
pub fn sta(mapped: &MappedNetlist, lib: &CellLibrary) -> TimingReport {
    let n = mapped.net_space;
    // Load per net: Σ input-pin caps of consumers.
    let mut load_ff = vec![0.0f64; n];
    for c in &mapped.cells {
        let cap = lib.get(c.cell).cap_ff;
        for &i in &c.ins {
            load_ff[i as usize] += cap;
        }
    }
    for (kind, ins, _) in &mapped.macros {
        let cap = lib
            .macro_cell(*kind)
            .map(|m| m.cap_ff)
            .unwrap_or(0.7);
        for &i in ins {
            load_ff[i as usize] += cap;
        }
    }

    // Arrival times. Launch points: primary inputs at 0, sequential cell /
    // sequential macro outputs at clk→q. Iterate cells in stored order
    // (topologically consistent by construction) twice to settle
    // forward-wire (Buf) orderings.
    let mut arrival = vec![0.0f64; n];
    let mut depth = vec![0usize; n];
    for (kind, _, outs) in &mapped.macros {
        if let Some(m) = lib.macro_cell(*kind) {
            if m.sequential {
                for &o in outs {
                    arrival[o as usize] =
                        m.delay_ps + load_delay_ps(load_ff[o as usize], m, lib);
                }
            }
        }
    }
    for c in &mapped.cells {
        if c.sequential {
            let m = lib.get(c.cell);
            arrival[c.out as usize] =
                m.delay_ps + load_delay_ps(load_ff[c.out as usize], m, lib);
        }
    }
    for _ in 0..2 {
        for c in &mapped.cells {
            if c.sequential {
                continue;
            }
            let m = lib.get(c.cell);
            let mut worst = 0.0f64;
            let mut d = 0usize;
            for &i in &c.ins {
                if arrival[i as usize] > worst {
                    worst = arrival[i as usize];
                }
                if depth[i as usize] > d {
                    d = depth[i as usize];
                }
            }
            arrival[c.out as usize] =
                worst + m.delay_ps + load_delay_ps(load_ff[c.out as usize], m, lib);
            depth[c.out as usize] = d + 1;
        }
        // Combinational macro cells (e.g. syn_readout) also propagate.
        for (kind, ins, outs) in &mapped.macros {
            if let Some(m) = lib.macro_cell(*kind) {
                if !m.sequential {
                    let mut worst = 0.0f64;
                    let mut d = 0usize;
                    for &i in ins {
                        worst = worst.max(arrival[i as usize]);
                        d = d.max(depth[i as usize]);
                    }
                    for &o in outs {
                        arrival[o as usize] =
                            worst + m.delay_ps + load_delay_ps(load_ff[o as usize], m, lib);
                        depth[o as usize] = d + 1;
                    }
                }
            }
        }
    }

    // Capture points: sequential D inputs (+setup), macro inputs of
    // sequential macros (+setup), and primary outputs.
    let mut cp = 0.0f64;
    let mut max_depth = 0usize;
    for c in &mapped.cells {
        if c.sequential {
            let m = lib.get(c.cell);
            for &i in &c.ins {
                cp = cp.max(arrival[i as usize] + m.setup_ps);
                max_depth = max_depth.max(depth[i as usize]);
            }
        }
    }
    for (kind, ins, _) in &mapped.macros {
        if let Some(m) = lib.macro_cell(*kind) {
            if m.sequential {
                for &i in ins {
                    cp = cp.max(arrival[i as usize] + m.setup_ps);
                    max_depth = max_depth.max(depth[i as usize]);
                }
            }
        }
    }
    for (_, net) in &mapped.outputs {
        cp = cp.max(arrival[*net as usize]);
        max_depth = max_depth.max(depth[*net as usize]);
    }

    TimingReport {
        critical_path_ps: cp,
        max_depth,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells;
    use crate::gates::netlist::NetBuilder;
    use crate::synth::map::tech_map;

    #[test]
    fn chain_depth_accumulates_delay() {
        let lib = cells::asap7();
        let make_chain = |len: usize| {
            let mut b = NetBuilder::new("t");
            let mut x = b.input("a");
            let c = b.input("b");
            for _ in 0..len {
                x = b.xor(x, c);
            }
            let q = b.dff(x, None, false);
            b.output("q", q);
            tech_map(&b.finish(), &lib)
        };
        let short = sta(&make_chain(2), &lib);
        let long = sta(&make_chain(10), &lib);
        assert!(long.critical_path_ps > short.critical_path_ps * 3.0);
        assert_eq!(long.max_depth, 10);
    }

    #[test]
    fn fanout_load_increases_delay() {
        let lib = cells::asap7();
        let make = |fanout: usize| {
            let mut b = NetBuilder::new("t");
            let a = b.input("a");
            let c = b.input("b");
            let x = b.and(a, c);
            for k in 0..fanout {
                let y = b.xor(x, c);
                let q = b.dff(y, None, false);
                b.output(&format!("q{k}"), q);
            }
            tech_map(&b.finish(), &lib)
        };
        let lo = sta(&make(1), &lib);
        let hi = sta(&make(12), &lib);
        assert!(hi.critical_path_ps > lo.critical_path_ps);
    }

    #[test]
    fn sequential_launch_and_capture_counted() {
        let lib = cells::asap7();
        let mut b = NetBuilder::new("t");
        let d = b.input("d");
        let q1 = b.dff(d, None, false);
        let n1 = b.not(q1);
        let q2 = b.dff(n1, None, false);
        b.output("q", q2);
        let mapped = tech_map(&b.finish(), &lib);
        let t = sta(&mapped, &lib);
        let dff = lib.get(crate::cells::names::DFF);
        let inv = lib.get(crate::cells::names::INV);
        // clk→q + inv + load + setup
        assert!(t.critical_path_ps >= dff.delay_ps + inv.delay_ps + dff.setup_ps);
    }
}
