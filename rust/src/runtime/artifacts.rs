//! Artifact manifest parsing (`artifacts/manifest.kv`).

use crate::util::kv::KvDoc;
use std::path::{Path, PathBuf};

/// Metadata of one compiled artifact (one section of the manifest).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// Artifact name (the manifest section header).
    pub name: String,
    /// Path of the compiled HLO text, relative to the manifest dir.
    pub path: PathBuf,
    /// Entry-point kind: "step" | "infer" | "step_batched" | "infer_batched".
    pub kind: String,
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons per column.
    pub q: usize,
    /// Neuron firing threshold baked into the artifact.
    pub theta: u32,
    /// Batch dimension (1 = unbatched).
    pub batch: usize,
    /// Unit cycles per gamma cycle.
    pub gamma_cycles: u32,
    /// Synaptic weight precision, bits.
    pub weight_bits: u8,
    /// STDP capture probability.
    pub mu_capture: f64,
    /// STDP minus probability.
    pub mu_minus: f64,
    /// STDP search probability.
    pub mu_search: f64,
    /// STDP backoff probability.
    pub mu_backoff: f64,
    /// Whether bimodal weight stabilization is applied.
    pub stabilize: bool,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// All artifact entries, in name order.
    pub artifacts: Vec<ArtifactMeta>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.kv`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let doc = KvDoc::load(dir.join("manifest.kv"))?;
        // Section names are the artifact names: collect unique prefixes.
        let mut names: Vec<String> = doc
            .keys()
            .filter_map(|k| k.rsplit_once('.').map(|(s, _)| s.to_string()))
            .collect();
        names.sort();
        names.dedup();
        let mut artifacts = Vec::new();
        for name in names {
            let get = |field: &str| -> crate::Result<String> {
                Ok(doc.require(&format!("{name}.{field}"))?.to_string())
            };
            let meta = ArtifactMeta {
                path: dir.join(get("path")?),
                kind: get("kind")?,
                p: get("p")?.parse()?,
                q: get("q")?.parse()?,
                theta: get("theta")?.parse()?,
                batch: get("batch")?.parse()?,
                gamma_cycles: get("gamma_cycles")?.parse()?,
                weight_bits: get("weight_bits")?.parse()?,
                mu_capture: get("mu_capture")?.parse()?,
                mu_minus: get("mu_minus")?.parse()?,
                mu_search: get("mu_search")?.parse()?,
                mu_backoff: get("mu_backoff")?.parse()?,
                stabilize: get("stabilize")? == "true",
                name,
            };
            artifacts.push(meta);
        }
        Ok(ArtifactManifest { dir, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the artifact for a (p, q, kind) triple.
    pub fn find(&self, p: usize, q: usize, kind: &str) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.p == p && a.q == q && a.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::write(
            dir.join("manifest.kv"),
            "[column_p4_q2_th7_step]\n\
             path = column_p4_q2_th7_step.hlo.txt\n\
             kind = step\np = 4\nq = 2\ntheta = 7\nbatch = 1\n\
             gamma_cycles = 16\nweight_bits = 3\n\
             mu_capture = 1.0\nmu_minus = 0.5\nmu_search = 0.0625\n\
             mu_backoff = 0.5\nstabilize = true\n",
        )
        .unwrap();
    }

    #[test]
    fn parses_manifest_sections() {
        let dir = std::env::temp_dir().join(format!("tnn7_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.p, 4);
        assert_eq!(a.q, 2);
        assert_eq!(a.kind, "step");
        assert!(a.stabilize);
        assert_eq!(a.mu_search, 0.0625);
        assert!(m.find(4, 2, "step").is_some());
        assert!(m.find(4, 2, "infer").is_none());
        assert!(m.by_name("column_p4_q2_th7_step").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration sanity when `make artifacts` has run.
        if let Ok(m) = ArtifactManifest::load("artifacts") {
            assert!(!m.artifacts.is_empty());
            assert!(m.find(82, 2, "step").is_some(), "TwoLeadECG column present");
        }
    }
}
