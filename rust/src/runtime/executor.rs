//! PJRT execution of compiled column artifacts.

use super::artifacts::{ArtifactManifest, ArtifactMeta};
use crate::tnn::spike::SpikeTime;
use anyhow::Context;
use std::collections::HashMap;
use std::path::Path;

/// Shared PJRT CPU client + compiled executables, keyed by artifact name.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    /// The parsed artifact manifest the executables were compiled from.
    pub manifest: ArtifactManifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Create the CPU client and compile every artifact in the manifest.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let mut executables = HashMap::new();
        for meta in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().context("artifact path utf8")?,
            )
            .with_context(|| format!("parse HLO text {:?}", meta.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {}", meta.name))?;
            executables.insert(meta.name.clone(), exe);
        }
        Ok(XlaRuntime {
            client,
            manifest,
            executables,
        })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of all compiled artifacts, sorted.
    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Bind a column executable by (p, q, kind).
    pub fn column(&self, p: usize, q: usize, kind: &str) -> crate::Result<ColumnExecutable<'_>> {
        let meta = self
            .manifest
            .find(p, q, kind)
            .with_context(|| format!("no artifact for p={p} q={q} kind={kind}"))?
            .clone();
        let exe = self
            .executables
            .get(&meta.name)
            .context("executable missing")?;
        Ok(ColumnExecutable { meta, exe })
    }

    /// Bind by exact artifact name.
    pub fn by_name(&self, name: &str) -> crate::Result<ColumnExecutable<'_>> {
        let meta = self
            .manifest
            .by_name(name)
            .with_context(|| format!("no artifact named {name}"))?
            .clone();
        let exe = self.executables.get(name).context("executable missing")?;
        Ok(ColumnExecutable { meta, exe })
    }
}

/// One bound column entry point.
pub struct ColumnExecutable<'a> {
    /// The artifact's manifest entry (geometry, θ, STDP parameters).
    pub meta: ArtifactMeta,
    exe: &'a xla::PjRtLoadedExecutable,
}

fn lit_1d(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

fn lit_2d(v: &[f32], rows: usize, cols: usize) -> crate::Result<xla::Literal> {
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

fn lit_3d(v: &[f32], a: usize, b: usize, c: usize) -> crate::Result<xla::Literal> {
    Ok(xla::Literal::vec1(v).reshape(&[a as i64, b as i64, c as i64])?)
}

impl ColumnExecutable<'_> {
    fn run(&self, args: &[xla::Literal]) -> crate::Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        Ok(result.to_tuple()?)
    }

    /// Learning step (`kind == "step"`): one gamma cycle.
    /// `xs`: p spike times; `w`: p×q weights (f32 in 0..=w_max);
    /// `u_case`/`u_stab`: p×q uniforms. Returns (post-WTA spikes, new w).
    pub fn step(
        &self,
        xs: &[SpikeTime],
        w: &[f32],
        u_case: &[f32],
        u_stab: &[f32],
    ) -> crate::Result<(Vec<SpikeTime>, Vec<f32>)> {
        let (p, q) = (self.meta.p, self.meta.q);
        anyhow::ensure!(self.meta.kind == "step", "artifact kind {}", self.meta.kind);
        anyhow::ensure!(xs.len() == p && w.len() == p * q);
        let x: Vec<f32> = xs.iter().map(|s| s.to_f32()).collect();
        let out = self.run(&[
            lit_1d(&x),
            lit_2d(w, p, q)?,
            lit_2d(u_case, p, q)?,
            lit_2d(u_stab, p, q)?,
        ])?;
        anyhow::ensure!(out.len() == 2, "expected 2 results, got {}", out.len());
        let y: Vec<f32> = out[0].to_vec()?;
        let w_new: Vec<f32> = out[1].to_vec()?;
        Ok((y.iter().map(|&v| SpikeTime::from_f32(v)).collect(), w_new))
    }

    /// Inference (`kind == "infer"`).
    pub fn infer(&self, xs: &[SpikeTime], w: &[f32]) -> crate::Result<Vec<SpikeTime>> {
        let (p, q) = (self.meta.p, self.meta.q);
        anyhow::ensure!(self.meta.kind == "infer", "artifact kind {}", self.meta.kind);
        anyhow::ensure!(xs.len() == p && w.len() == p * q);
        let x: Vec<f32> = xs.iter().map(|s| s.to_f32()).collect();
        let out = self.run(&[lit_1d(&x), lit_2d(w, p, q)?])?;
        let y: Vec<f32> = out[0].to_vec()?;
        Ok(y.iter().map(|&v| SpikeTime::from_f32(v)).collect())
    }

    /// Batched learning step (`kind == "step_batched"`): B gamma instances
    /// processed with the weights threaded through (identical to B
    /// sequential steps). `xs`: B×p; uniforms: B×p×q.
    pub fn step_batched(
        &self,
        xs: &[SpikeTime],
        w: &[f32],
        u_case: &[f32],
        u_stab: &[f32],
    ) -> crate::Result<(Vec<SpikeTime>, Vec<f32>)> {
        let (p, q, b) = (self.meta.p, self.meta.q, self.meta.batch);
        anyhow::ensure!(self.meta.kind == "step_batched");
        anyhow::ensure!(xs.len() == b * p && w.len() == p * q);
        anyhow::ensure!(u_case.len() == b * p * q && u_stab.len() == b * p * q);
        let x: Vec<f32> = xs.iter().map(|s| s.to_f32()).collect();
        let out = self.run(&[
            lit_2d(&x, b, p)?,
            lit_2d(w, p, q)?,
            lit_3d(u_case, b, p, q)?,
            lit_3d(u_stab, b, p, q)?,
        ])?;
        let y: Vec<f32> = out[0].to_vec()?;
        let w_new: Vec<f32> = out[1].to_vec()?;
        Ok((y.iter().map(|&v| SpikeTime::from_f32(v)).collect(), w_new))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tnn::column::Column;
    use crate::tnn::params::TnnParams;
    use crate::util::Rng64;

    fn runtime() -> Option<XlaRuntime> {
        // Requires `make artifacts`; tests are skipped (not failed) when the
        // artifacts are absent so `cargo test` works pre-build.
        if !std::path::Path::new("artifacts/manifest.kv").exists() {
            eprintln!("artifacts/ missing; skipping XLA runtime test");
            return None;
        }
        Some(XlaRuntime::load("artifacts").expect("runtime load"))
    }

    #[test]
    fn xla_step_matches_golden_model() {
        let Some(rt) = runtime() else { return };
        let exe = rt.column(16, 4, "step").expect("p16 q4 artifact");
        let meta = exe.meta.clone();
        let params = TnnParams {
            weight_bits: meta.weight_bits,
            gamma_cycles: meta.gamma_cycles,
            mu_capture: meta.mu_capture,
            mu_minus: meta.mu_minus,
            mu_search: meta.mu_search,
            mu_backoff: meta.mu_backoff,
            stabilize: meta.stabilize,
        };
        let mut rng = Rng64::seed_from_u64(99);
        let mut golden = Column::with_random_weights(
            meta.p,
            meta.q,
            meta.theta,
            params,
            &mut rng,
        );
        let mut w: Vec<f32> = golden.weights().iter().map(|&x| x as f32).collect();
        for gamma in 0..20 {
            let xs: Vec<SpikeTime> = (0..meta.p)
                .map(|_| {
                    if rng.gen_bool(0.25) {
                        SpikeTime::NONE
                    } else {
                        SpikeTime::at(rng.gen_range(0, 8) as u32)
                    }
                })
                .collect();
            let n = meta.p * meta.q;
            let u_case: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
            let u_stab: Vec<f32> = (0..n).map(|_| rng.gen_f32()).collect();
            let (y_xla, w_xla) = exe.step(&xs, &w, &u_case, &u_stab).unwrap();
            let uc64: Vec<f64> = u_case.iter().map(|&v| v as f64).collect();
            let us64: Vec<f64> = u_stab.iter().map(|&v| v as f64).collect();
            let out = golden.step_with_uniforms(&xs, &uc64, &us64);
            assert_eq!(y_xla, out.output, "gamma {gamma} spikes");
            let w_golden: Vec<f32> =
                golden.weights().iter().map(|&x| x as f32).collect();
            assert_eq!(w_xla, w_golden, "gamma {gamma} weights");
            w = w_xla;
        }
    }

    #[test]
    fn xla_infer_is_pure() {
        let Some(rt) = runtime() else { return };
        let exe = rt.column(16, 4, "infer").expect("infer artifact");
        let p = exe.meta.p;
        let xs: Vec<SpikeTime> = (0..p).map(|i| SpikeTime::at((i % 8) as u32)).collect();
        let w = vec![4.0f32; p * exe.meta.q];
        let y1 = exe.infer(&xs, &w).unwrap();
        let y2 = exe.infer(&xs, &w).unwrap();
        assert_eq!(y1, y2);
        assert!(y1.iter().filter(|t| t.is_spike()).count() <= 1, "1-WTA");
    }

    #[test]
    fn xla_batched_step_equals_sequential() {
        let Some(rt) = runtime() else { return };
        let batched = rt
            .by_name("column_p82_q2_th143_b16_step_batched")
            .expect("batched artifact");
        let single = rt.column(82, 2, "step").expect("single artifact");
        let (p, q, b) = (batched.meta.p, batched.meta.q, batched.meta.batch);
        let mut rng = Rng64::seed_from_u64(7);
        let mut w: Vec<f32> = (0..p * q).map(|_| rng.gen_range(0, 8) as f32).collect();
        let xs: Vec<SpikeTime> = (0..b * p)
            .map(|_| {
                if rng.gen_bool(0.3) {
                    SpikeTime::NONE
                } else {
                    SpikeTime::at(rng.gen_range(0, 8) as u32)
                }
            })
            .collect();
        let u_case: Vec<f32> = (0..b * p * q).map(|_| rng.gen_f32()).collect();
        let u_stab: Vec<f32> = (0..b * p * q).map(|_| rng.gen_f32()).collect();
        let (ys_b, w_b) = batched.step_batched(&xs, &w, &u_case, &u_stab).unwrap();
        // sequential reference through the single-step artifact
        let mut ys_seq = Vec::new();
        for i in 0..b {
            let xi = &xs[i * p..(i + 1) * p];
            let ui = &u_case[i * p * q..(i + 1) * p * q];
            let si = &u_stab[i * p * q..(i + 1) * p * q];
            let (y, w_new) = single.step(xi, &w, ui, si).unwrap();
            ys_seq.extend(y);
            w = w_new;
        }
        assert_eq!(ys_b, ys_seq);
        assert_eq!(w_b, w);
    }
}
