//! XLA/PJRT runtime: loads the AOT-compiled column executables produced by
//! `python/compile/aot.py` and runs them from the Rust hot path.
//!
//! Python is **never** on the request path: `make artifacts` lowers the
//! JAX/Pallas column functions to HLO *text* once; this module parses the
//! manifest, compiles each module on the PJRT CPU client, and exposes typed
//! entry points ([`ColumnExecutable`]) operating on spike-time vectors.

pub mod artifacts;
pub mod executor;

pub use artifacts::{ArtifactManifest, ArtifactMeta};
pub use executor::{ColumnExecutable, XlaRuntime};
