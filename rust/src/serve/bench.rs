//! Bench mode: drive the server with the deterministic seeded client
//! over every arrival pattern, verify batched winners against an
//! independently rebuilt sequential reference, and report latency
//! quantiles + sustained throughput into `BENCH_serve.json`.

use super::server::{build_entry_engine, Reply, Server};
use super::{ArrivalPattern, ServeSpec};
use crate::gates::artifact_cache::{cache_stats, set_cache_capacities, CacheStats};
use crate::metrics::LatencyHistogram;
use crate::util::json::Json;
use crate::util::Rng64;
use std::fmt::Write as _;
use std::sync::mpsc;
use std::time::Instant;

/// One registry entry as reported (names only; the live engines stay in
/// the server).
#[derive(Clone, Debug)]
pub struct EntrySummary {
    /// Wire name (`gate:12x2`).
    pub name: String,
    /// Engine kind spelling.
    pub kind: String,
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons in the column.
    pub q: usize,
    /// Query-pool size.
    pub queries: usize,
}

/// Latency/throughput summary of one arrival pattern's run.
#[derive(Clone, Debug)]
pub struct PatternStats {
    /// The arrival schedule this row measured.
    pub pattern: ArrivalPattern,
    /// Requests the client sent.
    pub requests: usize,
    /// Lane-block passes the server executed for them.
    pub batches: u64,
    /// Mean requests coalesced per pass.
    pub mean_batch: f64,
    /// Median end-to-end latency (µs, bucket upper bound).
    pub p50_us: u64,
    /// 99th-percentile end-to-end latency (µs, bucket upper bound).
    pub p99_us: u64,
    /// Mean end-to-end latency (µs).
    pub mean_us: f64,
    /// Worst observed latency (µs).
    pub max_us: u64,
    /// Sustained queries/sec over the pattern's wall time.
    pub qps: f64,
    /// Did every server winner equal the sequential reference's?
    pub winners_match_sequential: bool,
}

/// The serving stack's resilience counters after a run (a plain-data
/// snapshot of [`crate::metrics::ServeCounters`]). In a healthy bench
/// run everything but `submitted`/`replies` is zero — nonzero shed or
/// panic counts in `BENCH_serve.json` are the first thing to look at
/// when a soak goes sideways.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResilienceSnapshot {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests shed at admission (`!overload`).
    pub shed: u64,
    /// Requests whose deadline expired (at dequeue or at reply).
    pub expired: u64,
    /// Worker batches that panicked.
    pub batch_panics: u64,
    /// Workers the supervisor respawned.
    pub worker_respawns: u64,
    /// Replies delivered (success or typed error).
    pub replies: u64,
}

/// Everything bench mode measures (and `BENCH_serve.json` records).
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// The configuration the service ran under.
    pub spec: ServeSpec,
    /// The registry (engines × geometries).
    pub entries: Vec<EntrySummary>,
    /// One row per arrival pattern.
    pub patterns: Vec<PatternStats>,
    /// Artifact-cache occupancy/evictions after the run.
    pub cache: CacheStats,
    /// Resilience counters at the end of the run.
    pub resilience: ResilienceSnapshot,
    /// TSV transcript, `pattern \t id \t entry \t winner` sorted by
    /// (pattern order, id) — byte-stable at any worker count; diffed
    /// against the committed golden in CI.
    pub transcript: String,
}

/// Render a winner for the transcript / summary (`-` = no spike).
fn fmt_winner(w: Option<usize>) -> String {
    w.map_or_else(|| "-".to_string(), |i| i.to_string())
}

/// The seeded client's arrival schedule for one pattern: request i is
/// `(entry index, query index)`. Deterministic from (seed, pattern slot).
fn make_schedule(
    spec: &ServeSpec,
    pattern: ArrivalPattern,
    slot: u64,
    pools: &[usize],
    caps: &[usize],
) -> Vec<(usize, usize)> {
    let n = pools.len();
    let mut rng = Rng64::seed_from_u64(spec.seed ^ 0xA11C_E5E0).split_stream(slot);
    let mut sched = Vec::with_capacity(spec.requests);
    match pattern {
        ArrivalPattern::Steady => {
            for i in 0..spec.requests {
                let e = i % n;
                sched.push((e, (i / n) % pools[e]));
            }
        }
        ArrivalPattern::Bursty => {
            while sched.len() < spec.requests {
                let e = rng.gen_range(0, n);
                let burst = rng.gen_range(2, caps[e].max(3));
                let base = rng.gen_range(0, pools[e]);
                for b in 0..burst {
                    if sched.len() == spec.requests {
                        break;
                    }
                    sched.push((e, (base + b) % pools[e]));
                }
            }
        }
        ArrivalPattern::Shuffled => {
            for _ in 0..spec.requests {
                let e = rng.gen_range(0, n);
                sched.push((e, rng.gen_range(0, pools[e])));
            }
        }
    }
    sched
}

/// Run the full bench: build the sequential reference, start the server,
/// sweep every arrival pattern, and assemble the report. The reference
/// winners come from stateful engines rebuilt independently through
/// [`build_entry_engine`] and queried one volley at a time with
/// `Engine::infer_winner` — the differential the tentpole's
/// "batching is semantics-free" claim is checked against.
pub fn run_bench(spec: &ServeSpec) -> crate::Result<ServeReport> {
    spec.validate()?;
    if spec.capacity > 0 {
        set_cache_capacities(spec.capacity, spec.capacity * 2);
    }

    // --- sequential reference: fresh engines, one query at a time ------
    let mut expected: Vec<Vec<Option<usize>>> = Vec::new();
    {
        let mut idx = 0u64;
        for &kind in &spec.engines {
            for &(p, q) in &spec.geometries {
                let (mut engine, queries) = build_entry_engine(spec, kind, p, q, idx)?;
                let mut winners = Vec::with_capacity(queries.len());
                for v in &queries {
                    winners.push(engine.infer_winner(v)?);
                }
                expected.push(winners);
                idx += 1;
            }
        }
    }

    // --- the server under test -----------------------------------------
    // The bench client floods each pattern's whole schedule up front, so
    // admission control would shed most of it and the differential
    // against the sequential reference would be vacuous. Bench mode
    // therefore lifts the queue bound; shed/deadline behavior is
    // exercised by the serving modes, the chaos harness, and the tests.
    let mut bench_spec = spec.clone();
    bench_spec.queue_depth = 0;
    bench_spec.deadline_ms = 0;
    let server = Server::start(&bench_spec)?;
    let entries: Vec<EntrySummary> = server
        .entries()
        .iter()
        .map(|e| EntrySummary {
            name: e.name.clone(),
            kind: e.kind.name().to_string(),
            p: e.p,
            q: e.q,
            queries: e.queries.len(),
        })
        .collect();
    let pools: Vec<usize> = server.entries().iter().map(|e| e.queries.len()).collect();
    let caps: Vec<usize> = server.entries().iter().map(|e| e.max_batch).collect();

    let mut patterns = Vec::new();
    let mut transcript = String::new();
    for (slot, &pattern) in spec.patterns.iter().enumerate() {
        let sched = make_schedule(spec, pattern, slot as u64, &pools, &caps);
        let b0 = server.batches();
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        for (i, &(e, qi)) in sched.iter().enumerate() {
            let volley = server.entries()[e].queries[qi].clone();
            server.submit(i as u64, e, volley, tx.clone())?;
        }
        drop(tx);
        let mut replies: Vec<Reply> = rx.iter().collect();
        let wall = t0.elapsed();
        let batches = server.batches() - b0;
        anyhow::ensure!(
            replies.len() == sched.len(),
            "{}: {} replies for {} requests",
            pattern.name(),
            replies.len(),
            sched.len()
        );
        replies.sort_by_key(|r| r.id);

        let hist = LatencyHistogram::default();
        let mut matched = true;
        for r in &replies {
            hist.observe(r.latency);
            let (e, qi) = sched[r.id as usize];
            let ok = matches!(&r.outcome, Ok(w) if *w == expected[e][qi]);
            matched &= ok;
            let _ = writeln!(
                transcript,
                "{}\t{}\t{}\t{}",
                pattern.name(),
                r.id,
                entries[e].name,
                match &r.outcome {
                    Ok(w) => fmt_winner(*w),
                    Err(msg) => format!("!{msg}"),
                }
            );
        }
        patterns.push(PatternStats {
            pattern,
            requests: sched.len(),
            batches,
            mean_batch: sched.len() as f64 / (batches as f64).max(1.0),
            p50_us: hist.quantile_us(0.5),
            p99_us: hist.quantile_us(0.99),
            mean_us: hist.mean_us(),
            max_us: hist.max_us(),
            qps: sched.len() as f64 / wall.as_secs_f64().max(1e-9),
            winners_match_sequential: matched,
        });
    }

    let cache = cache_stats();
    let c = server.counters();
    let resilience = ResilienceSnapshot {
        submitted: c.submitted.get(),
        shed: c.shed.get(),
        expired: c.expired_dequeue.get() + c.expired_reply.get(),
        batch_panics: c.batch_panics.get(),
        worker_respawns: c.worker_respawns.get(),
        replies: c.replies.get(),
    };
    server.shutdown();
    Ok(ServeReport {
        spec: spec.clone(),
        entries,
        patterns,
        cache,
        resilience,
        transcript,
    })
}

/// Print a [`ServeReport`] as a human-readable summary table.
pub fn print_summary(r: &ServeReport) {
    println!(
        "tnn7 serve bench: seed {}, {} workers x {}w lane blocks, {} registry entries",
        r.spec.seed,
        r.spec.workers,
        r.spec.words,
        r.entries.len()
    );
    for e in &r.entries {
        println!("  entry {:<14} {} queries", e.name, e.queries);
    }
    println!(
        "{:<10} {:>8} {:>8} {:>10} {:>9} {:>9} {:>10} {:>6}",
        "pattern", "requests", "batches", "mean batch", "p50 us", "p99 us", "qps", "exact"
    );
    for p in &r.patterns {
        println!(
            "{:<10} {:>8} {:>8} {:>10.2} {:>9} {:>9} {:>10.0} {:>6}",
            p.pattern.name(),
            p.requests,
            p.batches,
            p.mean_batch,
            p.p50_us,
            p.p99_us,
            p.qps,
            if p.winners_match_sequential { "yes" } else { "NO" }
        );
    }
    println!(
        "cache: {} designs / {} programs live (capacity {}/{}), {} evictions",
        r.cache.designs,
        r.cache.programs,
        r.cache.design_capacity,
        r.cache.program_capacity,
        r.cache.evictions
    );
    println!(
        "resilience: {} submitted, {} shed, {} expired, {} batch panics, {} respawns, {} replies",
        r.resilience.submitted,
        r.resilience.shed,
        r.resilience.expired,
        r.resilience.batch_panics,
        r.resilience.worker_respawns,
        r.resilience.replies
    );
}

/// JSON payload of a [`ServeReport`] (`BENCH_serve.json`).
pub fn serve_json(r: &ServeReport) -> Json {
    Json::obj()
        .set("seed", Json::Int(r.spec.seed as i64))
        .set("workers", r.spec.workers)
        .set("words", r.spec.words)
        .set("requests_total", r.spec.requests * r.spec.patterns.len())
        .set(
            "registry",
            Json::Arr(
                r.entries
                    .iter()
                    .map(|e| {
                        Json::obj()
                            .set("entry", e.name.as_str())
                            .set("kind", e.kind.as_str())
                            .set("p", e.p)
                            .set("q", e.q)
                            .set("queries", e.queries)
                    })
                    .collect(),
            ),
        )
        .set(
            "patterns",
            Json::Arr(
                r.patterns
                    .iter()
                    .map(|p| {
                        Json::obj()
                            .set("pattern", p.pattern.name())
                            .set("requests", p.requests)
                            .set("batches", Json::Int(p.batches as i64))
                            .set("mean_batch", p.mean_batch)
                            .set("p50_us", Json::Int(p.p50_us as i64))
                            .set("p99_us", Json::Int(p.p99_us as i64))
                            .set("mean_us", p.mean_us)
                            .set("max_us", Json::Int(p.max_us as i64))
                            .set("qps", p.qps)
                            .set("winners_match_sequential", p.winners_match_sequential)
                    })
                    .collect(),
            ),
        )
        .set(
            "cache",
            Json::obj()
                .set("designs", r.cache.designs)
                .set("programs", r.cache.programs)
                .set("design_capacity", r.cache.design_capacity)
                .set("program_capacity", r.cache.program_capacity)
                .set("evictions", Json::Int(r.cache.evictions as i64)),
        )
        .set(
            "resilience",
            Json::obj()
                .set("submitted", Json::Int(r.resilience.submitted as i64))
                .set("shed", Json::Int(r.resilience.shed as i64))
                .set("expired", Json::Int(r.resilience.expired as i64))
                .set("batch_panics", Json::Int(r.resilience.batch_panics as i64))
                .set(
                    "worker_respawns",
                    Json::Int(r.resilience.worker_respawns as i64),
                )
                .set("replies", Json::Int(r.resilience.replies as i64)),
        )
}

/// Write `BENCH_serve.json` and `serve_transcript.tsv` into the spec's
/// `out_dir` (created if missing).
pub fn write_report(r: &ServeReport) -> crate::Result<()> {
    std::fs::create_dir_all(&r.spec.out_dir)?;
    std::fs::write(
        r.spec.out_dir.join("BENCH_serve.json"),
        serve_json(r).to_pretty(),
    )?;
    std::fs::write(r.spec.out_dir.join("serve_transcript.tsv"), &r.transcript)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;

    fn tiny_spec() -> ServeSpec {
        let mut s = ServeSpec::quick();
        s.engines = vec![EngineKind::Golden, EngineKind::Gate];
        s.geometries = vec![(6, 2)];
        s.per_cluster = 3;
        s.requests = 24;
        s.words = 1;
        s
    }

    #[test]
    fn schedules_are_deterministic_and_sized() {
        let spec = tiny_spec();
        let pools = vec![6, 6];
        let caps = vec![64, 64];
        for (slot, p) in [
            ArrivalPattern::Steady,
            ArrivalPattern::Bursty,
            ArrivalPattern::Shuffled,
        ]
        .into_iter()
        .enumerate()
        {
            let a = make_schedule(&spec, p, slot as u64, &pools, &caps);
            let b = make_schedule(&spec, p, slot as u64, &pools, &caps);
            assert_eq!(a, b, "{} schedule must reproduce", p.name());
            assert_eq!(a.len(), spec.requests);
            for &(e, qi) in &a {
                assert!(e < 2 && qi < 6);
            }
        }
        // Steady really interleaves entries.
        let s = make_schedule(&spec, ArrivalPattern::Steady, 0, &pools, &caps);
        assert_eq!(s[0].0, 0);
        assert_eq!(s[1].0, 1);
    }

    #[test]
    fn bench_runs_end_to_end_and_matches_the_sequential_reference() {
        let r = run_bench(&tiny_spec()).unwrap();
        assert_eq!(r.entries.len(), 2);
        assert_eq!(r.patterns.len(), 3);
        for p in &r.patterns {
            assert!(p.winners_match_sequential, "{} diverged", p.pattern.name());
            assert_eq!(p.requests, 24);
            assert!(p.batches >= 1);
            assert!(p.mean_batch >= 1.0);
            assert!(p.qps > 0.0);
        }
        assert_eq!(
            r.transcript.lines().count(),
            3 * 24,
            "one transcript line per request"
        );
        // The report JSON carries the headline fields the schema checks.
        let j = serve_json(&r).to_string();
        for key in [
            "\"registry\"",
            "\"patterns\"",
            "\"p50_us\"",
            "\"p99_us\"",
            "\"qps\"",
            "\"winners_match_sequential\"",
            "\"cache\"",
            "\"resilience\"",
            "\"batch_panics\"",
        ] {
            assert!(j.contains(key), "JSON missing {key}");
        }
        // A clean bench run sheds, expires, and panics nothing.
        assert_eq!(r.resilience.submitted, 3 * 24);
        assert_eq!(r.resilience.replies, 3 * 24);
        assert_eq!(r.resilience.shed, 0);
        assert_eq!(r.resilience.expired, 0);
        assert_eq!(r.resilience.batch_panics, 0);
        assert_eq!(r.resilience.worker_respawns, 0);
    }
}
