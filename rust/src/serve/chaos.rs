//! The deterministic chaos harness: scheduled failure injection across
//! the whole serving path, with a verdict report that is bit-identical
//! at any worker count.
//!
//! `tnn7 serve chaos=<spec>` drives the seeded client through a request
//! stream in which specific request *indices* carry perturbations:
//! worker panics, slow batches, forced admission sheds, pre-expired
//! deadlines, malformed request lines, dropped reply channels, and
//! gate-level stuck-at faults from [`crate::gates::fault`]. Every
//! request ends in exactly one verdict — `shed`, `expired`, `errored`,
//! `parse`, `dropped`, or `survived` — and the verdict transcript plus
//! the per-category counts land in `BENCH_chaos.json` /
//! `chaos_transcript.tsv`.
//!
//! **Determinism rule** (how chaos verdicts stay invariant under worker
//! count, the property `tests/serve.rs` pins at 1/2/4 workers):
//!
//! 1. the event *schedule* is modular arithmetic on the request index
//!    (`i % period == offset`, fixed priority order) — never an
//!    occupancy or timing observation; event *parameters* (query, fault
//!    net, line corruption) come from the frozen
//!    [`Rng64::split_stream`](crate::util::Rng64::split_stream)
//!    discipline, one stream per request index;
//! 2. sheds are injector-forced at admission (the run disables the
//!    occupancy bound), so whether a queue *happened* to be deep never
//!    decides a verdict;
//! 3. deadlines are pre-expired at submission (the deadline is the
//!    submit-time instant), so expiry does not race the worker pool;
//! 4. perturbing requests run as singleton batches (chaos isolation in
//!    the coalescer), so a panic or fault can only ever affect its own
//!    rider — verdicts never depend on batch composition, which is the
//!    one thing that *does* vary with worker count.

use super::proto::parse_request;
use super::server::{ChaosAction, Reply, ServeError, Server, SubmitOpts};
use super::ServeSpec;
use crate::gates::fault::GateFault;
use crate::util::json::Json;
use crate::util::Rng64;
use std::fmt::Write as _;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// The perturbation scheduled for one request index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChaosEvent {
    /// No perturbation: a plain request.
    None,
    /// The serving batch panics under `catch_unwind`.
    Panic,
    /// Admission shed forced by the injector (`!overload` reply).
    Shed,
    /// Submitted with an already-expired deadline (`!deadline` reply).
    Expire,
    /// A deterministically corrupted request line through the parser.
    Malformed,
    /// The client drops its reply channel (simulated dead connection).
    Drop,
    /// The serving batch is stalled before execution.
    Slow,
    /// A gate-level stuck-at fault rides the serving pass.
    Fault,
}

/// One category's schedule: fire at request indices `i` with
/// `i % period == offset` (`period == 0` = never).
type Cadence = (u64, u64);

/// A named chaos schedule (`chaos=off|default|heavy`). Event categories
/// are resolved in a fixed priority order when cadences collide on an
/// index: panic > shed > expire > malformed > drop > slow > fault.
#[derive(Clone, Debug)]
pub struct ChaosSpec {
    /// Canonical spelling (`off`/`default`/`heavy`).
    pub name: &'static str,
    panic: Cadence,
    shed: Cadence,
    expire: Cadence,
    malformed: Cadence,
    drop: Cadence,
    slow: Cadence,
    fault: Cadence,
    /// Stall injected by [`ChaosEvent::Slow`] batches, in milliseconds.
    pub slow_ms: u64,
}

impl ChaosSpec {
    /// No injection (the implicit default of `tnn7 serve`).
    pub fn off() -> ChaosSpec {
        ChaosSpec {
            name: "off",
            panic: (0, 0),
            shed: (0, 0),
            expire: (0, 0),
            malformed: (0, 0),
            drop: (0, 0),
            slow: (0, 0),
            fault: (0, 0),
            slow_ms: 0,
        }
    }

    /// The standard soak: every category fires at least twice within the
    /// quick spec's 96 requests (offsets chosen so the priority order
    /// rarely has to break a tie).
    pub fn default_spec() -> ChaosSpec {
        ChaosSpec {
            name: "default",
            panic: (48, 13),
            shed: (16, 5),
            expire: (16, 9),
            malformed: (24, 2),
            drop: (24, 17),
            slow: (48, 29),
            fault: (12, 7),
            slow_ms: 5,
        }
    }

    /// Double-density schedule for longer soaks.
    pub fn heavy() -> ChaosSpec {
        ChaosSpec {
            name: "heavy",
            panic: (24, 13),
            shed: (8, 5),
            expire: (8, 1),
            malformed: (12, 2),
            drop: (12, 11),
            // Not (24, 21): every 21 + 24k is ≡ 5 (mod 8), which the
            // higher-priority shed cadence would swallow entirely.
            slow: (24, 22),
            fault: (6, 3),
            slow_ms: 2,
        }
    }

    /// Parse a `chaos=` spelling.
    pub fn parse(s: &str) -> crate::Result<ChaosSpec> {
        match s {
            "off" => Ok(ChaosSpec::off()),
            "default" => Ok(ChaosSpec::default_spec()),
            "heavy" => Ok(ChaosSpec::heavy()),
            other => anyhow::bail!("unknown chaos spec {other:?} (off|default|heavy)"),
        }
    }

    /// The event scheduled for request index `i` (priority order breaks
    /// cadence collisions).
    pub fn event_at(&self, i: u64) -> ChaosEvent {
        let hit = |(period, offset): Cadence| period > 0 && i % period == offset;
        if hit(self.panic) {
            ChaosEvent::Panic
        } else if hit(self.shed) {
            ChaosEvent::Shed
        } else if hit(self.expire) {
            ChaosEvent::Expire
        } else if hit(self.malformed) {
            ChaosEvent::Malformed
        } else if hit(self.drop) {
            ChaosEvent::Drop
        } else if hit(self.slow) {
            ChaosEvent::Slow
        } else if hit(self.fault) {
            ChaosEvent::Fault
        } else {
            ChaosEvent::None
        }
    }
}

/// Per-category verdict totals (each request lands in exactly one).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosCounts {
    /// Rejected at admission (`!overload`).
    pub shed: u64,
    /// Deadline verdicts (`!deadline`).
    pub expired: u64,
    /// Internal-error verdicts (panicked or failed batches).
    pub errored: u64,
    /// Malformed lines rejected by the parser.
    pub parse_errors: u64,
    /// Replies sent into a dropped channel (dead client).
    pub dropped: u64,
    /// Requests that got a winner.
    pub survived: u64,
}

/// Everything one chaos run measures (and `BENCH_chaos.json` records).
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// The chaos schedule name.
    pub chaos: String,
    /// Root seed of the run.
    pub seed: u64,
    /// Worker threads the run used (the transcript must not depend on it).
    pub workers: usize,
    /// Requests driven through the schedule.
    pub requests: usize,
    /// Verdict totals.
    pub counts: ChaosCounts,
    /// Batches that panicked under supervision.
    pub batch_panics: u64,
    /// Workers respawned by the supervisor.
    pub worker_respawns: u64,
    /// Requests that never received their reply (must be 0: the
    /// no-stranded-rider invariant).
    pub stranded: u64,
    /// TSV transcript `id \t entry \t verdict \t detail`, sorted by id —
    /// byte-identical at any worker count.
    pub transcript: String,
}

/// One transcript row (intermediate; rows merge sorted by id).
struct VerdictRow {
    id: u64,
    entry: String,
    verdict: &'static str,
    detail: String,
}

/// Build the deterministically corrupted request line for a
/// [`ChaosEvent::Malformed`] index (corruption mode drawn from the
/// request's own rng stream).
fn corrupt_line(rng: &mut Rng64, id: u64, entry_name: &str, p: usize) -> String {
    let volley: Vec<String> = (0..p).map(|k| (k % 4).to_string()).collect();
    match rng.gen_range(0, 4) {
        0 => format!("x{id} {entry_name} {}", volley.join(",")),
        1 => format!("{id} ghost:9x9 {}", volley.join(",")),
        2 => {
            let mut v = volley;
            let bad = rng.gen_range(0, v.len());
            v[bad] = "zz".to_string();
            format!("{id} {entry_name} {}", v.join(","))
        }
        _ => format!("{id} {entry_name}"),
    }
}

/// Run the chaos soak: drive `spec.requests` scheduled requests through
/// a live server and reduce every outcome to a verdict row. The serve
/// spec's `chaos` key names the schedule (must not be `off`). The
/// occupancy bound is disabled for the run (rule 2 of the module docs);
/// sheds are injector-forced instead.
pub fn run_chaos(spec: &ServeSpec) -> crate::Result<ChaosReport> {
    spec.validate()?;
    let chaos = ChaosSpec::parse(&spec.chaos)?;
    anyhow::ensure!(
        chaos.name != "off",
        "chaos mode needs a schedule: chaos=default|heavy"
    );
    let mut sspec = spec.clone();
    sspec.queue_depth = 0; // occupancy is timing; chaos sheds are forced
    let server = Server::start(&sspec)?;
    let n_entries = server.entries().len();
    let pools: Vec<usize> = server.entries().iter().map(|e| e.queries.len()).collect();
    let names: Vec<String> = server.entries().iter().map(|e| e.name.clone()).collect();
    let gate_entries: Vec<usize> = server
        .entries()
        .iter()
        .enumerate()
        .filter(|(_, e)| e.service.gate_net_count().is_some())
        .map(|(i, _)| i)
        .collect();

    let root = Rng64::seed_from_u64(spec.seed ^ 0xC4A0_55ED);
    let (tx, rx) = mpsc::channel::<Reply>();
    let mut rows: Vec<VerdictRow> = Vec::new(); // verdicts decided injector-side
    let mut expected = 0usize; // replies owed on the shared channel

    for i in 0..spec.requests as u64 {
        let mut rng = root.split_stream(i);
        let mut event = chaos.event_at(i);
        if event == ChaosEvent::Fault && gate_entries.is_empty() {
            event = ChaosEvent::None; // no nets to fault in this registry
        }
        let e = if event == ChaosEvent::Fault {
            gate_entries[i as usize % gate_entries.len()]
        } else {
            i as usize % n_entries
        };
        let qi = rng.gen_range(0, pools[e]);
        let volley = server.entries()[e].queries[qi].clone();
        let mut opts = SubmitOpts::default();
        match event {
            ChaosEvent::Malformed => {
                let line = corrupt_line(&mut rng, i, &names[e], volley.len());
                let err = parse_request(&server, &line)
                    .err()
                    .map_or_else(|| "corrupt line parsed cleanly".to_string(), |e| e.to_string());
                rows.push(VerdictRow {
                    id: i,
                    entry: names[e].clone(),
                    verdict: "parse",
                    detail: err,
                });
                continue;
            }
            ChaosEvent::Drop => {
                // Simulated dead connection: the reply lands in a dropped
                // channel (its send is a no-op the worker must survive).
                let (dtx, drx) = mpsc::channel::<Reply>();
                server.submit(i, e, volley, dtx)?;
                drop(drx);
                rows.push(VerdictRow {
                    id: i,
                    entry: names[e].clone(),
                    verdict: "dropped",
                    detail: "-".to_string(),
                });
                continue;
            }
            ChaosEvent::Shed => opts.force_shed = true,
            ChaosEvent::Expire => opts.deadline = Some(Instant::now()),
            ChaosEvent::Panic => opts.chaos = Some(ChaosAction::Panic),
            ChaosEvent::Slow => {
                opts.chaos = Some(ChaosAction::Slow(Duration::from_millis(chaos.slow_ms)));
            }
            ChaosEvent::Fault => {
                let nets = server.entries()[e]
                    .service
                    .gate_net_count()
                    .expect("gate entry has nets");
                opts.chaos = Some(ChaosAction::Fault(GateFault::StuckAt {
                    net: rng.gen_range(0, nets) as u32,
                    value: rng.gen_range(0, 2) == 1,
                }));
            }
            ChaosEvent::None => {}
        }
        server.submit_with(i, e, volley, tx.clone(), opts)?;
        expected += 1;
    }
    drop(tx);

    // Collect with a hang guard: a stranded rider (the bug class the
    // supervision layer exists to prevent) surfaces as a nonzero
    // `stranded` count instead of a hung run.
    let mut replies: Vec<Reply> = Vec::with_capacity(expected);
    while replies.len() < expected {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(r) => replies.push(r),
            Err(_) => break,
        }
    }
    let stranded = (expected - replies.len()) as u64;

    let mut counts = ChaosCounts {
        parse_errors: rows.iter().filter(|r| r.verdict == "parse").count() as u64,
        dropped: rows.iter().filter(|r| r.verdict == "dropped").count() as u64,
        ..ChaosCounts::default()
    };
    for r in &replies {
        let (verdict, detail) = match &r.outcome {
            Ok(w) => {
                counts.survived += 1;
                (
                    "survived",
                    w.map_or_else(|| "-".to_string(), |i| i.to_string()),
                )
            }
            Err(e @ ServeError::Overload) => {
                counts.shed += 1;
                ("shed", e.to_string())
            }
            Err(e @ ServeError::Deadline) => {
                counts.expired += 1;
                ("expired", e.to_string())
            }
            Err(e @ (ServeError::Parse(_) | ServeError::Internal(_))) => {
                counts.errored += 1;
                ("errored", e.to_string())
            }
        };
        rows.push(VerdictRow {
            id: r.id,
            entry: names[r.entry].clone(),
            verdict,
            detail,
        });
    }
    rows.sort_by_key(|r| r.id);
    let mut transcript = String::new();
    for r in &rows {
        let _ = writeln!(transcript, "{}\t{}\t{}\t{}", r.id, r.entry, r.verdict, r.detail);
    }

    // The panic counter is final once every reply is in (no queued work
    // can panic after its reply); the respawn counter trails it by the
    // supervisor's event handling, so give it a bounded moment to settle.
    let t0 = Instant::now();
    while server.counters().worker_respawns.get() < server.counters().batch_panics.get()
        && t0.elapsed() < Duration::from_secs(10)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let batch_panics = server.counters().batch_panics.get();
    let worker_respawns = server.counters().worker_respawns.get();
    server.shutdown();

    Ok(ChaosReport {
        chaos: chaos.name.to_string(),
        seed: spec.seed,
        workers: spec.workers,
        requests: spec.requests,
        counts,
        batch_panics,
        worker_respawns,
        stranded,
        transcript,
    })
}

/// Print a [`ChaosReport`] summary (the CI smoke greps the `survived`
/// and `stranded` figures from these lines).
pub fn print_chaos_summary(r: &ChaosReport) {
    println!(
        "tnn7 serve chaos: spec {}, seed {}, {} requests, {} workers",
        r.chaos, r.seed, r.requests, r.workers
    );
    println!(
        "verdicts: shed {} expired {} errored {} parse {} dropped {} survived {}",
        r.counts.shed,
        r.counts.expired,
        r.counts.errored,
        r.counts.parse_errors,
        r.counts.dropped,
        r.counts.survived
    );
    println!(
        "supervision: batch panics {}, worker respawns {}, stranded {}",
        r.batch_panics, r.worker_respawns, r.stranded
    );
}

/// JSON payload of a [`ChaosReport`] (`BENCH_chaos.json`).
pub fn chaos_json(r: &ChaosReport) -> Json {
    Json::obj()
        .set("chaos", r.chaos.as_str())
        .set("seed", Json::Int(r.seed as i64))
        .set("workers", r.workers)
        .set("requests", r.requests)
        .set(
            "counts",
            Json::obj()
                .set("shed", Json::Int(r.counts.shed as i64))
                .set("expired", Json::Int(r.counts.expired as i64))
                .set("errored", Json::Int(r.counts.errored as i64))
                .set("parse_errors", Json::Int(r.counts.parse_errors as i64))
                .set("dropped", Json::Int(r.counts.dropped as i64))
                .set("survived", Json::Int(r.counts.survived as i64)),
        )
        .set(
            "supervision",
            Json::obj()
                .set("batch_panics", Json::Int(r.batch_panics as i64))
                .set("worker_respawns", Json::Int(r.worker_respawns as i64)),
        )
        .set("stranded", Json::Int(r.stranded as i64))
}

/// Write `BENCH_chaos.json` and `chaos_transcript.tsv` into `spec`'s
/// `out_dir` (created if missing).
pub fn write_chaos_report(spec: &ServeSpec, r: &ChaosReport) -> crate::Result<()> {
    std::fs::create_dir_all(&spec.out_dir)?;
    std::fs::write(
        spec.out_dir.join("BENCH_chaos.json"),
        chaos_json(r).to_pretty(),
    )?;
    std::fs::write(spec.out_dir.join("chaos_transcript.tsv"), &r.transcript)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_parse_and_cover_every_category_in_a_quick_run() {
        for (name, spec) in [
            ("default", ChaosSpec::default_spec()),
            ("heavy", ChaosSpec::heavy()),
        ] {
            assert_eq!(ChaosSpec::parse(name).unwrap().name, name);
            let mut seen = std::collections::HashMap::new();
            for i in 0..96 {
                *seen.entry(spec.event_at(i)).or_insert(0u32) += 1;
            }
            for ev in [
                ChaosEvent::None,
                ChaosEvent::Panic,
                ChaosEvent::Shed,
                ChaosEvent::Expire,
                ChaosEvent::Malformed,
                ChaosEvent::Drop,
                ChaosEvent::Slow,
                ChaosEvent::Fault,
            ] {
                assert!(
                    seen.get(&ev).copied().unwrap_or(0) >= 2,
                    "{name}: event {ev:?} fires < 2 times in 96 requests: {seen:?}"
                );
            }
        }
        let off = ChaosSpec::parse("off").unwrap();
        assert!((0..1000).all(|i| off.event_at(i) == ChaosEvent::None));
        assert!(ChaosSpec::parse("wat").is_err());
    }

    #[test]
    fn default_schedule_is_frozen() {
        // The committed CI verdict counts depend on these exact indices;
        // changing the cadences is a breaking change to the soak.
        let spec = ChaosSpec::default_spec();
        assert_eq!(spec.event_at(13), ChaosEvent::Panic);
        assert_eq!(spec.event_at(5), ChaosEvent::Shed);
        assert_eq!(spec.event_at(9), ChaosEvent::Expire);
        assert_eq!(spec.event_at(2), ChaosEvent::Malformed);
        assert_eq!(spec.event_at(17), ChaosEvent::Drop);
        assert_eq!(spec.event_at(29), ChaosEvent::Slow);
        assert_eq!(spec.event_at(7), ChaosEvent::Fault);
        assert_eq!(spec.event_at(0), ChaosEvent::None);
        // Collision resolution: 41 hits both expire (41 % 16 == 9) and
        // drop (41 % 24 == 17); expire outranks drop.
        assert_eq!(spec.event_at(41), ChaosEvent::Expire);
    }
}
