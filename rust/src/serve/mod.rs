//! `tnn7 serve` — the always-on dynamic-batching inference service.
//!
//! Every other entry point in the repo is a one-shot batch CLI; this
//! module is the long-lived deployment shape the paper's µW-scale
//! "online sensory processing" story implies: a persistent server that
//! absorbs streams of UCR-style queries and answers each with a WTA
//! winner. The request lifecycle is
//!
//! 1. **arrival** — a client submits `(id, entry, volley)` (over a
//!    line-delimited local socket, a stdin pipe, or in-process from the
//!    bench client); the request is timestamped and queued;
//! 2. **coalesce** — a free worker pops the oldest request and greedily
//!    extracts every queued request for the *same registry entry*, up to
//!    the entry's lane budget (`words × 64`, PR 5's compiled lane blocks
//!    as the batching unit);
//! 3. **lane-block pass** — the batch runs as one compiled-sim pass on
//!    the entry's [`ServiceEngine`](crate::coordinator::ServiceEngine)
//!    (per-request executor scratch over the shared
//!    `OptLevel::Inference` program from the artifact cache);
//! 4. **respond** — each request gets its winner and its end-to-end
//!    latency (queue wait + service time) on its own reply channel.
//!
//! **Determinism rule:** inference is RNG-free on every engine (all-ones
//! uniforms block every STDP case), so a winner depends only on
//! (entry weights, volley) — never on which pass a volley landed in,
//! which worker ran it, or what else shared its lane block. Dynamic
//! batching is therefore *semantics-free*: server winners are bit-exact
//! with sequential `Engine::infer_winner` calls on the same queries,
//! which `run_bench` re-verifies on every run and `tests/serve.rs` pins
//! at 1/2/4 workers.
//!
//! The registry is the engine-cross-geometry product of the spec
//! (mixed-engine, mixed-geometry traffic out of the box), each entry
//! deterministically trained from `seed` via the frozen `split_stream`
//! discipline — so the whole service, including its committed golden
//! transcript, reproduces from the printed seed alone.
//!
//! **Resilience** (the always-on hardening layered over that lifecycle):
//! admission control sheds past `queue_depth` with `!overload`,
//! per-request deadlines reply `!deadline` at dequeue or reply time,
//! every batch runs under `catch_unwind` with the supervisor respawning
//! panicked workers (riders get `!internal`, never a stranded channel),
//! and the socket listener takes a drain signal, a connection cap and
//! per-connection read timeouts. The [`chaos`] module soaks all of it
//! deterministically; `tests/serve.rs` pins the verdict transcript
//! bit-identical at 1/2/4 workers.

mod bench;
pub mod chaos;
mod proto;
mod server;

pub use bench::{
    print_summary, run_bench, serve_json, write_report, EntrySummary, PatternStats,
    ResilienceSnapshot, ServeReport,
};
pub use chaos::{print_chaos_summary, run_chaos, write_chaos_report, ChaosReport, ChaosSpec};
pub use proto::{parse_request, serve_lines, serve_socket, serve_socket_on, SocketConfig};
pub use server::{
    build_entry_engine, ChaosAction, Reply, ServeEntry, ServeError, Server, SubmitOpts,
};

use crate::config::EngineKind;
use crate::util::kv::KvDoc;
use std::path::PathBuf;

/// Client arrival schedule shapes the bench mode drives (`patterns=` key).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// Entries round-robined one query at a time — the coalescer sees a
    /// maximally interleaved (worst-case mixed-geometry) queue.
    Steady,
    /// Seeded same-entry bursts of random length — the coalescer's best
    /// case, exercising full lane blocks.
    Bursty,
    /// Every request's entry and query drawn independently at random —
    /// unstructured mixed-engine traffic.
    Shuffled,
}

impl ArrivalPattern {
    /// Canonical spelling (inverse of [`ArrivalPattern::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            ArrivalPattern::Steady => "steady",
            ArrivalPattern::Bursty => "bursty",
            ArrivalPattern::Shuffled => "shuffled",
        }
    }

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "steady" => Ok(ArrivalPattern::Steady),
            "bursty" => Ok(ArrivalPattern::Bursty),
            "shuffled" => Ok(ArrivalPattern::Shuffled),
            other => anyhow::bail!("unknown arrival pattern {other:?} (steady|bursty|shuffled)"),
        }
    }
}

/// Service configuration (the `tnn7 serve` subcommand's `key=value`
/// surface), following the same kv discipline as
/// [`crate::config::RunConfig`].
#[derive(Clone, Debug)]
pub struct ServeSpec {
    /// Root seed: drives entry weights (via per-entry `split_stream`
    /// lanes), query pools and the bench client's arrival schedules.
    pub seed: u64,
    /// Server worker threads draining the request queue.
    pub workers: usize,
    /// Lane-block width `W` of pooled compiled executors (`W × 64` lanes
    /// per pass = the per-entry coalescing budget).
    pub words: usize,
    /// Settle threads per pooled executor (0 = machine parallelism).
    pub threads: usize,
    /// Engine kinds in the registry (`engines=gate,golden`).
    pub engines: Vec<EngineKind>,
    /// Column geometries in the registry (`geometries=12x2,8x3`); the
    /// registry is the engines × geometries product.
    pub geometries: Vec<(usize, usize)>,
    /// UCR samples per cluster in each entry's training set / query pool.
    pub per_cluster: usize,
    /// Requests the bench client sends per arrival pattern.
    pub requests: usize,
    /// Arrival patterns the bench mode sweeps.
    pub patterns: Vec<ArrivalPattern>,
    /// Artifact-cache capacity override (0 = keep the global defaults);
    /// applied to the design cache, with 2× for the program cache.
    pub capacity: usize,
    /// Admission bound: queued requests beyond this are shed with an
    /// `!overload` reply (0 = unbounded). The default is far above the
    /// bench client's burst sizes, so shedding never perturbs the
    /// committed golden transcript.
    pub queue_depth: usize,
    /// Per-request deadline budget in ms stamped on pipe/socket
    /// submissions (0 = no deadline). Expired requests reply
    /// `!deadline`. Bench mode ignores it (the flood client would
    /// expire its own differential).
    pub deadline_ms: u64,
    /// Concurrent socket connections accepted before new clients get an
    /// immediate `!overload` and a close.
    pub max_connections: usize,
    /// Per-connection socket read timeout in ms; a client that sends
    /// nothing for this long is disconnected (0 = no timeout).
    pub read_timeout_ms: u64,
    /// Chaos schedule name (`off`/`default`/`heavy`); anything but `off`
    /// switches `tnn7 serve` into the chaos-soak mode.
    pub chaos: String,
    /// Output directory for `BENCH_serve.json` + `serve_transcript.tsv`.
    pub out_dir: PathBuf,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            seed: 7,
            workers: 2,
            words: crate::gates::DEFAULT_SIM_WORDS,
            threads: 1,
            engines: vec![EngineKind::Gate, EngineKind::Golden],
            geometries: vec![(12, 2), (8, 3)],
            per_cluster: 8,
            requests: 400,
            patterns: vec![
                ArrivalPattern::Steady,
                ArrivalPattern::Bursty,
                ArrivalPattern::Shuffled,
            ],
            capacity: 0,
            queue_depth: 1024,
            deadline_ms: 0,
            max_connections: 32,
            read_timeout_ms: 5000,
            chaos: "off".to_string(),
            out_dir: PathBuf::from("."),
        }
    }
}

impl ServeSpec {
    /// CI-speed service: the full mixed-engine × mixed-geometry registry
    /// on a small request budget (also the committed golden transcript's
    /// configuration — keep them in lockstep).
    pub fn quick() -> Self {
        ServeSpec {
            words: 1,
            per_cluster: 4,
            requests: 96,
            ..ServeSpec::default()
        }
    }

    /// Load from a kv doc; missing keys keep defaults.
    pub fn from_kv(doc: &KvDoc) -> crate::Result<Self> {
        let mut c = ServeSpec::default();
        if let Some(v) = doc.get_u64("seed")? {
            c.seed = v;
        }
        if let Some(v) = doc.get_usize("workers")? {
            c.workers = v;
        }
        if let Some(v) = doc.get_usize("words")? {
            c.words = v;
        }
        if let Some(v) = doc.get_usize("threads")? {
            c.threads = v;
        }
        if let Some(v) = doc.get("engines") {
            c.engines = v
                .split(',')
                .map(|s| EngineKind::parse(s.trim()))
                .collect::<crate::Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.get("geometries") {
            c.geometries = v
                .split(',')
                .map(parse_geometry)
                .collect::<crate::Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.get_usize("per_cluster")? {
            c.per_cluster = v;
        }
        if let Some(v) = doc.get_usize("requests")? {
            c.requests = v;
        }
        if let Some(v) = doc.get("patterns") {
            c.patterns = v
                .split(',')
                .map(|s| ArrivalPattern::parse(s.trim()))
                .collect::<crate::Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.get_usize("capacity")? {
            c.capacity = v;
        }
        if let Some(v) = doc.get_usize("queue_depth")? {
            c.queue_depth = v;
        }
        if let Some(v) = doc.get_u64("deadline_ms")? {
            c.deadline_ms = v;
        }
        if let Some(v) = doc.get_usize("max_connections")? {
            c.max_connections = v;
        }
        if let Some(v) = doc.get_u64("read_timeout_ms")? {
            c.read_timeout_ms = v;
        }
        if let Some(v) = doc.get("chaos") {
            c.chaos = v.to_string();
        }
        if let Some(v) = doc.get("out_dir") {
            c.out_dir = PathBuf::from(v);
        }
        c.validate()?;
        Ok(c)
    }

    /// Apply `key=value` CLI overrides.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> crate::Result<()> {
        let mut doc = KvDoc::default();
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override must be key=value: {o}"))?;
            doc.set(k.trim(), v.trim());
        }
        let merged = Self::from_kv(&doc)?;
        // from_kv starts from defaults; re-apply only the overridden keys.
        for key in doc.keys() {
            match key {
                "seed" => self.seed = merged.seed,
                "workers" => self.workers = merged.workers,
                "words" => self.words = merged.words,
                "threads" => self.threads = merged.threads,
                "engines" => self.engines = merged.engines.clone(),
                "geometries" => self.geometries = merged.geometries.clone(),
                "per_cluster" => self.per_cluster = merged.per_cluster,
                "requests" => self.requests = merged.requests,
                "patterns" => self.patterns = merged.patterns.clone(),
                "capacity" => self.capacity = merged.capacity,
                "queue_depth" => self.queue_depth = merged.queue_depth,
                "deadline_ms" => self.deadline_ms = merged.deadline_ms,
                "max_connections" => self.max_connections = merged.max_connections,
                "read_timeout_ms" => self.read_timeout_ms = merged.read_timeout_ms,
                "chaos" => self.chaos = merged.chaos.clone(),
                "out_dir" => self.out_dir = merged.out_dir.clone(),
                other => anyhow::bail!("unknown serve key {other:?}"),
            }
        }
        self.validate()
    }

    /// Sanity-check the configuration.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.workers >= 1, "workers must be >= 1");
        anyhow::ensure!(
            (1..=64).contains(&self.words),
            "words must be in 1..=64"
        );
        anyhow::ensure!(!self.engines.is_empty(), "engines must be non-empty");
        anyhow::ensure!(
            !self.engines.contains(&EngineKind::Xla),
            "the XLA engine cannot be served (device-side state)"
        );
        anyhow::ensure!(!self.geometries.is_empty(), "geometries must be non-empty");
        for &(p, q) in &self.geometries {
            anyhow::ensure!(p >= 1 && q >= 1, "geometry {p}x{q} must have p,q >= 1");
        }
        anyhow::ensure!(self.per_cluster >= 2, "per_cluster must be >= 2");
        anyhow::ensure!(self.requests >= 1, "requests must be >= 1");
        anyhow::ensure!(!self.patterns.is_empty(), "patterns must be non-empty");
        anyhow::ensure!(
            self.max_connections >= 1,
            "max_connections must be >= 1"
        );
        chaos::ChaosSpec::parse(&self.chaos)?;
        Ok(())
    }
}

/// Parse one `PxQ` geometry spelling (e.g. `12x2`).
fn parse_geometry(s: &str) -> crate::Result<(usize, usize)> {
    let (p, q) = s
        .trim()
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("bad geometry {s:?} (want PxQ, e.g. 12x2)"))?;
    Ok((
        p.parse()
            .map_err(|_| anyhow::anyhow!("bad geometry p in {s:?}"))?,
        q.parse()
            .map_err(|_| anyhow::anyhow!("bad geometry q in {s:?}"))?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_defaults_and_quick_are_valid() {
        ServeSpec::default().validate().unwrap();
        ServeSpec::quick().validate().unwrap();
    }

    #[test]
    fn spec_overrides_roundtrip_and_reject_unknown_keys() {
        let mut s = ServeSpec::quick();
        s.apply_overrides(&[
            "seed=9".into(),
            "workers=4".into(),
            "engines=golden".into(),
            "geometries=4x2,6x3".into(),
            "patterns=bursty".into(),
            "capacity=8".into(),
            "queue_depth=16".into(),
            "deadline_ms=250".into(),
            "max_connections=4".into(),
            "read_timeout_ms=900".into(),
            "chaos=default".into(),
            "out_dir=target/serve".into(),
        ])
        .unwrap();
        assert_eq!(s.seed, 9);
        assert_eq!(s.workers, 4);
        assert_eq!(s.engines, vec![EngineKind::Golden]);
        assert_eq!(s.geometries, vec![(4, 2), (6, 3)]);
        assert_eq!(s.patterns, vec![ArrivalPattern::Bursty]);
        assert_eq!(s.capacity, 8);
        assert_eq!(s.queue_depth, 16);
        assert_eq!(s.deadline_ms, 250);
        assert_eq!(s.max_connections, 4);
        assert_eq!(s.read_timeout_ms, 900);
        assert_eq!(s.chaos, "default");
        assert_eq!(s.out_dir, PathBuf::from("target/serve"));
        s.apply_overrides(&["chaos=off".into()]).unwrap();
        assert_eq!(
            s.requests,
            ServeSpec::quick().requests,
            "non-overridden keys keep quick values"
        );
        let err = s.apply_overrides(&["bogus=1".into()]).unwrap_err();
        assert!(err.to_string().contains("unknown serve key"));
        let err = s.apply_overrides(&["geometries=12".into()]).unwrap_err();
        assert!(err.to_string().contains("bad geometry"));
        let err = s.apply_overrides(&["engines=xla".into()]).unwrap_err();
        assert!(err.to_string().contains("cannot be served"));
        let err = s.apply_overrides(&["patterns=diurnal".into()]).unwrap_err();
        assert!(err.to_string().contains("unknown arrival pattern"));
        let err = s.apply_overrides(&["chaos=mayhem".into()]).unwrap_err();
        assert!(err.to_string().contains("unknown chaos spec"));
        let err = s.apply_overrides(&["max_connections=0".into()]).unwrap_err();
        assert!(err.to_string().contains("max_connections"));
    }

    #[test]
    fn arrival_pattern_names_roundtrip() {
        for p in [
            ArrivalPattern::Steady,
            ArrivalPattern::Bursty,
            ArrivalPattern::Shuffled,
        ] {
            assert_eq!(ArrivalPattern::parse(p.name()).unwrap(), p);
        }
    }
}
