//! The wire protocol: line-delimited requests over stdin (CI pipe mode)
//! or a local TCP socket.
//!
//! Request line:  `<id> <entry> <volley>`
//!   * `id` — client-chosen u64, echoed verbatim in the reply;
//!   * `entry` — registry wire name, `<engine>:<p>x<q>` (e.g. `gate:12x2`);
//!   * `volley` — `p` comma-separated spike times (`-` = no spike on
//!     that line), e.g. `1,-,2,0`.
//!
//! Reply line:  `<id> <winner>` where `winner` is the WTA neuron index or
//! `-` when no neuron fired; a failed request replies `<id> !<error>`
//! (typed: `!overload`, `!deadline`, `!parse: …`, `!internal: …`).
//!
//! **Malformed lines never kill a stream**: a line that fails to parse
//! replies `<id> !parse: <error>` when the id token is recoverable, or a
//! bare `!parse` line when it is not, and the connection stays alive —
//! one garbled client line can't take down the exchange.
//!
//! Replies are emitted sorted by request id (id-less `!parse` lines
//! first, in input order), so the output byte stream is identical at any
//! worker count — the property the CI smoke pins by diffing 1/2/4-worker
//! transcripts.
//!
//! **Socket hardening** ([`serve_socket`]): a drain signal (set by the
//! `!drain` control line, or programmatically in lieu of SIGINT — this
//! build vendors no signal-handling crate) stops the accept loop, lets
//! every open connection flush its in-flight replies, and joins the
//! connection threads; a concurrent-connection cap answers excess
//! clients `!overload` and closes them; per-connection read timeouts
//! disconnect clients that stall mid-stream so one slow peer can't pin a
//! scoped thread forever.

use super::server::{Reply, ServeError, Server, SubmitOpts};
use super::ServeSpec;
use crate::tnn::spike::SpikeTime;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Parse one request line against `server`'s registry. Returns
/// `(id, entry index, volley)`.
pub fn parse_request(
    server: &Server,
    line: &str,
) -> crate::Result<(u64, usize, Vec<SpikeTime>)> {
    let mut parts = line.split_whitespace();
    let id: u64 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("bad request id in {line:?}"))?;
    let entry_name = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request {id}: missing entry name"))?;
    let entry = server
        .entry_index(entry_name)
        .ok_or_else(|| anyhow::anyhow!("request {id}: unknown entry {entry_name:?}"))?;
    let volley_text = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request {id}: missing volley"))?;
    anyhow::ensure!(
        parts.next().is_none(),
        "request {id}: trailing tokens after volley"
    );
    let volley = volley_text
        .split(',')
        .map(|t| {
            if t == "-" {
                Ok(SpikeTime::NONE)
            } else {
                t.parse::<u32>()
                    .map(SpikeTime::at)
                    .map_err(|_| anyhow::anyhow!("request {id}: bad spike time {t:?}"))
            }
        })
        .collect::<crate::Result<Vec<_>>>()?;
    Ok((id, entry, volley))
}

/// Render one reply line (without the trailing newline).
fn format_reply(r: &Reply) -> String {
    match &r.outcome {
        Ok(Some(w)) => format!("{} {w}", r.id),
        Ok(None) => format!("{} -", r.id),
        Err(e) => format!("{} !{e}", r.id),
    }
}

/// Recover the id token from a line that failed to parse (so the error
/// reply can still be addressed to it).
fn recover_id(line: &str) -> Option<u64> {
    line.split_whitespace().next()?.parse().ok()
}

/// Per-exchange line intake shared by the pipe and socket paths: feeds
/// well-formed lines to the server, converts malformed ones into local
/// `!parse` replies, and flushes everything id-sorted at the end.
struct LineSink {
    tx: mpsc::Sender<Reply>,
    /// Parse-failure replies with a recoverable id (merged into the
    /// id-sorted output).
    local: Vec<Reply>,
    /// Bare `!parse` lines for id-less garbage, kept in input order.
    noid: Vec<String>,
    submitted: u64,
}

impl LineSink {
    fn new(tx: mpsc::Sender<Reply>) -> LineSink {
        LineSink {
            tx,
            local: Vec::new(),
            noid: Vec::new(),
            submitted: 0,
        }
    }

    fn parse_reply(id: u64, msg: String) -> Reply {
        Reply {
            id,
            entry: usize::MAX, // never reached the registry
            outcome: Err(ServeError::Parse(msg)),
            latency: Duration::ZERO,
            batch: 0,
        }
    }

    /// Handle one raw input line (blank and `#` comment lines are
    /// skipped). A malformed line becomes a local parse reply; the
    /// stream stays alive.
    fn handle(&mut self, server: &Server, line: &str, deadline: Option<Duration>) {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            return;
        }
        match parse_request(server, t) {
            Ok((id, entry, volley)) => {
                let opts = SubmitOpts {
                    deadline: deadline.map(|d| Instant::now() + d),
                    ..SubmitOpts::default()
                };
                match server.submit_with(id, entry, volley, self.tx.clone(), opts) {
                    Ok(_) => self.submitted += 1,
                    // Post-parse rejections (e.g. volley length != p) are
                    // still client-side defects of this one request.
                    Err(e) => self.local.push(Self::parse_reply(id, e.to_string())),
                }
            }
            Err(e) => match recover_id(t) {
                Some(id) => self.local.push(Self::parse_reply(id, e.to_string())),
                None => self.noid.push("!parse".to_string()),
            },
        }
    }

    /// Await every in-flight reply, merge the local parse replies, and
    /// write the exchange's output: id-less `!parse` lines first (input
    /// order), then one reply line per request sorted by id. Returns the
    /// number of lines answered.
    fn finish(self, rx: mpsc::Receiver<Reply>, mut writer: impl Write) -> crate::Result<u64> {
        let LineSink {
            tx,
            mut local,
            noid,
            submitted,
        } = self;
        // Our clone of the sender is gone; the channel closes once every
        // in-flight request has replied.
        drop(tx);
        let mut replies: Vec<Reply> = rx.iter().collect();
        debug_assert_eq!(replies.len() as u64, submitted);
        replies.append(&mut local);
        replies.sort_by_key(|r| r.id);
        for line in &noid {
            writeln!(writer, "{line}")?;
        }
        for r in &replies {
            writeln!(writer, "{}", format_reply(r))?;
        }
        writer.flush()?;
        Ok(replies.len() as u64 + noid.len() as u64)
    }
}

/// Pipe mode: read request lines from `reader` until EOF, serve them all
/// through `server`, and write one reply line per request to `writer`,
/// sorted by request id (byte-stable at any worker count). Returns the
/// number of lines answered (served + parse failures). Blank lines and
/// `#` comments are skipped; malformed lines get `!parse` replies
/// without killing the stream. `deadline_ms > 0` stamps every request
/// with a deadline that far in the future.
pub fn serve_lines(
    server: &Server,
    reader: impl BufRead,
    writer: impl Write,
    deadline_ms: u64,
) -> crate::Result<u64> {
    let deadline = (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms));
    let (tx, rx) = mpsc::channel();
    let mut sink = LineSink::new(tx);
    for line in reader.lines() {
        sink.handle(server, &line?, deadline);
    }
    sink.finish(rx, writer)
}

/// Socket-mode hardening knobs (see [`ServeSpec`] for the kv surface).
#[derive(Clone, Debug)]
pub struct SocketConfig {
    /// Concurrent connections served before new clients are answered
    /// `!overload` and closed.
    pub max_connections: usize,
    /// Per-connection read timeout; a client silent for this long is
    /// disconnected (its in-flight replies still flush).
    /// `Duration::ZERO` = no timeout (a stalled client then also stalls
    /// drain for its connection — prefer a finite timeout).
    pub read_timeout: Duration,
    /// Per-request deadline budget in ms stamped on socket submissions
    /// (0 = none).
    pub deadline_ms: u64,
}

impl SocketConfig {
    /// Lift the socket knobs out of a [`ServeSpec`].
    pub fn from_spec(spec: &ServeSpec) -> SocketConfig {
        SocketConfig {
            max_connections: spec.max_connections.max(1),
            read_timeout: Duration::from_millis(spec.read_timeout_ms),
            deadline_ms: spec.deadline_ms,
        }
    }
}

/// One connection's exchange: read lines until EOF, a read timeout, or
/// drain; then flush the id-sorted replies and close. The `!drain`
/// control line initiates a server-wide graceful drain (the socket
/// stand-in for SIGINT: no signal-handling crate is vendored).
fn serve_connection(
    server: &Server,
    stream: TcpStream,
    drain: &AtomicBool,
    cfg: &SocketConfig,
) -> crate::Result<u64> {
    if cfg.read_timeout > Duration::ZERO {
        stream.set_read_timeout(Some(cfg.read_timeout))?;
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let deadline = (cfg.deadline_ms > 0).then(|| Duration::from_millis(cfg.deadline_ms));
    let (tx, rx) = mpsc::channel();
    let mut sink = LineSink::new(tx);
    let mut buf = String::new();
    loop {
        if drain.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_line(&mut buf) {
            Ok(0) => break, // EOF
            Ok(_) => {
                if buf.trim() == "!drain" {
                    drain.store(true, Ordering::Relaxed);
                    break;
                }
                sink.handle(server, &buf, deadline);
                buf.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Idle past the read timeout: disconnect the slow client
                // (any partial line it sent stays unanswered; its
                // completed requests flush below).
                break;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                // Hard connection error: still flush what we owe.
                let _ = sink.finish(rx, &stream);
                return Err(e.into());
            }
        }
    }
    sink.finish(rx, &stream)
}

/// Socket mode on an already-bound listener (separated from
/// [`serve_socket`] so tests can bind port 0 and learn the address).
/// Serves until `drain` is set — by a client's `!drain` control line or
/// externally — then stops accepting, lets every open connection flush
/// its in-flight replies, and joins the connection threads before
/// returning. See [`SocketConfig`] for the cap/timeout knobs.
pub fn serve_socket_on(
    server: &Server,
    listener: TcpListener,
    drain: &AtomicBool,
    cfg: &SocketConfig,
) -> crate::Result<()> {
    listener.set_nonblocking(true)?;
    eprintln!(
        "tnn7 serve: listening on {} ({} registry entries, {} connection cap)",
        listener.local_addr()?,
        server.entries().len(),
        cfg.max_connections,
    );
    let live = AtomicUsize::new(0);
    std::thread::scope(|scope| -> crate::Result<()> {
        loop {
            if drain.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((mut stream, _)) => {
                    if live.load(Ordering::Relaxed) >= cfg.max_connections {
                        // Shed the connection itself: reply and close
                        // without spending a thread on it.
                        let _ = writeln!(stream, "!overload");
                        continue;
                    }
                    live.fetch_add(1, Ordering::Relaxed);
                    let live = &live;
                    scope.spawn(move || {
                        if let Err(e) = serve_connection(server, stream, drain, cfg) {
                            eprintln!("tnn7 serve: connection error: {e}");
                        }
                        live.fetch_sub(1, Ordering::Relaxed);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // Nonblocking accept poll: this is what keeps the
                    // loop responsive to the drain signal.
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => anyhow::bail!("accept failed: {e}"),
            }
        }
        eprintln!("tnn7 serve: draining ({} connections open)", live.load(Ordering::Relaxed));
        Ok(())
        // Scope exit joins every connection thread: each breaks out of
        // its read loop at the next timeout tick (or EOF) once drain is
        // set, flushes its replies, and returns.
    })
}

/// Socket mode: bind `addr` (e.g. `127.0.0.1:7411`) and serve via
/// [`serve_socket_on`] until drained.
pub fn serve_socket(
    server: &Server,
    addr: &str,
    drain: &AtomicBool,
    cfg: &SocketConfig,
) -> crate::Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("cannot bind {addr}: {e}"))?;
    serve_socket_on(server, listener, drain, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::serve::ServeSpec;

    fn spec() -> ServeSpec {
        let mut s = ServeSpec::quick();
        s.engines = vec![EngineKind::Golden];
        s.geometries = vec![(4, 2)];
        s.per_cluster = 2;
        s.words = 1;
        s
    }

    #[test]
    fn parse_request_accepts_the_wire_format_and_rejects_garbage() {
        let server = Server::start(&spec()).unwrap();
        let (id, entry, volley) = parse_request(&server, "7 golden:4x2 1,-,2,0").unwrap();
        assert_eq!(id, 7);
        assert_eq!(entry, 0);
        assert_eq!(
            volley,
            vec![
                SpikeTime::at(1),
                SpikeTime::NONE,
                SpikeTime::at(2),
                SpikeTime::at(0)
            ]
        );
        for bad in [
            "x golden:4x2 1,-,2,0",
            "7 gate:9x9 1,-,2,0",
            "7 golden:4x2 1,-,zz,0",
            "7 golden:4x2",
            "7 golden:4x2 1,-,2,0 extra",
        ] {
            assert!(parse_request(&server, bad).is_err(), "accepted {bad:?}");
        }
        server.shutdown();
    }

    #[test]
    fn serve_lines_replies_in_id_order_with_comments_skipped() {
        let server = Server::start(&spec()).unwrap();
        let input = "# smoke\n5 golden:4x2 1,-,2,0\n\n2 golden:4x2 0,0,0,0\n9 golden:4x2 -,-,-,-\n";
        let mut out = Vec::new();
        let n = serve_lines(&server, input.as_bytes(), &mut out, 0).unwrap();
        assert_eq!(n, 3);
        let text = String::from_utf8(out).unwrap();
        let ids: Vec<&str> = text
            .lines()
            .map(|l| l.split_whitespace().next().unwrap())
            .collect();
        assert_eq!(ids, ["2", "5", "9"], "replies sorted by id:\n{text}");
        // The all-silent volley cannot fire any neuron.
        assert!(text.lines().any(|l| l == "9 -"), "{text}");
        server.shutdown();
    }

    #[test]
    fn malformed_lines_get_parse_replies_and_never_kill_the_stream() {
        let server = Server::start(&spec()).unwrap();
        // One good line sandwiched between every malformed shape: a bad
        // volley token, an unknown entry, a wrong-length volley (passes
        // the parser, rejected at submit), and id-less garbage.
        let input = "\
3 golden:4x2 1,-,zz,0
1 golden:4x2 0,0,0,0
4 ghost:9x9 0,0,0,0
5 golden:4x2 1,2
!!! total garbage
";
        let mut out = Vec::new();
        let n = serve_lines(&server, input.as_bytes(), &mut out, 0).unwrap();
        assert_eq!(n, 5, "every line is answered");
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "!parse", "id-less garbage leads, bare !parse");
        assert!(lines[1].starts_with("1 "), "good line served: {text}");
        assert!(!lines[1].contains('!'), "good line has a winner: {text}");
        assert!(
            lines[2].starts_with("3 !parse: ") && lines[2].contains("bad spike time"),
            "{text}"
        );
        assert!(
            lines[3].starts_with("4 !parse: ") && lines[3].contains("unknown entry"),
            "{text}"
        );
        assert!(
            lines[4].starts_with("5 !parse: ") && lines[4].contains("volley length"),
            "{text}"
        );
        server.shutdown();
    }

    #[test]
    fn socket_serves_caps_connections_and_drains_gracefully() {
        use std::io::{BufRead, BufReader, Write};
        let server = Server::start(&spec()).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let drain = AtomicBool::new(false);
        let cfg = SocketConfig {
            max_connections: 1,
            read_timeout: Duration::from_millis(50),
            deadline_ms: 0,
        };
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| serve_socket_on(&server, listener, &drain, &cfg));
            // Connection 1: a request plus garbage, then EOF.
            let mut c1 = std::net::TcpStream::connect(addr).unwrap();
            c1.write_all(b"8 golden:4x2 1,-,2,0\nnot a request\n").unwrap();
            // Connection 2 while c1 is still open: over the cap.
            // (c1 is accepted first: connect() completed its handshake
            // before c2's SYN, and accept drains in arrival order.)
            let c2 = std::net::TcpStream::connect(addr).unwrap();
            let mut r2 = BufReader::new(c2);
            let mut line = String::new();
            r2.read_line(&mut line).unwrap();
            assert_eq!(line.trim(), "!overload", "capped connection is shed");
            // c1's exchange completes: EOF ends the read loop, replies
            // flush sorted (bare !parse first).
            c1.shutdown(std::net::Shutdown::Write).unwrap();
            let mut r1 = BufReader::new(c1);
            let mut out = String::new();
            r1.read_line(&mut out).unwrap();
            assert_eq!(out.trim(), "!parse");
            out.clear();
            r1.read_line(&mut out).unwrap();
            assert!(out.starts_with("8 "), "served reply: {out}");
            // Graceful drain: the accept loop exits and joins.
            drain.store(true, Ordering::Relaxed);
            handle.join().unwrap().unwrap();
        });
        server.shutdown();
    }
}
