//! The wire protocol: line-delimited requests over stdin (CI pipe mode)
//! or a local TCP socket.
//!
//! Request line:  `<id> <entry> <volley>`
//!   * `id` — client-chosen u64, echoed verbatim in the reply;
//!   * `entry` — registry wire name, `<engine>:<p>x<q>` (e.g. `gate:12x2`);
//!   * `volley` — `p` comma-separated spike times (`-` = no spike on
//!     that line), e.g. `1,-,2,0`.
//!
//! Reply line:  `<id> <winner>` where `winner` is the WTA neuron index or
//! `-` when no neuron fired; a failed request replies `<id> !<error>`.
//!
//! Replies are emitted sorted by request id, so the output byte stream is
//! identical at any worker count — the property the CI smoke pins by
//! diffing 1/2/4-worker transcripts.

use super::server::{Reply, Server};
use crate::tnn::spike::SpikeTime;
use std::io::{BufRead, Write};
use std::sync::mpsc;

/// Parse one request line against `server`'s registry. Returns
/// `(id, entry index, volley)`.
pub fn parse_request(
    server: &Server,
    line: &str,
) -> crate::Result<(u64, usize, Vec<SpikeTime>)> {
    let mut parts = line.split_whitespace();
    let id: u64 = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("bad request id in {line:?}"))?;
    let entry_name = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request {id}: missing entry name"))?;
    let entry = server
        .entry_index(entry_name)
        .ok_or_else(|| anyhow::anyhow!("request {id}: unknown entry {entry_name:?}"))?;
    let volley_text = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request {id}: missing volley"))?;
    anyhow::ensure!(
        parts.next().is_none(),
        "request {id}: trailing tokens after volley"
    );
    let volley = volley_text
        .split(',')
        .map(|t| {
            if t == "-" {
                Ok(SpikeTime::NONE)
            } else {
                t.parse::<u32>()
                    .map(SpikeTime::at)
                    .map_err(|_| anyhow::anyhow!("request {id}: bad spike time {t:?}"))
            }
        })
        .collect::<crate::Result<Vec<_>>>()?;
    Ok((id, entry, volley))
}

/// Render one reply line (without the trailing newline).
fn format_reply(r: &Reply) -> String {
    match &r.outcome {
        Ok(Some(w)) => format!("{} {w}", r.id),
        Ok(None) => format!("{} -", r.id),
        Err(e) => format!("{} !{e}", r.id),
    }
}

/// Pipe mode: read request lines from `reader` until EOF, serve them all
/// through `server`, and write one reply line per request to `writer`,
/// sorted by request id (byte-stable at any worker count). Returns the
/// number of requests served. Blank lines and `#` comments are skipped;
/// a malformed line fails the whole stream (the pipe is a CI artifact,
/// not untrusted input).
pub fn serve_lines(
    server: &Server,
    reader: impl BufRead,
    mut writer: impl Write,
) -> crate::Result<u64> {
    let (tx, rx) = mpsc::channel();
    let mut submitted = 0u64;
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let (id, entry, volley) = parse_request(server, t)?;
        server.submit(id, entry, volley, tx.clone())?;
        submitted += 1;
    }
    // Our clone of the sender is gone; the channel closes once every
    // in-flight request has replied.
    drop(tx);
    let mut replies: Vec<Reply> = rx.iter().collect();
    debug_assert_eq!(replies.len() as u64, submitted);
    replies.sort_by_key(|r| r.id);
    for r in &replies {
        writeln!(writer, "{}", format_reply(r))?;
    }
    writer.flush()?;
    Ok(submitted)
}

/// Socket mode: bind `addr` (e.g. `127.0.0.1:7411`) and serve forever,
/// one [`serve_lines`] exchange per connection (concurrent connections
/// each get their own thread; they share the server's worker pool and
/// coalesce into each other's lane blocks). Never returns except on a
/// bind/accept error.
pub fn serve_socket(server: &Server, addr: &str) -> crate::Result<()> {
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("cannot bind {addr}: {e}"))?;
    eprintln!(
        "tnn7 serve: listening on {} ({} registry entries)",
        listener.local_addr()?,
        server.entries().len(),
    );
    std::thread::scope(|scope| -> crate::Result<()> {
        for conn in listener.incoming() {
            let stream = conn?;
            scope.spawn(move || {
                let reader = match stream.try_clone() {
                    Ok(s) => std::io::BufReader::new(s),
                    Err(e) => {
                        eprintln!("tnn7 serve: connection clone failed: {e}");
                        return;
                    }
                };
                if let Err(e) = serve_lines(server, reader, &stream) {
                    eprintln!("tnn7 serve: connection error: {e}");
                }
            });
        }
        Ok(())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::serve::ServeSpec;

    fn spec() -> ServeSpec {
        let mut s = ServeSpec::quick();
        s.engines = vec![EngineKind::Golden];
        s.geometries = vec![(4, 2)];
        s.per_cluster = 2;
        s.words = 1;
        s
    }

    #[test]
    fn parse_request_accepts_the_wire_format_and_rejects_garbage() {
        let server = Server::start(&spec()).unwrap();
        let (id, entry, volley) = parse_request(&server, "7 golden:4x2 1,-,2,0").unwrap();
        assert_eq!(id, 7);
        assert_eq!(entry, 0);
        assert_eq!(
            volley,
            vec![
                SpikeTime::at(1),
                SpikeTime::NONE,
                SpikeTime::at(2),
                SpikeTime::at(0)
            ]
        );
        for bad in [
            "x golden:4x2 1,-,2,0",
            "7 gate:9x9 1,-,2,0",
            "7 golden:4x2 1,-,zz,0",
            "7 golden:4x2",
            "7 golden:4x2 1,-,2,0 extra",
        ] {
            assert!(parse_request(&server, bad).is_err(), "accepted {bad:?}");
        }
        server.shutdown();
    }

    #[test]
    fn serve_lines_replies_in_id_order_with_comments_skipped() {
        let server = Server::start(&spec()).unwrap();
        let input = "# smoke\n5 golden:4x2 1,-,2,0\n\n2 golden:4x2 0,0,0,0\n9 golden:4x2 -,-,-,-\n";
        let mut out = Vec::new();
        let n = serve_lines(&server, input.as_bytes(), &mut out).unwrap();
        assert_eq!(n, 3);
        let text = String::from_utf8(out).unwrap();
        let ids: Vec<&str> = text
            .lines()
            .map(|l| l.split_whitespace().next().unwrap())
            .collect();
        assert_eq!(ids, ["2", "5", "9"], "replies sorted by id:\n{text}");
        // The all-silent volley cannot fire any neuron.
        assert!(text.lines().any(|l| l == "9 -"), "{text}");
        server.shutdown();
    }
}
