//! The serving core: the deterministic entry registry, the shared request
//! queue, and the worker pool that coalesces arrivals into lane-block
//! passes (the module-level docs in [`super`] walk the request lifecycle).

use super::ServeSpec;
use crate::config::EngineKind;
use crate::coordinator::{encode_ucr, ucr_engine_with, Engine, ServiceEngine};
use crate::gates::wordsim::LANES;
use crate::tnn::params::TnnParams;
use crate::tnn::spike::SpikeTime;
use crate::ucr::{self, UcrConfig};
use crate::util::Rng64;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One registry entry: a frozen [`ServiceEngine`] plus the seeded query
/// pool clients draw from. Addressed by `name` (`<engine>:<p>x<q>`).
pub struct ServeEntry {
    /// Wire name, `<engine>:<p>x<q>` (e.g. `gate:12x2`).
    pub name: String,
    /// Engine kind serving this entry.
    pub kind: EngineKind,
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons (= clusters) in the column.
    pub q: usize,
    /// The `Send + Sync` inference handle requests run on.
    pub service: ServiceEngine,
    /// Seeded query pool (encoded UCR volleys) for bench/smoke clients.
    pub queries: Vec<Vec<SpikeTime>>,
    /// Coalescing budget: requests per lane-block pass (`words × 64`).
    pub max_batch: usize,
}

/// One answered request.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Echo of the client's request id.
    pub id: u64,
    /// Registry index the request ran on.
    pub entry: usize,
    /// The WTA winner (`Ok(None)` = no neuron fired), or the service
    /// error (e.g. a memoized program-build failure).
    pub outcome: Result<Option<usize>, String>,
    /// End-to-end latency: queue wait + lane-block service time.
    pub latency: Duration,
    /// Size of the coalesced pass this request rode in.
    pub batch: usize,
}

/// A queued request (internal; built by [`Server::submit`]).
struct Request {
    id: u64,
    entry: usize,
    volley: Vec<SpikeTime>,
    t0: Instant,
    tx: mpsc::Sender<Reply>,
}

/// Queue state under the mutex: the pending requests plus the open flag
/// (inside the lock so shutdown can't race a worker's wait).
struct QueueState {
    queue: VecDeque<Request>,
    open: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
    batches: AtomicU64,
    coalesced: AtomicU64,
}

fn lock_state(shared: &Shared) -> std::sync::MutexGuard<'_, QueueState> {
    shared.state.lock().unwrap_or_else(|p| p.into_inner())
}

/// The always-on inference server: a deterministic entry registry, one
/// shared FIFO request queue, and `workers` draining threads that batch
/// same-entry arrivals into lane-block passes.
pub struct Server {
    entries: Arc<Vec<ServeEntry>>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Build the registry from `spec` and start the worker pool.
    pub fn start(spec: &ServeSpec) -> crate::Result<Server> {
        let entries = Arc::new(build_entries(spec)?);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                open: true,
            }),
            cv: Condvar::new(),
            batches: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
        });
        let workers = (0..spec.workers.max(1))
            .map(|_| {
                let entries = entries.clone();
                let shared = shared.clone();
                std::thread::spawn(move || worker_loop(&entries, &shared))
            })
            .collect();
        Ok(Server {
            entries,
            shared,
            workers,
        })
    }

    /// The registry, in construction order (engines × geometries).
    pub fn entries(&self) -> &[ServeEntry] {
        &self.entries
    }

    /// Look up a registry entry by wire name (`gate:12x2`).
    pub fn entry_index(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name)
    }

    /// Enqueue one request; its [`Reply`] arrives on `tx`. Errs on an
    /// unknown entry index or a volley whose length is not the entry's
    /// `p` (rejected up front, so a malformed query can never poison a
    /// coalesced pass for its batch-mates).
    pub fn submit(
        &self,
        id: u64,
        entry: usize,
        volley: Vec<SpikeTime>,
        tx: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        let e = self
            .entries
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("unknown entry index {entry}"))?;
        anyhow::ensure!(
            volley.len() == e.p,
            "request {id}: volley length {} != p = {} of entry {}",
            volley.len(),
            e.p,
            e.name
        );
        let mut st = lock_state(&self.shared);
        anyhow::ensure!(st.open, "server is shutting down");
        st.queue.push_back(Request {
            id,
            entry,
            volley,
            t0: Instant::now(),
            tx,
        });
        drop(st);
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Lane-block passes executed so far.
    pub fn batches(&self) -> u64 {
        self.shared.batches.load(Ordering::Relaxed)
    }

    /// Requests answered so far (across all passes).
    pub fn coalesced(&self) -> u64 {
        self.shared.coalesced.load(Ordering::Relaxed)
    }

    /// Stop accepting requests, drain the queue, and join the workers.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        lock_state(&self.shared).open = false;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Worker: pop the oldest request, greedily coalesce queued same-entry
/// requests up to the entry's lane budget (relative order of everything
/// left behind is preserved), run one batched pass, reply to each rider.
fn worker_loop(entries: &[ServeEntry], shared: &Shared) {
    loop {
        let batch: Vec<Request> = {
            let mut st = lock_state(shared);
            loop {
                if let Some(front) = st.queue.pop_front() {
                    let (e, cap) = (front.entry, entries[front.entry].max_batch);
                    let mut batch = vec![front];
                    let mut rest = VecDeque::with_capacity(st.queue.len());
                    while let Some(r) = st.queue.pop_front() {
                        if r.entry == e && batch.len() < cap {
                            batch.push(r);
                        } else {
                            rest.push_back(r);
                        }
                    }
                    st.queue = rest;
                    break batch;
                }
                if !st.open {
                    return;
                }
                st = shared
                    .cv
                    .wait(st)
                    .unwrap_or_else(|p| p.into_inner());
            }
        };
        let (e, n) = (batch[0].entry, batch.len());
        let volleys: Vec<&[SpikeTime]> = batch.iter().map(|r| r.volley.as_slice()).collect();
        let result = entries[e].service.infer_batch(&volleys);
        drop(volleys);
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.coalesced.fetch_add(n as u64, Ordering::Relaxed);
        match result {
            Ok(winners) => {
                for (r, w) in batch.into_iter().zip(winners) {
                    let _ = r.tx.send(Reply {
                        id: r.id,
                        entry: e,
                        outcome: Ok(w),
                        latency: r.t0.elapsed(),
                        batch: n,
                    });
                }
            }
            Err(err) => {
                let msg = err.to_string();
                for r in batch {
                    let _ = r.tx.send(Reply {
                        id: r.id,
                        entry: e,
                        outcome: Err(msg.clone()),
                        latency: r.t0.elapsed(),
                        batch: n,
                    });
                }
            }
        }
    }
}

/// Build the stateful engine + query pool for registry slot `idx` of
/// `spec` — entry weights come from one epoch of online STDP on a seeded
/// UCR workload, all drawn from frozen per-entry `split_stream` lanes.
/// This is the ONE recipe shared by [`Server::start`] and the bench
/// mode's sequential reference, which is what makes "batched winners are
/// bit-exact with sequential `infer_winner`" a differential test of the
/// server rather than a tautology.
pub fn build_entry_engine(
    spec: &ServeSpec,
    kind: EngineKind,
    p: usize,
    q: usize,
    idx: u64,
) -> crate::Result<(Engine<'static>, Vec<Vec<SpikeTime>>)> {
    let root = Rng64::seed_from_u64(spec.seed);
    let data = ucr::generate(
        UcrConfig {
            name: "serve",
            p,
            q,
        },
        spec.per_cluster,
        spec.seed ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let items = encode_ucr(&data, 8);
    let mut init_rng = root.split_stream(2 * idx);
    let mut engine = ucr_engine_with(kind, p, q, &items, TnnParams::default(), &mut init_rng)?;
    let mut train_rng = root.split_stream(2 * idx + 1);
    for item in &items {
        engine.step(&item.volley, &mut train_rng)?;
    }
    Ok((engine, items.into_iter().map(|i| i.volley).collect()))
}

/// Materialize the registry: engines × geometries, each frozen into a
/// [`ServiceEngine`] via [`build_entry_engine`].
fn build_entries(spec: &ServeSpec) -> crate::Result<Vec<ServeEntry>> {
    let mut entries = Vec::new();
    for &kind in &spec.engines {
        for &(p, q) in &spec.geometries {
            let idx = entries.len() as u64;
            let (engine, queries) = build_entry_engine(spec, kind, p, q, idx)?;
            let service = engine.service(spec.words, spec.threads)?;
            entries.push(ServeEntry {
                name: format!("{}:{p}x{q}", kind.name()),
                kind,
                p,
                q,
                service,
                queries,
                max_batch: spec.words.max(1) * LANES,
            });
        }
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ServeSpec {
        let mut s = ServeSpec::quick();
        s.engines = vec![EngineKind::Golden, EngineKind::Gate];
        s.geometries = vec![(6, 2)];
        s.per_cluster = 3;
        s.workers = 2;
        s.words = 1;
        s
    }

    #[test]
    fn registry_is_the_engine_geometry_product_with_seeded_pools() {
        let server = Server::start(&tiny_spec()).unwrap();
        assert_eq!(server.entries().len(), 2);
        assert_eq!(server.entries()[0].name, "golden:6x2");
        assert_eq!(server.entries()[1].name, "gate:6x2");
        assert_eq!(server.entry_index("gate:6x2"), Some(1));
        assert_eq!(server.entry_index("gate:9x9"), None);
        for e in server.entries() {
            assert_eq!(e.queries.len(), 6, "per_cluster x q queries");
            assert_eq!(e.max_batch, LANES);
        }
        server.shutdown();
    }

    #[test]
    fn submissions_are_answered_and_malformed_volleys_rejected_up_front() {
        let server = Server::start(&tiny_spec()).unwrap();
        let (tx, rx) = mpsc::channel();
        let q = server.entries()[0].queries[0].clone();
        server.submit(42, 0, q, tx.clone()).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.entry, 0);
        assert!(r.outcome.is_ok());
        assert!(r.batch >= 1);
        // Wrong-length volley: rejected at submit, never queued.
        let err = server
            .submit(43, 0, vec![SpikeTime::NONE; 3], tx.clone())
            .unwrap_err();
        assert!(err.to_string().contains("volley length"), "{err}");
        let err = server.submit(44, 9, vec![], tx).unwrap_err();
        assert!(err.to_string().contains("unknown entry"), "{err}");
        assert_eq!(server.coalesced(), 1);
        server.shutdown();
    }
}
