//! The serving core: the deterministic entry registry, the shared request
//! queue with admission control, and the supervised worker pool that
//! coalesces arrivals into lane-block passes (the module-level docs in
//! [`super`] walk the request lifecycle).
//!
//! **Resilience layer** (the always-on hardening): every submitted
//! request gets exactly one [`Reply`] — a winner or a typed
//! [`ServeError`] — no matter what happens in between:
//!
//! * **admission** — a bounded queue (`queue_depth`, 0 = unbounded)
//!   sheds the *newest* arrival with [`ServeError::Overload`] instead of
//!   queueing unboundedly;
//! * **deadlines** — a request may carry a deadline, checked at dequeue
//!   (an expired rider replies [`ServeError::Deadline`] without burning a
//!   batch slot) and again at reply time;
//! * **supervision** — each batch runs under `catch_unwind`; a panicking
//!   batch converts into per-rider [`ServeError::Internal`] replies (no
//!   stranded mpsc channels), the worker exits, and the supervisor
//!   respawns a replacement (panic/respawn counters in
//!   [`ServeCounters`]);
//! * **chaos** — a request may carry a [`ChaosAction`] (worker panic,
//!   slow batch, gate-level stuck-at fault). Chaos-marked requests are
//!   *isolated into singleton batches*, so their verdicts never depend on
//!   which batch-mates they coalesced with — the property that keeps the
//!   chaos harness's verdict transcript bit-identical at any worker
//!   count.

use super::ServeSpec;
use crate::config::EngineKind;
use crate::coordinator::{encode_ucr, ucr_engine_with, Engine, ServiceEngine};
use crate::gates::fault::GateFault;
use crate::gates::wordsim::LANES;
use crate::metrics::ServeCounters;
use crate::tnn::params::TnnParams;
use crate::tnn::spike::SpikeTime;
use crate::ucr::{self, UcrConfig};
use crate::util::Rng64;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One registry entry: a frozen [`ServiceEngine`] plus the seeded query
/// pool clients draw from. Addressed by `name` (`<engine>:<p>x<q>`).
pub struct ServeEntry {
    /// Wire name, `<engine>:<p>x<q>` (e.g. `gate:12x2`).
    pub name: String,
    /// Engine kind serving this entry.
    pub kind: EngineKind,
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons (= clusters) in the column.
    pub q: usize,
    /// The `Send + Sync` inference handle requests run on.
    pub service: ServiceEngine,
    /// Seeded query pool (encoded UCR volleys) for bench/smoke clients.
    pub queries: Vec<Vec<SpikeTime>>,
    /// Coalescing budget: requests per lane-block pass (`words × 64`).
    pub max_batch: usize,
}

/// The typed failure face of the serving path — everything that can go
/// wrong with one request, rendered on the wire as `!<error>` via
/// [`std::fmt::Display`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission: the queue was full (or the chaos injector
    /// forced a shed).
    Overload,
    /// The request's deadline passed before a winner could be delivered.
    Deadline,
    /// The request line / submission could not be parsed.
    Parse(String),
    /// The service errored or panicked while the request was in flight.
    Internal(String),
}

impl ServeError {
    /// Stable verdict-category spelling (`overload`/`deadline`/`parse`/
    /// `internal`) — the chaos harness's bucketing key.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Overload => "overload",
            ServeError::Deadline => "deadline",
            ServeError::Parse(_) => "parse",
            ServeError::Internal(_) => "internal",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overload => write!(f, "overload"),
            ServeError::Deadline => write!(f, "deadline"),
            ServeError::Parse(m) => write!(f, "parse: {m}"),
            ServeError::Internal(m) => write!(f, "internal: {m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A scheduled perturbation riding on one request (the chaos harness's
/// injection vehicle; see [`crate::serve::chaos`]). Chaos-marked requests
/// always run as singleton batches.
#[derive(Clone, Debug)]
pub enum ChaosAction {
    /// Panic the worker mid-batch (under `catch_unwind`; the supervisor
    /// respawns it).
    Panic,
    /// Stall the batch for the given duration before serving it.
    Slow(Duration),
    /// Inject a gate-level stuck-at fault (from [`crate::gates::fault`])
    /// into the pass that serves this request.
    Fault(GateFault),
}

/// Per-request submission options (see [`Server::submit_with`]).
#[derive(Clone, Debug, Default)]
pub struct SubmitOpts {
    /// Absolute deadline; `None` = never expires.
    pub deadline: Option<Instant>,
    /// Force an admission shed regardless of queue occupancy (the chaos
    /// injector's deterministic stand-in for a full queue).
    pub force_shed: bool,
    /// Perturbation to inject while serving this request.
    pub chaos: Option<ChaosAction>,
}

/// One answered request.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Echo of the client's request id.
    pub id: u64,
    /// Registry index the request ran on.
    pub entry: usize,
    /// The WTA winner (`Ok(None)` = no neuron fired), or the typed
    /// serving error (shed, expired, parse failure, service failure).
    pub outcome: Result<Option<usize>, ServeError>,
    /// End-to-end latency: queue wait + lane-block service time.
    pub latency: Duration,
    /// Size of the coalesced pass this request rode in (0 when the
    /// request never reached a pass: shed or expired at dequeue).
    pub batch: usize,
}

/// A queued request (internal; built by [`Server::submit_with`]).
struct Request {
    id: u64,
    entry: usize,
    volley: Vec<SpikeTime>,
    t0: Instant,
    deadline: Option<Instant>,
    chaos: Option<ChaosAction>,
    tx: mpsc::Sender<Reply>,
}

impl Request {
    fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// Queue state under the mutex: the pending requests plus the open flag
/// (inside the lock so shutdown can't race a worker's wait).
struct QueueState {
    queue: VecDeque<Request>,
    open: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
    batches: AtomicU64,
    coalesced: AtomicU64,
    /// Admission bound (0 = unbounded).
    queue_depth: usize,
    counters: ServeCounters,
}

// POISON-TAG: shared serving state; a panicked peer must not wedge us.
fn lock_state(shared: &Shared) -> std::sync::MutexGuard<'_, QueueState> {
    shared.state.lock().unwrap_or_else(|p| p.into_inner())
}

/// How a worker thread ended (the supervisor's respawn signal).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WorkerExit {
    /// Clean drain: the queue emptied after shutdown.
    Normal,
    /// A batch panicked (riders were answered with
    /// [`ServeError::Internal`] first); the supervisor respawns.
    Panicked,
}

/// The always-on inference server: a deterministic entry registry, one
/// shared bounded FIFO request queue, and a supervised pool of draining
/// threads that batch same-entry arrivals into lane-block passes.
pub struct Server {
    entries: Arc<Vec<ServeEntry>>,
    shared: Arc<Shared>,
    supervisor: Option<JoinHandle<()>>,
}

impl Server {
    /// Build the registry from `spec` and start the supervised worker
    /// pool.
    pub fn start(spec: &ServeSpec) -> crate::Result<Server> {
        let entries = Arc::new(build_entries(spec)?);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                queue: VecDeque::new(),
                open: true,
            }),
            cv: Condvar::new(),
            batches: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            queue_depth: spec.queue_depth,
            counters: ServeCounters::default(),
        });
        let workers = spec.workers.max(1);
        let supervisor = {
            let entries = entries.clone();
            let shared = shared.clone();
            std::thread::spawn(move || supervise(&entries, &shared, workers))
        };
        Ok(Server {
            entries,
            shared,
            supervisor: Some(supervisor),
        })
    }

    /// The registry, in construction order (engines × geometries).
    pub fn entries(&self) -> &[ServeEntry] {
        &self.entries
    }

    /// Look up a registry entry by wire name (`gate:12x2`).
    pub fn entry_index(&self, name: &str) -> Option<usize> {
        self.entries.iter().position(|e| e.name == name)
    }

    /// Enqueue one request with default options (no deadline, no chaos);
    /// its [`Reply`] arrives on `tx`. See [`Server::submit_with`].
    pub fn submit(
        &self,
        id: u64,
        entry: usize,
        volley: Vec<SpikeTime>,
        tx: mpsc::Sender<Reply>,
    ) -> crate::Result<()> {
        self.submit_with(id, entry, volley, tx, SubmitOpts::default())
            .map(|_| ())
    }

    /// Enqueue one request; its [`Reply`] arrives on `tx`. Returns
    /// `Ok(true)` when queued, `Ok(false)` when shed at admission (the
    /// [`ServeError::Overload`] reply is still delivered on `tx` — every
    /// accepted submission gets exactly one reply). Errs only on caller
    /// bugs: an unknown entry index or a volley whose length is not the
    /// entry's `p` (rejected up front, so a malformed query can never
    /// poison a coalesced pass for its batch-mates).
    pub fn submit_with(
        &self,
        id: u64,
        entry: usize,
        volley: Vec<SpikeTime>,
        tx: mpsc::Sender<Reply>,
        opts: SubmitOpts,
    ) -> crate::Result<bool> {
        let e = self
            .entries
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("unknown entry index {entry}"))?;
        anyhow::ensure!(
            volley.len() == e.p,
            "request {id}: volley length {} != p = {} of entry {}",
            volley.len(),
            e.p,
            e.name
        );
        self.shared.counters.submitted.inc();
        let mut st = lock_state(&self.shared);
        anyhow::ensure!(st.open, "server is shutting down");
        let full =
            self.shared.queue_depth > 0 && st.queue.len() >= self.shared.queue_depth;
        if full || opts.force_shed {
            drop(st);
            self.shared.counters.shed.inc();
            self.shared.counters.replies.inc();
            let _ = tx.send(Reply {
                id,
                entry,
                outcome: Err(ServeError::Overload),
                latency: Duration::ZERO,
                batch: 0,
            });
            return Ok(false);
        }
        st.queue.push_back(Request {
            id,
            entry,
            volley,
            t0: Instant::now(),
            deadline: opts.deadline,
            chaos: opts.chaos,
            tx,
        });
        drop(st);
        self.shared.cv.notify_one();
        Ok(true)
    }

    /// Lane-block passes executed so far.
    pub fn batches(&self) -> u64 {
        self.shared.batches.load(Ordering::Relaxed)
    }

    /// Requests answered by a pass so far (shed/expired replies excluded).
    pub fn coalesced(&self) -> u64 {
        self.shared.coalesced.load(Ordering::Relaxed)
    }

    /// The resilience counters (admission, deadlines, supervision).
    pub fn counters(&self) -> &ServeCounters {
        &self.shared.counters
    }

    /// Stop accepting requests, drain the queue, and join the workers.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        lock_state(&self.shared).open = false;
        self.shared.cv.notify_all();
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// Render a `catch_unwind` payload for the per-rider error reply. The
/// text must be deterministic (no worker ids, no addresses): it lands in
/// the chaos transcript, which is pinned bit-identical across worker
/// counts.
fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Supervisor: spawn the initial workers, then wait on their exit events.
/// A clean drain retires the worker; a panicked batch (riders already
/// answered) respawns a replacement under a fresh id for as long as the
/// server is open or the queue still holds work, bumping the
/// `worker_respawns` counter.
fn supervise(entries: &Arc<Vec<ServeEntry>>, shared: &Arc<Shared>, workers: usize) {
    let (etx, erx) = mpsc::channel::<(usize, WorkerExit)>();
    let spawn_worker = |wid: usize| -> JoinHandle<()> {
        let entries = entries.clone();
        let shared = shared.clone();
        let etx = etx.clone();
        std::thread::spawn(move || {
            // Belt and braces: per-batch panics are caught (and replied
            // to) inside worker_loop; this outer catch covers panics in
            // the loop machinery itself so the supervisor always hears an
            // exit event.
            let exit = catch_unwind(AssertUnwindSafe(|| worker_loop(&entries, &shared)))
                .unwrap_or(WorkerExit::Panicked);
            let _ = etx.send((wid, exit));
        })
    };
    let mut handles: HashMap<usize, JoinHandle<()>> =
        (0..workers).map(|w| (w, spawn_worker(w))).collect();
    let mut next_id = workers;
    while !handles.is_empty() {
        let Ok((wid, exit)) = erx.recv() else { break };
        if let Some(h) = handles.remove(&wid) {
            let _ = h.join();
        }
        if exit == WorkerExit::Panicked {
            let respawn = {
                let st = lock_state(shared);
                st.open || !st.queue.is_empty()
            };
            if respawn {
                shared.counters.worker_respawns.inc();
                eprintln!(
                    "tnn7 serve: worker {wid} panicked; respawned as worker {next_id} \
                     (panics {}, respawns {})",
                    shared.counters.batch_panics.get(),
                    shared.counters.worker_respawns.get(),
                );
                handles.insert(next_id, spawn_worker(next_id));
                next_id += 1;
            }
        }
    }
}

/// Send one reply and bump the reply counter (every dequeued or shed
/// request funnels through here exactly once).
fn reply_to(shared: &Shared, r: Request, outcome: Result<Option<usize>, ServeError>, batch: usize) {
    shared.counters.replies.inc();
    let _ = r.tx.send(Reply {
        id: r.id,
        entry: r.entry,
        outcome,
        latency: r.t0.elapsed(),
        batch,
    });
}

/// Worker: pop the oldest live request, greedily coalesce queued
/// same-entry requests up to the entry's lane budget (relative order of
/// everything left behind is preserved; chaos-marked requests stay
/// singletons), run one batched pass under `catch_unwind`, reply to each
/// rider. Expired requests encountered at the queue front or during the
/// coalescing scan get [`ServeError::Deadline`] without burning a batch
/// slot.
fn worker_loop(entries: &[ServeEntry], shared: &Shared) -> WorkerExit {
    loop {
        let mut expired: Vec<Request> = Vec::new();
        let batch: Vec<Request> = {
            let mut st = lock_state(shared);
            let front = loop {
                match st.queue.pop_front() {
                    Some(r) if r.expired() => {
                        expired.push(r);
                        if st.queue.is_empty() {
                            break None; // deliver the expiries now
                        }
                    }
                    Some(r) => break Some(r),
                    None => {
                        if !st.open {
                            return WorkerExit::Normal;
                        }
                        st = shared.cv.wait(st).unwrap_or_else(|p| p.into_inner());
                    }
                }
            };
            match front {
                None => Vec::new(),
                Some(front) => {
                    let (e, cap) = (front.entry, entries[front.entry].max_batch);
                    // Chaos isolation: a marked request runs alone, so
                    // its perturbation can only ever affect itself.
                    let isolated = front.chaos.is_some();
                    let mut batch = vec![front];
                    if !isolated {
                        let mut rest = VecDeque::with_capacity(st.queue.len());
                        while let Some(r) = st.queue.pop_front() {
                            if r.entry == e && batch.len() < cap && r.chaos.is_none() {
                                if r.expired() {
                                    expired.push(r);
                                } else {
                                    batch.push(r);
                                }
                            } else {
                                rest.push_back(r);
                            }
                        }
                        st.queue = rest;
                    }
                    batch
                }
            }
        };
        if !expired.is_empty() {
            shared
                .counters
                .expired_dequeue
                .add(expired.len() as u64);
            for r in expired {
                reply_to(shared, r, Err(ServeError::Deadline), 0);
            }
        }
        if batch.is_empty() {
            continue;
        }

        let (e, n) = (batch[0].entry, batch.len());
        let chaos = batch[0].chaos.clone();
        shared.counters.dequeued.add(n as u64);
        if let Some(ChaosAction::Slow(d)) = &chaos {
            std::thread::sleep(*d);
        }
        let volleys: Vec<&[SpikeTime]> = batch.iter().map(|r| r.volley.as_slice()).collect();
        // The batch Vec stays outside the closure so a panicking pass
        // still lets us answer every rider afterwards.
        let result = catch_unwind(AssertUnwindSafe(|| match &chaos {
            Some(ChaosAction::Panic) => panic!("chaos: injected worker panic"),
            Some(ChaosAction::Fault(f)) => entries[e].service.infer_batch_faulted(&volleys, f),
            _ => entries[e].service.infer_batch(&volleys),
        }));
        drop(volleys);
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.coalesced.fetch_add(n as u64, Ordering::Relaxed);
        match result {
            Ok(Ok(winners)) => {
                for (r, w) in batch.into_iter().zip(winners) {
                    let outcome = if r.expired() {
                        shared.counters.expired_reply.inc();
                        Err(ServeError::Deadline)
                    } else {
                        Ok(w)
                    };
                    reply_to(shared, r, outcome, n);
                }
            }
            Ok(Err(err)) => {
                let msg = err.to_string();
                for r in batch {
                    reply_to(shared, r, Err(ServeError::Internal(msg.clone())), n);
                }
            }
            Err(payload) => {
                // A panicked pass: answer every rider (no stranded
                // channels), then exit so the supervisor respawns us.
                shared.counters.batch_panics.inc();
                let msg = format!("worker panicked: {}", panic_text(&*payload));
                for r in batch {
                    reply_to(shared, r, Err(ServeError::Internal(msg.clone())), n);
                }
                return WorkerExit::Panicked;
            }
        }
    }
}

/// Build the stateful engine + query pool for registry slot `idx` of
/// `spec` — entry weights come from one epoch of online STDP on a seeded
/// UCR workload, all drawn from frozen per-entry `split_stream` lanes.
/// This is the ONE recipe shared by [`Server::start`] and the bench
/// mode's sequential reference, which is what makes "batched winners are
/// bit-exact with sequential `infer_winner`" a differential test of the
/// server rather than a tautology.
pub fn build_entry_engine(
    spec: &ServeSpec,
    kind: EngineKind,
    p: usize,
    q: usize,
    idx: u64,
) -> crate::Result<(Engine<'static>, Vec<Vec<SpikeTime>>)> {
    let root = Rng64::seed_from_u64(spec.seed);
    let data = ucr::generate(
        UcrConfig {
            name: "serve",
            p,
            q,
        },
        spec.per_cluster,
        spec.seed ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let items = encode_ucr(&data, 8);
    let mut init_rng = root.split_stream(2 * idx);
    let mut engine = ucr_engine_with(kind, p, q, &items, TnnParams::default(), &mut init_rng)?;
    let mut train_rng = root.split_stream(2 * idx + 1);
    for item in &items {
        engine.step(&item.volley, &mut train_rng)?;
    }
    Ok((engine, items.into_iter().map(|i| i.volley).collect()))
}

/// Materialize the registry: engines × geometries, each frozen into a
/// [`ServiceEngine`] via [`build_entry_engine`].
fn build_entries(spec: &ServeSpec) -> crate::Result<Vec<ServeEntry>> {
    let mut entries = Vec::new();
    for &kind in &spec.engines {
        for &(p, q) in &spec.geometries {
            let idx = entries.len() as u64;
            let (engine, queries) = build_entry_engine(spec, kind, p, q, idx)?;
            let service = engine.service(spec.words, spec.threads)?;
            entries.push(ServeEntry {
                name: format!("{}:{p}x{q}", kind.name()),
                kind,
                p,
                q,
                service,
                queries,
                max_batch: spec.words.max(1) * LANES,
            });
        }
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> ServeSpec {
        let mut s = ServeSpec::quick();
        s.engines = vec![EngineKind::Golden, EngineKind::Gate];
        s.geometries = vec![(6, 2)];
        s.per_cluster = 3;
        s.workers = 2;
        s.words = 1;
        s
    }

    #[test]
    fn registry_is_the_engine_geometry_product_with_seeded_pools() {
        let server = Server::start(&tiny_spec()).unwrap();
        assert_eq!(server.entries().len(), 2);
        assert_eq!(server.entries()[0].name, "golden:6x2");
        assert_eq!(server.entries()[1].name, "gate:6x2");
        assert_eq!(server.entry_index("gate:6x2"), Some(1));
        assert_eq!(server.entry_index("gate:9x9"), None);
        for e in server.entries() {
            assert_eq!(e.queries.len(), 6, "per_cluster x q queries");
            assert_eq!(e.max_batch, LANES);
        }
        server.shutdown();
    }

    #[test]
    fn submissions_are_answered_and_malformed_volleys_rejected_up_front() {
        let server = Server::start(&tiny_spec()).unwrap();
        let (tx, rx) = mpsc::channel();
        let q = server.entries()[0].queries[0].clone();
        server.submit(42, 0, q, tx.clone()).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(r.id, 42);
        assert_eq!(r.entry, 0);
        assert!(r.outcome.is_ok());
        assert!(r.batch >= 1);
        // Wrong-length volley: rejected at submit, never queued.
        let err = server
            .submit(43, 0, vec![SpikeTime::NONE; 3], tx.clone())
            .unwrap_err();
        assert!(err.to_string().contains("volley length"), "{err}");
        let err = server.submit(44, 9, vec![], tx).unwrap_err();
        assert!(err.to_string().contains("unknown entry"), "{err}");
        assert_eq!(server.coalesced(), 1);
        assert_eq!(server.counters().submitted.get(), 1);
        assert_eq!(server.counters().replies.get(), 1);
        server.shutdown();
    }

    #[test]
    fn forced_shed_replies_overload_without_queueing() {
        let server = Server::start(&tiny_spec()).unwrap();
        let (tx, rx) = mpsc::channel();
        let q = server.entries()[0].queries[0].clone();
        let queued = server
            .submit_with(
                1,
                0,
                q,
                tx,
                SubmitOpts {
                    force_shed: true,
                    ..SubmitOpts::default()
                },
            )
            .unwrap();
        assert!(!queued);
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(r.outcome, Err(ServeError::Overload));
        assert_eq!(r.batch, 0);
        assert_eq!(server.counters().shed.get(), 1);
        assert_eq!(server.batches(), 0, "shed requests never reach a pass");
        server.shutdown();
    }

    #[test]
    fn serve_error_wire_spellings_are_stable() {
        assert_eq!(ServeError::Overload.to_string(), "overload");
        assert_eq!(ServeError::Deadline.to_string(), "deadline");
        assert_eq!(
            ServeError::Parse("bad id".into()).to_string(),
            "parse: bad id"
        );
        assert_eq!(
            ServeError::Internal("boom".into()).to_string(),
            "internal: boom"
        );
        assert_eq!(ServeError::Overload.kind(), "overload");
        assert_eq!(ServeError::Internal(String::new()).kind(), "internal");
    }
}
