//! Content-addressed on-disk cache for sweep point results.
//!
//! Every grid point is keyed by a stable 64-bit FNV-1a hash of its
//! [`SweepPoint::canonical`](super::spec::SweepPoint::canonical) string
//! plus [`CACHE_VERSION`]; the result lives in `<cache_dir>/<key>.kv` in
//! the crate's usual key-value format. A killed sweep therefore resumes
//! instantly — re-running a spec re-reads every finished point and
//! recomputes only the missing (or version-invalidated) ones. Entries are
//! written atomically (temp file + rename), so a crash mid-write can never
//! leave a half-entry that later parses.
//!
//! Deterministic fields (PPA, clustering quality, synthesis gate counts)
//! round-trip exactly: they are serialized with Rust's shortest-roundtrip
//! float formatting, so a merged report built from cached points is
//! byte-identical to one built from a cold run. Wall-clock fields
//! (`synth_ms`, `train_ms`) are cached as measured on the run that
//! computed the point.

use super::exec::PointResult;
use super::spec::SweepPoint;
use crate::util::kv::KvDoc;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide sequence for temp-file names: two writers storing the same
/// key concurrently (or two processes sharing a cache directory) must
/// never collide on the temp path, or the loser's rename fails spuriously.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Cache format/semantics version. Bump whenever a change anywhere in the
/// measurement pipeline (engines, synthesis, PPA model, workload
/// generation, draw disciplines) invalidates previously-cached results —
/// every old entry then misses and is recomputed.
///
/// v2: points gained the `alpha_measured` field (gate-sim switching
/// activity measured on the compiled lane-block backend, pinned by
/// `exec::SWEEP_ALPHA_CYCLES` / `exec::SWEEP_ALPHA_WORDS`).
///
/// v3: points gained `alpha_opt_measured` / `power_meas_nw` — the
/// measured per-net α carried onto the synthesis optimizer's renumbered
/// mapping through its `NetRemap` (TNN7 flow; baseline rows fall back to
/// the probabilistic values).
pub const CACHE_VERSION: &str = "tnn7-sweep-v3";

/// Stable 64-bit FNV-1a hash (the cache's content address). Frozen: keys
/// must not change across platforms or releases, or warm caches would be
/// silently abandoned.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Outcome of one cache probe ([`PointCache::lookup`]).
#[derive(Clone, Debug, PartialEq)]
pub enum CacheLookup {
    /// A valid entry for exactly this point.
    Hit(PointResult),
    /// No entry on disk, or an entry whose canonical string names a
    /// different point (hand-edit / hash collision — never quarantined).
    Miss,
    /// The entry was corrupt or truncated; it has been renamed
    /// `<key>.corrupt` so the damaged bytes survive for forensics while
    /// the point recomputes and re-stores cleanly.
    Quarantined,
}

/// On-disk cache handle (a directory of `<key>.kv` entries).
#[derive(Clone, Debug)]
pub struct PointCache {
    dir: PathBuf,
}

impl PointCache {
    /// Open (and create if needed) a cache directory.
    pub fn open(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(PointCache { dir })
    }

    /// The content address of a point under [`CACHE_VERSION`].
    pub fn key(point: &SweepPoint) -> String {
        let canon = format!("{};{}", CACHE_VERSION, point.canonical());
        format!("{:016x}", fnv1a64(canon.as_bytes()))
    }

    /// Path of a point's cache entry.
    pub fn path(&self, point: &SweepPoint) -> PathBuf {
        self.dir.join(format!("{}.kv", Self::key(point)))
    }

    /// Load a point's cached result, if present and valid ([`Self::lookup`]
    /// collapsed to an `Option`; both a miss and a quarantined entry load
    /// as `None` and the point recomputes).
    pub fn load(&self, point: &SweepPoint) -> Option<PointResult> {
        match self.lookup(point) {
            CacheLookup::Hit(r) => Some(r),
            CacheLookup::Miss | CacheLookup::Quarantined => None,
        }
    }

    /// Probe a point's cache entry, distinguishing the three outcomes a
    /// sweep must account for. An unreadable/unparseable file, or one that
    /// names this point but is missing result fields (a truncated write
    /// from a crashed or pre-atomic-rename writer), is **quarantined**:
    /// renamed to `<key>.corrupt` so it cannot mask the recompute's clean
    /// re-store, and counted in the sweep summary. An entry whose
    /// canonical string does not match exactly stays a plain miss — a
    /// hash collision or hand-edited file must not alias a result, but it
    /// is not damage either.
    pub fn lookup(&self, point: &SweepPoint) -> CacheLookup {
        let path = self.path(point);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return CacheLookup::Miss,
            Err(_) => return self.quarantine(point),
        };
        let doc = match KvDoc::parse(&text) {
            Ok(d) => d,
            Err(_) => return self.quarantine(point),
        };
        if doc.get("point") != Some(point.canonical().as_str()) {
            return CacheLookup::Miss;
        }
        match PointResult::from_kv(point, &doc) {
            Some(r) => CacheLookup::Hit(r),
            None => self.quarantine(point),
        }
    }

    /// Path a quarantined entry is renamed to.
    pub fn corrupt_path(&self, point: &SweepPoint) -> PathBuf {
        self.dir.join(format!("{}.corrupt", Self::key(point)))
    }

    /// Move a damaged entry out of the key's path (best-effort: if the
    /// rename itself fails the entry simply misses again next run).
    fn quarantine(&self, point: &SweepPoint) -> CacheLookup {
        std::fs::rename(self.path(point), self.corrupt_path(point)).ok();
        CacheLookup::Quarantined
    }

    /// Atomically persist a point's result (temp file + rename).
    pub fn store(&self, point: &SweepPoint, result: &PointResult) -> crate::Result<()> {
        let mut doc = result.to_kv();
        doc.set("version", CACHE_VERSION);
        doc.set("point", point.canonical());
        let final_path = self.path(point);
        let tmp = self.dir.join(format!(
            "{}.tmp.{}.{}",
            Self::key(point),
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        std::fs::write(&tmp, doc.to_text())?;
        std::fs::rename(&tmp, &final_path)?;
        Ok(())
    }

    /// Remove a point's cache entry (returns whether one existed) —
    /// targeted invalidation, used by the resumability tests and by
    /// operators who want to force one point to re-measure.
    pub fn invalidate(&self, point: &SweepPoint) -> bool {
        std::fs::remove_file(self.path(point)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::sweep::spec::ThetaPolicy;
    use crate::synth::flow::Flow;

    fn point() -> SweepPoint {
        SweepPoint {
            p: 8,
            q: 2,
            theta: ThetaPolicy::Default,
            flow: Flow::Tnn7,
            engine: EngineKind::Golden,
            seed: 7,
            per_cluster: 4,
            epochs: 1,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tnn7_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fnv_is_frozen() {
        // Golden values: the empty string hashes to the FNV offset basis,
        // and "a" to the reference FNV-1a value. These pin the algorithm.
        assert_eq!(fnv1a64(b""), 0xCBF2_9CE4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xAF63_DC4C_8601_EC8C);
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }

    #[test]
    fn key_depends_on_every_point_field_and_version() {
        let base = point();
        let k0 = PointCache::key(&base);
        let mut variants = Vec::new();
        variants.push(SweepPoint { p: 9, ..base.clone() });
        variants.push(SweepPoint { q: 3, ..base.clone() });
        variants.push(SweepPoint { theta: ThetaPolicy::Fixed(5), ..base.clone() });
        variants.push(SweepPoint { flow: Flow::Baseline, ..base.clone() });
        variants.push(SweepPoint { engine: EngineKind::Batched, ..base.clone() });
        variants.push(SweepPoint { seed: 8, ..base.clone() });
        variants.push(SweepPoint { per_cluster: 5, ..base.clone() });
        variants.push(SweepPoint { epochs: 2, ..base.clone() });
        for v in variants {
            assert_ne!(PointCache::key(&v), k0, "key must separate {v:?}");
        }
    }

    #[test]
    fn roundtrip_store_load_invalidate() {
        let dir = tmpdir("roundtrip");
        let cache = PointCache::open(&dir).unwrap();
        let p = point();
        assert!(cache.load(&p).is_none(), "cold cache misses");
        let r = PointResult::synthetic_for_tests();
        cache.store(&p, &r).unwrap();
        let got = cache.load(&p).expect("warm cache hits");
        assert_eq!(got, r, "deterministic fields round-trip exactly");
        assert!(cache.invalidate(&p));
        assert!(cache.load(&p).is_none(), "invalidated point misses");
        assert!(!cache.invalidate(&p), "second invalidate is a no-op");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_entry_is_quarantined_not_served() {
        let dir = tmpdir("truncate");
        let cache = PointCache::open(&dir).unwrap();
        let p = point();
        cache.store(&p, &PointResult::synthetic_for_tests()).unwrap();
        // Simulate a crashed pre-atomic-rename writer: cut the entry off
        // mid-file (keys are sorted, so this drops the trailing fields).
        let path = cache.path(&p);
        let text = std::fs::read_to_string(&path).unwrap();
        let cut = text.find("train_ms").expect("entry carries train_ms");
        std::fs::write(&path, &text[..cut]).unwrap();
        assert_eq!(cache.lookup(&p), CacheLookup::Quarantined);
        assert!(
            cache.corrupt_path(&p).exists(),
            "damaged bytes kept under <key>.corrupt"
        );
        assert!(!path.exists(), "damaged entry moved off the key's path");
        // The quarantined entry cannot mask anything: the next probe is a
        // plain miss, and a clean re-store hits again.
        assert_eq!(cache.lookup(&p), CacheLookup::Miss);
        cache.store(&p, &PointResult::synthetic_for_tests()).unwrap();
        assert!(matches!(cache.lookup(&p), CacheLookup::Hit(_)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mismatched_canonical_string_is_a_miss() {
        let dir = tmpdir("mismatch");
        let cache = PointCache::open(&dir).unwrap();
        let p = point();
        cache.store(&p, &PointResult::synthetic_for_tests()).unwrap();
        // Corrupt the stored canonical string: the entry must stop hitting.
        let path = cache.path(&p);
        let text = std::fs::read_to_string(&path).unwrap().replace("seed=7", "seed=8");
        std::fs::write(&path, text).unwrap();
        assert!(cache.load(&p).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
