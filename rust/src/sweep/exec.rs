//! Sweep executor: shards grid points across worker threads, with warm
//! results served from the [`PointCache`](super::cache::PointCache).
//!
//! # Determinism contract
//!
//! Every deterministic field of a [`PointResult`] is a pure function of
//! the [`SweepPoint`] alone — never of grid position, worker assignment,
//! or thread count. Per-point randomness derives from the point's own
//! seed through the frozen [`Rng64::split_stream`] discipline (the same
//! contract `tnn::batch` shards training by):
//!
//! * initial weights draw from `Rng64::seed_from_u64(seed).split_stream(0)`;
//! * training epoch `e` streams with seed
//!   `Rng64::seed_from_u64(seed).split_stream(1 + e).next_u64()`.
//!
//! Sharding therefore cannot change results: a sweep run with 1, 2 or 8
//! workers produces bit-identical deterministic fields, and a point cached
//! by one grid is valid in any other grid that contains the same point.
//! Only the wall-clock fields (`synth_ms`, `train_ms`) vary run to run.

use super::cache::{CacheLookup, PointCache};
use super::spec::{SweepPoint, SweepSpec, ThetaPolicy};
use crate::coordinator::{encode_ucr, run_stream, score_winners, volley_density};
use crate::gates::column_design::{build_column, BrvSource};
use crate::gates::{OptLevel, SimBackend};
use crate::ppa::report::analyze;
use crate::synth::flow::synthesize;
use crate::tnn::params::TnnParams;
use crate::ucr::UcrConfig;
use crate::util::kv::KvDoc;
use crate::util::Rng64;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Everything measured at one grid point. All fields except `synth_ms` /
/// `train_ms` are deterministic (see the module docs) and round-trip
/// exactly through the cache.
#[derive(Clone, Debug, PartialEq)]
pub struct PointResult {
    /// Resolved neuron threshold (after applying the point's θ policy).
    pub theta: u32,
    // --- post-synthesis PPA (flow's library, harness::GAMMA_CYCLES) ---
    /// Total area (cells + net estimate), µm².
    pub area_um2: f64,
    /// Total power at the standard operating point, nW.
    pub power_nw: f64,
    /// Leakage component of `power_nw`, nW.
    pub leakage_nw: f64,
    /// Computation time per gamma (critical path × gamma cycles), ns.
    pub comp_time_ns: f64,
    /// Energy-delay product, fJ·ns.
    pub edp_fj_ns: f64,
    /// Mean switching activity α of the point's column netlist, measured
    /// by gate-level simulation on the compiled lane-block backend under
    /// the standard randomized TNN workload (the measurement is pinned by
    /// [`SWEEP_ALPHA_CYCLES`] / [`SWEEP_ALPHA_WORDS`] and seeded by the
    /// point, so it is a pure function of the point — deterministic at
    /// any thread count and identical under every `sim_backend` setting).
    pub alpha_measured: f64,
    /// Mean measured α over the nets *retained by the synthesis
    /// optimizer*, i.e. the measured per-net vector carried onto the
    /// optimized mapping through the flow's
    /// [`NetRemap`](crate::gates::opt::NetRemap). Defined for the
    /// macro-preserving TNN7 flow (whose optimizer input is the measured
    /// design netlist); baseline rows report `alpha_measured` (the
    /// expanded netlist's ids don't correspond to the measured ones).
    pub alpha_opt_measured: f64,
    /// Total power re-analyzed with the measured per-net α on the
    /// optimized mapping
    /// ([`crate::ppa::report::analyze_with_alpha_remapped`]) — the
    /// measured-activity counterpart of `power_nw`. Baseline rows report
    /// the probabilistic `power_nw` (same caveat as `alpha_opt_measured`).
    pub power_meas_nw: f64,
    // --- synthesis shape (deterministic) ---
    /// Gates entering the optimizer (the Fig. 12 search-space size).
    pub gates_in: usize,
    /// Standard cells in the mapped netlist.
    pub cells_out: usize,
    /// Preserved hard-macro instances in the mapped netlist.
    pub macros_out: usize,
    // --- workload quality ---
    /// Gamma items in the generated workload.
    pub items: usize,
    /// Items that fired on the post-training inference pass.
    pub fired: usize,
    /// Rand index of post-training winners vs ground-truth clusters.
    pub rand_index: f64,
    /// Cluster purity of post-training winners.
    pub purity: f64,
    // --- wall clock (nondeterministic; cached as measured) ---
    /// Metered synthesis wall time (the Fig. 12 quantity), ms.
    pub synth_ms: f64,
    /// Training + scoring wall time, ms.
    pub train_ms: f64,
}

impl PointResult {
    /// Clustering error in percent (`(1 − purity) × 100`) — the y-axis the
    /// Pareto frontiers trade PPA against.
    pub fn error_pct(&self) -> f64 {
        (1.0 - self.purity) * 100.0
    }

    /// Serialize to the cache entry format (field per key).
    pub fn to_kv(&self) -> KvDoc {
        let mut d = KvDoc::default();
        d.set("theta", self.theta);
        d.set("area_um2", self.area_um2);
        d.set("power_nw", self.power_nw);
        d.set("leakage_nw", self.leakage_nw);
        d.set("comp_time_ns", self.comp_time_ns);
        d.set("edp_fj_ns", self.edp_fj_ns);
        d.set("alpha_measured", self.alpha_measured);
        d.set("alpha_opt_measured", self.alpha_opt_measured);
        d.set("power_meas_nw", self.power_meas_nw);
        d.set("gates_in", self.gates_in);
        d.set("cells_out", self.cells_out);
        d.set("macros_out", self.macros_out);
        d.set("items", self.items);
        d.set("fired", self.fired);
        d.set("rand_index", self.rand_index);
        d.set("purity", self.purity);
        d.set("synth_ms", self.synth_ms);
        d.set("train_ms", self.train_ms);
        d
    }

    /// Deserialize a cache entry; `None` (a cache miss) on any missing or
    /// malformed field. The `point` argument is unused today but keeps the
    /// signature ready for per-point schema evolution.
    pub fn from_kv(_point: &SweepPoint, doc: &KvDoc) -> Option<PointResult> {
        let f = |k: &str| doc.get_f64(k).ok().flatten();
        let u = |k: &str| doc.get_usize(k).ok().flatten();
        Some(PointResult {
            theta: doc.get_u64("theta").ok().flatten()? as u32,
            area_um2: f("area_um2")?,
            power_nw: f("power_nw")?,
            leakage_nw: f("leakage_nw")?,
            comp_time_ns: f("comp_time_ns")?,
            edp_fj_ns: f("edp_fj_ns")?,
            alpha_measured: f("alpha_measured")?,
            alpha_opt_measured: f("alpha_opt_measured")?,
            power_meas_nw: f("power_meas_nw")?,
            gates_in: u("gates_in")?,
            cells_out: u("cells_out")?,
            macros_out: u("macros_out")?,
            items: u("items")?,
            fired: u("fired")?,
            rand_index: f("rand_index")?,
            purity: f("purity")?,
            synth_ms: f("synth_ms")?,
            train_ms: f("train_ms")?,
        })
    }

    #[cfg(test)]
    pub(crate) fn synthetic_for_tests() -> PointResult {
        PointResult {
            theta: 14,
            area_um2: 123.456789,
            power_nw: 987.0000001,
            leakage_nw: 55.5,
            comp_time_ns: 3.25,
            edp_fj_ns: 101.0,
            alpha_measured: 0.0417,
            alpha_opt_measured: 0.0432,
            power_meas_nw: 991.25,
            gates_in: 1000,
            cells_out: 420,
            macros_out: 18,
            items: 8,
            fired: 7,
            rand_index: 0.875,
            purity: 0.75,
            synth_ms: 1.5,
            train_ms: 2.5,
        }
    }
}

/// One merged report row: the point, its result, and whether the result
/// was served from the warm cache.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// The grid point.
    pub point: SweepPoint,
    /// Its measurements.
    pub result: PointResult,
    /// `true` when the result came from the cache rather than being
    /// computed by this run.
    pub cached: bool,
}

/// A finished sweep: every point's row in canonical grid order, plus
/// cache-hit accounting.
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    /// The spec that defined the grid.
    pub spec: SweepSpec,
    /// One row per point, in [`SweepSpec::points`] order.
    pub rows: Vec<SweepRow>,
    /// Points computed by this run.
    pub computed: usize,
    /// Points served from the warm cache.
    pub cached: usize,
    /// Corrupt/truncated cache entries quarantined (renamed
    /// `<key>.corrupt`) by this run; each such point was recomputed.
    pub quarantined: usize,
}

/// Lane-cycles of the per-point measured-activity run. Part of the
/// measurement definition: changing it changes `alpha_measured` for every
/// point, so any edit must bump [`super::cache::CACHE_VERSION`].
pub const SWEEP_ALPHA_CYCLES: u64 = 2048;

/// Lane-block width of the per-point measured-activity run (same
/// CACHE_VERSION contract as [`SWEEP_ALPHA_CYCLES`]). Pinned here — NOT
/// the spec's `sim_words` execution knob — so the measurement is a pure
/// function of the point.
pub const SWEEP_ALPHA_WORDS: usize = 2;

/// Measure one grid point from scratch with the default batched-inference
/// backend and no netlist optimization (see [`compute_point_with`]).
pub fn compute_point(point: &SweepPoint) -> crate::Result<PointResult> {
    compute_point_with(point, SimBackend::BitParallel64, OptLevel::None)
}

/// Measure one grid point from scratch: generate the seeded workload,
/// resolve θ, synthesize the column under the point's flow (metered, the
/// Fig. 12 quantity), analyze PPA, measure gate-level switching activity
/// on the compiled lane-block simulator, then train the point's engine
/// through the same streaming path the conformance harness drives and
/// score the post-training clustering.
///
/// `sim_backend` selects the simulator behind the gate engine's batched
/// inference scoring only, and `opt` the netlist optimization level of a
/// compiled selection — winners are bit-exact across backends and levels,
/// so every deterministic field of the result is independent of both
/// (which is what keeps cache keys backend- and opt-stable).
pub fn compute_point_with(
    point: &SweepPoint,
    sim_backend: SimBackend,
    opt: OptLevel,
) -> crate::Result<PointResult> {
    let params = TnnParams::default();
    // Workload: the same synthetic UCR-style generator the conformance
    // suite sweeps, at the point's geometry.
    let cfg = UcrConfig {
        name: "sweep",
        p: point.p,
        q: point.q,
    };
    let data = crate::ucr::generate(cfg, point.per_cluster, point.seed);
    let items = encode_ucr(&data, params.t_max());
    let theta = match point.theta {
        ThetaPolicy::Default => params.default_theta(point.p),
        ThetaPolicy::Sparse => crate::tnn::encode::sparse_theta(
            point.p,
            params.w_max(),
            volley_density(&items),
        ),
        ThetaPolicy::Fixed(n) => n,
    };

    // Hardware: synthesize this geometry under the point's flow and run
    // the PPA models on the mapped netlist.
    let design = build_column(point.p, point.q, theta, BrvSource::Lfsr);
    let out = synthesize(&design.netlist, point.flow);
    let lib = point.flow.library();
    let ppa = analyze(&out.mapped, &lib, crate::harness::GAMMA_CYCLES);
    // Gate-level measured switching activity on the compiled lane-block
    // simulator (pinned measurement constants + the point's seed — see
    // the field docs). The synthesis optimizer renumbers nets, so the
    // per-net vector is carried onto the optimized mapping through the
    // flow's NetRemap before feeding `analyze_with_alpha_remapped` — only
    // meaningful for the macro-preserving TNN7 flow, whose optimizer
    // input *is* the measured design netlist; the baseline flow expands
    // macros into a fresh id space first, so its rows keep the
    // probabilistic power and the mean α as before.
    let meas = crate::ppa::activity::measure(
        &design.netlist,
        SWEEP_ALPHA_CYCLES,
        point.seed,
        SimBackend::Compiled { words: SWEEP_ALPHA_WORDS, threads: 1 },
    )
    .map_err(anyhow::Error::msg)?;
    let alpha_measured = meas.alpha.iter().sum::<f64>() / meas.alpha.len().max(1) as f64;
    let (alpha_opt_measured, power_meas_nw) = match point.flow {
        crate::synth::flow::Flow::Tnn7 => {
            let translated = out.remap.translate_per_net(&meas.alpha);
            let mean =
                translated.iter().sum::<f64>() / translated.len().max(1) as f64;
            let ppa_meas = crate::ppa::report::analyze_with_alpha_remapped(
                &out.mapped,
                &lib,
                crate::harness::GAMMA_CYCLES,
                &meas.alpha,
                &out.remap,
            );
            (mean, ppa_meas.power_nw)
        }
        crate::synth::flow::Flow::Baseline => (alpha_measured, ppa.power_nw),
    };

    // Function: train the engine online (same run_stream pipeline as
    // `run ucr` and the conformance harness), then score a draw-free
    // inference pass. All randomness follows the split_stream discipline
    // documented in the module docs.
    let root = Rng64::seed_from_u64(point.seed);
    let mut weight_rng = root.split_stream(0);
    let mut engine = crate::coordinator::engine_with_theta(
        point.engine,
        point.p,
        point.q,
        theta,
        params,
        &mut weight_rng,
    )?;
    engine.set_sim_backend(sim_backend);
    engine.set_opt_level(opt);
    let t_train = Instant::now();
    for epoch in 0..point.epochs {
        let mut stream = root.split_stream(1 + epoch);
        run_stream(&mut engine, items.clone(), 16, stream.next_u64())?;
    }
    let winners = engine.infer_winners(&items)?;
    let train_ms = t_train.elapsed().as_secs_f64() * 1e3;
    let (fired, rand_index, purity) = score_winners(&winners, &items, point.q);

    Ok(PointResult {
        theta,
        area_um2: ppa.area_um2,
        power_nw: ppa.power_nw,
        leakage_nw: ppa.leakage_nw,
        comp_time_ns: ppa.comp_time_ns,
        edp_fj_ns: ppa.edp_fj_ns,
        alpha_measured,
        alpha_opt_measured,
        power_meas_nw,
        gates_in: out.stats.gates_in,
        cells_out: out.stats.cells_out,
        macros_out: out.stats.macros_out,
        items: items.len(),
        fired,
        rand_index,
        purity,
        synth_ms: out.stats.wall.as_secs_f64() * 1e3,
        train_ms,
    })
}

/// Run a sweep: serve warm points from the cache (when `use_cache`),
/// shard the rest across `spec.threads` workers (0 = machine
/// parallelism), persist every freshly-computed point, and merge rows in
/// canonical grid order. The first point error stops every worker before
/// its next point and aborts the sweep; already-computed points stay
/// cached, so the retry resumes where it failed.
pub fn run_sweep(spec: &SweepSpec, use_cache: bool) -> crate::Result<SweepOutcome> {
    let points = spec.points();
    let sim_backend = spec.resolved_sim_backend();
    let cache = if use_cache {
        Some(PointCache::open(&spec.cache_dir)?)
    } else {
        None
    };

    let mut slots: Vec<Option<(PointResult, bool)>> = vec![None; points.len()];
    let mut todo: Vec<usize> = Vec::new();
    let mut quarantined = 0usize;
    for (i, pt) in points.iter().enumerate() {
        match cache.as_ref().map(|c| c.lookup(pt)) {
            Some(CacheLookup::Hit(r)) => slots[i] = Some((r, true)),
            Some(CacheLookup::Quarantined) => {
                quarantined += 1;
                todo.push(i);
            }
            Some(CacheLookup::Miss) | None => todo.push(i),
        }
    }

    let threads = if spec.threads == 0 {
        crate::tnn::batch::default_threads()
    } else {
        spec.threads
    }
    .clamp(1, todo.len().max(1));

    let next = AtomicUsize::new(0);
    let fresh: Mutex<Vec<(usize, PointResult)>> = Mutex::new(Vec::new());
    let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // Stop promptly once any worker has failed — on a large
                // grid the operator should not wait for the remaining
                // points to finish before seeing the error.
                // POISON-TAG: a panicking sibling poisons this mutex; the
                // data (an error slot / result list) is still coherent,
                // so recover it instead of cascading the panic.
                if first_err.lock().unwrap_or_else(|p| p.into_inner()).is_some() {
                    break;
                }
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= todo.len() {
                    break;
                }
                let i = todo[k];
                let outcome = run_point_guarded(&points[i], || {
                    compute_point_with(&points[i], sim_backend, spec.opt)
                })
                .and_then(|r| {
                    if let Some(c) = &cache {
                        c.store(&points[i], &r)?;
                    }
                    Ok(r)
                });
                match outcome {
                    // POISON-TAG: recover the still-coherent list.
                    Ok(r) => fresh
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .push((i, r)),
                    Err(e) => {
                        // POISON-TAG: recover the still-coherent slot.
                        let mut slot = first_err.lock().unwrap_or_else(|p| p.into_inner());
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        break;
                    }
                }
            });
        }
    });
    // POISON-TAG: the scope has joined every worker; recover the data.
    if let Some(e) = first_err.into_inner().unwrap_or_else(|p| p.into_inner()) {
        return Err(e);
    }

    let computed = {
        let fresh = fresh.into_inner().unwrap_or_else(|p| p.into_inner());
        let n = fresh.len();
        for (i, r) in fresh {
            slots[i] = Some((r, false));
        }
        n
    };
    let rows: Vec<SweepRow> = points
        .into_iter()
        .zip(slots)
        .map(|(point, slot)| {
            let (result, cached) = slot.expect("every point computed or cached");
            SweepRow {
                point,
                result,
                cached,
            }
        })
        .collect();
    let cached = rows.iter().filter(|r| r.cached).count();
    Ok(SweepOutcome {
        spec: spec.clone(),
        rows,
        computed,
        cached,
        quarantined,
    })
}

/// The `key=value` overrides that re-run exactly one grid point — the
/// one-command repro printed when a worker panics.
fn repro_overrides(p: &SweepPoint) -> String {
    format!(
        "geometries={}x{} theta={} flows={} engines={} seeds={} per_cluster={} epochs={}",
        p.p,
        p.q,
        p.theta.name(),
        p.flow.name(),
        p.engine.name(),
        p.seed,
        p.per_cluster,
        p.epochs
    )
}

/// Render a panic payload (the `Box<dyn Any>` from `catch_unwind`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one point's measurement behind a panic guard. A panicking point
/// (a geometry assert deep in synthesis, an engine invariant trip, …)
/// becomes a loud `Err` that names the point's canonical key and the
/// exact `tnn7 sweep` overrides reproducing just that point — instead of
/// unwinding through the worker scope and aborting the whole process with
/// no pointer to the offending point. The executor's first-error protocol
/// then stops the remaining workers cleanly at their next point boundary.
fn run_point_guarded(
    point: &SweepPoint,
    compute: impl FnOnce() -> crate::Result<PointResult>,
) -> crate::Result<PointResult> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(compute)) {
        Ok(outcome) => outcome,
        Err(payload) => Err(anyhow::anyhow!(
            "worker panicked at sweep point [{}]: {}\n  repro: tnn7 sweep {} --no-cache",
            point.canonical(),
            panic_message(&*payload),
            repro_overrides(point),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::synth::flow::Flow;

    fn small_point(engine: EngineKind) -> SweepPoint {
        SweepPoint {
            p: 6,
            q: 2,
            theta: ThetaPolicy::Default,
            flow: Flow::Tnn7,
            engine,
            seed: 11,
            per_cluster: 3,
            epochs: 1,
        }
    }

    #[test]
    fn compute_point_is_reproducible() {
        let p = small_point(EngineKind::Golden);
        let a = compute_point(&p).unwrap();
        let b = compute_point(&p).unwrap();
        // Deterministic fields identical; wall clocks excluded.
        assert_eq!(a.theta, b.theta);
        assert_eq!(a.area_um2, b.area_um2);
        assert_eq!(a.power_nw, b.power_nw);
        assert_eq!(a.edp_fj_ns, b.edp_fj_ns);
        assert_eq!(a.alpha_measured, b.alpha_measured);
        assert_eq!(a.alpha_opt_measured, b.alpha_opt_measured);
        assert_eq!(a.power_meas_nw, b.power_meas_nw);
        assert_eq!(a.gates_in, b.gates_in);
        assert_eq!((a.fired, a.rand_index, a.purity), (b.fired, b.rand_index, b.purity));
        assert_eq!(a.items, 6);
        assert!(a.area_um2 > 0.0 && a.power_nw > 0.0);
        assert!(a.alpha_measured > 0.0, "LFSR column always toggles");
        // TNN7 flow: the per-net path is live, not the mean-α fallback.
        assert!(a.alpha_opt_measured > 0.0 && a.power_meas_nw > 0.0);
        assert_ne!(a.power_meas_nw, a.power_nw, "measured α differs from priors");
    }

    #[test]
    fn sim_backend_choice_never_changes_deterministic_fields() {
        // The cache-key contract: a gate-engine point computed under the
        // interpreter, the compiled backend, and the optimizer-reduced
        // compiled backend must agree on every deterministic field
        // (winners are bit-exact), so cache keys can legitimately exclude
        // both the backend and the opt level.
        let p = small_point(EngineKind::Gate);
        let a =
            compute_point_with(&p, SimBackend::BitParallel64, OptLevel::None).unwrap();
        let b = compute_point_with(
            &p,
            SimBackend::Compiled { words: 1, threads: 1 },
            OptLevel::None,
        )
        .unwrap();
        let c = compute_point_with(
            &p,
            SimBackend::Compiled { words: 2, threads: 1 },
            OptLevel::Inference,
        )
        .unwrap();
        for other in [&b, &c] {
            assert_eq!(a.theta, other.theta);
            assert_eq!(a.alpha_measured, other.alpha_measured);
            assert_eq!(
                (a.fired, a.rand_index, a.purity),
                (other.fired, other.rand_index, other.purity)
            );
            assert_eq!(a.items, other.items);
        }
    }

    #[test]
    fn golden_and_batched_agree_on_draw_free_fields() {
        // Both engines share the weight-draw protocol, so the synthesized
        // hardware and the workload are identical; training trajectories
        // may differ (batched uses a leaner draw discipline).
        let g = compute_point(&small_point(EngineKind::Golden)).unwrap();
        let b = compute_point(&small_point(EngineKind::Batched)).unwrap();
        assert_eq!(g.theta, b.theta);
        assert_eq!(g.area_um2, b.area_um2);
        assert_eq!(g.alpha_measured, b.alpha_measured, "same netlist, same seed");
        assert_eq!(g.gates_in, b.gates_in);
        assert_eq!(g.items, b.items);
    }

    #[test]
    fn result_kv_roundtrip_is_exact() {
        let p = small_point(EngineKind::Golden);
        let r = compute_point(&p).unwrap();
        let doc = r.to_kv();
        let back = PointResult::from_kv(&p, &doc).unwrap();
        assert_eq!(back, r, "shortest-roundtrip floats must survive kv");
    }

    #[test]
    fn panicking_point_reports_canonical_key_and_repro_command() {
        let pt = small_point(EngineKind::Golden);
        let err = run_point_guarded(&pt, || panic!("injected failure"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("injected failure"), "payload surfaced: {err}");
        assert!(err.contains(&pt.canonical()), "canonical key named: {err}");
        assert!(err.contains("repro: tnn7 sweep"), "repro command: {err}");
        assert!(err.contains("geometries=6x2") && err.contains("seeds=11"));
        // String payloads (panic with a formatted message) surface too.
        let err = run_point_guarded(&pt, || panic!("code {}", 7))
            .unwrap_err()
            .to_string();
        assert!(err.contains("code 7"));
        // And a clean compute passes straight through the guard.
        let ok = run_point_guarded(&pt, || compute_point(&pt)).unwrap();
        assert_eq!(ok.items, 6);
    }

    #[test]
    fn truncated_cache_entry_recomputes_once_and_quarantines() {
        let base = std::env::temp_dir()
            .join(format!("tnn7_exec_quarantine_{}", std::process::id()));
        std::fs::remove_dir_all(&base).ok();
        let spec = SweepSpec {
            name: "quarantine-test".into(),
            geometries: vec![(6, 2)],
            flows: vec![Flow::Tnn7],
            engines: vec![EngineKind::Golden],
            seeds: vec![11],
            per_cluster: 3,
            epochs: 1,
            threads: 1,
            cache_dir: base.join("cache"),
            out_dir: base.join("out"),
            ..SweepSpec::default()
        };
        let first = run_sweep(&spec, true).unwrap();
        assert_eq!(
            (first.computed, first.cached, first.quarantined),
            (1, 0, 0),
            "cold run computes the point"
        );
        // Truncate the entry mid-file (a crashed writer's torn state).
        let cache = PointCache::open(&spec.cache_dir).unwrap();
        let point = &spec.points()[0];
        let path = cache.path(point);
        let text = std::fs::read_to_string(&path).unwrap();
        let cut = text.find("train_ms").expect("entry carries train_ms");
        std::fs::write(&path, &text[..cut]).unwrap();
        let second = run_sweep(&spec, true).unwrap();
        assert_eq!(
            (second.computed, second.cached, second.quarantined),
            (1, 0, 1),
            "exactly one recompute plus one quarantine"
        );
        assert!(cache.corrupt_path(point).exists());
        // Deterministic fields of the recompute match the cold run.
        assert_eq!(first.rows[0].result.purity, second.rows[0].result.purity);
        // The recompute re-stored cleanly: a third run is fully warm.
        let third = run_sweep(&spec, true).unwrap();
        assert_eq!((third.computed, third.cached, third.quarantined), (0, 1, 0));
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn error_pct_inverts_purity() {
        let mut r = PointResult::synthetic_for_tests();
        r.purity = 0.8;
        assert!((r.error_pct() - 20.0).abs() < 1e-12);
    }
}
