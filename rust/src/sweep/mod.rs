//! Design-space exploration sweeps with a resumable artifact cache.
//!
//! The paper's headline claim is not one design point but a *design
//! space*: nine macros whose PPA and synthesis-runtime advantages hold
//! from a 40 µW UCR column up to multi-mm² MNIST networks. This module
//! turns "evaluate the whole space" into one declarative job:
//!
//! * [`spec`] — a [`SweepSpec`] names the grid (column geometries `p`×`q`,
//!   θ policy, synthesis flows, behavioral engines, seeds) in the crate's
//!   `key = value` format, or assembles it from CLI flags;
//! * [`exec`] — the executor shards points across worker threads under the
//!   frozen [`Rng64::split_stream`](crate::util::Rng64::split_stream)
//!   determinism contract: deterministic results are bit-exact at any
//!   thread count, and every point runs on the same conformance-checked
//!   engine constructions as `harness::conformance`;
//! * [`cache`] — every finished point persists under a content address
//!   (stable hash of the point definition + a cache version tag), so a
//!   killed sweep resumes instantly and re-runs only missing or
//!   invalidated points;
//! * [`report`] — the merged grid is reported as a deterministic TSV, the
//!   power–error / area–error / EDP–error Pareto frontiers, the
//!   Baseline-vs-TNN7 synthesis-runtime ratio curve (Fig. 12 generalized
//!   to the grid), and a `BENCH_sweep.json` artifact.
//!
//! Entry point: `tnn7 sweep [spec.kv] [--quick] [--no-cache] [key=value …]`
//! (see `docs/ARCHITECTURE.md` §"Sweep subsystem" and the README
//! reproduction matrix).

pub mod cache;
pub mod exec;
pub mod report;
pub mod spec;

pub use cache::{CacheLookup, PointCache, CACHE_VERSION};
pub use exec::{
    compute_point, compute_point_with, run_sweep, PointResult, SweepOutcome, SweepRow,
    SWEEP_ALPHA_CYCLES, SWEEP_ALPHA_WORDS,
};
pub use report::{pareto, print_summary, synth_ratio_curve, tsv, write_reports, ParetoFronts};
pub use spec::{SweepPoint, SweepSpec, ThetaPolicy};
