//! Sweep reporting: the merged per-point table (TSV), Pareto-frontier
//! extraction over the PPA/accuracy trade-offs, the Baseline-vs-TNN7
//! synthesis-runtime ratio curve (the paper's Fig. 12 generalized to the
//! whole grid), and the `BENCH_sweep.json` artifact.
//!
//! The TSV contains **only deterministic fields** — its bytes are
//! invariant under thread count and cache warmth, which is what the
//! resumability tests compare. Wall-clock measurements (synthesis and
//! training times, and the ratio curve built from them) live in the JSON
//! artifact and the console summary.

use super::exec::{SweepOutcome, SweepRow};
use crate::ppa::report::pareto_front;
use crate::util::json::Json;
use std::path::PathBuf;

/// The three Pareto frontiers the sweep extracts, as row indices into
/// [`SweepOutcome::rows`] (each sorted along the frontier; all axes are
/// minimized, with clustering error as the common quality axis).
#[derive(Clone, Debug)]
pub struct ParetoFronts {
    /// Power (nW) vs clustering error (%).
    pub power_error: Vec<usize>,
    /// Area (µm²) vs clustering error (%).
    pub area_error: Vec<usize>,
    /// Energy-delay product (fJ·ns) vs clustering error (%).
    pub edp_error: Vec<usize>,
}

/// Extract the power–error, area–error and EDP–error frontiers of a grid.
pub fn pareto(rows: &[SweepRow]) -> ParetoFronts {
    let with = |f: fn(&SweepRow) -> f64| -> Vec<usize> {
        let pts: Vec<(f64, f64)> = rows
            .iter()
            .map(|r| (f(r), r.result.error_pct()))
            .collect();
        pareto_front(&pts)
    };
    ParetoFronts {
        power_error: with(|r| r.result.power_nw),
        area_error: with(|r| r.result.area_um2),
        edp_error: with(|r| r.result.edp_fj_ns),
    }
}

/// One Baseline/TNN7 pair of the synthesis-runtime ratio curve.
#[derive(Clone, Debug)]
pub struct RatioRow {
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons per column.
    pub q: usize,
    /// Synapse count (the curve's x-axis).
    pub synapses: usize,
    /// Workload seed of the paired points.
    pub seed: u64,
    /// Metered baseline (ASAP7) synthesis wall time, ms.
    pub asap7_ms: f64,
    /// Metered TNN7 synthesis wall time, ms.
    pub tnn7_ms: f64,
}

impl RatioRow {
    /// Baseline-over-TNN7 synthesis-runtime ratio (>1 means the macro
    /// flow is faster; the paper reports 3.17× on average).
    pub fn ratio(&self) -> f64 {
        self.asap7_ms / self.tnn7_ms.max(1e-9)
    }
}

/// Pair up grid points that differ only in flow and compute the
/// synthesis-runtime ratio for each pair, sorted by synapse count. Points
/// without a counterpart under the other flow are skipped (e.g. a spec
/// that sweeps only one flow produces an empty curve).
pub fn synth_ratio_curve(rows: &[SweepRow]) -> Vec<RatioRow> {
    use crate::synth::flow::Flow;
    let mut curve = Vec::new();
    for base in rows.iter().filter(|r| r.point.flow == Flow::Baseline) {
        let want = crate::sweep::spec::SweepPoint {
            flow: Flow::Tnn7,
            ..base.point.clone()
        };
        if let Some(t7) = rows.iter().find(|r| r.point == want) {
            curve.push(RatioRow {
                p: base.point.p,
                q: base.point.q,
                synapses: base.point.synapses(),
                seed: base.point.seed,
                asap7_ms: base.result.synth_ms,
                tnn7_ms: t7.result.synth_ms,
            });
        }
    }
    curve.sort_by_key(|r| (r.synapses, r.p, r.seed));
    curve
}

/// Render the deterministic per-point table. Stable column set and
/// formatting: bytes are identical across thread counts and cache
/// warmth for the same spec (see the module docs).
pub fn tsv(outcome: &SweepOutcome) -> String {
    let mut s = String::from(
        "p\tq\ttheta\tflow\tengine\tseed\tsynapses\tarea_um2\tpower_uw\tcomp_ns\t\
         edp_fj_ns\talpha_meas\talpha_opt\tpower_meas_uw\tgates_in\tcells\tmacros\titems\tfired\trand_index\tpurity\terror_pct\n",
    );
    for r in &outcome.rows {
        let (pt, res) = (&r.point, &r.result);
        s.push_str(&format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:.2}\t{:.3}\t{:.2}\t{:.1}\t{:.5}\t{:.5}\t{:.3}\t{}\t{}\t{}\t{}\t{}\t{:.4}\t{:.4}\t{:.2}\n",
            pt.p,
            pt.q,
            res.theta,
            pt.flow.name(),
            pt.engine.name(),
            pt.seed,
            pt.synapses(),
            res.area_um2,
            res.power_nw / 1000.0,
            res.comp_time_ns,
            res.edp_fj_ns,
            res.alpha_measured,
            res.alpha_opt_measured,
            res.power_meas_nw / 1000.0,
            res.gates_in,
            res.cells_out,
            res.macros_out,
            res.items,
            res.fired,
            res.rand_index,
            res.purity,
            res.error_pct(),
        ));
    }
    s
}

/// Build the `BENCH_sweep.json` document: per-point rows (including the
/// wall-clock fields), the three Pareto frontiers, the synthesis-runtime
/// ratio curve, and cache accounting.
pub fn to_json(outcome: &SweepOutcome) -> Json {
    let fronts = pareto(&outcome.rows);
    let curve = synth_ratio_curve(&outcome.rows);
    Json::obj()
        .set("name", outcome.spec.name.as_str())
        .set("points", outcome.rows.len())
        .set("computed", outcome.computed)
        .set("cached", outcome.cached)
        .set("quarantined", outcome.quarantined)
        .set(
            "rows",
            Json::Arr(
                outcome
                    .rows
                    .iter()
                    .map(|r| {
                        Json::obj()
                            .set("p", r.point.p)
                            .set("q", r.point.q)
                            .set("theta", r.result.theta)
                            .set("flow", r.point.flow.name())
                            .set("engine", r.point.engine.name())
                            .set("seed", Json::Int(r.point.seed as i64))
                            .set("synapses", r.point.synapses())
                            .set("area_um2", r.result.area_um2)
                            .set("power_nw", r.result.power_nw)
                            .set("leakage_nw", r.result.leakage_nw)
                            .set("comp_time_ns", r.result.comp_time_ns)
                            .set("edp_fj_ns", r.result.edp_fj_ns)
                            .set("alpha_measured", r.result.alpha_measured)
                            .set("alpha_opt_measured", r.result.alpha_opt_measured)
                            .set("power_meas_nw", r.result.power_meas_nw)
                            .set("gates_in", r.result.gates_in)
                            .set("cells_out", r.result.cells_out)
                            .set("macros_out", r.result.macros_out)
                            .set("items", r.result.items)
                            .set("fired", r.result.fired)
                            .set("rand_index", r.result.rand_index)
                            .set("purity", r.result.purity)
                            .set("error_pct", r.result.error_pct())
                            .set("synth_ms", r.result.synth_ms)
                            .set("train_ms", r.result.train_ms)
                            .set("cached", r.cached)
                    })
                    .collect(),
            ),
        )
        .set(
            "pareto",
            Json::obj()
                .set("power_error", fronts.power_error)
                .set("area_error", fronts.area_error)
                .set("edp_error", fronts.edp_error),
        )
        .set(
            "synth_runtime_ratio",
            Json::Arr(
                curve
                    .iter()
                    .map(|r| {
                        Json::obj()
                            .set("p", r.p)
                            .set("q", r.q)
                            .set("synapses", r.synapses)
                            .set("seed", Json::Int(r.seed as i64))
                            .set("asap7_ms", r.asap7_ms)
                            .set("tnn7_ms", r.tnn7_ms)
                            .set("ratio", r.ratio())
                    })
                    .collect(),
            ),
        )
}

/// Print the human-readable sweep summary: the point table, frontier
/// membership, the runtime-ratio curve and cache accounting.
pub fn print_summary(outcome: &SweepOutcome) {
    // The quarantine note goes after the closing paren: the "(N computed,
    // M cached)" shape is a CI grep target and must stay byte-stable on
    // clean runs.
    println!(
        "Sweep '{}': {} points ({} computed, {} cached){}",
        outcome.spec.name,
        outcome.rows.len(),
        outcome.computed,
        outcome.cached,
        if outcome.quarantined > 0 {
            format!(", {} corrupt cache entries quarantined", outcome.quarantined)
        } else {
            String::new()
        },
    );
    println!(
        "{:<10} {:>5} | {:<6} {:<8} {:>4} | {:>10} {:>9} {:>8} {:>11} | {:>6} {:>7} | {:>9}",
        "geometry", "theta", "flow", "engine", "seed", "area µm²", "power µW", "comp ns",
        "EDP fJ·ns", "err %", "purity", "synth"
    );
    for r in &outcome.rows {
        println!(
            "{:<10} {:>5} | {:<6} {:<8} {:>4} | {:>10.2} {:>9.3} {:>8.2} {:>11.1} | {:>6.2} {:>7.3} | {:>9}",
            format!("{}x{}", r.point.p, r.point.q),
            r.result.theta,
            r.point.flow.name(),
            r.point.engine.name(),
            r.point.seed,
            r.result.area_um2,
            r.result.power_nw / 1000.0,
            r.result.comp_time_ns,
            r.result.edp_fj_ns,
            r.result.error_pct(),
            r.result.purity,
            if r.cached {
                "cached".to_string()
            } else {
                format!("{:.1} ms", r.result.synth_ms)
            },
        );
    }
    let fronts = pareto(&outcome.rows);
    let describe = |name: &str, front: &[usize]| {
        let members: Vec<String> = front
            .iter()
            .map(|&i| {
                let r = &outcome.rows[i];
                format!("{}x{}/{}", r.point.p, r.point.q, r.point.flow.name())
            })
            .collect();
        println!("Pareto {name}: {}", members.join(" -> "));
    };
    describe("power-error", &fronts.power_error);
    describe("area-error", &fronts.area_error);
    describe("EDP-error", &fronts.edp_error);
    let curve = synth_ratio_curve(&outcome.rows);
    if !curve.is_empty() {
        let avg: f64 = curve.iter().map(|r| r.ratio()).sum::<f64>() / curve.len() as f64;
        println!("Synthesis-runtime ratio (ASAP7/TNN7) by synapse count:");
        for r in &curve {
            println!(
                "  {:>6} synapses ({}x{}): {:>8.2} ms / {:>8.2} ms = {:>5.2}x",
                r.synapses, r.p, r.q, r.asap7_ms, r.tnn7_ms, r.ratio()
            );
        }
        println!("  average {avg:.2}x (paper Fig. 12: 3.17x)");
    }
}

/// Write `sweep.tsv` and `BENCH_sweep.json` into the spec's `out_dir`;
/// returns both paths.
pub fn write_reports(outcome: &SweepOutcome) -> crate::Result<(PathBuf, PathBuf)> {
    std::fs::create_dir_all(&outcome.spec.out_dir)?;
    let tsv_path = outcome.spec.out_dir.join("sweep.tsv");
    std::fs::write(&tsv_path, tsv(outcome))?;
    let json_path = outcome.spec.out_dir.join("BENCH_sweep.json");
    std::fs::write(&json_path, to_json(outcome).to_pretty())?;
    Ok((tsv_path, json_path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::sweep::exec::{PointResult, SweepRow};
    use crate::sweep::spec::{SweepPoint, SweepSpec, ThetaPolicy};
    use crate::synth::flow::Flow;

    fn row(p: usize, flow: Flow, purity: f64, power: f64, synth_ms: f64) -> SweepRow {
        let mut result = PointResult::synthetic_for_tests();
        result.purity = purity;
        result.power_nw = power;
        result.area_um2 = power / 2.0;
        result.edp_fj_ns = power * 3.0;
        result.synth_ms = synth_ms;
        SweepRow {
            point: SweepPoint {
                p,
                q: 2,
                theta: ThetaPolicy::Default,
                flow,
                engine: EngineKind::Golden,
                seed: 7,
                per_cluster: 4,
                epochs: 1,
            },
            result,
            cached: false,
        }
    }

    fn outcome(rows: Vec<SweepRow>) -> SweepOutcome {
        SweepOutcome {
            spec: SweepSpec::default(),
            computed: rows.len(),
            cached: 0,
            quarantined: 0,
            rows,
        }
    }

    #[test]
    fn pareto_prefers_cheap_accurate_points() {
        // r1 dominates r0 (lower power, lower error); r2 trades error for
        // power and survives alongside r1.
        let rows = vec![
            row(8, Flow::Baseline, 0.70, 900.0, 4.0),
            row(10, Flow::Tnn7, 0.80, 700.0, 2.0),
            row(12, Flow::Tnn7, 0.60, 500.0, 2.5),
        ];
        let f = pareto(&rows);
        assert_eq!(f.power_error, vec![2, 1]);
        assert_eq!(f.area_error, vec![2, 1]);
        assert_eq!(f.edp_error, vec![2, 1]);
    }

    #[test]
    fn ratio_curve_pairs_flows_per_geometry() {
        let rows = vec![
            row(8, Flow::Baseline, 0.7, 900.0, 9.0),
            row(8, Flow::Tnn7, 0.7, 700.0, 3.0),
            row(16, Flow::Baseline, 0.7, 900.0, 20.0),
            row(16, Flow::Tnn7, 0.7, 700.0, 4.0),
            // Unpaired geometry: no Tnn7 counterpart -> skipped.
            row(32, Flow::Baseline, 0.7, 900.0, 50.0),
        ];
        let curve = synth_ratio_curve(&rows);
        assert_eq!(curve.len(), 2);
        assert_eq!((curve[0].p, curve[1].p), (8, 16), "sorted by synapses");
        assert!((curve[0].ratio() - 3.0).abs() < 1e-9);
        assert!((curve[1].ratio() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn tsv_is_deterministic_and_excludes_wall_clock() {
        let mk = |synth_ms| outcome(vec![row(8, Flow::Tnn7, 0.75, 800.0, synth_ms)]);
        let (a, b) = (tsv(&mk(1.0)), tsv(&mk(999.0)));
        assert_eq!(a, b, "wall clock must not reach the TSV");
        assert!(a.starts_with("p\tq\ttheta\tflow\tengine\tseed\tsynapses"));
        assert!(a.contains("TNN7"));
        assert!(a.lines().count() == 2);
    }

    #[test]
    fn json_carries_rows_pareto_and_ratio_curve() {
        let o = outcome(vec![
            row(8, Flow::Baseline, 0.7, 900.0, 9.0),
            row(8, Flow::Tnn7, 0.8, 700.0, 3.0),
        ]);
        let j = to_json(&o).to_string();
        assert!(j.contains("\"pareto\""));
        assert!(j.contains("\"synth_runtime_ratio\""));
        assert!(j.contains("\"power_error\""));
        assert!(j.contains("\"error_pct\""));
        assert!(j.contains("\"alpha_measured\""));
        assert!(j.contains("\"alpha_opt_measured\""));
        assert!(j.contains("\"power_meas_nw\""));
        assert!(j.contains("\"cached\""));
        assert!(j.contains("\"quarantined\""));
    }
}
