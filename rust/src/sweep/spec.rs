//! Declarative sweep specifications.
//!
//! A [`SweepSpec`] names a grid over the TNN design space — column
//! geometries `p`×`q`, a θ sizing policy, synthesis flows, behavioral
//! engines, and workload seeds — plus the per-point workload budget. It is
//! parsed from the same `key = value` format as every other config in this
//! crate ([`crate::util::kv::KvDoc`]), and CLI `key=value` overrides merge
//! on top, so a whole experiment campaign is one small text file.

use crate::config::EngineKind;
use crate::gates::{OptLevel, SimBackend};
use crate::synth::flow::Flow;
use crate::tnn::params::TnnParams;
use crate::util::kv::KvDoc;
use std::path::PathBuf;

/// How each point's neuron firing threshold θ is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThetaPolicy {
    /// `TnnParams::default_theta(p)` — the θ ∝ p·w_max/4 rule of [1].
    Default,
    /// Density-scaled θ from the generated workload's measured spike
    /// density (`tnn::encode::sparse_theta`, the `run ucr` sizing rule).
    Sparse,
    /// One fixed θ for every geometry.
    Fixed(u32),
}

impl ThetaPolicy {
    /// Parse `default` / `sparse` / `fixed:<n>`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "default" => Ok(ThetaPolicy::Default),
            "sparse" => Ok(ThetaPolicy::Sparse),
            other => match other.strip_prefix("fixed:") {
                Some(n) => Ok(ThetaPolicy::Fixed(n.parse().map_err(|_| {
                    anyhow::anyhow!("theta: bad fixed value {n:?}")
                })?)),
                None => anyhow::bail!("unknown theta policy {other:?} (default|sparse|fixed:<n>)"),
            },
        }
    }

    /// Canonical spelling (inverse of [`ThetaPolicy::parse`]).
    pub fn name(&self) -> String {
        match self {
            ThetaPolicy::Default => "default".into(),
            ThetaPolicy::Sparse => "sparse".into(),
            ThetaPolicy::Fixed(n) => format!("fixed:{n}"),
        }
    }
}

/// A declarative design-space sweep: the cartesian product of geometries ×
/// flows × engines × seeds, with one workload budget shared by every point.
///
/// ```
/// use tnn7::sweep::SweepSpec;
/// use tnn7::util::kv::KvDoc;
///
/// let doc = KvDoc::parse(
///     "geometries = 8x2,12x2\n\
///      flows = asap7,tnn7\n\
///      seeds = 7\n\
///      per_cluster = 4\n\
///      epochs = 1\n",
/// ).unwrap();
/// let spec = SweepSpec::from_kv(&doc).unwrap();
/// assert_eq!(spec.points().len(), 2 * 2 * 1 * 1); // geoms × flows × engines × seeds
/// ```
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Campaign name (labels reports; not part of point cache keys).
    pub name: String,
    /// Column geometries to sweep, as `(p, q)` pairs.
    pub geometries: Vec<(usize, usize)>,
    /// θ sizing policy applied to every point.
    pub theta: ThetaPolicy,
    /// Synthesis flows to sweep ([`Flow::Baseline`] = ASAP7, [`Flow::Tnn7`]).
    pub flows: Vec<Flow>,
    /// Behavioral engines to sweep (golden / batched / gate; the XLA engine
    /// needs AOT artifacts and is not sweepable).
    pub engines: Vec<EngineKind>,
    /// Workload seeds (each seed is a full grid axis).
    pub seeds: Vec<u64>,
    /// Generated samples per cluster for each point's training workload.
    pub per_cluster: usize,
    /// Training epochs per point.
    pub epochs: u64,
    /// Executor worker threads (0 = machine parallelism).
    pub threads: usize,
    /// On-disk point cache directory.
    pub cache_dir: PathBuf,
    /// Report output directory (`sweep.tsv`, `BENCH_sweep.json`).
    pub out_dir: PathBuf,
    /// Gate-level simulator backend for each point's batched inference
    /// scoring (`sim_backend` key). An **execution knob** like `threads`:
    /// winners are bit-exact across backends, so it is deliberately NOT
    /// part of [`SweepPoint`] or the cache key — a cache warmed under one
    /// backend serves every other backend 100% (CI proves this).
    pub sim_backend: SimBackend,
    /// Lane-block width for a `compiled` `sim_backend` (`sim_words` key).
    pub sim_words: usize,
    /// Netlist optimization level for each point's compiled gate-engine
    /// inference scoring (`opt` key, `none|inference`). An **execution
    /// knob** exactly like `sim_backend`: winners are bit-exact across
    /// levels, so it is deliberately NOT part of [`SweepPoint`] or the
    /// cache key — a cache warmed at one level serves every other level
    /// 100% (CI proves this).
    pub opt: OptLevel,
}

impl Default for SweepSpec {
    fn default() -> Self {
        // The default campaign: a 12-point (p, q) × flow grid — six column
        // geometries spanning wide/tall shapes under both synthesis flows,
        // golden engine, one seed. `tnn7 sweep` with no spec file runs this.
        // The cache location and worker count default from `RunConfig`, so
        // the `cache_dir`/`threads` config keys are the single source of
        // truth for both the run and sweep surfaces.
        let run = crate::config::RunConfig::default();
        SweepSpec {
            name: "default".into(),
            geometries: vec![(8, 2), (10, 3), (12, 2), (16, 3), (20, 2), (16, 4)],
            theta: ThetaPolicy::Default,
            flows: vec![Flow::Baseline, Flow::Tnn7],
            engines: vec![EngineKind::Golden],
            seeds: vec![7],
            per_cluster: 12,
            epochs: 2,
            threads: run.threads,
            cache_dir: run.cache_dir,
            out_dir: ".".into(),
            sim_backend: SimBackend::Compiled {
                words: crate::gates::DEFAULT_SIM_WORDS,
                threads: 1,
            },
            sim_words: crate::gates::DEFAULT_SIM_WORDS,
            opt: OptLevel::None,
        }
    }
}

/// One fully-resolved grid point (everything that defines its result —
/// the cache key hashes exactly these fields plus the cache version).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SweepPoint {
    /// Synapse lines per neuron.
    pub p: usize,
    /// Neurons (clusters) per column.
    pub q: usize,
    /// θ policy this point resolves θ under.
    pub theta: ThetaPolicy,
    /// Synthesis flow.
    pub flow: Flow,
    /// Behavioral engine that runs the training workload.
    pub engine: EngineKind,
    /// Workload seed.
    pub seed: u64,
    /// Samples per cluster in the generated workload.
    pub per_cluster: usize,
    /// Training epochs.
    pub epochs: u64,
}

impl SweepPoint {
    /// Total synapse count (the Fig. 11 x-axis).
    pub fn synapses(&self) -> usize {
        self.p * self.q
    }

    /// Canonical one-line description — the string the cache key hashes.
    /// Every field that can change the result (and the TNN operating
    /// point) must appear here.
    pub fn canonical(&self) -> String {
        let tp = TnnParams::default();
        format!(
            "p={};q={};theta={};flow={};engine={};seed={};per_cluster={};epochs={};wbits={};gamma={}",
            self.p,
            self.q,
            self.theta.name(),
            self.flow.name(),
            self.engine.name(),
            self.seed,
            self.per_cluster,
            self.epochs,
            tp.weight_bits,
            tp.gamma_cycles,
        )
    }
}

fn parse_geometry(s: &str) -> crate::Result<(usize, usize)> {
    let (p, q) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("geometry must be <p>x<q>, got {s:?}"))?;
    let (p, q) = (p.trim().parse()?, q.trim().parse()?);
    anyhow::ensure!(p >= 1 && q >= 1, "geometry {s:?}: p and q must be >= 1");
    Ok((p, q))
}

fn split_list(s: &str) -> impl Iterator<Item = &str> {
    s.split(',').map(str::trim).filter(|t| !t.is_empty())
}

impl SweepSpec {
    /// Load from a kv file; missing keys keep [`SweepSpec::default`] values.
    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        Self::from_kv(&KvDoc::load(path)?)
    }

    /// Build from a parsed kv document; missing keys keep defaults.
    ///
    /// Recognized keys: `name`, `geometries` (`8x2,12x2,…`), `datasets`
    /// (UCR suite names, appended to `geometries`), `theta`
    /// (`default|sparse|fixed:<n>`), `flows` (`asap7,tnn7`), `engines`
    /// (`golden,batched,gate`), `seeds`, `per_cluster`, `epochs`,
    /// `threads`, `cache_dir`, `out_dir`, `sim_backend`
    /// (`scalar|bit-parallel-64|compiled`), `sim_words`, `opt`
    /// (`none|inference`).
    pub fn from_kv(doc: &KvDoc) -> crate::Result<Self> {
        let mut s = SweepSpec::default();
        if let Some(v) = doc.get("name") {
            s.name = v.to_string();
        }
        let mut geoms = Vec::new();
        if let Some(v) = doc.get("geometries") {
            for g in split_list(v) {
                geoms.push(parse_geometry(g)?);
            }
        }
        if let Some(v) = doc.get("datasets") {
            let suite = crate::ucr::ucr_suite();
            for name in split_list(v) {
                let cfg = suite
                    .iter()
                    .find(|c| c.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}"))?;
                geoms.push((cfg.p, cfg.q));
            }
        }
        if !geoms.is_empty() {
            s.geometries = geoms;
        }
        if let Some(v) = doc.get("theta") {
            s.theta = ThetaPolicy::parse(v)?;
        }
        if let Some(v) = doc.get("flows") {
            s.flows = split_list(v).map(Flow::parse).collect::<crate::Result<_>>()?;
        }
        if let Some(v) = doc.get("engines") {
            s.engines = split_list(v)
                .map(|e| {
                    let kind = EngineKind::parse(e)?;
                    anyhow::ensure!(
                        kind != EngineKind::Xla,
                        "the xla engine needs AOT artifacts and cannot be swept"
                    );
                    Ok(kind)
                })
                .collect::<crate::Result<_>>()?;
        }
        if let Some(v) = doc.get("seeds") {
            s.seeds = split_list(v)
                .map(|x| {
                    x.parse()
                        .map_err(|_| anyhow::anyhow!("seeds: bad u64 {x:?}"))
                })
                .collect::<crate::Result<_>>()?;
        }
        if let Some(v) = doc.get_usize("per_cluster")? {
            s.per_cluster = v;
        }
        if let Some(v) = doc.get_u64("epochs")? {
            s.epochs = v;
        }
        if let Some(v) = doc.get_usize("threads")? {
            s.threads = v;
        }
        if let Some(v) = doc.get("cache_dir") {
            s.cache_dir = v.into();
        }
        if let Some(v) = doc.get("out_dir") {
            s.out_dir = v.into();
        }
        if let Some(v) = doc.get("sim_backend") {
            s.sim_backend = SimBackend::parse(v)?;
        }
        if let Some(v) = doc.get_usize("sim_words")? {
            s.sim_words = v;
        }
        if let Some(v) = doc.get("opt") {
            s.opt = OptLevel::parse(v)?;
        }
        s.validate()?;
        Ok(s)
    }

    /// The fully-resolved per-point simulator backend: a `compiled`
    /// selection picks up the `sim_words` lane-block width, single
    /// threaded — grid points are already sharded across the executor's
    /// workers, so per-point settle threading would only oversubscribe.
    pub fn resolved_sim_backend(&self) -> SimBackend {
        match self.sim_backend {
            SimBackend::Compiled { .. } => SimBackend::Compiled {
                words: self.sim_words,
                threads: 1,
            },
            b => b,
        }
    }

    /// Apply `key=value` CLI overrides on top of this spec (same keys as
    /// [`SweepSpec::from_kv`]; list-valued keys replace the whole list).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> crate::Result<()> {
        if overrides.is_empty() {
            return Ok(());
        }
        let mut doc = KvDoc::default();
        for o in overrides {
            let (k, v) = o
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override must be key=value: {o}"))?;
            doc.set(k.trim(), v.trim());
        }
        const KEYS: [&str; 15] = [
            "name", "geometries", "datasets", "theta", "flows", "engines", "seeds",
            "per_cluster", "epochs", "threads", "cache_dir", "out_dir", "sim_backend",
            "sim_words", "opt",
        ];
        for key in doc.keys() {
            anyhow::ensure!(KEYS.contains(&key), "unknown sweep key {key:?}");
        }
        let merged = Self::from_kv(&doc)?;
        for key in doc.keys() {
            match key {
                "name" => self.name = merged.name.clone(),
                "geometries" | "datasets" => self.geometries = merged.geometries.clone(),
                "theta" => self.theta = merged.theta,
                "flows" => self.flows = merged.flows.clone(),
                "engines" => self.engines = merged.engines.clone(),
                "seeds" => self.seeds = merged.seeds.clone(),
                "per_cluster" => self.per_cluster = merged.per_cluster,
                "epochs" => self.epochs = merged.epochs,
                "threads" => self.threads = merged.threads,
                "cache_dir" => self.cache_dir = merged.cache_dir.clone(),
                "out_dir" => self.out_dir = merged.out_dir.clone(),
                "sim_backend" => self.sim_backend = merged.sim_backend,
                "sim_words" => self.sim_words = merged.sim_words,
                "opt" => self.opt = merged.opt,
                _ => unreachable!("key set checked above"),
            }
        }
        self.validate()
    }

    /// A CI-speed campaign: 6 points (3 geometries × both flows), tiny
    /// workload budgets. `tnn7 sweep --quick` runs this.
    pub fn quick() -> Self {
        SweepSpec {
            name: "quick".into(),
            geometries: vec![(6, 2), (8, 2), (7, 3)],
            per_cluster: 4,
            epochs: 1,
            ..SweepSpec::default()
        }
    }

    /// Sanity-check the grid axes.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(!self.geometries.is_empty(), "sweep needs >= 1 geometry");
        anyhow::ensure!(!self.flows.is_empty(), "sweep needs >= 1 flow");
        anyhow::ensure!(!self.engines.is_empty(), "sweep needs >= 1 engine");
        anyhow::ensure!(!self.seeds.is_empty(), "sweep needs >= 1 seed");
        anyhow::ensure!(self.per_cluster >= 1, "per_cluster must be >= 1");
        anyhow::ensure!(self.epochs >= 1, "epochs must be >= 1");
        anyhow::ensure!(
            (1..=64).contains(&self.sim_words),
            "sim_words must be in 1..=64"
        );
        Ok(())
    }

    /// Expand the grid to its fully-resolved points, in canonical order
    /// (geometry-major, then flow, engine, seed). The order is part of the
    /// report contract: merged reports list points in this order whether
    /// they were computed or loaded from cache. Duplicate points (a
    /// geometry listed twice, or `datasets` repeating a `geometries`
    /// shape) are dropped, keeping the first occurrence — they would
    /// waste compute and make two workers race on one cache entry.
    pub fn points(&self) -> Vec<SweepPoint> {
        let mut pts = Vec::new();
        for &(p, q) in &self.geometries {
            for &flow in &self.flows {
                for &engine in &self.engines {
                    for &seed in &self.seeds {
                        pts.push(SweepPoint {
                            p,
                            q,
                            theta: self.theta,
                            flow,
                            engine,
                            seed,
                            per_cluster: self.per_cluster,
                            epochs: self.epochs,
                        });
                    }
                }
            }
        }
        let mut seen = std::collections::HashSet::new();
        pts.retain(|p| seen.insert(p.canonical()));
        pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_has_twelve_points() {
        let spec = SweepSpec::default();
        spec.validate().unwrap();
        assert_eq!(spec.points().len(), 12);
        assert_eq!(SweepSpec::quick().points().len(), 6);
    }

    #[test]
    fn kv_parsing_covers_all_axes() {
        let doc = KvDoc::parse(
            "name = trial\n\
             geometries = 4x2, 8x3\n\
             theta = fixed:9\n\
             flows = tnn7\n\
             engines = golden,batched\n\
             seeds = 1,2,3\n\
             per_cluster = 5\n\
             epochs = 4\n\
             threads = 2\n\
             cache_dir = /tmp/c\n\
             out_dir = /tmp/o\n",
        )
        .unwrap();
        let s = SweepSpec::from_kv(&doc).unwrap();
        assert_eq!(s.name, "trial");
        assert_eq!(s.geometries, vec![(4, 2), (8, 3)]);
        assert_eq!(s.theta, ThetaPolicy::Fixed(9));
        assert_eq!(s.flows, vec![Flow::Tnn7]);
        assert_eq!(s.engines, vec![EngineKind::Golden, EngineKind::Batched]);
        assert_eq!(s.seeds, vec![1, 2, 3]);
        assert_eq!(s.per_cluster, 5);
        assert_eq!(s.epochs, 4);
        assert_eq!(s.threads, 2);
        // 2 geoms × 1 flow × 2 engines × 3 seeds
        assert_eq!(s.points().len(), 12);
    }

    #[test]
    fn datasets_resolve_to_suite_geometries() {
        let doc = KvDoc::parse("datasets = TwoLeadECG,ECG200\n").unwrap();
        let s = SweepSpec::from_kv(&doc).unwrap();
        assert_eq!(s.geometries, vec![(82, 2), (96, 2)]);
        assert!(SweepSpec::from_kv(&KvDoc::parse("datasets = NoSuch\n").unwrap()).is_err());
    }

    #[test]
    fn duplicate_grid_entries_expand_to_one_point() {
        // A repeated geometry — or `datasets` echoing a `geometries` shape —
        // must not produce duplicate points (two workers would race on one
        // cache entry).
        let doc = KvDoc::parse(
            "geometries = 8x2,8x2,82x2\ndatasets = TwoLeadECG\nflows = tnn7\n",
        )
        .unwrap();
        let s = SweepSpec::from_kv(&doc).unwrap();
        assert_eq!(s.geometries, vec![(8, 2), (8, 2), (82, 2), (82, 2)]);
        let pts = s.points();
        assert_eq!(pts.len(), 2, "8x2 and 82x2 once each");
        assert_eq!((pts[0].p, pts[1].p), (8, 82), "first occurrence order kept");
    }

    #[test]
    fn overrides_merge_and_reject_unknown() {
        let mut s = SweepSpec::default();
        s.apply_overrides(&["seeds=9,10".into(), "theta=sparse".into()])
            .unwrap();
        assert_eq!(s.seeds, vec![9, 10]);
        assert_eq!(s.theta, ThetaPolicy::Sparse);
        // untouched axes keep defaults
        assert_eq!(s.geometries.len(), 6);
        assert!(s.apply_overrides(&["bogus=1".into()]).is_err());
        assert!(s.apply_overrides(&["engines=xla".into()]).is_err());
    }

    #[test]
    fn sim_backend_is_an_execution_knob_outside_the_point_definition() {
        let doc = KvDoc::parse("sim_backend = bit-parallel-64\nsim_words = 4\n").unwrap();
        let s = SweepSpec::from_kv(&doc).unwrap();
        assert_eq!(s.sim_backend, SimBackend::BitParallel64);
        assert_eq!(s.sim_words, 4);
        assert_eq!(s.resolved_sim_backend(), SimBackend::BitParallel64);
        let mut s = SweepSpec::default();
        assert_eq!(
            s.resolved_sim_backend(),
            SimBackend::Compiled { words: crate::gates::DEFAULT_SIM_WORDS, threads: 1 }
        );
        s.apply_overrides(&["sim_backend=compiled".into(), "sim_words=8".into()])
            .unwrap();
        assert_eq!(
            s.resolved_sim_backend(),
            SimBackend::Compiled { words: 8, threads: 1 }
        );
        assert!(s.apply_overrides(&["sim_words=0".into()]).is_err());
        // The backend must never reach the point definition (cache keys
        // stay backend-stable): canonical strings don't mention it.
        for p in s.points() {
            assert!(!p.canonical().contains("sim"), "{}", p.canonical());
        }
    }

    #[test]
    fn opt_is_an_execution_knob_outside_the_point_definition() {
        let doc = KvDoc::parse("opt = inference\n").unwrap();
        let s = SweepSpec::from_kv(&doc).unwrap();
        assert_eq!(s.opt, OptLevel::Inference);
        let mut s = SweepSpec::default();
        assert_eq!(s.opt, OptLevel::None, "default level is none");
        s.apply_overrides(&["opt=inference".into()]).unwrap();
        assert_eq!(s.opt, OptLevel::Inference);
        assert!(s.apply_overrides(&["opt=bogus".into()]).is_err());
        // Like sim_backend, opt must never reach the point definition
        // (cache keys stay level-stable): canonical strings don't mention
        // it.
        for p in s.points() {
            assert!(!p.canonical().contains("opt="), "{}", p.canonical());
        }
    }

    #[test]
    fn theta_policy_roundtrips() {
        for t in [ThetaPolicy::Default, ThetaPolicy::Sparse, ThetaPolicy::Fixed(17)] {
            assert_eq!(ThetaPolicy::parse(&t.name()).unwrap(), t);
        }
        assert!(ThetaPolicy::parse("fixed:x").is_err());
        assert!(ThetaPolicy::parse("nope").is_err());
    }

    #[test]
    fn canonical_strings_distinguish_points() {
        let spec = SweepSpec::default();
        let pts = spec.points();
        let mut canon: Vec<String> = pts.iter().map(|p| p.canonical()).collect();
        canon.sort();
        canon.dedup();
        assert_eq!(canon.len(), pts.len(), "canonical strings must be unique");
        assert!(canon[0].contains("wbits=3") && canon[0].contains("gamma=16"));
    }

    #[test]
    fn bad_geometry_rejected() {
        assert!(parse_geometry("8x0").is_err());
        assert!(parse_geometry("8").is_err());
        assert_eq!(parse_geometry(" 82x2 ".trim()).unwrap(), (82, 2));
    }
}
