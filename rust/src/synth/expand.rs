//! Macro expansion: replace every hard-macro instance with its
//! behavioral-RTL gate network (the ASAP7-baseline elaboration step).

use crate::gates::macros9;
use crate::gates::netlist::{Gate, NetBuilder, NetId, Netlist};

/// Expand all macro instances of `nl` into generic gates. The result has no
/// macro instances; inputs/outputs are preserved by name and order.
pub fn expand_macros(nl: &Netlist) -> Netlist {
    let mut b = NetBuilder::new(&nl.name);
    let n = nl.gates.len();
    let mut map: Vec<NetId> = vec![u32::MAX; n];
    // Deferred feedback: (old dff id, new dff id) and (old buf id, new wire).
    let mut dffs: Vec<(usize, NetId)> = Vec::new();
    let mut bufs: Vec<(usize, NetId)> = Vec::new();
    // Expanded macro outputs, filled lazily per instance.
    let mut minst_outs: Vec<Option<Vec<NetId>>> = vec![None; nl.macros.len()];
    let mut input_cursor = 0usize;

    for (i, g) in nl.gates.iter().enumerate() {
        let new = match *g {
            Gate::Input => {
                let (name, _) = &nl.inputs[input_cursor];
                input_cursor += 1;
                b.input(name)
            }
            Gate::Const(v) => b.constant(v),
            Gate::Buf(_) => {
                let w = b.wire();
                bufs.push((i, w));
                w
            }
            Gate::Not(a) => {
                let a = map[a as usize];
                b.not(a)
            }
            Gate::And(a, c) => {
                let (a, c) = (map[a as usize], map[c as usize]);
                b.and(a, c)
            }
            Gate::Or(a, c) => {
                let (a, c) = (map[a as usize], map[c as usize]);
                b.or(a, c)
            }
            Gate::Xor(a, c) => {
                let (a, c) = (map[a as usize], map[c as usize]);
                b.xor(a, c)
            }
            Gate::Mux(s, a, c) => {
                let (s, a, c) = (map[s as usize], map[a as usize], map[c as usize]);
                b.mux(s, a, c)
            }
            Gate::Dff { .. } => {
                let cell = b.dff_cell_vec(1)[0];
                dffs.push((i, cell));
                cell
            }
            Gate::MacroOut { inst, pin } => {
                if minst_outs[inst as usize].is_none() {
                    let m = &nl.macros[inst as usize];
                    let ins: Vec<NetId> =
                        m.inputs.iter().map(|&x| map[x as usize]).collect();
                    debug_assert!(
                        ins.iter().all(|&x| x != u32::MAX),
                        "macro input not yet mapped"
                    );
                    minst_outs[inst as usize] = Some(macros9::expand(m.kind, &mut b, &ins));
                }
                minst_outs[inst as usize].as_ref().unwrap()[pin as usize]
            }
        };
        map[i] = new;
    }

    // Patch feedback.
    for (old, cell) in dffs {
        if let Gate::Dff { d, rst, init } = nl.gates[old] {
            let d = map[d as usize];
            let rst = rst.map(|r| map[r as usize]);
            b.patch_dff_vec(&[cell], &[d], rst, init as u64);
        }
    }
    for (old, w) in bufs {
        if let Gate::Buf(src) = nl.gates[old] {
            b.connect(w, map[src as usize]);
        }
    }
    for (name, net) in &nl.outputs {
        b.output(name, map[*net as usize]);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::column_design::{build_column, BrvSource};
    use crate::gates::sim::Simulator;
    use crate::util::Rng64;

    #[test]
    fn expansion_removes_all_macros() {
        let d = build_column(3, 2, 3, BrvSource::Lfsr);
        let flat = expand_macros(&d.netlist);
        assert!(flat.macros.is_empty());
        assert!(flat.census().comb > d.netlist.census().comb);
        flat.levelize().expect("expanded netlist is acyclic");
        assert_eq!(flat.inputs.len(), d.netlist.inputs.len());
        assert_eq!(flat.outputs.len(), d.netlist.outputs.len());
    }

    #[test]
    fn expanded_column_is_cycle_equivalent_to_macro_column() {
        // Drive both netlists with identical random stimulus for several
        // gamma periods; all primary outputs must agree at every cycle.
        let d = build_column(3, 2, 4, BrvSource::Lfsr);
        let flat = expand_macros(&d.netlist);
        let mut sim_m = Simulator::new(&d.netlist).unwrap();
        let mut sim_f = Simulator::new(&flat).unwrap();
        let mut rng = Rng64::seed_from_u64(2024);
        let in_names: Vec<String> =
            d.netlist.inputs.iter().map(|(n, _)| n.clone()).collect();
        for cycle in 0..200u32 {
            for name in &in_names {
                let v = if name == "GRST" {
                    cycle % 16 == 15
                } else {
                    rng.gen_bool(0.15)
                };
                sim_m.set_input(name, v);
                sim_f.set_input(name, v);
            }
            sim_m.settle();
            sim_f.settle();
            for (name, _) in &d.netlist.outputs {
                assert_eq!(
                    sim_m.get_output(name),
                    sim_f.get_output(name),
                    "output {name} mismatch at cycle {cycle}"
                );
            }
            sim_m.clock();
            sim_f.clock();
        }
    }
}
