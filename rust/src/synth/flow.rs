//! Synthesis flow orchestration with wall-clock metering (the Fig. 12
//! measurement apparatus).

use super::expand::expand_macros;
use super::map::{tech_map, MappedNetlist};
use super::opt::{optimize_tracked, OptStats};
use crate::cells::{self, CellLibrary};
use crate::gates::netlist::Netlist;
use crate::gates::opt::NetRemap;
use std::time::{Duration, Instant};

/// Which cell library / macro policy to synthesize with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flow {
    /// ASAP7 baseline: expand macros into RTL, optimize everything, map to
    /// standard cells (what Genus did with the modules of [6]).
    Baseline,
    /// TNN7: preserve macro instances as hard cells; optimize and map only
    /// the glue logic.
    Tnn7,
}

impl Flow {
    /// The cell library this flow maps to (ASAP7 standard cells for the
    /// baseline, the TNN7 macro suite + glue cells otherwise).
    pub fn library(&self) -> CellLibrary {
        match self {
            Flow::Baseline => cells::asap7(),
            Flow::Tnn7 => cells::tnn7(),
        }
    }

    /// Display name, as used in the paper's tables.
    pub fn name(&self) -> &'static str {
        match self {
            Flow::Baseline => "ASAP7",
            Flow::Tnn7 => "TNN7",
        }
    }

    /// Parse a CLI/config spelling (`asap7`/`baseline` or `tnn7`).
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "asap7" | "baseline" => Ok(Flow::Baseline),
            "tnn7" => Ok(Flow::Tnn7),
            other => anyhow::bail!("unknown flow {other:?} (asap7|tnn7)"),
        }
    }

    /// Synthesize a design under this flow (method form of [`synthesize`]).
    ///
    /// ```
    /// use tnn7::gates::column_design::{build_column, BrvSource};
    /// use tnn7::synth::flow::Flow;
    ///
    /// let design = build_column(4, 2, 4, BrvSource::Lfsr);
    /// let base = Flow::Baseline.run(&design.netlist);
    /// let tnn7 = Flow::Tnn7.run(&design.netlist);
    /// // TNN7 preserves the nine macros as hard cells; the baseline
    /// // expands them into gates, so it enters the optimizer far larger —
    /// // the mechanism behind the paper's Fig. 12 runtime gap.
    /// assert!(tnn7.mapped.macro_count() > 0);
    /// assert_eq!(base.mapped.macro_count(), 0);
    /// assert!(base.stats.gates_in > tnn7.stats.gates_in);
    /// ```
    pub fn run(&self, design: &Netlist) -> SynthOutcome {
        synthesize(design, *self)
    }
}

/// Statistics of one synthesis run.
#[derive(Clone, Debug)]
pub struct SynthStats {
    /// Flow the run used.
    pub flow: Flow,
    /// End-to-end netlist-generation wall time (elaborate/expand + optimize
    /// + map) — the quantity Fig. 12 compares.
    pub wall: Duration,
    /// Macro-expansion (elaboration) wall time (baseline flow only).
    pub expand_wall: Duration,
    /// Logic-optimization wall time.
    pub opt_wall: Duration,
    /// Technology-mapping wall time.
    pub map_wall: Duration,
    /// Gate count entering the optimizer (the search-space size).
    pub gates_in: usize,
    /// Optimizer statistics (iterations, rewrites, work).
    pub opt: OptStats,
    /// Mapped standard-cell count.
    pub cells_out: usize,
    /// Preserved hard-macro count.
    pub macros_out: usize,
}

/// Result of a synthesis run.
pub struct SynthOutcome {
    /// The technology-mapped netlist.
    pub mapped: MappedNetlist,
    /// Metering and inventory statistics.
    pub stats: SynthStats,
    /// Optimizer-input-id → mapped-netlist-id translation (tech mapping
    /// preserves net ids, so this is exactly the logic optimizer's
    /// composed DCE remap). The *input* id space is the design netlist for
    /// [`Flow::Tnn7`]; for [`Flow::Baseline`] it is the macro-expanded
    /// netlist, whose ids do **not** correspond to the design's — per-net
    /// artifacts measured on the design netlist only translate under the
    /// macro-preserving flow.
    pub remap: NetRemap,
}

/// Synthesize a design netlist under the given flow.
pub fn synthesize(design: &Netlist, flow: Flow) -> SynthOutcome {
    let lib = flow.library();
    let t0 = Instant::now();

    let (working, expand_wall) = match flow {
        Flow::Baseline => {
            let te = Instant::now();
            let flat = expand_macros(design);
            (flat, te.elapsed())
        }
        Flow::Tnn7 => (design.clone(), Duration::ZERO),
    };
    let gates_in = working.gates.len();

    let topt = Instant::now();
    let (optimized, opt_stats, remap) = optimize_tracked(working);
    let opt_wall = topt.elapsed();

    let tmap = Instant::now();
    let mapped = tech_map(&optimized, &lib);
    let map_wall = tmap.elapsed();

    let stats = SynthStats {
        flow,
        wall: t0.elapsed(),
        expand_wall,
        opt_wall,
        map_wall,
        gates_in,
        opt: opt_stats,
        cells_out: mapped.cell_count(),
        macros_out: mapped.macro_count(),
    };
    SynthOutcome { mapped, stats, remap }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::column_design::{build_column, BrvSource};

    #[test]
    fn both_flows_synthesize_a_column() {
        let d = build_column(8, 2, 8, BrvSource::Lfsr);
        let base = synthesize(&d.netlist, Flow::Baseline);
        let tnn7 = synthesize(&d.netlist, Flow::Tnn7);
        assert!(base.mapped.macro_count() == 0);
        assert!(tnn7.mapped.macro_count() > 0);
        // The baseline flow must see (and therefore optimize) far more
        // gates — the mechanism behind the Fig. 12 runtime gap.
        assert!(
            base.stats.gates_in > 3 * tnn7.stats.gates_in,
            "baseline {} vs tnn7 {}",
            base.stats.gates_in,
            tnn7.stats.gates_in
        );
        assert!(base.stats.cells_out > tnn7.stats.cells_out);
    }

    #[test]
    fn synthesis_work_scales_with_synapse_count() {
        let small = build_column(4, 2, 4, BrvSource::Lfsr);
        let large = build_column(16, 2, 16, BrvSource::Lfsr);
        let s = synthesize(&small.netlist, Flow::Baseline);
        let l = synthesize(&large.netlist, Flow::Baseline);
        assert!(l.stats.opt.work > 2 * s.stats.opt.work);
    }
}
