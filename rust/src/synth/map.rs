//! Technology mapping: cover the optimized generic netlist with library
//! cells (greedy pattern covering with inverter-fusion: NAND/NOR/XNOR/AOI21/
//! OAI21), and bind macro instances to hard cells when the target library
//! provides them.

use crate::cells::{names, CellLibrary};
use crate::gates::macros9::MacroKind;
use crate::gates::netlist::{Gate, NetId, Netlist};

/// One mapped standard-cell instance.
#[derive(Clone, Debug)]
pub struct MappedCell {
    /// Library cell name.
    pub cell: &'static str,
    /// Output net (generic NetId namespace of the source netlist).
    pub out: NetId,
    /// Input nets.
    pub ins: Vec<NetId>,
    /// Sequential cell?
    pub sequential: bool,
}

/// A technology-mapped netlist: standard cells + hard-macro instances.
#[derive(Clone, Debug)]
pub struct MappedNetlist {
    /// Design name (inherited from the source netlist).
    pub name: String,
    /// Mapped standard cells.
    pub cells: Vec<MappedCell>,
    /// (kind, input nets, output nets) per preserved macro instance.
    pub macros: Vec<(MacroKind, Vec<NetId>, Vec<NetId>)>,
    /// Primary inputs: (name, net).
    pub inputs: Vec<(String, NetId)>,
    /// Primary outputs: (name, net).
    pub outputs: Vec<(String, NetId)>,
    /// Upper bound of the net id namespace.
    pub net_space: usize,
}

impl MappedNetlist {
    /// Mapped standard-cell count.
    pub fn cell_count(&self) -> usize {
        self.cells.len()
    }
    /// Preserved hard-macro count.
    pub fn macro_count(&self) -> usize {
        self.macros.len()
    }
    /// Total pin count (cell pins + macro pins) — the net-area proxy.
    pub fn pin_count(&self) -> usize {
        let cp: usize = self.cells.iter().map(|c| 1 + c.ins.len()).sum();
        let mp: usize = self
            .macros
            .iter()
            .map(|(_, i, o)| i.len() + o.len())
            .sum();
        cp + mp
    }
}

/// Map a generic netlist onto `lib`. Macro instances become hard cells when
/// the library has them; otherwise the caller must have expanded them first
/// (the baseline flow).
pub fn tech_map(nl: &Netlist, lib: &CellLibrary) -> MappedNetlist {
    let n = nl.gates.len();
    let fanout = nl.fanout_counts();
    let mut covered = vec![false; n]; // absorbed into a fused parent cell
    let mut cells: Vec<MappedCell> = Vec::with_capacity(n);

    let single_use = |i: NetId| fanout[i as usize] == 1;

    // Pass 1: inverter-rooted fusion patterns (NAND2/NOR2/XNOR2/AOI21/OAI21).
    for i in 0..n {
        let Gate::Not(a) = nl.gates[i] else { continue };
        if covered[a as usize] || !single_use(a) {
            continue;
        }
        let fused: Option<(&'static str, Vec<NetId>, Vec<NetId>)> = match nl.gates[a as usize]
        {
            Gate::And(x, y) => {
                // AOI21 = !(x·y + z): Not(Or(And(x,y), z)) handled at the Or
                // root below; plain Not(And) → NAND2.
                Some((names::NAND2, vec![x, y], vec![a]))
            }
            Gate::Or(x, y) => {
                // Try OAI/AOI first: Not(Or(And(p,q), z)) → AOI21.
                let aoi = match (nl.gates[x as usize], single_use(x)) {
                    (Gate::And(p, q), true) if !covered[x as usize] => {
                        Some((names::AOI21, vec![p, q, y], vec![a, x]))
                    }
                    _ => match (nl.gates[y as usize], single_use(y)) {
                        (Gate::And(p, q), true) if !covered[y as usize] => {
                            Some((names::AOI21, vec![p, q, x], vec![a, y]))
                        }
                        _ => None,
                    },
                };
                aoi.or(Some((names::NOR2, vec![x, y], vec![a])))
            }
            Gate::Xor(x, y) => Some((names::XNOR2, vec![x, y], vec![a])),
            Gate::And(..) => unreachable!(),
            _ => None,
        };
        // Also try OAI21: Not(And(Or(p,q), z)).
        let fused = if fused.as_ref().map(|f| f.0) == Some(names::NAND2) {
            if let Gate::And(x, y) = nl.gates[a as usize] {
                match (nl.gates[x as usize], single_use(x), covered[x as usize]) {
                    (Gate::Or(p, q), true, false) => {
                        Some((names::OAI21, vec![p, q, y], vec![a, x]))
                    }
                    _ => match (nl.gates[y as usize], single_use(y), covered[y as usize]) {
                        (Gate::Or(p, q), true, false) => {
                            Some((names::OAI21, vec![p, q, x], vec![a, y]))
                        }
                        _ => fused,
                    },
                }
            } else {
                fused
            }
        } else {
            fused
        };
        if let Some((cellname, ins, absorbed)) = fused {
            for &x in &absorbed {
                covered[x as usize] = true;
            }
            cells.push(MappedCell {
                cell: cellname,
                out: i as NetId,
                ins,
                sequential: false,
            });
            covered[i] = true; // the Not root is mapped
        }
    }

    // Pass 2: everything not covered maps 1:1.
    for i in 0..n {
        if covered[i] {
            continue;
        }
        let mc = match nl.gates[i] {
            Gate::Input | Gate::MacroOut { .. } => continue,
            Gate::Const(v) => MappedCell {
                cell: if v { names::TIE1 } else { names::TIE0 },
                out: i as NetId,
                ins: vec![],
                sequential: false,
            },
            Gate::Buf(a) => MappedCell {
                cell: names::BUF,
                out: i as NetId,
                ins: vec![a],
                sequential: false,
            },
            Gate::Not(a) => MappedCell {
                cell: names::INV,
                out: i as NetId,
                ins: vec![a],
                sequential: false,
            },
            Gate::And(a, b) => MappedCell {
                cell: names::AND2,
                out: i as NetId,
                ins: vec![a, b],
                sequential: false,
            },
            Gate::Or(a, b) => MappedCell {
                cell: names::OR2,
                out: i as NetId,
                ins: vec![a, b],
                sequential: false,
            },
            Gate::Xor(a, b) => MappedCell {
                cell: names::XOR2,
                out: i as NetId,
                ins: vec![a, b],
                sequential: false,
            },
            Gate::Mux(s, a, b) => MappedCell {
                cell: names::MUX2,
                out: i as NetId,
                ins: vec![s, a, b],
                sequential: false,
            },
            Gate::Dff { d, rst, .. } => MappedCell {
                cell: if rst.is_some() { names::DFFR } else { names::DFF },
                out: i as NetId,
                ins: match rst {
                    Some(r) => vec![d, r],
                    None => vec![d],
                },
                sequential: true,
            },
        };
        cells.push(mc);
    }

    // Macro instances → hard cells (must exist in the target library).
    let macros: Vec<(MacroKind, Vec<NetId>, Vec<NetId>)> = nl
        .macros
        .iter()
        .map(|m| {
            assert!(
                lib.macro_cell(m.kind).is_some(),
                "library {} cannot map macro {:?}; expand first",
                lib.name,
                m.kind
            );
            (m.kind, m.inputs.clone(), m.outputs.clone())
        })
        .collect();

    MappedNetlist {
        name: nl.name.clone(),
        cells,
        macros,
        inputs: nl.inputs.clone(),
        outputs: nl.outputs.clone(),
        net_space: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cells;
    use crate::gates::netlist::NetBuilder;

    #[test]
    fn fuses_nand_nor_xnor() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.and(a, c);
        let nx = b.not(x);
        let y = b.or(a, c);
        let ny = b.not(y);
        let z = b.xor(a, c);
        let nz = b.not(z);
        b.output("nx", nx);
        b.output("ny", ny);
        b.output("nz", nz);
        let mapped = tech_map(&b.finish(), &cells::asap7());
        let names: Vec<&str> = mapped.cells.iter().map(|c| c.cell).collect();
        assert!(names.contains(&names::NAND2), "{names:?}");
        assert!(names.contains(&names::NOR2), "{names:?}");
        assert!(names.contains(&names::XNOR2), "{names:?}");
        assert_eq!(mapped.cell_count(), 3, "{names:?}");
    }

    #[test]
    fn fuses_aoi21() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let d = b.input("c");
        let x = b.and(a, c);
        let y = b.or(x, d);
        let ny = b.not(y);
        b.output("o", ny);
        let mapped = tech_map(&b.finish(), &cells::asap7());
        assert_eq!(mapped.cell_count(), 1);
        assert_eq!(mapped.cells[0].cell, names::AOI21);
        assert_eq!(mapped.cells[0].ins.len(), 3);
    }

    #[test]
    fn shared_inner_gates_are_not_fused() {
        // The And output feeds both the Not and a primary output: the
        // NAND fusion would duplicate logic, so it must not happen.
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let x = b.and(a, c);
        let nx = b.not(x);
        b.output("x", x);
        b.output("nx", nx);
        let mapped = tech_map(&b.finish(), &cells::asap7());
        let names_v: Vec<&str> = mapped.cells.iter().map(|c| c.cell).collect();
        assert_eq!(names_v.len(), 2);
        assert!(names_v.contains(&names::AND2));
        assert!(names_v.contains(&names::INV));
    }

    #[test]
    fn dffs_map_by_reset_kind() {
        let mut b = NetBuilder::new("t");
        let d = b.input("d");
        let r = b.input("r");
        let q1 = b.dff(d, None, false);
        let q2 = b.dff(d, Some(r), false);
        b.output("q1", q1);
        b.output("q2", q2);
        let mapped = tech_map(&b.finish(), &cells::asap7());
        let mut names_v: Vec<&str> = mapped.cells.iter().map(|c| c.cell).collect();
        names_v.sort();
        assert_eq!(names_v, vec![names::DFFR, names::DFF]); // sorted order
    }

    #[test]
    fn tnn7_library_binds_macros() {
        use crate::gates::macros9::MacroKind;
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let o = b.macro_inst(MacroKind::Pulse2Edge, vec![p, g]);
        b.output("o", o[0]);
        let nl = b.finish();
        let mapped = tech_map(&nl, &cells::tnn7());
        assert_eq!(mapped.macro_count(), 1);
        assert_eq!(mapped.cell_count(), 0);
    }

    #[test]
    #[should_panic(expected = "cannot map macro")]
    fn baseline_library_rejects_macros() {
        use crate::gates::macros9::MacroKind;
        let mut b = NetBuilder::new("t");
        let p = b.input("p");
        let g = b.input("g");
        let o = b.macro_inst(MacroKind::Pulse2Edge, vec![p, g]);
        b.output("o", o[0]);
        tech_map(&b.finish(), &cells::asap7());
    }
}
