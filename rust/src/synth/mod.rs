//! Behavioral → gate synthesis engine (the substitute for Cadence Genus).
//!
//! The flow mirrors the paper's Section II-B methodology:
//!
//! * **Baseline (ASAP7)**: macro instances are *expanded* into their
//!   behavioral-RTL gate networks ([`expand`]), the whole design is run
//!   through the logic optimizer ([`opt`]) and technology-mapped onto the
//!   standard-cell library ([`map`]). This reproduces what Genus did with
//!   the original modules of [6].
//! * **TNN7**: macro instances are *preserved* as hard cells (their Table II
//!   characterization comes from [`crate::cells::tnn7`]); only the glue
//!   logic is optimized and mapped. Because the optimizer's and mapper's
//!   work scales with visible gate count, this flow is mechanistically
//!   faster — the source of the paper's Fig. 12 runtime result ("macro
//!   design instances are preserved and not manipulated during synthesis").
//!
//! [`flow::synthesize`] runs either flow with wall-clock metering and
//! returns the mapped netlist plus statistics.

pub mod expand;
pub mod flow;
pub mod map;
pub mod opt;

pub use expand::expand_macros;
pub use flow::{synthesize, Flow, SynthOutcome, SynthStats};
pub use map::{MappedCell, MappedNetlist};
pub use opt::{optimize, OptStats};
