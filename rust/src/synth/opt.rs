//! Logic optimization passes.
//!
//! Iterated to a fixpoint (bounded): constant folding, buffer collapsing,
//! double-inverter elimination, idempotence/absorption rules, common
//! sub-expression elimination (structural hashing), and dead-code
//! elimination. The work performed scales with the visible gate count —
//! which is exactly why hard-macro preservation speeds synthesis up
//! (Fig. 12 of the paper).

use crate::gates::netlist::{Gate, MacroInst, NetId, Netlist};
use crate::gates::opt::NetRemap;
use std::collections::HashMap;

/// Optimization statistics (also the Fig. 12 "work" evidence).
#[derive(Clone, Copy, Debug, Default)]
pub struct OptStats {
    /// Gate count entering the optimizer.
    pub gates_before: usize,
    /// Gate count after the fixpoint.
    pub gates_after: usize,
    /// Rewrite+DCE iterations until the fixpoint (bounded).
    pub iterations: usize,
    /// Total gate visits across all passes (the optimizer's work measure).
    pub work: u64,
    /// Total rewrites applied.
    pub rewrites: u64,
}

/// Run the optimization pipeline on a netlist.
pub fn optimize(nl: Netlist) -> (Netlist, OptStats) {
    let (nl, stats, _) = optimize_tracked(nl);
    (nl, stats)
}

/// [`optimize`], additionally returning the input-id → output-id
/// [`NetRemap`]: rewrite passes only redirect references (identity on the
/// id space), so the remap is the composition of every DCE compaction. A
/// net that was aliased away or removed maps to `None`; its readers now
/// reference the canonical survivor, which keeps its own activity — which
/// is what lets a per-net toggle vector measured on the *input* netlist be
/// carried onto the optimized mapping
/// ([`crate::ppa::report::analyze_with_alpha_remapped`]).
pub fn optimize_tracked(mut nl: Netlist) -> (Netlist, OptStats, NetRemap) {
    let mut stats = OptStats {
        gates_before: nl.gates.len(),
        ..OptStats::default()
    };
    let mut remap = NetRemap::identity(nl.gates.len(), nl.macros.len());
    const MAX_ITERS: usize = 12;
    loop {
        stats.iterations += 1;
        let rewrites = rewrite_pass(&mut nl, &mut stats.work);
        stats.rewrites += rewrites;
        let removed = match dce(&mut nl, &mut stats.work) {
            Some(step) => {
                remap = remap.then(&step);
                true
            }
            None => false,
        };
        if (rewrites == 0 && !removed) || stats.iterations >= MAX_ITERS {
            break;
        }
    }
    stats.gates_after = nl.gates.len();
    (nl, stats, remap)
}

/// One local-rewrite sweep: computes a replacement map (net → equivalent
/// net) and applies it to all references. Returns the number of rewrites.
fn rewrite_pass(nl: &mut Netlist, work: &mut u64) -> u64 {
    let n = nl.gates.len();
    let mut replace: Vec<NetId> = (0..n as NetId).collect();
    let mut cse: HashMap<Gate, NetId> = HashMap::with_capacity(n);
    let mut changes = 0u64;

    // resolve with path compression
    fn res(replace: &mut [NetId], mut x: NetId) -> NetId {
        while replace[x as usize] != x {
            let up = replace[replace[x as usize] as usize];
            replace[x as usize] = up;
            x = up;
        }
        x
    }

    let is_const = |gates: &[Gate], x: NetId| -> Option<bool> {
        match gates[x as usize] {
            Gate::Const(v) => Some(v),
            _ => None,
        }
    };

    for i in 0..n {
        *work += 1;
        let g = nl.gates[i];
        let simplified: Option<NetId> = match g {
            Gate::Buf(a) => Some(res(&mut replace, a)),
            Gate::Not(a0) => {
                let a = res(&mut replace, a0);
                match nl.gates[a as usize] {
                    Gate::Not(b) => Some(res(&mut replace, b)),
                    Gate::Const(_) => None, // folded below via canonical form
                    _ => None,
                }
            }
            Gate::And(a0, b0) => {
                let (a, b) = (res(&mut replace, a0), res(&mut replace, b0));
                match (is_const(&nl.gates, a), is_const(&nl.gates, b)) {
                    (Some(false), _) | (_, Some(false)) => None, // → const, handled below
                    (Some(true), _) => Some(b),
                    (_, Some(true)) => Some(a),
                    _ if a == b => Some(a),
                    _ => None,
                }
            }
            Gate::Or(a0, b0) => {
                let (a, b) = (res(&mut replace, a0), res(&mut replace, b0));
                match (is_const(&nl.gates, a), is_const(&nl.gates, b)) {
                    (Some(true), _) | (_, Some(true)) => None,
                    (Some(false), _) => Some(b),
                    (_, Some(false)) => Some(a),
                    _ if a == b => Some(a),
                    _ => None,
                }
            }
            Gate::Xor(a0, b0) => {
                let (a, b) = (res(&mut replace, a0), res(&mut replace, b0));
                match (is_const(&nl.gates, a), is_const(&nl.gates, b)) {
                    (Some(false), _) => Some(b),
                    (_, Some(false)) => Some(a),
                    _ => None,
                }
            }
            Gate::Mux(s0, a0, b0) => {
                let (s, a, b) = (
                    res(&mut replace, s0),
                    res(&mut replace, a0),
                    res(&mut replace, b0),
                );
                match is_const(&nl.gates, s) {
                    Some(false) => Some(a),
                    Some(true) => Some(b),
                    None if a == b => Some(a),
                    None => None,
                }
            }
            _ => None,
        };
        if let Some(tgt) = simplified {
            if tgt != i as NetId {
                replace[i] = tgt;
                changes += 1;
                continue;
            }
        }
        // Rebuild the gate with resolved operands, canonicalize, fold
        // const-producing forms, then CSE.
        let rebuilt = match g {
            Gate::Not(a) => {
                let a = res(&mut replace, a);
                match is_const(&nl.gates, a) {
                    Some(v) => Gate::Const(!v),
                    None => Gate::Not(a),
                }
            }
            Gate::And(a, b) => {
                let (mut a, mut b) = (res(&mut replace, a), res(&mut replace, b));
                if a > b {
                    std::mem::swap(&mut a, &mut b);
                }
                match (is_const(&nl.gates, a), is_const(&nl.gates, b)) {
                    (Some(false), _) | (_, Some(false)) => Gate::Const(false),
                    _ => Gate::And(a, b),
                }
            }
            Gate::Or(a, b) => {
                let (mut a, mut b) = (res(&mut replace, a), res(&mut replace, b));
                if a > b {
                    std::mem::swap(&mut a, &mut b);
                }
                match (is_const(&nl.gates, a), is_const(&nl.gates, b)) {
                    (Some(true), _) | (_, Some(true)) => Gate::Const(true),
                    _ => Gate::Or(a, b),
                }
            }
            Gate::Xor(a, b) => {
                let (mut a, mut b) = (res(&mut replace, a), res(&mut replace, b));
                if a > b {
                    std::mem::swap(&mut a, &mut b);
                }
                if a == b {
                    Gate::Const(false)
                } else {
                    Gate::Xor(a, b)
                }
            }
            Gate::Mux(s, a, b) => Gate::Mux(
                res(&mut replace, s),
                res(&mut replace, a),
                res(&mut replace, b),
            ),
            Gate::Buf(a) => Gate::Buf(res(&mut replace, a)),
            Gate::Dff { d, rst, init } => Gate::Dff {
                d: res(&mut replace, d),
                rst: rst.map(|r| res(&mut replace, r)),
                init,
            },
            other => other,
        };
        if rebuilt != g {
            changes += 1;
        }
        nl.gates[i] = rebuilt;
        // CSE on pure-comb, non-state gates (Input/Const excluded: Const is
        // canonical via builder, Input must stay).
        let cse_eligible = matches!(
            rebuilt,
            Gate::Not(_) | Gate::And(..) | Gate::Or(..) | Gate::Xor(..) | Gate::Mux(..)
        );
        if cse_eligible {
            match cse.entry(rebuilt) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    replace[i] = *e.get();
                    changes += 1;
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(i as NetId);
                }
            }
        }
    }

    // Apply the replacement map to every reference.
    for i in 0..n {
        let g = nl.gates[i];
        nl.gates[i] = match g {
            Gate::Buf(a) => Gate::Buf(res(&mut replace, a)),
            Gate::Not(a) => Gate::Not(res(&mut replace, a)),
            Gate::And(a, b) => Gate::And(res(&mut replace, a), res(&mut replace, b)),
            Gate::Or(a, b) => Gate::Or(res(&mut replace, a), res(&mut replace, b)),
            Gate::Xor(a, b) => Gate::Xor(res(&mut replace, a), res(&mut replace, b)),
            Gate::Mux(s, a, b) => Gate::Mux(
                res(&mut replace, s),
                res(&mut replace, a),
                res(&mut replace, b),
            ),
            Gate::Dff { d, rst, init } => Gate::Dff {
                d: res(&mut replace, d),
                rst: rst.map(|r| res(&mut replace, r)),
                init,
            },
            other => other,
        };
    }
    for m in &mut nl.macros {
        for x in &mut m.inputs {
            *x = res(&mut replace, *x);
        }
    }
    for (_, net) in &mut nl.outputs {
        *net = res(&mut replace, *net);
    }
    changes
}

/// Dead-code elimination with compaction: keeps everything reachable from
/// primary outputs, macro instances (always live — they implement declared
/// design function), live DFF fan-ins, and primary inputs (pin interface).
/// Returns the compaction's [`NetRemap`], or `None` when nothing was
/// removed (macro instances are never removed, so the macro map is always
/// identity).
fn dce(nl: &mut Netlist, work: &mut u64) -> Option<NetRemap> {
    let n = nl.gates.len();
    let mut live = vec![false; n];
    let mut stack: Vec<NetId> = Vec::new();
    let mut mark = |x: NetId, live: &mut Vec<bool>, stack: &mut Vec<NetId>| {
        if !live[x as usize] {
            live[x as usize] = true;
            stack.push(x);
        }
    };
    for (_, net) in &nl.outputs {
        mark(*net, &mut live, &mut stack);
    }
    for m in &nl.macros {
        for &x in &m.inputs {
            mark(x, &mut live, &mut stack);
        }
        for &x in &m.outputs {
            mark(x, &mut live, &mut stack);
        }
    }
    for (_, net) in &nl.inputs {
        mark(*net, &mut live, &mut stack);
    }
    let mut fin = Vec::new();
    while let Some(x) = stack.pop() {
        *work += 1;
        let g = nl.gates[x as usize];
        g.comb_fanin(&mut fin);
        for &src in &fin {
            if !live[src as usize] {
                live[src as usize] = true;
                stack.push(src);
            }
        }
        if let Gate::Dff { d, rst, .. } = g {
            if !live[d as usize] {
                live[d as usize] = true;
                stack.push(d);
            }
            if let Some(r) = rst {
                if !live[r as usize] {
                    live[r as usize] = true;
                    stack.push(r);
                }
            }
        }
    }
    let removed = live.iter().filter(|&&l| !l).count();
    if removed == 0 {
        return None;
    }
    // Compact.
    let mut remap: Vec<NetId> = vec![u32::MAX; n];
    let mut gates = Vec::with_capacity(n - removed);
    for i in 0..n {
        if live[i] {
            remap[i] = gates.len() as NetId;
            gates.push(nl.gates[i]);
        }
    }
    for g in &mut gates {
        *g = match *g {
            Gate::Buf(a) => Gate::Buf(remap[a as usize]),
            Gate::Not(a) => Gate::Not(remap[a as usize]),
            Gate::And(a, b) => Gate::And(remap[a as usize], remap[b as usize]),
            Gate::Or(a, b) => Gate::Or(remap[a as usize], remap[b as usize]),
            Gate::Xor(a, b) => Gate::Xor(remap[a as usize], remap[b as usize]),
            Gate::Mux(s, a, b) => {
                Gate::Mux(remap[s as usize], remap[a as usize], remap[b as usize])
            }
            Gate::Dff { d, rst, init } => Gate::Dff {
                d: remap[d as usize],
                rst: rst.map(|r| remap[r as usize]),
                init,
            },
            other => other,
        };
    }
    let macros: Vec<MacroInst> = nl
        .macros
        .iter()
        .map(|m| MacroInst {
            kind: m.kind,
            inputs: m.inputs.iter().map(|&x| remap[x as usize]).collect(),
            outputs: m.outputs.iter().map(|&x| remap[x as usize]).collect(),
        })
        .collect();
    nl.gates = gates;
    nl.macros = macros;
    for (_, net) in &mut nl.inputs {
        *net = remap[*net as usize];
    }
    for (_, net) in &mut nl.outputs {
        *net = remap[*net as usize];
    }
    let new_nets = nl.gates.len();
    let n_macros = nl.macros.len();
    Some(NetRemap::from_maps(
        remap
            .iter()
            .map(|&m| (m != u32::MAX).then_some(m))
            .collect(),
        new_nets,
        (0..n_macros as u32).map(Some).collect(),
        n_macros,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::netlist::NetBuilder;
    use crate::gates::sim::Simulator;
    use crate::util::Rng64;

    #[test]
    fn folds_constants_and_dedupes() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let one = b.constant(true);
        let x = b.and(a, one); // → a
        let y = b.and(a, one); // duplicate → CSE
        let z = b.or(x, y); // or(a,a) → a
        let nz = b.not(z);
        let nnz = b.not(nz); // double inverter → a
        b.output("o", nnz);
        let (nl, stats) = optimize(b.finish());
        assert!(stats.rewrites > 0);
        // Output should collapse to the input directly.
        let (_, out) = nl.outputs[0];
        assert_eq!(out, nl.inputs[0].1);
        assert!(nl.gates.len() <= 3, "gates left: {}", nl.gates.len());
    }

    #[test]
    fn tracked_remap_translates_per_net_vectors_onto_the_optimized_netlist() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let dead = b.xor(a, c); // unreferenced → removed by DCE
        let x = b.and(a, c);
        let y = b.and(a, c); // CSE alias of x → removed
        let o = b.or(x, y); // or(x,x) → alias of x → removed
        b.output("o", o);
        let original = b.finish();
        let n = original.gates.len();
        let (opt, _, remap) = optimize_tracked(original);
        assert_eq!(remap.old_net_count(), n);
        assert_eq!(remap.new_net_count(), opt.gates.len());
        assert!(remap.net(a).is_some() && remap.net(c).is_some());
        assert_eq!(remap.net(dead), None, "dead xor has no image");
        assert_eq!(remap.net(y), None, "CSE alias has no image");
        // The output port now points at the surviving and-gate's image.
        let (_, out) = opt.outputs[0];
        assert_eq!(remap.net(x), Some(out));
        // A per-net vector translates: survivors carry their entries to
        // their new indices, removed entries drop.
        let per_net: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let t = remap.translate_per_net(&per_net);
        assert_eq!(t.len(), opt.gates.len());
        assert_eq!(t[out as usize], x as f64);
    }

    #[test]
    fn dce_removes_unreachable_logic() {
        let mut b = NetBuilder::new("t");
        let a = b.input("a");
        let c = b.input("b");
        let _dead1 = b.xor(a, c);
        let live = b.and(a, c);
        b.output("o", live);
        let (nl, _) = optimize(b.finish());
        // dead xor gone; and + 2 inputs remain.
        assert_eq!(nl.census().comb, 1);
    }

    #[test]
    fn optimization_preserves_function_on_random_logic() {
        let mut rng = Rng64::seed_from_u64(31);
        for trial in 0..20 {
            // random DAG with registers
            let mut b = NetBuilder::new("t");
            let inputs: Vec<_> = (0..6).map(|i| b.input(&format!("i{i}"))).collect();
            let mut nets = inputs.clone();
            for _ in 0..60 {
                let pick = |rng: &mut Rng64, nets: &Vec<u32>| {
                    nets[rng.gen_range(0, nets.len())]
                };
                let a = pick(&mut rng, &nets);
                let c = pick(&mut rng, &nets);
                let g = match rng.gen_range(0, 6) {
                    0 => b.and(a, c),
                    1 => b.or(a, c),
                    2 => b.xor(a, c),
                    3 => b.not(a),
                    4 => {
                        let s = pick(&mut rng, &nets);
                        b.mux(s, a, c)
                    }
                    _ => b.dff(a, None, false),
                };
                nets.push(g);
            }
            for (k, &net) in nets.iter().rev().take(4).enumerate() {
                b.output(&format!("o{k}"), net);
            }
            let original = b.finish();
            let (opt, _) = optimize(original.clone());
            let mut sim_a = Simulator::new(&original).unwrap();
            let mut sim_b = Simulator::new(&opt).unwrap();
            for cycle in 0..50 {
                for i in 0..6 {
                    let v = rng.gen_bool(0.5);
                    sim_a.set_input(&format!("i{i}"), v);
                    sim_b.set_input(&format!("i{i}"), v);
                }
                sim_a.settle();
                sim_b.settle();
                for k in 0..4 {
                    assert_eq!(
                        sim_a.get_output(&format!("o{k}")),
                        sim_b.get_output(&format!("o{k}")),
                        "trial {trial} cycle {cycle} output o{k}"
                    );
                }
                sim_a.clock();
                sim_b.clock();
            }
        }
    }

    #[test]
    fn optimizing_expanded_column_preserves_gamma_behavior() {
        use crate::gates::column_design::{build_column, BrvSource};
        use crate::synth::expand::expand_macros;
        let d = build_column(3, 2, 4, BrvSource::Lfsr);
        let flat = expand_macros(&d.netlist);
        let (opt, stats) = optimize(flat.clone());
        assert!(stats.gates_after < stats.gates_before);
        let mut sim_a = Simulator::new(&flat).unwrap();
        let mut sim_b = Simulator::new(&opt).unwrap();
        let mut rng = Rng64::seed_from_u64(5);
        let names: Vec<String> = flat.inputs.iter().map(|(n, _)| n.clone()).collect();
        for cycle in 0..160u32 {
            for n in &names {
                let v = if n == "GRST" {
                    cycle % 16 == 15
                } else {
                    rng.gen_bool(0.2)
                };
                sim_a.set_input(n, v);
                sim_b.set_input(n, v);
            }
            sim_a.settle();
            sim_b.settle();
            for (n, _) in &flat.outputs {
                assert_eq!(
                    sim_a.get_output(n),
                    sim_b.get_output(n),
                    "cycle {cycle} output {n}"
                );
            }
            sim_a.clock();
            sim_b.clock();
        }
    }
}
